"""Table 6 / Fig 17: delta-tracking overhead — Kishu (Lemma-1 pruned) vs
AblatedKishu(check-all) vs a live-instrumentation provenance tracker
(IPyFlow analogue: sys.settrace line tracing with symbol resolution)."""
from __future__ import annotations

import sys
import time
from typing import Dict, List

import numpy as np

from repro.core import Namespace, TrackedNamespace
from benchmarks.harness import run_kishu
from benchmarks.workloads import ALL_WORKLOADS, Workload


def run_traced(wl: Workload) -> Dict[str, float]:
    """Provenance-style tracker: trace every line executed inside commands,
    resolving local symbols at each step (the runtime-resolution overhead the
    paper criticizes in §2.4)."""
    ns = Namespace()
    for prefix, sub in wl.init.items():
        if isinstance(sub, dict):
            ns.set_tree(prefix, sub)
        else:
            ns[prefix] = sub
    tns = TrackedNamespace(ns)

    resolved = 0

    def tracer(frame, event, arg):
        nonlocal resolved
        frame.f_trace_opcodes = True         # per-op instrumentation
        if event in ("line", "opcode"):
            # symbol resolution: inspect the frame's locals (id() forces a
            # real lookup without mutating anything)
            for v in frame.f_locals.values():
                resolved += id(v) is None
        return tracer

    t_exec = 0.0
    t_overhead = 0.0
    for cname, args in wl.script:
        fn = wl.registry[cname]
        t0 = time.perf_counter()
        fn(tns, **args)
        base = time.perf_counter() - t0

        # re-run under tracing on a scratch copy to measure overhead
        scratch = Namespace({k: (v.copy() if isinstance(v, np.ndarray) else v)
                             for k, v in ns.items()})
        stns = TrackedNamespace(scratch)
        t0 = time.perf_counter()
        sys.settrace(tracer)
        try:
            fn(stns, **args)
        finally:
            sys.settrace(None)
        traced = time.perf_counter() - t0
        t_exec += base
        t_overhead += max(traced - base, 0.0)
    return {"exec_s": t_exec, "track_s": t_overhead}


def run(workloads=None) -> List[dict]:
    out = []
    for wname in (workloads or ALL_WORKLOADS):
        wl = ALL_WORKLOADS[wname]()
        k = run_kishu(wl, undo=False, branch=False)
        ka = run_kishu(wl, check_all=True, undo=False, branch=False)
        tr = run_traced(wl)
        exec_s = max(tr["exec_s"], 1e-9)
        out.append({
            "bench": "tracking",
            "workload": wname,
            "kishu_track_s": round(k.total_track_s, 4),
            "check_all_track_s": round(ka.total_track_s, 4),
            "provenance_track_s": round(tr["track_s"], 4),
            "kishu_pct_runtime": round(100 * k.total_track_s / exec_s, 2),
            "speedup_vs_check_all": round(
                ka.total_track_s / max(k.total_track_s, 1e-9), 2),
            "speedup_vs_provenance": round(
                tr["track_s"] / max(k.total_track_s, 1e-9), 2),
        })
    return out
