"""Fig 12 / Tables 4-5 analogue: leaf-type compatibility matrix.

The paper validates 146 library classes; our state universe is typed array
leaves + framework objects.  For every leaf type we attempt
checkpoint -> mutate -> checkout and classify:
  success         roundtrip bit-exact, update detected
  false_positive  unchanged leaf re-flagged on access (opaque semantics)
  fail            changed leaf NOT detected (must be zero — Table 5)
DumpSession is run alongside to show which types *it* fails on.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (KishuSession, MemoryStore, Namespace, OpaqueLeaf)
from repro.core.baselines import DumpSession


def _jnp(dtype):
    return lambda: jnp.arange(64, dtype=dtype)


LEAF_TYPES: Dict[str, Callable[[], Any]] = {
    # numpy dtypes
    "np.float32": lambda: np.arange(64, dtype=np.float32),
    "np.float64": lambda: np.arange(64, dtype=np.float64),
    "np.float16": lambda: np.arange(64, dtype=np.float16),
    "np.int8": lambda: np.arange(64, dtype=np.int8),
    "np.int16": lambda: np.arange(64, dtype=np.int16),
    "np.int32": lambda: np.arange(64, dtype=np.int32),
    "np.int64": lambda: np.arange(64, dtype=np.int64),
    "np.uint8": lambda: np.arange(64, dtype=np.uint8),
    "np.bool": lambda: np.arange(64) % 2 == 0,
    "np.complex64": lambda: (np.arange(64) + 1j).astype(np.complex64),
    "np.structured": lambda: np.zeros(8, dtype=[("a", "f4"), ("b", "i4")]),
    "np.view_slice": lambda: np.arange(100, dtype=np.float32)[10:50],
    "np.view_strided": lambda: np.arange(100, dtype=np.float32)[::2],
    "np.scalar0d": lambda: np.array(3.5, np.float32),
    # jax arrays
    "jax.float32": _jnp(jnp.float32),
    "jax.bfloat16": _jnp(jnp.bfloat16),
    "jax.float16": _jnp(jnp.float16),
    "jax.int32": _jnp(jnp.int32),
    "jax.int8": _jnp(jnp.int8),
    "jax.uint32": _jnp(jnp.uint32),
    "jax.bool": lambda: jnp.arange(64) % 2 == 0,
    "jax.prng_key": lambda: jax.random.key_data(jax.random.key(7)),
    "jax.prng_typed": lambda: jax.random.key(7),
    # python objects
    "py.int": lambda: 41,
    "py.float": lambda: 2.5,
    "py.str": lambda: "hello",
    "py.bytes": lambda: b"\x00\x01\x02",
    "py.list": lambda: [1, 2, 3],
    "py.dict": lambda: {"a": 1},
    "py.tuple_nested": lambda: (1, (2, [3, 4])),
    "py.none": lambda: None,
    # problematic (generator/lock analogues)
    "opaque.handle": lambda: OpaqueLeaf(payload=1, note="generator"),
    "opaque.remote": lambda: OpaqueLeaf(payload="ray://ds", note="remote ds"),
}


def _mutate(v: Any) -> Any:
    if isinstance(v, OpaqueLeaf):
        return OpaqueLeaf(payload=(v.payload, "mut"), note=v.note)
    if isinstance(v, np.ndarray):
        if v.dtype.fields:
            out = v.copy(); out["a"] = out["a"] + 1; return out
        if v.ndim == 0:
            return np.array(v + 1, v.dtype)   # keep 0-d ndarray type
        return v + v.dtype.type(1) if v.dtype != bool else ~v
    if isinstance(v, jax.Array):
        if jnp.issubdtype(v.dtype, jax.dtypes.prng_key):
            return jax.random.split(v, 1)[0]
        return ~v if v.dtype == jnp.bool_ else v + 1
    if isinstance(v, (int, float)):
        return v + 1
    if isinstance(v, str):
        return v + "!"
    if isinstance(v, bytes):
        return v + b"!"
    if isinstance(v, list):
        return v + [9]
    if isinstance(v, dict):
        return {**v, "z": 9}
    if isinstance(v, tuple):
        return v + (9,)
    if v is None:
        return ()
    raise TypeError(type(v))


def _equal(a: Any, b: Any) -> bool:
    if isinstance(a, OpaqueLeaf):
        return a == b
    if isinstance(a, (np.ndarray, jax.Array)):
        if isinstance(a, jax.Array) and jnp.issubdtype(a.dtype, jax.dtypes.prng_key):
            return bool(jnp.all(jax.random.key_data(a) == jax.random.key_data(b)))
        return np.array_equal(np.asarray(a), np.asarray(b)) and \
            np.asarray(a).dtype == np.asarray(b).dtype
    return a == b and type(a) is type(b)


def run() -> List[dict]:
    rows = []
    for name, mk in LEAF_TYPES.items():
        sess = KishuSession(MemoryStore(), chunk_bytes=1 << 12)

        def mutate(ns):
            ns["x"] = _mutate(ns["x"])

        def read_only(ns):
            _ = ns["x"]
            ns["probe"] = 1 if "probe" not in ns.base else ns["probe"] + 1

        def seed(ns):
            ns["x"] = mk()     # dict leaves must stay leaves (no tree-flatten)

        sess.register("mutate", mutate)
        sess.register("read_only", read_only)
        sess.register("seed", seed)
        sess.init_state({})
        c0 = sess.run("seed")
        cid = sess.run("mutate")
        detected = any("x" in k for k in
                       (tuple(kk) for kk in sess.graph.nodes[cid].manifests))
        # checkout back and verify exactness
        sess.run("mutate")
        sess.checkout(cid)
        v_mut = _mutate(mk())
        exact = _equal(sess.ns["x"], v_mut)
        # false positive check: read-only access flags update?
        sess2 = KishuSession(MemoryStore(), chunk_bytes=1 << 12)
        sess2.register("read_only", read_only)
        sess2.register("seed", seed)
        sess2.init_state({})
        sess2.run("seed")
        c = sess2.run("read_only")
        fp = any("x" in tuple(kk) for kk in sess2.graph.nodes[c].manifests)

        # DumpSession on the same type
        d = DumpSession(MemoryStore())
        ns = Namespace({"x": mk()})
        dump_ok = not d.checkpoint(ns, "t").failed

        if not detected:
            cls = "FAIL(no-detect)"
        elif not exact:
            cls = "FAIL(inexact)"
        elif fp:
            cls = "false_positive(updated-on-access)"
        else:
            cls = "success"
        rows.append({"bench": "compat", "leaf_type": name, "kishu": cls,
                     "dump_session": "ok" if dump_ok else "FAIL"})
    return rows
