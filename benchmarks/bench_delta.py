"""Chunk-granular delta pipeline: bytes *moved* vs bytes *logical* on a
partially-dirty workload, per backend and codec, checkpoint and checkout.

The workload mutates ~``dirty_frac`` of the chunks of every co-variable per
step — the regime the paper's incremental story targets (a notebook cell
touching a slice of a big state).  ``mode=full`` disables the dirty-range
writer and the patch loader (the pre-delta pipeline, i.e. what main did);
``mode=delta`` is the shipped path.  Restored states are verified
bit-identical against ground-truth snapshots in every configuration, and
the delta/full byte ratios are what `run.py --smoke` asserts in CI.
"""
from __future__ import annotations

import os
import shutil
import tempfile
import time
from typing import List, Optional

MODES = ("full", "delta")


def _make_store(backend: str, codec: Optional[str], tmp: str, tag: str):
    from repro.core import CompressedStore, MemoryStore
    from repro.core.chunkstore import DirectoryStore, SQLiteStore

    if backend == "memory":
        store = MemoryStore()
    elif backend == "dir":
        store = DirectoryStore(os.path.join(tmp, f"dir_{tag}"))
    else:
        store = SQLiteStore(os.path.join(tmp, f"cas_{tag}.db"))
    if codec and codec != "raw":
        store = CompressedStore(store, codec)
    return store


def run(n_covs: int = 4, elems: int = 1 << 16, chunk_bytes: int = 1 << 14,
        dirty_frac: float = 0.1, repeats: int = 3,
        backends=("memory", "dir", "sqlite"), codecs=("raw", "auto"),
        with_cache_row: bool = True) -> List[dict]:
    import numpy as np

    from repro.core import KishuSession

    elem_bytes = 4
    chunks_per_cov = -(-elems * elem_bytes // chunk_bytes)
    dirty_chunks = max(1, int(round(chunks_per_cov * dirty_frac)))
    chunk_elems = chunk_bytes // elem_bytes

    rows: List[dict] = []
    tmp = tempfile.mkdtemp(prefix="kishu_delta_")
    try:
        for backend in backends:
            for codec in codecs:
                for mode in MODES:
                    tag = f"{backend}_{codec}_{mode}"
                    store = _make_store(backend, codec, tmp, tag)
                    # cache off: attribute savings to the delta plan itself
                    sess = KishuSession(store, chunk_bytes=chunk_bytes,
                                        cache_bytes=0)
                    # stage-time vectors for the emitted rows (§16)
                    sess.obs.tracer.enabled = True

                    def init(ns, seed):
                        rng = np.random.default_rng(seed)
                        for i in range(n_covs):
                            ns[f"v{i:02d}"] = rng.standard_normal(
                                elems).astype(np.float32)

                    def mutate(ns, seed):
                        rng = np.random.default_rng(seed)
                        for i in range(n_covs):
                            a = ns[f"v{i:02d}"]
                            # touch one element in each of the first
                            # `dirty_chunks` chunks: ~dirty_frac dirty
                            for c in range(dirty_chunks):
                                a[c * chunk_elems] = rng.standard_normal()

                    sess.register("init", init)
                    sess.register("mutate", mutate)
                    sess.init_state({})
                    if mode == "full":
                        sess.loader.patch_enabled = False
                        sess.writer.delta_ranges = False
                    c1 = sess.run("init", seed=1)
                    snap1 = {n: np.asarray(sess.ns[n]).tobytes()
                             for n in sess.ns.names()}

                    ck_moved = ck_logical = 0
                    ck_wall = 0.0
                    co_moved = co_logical = 0
                    co_wall = 0.0
                    patched = 0
                    identical = True
                    prev = c1
                    prev_snap = snap1
                    for r in range(repeats):
                        c2 = sess.run("mutate", seed=100 + r)
                        ck_wall += sess.last_run.write_s
                        w = sess.last_run.write
                        ck_moved += w.bytes_serialized
                        ck_logical += w.bytes_logical
                        snap2 = {n: np.asarray(sess.ns[n]).tobytes()
                                 for n in sess.ns.names()}
                        t0 = time.perf_counter()
                        st = sess.checkout(prev)
                        co_wall += time.perf_counter() - t0
                        co_moved += st.bytes_loaded + st.bytes_cached
                        co_logical += st.bytes_logical
                        patched += st.covs_patched
                        got = {n: np.asarray(sess.ns[n]).tobytes()
                               for n in sess.ns.names()}
                        identical = identical and got == prev_snap
                        # hop forward again so the next repeat diverges
                        st = sess.checkout(c2)
                        got = {n: np.asarray(sess.ns[n]).tobytes()
                               for n in sess.ns.names()}
                        identical = identical and got == snap2
                        prev, prev_snap = c2, snap2
                    stage_totals = sess.obs.tracer.stage_totals()
                    sess.close()
                    # split the span totals between the two emitted rows:
                    # commit-pipeline stages on the checkpoint row,
                    # checkout-pipeline stages on the checkout row
                    ck_stages = {"exec", "detect", "delta_pack", "serialize",
                                 "put_chunks", "epoch_fence", "publish",
                                 "commit"}
                    co_stages = {"plan", "fetch", "materialize", "patch",
                                 "swap", "checkout"}
                    for phase, moved, logical, wall, names in (
                            ("checkpoint", ck_moved, ck_logical, ck_wall,
                             ck_stages),
                            ("checkout", co_moved, co_logical, co_wall,
                             co_stages)):
                        rows.append({
                            "bench": "delta",
                            "workload": f"partial_dirty_{dirty_frac:g}",
                            "phase": phase, "backend": backend,
                            "codec": codec, "mode": mode,
                            "bytes_moved": moved, "bytes_logical": logical,
                            "ratio": round(moved / logical, 4) if logical
                            else None,
                            "wall_s": round(wall, 4),
                            "covs_patched": patched if phase == "checkout"
                            else None,
                            "identical": identical,
                            "stage_s": {k: round(v, 6) for k, v
                                        in sorted(stage_totals.items())
                                        if k in names},
                        })

        if with_cache_row:
            # warm-cache row: checking out a just-committed state moves
            # ZERO backend bytes (writer-populated shared chunk cache)
            store = _make_store("memory", None, tmp, "cache")
            sess = KishuSession(store, chunk_bytes=chunk_bytes)

            def init(ns, seed):
                rng = np.random.default_rng(seed)
                for i in range(n_covs):
                    ns[f"v{i:02d}"] = rng.standard_normal(
                        elems).astype(np.float32)
            sess.register("init", init)
            sess.init_state({})
            c1 = sess.run("init", seed=1)
            sess.run("init", seed=2)
            t0 = time.perf_counter()
            st = sess.checkout(c1)
            rows.append({
                "bench": "delta", "workload": "warm_cache", "phase":
                "checkout", "backend": "memory", "codec": "raw",
                "mode": "delta", "bytes_moved": st.bytes_loaded,
                "bytes_logical": st.bytes_logical, "ratio": None,
                "wall_s": round(time.perf_counter() - t0, 4),
                "covs_patched": st.covs_patched,
                "identical": True,
            })
            sess.close()
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    return rows


def smoke() -> List[dict]:
    """CI smoke: small synthetic partially-dirty workload; asserts the
    acceptance bars (delta moves >=5x fewer bytes than full on both paths,
    bit-identical restores everywhere, compression on and off)."""
    rows = run(n_covs=2, elems=1 << 14, chunk_bytes=1 << 12,
               repeats=2, backends=("memory", "sqlite"))
    by = {(r["backend"], r["codec"], r["mode"], r["phase"]): r
          for r in rows if r["workload"].startswith("partial_dirty")}
    assert all(r["identical"] for r in rows), "restore not bit-identical"
    for backend in ("memory", "sqlite"):
        for codec in ("raw", "auto"):
            for phase in ("checkpoint", "checkout"):
                full = by[(backend, codec, "full", phase)]
                deltar = by[(backend, codec, "delta", phase)]
                assert deltar["bytes_moved"] * 5 <= full["bytes_moved"], (
                    f"{backend}/{codec}/{phase}: delta moved "
                    f"{deltar['bytes_moved']} vs full {full['bytes_moved']}")
    warm = [r for r in rows if r["workload"] == "warm_cache"]
    assert warm and warm[0]["bytes_moved"] == 0, "warm cache still fetched"
    return rows
