"""Perf hillclimb driver (§Perf methodology): compile a cell under a
rules-variant, calibrate its scan-aware costs, and print the three roofline
terms against the baseline artifact.

    PYTHONPATH=src python -m benchmarks.hillclimb \
        --arch smollm-360m --shape prefill_32k --mesh single \
        --variant attn_repl --opt attn_fallback=replicate

Each invocation is one hypothesis->change->measure iteration; results land
in benchmarks/artifacts/dryrun/<cell>__<variant>.json and are summarized
here and in EXPERIMENTS.md §Perf.
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import json

from repro.launch import dryrun
from benchmarks import roofline


def term_row(rec):
    row = roofline.analyze_record(rec)
    return row


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--variant", required=True)
    ap.add_argument("--opt", action="append", default=[],
                    help="rules option key=value (repeatable)")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    rules_opts = {}
    for kv in args.opt:
        k, v = kv.split("=", 1)
        rules_opts[k] = {"true": True, "false": False}.get(v.lower(), v)

    rec = dryrun.run_cell(args.arch, args.shape, args.mesh,
                          variant=args.variant, rules_opts=rules_opts,
                          force=args.force)
    if rec["status"] != "ok":
        print("variant compile FAILED:", rec.get("error", "")[:400])
        return
    dryrun.calibrate_cell(args.arch, args.shape, args.mesh,
                          variant=args.variant, rules_opts=rules_opts,
                          force=args.force)

    art = dryrun.ART_DIR
    with open(os.path.join(
            art, f"{args.arch}__{args.shape}__{args.mesh}.json")) as f:
        base = json.load(f)
    with open(os.path.join(
            art, f"{args.arch}__{args.shape}__{args.mesh}"
                 f"__{args.variant}.json")) as f:
        var = json.load(f)

    b, v = term_row(base), term_row(var)
    print(f"\n{args.arch} x {args.shape} x {args.mesh}  "
          f"variant={args.variant} {rules_opts}")
    print(f"{'term':12s} {'baseline':>12s} {'variant':>12s} {'delta':>8s}")
    for t in ("compute_s", "memory_s", "collective_s"):
        d = (v[t] - b[t]) / max(b[t], 1e-12)
        print(f"{t:12s} {b[t]:12.4e} {v[t]:12.4e} {d:+8.1%}")
    print(f"{'dominant':12s} {b['dominant']:>12s} {v['dominant']:>12s}")
    print(f"{'rf_frac':12s} {b['roofline_frac']:12.4f} "
          f"{v['roofline_frac']:12.4f}")
    print(f"{'argGiB/dev':12s} {b['arg_GiB_per_dev']:12.2f} "
          f"{v['arg_GiB_per_dev']:12.2f}")


if __name__ == "__main__":
    main()
