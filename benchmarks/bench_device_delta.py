"""Fused on-device delta pipeline: device→host traffic vs dirty fraction.

The workload is jax device arrays mutated in-place (``.at[].set``) so that
~``dirty_frac`` of each co-variable's chunks change per cell.  ``mode``:

  device — ``KISHU_DEVICE_DELTA=1``: detection + extraction run as the
           fused delta_pack pass (Pallas on TPU, jnp ref elsewhere); only
           hash pairs, dirty flags and *compacted dirty rows* cross the
           device→host boundary (WriteStats.bytes_dev2host).
  host   — ``KISHU_DEVICE_DELTA=0 KISHU_DEVICE_HASH=0``: the pre-fusion
           path; detection hashes the whole array host-side, so traffic
           equals the full array size every commit.

Every configuration is verified bit-identical against the host path (same
restored states AND the same content-addressed chunk keys), and the
10%-dirty device rows must show traffic ratio ≤ 0.15 of full-array size —
the acceptance bar ``run.py --smoke-device`` asserts in CI.  Rows feed
``BENCH_device_delta.json``; ``benchmarks/roofline.py`` turns the detection
wall times into an achieved-vs-peak HBM bandwidth roofline row.
"""
from __future__ import annotations

import os
import shutil
import tempfile
from typing import List, Optional

from repro.configs.xla_flags import apply_xla_tuning

apply_xla_tuning()      # opt-in ($KISHU_XLA_TUNING=1), no-op on CPU

MODES = ("host", "device")
DIRTY_FRACS = (0.01, 0.10, 0.50)


def _make_store(backend: str, tmp: str, tag: str):
    from repro.core import MemoryStore
    from repro.core.chunkstore import DirectoryStore, SQLiteStore
    if backend == "memory":
        return MemoryStore()
    if backend == "dir":
        return DirectoryStore(os.path.join(tmp, f"dir_{tag}"))
    return SQLiteStore(os.path.join(tmp, f"cas_{tag}.db"))


def _run_one(backend: str, mode: str, dirty_frac: float, tmp: str, *,
             n_covs: int, elems: int, chunk_bytes: int, repeats: int):
    """One (backend, mode, dirty_frac) cell: returns (row, states, keys)."""
    import time

    import jax.numpy as jnp
    import numpy as np

    from repro.core import KishuSession

    env = {"device": ("1", "1"), "host": ("0", "0")}[mode]
    os.environ["KISHU_DEVICE_DELTA"] = env[0]
    os.environ["KISHU_DEVICE_HASH"] = env[1]

    elem_bytes = 4
    chunks_per_cov = -(-elems * elem_bytes // chunk_bytes)
    dirty_chunks = max(1, int(round(chunks_per_cov * dirty_frac)))
    chunk_elems = chunk_bytes // elem_bytes
    touch = np.arange(dirty_chunks, dtype=np.int64) * chunk_elems

    tag = f"{backend}_{mode}_{dirty_frac:g}"
    store = _make_store(backend, tmp, tag)
    sess = KishuSession(store, chunk_bytes=chunk_bytes, cache_bytes=0)

    def init(ns, seed):
        for i in range(n_covs):
            ns[f"v{i:02d}"] = (jnp.arange(elems, dtype=jnp.float32)
                               * (seed + i))

    def mutate(ns, seed):
        vals = jnp.full((dirty_chunks,), float(seed), jnp.float32)
        for i in range(n_covs):
            ns[f"v{i:02d}"] = ns[f"v{i:02d}"].at[touch].set(vals + i)

    sess.register("init", init)
    sess.register("mutate", mutate)
    sess.init_state({})
    sess.run("init", seed=1)

    d2h = serialized = logical = packed = fallbacks = 0
    detect_s = write_s = 0.0
    commits = []
    for r in range(repeats):
        commits.append(sess.run("mutate", seed=100 + r))
        run, w = sess.last_run, sess.last_run.write
        detect_s += run.detect_s
        write_s += run.write_s
        d2h += w.bytes_dev2host
        serialized += w.bytes_serialized
        logical += w.bytes_logical
        packed += w.covs_packed
        fallbacks += w.kernel_fallbacks

    # restored states + the content-addressed chunk keys are the
    # bit-identity witnesses compared across modes
    states = {}
    for cid in commits:
        t0 = time.perf_counter()
        sess.checkout(cid)
        states[len(states)] = {n: np.asarray(sess.ns[n]).tobytes()
                               for n in sess.ns.names()}
    keys = sorted(store.list_chunk_keys())
    sess.close()

    # host mode moves the full array device→host per detection pass
    traffic = d2h if mode == "device" else logical
    row = {
        "bench": "device_delta", "backend": backend, "mode": mode,
        "dirty_frac": dirty_frac,
        "bytes_dev2host": traffic,
        "bytes_logical": logical,
        "traffic_ratio": round(traffic / logical, 4) if logical else None,
        "bytes_serialized": serialized,
        "covs_packed": packed,
        "kernel_fallbacks": fallbacks,
        "detect_s": round(detect_s, 5),
        "write_s": round(write_s, 5),
    }
    return row, states, keys


def run(n_covs: int = 2, elems: int = 1 << 16, chunk_bytes: int = 1 << 12,
        repeats: int = 3, backends=("memory", "sqlite"),
        dirty_fracs=DIRTY_FRACS) -> List[dict]:
    saved = {k: os.environ.get(k)
             for k in ("KISHU_DEVICE_DELTA", "KISHU_DEVICE_HASH")}
    rows: List[dict] = []
    tmp = tempfile.mkdtemp(prefix="kishu_devdelta_")
    try:
        for backend in backends:
            for frac in dirty_fracs:
                per_mode = {}
                for mode in MODES:
                    row, states, keys = _run_one(
                        backend, mode, frac, tmp, n_covs=n_covs,
                        elems=elems, chunk_bytes=chunk_bytes,
                        repeats=repeats)
                    per_mode[mode] = (row, states, keys)
                h_row, h_states, h_keys = per_mode["host"]
                d_row, d_states, d_keys = per_mode["device"]
                identical = (h_states == d_states and h_keys == d_keys)
                for row in (h_row, d_row):
                    row["identical"] = identical
                    rows.append(row)
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
        for k, v in saved.items():       # never leak the forced env
            if v is None:
                os.environ.pop(k, None)
            else:
                os.environ[k] = v
    return rows


def smoke() -> List[dict]:
    """CI gate (CPU interpreter path): the fused pipeline must engage, stay
    bit-identical to the host path on every backend, and on the 10%-dirty
    workload move ≤ 0.15 of full-array size device→host."""
    rows = run(n_covs=2, elems=1 << 14, chunk_bytes=1 << 12, repeats=2)
    assert all(r["identical"] for r in rows), \
        "device path not bit-identical to host path"
    dev = [r for r in rows if r["mode"] == "device"]
    assert dev and all(r["covs_packed"] > 0 for r in dev), \
        "fused delta pack never engaged on the device path"
    for r in dev:
        if r["dirty_frac"] <= 0.10:
            assert r["traffic_ratio"] is not None \
                and r["traffic_ratio"] <= 0.15, (
                    f"{r['backend']}@{r['dirty_frac']}: device→host ratio "
                    f"{r['traffic_ratio']} > 0.15")

    # pallas-kernel parity on the interpreter (the TPU kernel itself, not
    # just the jnp ref the auto probe lands on under CPU)
    import jax.numpy as jnp
    import numpy as np

    from repro.core import hashing
    from repro.kernels.delta_pack.ops import delta_pack

    rng = np.random.default_rng(7)
    a = rng.integers(0, 255, 4096 * 3 + 5, dtype=np.uint8)
    prev = hashing.chunk_hashes_np(a.tobytes(), 1024)
    b = a.copy()
    b[2048] ^= 0xFF
    pack = delta_pack(jnp.asarray(b), prev, 1024, backend="pallas",
                      interpret=True)
    assert np.array_equal(pack.hashes,
                          hashing.chunk_hashes_np(b.tobytes(), 1024))
    assert list(pack.dirty) == [2]
    (ci, data), = list(pack.read_chunks())
    assert data == b[2048:3072].tobytes()
    rows.append({"bench": "device_delta", "backend": "-",
                 "mode": "pallas_interpret", "dirty_frac": None,
                 "identical": True, "covs_packed": 1})
    return rows
