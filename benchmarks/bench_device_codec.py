"""Closed PCIe loop: on-device codec (write) + fused scatter (checkout).

The workload is compressible jax int32 device arrays (values < 2**7, so
24+ of every word's 32 bit-planes are constant) mutated in-place so that
~``dirty_frac`` of each co-variable's chunks change per cell.  ``mode``:

  host   — every device feature off: detection hashes host-side and the
           full array crosses the PCIe boundary each commit.
  device — ``KISHU_DEVICE_DELTA=1`` only: the fused delta pack ships raw
           compacted dirty rows device→host; checkout patches with the
           per-chunk ``dynamic_update_slice`` loop (the DUS baseline).
  codec  — ``KISHU_DEVICE_CODEC=1 KISHU_DEVICE_SCATTER=1`` on top: dirty
           rows are bitshuffle/RLE-encoded *on device* so only bit-plane
           payloads + masks cross PCIe (WriteStats.bytes_dev2host), and
           checkout uploads compacted rows once and scatters every dirty
           chunk of a co-variable in one Pallas pass
           (CheckoutStats.covs_scattered / bytes_host2dev).

Every configuration must restore bit-identical states AND produce the
same sorted content-addressed chunk keys (CAS keys stay logical-byte no
matter how chunks are stored).  The 10%-dirty codec rows must show
device→host traffic ≤ 0.05 of the logical array size, and the fused
scatter's p50 checkout latency must not regress past the DUS baseline —
the acceptance bars ``run.py --smoke-device-codec`` asserts in CI.
Rows feed ``BENCH_device_codec.json``.
"""
from __future__ import annotations

import os
import shutil
import tempfile
from typing import List

from repro.configs.xla_flags import apply_xla_tuning

apply_xla_tuning()      # opt-in ($KISHU_XLA_TUNING=1), no-op on CPU

MODES = ("host", "device", "codec_dus", "codec")
DIRTY_FRACS = (0.10, 0.50)

_ENV_KEYS = ("KISHU_DEVICE_DELTA", "KISHU_DEVICE_HASH",
             "KISHU_DEVICE_CODEC", "KISHU_DEVICE_SCATTER")
# codec_dus isolates the checkout-side change: same on-device encode and
# same stored frames as "codec", but patches through the per-chunk DUS
# loop — the honest latency baseline for the fused scatter.
#              delta hash codec scatter
_ENV = {
    "host":      ("0", "0", "0", "0"),
    "device":    ("1", "1", "0", "0"),
    "codec_dus": ("1", "1", "1", "0"),
    "codec":     ("1", "1", "1", "1"),
}


def _make_store(backend: str, tmp: str, tag: str):
    from repro.core import MemoryStore
    from repro.core.chunkstore import DirectoryStore, SQLiteStore
    if backend == "memory":
        return MemoryStore()
    if backend == "dir":
        return DirectoryStore(os.path.join(tmp, f"dir_{tag}"))
    return SQLiteStore(os.path.join(tmp, f"cas_{tag}.db"))


def _p50(xs: List[float]) -> float:
    xs = sorted(xs)
    return xs[(len(xs) - 1) // 2] if xs else 0.0


def _run_one(backend: str, mode: str, dirty_frac: float, tmp: str, *,
             n_covs: int, elems: int, chunk_bytes: int, repeats: int):
    """One (backend, mode, dirty_frac) cell: returns (row, states, keys)."""
    import time

    import jax.numpy as jnp
    import numpy as np

    from repro.core import KishuSession

    for k, v in zip(_ENV_KEYS, _ENV[mode]):
        os.environ[k] = v

    elem_bytes = 4
    chunks_per_cov = -(-elems * elem_bytes // chunk_bytes)
    dirty_chunks = max(1, int(round(chunks_per_cov * dirty_frac)))
    chunk_elems = chunk_bytes // elem_bytes
    touch = np.arange(dirty_chunks, dtype=np.int64) * chunk_elems

    tag = f"{backend}_{mode}_{dirty_frac:g}"
    store = _make_store(backend, tmp, tag)
    sess = KishuSession(store, chunk_bytes=chunk_bytes, cache_bytes=0)

    def init(ns, seed):
        # values < 2**7: bit-planes 7..31 of every int32 word are all-zero,
        # the shape the bitshuffle codec is built for
        for i in range(n_covs):
            ns[f"v{i:02d}"] = (jnp.arange(elems, dtype=jnp.int32)
                               * (seed + i)) % 97

    def mutate(ns, seed):
        vals = jnp.full((dirty_chunks,), seed % 89, jnp.int32)
        for i in range(n_covs):
            ns[f"v{i:02d}"] = ns[f"v{i:02d}"].at[touch].set(vals + i)

    sess.register("init", init)
    sess.register("mutate", mutate)
    sess.init_state({})
    sess.run("init", seed=1)

    d2h = serialized = logical = encoded = skipped = fallbacks = 0
    commits = []
    for r in range(repeats):
        commits.append(sess.run("mutate", seed=100 + r))
        w = sess.last_run.write
        d2h += w.bytes_dev2host
        serialized += w.bytes_serialized
        logical += w.bytes_logical
        encoded += w.chunks_encoded
        skipped += w.chunks_codec_skipped
        fallbacks += w.kernel_fallbacks

    # restored states + the content-addressed chunk keys are the
    # bit-identity witnesses compared across modes.  The first pass over
    # the commits is the untimed warmup (jit compiles of the scatter /
    # DUS patch kernels land here) and captures the witness states; the
    # second pass re-walks the same commits for the latency samples.
    states = {}
    patched = scattered = h2d = 0
    patch_wall: List[float] = []
    for cid in commits:
        cstats = sess.checkout(cid)
        patched += cstats.covs_patched
        scattered += cstats.covs_scattered
        h2d += cstats.bytes_host2dev
        states[len(states)] = {n: np.asarray(sess.ns[n]).tobytes()
                               for n in sess.ns.names()}
    for cid in commits:
        t0 = time.perf_counter()
        cstats = sess.checkout(cid)
        patch_wall.append(time.perf_counter() - t0)
        patched += cstats.covs_patched
        scattered += cstats.covs_scattered
        h2d += cstats.bytes_host2dev
    keys = sorted(store.list_chunk_keys())
    sess.close()

    # host mode moves the full array device→host per detection pass
    traffic = d2h if mode != "host" else logical
    row = {
        "bench": "device_codec", "backend": backend, "mode": mode,
        "dirty_frac": dirty_frac,
        "bytes_dev2host": traffic,
        "bytes_logical": logical,
        "traffic_ratio": round(traffic / logical, 4) if logical else None,
        "bytes_serialized": serialized,
        "bytes_host2dev": h2d,
        "chunks_encoded": encoded,
        "chunks_codec_skipped": skipped,
        "covs_patched": patched,
        "covs_scattered": scattered,
        "kernel_fallbacks": fallbacks,
        "checkout_p50_s": round(_p50(patch_wall), 5),
    }
    return row, states, keys


def run(n_covs: int = 2, elems: int = 1 << 16, chunk_bytes: int = 1 << 12,
        repeats: int = 3, backends=("memory", "sqlite"),
        dirty_fracs=DIRTY_FRACS) -> List[dict]:
    saved = {k: os.environ.get(k) for k in _ENV_KEYS}
    rows: List[dict] = []
    tmp = tempfile.mkdtemp(prefix="kishu_devcodec_")
    try:
        for backend in backends:
            for frac in dirty_fracs:
                per_mode = {}
                for mode in MODES:
                    row, states, keys = _run_one(
                        backend, mode, frac, tmp, n_covs=n_covs,
                        elems=elems, chunk_bytes=chunk_bytes,
                        repeats=repeats)
                    per_mode[mode] = (row, states, keys)
                _, h_states, h_keys = per_mode["host"]
                identical = all(
                    per_mode[m][1] == h_states and per_mode[m][2] == h_keys
                    for m in MODES)
                for mode in MODES:
                    per_mode[mode][0]["identical"] = identical
                    rows.append(per_mode[mode][0])
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
        for k, v in saved.items():       # never leak the forced env
            if v is None:
                os.environ.pop(k, None)
            else:
                os.environ[k] = v
    return rows


def smoke() -> List[dict]:
    """CI gate (CPU interpreter path): the codec must engage and beat the
    0.05 PCIe-traffic bar at 10% dirty, the fused scatter must cover every
    patched co-variable in one pass without regressing past the DUS
    baseline, and every mode must stay bit-identical on every backend."""
    rows = run(n_covs=2, elems=1 << 14, chunk_bytes=1 << 12, repeats=2)
    assert all(r["identical"] for r in rows), \
        "codec/scatter path not bit-identical to host path"
    codec = [r for r in rows if r["mode"] == "codec"]
    assert codec and all(r["chunks_encoded"] > 0 for r in codec), \
        "device codec never engaged on the codec path"
    for r in codec:
        if r["dirty_frac"] <= 0.10:
            assert r["traffic_ratio"] is not None \
                and r["traffic_ratio"] <= 0.05, (
                    f"{r['backend']}@{r['dirty_frac']}: device→host ratio "
                    f"{r['traffic_ratio']} > 0.05")
        assert r["covs_patched"] > 0 \
            and r["covs_scattered"] == r["covs_patched"], (
                f"{r['backend']}@{r['dirty_frac']}: "
                f"{r['covs_scattered']}/{r['covs_patched']} patched covs "
                f"went through the fused scatter")
        assert r["bytes_host2dev"] > 0, "host→device accounting missing"
    # p50 latency: one fused scatter per cov must not regress past the
    # per-chunk DUS loop reading the same stored frames (1.5x headroom
    # absorbs CPU timer jitter in CI)
    by_cell = {}
    for r in rows:
        by_cell.setdefault((r["backend"], r["dirty_frac"]),
                           {})[r["mode"]] = r
    for (backend, frac), cell in by_cell.items():
        dus, sc = cell["codec_dus"]["checkout_p50_s"], \
            cell["codec"]["checkout_p50_s"]
        assert sc <= max(dus * 1.5, dus + 0.005), (
            f"{backend}@{frac}: scatter checkout p50 {sc}s regressed past "
            f"DUS baseline {dus}s")

    # pallas-kernel parity on the interpreter (the TPU kernels themselves,
    # not just the jnp refs the auto probe lands on under CPU)
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels.delta_codec import host as codec_host
    from repro.kernels.delta_codec.kernel import codec_encode_pallas
    from repro.kernels.delta_codec.host import (frames_from_encoded,
                                                bitplane_decompress,
                                                _FRAME_HDR)
    from repro.kernels.patch_scatter.kernel import patch_scatter_pallas

    rng = np.random.default_rng(11)
    rows_np = (rng.integers(0, 97, (8, 256), dtype=np.int64)
               .astype(np.uint32))
    gw = 256
    masks, count, planes = codec_encode_pallas(
        jnp.asarray(rows_np), gw=gw, interpret=True)
    n = int(np.asarray(count)[0, 0])
    frames = frames_from_encoded(
        np.asarray(masks), np.asarray(planes)[:n], 1, gw,
        [gw * 4] * rows_np.shape[0])
    for i in range(rows_np.shape[0]):
        want = rows_np[i].tobytes()
        assert codec_host.bitplane_compress(want) == frames[i][_FRAME_HDR:]
        assert bitplane_decompress(frames[i][_FRAME_HDR:]) == want

    words = jnp.asarray(rng.integers(0, 2**32, (16, 64), dtype=np.uint64)
                        .astype(np.uint32))
    new_rows = jnp.asarray(rng.integers(0, 2**32, (3, 64), dtype=np.uint64)
                           .astype(np.uint32))
    idx = jnp.asarray([1, 7, 14], jnp.int32)
    want_np = np.asarray(words).copy()
    want_np[[1, 7, 14]] = np.asarray(new_rows)
    got = patch_scatter_pallas(words, idx, new_rows, interpret=True)
    assert np.array_equal(np.asarray(got), want_np)
    rows.append({"bench": "device_codec", "backend": "-",
                 "mode": "pallas_interpret", "dirty_frac": None,
                 "identical": True, "chunks_encoded": rows_np.shape[0]})
    return rows
