"""Cost-based checkout planner: fetch-only vs planner-auto on a slow store.

The planner's bet (DESIGN.md §18) is that on a slow/remote store a large
*derived* co-variable is cheaper to recompute from its recorded command
than to fetch, while in-place-dirtied state is still cheapest as a chunk
patch.  The workload makes both lanes load-bearing:

  ``w``     large array, dirtied in place at rate *d* per cell — the
            planner must keep it on the patch lane (dirty chunks only);
  ``seed``  one small chunk, never changes after init;
  ``big``   large array recomputed each step by a ``derive`` cell whose
            only data read is ``seed`` — its replay closure is one cheap
            command plus a one-chunk fetch, vs a full fetch of ``big``.

Every store read goes through :class:`benchmarks.bench_fabric.DeviceStore`
(a lock-serialized queue charging ``read_latency_s`` per chunk), with the
session cache off, so checkout wall time tracks chunks fetched.  A warmup
round trip feeds the planner's online cost model the device's real get
rate before anything is timed.

Per dirty rate {1, 10, 50}% the benchmark reports p50 checkout wall for
``plan_mode="off"`` vs ``"auto"``, the planner's estimate-vs-actual error,
and three identity checks: restored arrays bit-identical across modes,
the two stores hold identical chunk-key sets (content-addressed writes are
untouched by planning), and ``kishu plan``'s priced paths equal the
executed ``covs_planned_*`` stats.  ``smoke()`` pins the ≥1.5× bar at the
10%-dirty point.
"""
from __future__ import annotations

import os
import statistics
import tempfile
import shutil
import time
from typing import Dict, List

from benchmarks.bench_fabric import DeviceStore
from repro.core.chunkstore import DirectoryStore

ELEMS = 1 << 16             # w / big: 256 KiB float32 = 64 x 4 KiB chunks
SEED_ELEMS = 256            # seed: a single chunk
CHUNK_BYTES = 1 << 12
READ_LATENCY_S = 0.002
DIRTY_FRACS = (0.01, 0.10, 0.50)
STEPS = 3


def _register(sess, elems: int, chunk_bytes: int) -> None:
    import numpy as np

    chunk_elems = chunk_bytes // 4

    def init(ns):
        ns["w"] = np.arange(elems, dtype=np.float32)
        ns["seed"] = np.linspace(0.0, 1.0, SEED_ELEMS).astype(np.float32)

    def touch(ns, step, dirty_chunks):
        a = ns["w"]                     # in-place dirty: patch-lane food
        for c in range(dirty_chunks):
            a[c * chunk_elems] = np.float32(step * 1000 + c)

    def derive(ns, scale):
        seed = ns["seed"]               # the ONLY data read: replay closure
        ns["big"] = (np.arange(elems, dtype=np.float32)
                     + np.float32(seed.sum())) * np.float32(scale)

    sess.register("init", init)
    sess.register("touch", touch)
    sess.register("derive", derive)


def _snapshot(sess) -> Dict[str, bytes]:
    import numpy as np
    return {n: np.asarray(sess.ns[n]).tobytes() for n in sess.ns.names()}


def _one_mode(base_dir: str, mode: str, dirty_frac: float, *,
              repeats: int, elems: int, chunk_bytes: int,
              read_latency_s: float) -> dict:
    from repro.core import KishuSession

    n_chunks = (elems * 4) // chunk_bytes
    dirty_chunks = max(1, int(round(n_chunks * dirty_frac)))
    path = os.path.join(base_dir, f"{mode}_{dirty_frac:g}")
    device = DeviceStore(DirectoryStore(path), read_latency_s)
    sess = KishuSession(device, chunk_bytes=chunk_bytes, cache_bytes=0,
                        plan_mode=mode)
    _register(sess, elems, chunk_bytes)
    sess.init_state({})
    sess.run("init")
    ids = []
    for r in range(1, STEPS + 1):
        sess.run("touch", step=r, dirty_chunks=dirty_chunks)
        ids.append(sess.run("derive", scale=r))
    target, head = ids[0], ids[-1]

    # warmup round trip: snapshots both states AND feeds the cost model
    # the device's observed get rate before anything is timed
    sess.checkout(target)
    snap_target = _snapshot(sess)
    sess.checkout(head)
    snap_head = _snapshot(sess)

    plan_counts = None
    if mode != "off":
        plan_counts = sess.plan(target).counts()

    samples: List[float] = []
    err: List[float] = []
    exec_counts = {"fetch": 0, "replay": 0, "patch": 0}
    est_s = 0.0
    identical = True
    for _ in range(repeats):
        t0 = time.perf_counter()
        st = sess.checkout(target)
        dt = time.perf_counter() - t0
        samples.append(dt)
        identical = identical and _snapshot(sess) == snap_target
        exec_counts = {"fetch": st.covs_planned_fetch,
                       "replay": st.covs_planned_replay,
                       "patch": st.covs_planned_patch}
        est_s = st.plan_est_s
        if st.plan_est_s > 0:
            err.append(abs(st.plan_est_s - dt) / max(dt, 1e-9))
        t0 = time.perf_counter()
        sess.checkout(head)
        samples.append(time.perf_counter() - t0)
        identical = identical and _snapshot(sess) == snap_head
    sess.close()
    return {
        "mode": mode,
        "p50": statistics.median(samples),
        "plan_est_s": est_s,
        "plan_err_frac": statistics.median(err) if err else None,
        "exec_counts": exec_counts,
        "plan_counts": plan_counts,
        "identical": identical,
        "snap_target": snap_target,
        "snap_head": snap_head,
        "chunk_keys": frozenset(DirectoryStore(path).list_chunk_keys()),
        "chunks_served": device.chunks_served,
    }


def run(dirty_fracs=DIRTY_FRACS, *, repeats: int = 3, elems: int = ELEMS,
        chunk_bytes: int = CHUNK_BYTES,
        read_latency_s: float = READ_LATENCY_S) -> List[dict]:
    rows: List[dict] = []
    tmp = tempfile.mkdtemp(prefix="kishu_planner_")
    try:
        for d in dirty_fracs:
            res = {}
            for mode in ("off", "auto"):
                res[mode] = _one_mode(tmp, mode, d, repeats=repeats,
                                      elems=elems, chunk_bytes=chunk_bytes,
                                      read_latency_s=read_latency_s)
                r = res[mode]
                row = {
                    "bench": "planner",
                    "workload": f"dirty_{d:g}",
                    "mode": mode,
                    "read_latency_ms": read_latency_s * 1e3,
                    "p50_checkout_s": round(r["p50"], 4),
                    "covs_fetch": r["exec_counts"]["fetch"],
                    "covs_replay": r["exec_counts"]["replay"],
                    "covs_patch": r["exec_counts"]["patch"],
                    "chunks_served": r["chunks_served"],
                    "identical": r["identical"],
                }
                if mode != "off":
                    row["plan_est_s"] = round(r["plan_est_s"], 4)
                    row["plan_err_frac"] = (round(r["plan_err_frac"], 3)
                                            if r["plan_err_frac"] is not None
                                            else None)
                rows.append(row)
            off, auto = res["off"], res["auto"]
            rows.append({
                "bench": "planner",
                "workload": f"dirty_{d:g}",
                "mode": "speedup_auto_vs_off",
                "checkout_speedup": round(off["p50"]
                                          / max(auto["p50"], 1e-9), 3),
                "identical": (off["identical"] and auto["identical"]
                              and off["snap_target"] == auto["snap_target"]
                              and off["snap_head"] == auto["snap_head"]),
                "chunk_keys_match":
                    off["chunk_keys"] == auto["chunk_keys"],
                "plan_matches_exec":
                    auto["plan_counts"] == auto["exec_counts"],
            })
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    # snapshots are cross-checked above; keep the artifact JSON-serializable
    return rows


def smoke() -> List[dict]:
    """CI gate: planner-auto beats fetch-only ≥1.5× at 10% dirty on the
    latency-injected store, restores bit-identical across modes (same
    arrays, same chunk-key sets), and the priced plan's path counts equal
    the executed checkout's ``covs_planned_*`` stats at every dirty rate."""
    rows = run(repeats=2)
    for r in rows:
        if r["mode"] != "speedup_auto_vs_off":
            continue
        assert r["identical"], f"restore not bit-identical: {r}"
        assert r["chunk_keys_match"], f"store chunk keys diverged: {r}"
        assert r["plan_matches_exec"], \
            f"kishu plan disagrees with executed paths: {r}"
    speedup = next(r["checkout_speedup"] for r in rows
                   if r["mode"] == "speedup_auto_vs_off"
                   and r["workload"] == "dirty_0.1")
    assert speedup >= 1.5, (
        f"planner-auto speedup {speedup} < 1.5x at 10% dirty")
    return rows
