"""Fig 13 + Fig 14: incremental checkpoint size and time across methods,
plus the parallel-engine evidence: serial vs parallel incremental checkout
wall time per chunk-store backend (DESIGN.md §9)."""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import time
from typing import Dict, List

from benchmarks.harness import METHODS, MethodResult
from benchmarks.workloads import ALL_WORKLOADS


def run(workloads=None, methods=None) -> List[MethodResult]:
    import jax
    out = []
    for wname in (workloads or ALL_WORKLOADS):
        wl = ALL_WORKLOADS[wname]()
        for mname in (methods or METHODS):
            out.append(METHODS[mname](wl))
        jax.clear_caches()     # bound jit memory across workloads (1-core box)
    return out


def run_checkout_io(n_covs: int = 16, elems: int = 1 << 19,
                    chunk_bytes: int = 1 << 18, io_threads: int = None,
                    repeats: int = 5, rtt_s: float = 0.002,
                    backends=("memory", "dir", "sqlite")) -> List[dict]:
    """Serial (io_threads=1, the pre-engine path) vs parallel incremental
    checkout per backend, restoring a fully-diverged multi-chunk state
    (n_covs co-variables x elems float32 -> n_covs * elems*4/chunk_bytes
    chunks), under two placements:

      - ``local``:  the store as-is (chunks in OS cache / local medium) —
        serial is already near memory bandwidth here, so this bounds the
        engine's overhead rather than showing its win;
      - ``remote``: the same backend behind a per-chunk round-trip of
        ``rtt_s`` (FaultInjectedStore read_delay — a networked mount /
        object store / cold medium), the latency-bound regime the parallel
        engine targets.

    Modes alternate within each repeat and the *median* of ``repeats`` is
    reported (min/mean are unstable on shared machines); restored state is
    checked bit-exact across modes.
    """
    import statistics

    import numpy as np

    from repro.core import FaultInjectedStore, KishuSession, MemoryStore
    from repro.core.chunkstore import DirectoryStore, SQLiteStore
    from repro.core.parallel import resolve_io_threads

    io_threads = resolve_io_threads(io_threads)
    rows_out: List[dict] = []
    tmp = tempfile.mkdtemp(prefix="kishu_ckpt_io_")
    try:
        for backend in backends:
            for placement in ("local", "remote"):
                if backend == "memory":
                    store = MemoryStore()
                elif backend == "dir":
                    store = DirectoryStore(
                        os.path.join(tmp, f"dir_cas_{placement}"))
                else:
                    store = SQLiteStore(
                        os.path.join(tmp, f"cas_{placement}.db"))
                if placement == "remote":
                    if backend == "memory":
                        continue        # no remote story for in-process RAM
                    store = FaultInjectedStore(store, read_delay=rtt_s)
                # cache_bytes=0: this bench measures backend transport; the
                # shared chunk cache would serve everything from memory
                sess = KishuSession(store, chunk_bytes=chunk_bytes,
                                    cache_bytes=0)

                def step(ns, seed):
                    rng = np.random.default_rng(seed)
                    for i in range(n_covs):
                        ns[f"v{i:02d}"] = rng.standard_normal(elems).astype(
                            np.float32)
                sess.register("step", step)
                sess.init_state({})
                c1 = sess.run("step", seed=1)
                c2 = sess.run("step", seed=2)

                times = {"serial": [], "parallel": []}
                loaded = {}
                snaps = {}
                for _ in range(repeats):
                    for mode, threads in (("serial", 1),
                                          ("parallel", io_threads)):
                        sess.loader.io_threads = threads
                        sess.checkout(c2)        # diverge everything
                        t0 = time.perf_counter()
                        st = sess.checkout(c1)   # the measured restore
                        times[mode].append(time.perf_counter() - t0)
                        loaded[mode] = st.bytes_loaded
                        snaps[mode] = {n: np.asarray(sess.ns[n]).tobytes()
                                       for n in sess.ns.names()}
                identical = snaps["serial"] == snaps["parallel"]
                med = {m: statistics.median(xs) for m, xs in times.items()}
                n_chunks = n_covs * (-(-elems * 4 // chunk_bytes))
                for mode in ("serial", "parallel"):
                    rows_out.append({
                        "bench": "ckpt_io", "backend": backend,
                        "placement": placement, "mode": mode,
                        "io_threads": 1 if mode == "serial" else io_threads,
                        "n_chunks": n_chunks,
                        "restore_MB": round(loaded[mode] / 2**20, 2),
                        "checkout_ms": round(med[mode] * 1e3, 2),
                        "speedup": (1.0 if mode == "serial" else
                                    round(med["serial"] / med["parallel"],
                                          2)),
                        "identical": identical,
                    })
                sess.close()
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    return rows_out


def rows(results: List[MethodResult]) -> List[dict]:
    table = []
    for r in results:
        table.append({
            "bench": "ckpt",
            "workload": r.workload,
            "method": r.method,
            "total_MB": round(r.total_bytes / 2**20, 3) if not r.failed else "",
            "total_ckpt_s": round(r.total_ckpt_s, 4) if not r.failed else "",
            "track_s": round(r.total_track_s, 4) if not r.failed else "",
            "undo_ms": round((r.undo_s or 0) * 1e3, 2) if not r.failed else "",
            "undo_MB_loaded": round((r.undo_bytes or 0) / 2**20, 3)
            if not r.failed else "",
            "branch_ms": round((r.branch_s or 0) * 1e3, 2)
            if not r.failed else "",
            "failed": r.failed,
            "note": r.note,
            # where the time went (span-name -> seconds); JSON-encoded so
            # the CSV stays one cell wide and BENCH json rows stay typed
            "stage_s": json.dumps(r.stage_s) if r.stage_s else "",
        })
    return table
