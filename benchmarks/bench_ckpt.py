"""Fig 13 + Fig 14: incremental checkpoint size and time across methods."""
from __future__ import annotations

from typing import Dict, List

from benchmarks.harness import METHODS, MethodResult
from benchmarks.workloads import ALL_WORKLOADS


def run(workloads=None, methods=None) -> List[MethodResult]:
    import jax
    out = []
    for wname in (workloads or ALL_WORKLOADS):
        wl = ALL_WORKLOADS[wname]()
        for mname in (methods or METHODS):
            out.append(METHODS[mname](wl))
        jax.clear_caches()     # bound jit memory across workloads (1-core box)
    return out


def rows(results: List[MethodResult]) -> List[dict]:
    table = []
    for r in results:
        table.append({
            "bench": "ckpt",
            "workload": r.workload,
            "method": r.method,
            "total_MB": round(r.total_bytes / 2**20, 3) if not r.failed else "",
            "total_ckpt_s": round(r.total_ckpt_s, 4) if not r.failed else "",
            "track_s": round(r.total_track_s, 4) if not r.failed else "",
            "undo_ms": round((r.undo_s or 0) * 1e3, 2) if not r.failed else "",
            "undo_MB_loaded": round((r.undo_bytes or 0) / 2**20, 3)
            if not r.failed else "",
            "branch_ms": round((r.branch_s or 0) * 1e3, 2)
            if not r.failed else "",
            "failed": r.failed,
            "note": r.note,
        })
    return table
