"""Runners executing a Workload under each checkpointing method and
collecting per-commit size/latency plus checkout timings."""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import numpy as np

from repro.core import (KishuSession, MemoryStore, Namespace,
                        TrackedNamespace)
from repro.core.baselines import DetReplaySession, DumpSession, PageIncremental
from benchmarks.workloads import Workload


@dataclass
class MethodResult:
    method: str
    workload: str
    ckpt_bytes: List[int] = field(default_factory=list)
    ckpt_s: List[float] = field(default_factory=list)
    track_s: List[float] = field(default_factory=list)
    commits: List[str] = field(default_factory=list)
    undo_s: Optional[float] = None
    undo_bytes: Optional[int] = None
    branch_s: Optional[float] = None
    failed: bool = False
    note: str = ""
    # per-stage wall-time vector (span-name -> total seconds), aggregated
    # from the session tracer so BENCH rows show WHERE time went, not just
    # totals (DESIGN.md §16); empty for baselines without a tracer
    stage_s: Dict[str, float] = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return int(sum(self.ckpt_bytes))

    @property
    def total_ckpt_s(self) -> float:
        return float(sum(self.ckpt_s))

    @property
    def total_track_s(self) -> float:
        return float(sum(self.track_s))


# ---------------------------------------------------------------------------
# Kishu (and variants)
# ---------------------------------------------------------------------------

def run_kishu(wl: Workload, *, check_all: bool = False,
              det_replay: bool = False, chunk_bytes: int = 1 << 16,
              undo: bool = True, branch: bool = True) -> MethodResult:
    store = MemoryStore()
    cls = DetReplaySession if det_replay else KishuSession
    sess = cls(store, chunk_bytes=chunk_bytes, check_all=check_all)
    # stage breakdown rides every row: flip the tracer on post-construction
    # (the enabled flag is read per span call) and fold totals in at the end
    sess.obs.tracer.enabled = True
    name = ("kishu_det_replay" if det_replay
            else "kishu_check_all" if check_all else "kishu")
    res = MethodResult(name, wl.name)

    for cname, fn in wl.registry.items():
        if det_replay:
            sess.register(cname, fn,
                          deterministic=cname in wl.deterministic)
        else:
            sess.register(cname, fn)
    sess.init_state(wl.init)
    prev_bytes = store.chunk_bytes_total() + sess.graph.total_meta_bytes()

    for cname, args in wl.script:
        sess.run(cname, **args)
        now = store.chunk_bytes_total() + sess.graph.total_meta_bytes()
        res.ckpt_bytes.append(now - prev_bytes)
        prev_bytes = now
        rs = sess.last_run
        res.ckpt_s.append(rs.detect_s + rs.write_s)
        res.track_s.append(rs.detect_s)
        res.commits.append(rs.commit_id)

    if undo and len(res.commits) >= 2:
        target = res.commits[-2]
        t0 = time.perf_counter()
        st = sess.checkout(target)
        res.undo_s = time.perf_counter() - t0
        res.undo_bytes = st.bytes_loaded + st.bytes_cached
        sess.checkout(res.commits[-1])

    if branch and len(res.commits) >= 4:
        mid = res.commits[len(res.commits) // 2]
        sess.checkout(mid)
        # re-run the suffix with perturbed args (a second branch)
        for cname, args in wl.script[len(wl.script) // 2:]:
            sess.run(cname, **args)
        tip_b = sess.graph.head
        t0 = time.perf_counter()
        sess.checkout(res.commits[-1])          # switch back to branch A
        res.branch_s = time.perf_counter() - t0
    res.stage_s = {k: round(v, 6)
                   for k, v in sorted(sess.obs.tracer.stage_totals().items())}
    return res


# ---------------------------------------------------------------------------
# Baselines
# ---------------------------------------------------------------------------

def _apply_script(ns: Namespace, wl: Workload, upto: Optional[int] = None):
    tns = TrackedNamespace(ns)
    for cname, args in (wl.script if upto is None else wl.script[:upto]):
        wl.registry[cname](tns, **args)


def run_dump(wl: Workload) -> MethodResult:
    store = MemoryStore()
    d = DumpSession(store)
    res = MethodResult("dump_session", wl.name)
    ns = Namespace()
    tns = TrackedNamespace(ns)
    for prefix, sub in wl.init.items():
        if isinstance(sub, dict):
            ns.set_tree(prefix, sub)
        else:
            ns[prefix] = sub
    d.checkpoint(ns, "t0000")
    for i, (cname, args) in enumerate(wl.script):
        wl.registry[cname](tns, **args)
        st = d.checkpoint(ns, f"t{i+1:04d}")
        if st.failed:
            res.failed, res.note = True, st.fail_reason
            return res
        res.ckpt_bytes.append(st.bytes_written)
        res.ckpt_s.append(st.ckpt_s)
        res.track_s.append(0.0)
    st = d.checkout(ns, f"t{len(wl.script)-1:04d}")
    res.undo_s, res.undo_bytes = st.checkout_s, st.bytes_loaded
    st = d.checkout(ns, f"t{len(wl.script)//2:04d}")
    res.branch_s = st.checkout_s
    return res


def run_page_incremental(wl: Workload) -> MethodResult:
    store = MemoryStore()
    p = PageIncremental(store)
    res = MethodResult("page_incremental", wl.name)
    ns = Namespace()
    tns = TrackedNamespace(ns)
    for prefix, sub in wl.init.items():
        if isinstance(sub, dict):
            ns.set_tree(prefix, sub)
        else:
            ns[prefix] = sub
    p.checkpoint(ns, "t0000", parent=None)
    prev = "t0000"
    for i, (cname, args) in enumerate(wl.script):
        wl.registry[cname](tns, **args)
        tag = f"t{i+1:04d}"
        st = p.checkpoint(ns, tag, parent=prev)
        if st.failed:
            res.failed, res.note = True, st.fail_reason
            return res
        prev = tag
        res.ckpt_bytes.append(st.bytes_written)
        res.ckpt_s.append(st.ckpt_s)
        res.track_s.append(0.0)
    st = p.checkout(ns, f"t{len(wl.script)-1:04d}")
    res.undo_s, res.undo_bytes = st.checkout_s, st.bytes_loaded
    st = p.checkout(ns, f"t{len(wl.script)//2:04d}")
    res.branch_s = st.checkout_s
    return res


def _rename(res: MethodResult, name: str) -> MethodResult:
    res.method = name
    return res


METHODS = {
    # paper-faithful: the co-variable is the atomic storage unit (one chunk)
    "kishu_paper": lambda wl: _rename(
        run_kishu(wl, chunk_bytes=1 << 34), "kishu_paper"),
    # beyond-paper: chunk-level dedup inside co-variables (DESIGN.md §2)
    "kishu_chunked": lambda wl: _rename(
        run_kishu(wl, chunk_bytes=1 << 16), "kishu_chunked"),
    "kishu_check_all": lambda wl: run_kishu(wl, check_all=True),
    "kishu_det_replay": lambda wl: run_kishu(wl, det_replay=True),
    "dump_session": run_dump,
    "page_incremental": run_page_incremental,
}
