"""Benchmark workloads — recorded session scripts with the paper's workload
traits (§2.2): incremental access (<10% of state per command), ~45:55
modify:create balance, small per-cell deltas, branchy exploration.

Four workloads mirror the evaluation notebooks' regimes (Table 2):
  sklearn_like    — text-mining analogue: big corpus loaded once, many small
                    auxiliary updates (the paper's Fig 2 pattern)
  hwlm_like       — many (~170) small variables, frequent small updates
  storesales_like — balanced creation/modification of medium arrays
  train_like      — an actual reduced-LM training session (params+opt states)

Each workload = (init tree, command registry, script).  Runners execute the
same script under Kishu, AblatedKishu(check-all), DumpSession,
PageIncremental, and DetReplay for apples-to-apples size/latency numbers.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Tuple

import numpy as np

MB = 1 << 20


@dataclass
class Workload:
    name: str
    init: Dict[str, Any]
    registry: Dict[str, Callable]
    script: List[Tuple[str, dict]]
    deterministic: List[str] = field(default_factory=list)


# ---------------------------------------------------------------------------
def sklearn_like(scale: int = 8) -> Workload:
    """Load a large corpus once; then many commands touching small slices
    (cleaning lists, fitting small models, drawing 'plots')."""
    rng = np.random.default_rng(0)
    corpus = rng.standard_normal(scale * MB // 4).astype(np.float32)

    def clean_list(ns, which: int, bump: float):
        ns[f"lists/l{which}"] = ns[f"lists/l{which}"] * 0.9 + bump

    def fit_model(ns, which: int):
        x = ns[f"lists/l{which}"]
        ns[f"models/m{which}"] = np.outer(x[:64], x[:64]).astype(np.float32)

    def draw_plot(ns, which: int):
        ns[f"plots/p{which}"] = ns[f"models/m{which}"].sum(0)

    def drop_column(ns):
        ns["aux/df"] = ns["aux/df"][:, 1:]

    def clean_tokens(ns, n: int):
        """Looped control flow over python objects — the cell shape where
        live-instrumentation provenance tracking explodes (§2.4, Fig 17)."""
        toks = ns["aux/tokens"]
        out = []
        for t in toks[:n]:
            if t % 3:
                out.append(t * 2 + 1)
            else:
                out.append(t)
        ns["aux/tokens"] = out + toks[n:]

    init = {"corpus": corpus,
            "aux": {"df": rng.standard_normal((512, 48)).astype(np.float32),
                    "tokens": list(range(20_000))},
            "lists": {f"l{i}": rng.standard_normal(4096).astype(np.float32)
                      for i in range(8)}}
    script: List[Tuple[str, dict]] = []
    for i in range(8):
        script.append(("clean_list", {"which": i, "bump": 0.1 * i}))
        script.append(("fit_model", {"which": i}))
        if i % 2 == 0:
            script.append(("draw_plot", {"which": i}))
        if i % 3 == 0:
            script.append(("clean_tokens", {"n": 5000}))
        if i == 5:
            script.append(("drop_column", {}))
    return Workload("sklearn_like", init,
                    {"clean_list": clean_list, "fit_model": fit_model,
                     "draw_plot": draw_plot, "drop_column": drop_column,
                     "clean_tokens": clean_tokens},
                    script, deterministic=["fit_model", "draw_plot",
                                           "clean_tokens"])


# ---------------------------------------------------------------------------
def hwlm_like(n_vars: int = 170) -> Workload:
    """Many small variables; each command touches a handful (HW-LM's 172
    variables, Table 7)."""
    rng = np.random.default_rng(1)
    init = {"vars": {f"v{i:03d}": rng.standard_normal(2048).astype(np.float32)
                     for i in range(n_vars)}}

    def update_few(ns, start: int):
        for i in range(start, start + 5):
            name = f"vars/v{i % n_vars:03d}"
            ns[name] = ns[name] * 0.99 + 0.01

    def reduce_pair(ns, i: int, j: int):
        ns[f"vars/v{i:03d}"] = ns[f"vars/v{i:03d}"] + ns[f"vars/v{j:03d}"]

    script: List[Tuple[str, dict]] = []
    for k in range(30):
        script.append(("update_few", {"start": 7 * k}))
        if k % 3 == 0:
            script.append(("reduce_pair", {"i": k % n_vars,
                                           "j": (k * 11 + 3) % n_vars}))
    return Workload("hwlm_like", init,
                    {"update_few": update_few, "reduce_pair": reduce_pair},
                    script, deterministic=["update_few", "reduce_pair"])


# ---------------------------------------------------------------------------
def storesales_like(scale: int = 4) -> Workload:
    """Balanced create/modify (~45:55) of medium arrays (TS-analysis-like)."""
    rng = np.random.default_rng(2)
    init = {"series": {f"s{i}": rng.standard_normal(scale * MB // 16 // 4)
                       .astype(np.float32) for i in range(4)}}

    def modify(ns, which: int):
        name = f"series/s{which}"
        ns[name] = ns[name] * 1.01

    def create(ns, tag: int):
        base = ns[f"series/s{tag % 4}"]
        ns[f"derived/d{tag}"] = (base[: len(base) // 4] ** 2).astype(np.float32)

    def aggregate(ns, tag: int):
        ns[f"aggs/a{tag}"] = np.array(
            [ns[f"derived/d{tag}"].mean(), ns[f"derived/d{tag}"].std()],
            np.float32)

    script: List[Tuple[str, dict]] = []
    for k in range(20):
        if k % 9 < 5:
            script.append(("modify", {"which": k % 4}))
        else:
            script.append(("create", {"tag": k}))
            script.append(("aggregate", {"tag": k}))
    return Workload("storesales_like", init,
                    {"modify": modify, "create": create,
                     "aggregate": aggregate},
                    script, deterministic=["aggregate"])


# ---------------------------------------------------------------------------
def train_like() -> Workload:
    """A real (reduced) LM training session: params + AdamW moments as the
    state; phases, eval, lr change — the framework's primary regime."""
    import jax
    from repro.models import get_config
    from repro.models.testing import reduced
    from repro.optim.adamw import AdamWConfig
    from repro.train import step as step_lib
    from repro.data.pipeline import DataState, TokenPipeline

    cfg = reduced(get_config("smollm-360m"), n_layers=4).replace(
        d_model=128, n_heads=4, n_kv_heads=2, d_ff=256)
    oc = AdamWConfig(lr=1e-3)
    pipe = TokenPipeline(cfg.vocab_size, 4, 32)
    step_fn = step_lib.make_train_step(cfg, oc, remat=False)
    state0 = step_lib.init_train_state(cfg, jax.random.key(0), oc)

    def train_phase(ns, steps: int):
        import jax.numpy as jnp
        state = ns.get_tree("state")
        ds = DataState(ns["data/seed"], ns["data/step"])
        for _ in range(steps):
            batch, ds = pipe.next_batch(ds)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            state, _ = step_fn(state, batch)
        ns.set_tree("state", state)
        ns["data/step"] = int(ds.step)

    def set_lr(ns, lr: float):
        ns["hparams/lr"] = lr

    def snapshot_metric(ns, tag: int):
        import jax
        leaf = jax.tree.leaves(ns.get_tree("state")["params"])[0]
        ns[f"metrics/m{tag}"] = float(abs(np.asarray(leaf)).mean())

    init = {"state": state0, "data": {"seed": 0, "step": 0},
            "hparams": {"lr": 1e-3}}
    script: List[Tuple[str, dict]] = []
    for k in range(10):
        script.append(("train_phase", {"steps": 2}))
        if k % 4 == 1:
            script.append(("snapshot_metric", {"tag": k}))
        if k == 5:
            script.append(("set_lr", {"lr": 5e-4}))
    return Workload("train_like", init,
                    {"train_phase": train_phase, "set_lr": set_lr,
                     "snapshot_metric": snapshot_metric},
                    script, deterministic=["train_phase"])


ALL_WORKLOADS = {
    "sklearn_like": sklearn_like,
    "hwlm_like": hwlm_like,
    "storesales_like": storesales_like,
    "train_like": train_like,
}
