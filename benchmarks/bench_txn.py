"""Transactional commit engine: group-commit throughput, publish hiding,
recovery cost → BENCH_txn.json (DESIGN.md §13).

Three stories, matching the engine's three claims:

  * **Group commit amortizes the publish.**  Every cell's metadata publish
    costs WAL + commit doc + HEAD + seal round-trips (each mirrored to
    every shard on a fabric, each an fsync on SQLite).  Batching ``group_n``
    consecutive cells into one journaled publish divides that per-cell meta
    traffic — ``meta_writes_per_cell`` drops toward 1/group_n of the
    unbatched engine's.
  * **Async publish hides behind think time.**  With ``async_publish`` the
    fence + publish run on a background thread while the next cell
    executes; per-cell wall approaches pure think+write time even when the
    publish itself is slow.
  * **Recovery is O(journal length).**  ``txn.recover`` replays/rolls back
    unsealed journals on open; the rows pin its cost as the journal count
    grows (a healthy store has zero, a crashed one a handful).

``smoke()`` is the CI gate: group commit must strictly reduce per-cell
meta writes, a kill mid-publish must recover to an fsck-clean state, and
recovery must be idempotent.
"""
from __future__ import annotations

import os
import tempfile
import time
from typing import List

import numpy as np

from repro.core import txn
from repro.core.chunkstore import (FaultInjectingStore, InjectedCrash,
                                   MemoryStore, SQLiteStore, chunk_key)
from repro.core.session import KishuSession


def _make_store(backend: str, tmp: str, tag: str):
    if backend == "memory":
        return MemoryStore()
    if backend == "sqlite":
        return SQLiteStore(os.path.join(tmp, f"{tag}.db"))
    raise ValueError(backend)


def _make_session(store, *, chunk_bytes=1 << 12, think_s=0.0, **kw):
    sess = KishuSession(store, chunk_bytes=chunk_bytes, cache_bytes=0, **kw)

    def init(ns, elems):
        ns["w"] = np.zeros(elems, np.float32)

    def step(ns, seed):
        if think_s:
            time.sleep(think_s)              # the cell's "think time"
        a = ns["w"]
        a[seed % len(a)] = float(seed)       # one dirty chunk per cell

    sess.register("init", init)
    sess.register("step", step)
    return sess


def _meta_writes(probe: FaultInjectingStore) -> int:
    return sum(op.startswith(("put_meta", "delete_meta"))
               for op in probe.op_log)


def run_group_commit(n_cells: int = 32, elems: int = 1 << 13,
                     group_ns=(1, 4, 16),
                     backends=("memory", "sqlite")) -> List[dict]:
    rows: List[dict] = []
    with tempfile.TemporaryDirectory(prefix="kishu_txn_") as tmp:
        for backend in backends:
            for g in group_ns:
                probe = FaultInjectingStore(
                    _make_store(backend, tmp, f"g{g}"))
                sess = _make_session(probe, group_commit_n=g)
                sess.init_state({})
                sess.run("init", elems=elems)
                t0 = time.perf_counter()
                for i in range(n_cells):
                    sess.run("step", seed=i + 1)
                sess.close()
                wall = time.perf_counter() - t0
                assert txn.fsck(probe.inner).problems == 0
                rows.append({
                    "bench": "txn", "story": "group_commit",
                    "backend": backend, "group_n": g, "n_cells": n_cells,
                    "wall_s": round(wall, 4),
                    "cells_per_s": round(n_cells / max(wall, 1e-9), 1),
                    "meta_writes_per_cell":
                        round(_meta_writes(probe) / n_cells, 2),
                    "publishes": sess.engine.stats.publishes,
                })
    return rows


class _RemoteMetaStore(MemoryStore):
    """Metadata round-trips cost ``meta_delay_s`` each (a remote commit
    service / mirrored fabric), one delay per *batch* — the honest model
    for where publish latency actually lives.  Chunk I/O is untouched."""

    def __init__(self, meta_delay_s: float):
        super().__init__()
        self.meta_delay_s = meta_delay_s

    def put_meta(self, name, doc):
        time.sleep(self.meta_delay_s)
        super().put_meta(name, doc)

    def put_meta_batch(self, docs):
        time.sleep(self.meta_delay_s)       # one round-trip for the batch
        super().put_meta_batch(docs)

    def delete_meta(self, name):
        time.sleep(self.meta_delay_s)
        super().delete_meta(name)


def run_publish_hiding(n_cells: int = 16, elems: int = 1 << 13,
                       think_s: float = 0.004,
                       meta_delay_s: float = 0.002) -> List[dict]:
    """Per-cell wall with the publish on the cell loop (sync) vs hidden
    behind the next cell's think time (async), against a latency-bound
    metadata backend."""
    rows: List[dict] = []
    for mode in ("sync", "async"):
        store = _RemoteMetaStore(meta_delay_s)
        sess = _make_session(store, think_s=think_s,
                             async_publish=(mode == "async"))
        sess.init_state({})
        sess.run("init", elems=elems)
        t0 = time.perf_counter()
        for i in range(n_cells):
            sess.run("step", seed=i + 1)
        loop_wall = time.perf_counter() - t0     # what the user feels
        sess.close()
        assert txn.fsck(store).problems == 0
        rows.append({
            "bench": "txn", "story": "publish_hiding", "mode": mode,
            "think_ms": think_s * 1e3, "meta_delay_ms": meta_delay_s * 1e3,
            "n_cells": n_cells,
            "cell_loop_wall_s": round(loop_wall, 4),
            "wall_per_cell_ms": round(loop_wall / n_cells * 1e3, 3),
            "publish_s": round(sess.engine.stats.publish_s, 4),
            "fence_wait_s": round(sess.engine.stats.fence_wait_s, 4),
        })
    sync = next(r for r in rows if r["mode"] == "sync")
    async_ = next(r for r in rows if r["mode"] == "async")
    rows.append({
        "bench": "txn", "story": "publish_hiding",
        "mode": "async_vs_sync",
        # derived row: absolute per-cell publish latency hidden by async,
        # under its own key so it never mixes with real measurements
        "hidden_ms_per_cell": round(sync["wall_per_cell_ms"]
                                    - async_["wall_per_cell_ms"], 3),
    })
    return rows


def _plant_unsealed(store, n: int) -> None:
    """Synthesize a crashed store: n unsealed journals, alternating
    open-state (journaled orphan chunks to roll back) and publish-state
    (docs to roll forward)."""
    head = store.get_meta("HEAD")
    for i in range(n):
        if i % 2 == 0:
            data = f"orphan{i}".encode() * 64
            key = chunk_key(data)
            store.put_chunk(key, data)
            store.put_meta(f"txn/recov{i:04d}",
                           {"status": "open", "chunks": [key], "docs": {}})
        else:
            store.put_meta(
                f"txn/recov{i:04d}",
                {"status": "publish", "chunks": [],
                 "docs": {f"commit/r{i:04d}": {"commit_id": f"r{i:04d}",
                                               "parent": None,
                                               "deleted": True},
                          "HEAD": head}})
            # the replayed docs are tombstone-shaped so the planted commits
            # stay invisible to the graph and gc can purge them


def run_recovery(journal_lens=(1, 8, 32)) -> List[dict]:
    rows: List[dict] = []
    with tempfile.TemporaryDirectory(prefix="kishu_txn_") as tmp:
        for n in journal_lens:
            store = _make_store("sqlite", tmp, f"rec{n}")
            sess = _make_session(store)
            sess.init_state({})
            sess.run("init", elems=1 << 13)
            sess.close()
            _plant_unsealed(store, n)
            t0 = time.perf_counter()
            out = txn.recover(store)
            wall = time.perf_counter() - t0
            assert out["replayed"] + out["rolled_back"] == n
            rows.append({
                "bench": "txn", "story": "recovery", "journal_len": n,
                "recover_wall_ms": round(wall * 1e3, 3),
                "replayed": out["replayed"],
                "rolled_back": out["rolled_back"],
                "chunks_dropped": out["chunks_dropped"],
            })
    return rows


def run(**kw) -> List[dict]:
    return run_group_commit(**kw) + run_publish_hiding() + run_recovery()


def smoke() -> List[dict]:
    """CI gate: group commit strictly reduces per-cell meta writes; a kill
    mid-publish recovers to an fsck-clean, prefix-identical state; recovery
    is idempotent."""
    rows = (run_group_commit(n_cells=16, group_ns=(1, 8))
            + run_publish_hiding(n_cells=8, think_s=0.002)
            + run_recovery(journal_lens=(1, 8)))

    by_g = {r["group_n"]: r for r in rows
            if r["story"] == "group_commit" and r["backend"] == "memory"}
    assert by_g[8]["meta_writes_per_cell"] < by_g[1]["meta_writes_per_cell"],\
        f"group commit did not amortize meta writes: {by_g}"

    modes = {r["mode"]: r for r in rows if r["story"] == "publish_hiding"}
    assert (modes["async"]["wall_per_cell_ms"]
            < modes["sync"]["wall_per_cell_ms"]), \
        f"async publish hid nothing: {modes}"

    # crash mid-publish -> recover -> fsck clean, state is a prefix
    probe = FaultInjectingStore(MemoryStore())
    sess = _make_session(probe)
    sess.init_state({})
    sess.run("init", elems=1 << 12)
    sess.run("step", seed=1)
    sess.close()
    kill_at = max(i for i, op in enumerate(probe.op_log)
                  if op.startswith("put_meta:commit/"))
    inner = MemoryStore()
    try:
        sess = _make_session(FaultInjectingStore(inner, crash_after=kill_at))
        sess.init_state({})
        sess.run("init", elems=1 << 12)
        sess.run("step", seed=1)
        sess.close()
        raise AssertionError("injected kill did not fire")
    except InjectedCrash:
        pass
    except txn.TxnError as e:       # kill inside the publish batch
        assert isinstance(e.__cause__, InjectedCrash)
    out = txn.recover(inner)
    assert out["replayed"] + out["rolled_back"] >= 1
    assert txn.fsck(inner).problems == 0, txn.fsck(inner).details
    assert txn.recover(inner) == {"replayed": 0, "rolled_back": 0,
                                  "commits_published": 0,
                                  "chunks_dropped": 0}
    rows.append({"bench": "txn", "story": "crash_smoke",
                 "kill_at_op": kill_at,
                 "replayed": out["replayed"],
                 "rolled_back": out["rolled_back"],
                 "fsck_problems": 0})
    return rows
