"""Fig 19: scalability to long sessions — Checkpoint Graph size vs #commits
and state-diff time vs checkout distance, up to 1000 cell executions."""
from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.core import KishuSession, MemoryStore


def run(n_commits: int = 1000) -> List[dict]:
    sess = KishuSession(MemoryStore(), chunk_bytes=1 << 14)

    def touch(ns, which: int):
        name = f"v{which % 40:02d}"
        ns[name] = ns[name] * 1.0001

    sess.register("touch", touch)
    sess.init_state({f"v{i:02d}": np.ones(256, np.float32)
                     for i in range(40)})
    commits = []
    rng = np.random.default_rng(0)
    sizes = []
    for i in range(n_commits):
        commits.append(sess.run("touch", which=int(rng.integers(40))))
        if (i + 1) % 100 == 0:
            sizes.append({"bench": "scalability",
                          "metric": "graph_bytes",
                          "commits": i + 1,
                          "graph_MB": round(
                              sess.graph.total_meta_bytes() / 2**20, 4)})
    out = sizes
    head = commits[-1]
    for dist in (1, 10, 100, 500, 999):
        if dist >= len(commits):
            continue
        target = commits[-1 - dist]
        t0 = time.perf_counter()
        plan = sess.graph.diff(head, target)
        dt = time.perf_counter() - t0
        out.append({"bench": "scalability", "metric": "diff_time",
                    "distance": dist, "diff_ms": round(dt * 1e3, 3),
                    "diverged": plan.n_diverged})
    return out
