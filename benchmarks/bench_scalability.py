"""Fig 19: scalability to long sessions — Checkpoint Graph size vs #commits,
state-diff time vs checkout distance, and end-to-end checkout wall time
(serial vs parallel chunk engine) up to 1000 cell executions."""
from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.core import KishuSession, MemoryStore, open_store


def run(n_commits: int = 1000, store_uri: str = "memory://",
        io_threads: int = 8, graph_rows: bool = True,
        checkout_rows: bool = True) -> List[dict]:
    """``graph_rows``: Checkpoint-Graph growth + diff-time sections (store
    agnostic; memory:// is fine).  ``checkout_rows``: end-to-end checkout
    wall vs distance, serial vs parallel — only meaningful on a backend the
    engine engages (dir:// / sqlite://; MemoryStore opts out of parallel
    fetch, so its "parallel" rows would just re-measure the serial path)."""
    store = open_store(store_uri)
    backend = type(store).__name__
    sess = KishuSession(store, chunk_bytes=1 << 14)

    def touch(ns, which: int):
        name = f"v{which % 40:02d}"
        ns[name] = ns[name] * 1.0001

    sess.register("touch", touch)
    sess.init_state({f"v{i:02d}": np.ones(256, np.float32)
                     for i in range(40)})
    commits = []
    rng = np.random.default_rng(0)
    sizes = []
    for i in range(n_commits):
        commits.append(sess.run("touch", which=int(rng.integers(40))))
        if (i + 1) % 100 == 0:
            sizes.append({"bench": "scalability",
                          "metric": "graph_bytes",
                          "commits": i + 1,
                          "graph_MB": round(
                              sess.graph.total_meta_bytes() / 2**20, 4)})
    out = sizes if graph_rows else []
    head = commits[-1]
    if graph_rows:
        for dist in (1, 10, 100, 500, 999):
            if dist >= len(commits):
                continue
            target = commits[-1 - dist]
            t0 = time.perf_counter()
            plan = sess.graph.diff(head, target)
            dt = time.perf_counter() - t0
            out.append({"bench": "scalability", "metric": "diff_time",
                        "distance": dist, "diff_ms": round(dt * 1e3, 3),
                        "diverged": plan.n_diverged})

    # end-to-end checkout wall at distance: serial pre-engine path vs the
    # parallel chunk engine, best-of-2 alternating (cache-warmth neutral)
    for dist in (10, 100, 999) if checkout_rows else ():
        if dist >= len(commits):
            continue
        target = commits[-1 - dist]
        best = {"serial": float("inf"), "parallel": float("inf")}
        diverged = 0
        for _ in range(2):
            for mode, threads in (("serial", 1), ("parallel", io_threads)):
                sess.loader.io_threads = threads
                sess.checkout(head)
                t0 = time.perf_counter()
                st = sess.checkout(target)
                best[mode] = min(best[mode], time.perf_counter() - t0)
                diverged = st.covs_loaded
        sess.checkout(head)
        for mode in ("serial", "parallel"):
            out.append({"bench": "scalability", "metric": "checkout_time",
                        "backend": backend, "distance": dist,
                        "mode": mode, "diverged": diverged,
                        "checkout_ms": round(best[mode] * 1e3, 3)})
    return out
