"""Storage fabric: scatter-gather checkout bandwidth + replica-loss restore.

Bandwidth story: a chunk store is ultimately a *device* with one queue — a
single disk serves its reads one at a time no matter how many threads ask.
``DeviceStore`` models that (per-store lock + fixed per-chunk service time),
so the comparison is honest on CI machines with one physical disk: the
baseline is one device holding everything; the fabric is a consistent-hash
ring over N such devices, where scatter-gather ``get_chunks`` drives all N
queues concurrently.  Checkout wall time on the paper's ~10%-dirty workload
then tracks aggregate device bandwidth: N shards ≈ N× the read throughput.
``smoke()`` asserts the ≥1.5× bar for a 4-shard fabric vs a single
DirectoryStore, restores verified bit-identical in every configuration.

Fault story: a 2-way replica set loses one full replica (chunks wiped, and
separately a ``FaultInjectedStore`` failing every read); checkout must
restore bit-identically off the surviving replica while read-repair heals
the chunks it touches, and ``scrub --repair`` + a clean ``scrub`` finish the
job — 0 problems afterward.  These rows are what CI's fabric smoke job pins.
"""
from __future__ import annotations

import os
import shutil
import tempfile
import threading
import time
from typing import List

from repro.core.chunkstore import ChunkStore, DirectoryStore


class DeviceStore(ChunkStore):
    """One storage device: a wrapped backend whose reads are serialized by a
    device-level queue (lock) and cost ``read_latency_s`` per chunk.  Writes
    are not throttled — the benchmark isolates checkout (read) bandwidth."""

    supports_parallel_get = True

    def __init__(self, inner: ChunkStore, read_latency_s: float):
        self.inner = inner
        self.read_latency_s = read_latency_s
        self.min_slab = getattr(inner, "min_slab", 1)
        self._q = threading.Lock()
        self.chunks_served = 0

    def get_chunk(self, key):
        with self._q:
            time.sleep(self.read_latency_s)
            self.chunks_served += 1
            return self.inner.get_chunk(key)

    def get_chunks(self, keys, *, missing_ok=False):
        uniq = list(dict.fromkeys(keys))
        with self._q:
            time.sleep(self.read_latency_s * len(uniq))
            self.chunks_served += len(uniq)
            return self.inner.get_chunks(uniq, missing_ok=missing_ok)

    def put_chunk(self, key, data):
        return self.inner.put_chunk(key, data)

    def put_chunks(self, pairs):
        return self.inner.put_chunks(pairs)

    def has_chunk(self, key):
        return self.inner.has_chunk(key)

    def list_chunk_keys(self):
        return self.inner.list_chunk_keys()

    def chunk_sizes(self, keys):
        return self.inner.chunk_sizes(keys)

    def delete_chunk(self, key):
        self.inner.delete_chunk(key)

    def delete_chunks(self, keys):
        return self.inner.delete_chunks(keys)

    def put_meta(self, name, doc):
        self.inner.put_meta(name, doc)

    def put_meta_batch(self, docs):
        self.inner.put_meta_batch(docs)

    def get_meta(self, name):
        return self.inner.get_meta(name)

    def list_meta(self, prefix):
        return self.inner.list_meta(prefix)

    def delete_meta(self, name):
        self.inner.delete_meta(name)

    def chunk_bytes_total(self):
        return self.inner.chunk_bytes_total()

    def n_chunks(self):
        return self.inner.n_chunks()


def _make_session(store, chunk_bytes):
    from repro.core import KishuSession
    return KishuSession(store, chunk_bytes=chunk_bytes, cache_bytes=0)


def _dirty_workload(sess, n_covs, elems, chunk_bytes, dirty_frac):
    import numpy as np

    elem_bytes = 4
    chunks_per_cov = -(-elems * elem_bytes // chunk_bytes)
    dirty_chunks = max(1, int(round(chunks_per_cov * dirty_frac)))
    chunk_elems = chunk_bytes // elem_bytes

    def init(ns, seed):
        rng = np.random.default_rng(seed)
        for i in range(n_covs):
            ns[f"v{i:02d}"] = rng.standard_normal(elems).astype(np.float32)

    def mutate(ns, seed):
        rng = np.random.default_rng(seed)
        for i in range(n_covs):
            a = ns[f"v{i:02d}"]
            for c in range(dirty_chunks):
                a[c * chunk_elems] = rng.standard_normal()

    sess.register("init", init)
    sess.register("mutate", mutate)


def _snapshot(sess):
    import numpy as np
    return {n: np.asarray(sess.ns[n]).tobytes() for n in sess.ns.names()}


def run_scatter_gather(n_shards: int = 4, n_covs: int = 8,
                       elems: int = 1 << 16, chunk_bytes: int = 1 << 12,
                       dirty_frac: float = 0.1, repeats: int = 3,
                       read_latency_s: float = 0.003) -> List[dict]:
    """Checkout wall time: single device vs an N-shard fabric of devices."""
    from repro.core.fabric import ShardedStore

    rows: List[dict] = []
    tmp = tempfile.mkdtemp(prefix="kishu_fabric_")
    try:
        for config in ("single", f"shard{n_shards}"):
            if config == "single":
                store = DeviceStore(
                    DirectoryStore(os.path.join(tmp, "single")),
                    read_latency_s)
                devices = [store]
            else:
                devices = [DeviceStore(
                    DirectoryStore(os.path.join(tmp, f"s{i}")),
                    read_latency_s) for i in range(n_shards)]
                store = ShardedStore(devices)
            sess = _make_session(store, chunk_bytes)
            _dirty_workload(sess, n_covs, elems, chunk_bytes, dirty_frac)
            sess.init_state({})
            prev = sess.run("init", seed=1)
            prev_snap = _snapshot(sess)
            wall = 0.0
            moved = 0
            identical = True
            for r in range(repeats):
                cur = sess.run("mutate", seed=100 + r)
                cur_snap = _snapshot(sess)
                t0 = time.perf_counter()
                st = sess.checkout(prev)            # hop back
                wall += time.perf_counter() - t0
                moved += st.bytes_loaded
                identical = identical and _snapshot(sess) == prev_snap
                t0 = time.perf_counter()
                sess.checkout(cur)                  # hop forward
                wall += time.perf_counter() - t0
                identical = identical and _snapshot(sess) == cur_snap
                prev, prev_snap = cur, cur_snap
            sess.close()
            rows.append({
                "bench": "fabric",
                "workload": f"partial_dirty_{dirty_frac:g}",
                "config": config, "n_devices": len(devices),
                "read_latency_ms": read_latency_s * 1e3,
                "checkout_wall_s": round(wall, 4),
                "bytes_moved": moved,
                "chunks_served": sum(d.chunks_served for d in devices),
                "identical": identical,
            })
        single = next(r for r in rows if r["config"] == "single")
        fabric = next(r for r in rows if r["config"] != "single")
        rows.append({
            "bench": "fabric", "workload": single["workload"],
            "config": f"speedup_shard{n_shards}_vs_single",
            "checkout_speedup": round(single["checkout_wall_s"]
                                      / max(fabric["checkout_wall_s"], 1e-9),
                                      3),
            "identical": single["identical"] and fabric["identical"],
        })
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    return rows


def run_replica_loss(n_covs: int = 4, elems: int = 1 << 14,
                     chunk_bytes: int = 1 << 12) -> List[dict]:
    """Restore with one full replica down, both loss modes: chunks wiped
    from disk, and a FaultInjectedStore failing every read."""
    from repro.core import FaultInjectedStore, open_store
    from repro.core.fabric import ReplicatedStore, scrub

    rows: List[dict] = []
    for mode in ("wiped", "fault_injected"):
        tmp = tempfile.mkdtemp(prefix="kishu_rloss_")
        try:
            uri = f"fabric://rep(dir://{tmp}/r0,dir://{tmp}/r1)"
            sess = _make_session(open_store(uri), chunk_bytes)
            _dirty_workload(sess, n_covs, elems, chunk_bytes, 0.1)
            sess.init_state({})
            c1 = sess.run("init", seed=1)
            snap1 = _snapshot(sess)
            sess.run("mutate", seed=2)
            sess.close()

            if mode == "wiped":
                shutil.rmtree(os.path.join(tmp, "r0", "chunks"))
                os.makedirs(os.path.join(tmp, "r0", "chunks"))
                store = open_store(uri)
            else:
                store = ReplicatedStore([
                    FaultInjectedStore(
                        DirectoryStore(os.path.join(tmp, "r0")),
                        fail_get=lambda k: True),
                    DirectoryStore(os.path.join(tmp, "r1"))])
            sess = _make_session(store, chunk_bytes)
            _dirty_workload(sess, n_covs, elems, chunk_bytes, 0.1)
            t0 = time.perf_counter()
            sess.checkout(c1)
            wall = time.perf_counter() - t0
            identical = _snapshot(sess) == snap1
            sess.close()

            # heal the rest of the store, then demand a clean bill
            fresh = open_store(uri)
            scrub(fresh, repair=True)
            problems_after = scrub(fresh, deep=True).problems
            rows.append({
                "bench": "fabric", "workload": f"replica_loss_{mode}",
                "config": "rep2_one_down",
                "checkout_wall_s": round(wall, 4),
                "identical": identical,
                "scrub_problems_after_repair": problems_after,
            })
        finally:
            shutil.rmtree(tmp, ignore_errors=True)
    return rows


def run(**kw) -> List[dict]:
    return run_scatter_gather(**kw) + run_replica_loss()


def smoke() -> List[dict]:
    """CI gate: ≥1.5× checkout throughput for a 4-shard fabric vs a single
    DirectoryStore on the 10%-dirty workload, bit-identical restores
    everywhere, and the replica-loss path healing to 0 scrub problems."""
    rows = run_scatter_gather(repeats=2) + run_replica_loss()
    assert all(r["identical"] for r in rows if "identical" in r), \
        "restore not bit-identical"
    speedup = next(r["checkout_speedup"] for r in rows
                   if "checkout_speedup" in r)
    assert speedup >= 1.5, (
        f"4-shard fabric checkout speedup {speedup} < 1.5x")
    for r in rows:
        if r["workload"].startswith("replica_loss"):
            assert r["scrub_problems_after_repair"] == 0, r
    return rows
