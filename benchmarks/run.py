"""Benchmark driver — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]

Prints CSV rows (``bench,...``) per benchmark plus the roofline table from
the dry-run artifacts (if present).
"""
from __future__ import annotations

import argparse
import csv
import sys
import time


def _print_rows(rows) -> None:
    if not rows:
        return
    keys = []
    for r in rows:
        for k in r:
            if k not in keys:
                keys.append(k)
    w = csv.DictWriter(sys.stdout, fieldnames=keys, extrasaction="ignore")
    w.writeheader()
    for r in rows:
        w.writerow(r)
    sys.stdout.flush()


def bench_ckpt(quick: bool):
    """Fig 13 (size) + Fig 14 (time) + Fig 15/16 (undo / branch switch)."""
    from benchmarks import bench_ckpt as b
    workloads = ["hwlm_like", "sklearn_like"] if quick else None
    return b.rows(b.run(workloads=workloads))


def bench_ckpt_io(quick: bool):
    """Parallel chunk engine: serial vs parallel checkout per backend."""
    from benchmarks import bench_ckpt as b
    if quick:
        return b.run_checkout_io(n_covs=8, elems=1 << 17,
                                 chunk_bytes=1 << 16, repeats=2)
    return b.run_checkout_io()


def bench_tracking(quick: bool):
    """Table 6 / Fig 17 (tracking overhead)."""
    from benchmarks import bench_tracking as b
    return b.run(["hwlm_like", "sklearn_like"] if quick else None)


def bench_covar_sweep(quick: bool):
    """Fig 18 (co-variable size sweep)."""
    from benchmarks import bench_covar_sweep as b
    return b.run(ks=(1, 10) if quick else (1, 2, 5, 10))


def bench_scalability(quick: bool):
    """Fig 19 (graph growth + diff time) + checkout wall vs distance."""
    import tempfile

    from benchmarks import bench_scalability as b
    # graph/diff scaling on the memory store (backend-agnostic metadata);
    # checkout timing on sqlite, a backend the parallel engine engages
    rows = b.run(n_commits=200 if quick else 1000, checkout_rows=False)
    with tempfile.TemporaryDirectory(prefix="kishu_scal_") as tmp:
        rows += b.run(n_commits=200 if quick else 400,
                      store_uri=f"sqlite://{tmp}/scal.db", graph_rows=False)
    return rows


def bench_compat(quick: bool):
    """Fig 12 / Tables 4-5 analogue (leaf-type compatibility matrix)."""
    from benchmarks import bench_compat as b
    return b.run()


def bench_roofline(quick: bool):
    """Deliverable (g): roofline terms per (arch x shape) from the dry-run."""
    from benchmarks import roofline
    rows = []
    for mesh in ("single", "multi"):
        for r in roofline.run(mesh=mesh):
            if r.get("status") == "ok":
                rows.append({
                    "bench": "roofline", "mesh": mesh, "arch": r["arch"],
                    "shape": r["shape"],
                    "compute_s": f"{r['compute_s']:.4e}",
                    "memory_s": f"{r['memory_s']:.4e}",
                    "collective_s": f"{r['collective_s']:.4e}",
                    "dominant": r["dominant"],
                    "useful_ratio": round(r["useful_ratio"], 3),
                    "roofline_frac": round(r["roofline_frac"], 4),
                })
            else:
                rows.append({"bench": "roofline", "mesh": mesh,
                             "arch": r["arch"], "shape": r["shape"],
                             "dominant": "SKIP"})
    return rows


ALL = {
    "ckpt": bench_ckpt,
    "ckpt_io": bench_ckpt_io,
    "tracking": bench_tracking,
    "covar_sweep": bench_covar_sweep,
    "scalability": bench_scalability,
    "compat": bench_compat,
    "roofline": bench_roofline,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", choices=list(ALL))
    args = ap.parse_args()
    names = [args.only] if args.only else list(ALL)
    for name in names:
        t0 = time.time()
        print(f"# ---- {name} ----", flush=True)
        rows = ALL[name](args.quick)
        _print_rows(rows)
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
