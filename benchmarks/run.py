"""Benchmark driver — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]
    PYTHONPATH=src python -m benchmarks.run --smoke      # CI delta gate

Prints CSV rows (``bench,...``) per benchmark plus the roofline table from
the dry-run artifacts (if present).  The ``delta`` bench (and ``--smoke``)
additionally writes machine-readable trajectory artifacts at the repo root —
``BENCH_ckpt_io.json`` (checkpoint-side bytes moved vs logical) and
``BENCH_checkout.json`` (checkout-side) — so future PRs can diff their
numbers against this one.  ``--smoke`` asserts the delta pipeline's
acceptance bars (>=5x fewer bytes moved on a ~10%-dirty workload,
bit-identical restores, compression on and off) and exits non-zero on
regression.
"""
from __future__ import annotations

import argparse
import csv
import json
import os
import sys
import time

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _write_bench_json(name: str, rows) -> None:
    path = os.path.join(_REPO_ROOT, name)
    with open(path, "w") as f:
        json.dump({"generated_by": "benchmarks/run.py", "rows": rows},
                  f, indent=1, sort_keys=True)
    print(f"# wrote {path}", flush=True)


def _emit_delta_artifacts(rows) -> None:
    ckpt = [r for r in rows if r.get("phase") == "checkpoint"]
    checkout = [r for r in rows if r.get("phase") == "checkout"]
    _write_bench_json("BENCH_ckpt_io.json", ckpt)
    _write_bench_json("BENCH_checkout.json", checkout)


def _print_rows(rows) -> None:
    if not rows:
        return
    keys = []
    for r in rows:
        for k in r:
            if k not in keys:
                keys.append(k)
    w = csv.DictWriter(sys.stdout, fieldnames=keys, extrasaction="ignore")
    w.writeheader()
    for r in rows:
        w.writerow(r)
    sys.stdout.flush()


def bench_ckpt(quick: bool):
    """Fig 13 (size) + Fig 14 (time) + Fig 15/16 (undo / branch switch)."""
    from benchmarks import bench_ckpt as b
    workloads = ["hwlm_like", "sklearn_like"] if quick else None
    return b.rows(b.run(workloads=workloads))


def bench_ckpt_io(quick: bool):
    """Parallel chunk engine: serial vs parallel checkout per backend."""
    from benchmarks import bench_ckpt as b
    if quick:
        return b.run_checkout_io(n_covs=8, elems=1 << 17,
                                 chunk_bytes=1 << 16, repeats=2)
    return b.run_checkout_io()


def bench_delta(quick: bool):
    """Chunk-granular delta pipeline: bytes moved vs logical, per backend /
    codec / phase, plus the warm-cache zero-fetch row.  Writes BENCH_*.json."""
    from benchmarks import bench_delta as b
    if quick:
        rows = b.run(n_covs=2, elems=1 << 14, chunk_bytes=1 << 12, repeats=2)
    else:
        rows = b.run()
    _emit_delta_artifacts(rows)
    return rows


def bench_fabric(quick: bool):
    """Storage fabric: scatter-gather checkout speedup (N-shard ring of
    device-modeled stores vs one device) + replica-loss restore/heal rows.
    Writes BENCH_fabric.json."""
    from benchmarks import bench_fabric as b
    rows = b.run(repeats=2) if quick else b.run()
    _write_bench_json("BENCH_fabric.json", rows)
    return rows


def bench_planner(quick: bool):
    """Cost-based checkout planner: p50 checkout wall for fetch-only vs
    planner-auto on a latency-injected device store at {1,10,50}% dirty,
    plan-estimate-vs-actual error, bit-identity across modes.  Writes
    BENCH_planner.json."""
    from benchmarks import bench_planner as b
    rows = b.run(repeats=2) if quick else b.run()
    _write_bench_json("BENCH_planner.json", rows)
    return rows


def bench_txn(quick: bool):
    """Transactional commit engine: group-commit throughput, publish
    latency hidden behind think time, recovery vs journal length.  Writes
    BENCH_txn.json."""
    from benchmarks import bench_txn as b
    rows = b.run(n_cells=12) if quick else b.run()
    _write_bench_json("BENCH_txn.json", rows)
    return rows


def bench_multi(quick: bool):
    """Multi-session safety: N tenants over one shared store via kishud —
    aggregate cells/s + p50/p99 checkout latency vs N, lease-steal
    recovery after a killed writer.  Writes BENCH_multi.json."""
    from benchmarks import bench_multi as b
    rows = b.run(n_cells=8) if quick else b.run(n_cells=32)
    _write_bench_json("BENCH_multi.json", rows)
    return rows


def bench_device_delta(quick: bool):
    """Fused on-device delta pipeline: device→host traffic vs dirty
    fraction {1,10,50}%, device (fused pack) vs host path, bit-identity
    across backends.  Writes BENCH_device_delta.json."""
    from benchmarks import bench_device_delta as b
    if quick:
        rows = b.run(n_covs=2, elems=1 << 14, chunk_bytes=1 << 12,
                     repeats=2, backends=("memory",))
    else:
        rows = b.run()
    _write_bench_json("BENCH_device_delta.json", rows)
    return rows


def bench_device_codec(quick: bool):
    """Closed PCIe loop: on-device bitshuffle codec (write) + fused
    device-scatter checkout vs the raw fused pipeline and the host path,
    bit-identity + logical CAS keys across backends.  Writes
    BENCH_device_codec.json."""
    from benchmarks import bench_device_codec as b
    if quick:
        rows = b.run(n_covs=2, elems=1 << 14, chunk_bytes=1 << 12,
                     repeats=2, backends=("memory",))
    else:
        rows = b.run()
    _write_bench_json("BENCH_device_codec.json", rows)
    return rows


def bench_obs(quick: bool):
    """Observability plane: tracing-on vs tracing-off commit+checkout
    latency on sqlite (overhead budget < 3%), Chrome-trace export contract
    (>= 6 stages, correct nesting).  Writes BENCH_obs.json."""
    from benchmarks import bench_obs as b
    rows = b.run(n_cells=15, repeats=3) if quick else b.run()
    _write_bench_json("BENCH_obs.json", rows)
    return rows


def bench_tracking(quick: bool):
    """Table 6 / Fig 17 (tracking overhead)."""
    from benchmarks import bench_tracking as b
    return b.run(["hwlm_like", "sklearn_like"] if quick else None)


def bench_covar_sweep(quick: bool):
    """Fig 18 (co-variable size sweep)."""
    from benchmarks import bench_covar_sweep as b
    return b.run(ks=(1, 10) if quick else (1, 2, 5, 10))


def bench_scalability(quick: bool):
    """Fig 19 (graph growth + diff time) + checkout wall vs distance."""
    import tempfile

    from benchmarks import bench_scalability as b
    # graph/diff scaling on the memory store (backend-agnostic metadata);
    # checkout timing on sqlite, a backend the parallel engine engages
    rows = b.run(n_commits=200 if quick else 1000, checkout_rows=False)
    with tempfile.TemporaryDirectory(prefix="kishu_scal_") as tmp:
        rows += b.run(n_commits=200 if quick else 400,
                      store_uri=f"sqlite://{tmp}/scal.db", graph_rows=False)
    return rows


def bench_compat(quick: bool):
    """Fig 12 / Tables 4-5 analogue (leaf-type compatibility matrix)."""
    from benchmarks import bench_compat as b
    return b.run()


def bench_roofline(quick: bool):
    """Deliverable (g): roofline terms per (arch x shape) from the dry-run."""
    from benchmarks import roofline
    rows = []
    rows += roofline.detection_rows()   # checkpoint-detection roofline
    for mesh in ("single", "multi"):
        for r in roofline.run(mesh=mesh):
            if r.get("status") == "ok":
                rows.append({
                    "bench": "roofline", "mesh": mesh, "arch": r["arch"],
                    "shape": r["shape"],
                    "compute_s": f"{r['compute_s']:.4e}",
                    "memory_s": f"{r['memory_s']:.4e}",
                    "collective_s": f"{r['collective_s']:.4e}",
                    "dominant": r["dominant"],
                    "useful_ratio": round(r["useful_ratio"], 3),
                    "roofline_frac": round(r["roofline_frac"], 4),
                })
            else:
                rows.append({"bench": "roofline", "mesh": mesh,
                             "arch": r["arch"], "shape": r["shape"],
                             "dominant": "SKIP"})
    return rows


ALL = {
    "ckpt": bench_ckpt,
    "ckpt_io": bench_ckpt_io,
    "delta": bench_delta,
    "device_delta": bench_device_delta,
    "device_codec": bench_device_codec,
    "fabric": bench_fabric,
    "planner": bench_planner,
    "txn": bench_txn,
    "multi": bench_multi,
    "obs": bench_obs,
    "tracking": bench_tracking,
    "covar_sweep": bench_covar_sweep,
    "scalability": bench_scalability,
    "compat": bench_compat,
    "roofline": bench_roofline,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", choices=list(ALL))
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI gate: delta-pipeline bytes-moved "
                         "assertions + BENCH_*.json artifacts")
    ap.add_argument("--smoke-device", action="store_true",
                    help="fast CI gate: fused on-device delta pipeline — "
                         "traffic-ratio + bit-identity assertions on the "
                         "CPU interpreter path + BENCH_device_delta.json")
    ap.add_argument("--smoke-device-codec", action="store_true",
                    help="fast CI gate: on-device codec + fused scatter "
                         "checkout — PCIe-traffic ratio, one-pass-per-cov "
                         "and bit-identity assertions on the CPU "
                         "interpreter path + BENCH_device_codec.json")
    ap.add_argument("--smoke-fabric", action="store_true",
                    help="fast CI gate: storage-fabric scatter-gather "
                         "speedup + replica-loss restore assertions + "
                         "BENCH_fabric.json")
    ap.add_argument("--smoke-planner", action="store_true",
                    help="fast CI gate: cost-based checkout planner — "
                         "planner-auto >=1.5x over fetch-only at 10%% "
                         "dirty on a latency-injected store, bit-identity "
                         "+ plan-matches-execution assertions + "
                         "BENCH_planner.json")
    ap.add_argument("--smoke-txn", action="store_true",
                    help="fast CI gate: transactional commit engine — "
                         "group-commit amortization + crash-recovery "
                         "assertions + BENCH_txn.json")
    ap.add_argument("--smoke-multi", action="store_true",
                    help="fast CI gate: multi-session safety — N-session "
                         "scaling rows, two-writer interleave, lease-steal "
                         "assertions + BENCH_multi.json")
    ap.add_argument("--smoke-obs", action="store_true",
                    help="fast CI gate: observability plane — Chrome-trace "
                         "export contract + tracing-overhead budget (<3%% "
                         "on the sqlite commit bench) + BENCH_obs.json")
    args = ap.parse_args()
    if args.smoke:
        from benchmarks import bench_delta as b
        rows = b.smoke()        # raises AssertionError on regression
        _print_rows(rows)
        _emit_delta_artifacts(rows)
        print("# delta smoke OK", flush=True)
        return
    if args.smoke_device:
        from benchmarks import bench_device_delta as b
        rows = b.smoke()        # raises AssertionError on regression
        _print_rows(rows)
        _write_bench_json("BENCH_device_delta.json", rows)
        print("# device delta smoke OK", flush=True)
        return
    if args.smoke_device_codec:
        from benchmarks import bench_device_codec as b
        rows = b.smoke()        # raises AssertionError on regression
        _print_rows(rows)
        _write_bench_json("BENCH_device_codec.json", rows)
        print("# device codec smoke OK", flush=True)
        return
    if args.smoke_fabric:
        from benchmarks import bench_fabric as b
        rows = b.smoke()        # raises AssertionError on regression
        _print_rows(rows)
        _write_bench_json("BENCH_fabric.json", rows)
        print("# fabric smoke OK", flush=True)
        return
    if args.smoke_planner:
        from benchmarks import bench_planner as b
        rows = b.smoke()        # raises AssertionError on regression
        _print_rows(rows)
        _write_bench_json("BENCH_planner.json", rows)
        print("# planner smoke OK", flush=True)
        return
    if args.smoke_txn:
        from benchmarks import bench_txn as b
        rows = b.smoke()        # raises AssertionError on regression
        _print_rows(rows)
        _write_bench_json("BENCH_txn.json", rows)
        print("# txn smoke OK", flush=True)
        return
    if args.smoke_multi:
        from benchmarks import bench_multi as b
        rows = b.smoke()        # raises AssertionError on regression
        _print_rows(rows)
        _write_bench_json("BENCH_multi.json", rows)
        print("# multi smoke OK", flush=True)
        return
    if args.smoke_obs:
        from benchmarks import bench_obs as b
        rows = b.smoke()        # raises AssertionError on regression
        _print_rows(rows)
        _write_bench_json("BENCH_obs.json", rows)
        print("# obs smoke OK", flush=True)
        return
    names = [args.only] if args.only else list(ALL)
    for name in names:
        t0 = time.time()
        print(f"# ---- {name} ----", flush=True)
        rows = ALL[name](args.quick)
        _print_rows(rows)
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
