"""Multi-session safety: N tenants over one shared store via kishud →
BENCH_multi.json (DESIGN.md §14).

Two stories, matching the daemon's two claims:

  * **Sessions multiplex without stepping on each other.**  N tenant
    sessions hammer one store through a single ``Kishud`` — each holds its
    own namespace lease, chunks dedup store-wide, and every operation is
    admitted through the two-class queue.  The rows pin aggregate cells/s
    and the p50/p99 checkout latency a single user feels as N grows (the
    honest cost of sharing: on one process the sessions contend for the
    GIL and the admission workers, so per-tenant throughput falls while
    aggregate throughput holds roughly flat).
  * **A dead writer's lease is stolen only after a full observed TTL.**
    A writer commits and is abandoned without releasing (the kill -9
    model); a contender with ``wait_s=0`` is refused at once, and a
    patient contender takes over only after the same lease doc has stayed
    unchanged for the doc's full ``ttl_s`` on the *contender's* monotonic
    clock — the row records the measured time-to-steal and that the store
    fscks clean after the successor's first commit.

``smoke()`` is the CI gate: the scaling rows must cover N ∈ {1, 2, 4, 8},
two concurrent sessions on a memory *and* a dir store must interleave
commits with bit-identical checkouts, one tenant's ``gc()`` must reap 0
chunks reachable from the other, and the steal must not beat the TTL.
"""
from __future__ import annotations

import os
import tempfile
import threading
import time
from typing import Dict, List

import numpy as np

from repro.core import txn
from repro.core.chunkstore import MemoryStore, open_store
from repro.core.lease import LeaseHeld
from repro.core.session import KishuSession
from repro.launch.kishud import Kishud


def _init(ns, elems):
    ns["w"] = np.zeros(elems, np.float32)


def _step(ns, seed):
    a = ns["w"]
    a[seed % len(a)] = float(seed)      # one dirty chunk per cell


def _wire(sess) -> None:
    sess.register("init", _init)
    sess.register("step", _step)


# ---------------------------------------------------------------------------
# story 1: throughput + checkout latency vs N sessions
# ---------------------------------------------------------------------------

def run_scaling(ns=(1, 2, 4, 8), n_cells: int = 16, elems: int = 1 << 13,
                chunk_bytes: int = 1 << 12, workers: int = 4,
                backend: str = "memory") -> List[dict]:
    rows: List[dict] = []
    with tempfile.TemporaryDirectory(prefix="kishu_multi_") as tmp:
        for n in ns:
            store = (MemoryStore() if backend == "memory"
                     else open_store(f"dir://{tmp}/scale{n}"))
            d = Kishud(store, workers=workers, lease_ttl_s=30.0,
                       chunk_bytes=chunk_bytes)
            lat_lock = threading.Lock()
            checkout_s: List[float] = []
            commit_done: List[float] = []
            start = threading.Barrier(n + 1)

            def tenant_loop(tid: int) -> None:
                sess = d.session(f"t{tid}")
                _wire(sess)
                sess.init_state({})
                sess.run("init", elems=elems)
                start.wait()
                cids = [sess.run("step", seed=i + 1)
                        for i in range(n_cells)]
                done = time.perf_counter()
                lats = []
                for cid in cids[-8:]:            # revisit recent commits
                    t0 = time.perf_counter()
                    sess.checkout(cid)
                    lats.append(time.perf_counter() - t0)
                with lat_lock:
                    commit_done.append(done)
                    checkout_s.extend(lats)
                sess.close()

            threads = [threading.Thread(target=tenant_loop, args=(i,))
                       for i in range(n)]
            for t in threads:
                t.start()
            start.wait()
            t0 = time.perf_counter()
            for t in threads:
                t.join()
            wall = max(commit_done) - t0
            status = d.status()
            d.close()
            assert all(r.problems == 0
                       for r in txn.fsck_all(store).values())
            rows.append({
                "bench": "multi", "story": "scaling", "backend": backend,
                "n_sessions": n, "n_cells_total": n * n_cells,
                "commit_wall_s": round(wall, 4),
                "cells_per_s": round(n * n_cells / max(wall, 1e-9), 1),
                "checkout_p50_ms":
                    round(float(np.percentile(checkout_s, 50)) * 1e3, 3),
                "checkout_p99_ms":
                    round(float(np.percentile(checkout_s, 99)) * 1e3, 3),
                "store_chunks": status["store_chunks"],
                "served_interactive":
                    status["queue"]["served_interactive"],
            })
    return rows


# ---------------------------------------------------------------------------
# story 2: lease steal after a killed writer
# ---------------------------------------------------------------------------

def run_lease_steal(ttl_s: float = 0.4) -> List[dict]:
    rows: List[dict] = []
    with tempfile.TemporaryDirectory(prefix="kishu_multi_") as tmp:
        uri = f"dir://{tmp}/cas"
        a = KishuSession(open_store(uri), tenant="nb",
                         lease_ttl_s=ttl_s, chunk_bytes=1 << 12)
        _wire(a)
        a.init_state({})
        a.run("init", elems=1 << 12)
        survivor = a.run("step", seed=7)
        expect = a.ns["w"].copy()
        del a                            # killed: lease doc left behind

        t0 = time.perf_counter()
        try:
            KishuSession(open_store(uri), tenant="nb", lease_ttl_s=ttl_s)
            raise AssertionError("impatient contender was granted a "
                                 "live writer's lease")
        except LeaseHeld:
            refused_at_once = True

        b = KishuSession(open_store(uri), tenant="nb", lease_ttl_s=ttl_s,
                         lease_wait_s=ttl_s * 10, chunk_bytes=1 << 12)
        steal_s = time.perf_counter() - t0
        _wire(b)
        # rehydrate HEAD (a fresh session attaches with an empty live
        # namespace), then check out under the stolen lease
        b.loader.materialize_state(b.tracked, b.graph.head)
        b.checkout(survivor)
        assert np.array_equal(b.ns["w"], expect), \
            "survivor commit not bit-identical after takeover"
        b.run("step", seed=8)
        root = b.store.root_store
        b.close()
        problems = sum(r.problems for r in txn.fsck_all(root).values())
        assert steal_s >= ttl_s, \
            f"lease stolen after {steal_s:.3f}s < ttl {ttl_s}s"
        assert problems == 0
        rows.append({
            "bench": "multi", "story": "lease_steal", "ttl_s": ttl_s,
            "refused_at_once": refused_at_once,
            "time_to_steal_s": round(steal_s, 3),
            "fsck_problems": problems,
        })
    return rows


def run(**kw) -> List[dict]:
    return run_scaling(**kw) + run_lease_steal()


# ---------------------------------------------------------------------------
# CI gate
# ---------------------------------------------------------------------------

def _two_writer_check(store) -> dict:
    """Two tenants interleave commits through one daemon; every commit
    must check out bit-identical, and either tenant's gc must reap zero
    chunks the other can still reach."""
    d = Kishud(store, workers=2, lease_ttl_s=30.0, chunk_bytes=1 << 12)
    sessions = {}
    snaps: Dict[str, Dict[str, np.ndarray]] = {"alice": {}, "bob": {}}
    for name in ("alice", "bob"):
        s = d.session(name)
        _wire(s)
        s.init_state({})
        s.run("init", elems=1 << 12)
        sessions[name] = s
    for i in range(6):                   # interleaved: a, b, a, b, ...
        name = "alice" if i % 2 == 0 else "bob"
        cid = sessions[name].run("step", seed=i + 1)
        snaps[name][cid] = sessions[name].ns["w"].copy()
    reaped = sessions["alice"].gc()["chunks_dropped"]
    assert reaped == 0, \
        f"alice's gc reaped {reaped} chunks while bob holds references"
    for name, s in sessions.items():
        for cid, expect in snaps[name].items():
            s.checkout(cid)
            assert np.array_equal(s.ns["w"], expect), \
                f"{name}:{cid} not bit-identical after concurrent commits"
        s.close()
    d.close()
    reports = txn.fsck_all(store)
    assert all(r.problems == 0 for r in reports.values()), \
        {t: r.details for t, r in reports.items() if r.problems}
    return {"bench": "multi", "story": "two_writer",
            "n_commits": 6, "gc_cross_reaped": 0, "fsck_problems": 0}


def smoke() -> List[dict]:
    """CI gate: scaling rows for N ∈ {1,2,4,8}; two concurrent sessions on
    memory and dir stores interleave safely; steal never beats the TTL."""
    rows = (run_scaling(ns=(1, 2, 4, 8), n_cells=6)
            + run_lease_steal(ttl_s=0.3))

    by_n = {r["n_sessions"]: r for r in rows if r["story"] == "scaling"}
    assert sorted(by_n) == [1, 2, 4, 8], f"missing N rows: {sorted(by_n)}"
    for n, r in by_n.items():
        assert r["cells_per_s"] > 0 and r["checkout_p99_ms"] > 0, r

    steal = next(r for r in rows if r["story"] == "lease_steal")
    assert steal["refused_at_once"] and steal["fsck_problems"] == 0
    assert steal["time_to_steal_s"] >= steal["ttl_s"]

    with tempfile.TemporaryDirectory(prefix="kishu_multi_") as tmp:
        for backend in ("memory", "dir"):
            store = (MemoryStore() if backend == "memory"
                     else open_store(f"dir://{os.path.join(tmp, 'cas')}"))
            row = _two_writer_check(store)
            rows.append({**row, "backend": backend})
    return rows
