"""Observability overhead bench — tracing on vs off on the sqlite commit
path (DESIGN.md §16 overhead budget).

Both modes run with the metrics plane in place (InstrumentedStore is
always on); the variable under test is *span tracing*, whose budget is
< 3% added wall on the sqlite commit bench.  Each mode runs ``repeats``
fresh sessions of ``n_cells`` partially-dirty commits plus an undo/redo
checkout pair; per-mode cost is the **min** across repeats (noise floor,
not the mean — the bar gates CI).  The traced mode's stage-time vector and
span count ride along in the row, so BENCH_obs.json doubles as a stage
breakdown artifact.

``smoke()`` (CI ``--smoke-obs``) additionally asserts the export contract:
a traced commit+checkout session yields a Chrome trace with >= 6 distinct
pipeline stages and parent/child intervals that nest.
"""
from __future__ import annotations

import os
import tempfile
import time
from typing import List

OVERHEAD_BUDGET_PCT = 3.0


def _workload(n_covs: int, elems: int, chunk_bytes: int):
    import numpy as np

    chunk_elems = chunk_bytes // 4
    n_chunks = -(-elems * 4 // chunk_bytes)
    dirty = max(1, n_chunks // 10)          # ~10% dirty per cell

    def init(ns, **_):
        rng = np.random.default_rng(7)
        for i in range(n_covs):
            ns[f"v{i:02d}"] = rng.standard_normal(elems).astype(np.float32)

    def mutate(ns, seed=0, **_):
        rng = np.random.default_rng(seed)
        for i in range(n_covs):
            a = ns[f"v{i:02d}"]
            for c in range(dirty):
                a[c * chunk_elems] = rng.standard_normal()

    return init, mutate


def _run_once(tmp: str, tag: str, *, trace: bool, n_covs: int, elems: int,
              chunk_bytes: int, n_cells: int) -> dict:
    from repro.core import KishuSession
    from repro.core.chunkstore import SQLiteStore

    store = SQLiteStore(os.path.join(tmp, f"obs_{tag}.db"))
    sess = KishuSession(store, chunk_bytes=chunk_bytes, cache_bytes=0,
                        trace=trace)
    init, mutate = _workload(n_covs, elems, chunk_bytes)
    sess.register("init", init)
    sess.register("mutate", mutate)
    sess.init_state({})
    first = sess.run("init")

    commits = []
    cell_s = []
    for s in range(n_cells):
        t0 = time.perf_counter()
        commits.append(sess.run("mutate", seed=s))
        cell_s.append(time.perf_counter() - t0)

    t0 = time.perf_counter()
    sess.checkout(commits[0])
    sess.checkout(commits[-1])
    checkout_s = time.perf_counter() - t0

    out = {"cell_s": cell_s, "checkout_s": checkout_s,
           "n_spans": len(sess.obs.tracer.spans),
           "stage_s": {k: round(v, 6) for k, v in
                       sorted(sess.obs.tracer.stage_totals().items())}}
    sess.close()
    del first
    return out


def run(n_covs: int = 4, elems: int = 1 << 16, chunk_bytes: int = 1 << 13,
        n_cells: int = 20, repeats: int = 5) -> List[dict]:
    """One row per mode (trace off / on) + one overhead summary row.

    Per-cell commit timings are reduced element-wise (min across repeats,
    per cell index — same seeds, so cell i does identical work every
    repeat) before summing: a single fsync stall or GC pause then taxes
    one cell of one repeat instead of poisoning a whole run's total, which
    is what the naive min-of-run-totals suffers from on shared CI boxes.
    """
    rows: List[dict] = []
    runs = {"off": [], "on": []}
    with tempfile.TemporaryDirectory(prefix="kishu_obs_") as tmp:
        # warmup pair (page cache, sqlite schema, jit) — discarded
        for trace in (False, True):
            _run_once(tmp, f"warm_{int(trace)}", trace=trace, n_covs=n_covs,
                      elems=elems, chunk_bytes=chunk_bytes, n_cells=2)
        # interleave modes across repeats so drift (thermal, page cache)
        # hits both alike
        for r in range(repeats):
            for trace in (False, True):
                res = _run_once(tmp, f"{r}_{int(trace)}", trace=trace,
                                n_covs=n_covs, elems=elems,
                                chunk_bytes=chunk_bytes, n_cells=n_cells)
                runs["on" if trace else "off"].append(res)
    floor = {}
    for key in ("off", "on"):
        per_cell = [min(rr["cell_s"][i] for rr in runs[key])
                    for i in range(n_cells)]
        floor[key] = {
            "commit_s": sum(per_cell),
            "checkout_s": min(rr["checkout_s"] for rr in runs[key]),
        }
        last = runs[key][-1]
        rows.append({
            "bench": "obs", "backend": "sqlite", "trace": key,
            "n_cells": n_cells,
            "commit_s": round(floor[key]["commit_s"], 5),
            "commit_ms_per_cell": round(
                floor[key]["commit_s"] / n_cells * 1e3, 4),
            "checkout_s": round(floor[key]["checkout_s"], 5),
            "n_spans": last["n_spans"],
            "stage_s": last["stage_s"],
        })
    overhead_pct = (floor["on"]["commit_s"] - floor["off"]["commit_s"]) \
        / floor["off"]["commit_s"] * 100.0
    co_overhead_pct = (floor["on"]["checkout_s"]
                       - floor["off"]["checkout_s"]) \
        / floor["off"]["checkout_s"] * 100.0
    rows.append({
        "bench": "obs", "backend": "sqlite", "trace": "overhead",
        "n_cells": n_cells,
        "commit_overhead_pct": round(overhead_pct, 3),
        "checkout_overhead_pct": round(co_overhead_pct, 3),
        "budget_pct": OVERHEAD_BUDGET_PCT,
    })
    return rows


def _check_export_contract() -> dict:
    """A traced commit+checkout exports >= 6 distinct pipeline stages with
    correct parent/child interval nesting (the acceptance bar)."""
    from repro.core import KishuSession, open_store
    from repro.obs import chrome_trace

    sess = KishuSession(open_store("memory://"), chunk_bytes=1 << 12,
                        trace=True)
    init, mutate = _workload(2, 1 << 14, 1 << 12)
    sess.register("init", init)
    sess.register("mutate", mutate)
    sess.init_state({})
    c1 = sess.run("init")
    sess.run("mutate", seed=1)
    sess.checkout(c1)
    spans = list(sess.obs.tracer.spans)
    sess.close()

    doc = chrome_trace(spans)
    events = doc["traceEvents"]
    assert events and all(
        e["ph"] == "X" and "ts" in e and "dur" in e for e in events)
    names = {e["name"] for e in events}
    assert len(names) >= 6, f"only {len(names)} distinct stages: {names}"
    by_id = {r.span_id: r for r in spans}
    nested = 0
    for r in spans:
        if r.parent_id is None:
            continue
        p = by_id[r.parent_id]          # parent must be recorded too
        assert p.t0_s - 1e-6 <= r.t0_s \
            and r.t0_s + r.dur_s <= p.t0_s + p.dur_s + 1e-6, \
            f"span {r.name} escapes parent {p.name}"
        nested += 1
    assert nested > 0, "no nested spans recorded"
    return {"bench": "obs", "trace": "export", "stages": len(names),
            "events": len(events), "nested_spans": nested}


def smoke() -> List[dict]:
    """CI gate: export contract + tracing overhead under budget."""
    rows = [_check_export_contract()]
    rows += run(n_cells=15, repeats=4)
    summary = rows[-1]
    assert summary["commit_overhead_pct"] < OVERHEAD_BUDGET_PCT, (
        f"tracing overhead {summary['commit_overhead_pct']}% exceeds "
        f"{OVERHEAD_BUDGET_PCT}% budget on the sqlite commit bench")
    return rows


if __name__ == "__main__":
    import json
    print(json.dumps(smoke(), indent=1))
