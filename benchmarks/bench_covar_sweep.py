"""Fig 18: checkpoint/checkout efficiency vs % of state data inside one
co-variable.  Ten 4MB arrays; k of them are views into one shared buffer
(one co-variable of k*4MB); a command modifies exactly one member array."""
from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.core import KishuSession, MemoryStore, Namespace, TrackedNamespace
from repro.core.baselines import DumpSession, PageIncremental

ARR_MB = 4
N_ARRS = 10
ARR_ELEMS = ARR_MB * (1 << 20) // 4


def _make_state(k_shared: int):
    """k arrays are slices of one base buffer (one co-variable); the rest are
    independent."""
    rng = np.random.default_rng(0)
    tree = {}
    if k_shared:
        base = rng.standard_normal(k_shared * ARR_ELEMS).astype(np.float32)
        for i in range(k_shared):
            tree[f"a{i}"] = base[i * ARR_ELEMS:(i + 1) * ARR_ELEMS]
    for i in range(k_shared, N_ARRS):
        tree[f"a{i}"] = rng.standard_normal(ARR_ELEMS).astype(np.float32)
    return tree


def modify_one(ns, which: int = 0):
    # in-place update of one member (paper: one array in the list)
    arr = ns[f"a{which}"]
    arr[:1024] = arr[:1024] + 1.0
    ns[f"a{which}"] = arr


def run(ks=(1, 2, 5, 10)) -> List[dict]:
    out = []
    for k in ks:
        # --- kishu, paper-faithful (whole co-variable = one chunk) and
        #     beyond-paper chunked dedup ---
        kishu_modes = {}
        for mode, cb in (("paper", 1 << 34), ("chunked", 1 << 18)):
            sess = KishuSession(MemoryStore(), chunk_bytes=cb)
            sess.register("modify_one", modify_one)
            sess.init_state(_make_state(k))
            base_bytes = sess.store.chunk_bytes_total()
            c1 = sess.run("modify_one", which=0)
            ck_bytes = sess.store.chunk_bytes_total() - base_bytes
            ck_s = sess.last_run.detect_s + sess.last_run.write_s
            sess.run("modify_one", which=0)
            t0 = time.perf_counter()
            sess.checkout(c1)
            co_s = time.perf_counter() - t0
            kishu_modes[mode] = (ck_bytes, ck_s, co_s)
        (ck_bytes, ck_s, co_s) = kishu_modes["paper"]
        (ck_bytes_c, ck_s_c, co_s_c) = kishu_modes["chunked"]

        # --- dump session ---
        ns = Namespace()
        ns.set_tree("", {})  # no-op
        for name, v in _make_state(k).items():
            ns[name] = v
        d = DumpSession(MemoryStore())
        tns = TrackedNamespace(ns)
        d.checkpoint(ns, "t0")
        modify_one(tns, 0)
        stt = d.checkpoint(ns, "t1")
        dump_bytes, dump_s = stt.bytes_written, stt.ckpt_s
        stt = d.checkout(ns, "t0")
        dump_co_s = stt.checkout_s

        # --- page incremental ---
        ns2 = Namespace()
        for name, v in _make_state(k).items():
            ns2[name] = v
        p = PageIncremental(MemoryStore())
        tns2 = TrackedNamespace(ns2)
        p.checkpoint(ns2, "t0", parent=None)
        modify_one(tns2, 0)
        stt = p.checkpoint(ns2, "t1", parent="t0")
        page_bytes, page_s = stt.bytes_written, stt.ckpt_s
        stt = p.checkout(ns2, "t0")
        page_co_s = stt.checkout_s

        out.append({
            "bench": "covar_sweep",
            "pct_state_in_covariable": 100 * k // N_ARRS,
            "kishu_ckpt_MB": round(ck_bytes / 2**20, 3),
            "kishu_ckpt_s": round(ck_s, 4),
            "kishu_checkout_s": round(co_s, 4),
            "kishu_chunked_ckpt_MB": round(ck_bytes_c / 2**20, 3),
            "kishu_chunked_ckpt_s": round(ck_s_c, 4),
            "kishu_chunked_checkout_s": round(co_s_c, 4),
            "dump_ckpt_MB": round(dump_bytes / 2**20, 3),
            "dump_ckpt_s": round(dump_s, 4),
            "dump_checkout_s": round(dump_co_s, 4),
            "page_ckpt_MB": round(page_bytes / 2**20, 3),
            "page_ckpt_s": round(page_s, 4),
            "page_checkout_s": round(page_co_s, 4),
        })
    return out
