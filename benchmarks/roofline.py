"""Roofline analysis from the dry-run artifacts (deliverable g).

Per (arch x shape x mesh):
  compute term    = HLO_FLOPs_per_device / peak_FLOP/s          [s]
  memory term     = HLO_bytes_per_device / HBM_bw               [s]
  collective term = collective_bytes_per_device / ICI link bw   [s]

cost_analysis() on the compiled SPMD module reports *per-device* flops and
bytes; collective bytes are parsed from the optimized HLO (also per-device),
so all three terms are per-chip seconds and directly comparable.  The
dominant term is the bottleneck; MODEL_FLOPS = 6*N(_active)*D measures how
much of the compiled compute is "useful" (catches remat/dispatch waste).
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from repro.launch.mesh import HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16

ART_DIR = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")

SHAPE_TOKENS = {
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 128,         # one token per sequence
    "long_500k": 1,
}


def model_flops(rec: dict) -> float:
    """6*N_active*D for training, 2*N_active*D for inference (fwd only)."""
    n = rec.get("params_active", 0)
    toks = SHAPE_TOKENS[rec["shape"]]
    mult = 6 if rec["shape"] == "train_4k" else 2
    return mult * n * toks


def analyze_record(rec: dict) -> Optional[dict]:
    if rec.get("status") != "ok":
        return {"arch": rec["arch"], "shape": rec["shape"],
                "mesh": rec["mesh"], "status": rec.get("status"),
                "reason": rec.get("reason", rec.get("error", ""))[:100]}
    cal = rec.get("calibrated") or {}
    calibrated = "flops" in cal
    if calibrated:
        # scan-aware costs (XLA counts a while body once; the dry-run's
        # unrolled calibration recovers the true linear-in-layers costs,
        # validated <2% flops / <1% collectives vs a full unroll)
        flops = cal["flops"]
        hbm_bytes = cal["bytes"]
        coll = cal["coll_total"]
    else:
        ca = rec["cost_analysis"]
        flops = ca.get("flops", 0.0)
        hbm_bytes = ca.get("bytes accessed", 0.0)
        coll = rec["collectives"]["total"]
    n_dev = rec["n_devices"]
    t_compute = flops / PEAK_FLOPS_BF16
    t_memory = hbm_bytes / HBM_BW
    t_coll = coll / ICI_BW_PER_LINK
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    step_time = max(terms.values())
    mf = model_flops(rec) / n_dev
    useful_ratio = mf / flops if flops else 0.0
    # roofline fraction: useful model flops per second vs peak
    mfu_bound = (mf / step_time) / PEAK_FLOPS_BF16 if step_time else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "status": "ok",
        "calibrated": calibrated,
        "compute_s": t_compute, "memory_s": t_memory, "collective_s": t_coll,
        "dominant": dominant,
        "model_flops_per_dev": mf,
        "hlo_flops_per_dev": flops,
        "useful_ratio": useful_ratio,
        "roofline_frac": mfu_bound,
        "arg_GiB_per_dev": rec["arg_bytes_per_device"] / 2**30,
        "fits_16GiB": rec["arg_bytes_per_device"] / 2**30 < 16.0,
    }


def load_all(art_dir: str = ART_DIR) -> List[dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def run(art_dir: str = ART_DIR, mesh: str = "single",
        include_variants: bool = False) -> List[dict]:
    rows = []
    for rec in load_all(art_dir):
        if rec.get("mesh") != mesh:
            continue
        if rec.get("variant") and not include_variants:
            continue                    # hillclimb variants live in §Perf
        row = analyze_record(rec)
        if row:
            row["variant"] = rec.get("variant", "")
            rows.append(row)
    return rows


DEVICE_DELTA_ART = os.path.join(os.path.dirname(__file__), "..",
                                "BENCH_device_delta.json")


def detection_rows(path: str = DEVICE_DELTA_ART) -> List[dict]:
    """Checkpoint-detection roofline: achieved vs peak HBM bandwidth.

    The fused delta_pack pass reads every byte of a co-variable exactly once
    (hash + diff + compact in one stream), so detection is memory-bound and
    its roofline is ``bytes_logical / detect_s`` against ``HBM_BW``.  Reads
    the device rows of BENCH_device_delta.json (written by
    bench_device_delta / ``run.py --smoke-device``); returns [] when the
    artifact doesn't exist yet.  On a CPU host the fraction is tiny — the
    row still pins down how far the current substrate is from the target.
    """
    if not os.path.exists(path):
        return []
    with open(path) as f:
        doc = json.load(f)
    out = []
    for r in doc.get("rows", []):
        if r.get("mode") != "device" or not r.get("detect_s"):
            continue
        achieved = r["bytes_logical"] / r["detect_s"]
        out.append({
            "bench": "roofline_detection",
            "backend": r["backend"], "dirty_frac": r["dirty_frac"],
            "bytes_logical": r["bytes_logical"],
            "detect_s": r["detect_s"],
            "achieved_GBps": round(achieved / 1e9, 3),
            "peak_GBps": round(HBM_BW / 1e9, 1),
            "hbm_frac": round(achieved / HBM_BW, 6),
            "bound": "memory",       # one HBM read stream by construction
        })
    return out


def markdown_table(rows: List[dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| useful | roofline frac | arg GiB/dev |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        if r.get("status") != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"SKIP ({r.get('reason','')[:40]}) | — | — | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"{r['dominant']} | {r['useful_ratio']:.2f} | "
            f"{r['roofline_frac']:.3f} | {r['arg_GiB_per_dev']:.2f} |")
    return hdr + "\n".join(lines)


if __name__ == "__main__":
    print(markdown_table(run()))
