"""End-to-end driver: train a ~100M-param LM for a few hundred steps with
Kishu incremental checkpointing, a mid-run undo, and a branch switch.

    PYTHONPATH=src python examples/train_e2e.py [--steps 200] [--d-model 256]

This is the deliverable-(b) end-to-end example.  It uses the smollm-360m
family config scaled to ~100M params (CPU-feasible), phases of 10 steps as
commands, rolls back a deliberately-injected LR spike, then branches two
data mixtures from a shared prefix and switches between them — the paper's
undo (§7.5.1) and path-exploration (§7.5.2) use cases on a real training
state.
"""
import argparse
import time

import numpy as np

from repro.core import open_store
from repro.models import get_config
from repro.optim.adamw import AdamWConfig
from repro.train.loop import ManagedTrainingSession


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--phase-steps", type=int, default=10)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=12)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--store", default="memory://")
    args = ap.parse_args()

    # ~100M params: 12L x d256 + 49152x256 embeddings (tied)
    cfg = get_config("smollm-360m").replace(
        n_layers=args.layers, d_model=args.d_model, n_heads=4, n_kv_heads=2,
        head_dim=64, d_ff=args.d_model * 4, dtype="float32")
    n = cfg.param_counts()["total"]
    print(f"model: {cfg.name}-derived, {n/1e6:.1f}M params")

    sess = ManagedTrainingSession(
        cfg, AdamWConfig(lr=3e-3), open_store(args.store),
        global_batch=args.batch, seq_len=args.seq, chunk_bytes=1 << 18)
    sess.attach(seed=0)

    phases = args.steps // args.phase_steps
    spike_at = phases // 2
    losses, good = [], sess.kishu.head
    for ph in range(phases):
        if ph == spike_at:                     # deliberate mistake
            sess.set_lr(1.0)
            print(f"-- phase {ph}: set lr=1.0 (simulated fat-finger)")
        t0 = time.time()
        cid = sess.train(args.phase_steps)
        loss = sess.ns["metrics/last_loss"]
        rs = sess.kishu.last_run
        print(f"phase {ph:2d} [{cid}] loss={loss:.4f} "
              f"({time.time()-t0:.1f}s; ckpt {rs.write.bytes_written/1e6:.1f}MB, "
              f"detect {rs.detect_s*1e3:.0f}ms)")
        if losses and loss > losses[-1] * 2:
            st = sess.checkout(good)
            print(f"   LOSS SPIKE -> undo to {good} in {st.wall_s*1e3:.0f}ms "
                  f"(loaded {st.covs_loaded}, kept {st.covs_identical}); "
                  f"restoring lr")
            sess.set_lr(3e-3)
        else:
            losses.append(loss)
            good = cid

    # ---- branch exploration: two data mixtures from the same ancestor ----
    fork = sess.kishu.head
    sess.swap_data(seed=101)
    sess.train(args.phase_steps)
    branch_a = sess.kishu.head
    loss_a = sess.ns["metrics/last_loss"]

    sess.checkout(fork)
    sess.swap_data(seed=202)
    sess.train(args.phase_steps)
    branch_b = sess.kishu.head
    loss_b = sess.ns["metrics/last_loss"]

    t0 = time.time()
    st = sess.checkout(branch_a)
    print(f"\nbranch A (seed 101) loss={loss_a:.4f}; "
          f"branch B (seed 202) loss={loss_b:.4f}")
    print(f"switched B->A in {(time.time()-t0)*1e3:.0f}ms "
          f"(loaded {st.covs_loaded} covs, {st.bytes_loaded/1e6:.1f}MB; "
          f"{st.covs_identical} identical)")
    print("storage:", sess.kishu.storage_stats())
    sess.close()


if __name__ == "__main__":
    main()
