"""Batched serving with cache state under Kishu: prefix snapshot + rollback.

    PYTHONPATH=src python examples/serve_batched.py

Serves a reduced mamba2 model (O(1) decode state — the long_500k family).
The decode caches live in a Kishu session: after prefilling a shared system
prompt, the cache state is committed once and each request batch *branches*
from it — regenerations (sampling retries, cancelled streams) roll back to
the prefix commit instead of re-running prefill.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import KishuSession, open_store
from repro.models import get_config, lm
from repro.models.testing import reduced
from repro.train import step as step_lib


def main() -> None:
    cfg = reduced(get_config("mamba2-780m"))
    params = lm.init_params(cfg, jax.random.key(0))
    decode = jax.jit(step_lib.make_decode_step(cfg))

    B, PREFIX, GEN = 4, 24, 12
    sess = KishuSession(open_store("memory://"), chunk_bytes=1 << 14)

    def prefill(ns, seed):
        caches = lm.init_caches(cfg, B, PREFIX + GEN)
        toks = jax.random.randint(jax.random.key(seed), (B, PREFIX), 0,
                                  cfg.vocab_size)
        tok = toks[:, :1]
        for t in range(PREFIX):
            tok, caches = decode(params, caches,
                                 {"tokens": tok, "index": jnp.asarray(t, jnp.int32)})
            if t + 1 < PREFIX:
                tok = toks[:, t + 1:t + 2]
        ns.set_tree("caches", caches)
        ns["last_tok"] = np.asarray(tok)
        ns["pos"] = PREFIX

    def generate(ns, n, flavor):
        caches = ns.get_tree("caches")
        tok = jnp.asarray(ns["last_tok"])
        pos = ns["pos"]
        outs = []
        for t in range(n):
            tok, caches = decode(params, caches,
                                 {"tokens": (tok + flavor) % cfg.vocab_size,
                                  "index": jnp.asarray(pos + t, jnp.int32)})
            outs.append(np.asarray(tok))
        ns.set_tree("caches", caches)
        ns["last_tok"] = np.asarray(tok)
        ns["pos"] = pos + n
        ns["generated"] = np.concatenate(outs, axis=1)

    sess.register("prefill", prefill)
    sess.register("generate", generate)
    sess.init_state({})

    t0 = time.time()
    prefix_commit = sess.run("prefill", seed=7)
    print(f"prefilled {B}x{PREFIX} tokens in {time.time()-t0:.2f}s "
          f"-> commit {prefix_commit} "
          f"({sess.last_run.write.bytes_written/1e3:.0f}KB cache delta)")

    results = {}
    for flavor in (1, 2, 3):
        t0 = time.time()
        st = sess.checkout(prefix_commit)
        sess.run("generate", n=GEN, flavor=flavor)
        results[flavor] = sess.ns["generated"][0, :6]
        print(f"flavor={flavor}: rollback {st.wall_s*1e3:5.1f}ms "
              f"(loaded {st.covs_loaded}, kept {st.covs_identical}), "
              f"gen {GEN} toks in {time.time()-t0:.2f}s -> {results[flavor]}")
    assert not np.array_equal(results[1], results[2])
    print("3 generations served from one prefill; no recomputation of the "
          "shared prefix")
    sess.close()


if __name__ == "__main__":
    main()
