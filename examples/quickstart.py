"""Quickstart: attach Kishu to a toy JAX workflow and time-travel.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import KishuSession, open_store


def main() -> None:
    store = open_store("memory://")          # or dir:///path, sqlite:///db
    s = KishuSession(store, chunk_bytes=1 << 16)

    # 1. register commands — the "cells" of your workflow
    def load_data(ns, n):
        rng = np.random.default_rng(ns["seed"])
        ns["data/x"] = rng.standard_normal((n, 16)).astype(np.float32)

    def fit(ns, steps, lr):
        x, w = ns["data/x"], ns["model/w"]
        for _ in range(steps):
            w = w - lr * (x.T @ (x @ w)) / len(x)
        ns["model/w"] = w

    s.register("load_data", load_data)
    s.register("fit", fit)

    # 2. attach: populate the namespace and commit the initial state
    s.init_state({"seed": 0, "model": {"w": np.ones((16, 4), np.float32)}})
    s.run("load_data", n=256)

    # 3. iterate — every command writes an incremental checkpoint
    c_lr_small = s.run("fit", steps=20, lr=0.01)
    w_small = s.ns["model/w"].copy()
    print(f"[{c_lr_small}] trained with lr=0.01, |w|={np.abs(w_small).mean():.4f}")
    print(f"   checkpoint wrote {s.last_run.write.bytes_written} bytes "
          f"({s.last_run.covs_updated} co-variables, "
          f"{s.last_run.covs_skipped} pruned by access tracking)")

    c_lr_big = s.run("fit", steps=20, lr=0.5)
    print(f"[{c_lr_big}] trained with lr=0.5, "
          f"|w|={np.abs(s.ns['model/w']).mean():.4f}  <- diverged!")

    # 4. time-travel: undo the bad run — only the diverged co-variable loads
    st = s.checkout(c_lr_small)
    print(f"undo -> {c_lr_small}: loaded {st.covs_loaded} co-variables "
          f"({st.bytes_loaded} B), kept {st.covs_identical} untouched, "
          f"in {st.wall_s*1e3:.1f} ms")
    assert np.array_equal(s.ns["model/w"], w_small)

    # 5. branch: different hyperparameters from the same ancestor
    c_branch = s.run("fit", steps=5, lr=0.05)
    print(f"[{c_branch}] new branch from {c_lr_small}")
    print("\ncommit graph:")
    for e in s.log():
        mark = "*" if e["head"] else " "
        print(f" {mark} {e['commit']} <- {e['parent']}  {e['command']}")


if __name__ == "__main__":
    main()
