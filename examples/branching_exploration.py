"""Path-based exploration (§2.1, §7.5.2): a hyperparameter sweep as branches
of the Checkpoint Graph sharing one expensive ancestor state.

    PYTHONPATH=src python examples/branching_exploration.py

Four LR branches fork from one warmed-up model.  Because branches share the
warmup state, each branch's incremental checkpoint stores only its diverged
co-variables, and switching between branches for comparison loads only the
diff (vs reloading the full state with a dump-based tool).
"""
import time

import numpy as np

from repro.core import open_store
from repro.models import get_config
from repro.models.testing import reduced
from repro.optim.adamw import AdamWConfig
from repro.train.loop import ManagedTrainingSession


def main() -> None:
    cfg = reduced(get_config("qwen3-1.7b"), n_layers=4)
    sess = ManagedTrainingSession(
        cfg, AdamWConfig(lr=1e-3), open_store("memory://"),
        global_batch=8, seq_len=64, chunk_bytes=1 << 16)
    sess.attach(seed=0)

    print("warmup (shared ancestor)...")
    sess.train(10)
    fork = sess.kishu.head
    base_bytes = sess.kishu.store.chunk_bytes_total()

    tips = {}
    for lr in (3e-4, 1e-3, 3e-3, 1e-2):
        sess.checkout(fork)
        sess.set_lr(lr)
        sess.train(5)
        sess.evaluate(batches=2)
        tips[lr] = (sess.kishu.head, sess.eval_loss())
        print(f"  branch lr={lr:7.4f} [{tips[lr][0]}] "
              f"eval={tips[lr][1]:.4f}")

    extra = sess.kishu.store.chunk_bytes_total() - base_bytes
    state_mb = sum(
        r.nbytes for r in sess.kishu.records.values()) / 1e6
    print(f"\n4 branches stored {extra/1e6:.1f}MB of deltas "
          f"(full state is {state_mb:.1f}MB -> a dump per branch tip would "
          f"be {4*state_mb:.1f}MB)")

    best = min(tips, key=lambda k: tips[k][1])
    print(f"best lr={best}; switching across branch tips:")
    for lr, (cid, _) in tips.items():
        t0 = time.time()
        st = sess.checkout(cid)
        print(f"  -> lr={lr:7.4f} in {(time.time()-t0)*1e3:6.1f}ms "
              f"(loaded {st.covs_loaded}, identical {st.covs_identical})")
    sess.checkout(tips[best][0])
    print(f"continuing from best branch {tips[best][0]}")
    sess.train(5)
    sess.close()


if __name__ == "__main__":
    main()
