"""Unified observability plane — spans, metrics, exporters (DESIGN.md §16).

One :class:`SessionObs` per :class:`~repro.core.session.KishuSession` bundles
a :class:`~repro.obs.trace.Tracer` (pipeline spans) and a
:class:`~repro.obs.metrics.MetricsRegistry` (counters + log-bucket
histograms).  The session *activates* its handle around ``run()`` /
``checkout()`` via a module-level contextvar, so deep library code — the
delta kernels, the txn recovery path — reports into whichever session is
executing on the current thread without plumbing a handle through every
signature.  Under kishud many sessions share the process; activation is
what keeps their counters (e.g. kernel fallbacks) from cross-attributing.

Tracing is off by default (``KISHU_TRACE=1`` or ``trace=True`` opts in) and
costs one attribute check per call site when off.  Metrics are always on:
an :class:`InstrumentedStore` times every store op, and pipeline code bumps
counters/histograms — no store writes of its own, so crash-injection op
accounting is unchanged.
"""
from __future__ import annotations

import contextlib
import contextvars
import os
import uuid
from typing import Dict, Optional

from repro.obs.metrics import (Counter, Gauge, Histogram, LATENCY_BASE_S,
                               MetricsRegistry, SIZE_BASE_BYTES, render)
from repro.obs.trace import (NULL_SPAN, SpanRecord, Tracer, chrome_trace,
                             spans_from_doc)

TRACE_META_PREFIX = "obs/trace/"

_INSTRUMENT_NAMES = ("InstrumentedStore", "instrument_tree", "backend_label")


def __getattr__(name: str):
    # repro.obs.instrument imports repro.core (for the ChunkStore base),
    # and repro.core.session imports repro.obs — re-exporting lazily keeps
    # this package importable from either direction
    if name in _INSTRUMENT_NAMES:
        from repro.obs import instrument
        return getattr(instrument, name)
    raise AttributeError(f"module 'repro.obs' has no attribute {name!r}")

_active_obs: contextvars.ContextVar[Optional["SessionObs"]] = \
    contextvars.ContextVar("kishu_obs_active", default=None)


def active() -> Optional["SessionObs"]:
    """The SessionObs activated on the current context, if any."""
    return _active_obs.get()


class SessionObs:
    """Per-session observability handle: tracer + metrics registry."""

    def __init__(self, *, trace: Optional[bool] = None,
                 tenant: Optional[str] = None, max_spans: int = 16384):
        if trace is None:
            trace = os.environ.get("KISHU_TRACE", "").strip() in (
                "1", "true", "on")
        self.sid = uuid.uuid4().hex[:12]
        self.tracer = Tracer(enabled=bool(trace), max_spans=max_spans)
        labels: Dict[str, str] = {"tenant": tenant} if tenant else {}
        self.registry = MetricsRegistry(const_labels=labels)
        self._fallback_logged = False

    # ---- spans ----

    def span(self, name: str, **args):
        return self.tracer.span(name, **args)

    @contextlib.contextmanager
    def activate(self):
        token = _active_obs.set(self)
        try:
            yield self
        finally:
            _active_obs.reset(token)

    # ---- kernel-fallback scoping (satellite: core/delta.py globals) ----

    def note_kernel_fallback(self, where: str) -> bool:
        """Count one device-kernel→host degradation; True if it is this
        session's first (caller logs the once-per-session warning)."""
        self.registry.counter("kishu_kernel_fallbacks_total",
                              where=where).inc()
        first = not self._fallback_logged
        self._fallback_logged = True
        return first

    def kernel_fallbacks(self) -> int:
        return int(self.registry.counter_total(
            "kishu_kernel_fallbacks_total"))

    # ---- persistence ----

    def to_doc(self) -> dict:
        return {"sid": self.sid,
                "tenant": self.registry.const_labels.get("tenant"),
                "spans": self.tracer.to_doc(),
                "metrics": self.registry.to_doc()}


__all__ = [
    "SessionObs", "active", "Tracer", "SpanRecord", "chrome_trace",
    "spans_from_doc", "NULL_SPAN", "MetricsRegistry", "Counter", "Gauge",
    "Histogram", "render", "LATENCY_BASE_S", "SIZE_BASE_BYTES",
    "InstrumentedStore", "instrument_tree", "backend_label",
    "TRACE_META_PREFIX",
]
