"""Counters, gauges, and log-bucket histograms with Prometheus text export.

Dependency-free, thread-safe, and cheap: a histogram ``observe`` is one
``frexp`` (power-of-two bucket index), one list bump, two adds.  Buckets
are ``base * 2**i`` — for latency ``base=1e-6`` spans 1µs…>1s in ~21
buckets; for sizes ``base=64`` spans 64B…>4GB in ~27.  Exponential buckets
match the phenomena: store-op latencies and chunk sizes both spread over
orders of magnitude, and ratios (p99/p50) matter more than absolutes.

:func:`render` emits Prometheus text exposition (``# TYPE`` headers,
cumulative ``_bucket{le=...}`` rows, ``_sum``/``_count``) and can merge
several registries — kishud serves one scrape covering the daemon plus
every live tenant session, disambiguated by each registry's const labels.
"""
from __future__ import annotations

import math
import threading
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple

LATENCY_BASE_S = 1e-6       # first bucket upper bound for *_seconds
SIZE_BASE_BYTES = 64.0      # first bucket upper bound for *_bytes
_MAX_BUCKETS = 40


def _label_key(labels: Dict[str, str]) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _fmt_labels(pairs: Iterable[Tuple[str, str]]) -> str:
    items = [f'{k}="{_escape(v)}"' for k, v in pairs]
    return "{" + ",".join(items) + "}" if items else ""


def _escape(v: str) -> str:
    return str(v).replace("\\", "\\\\").replace('"', '\\"').replace(
        "\n", "\\n")


def _fmt_num(v: float) -> str:
    if v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


class Counter:
    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: Dict[str, str]):
        self.name = name
        self.labels = labels
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n          # single bytecode add under the GIL


class Gauge:
    """Instantaneous value: either ``set()`` explicitly or backed by a
    zero-arg callable sampled at render time (live cache stats etc.)."""
    __slots__ = ("name", "labels", "value", "fn")

    def __init__(self, name: str, labels: Dict[str, str],
                 fn: Optional[Callable[[], float]] = None):
        self.name = name
        self.labels = labels
        self.value = 0.0
        self.fn = fn

    def set(self, v: float) -> None:
        self.value = float(v)

    def sample(self) -> float:
        if self.fn is not None:
            try:
                return float(self.fn())
            except Exception:  # noqa: BLE001 — a dead source reads as 0
                return 0.0
        return self.value


class Histogram:
    """Power-of-two buckets: bucket ``i`` holds observations in
    ``(base*2**(i-1), base*2**i]``; index 0 is ``<= base``."""
    __slots__ = ("name", "labels", "base", "counts", "sum", "count", "_lock")

    def __init__(self, name: str, labels: Dict[str, str],
                 base: float = LATENCY_BASE_S):
        self.name = name
        self.labels = labels
        self.base = float(base)
        self.counts: List[int] = []
        self.sum = 0.0
        self.count = 0
        self._lock = threading.Lock()

    def bucket_index(self, v: float) -> int:
        if v <= self.base:
            return 0
        i = int(math.ceil(math.log2(v / self.base)))
        return min(i, _MAX_BUCKETS)

    def observe(self, v: float) -> None:
        i = self.bucket_index(v)
        with self._lock:
            if i >= len(self.counts):
                self.counts.extend([0] * (i + 1 - len(self.counts)))
            self.counts[i] += 1
            self.sum += v
            self.count += 1

    def upper_bounds(self) -> List[float]:
        return [self.base * (2 ** i) for i in range(len(self.counts))]


class MetricsRegistry:
    """Get-or-create keyed on ``(name, labels)``; ``const_labels`` (e.g.
    ``tenant=...``) stamp every sample at render time so merged scrapes
    stay disambiguated."""

    def __init__(self, const_labels: Optional[Dict[str, str]] = None):
        self.const_labels = dict(const_labels or {})
        self._lock = threading.Lock()
        self._counters: Dict[Tuple, Counter] = {}
        self._gauges: Dict[Tuple, Gauge] = {}
        self._histograms: Dict[Tuple, Histogram] = {}

    def counter(self, name: str, **labels: str) -> Counter:
        key = (name, _label_key(labels))
        c = self._counters.get(key)
        if c is None:
            with self._lock:
                c = self._counters.setdefault(key, Counter(name, labels))
        return c

    def gauge(self, name: str, fn: Optional[Callable[[], float]] = None,
              **labels: str) -> Gauge:
        key = (name, _label_key(labels))
        g = self._gauges.get(key)
        if g is None:
            with self._lock:
                g = self._gauges.setdefault(key, Gauge(name, labels, fn))
        if fn is not None:
            g.fn = fn
        return g

    def histogram(self, name: str, base: float = LATENCY_BASE_S,
                  **labels: str) -> Histogram:
        key = (name, _label_key(labels))
        h = self._histograms.get(key)
        if h is None:
            with self._lock:
                h = self._histograms.setdefault(
                    key, Histogram(name, labels, base=base))
        return h

    def counter_total(self, name: str) -> float:
        """Sum of one counter family across all label sets."""
        return sum(c.value for (n, _), c in list(self._counters.items())
                   if n == name)

    # ---- persistence (snapshot into a meta doc and back) ----

    def to_doc(self) -> dict:
        with self._lock:
            return {
                "const_labels": dict(self.const_labels),
                "counters": [{"name": c.name, "labels": dict(c.labels),
                              "value": c.value}
                             for c in self._counters.values()],
                "histograms": [{"name": h.name, "labels": dict(h.labels),
                                "base": h.base, "counts": list(h.counts),
                                "sum": h.sum, "count": h.count}
                               for h in self._histograms.values()],
            }

    @classmethod
    def from_doc(cls, doc: dict) -> "MetricsRegistry":
        reg = cls(const_labels=doc.get("const_labels") or {})
        for c in doc.get("counters", []):
            reg.counter(c["name"], **c.get("labels", {})).value = \
                float(c.get("value", 0))
        for h in doc.get("histograms", []):
            hist = reg.histogram(h["name"], base=float(h.get("base", 1e-6)),
                                 **h.get("labels", {}))
            hist.counts = [int(x) for x in h.get("counts", [])]
            hist.sum = float(h.get("sum", 0.0))
            hist.count = int(h.get("count", 0))
        return reg


def render(registries: Iterable[MetricsRegistry]) -> str:
    """Prometheus text exposition over one or more registries.  Families
    with the same name merge under one ``# TYPE`` header; each sample
    carries its registry's const labels."""
    registries = list(registries)
    counters: Dict[str, List[Tuple[Tuple, float]]] = {}
    gauges: Dict[str, List[Tuple[Tuple, float]]] = {}
    hists: Dict[str, List[Tuple[Tuple, Histogram]]] = {}
    for reg in registries:
        const = tuple(sorted(reg.const_labels.items()))
        for c in list(reg._counters.values()):
            counters.setdefault(c.name, []).append(
                (const + _label_key(c.labels), c.value))
        for g in list(reg._gauges.values()):
            gauges.setdefault(g.name, []).append(
                (const + _label_key(g.labels), g.sample()))
        for h in list(reg._histograms.values()):
            hists.setdefault(h.name, []).append(
                (const + _label_key(h.labels), h))
    lines: List[str] = []
    for name in sorted(counters):
        lines.append(f"# TYPE {name} counter")
        for labels, value in counters[name]:
            lines.append(f"{name}{_fmt_labels(labels)} {_fmt_num(value)}")
    for name in sorted(gauges):
        lines.append(f"# TYPE {name} gauge")
        for labels, value in gauges[name]:
            lines.append(f"{name}{_fmt_labels(labels)} {_fmt_num(value)}")
    for name in sorted(hists):
        lines.append(f"# TYPE {name} histogram")
        for labels, h in hists[name]:
            cum = 0
            with h._lock:
                counts = list(h.counts)
                total, hsum = h.count, h.sum
            for i, n in enumerate(counts):
                cum += n
                le = h.base * (2 ** i)
                row = labels + (("le", f"{le:.6g}"),)
                lines.append(
                    f"{name}_bucket{_fmt_labels(row)} {cum}")
            row = labels + (("le", "+Inf"),)
            lines.append(f"{name}_bucket{_fmt_labels(row)} {total}")
            lines.append(f"{name}_sum{_fmt_labels(labels)} {_fmt_num(hsum)}")
            lines.append(f"{name}_count{_fmt_labels(labels)} {total}")
    return "\n".join(lines) + ("\n" if lines else "")
