"""InstrumentedStore — per-op latency/bytes metrics around any ChunkStore.

Pure delegation plus one ``perf_counter`` pair per op: every backend (dir /
sqlite / memory, and fabric compositions — shard, replica, tier) reports
``kishu_store_op_seconds{op,backend}`` histograms and directional
``kishu_store_bytes_total{dir,backend}`` counters without knowing the
observability plane exists.  The wrapper adds *zero* store operations of
its own, so the crash-injection op sweeps (FaultInjectingStore) count the
same writes with or without it.

Placement matters: the session wraps the *root* store and rebuilds the
tenant namespace view on top (``NamespacedStore(InstrumentedStore(root),
tenant)``) — the txn engine's ``isinstance(store, NamespacedStore)``
unwrapping and meta-prefix logic keep working untouched.

:func:`instrument_tree` optionally descends into a fabric topology and
wraps each shard / replica / tier child with a positional backend label
(``shard0:dir`` …) so a straggler shard shows up as its own histogram.
"""
from __future__ import annotations

import time
from typing import Any, Dict, Iterable, List, Optional, Tuple

from repro.core.chunkstore import ChunkStore
from repro.obs.metrics import (MetricsRegistry, SIZE_BASE_BYTES)

OP_SECONDS = "kishu_store_op_seconds"
BYTES_TOTAL = "kishu_store_bytes_total"

_BACKEND_LABELS = {
    "MemoryStore": "memory",
    "DirectoryStore": "dir",
    "SQLiteStore": "sqlite",
    "CompressedStore": "codec",
    "NamespacedStore": "ns",
    "ShardedStore": "shard",
    "ReplicatedStore": "rep",
    "TieredStore": "tier",
    "FaultInjectedStore": "fault",
    "FaultInjectingStore": "crash",
}


def backend_label(store: Any) -> str:
    name = type(store).__name__
    if name in _BACKEND_LABELS:
        return _BACKEND_LABELS[name]
    low = name.lower()
    return low[:-5] if low.endswith("store") and len(low) > 5 else low


def _pairs_bytes(pairs: Iterable[Tuple[str, bytes]]
                 ) -> Tuple[List[Tuple[str, bytes]], int]:
    pairs = list(pairs)
    return pairs, sum(len(d) for _, d in pairs)


class InstrumentedStore(ChunkStore):
    """Times every ChunkStore op into a :class:`MetricsRegistry`."""

    def __init__(self, inner: ChunkStore, registry: MetricsRegistry, *,
                 backend: Optional[str] = None):
        self.inner = inner
        self.registry = registry
        self.backend = backend or backend_label(inner)
        self.min_slab = getattr(inner, "min_slab", 1)
        self.supports_parallel_get = getattr(inner, "supports_parallel_get",
                                             True)
        self.native_scatter = getattr(inner, "native_scatter", False)
        self._lat: Dict[str, Any] = {}
        self._get_bytes = registry.counter(BYTES_TOTAL, dir="get",
                                           backend=self.backend)
        self._put_bytes = registry.counter(BYTES_TOTAL, dir="put",
                                           backend=self.backend)

    def _obs(self, op: str, t0: float) -> None:
        h = self._lat.get(op)
        if h is None:
            h = self._lat[op] = self.registry.histogram(
                OP_SECONDS, op=op, backend=self.backend)
        h.observe(time.perf_counter() - t0)

    # ---- chunk data ----

    def put_chunk(self, key: str, data: bytes) -> bool:
        t0 = time.perf_counter()
        try:
            wrote = self.inner.put_chunk(key, data)
        finally:
            self._obs("put_chunk", t0)
        if wrote:
            self._put_bytes.inc(len(data))
        return wrote

    def put_chunks(self, pairs: Iterable[Tuple[str, bytes]]) -> int:
        pairs, nbytes = _pairs_bytes(pairs)
        t0 = time.perf_counter()
        try:
            written = self.inner.put_chunks(pairs)
        finally:
            self._obs("put_chunks", t0)
        self._put_bytes.inc(nbytes)
        return written

    def put_chunk_stored(self, key: str, data: bytes) -> bool:
        t0 = time.perf_counter()
        try:
            wrote = self.inner.put_chunk_stored(key, data)
        finally:
            self._obs("put_chunk", t0)
        if wrote:
            self._put_bytes.inc(len(data))
        return wrote

    def put_chunks_stored(self, pairs: Iterable[Tuple[str, bytes]]) -> int:
        pairs, nbytes = _pairs_bytes(pairs)
        t0 = time.perf_counter()
        try:
            written = self.inner.put_chunks_stored(pairs)
        finally:
            self._obs("put_chunks", t0)
        self._put_bytes.inc(nbytes)
        return written

    def get_chunk(self, key: str) -> bytes:
        t0 = time.perf_counter()
        try:
            data = self.inner.get_chunk(key)
        finally:
            self._obs("get_chunk", t0)
        self._get_bytes.inc(len(data))
        return data

    def get_chunk_stored(self, key: str) -> bytes:
        t0 = time.perf_counter()
        try:
            data = self.inner.get_chunk_stored(key)
        finally:
            self._obs("get_chunk", t0)
        self._get_bytes.inc(len(data))
        return data

    def get_chunks(self, keys: Iterable[str], *, missing_ok: bool = False
                   ) -> Dict[str, bytes]:
        keys = list(keys)
        t0 = time.perf_counter()
        try:
            out = self.inner.get_chunks(keys, missing_ok=missing_ok)
        finally:
            self._obs("get_chunks", t0)
        self._get_bytes.inc(sum(len(d) for d in out.values()))
        return out

    def has_chunk(self, key: str) -> bool:
        t0 = time.perf_counter()
        try:
            return self.inner.has_chunk(key)
        finally:
            self._obs("has_chunk", t0)

    def list_chunk_keys(self) -> List[str]:
        t0 = time.perf_counter()
        try:
            return self.inner.list_chunk_keys()
        finally:
            self._obs("list_chunk_keys", t0)

    def chunk_sizes(self, keys: Iterable[str]) -> Dict[str, int]:
        t0 = time.perf_counter()
        try:
            return self.inner.chunk_sizes(keys)
        finally:
            self._obs("chunk_sizes", t0)

    def delete_chunk(self, key: str) -> None:
        t0 = time.perf_counter()
        try:
            self.inner.delete_chunk(key)
        finally:
            self._obs("delete_chunk", t0)

    def delete_chunks(self, keys: Iterable[str]) -> int:
        t0 = time.perf_counter()
        try:
            return self.inner.delete_chunks(keys)
        finally:
            self._obs("delete_chunks", t0)

    def chunk_bytes_total(self) -> int:
        return self.inner.chunk_bytes_total()

    def n_chunks(self) -> int:
        return self.inner.n_chunks()

    # ---- metadata ----

    def put_meta(self, name: str, doc: dict) -> None:
        t0 = time.perf_counter()
        try:
            self.inner.put_meta(name, doc)
        finally:
            self._obs("put_meta", t0)

    def put_meta_batch(self, docs: Dict[str, dict]) -> None:
        t0 = time.perf_counter()
        try:
            self.inner.put_meta_batch(docs)
        finally:
            self._obs("put_meta", t0)

    def get_meta(self, name: str) -> Optional[dict]:
        t0 = time.perf_counter()
        try:
            return self.inner.get_meta(name)
        finally:
            self._obs("get_meta", t0)

    def list_meta(self, prefix: str = "") -> List[str]:
        t0 = time.perf_counter()
        try:
            return self.inner.list_meta(prefix)
        finally:
            self._obs("list_meta", t0)

    def delete_meta(self, name: str) -> None:
        t0 = time.perf_counter()
        try:
            self.inner.delete_meta(name)
        finally:
            self._obs("delete_meta", t0)

    def delete_meta_batch(self, names: Iterable[str]) -> None:
        t0 = time.perf_counter()
        try:
            self.inner.delete_meta_batch(names)
        finally:
            self._obs("delete_meta", t0)

    # ---- passthrough for backend-specific surface (op_log, tenant_id…) ----

    def __getattr__(self, name: str):
        return getattr(self.inner, name)


def instrument_tree(store: Any, registry: MetricsRegistry) -> Any:
    """Wrap ``store`` and (for fabric compositions) each child, labelling
    children positionally so per-shard / per-replica stragglers separate.
    Mutates fabric child lists in place; intended for benches and tests,
    not for stores shared across sessions."""
    from repro.core import fabric

    if isinstance(store, fabric.ShardedStore):
        store.shards = [
            InstrumentedStore(s, registry,
                              backend=f"shard{i}:{backend_label(s)}")
            for i, s in enumerate(store.shards)]
    elif isinstance(store, fabric.ReplicatedStore):
        store.replicas = [
            InstrumentedStore(s, registry,
                              backend=f"rep{i}:{backend_label(s)}")
            for i, s in enumerate(store.replicas)]
    elif isinstance(store, fabric.TieredStore):
        store.cold = InstrumentedStore(
            store.cold, registry, backend=f"cold:{backend_label(store.cold)}")
    if isinstance(store, InstrumentedStore):
        return store
    return InstrumentedStore(store, registry)


__all__ = ["InstrumentedStore", "instrument_tree", "backend_label",
           "OP_SECONDS", "BYTES_TOTAL", "SIZE_BASE_BYTES"]
