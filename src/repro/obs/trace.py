"""Span tracer — contextvar-propagated, monotonic-clock, ring-bounded.

One :class:`Tracer` per session records :class:`SpanRecord` rows into a
bounded deque.  ``span()`` returns a context manager; nesting is tracked
through a module-level :class:`~contextvars.ContextVar` holding the current
span id, so a stage deep inside the pipeline (e.g. the fused delta pack in
``core/delta.py``) lands under the right parent without threading a handle
through every call signature.  Contextvars do *not* propagate into worker
threads — spans opened from the async-writer drain or the publish worker
simply become roots (parent ``None``), which is the honest picture: those
stages genuinely run off the commit's critical path.

Disabled cost is one attribute check plus returning a shared no-op context
manager — no allocation, no clock read — so the tracer can stay wired into
every hot path unconditionally.

Export is Chrome trace-event JSON (``ph: "X"`` complete events, µs
timestamps), loadable in Perfetto / ``chrome://tracing`` with no deps.
"""
from __future__ import annotations

import contextvars
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, Optional

# current span id for the *calling* context; shared across tracers — span ids
# are globally unique per process so a stale id from another tracer can never
# be mistaken for a parent in this one (records are matched by id).
_current_span: contextvars.ContextVar[Optional[int]] = contextvars.ContextVar(
    "kishu_obs_current_span", default=None)

_ids = iter(range(1, 1 << 62)).__next__
_ids_lock = threading.Lock()


def _next_id() -> int:
    with _ids_lock:
        return _ids()


@dataclass
class SpanRecord:
    """One completed span: ``t0_s`` is seconds since the tracer's epoch
    (``time.monotonic`` at construction), ``dur_s`` the wall duration."""
    span_id: int
    parent_id: Optional[int]
    name: str
    t0_s: float
    dur_s: float
    thread: int = 0
    args: Dict[str, Any] = field(default_factory=dict)


class _NullSpan:
    """Shared no-op context manager returned when tracing is disabled."""
    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("_tracer", "name", "args", "span_id", "parent_id",
                 "_t0", "_token")

    def __init__(self, tracer: "Tracer", name: str, args: Dict[str, Any]):
        self._tracer = tracer
        self.name = name
        self.args = args
        self.span_id = _next_id()
        self.parent_id: Optional[int] = None
        self._t0 = 0.0
        self._token = None

    def __enter__(self) -> "_Span":
        self.parent_id = _current_span.get()
        self._token = _current_span.set(self.span_id)
        self._t0 = time.monotonic()
        return self

    def __exit__(self, *exc) -> bool:
        t1 = time.monotonic()
        if self._token is not None:
            _current_span.reset(self._token)
        self._tracer._record(SpanRecord(
            span_id=self.span_id, parent_id=self.parent_id, name=self.name,
            t0_s=self._t0 - self._tracer.epoch, dur_s=t1 - self._t0,
            thread=threading.get_ident(), args=self.args))
        return False


class Tracer:
    """Ring-bounded span recorder.  ``enabled`` may be flipped at runtime;
    ``span()`` reads it per call, so benches can turn tracing on after the
    session is built."""

    def __init__(self, enabled: bool = False, max_spans: int = 16384):
        self.enabled = bool(enabled)
        self.epoch = time.monotonic()
        self.spans: deque = deque(maxlen=int(max_spans))
        self._lock = threading.Lock()

    def span(self, name: str, **args: Any):
        if not self.enabled:
            return NULL_SPAN
        return _Span(self, name, args)

    def _record(self, rec: SpanRecord) -> None:
        with self._lock:
            self.spans.append(rec)

    def clear(self) -> None:
        with self._lock:
            self.spans.clear()

    # ---- aggregation / export ----

    def stage_totals(self) -> Dict[str, float]:
        """Total seconds per span name (for bench stage vectors)."""
        out: Dict[str, float] = {}
        with self._lock:
            for rec in self.spans:
                out[rec.name] = out.get(rec.name, 0.0) + rec.dur_s
        return out

    def to_doc(self) -> List[dict]:
        """JSON-serializable span dump (persisted under ``obs/trace/``)."""
        with self._lock:
            return [{"id": r.span_id, "parent": r.parent_id, "name": r.name,
                     "t0": r.t0_s, "dur": r.dur_s, "tid": r.thread,
                     "args": r.args} for r in self.spans]


def spans_from_doc(doc: Iterable[dict]) -> List[SpanRecord]:
    return [SpanRecord(span_id=int(d["id"]),
                       parent_id=(None if d.get("parent") is None
                                  else int(d["parent"])),
                       name=str(d["name"]), t0_s=float(d["t0"]),
                       dur_s=float(d["dur"]), thread=int(d.get("tid", 0)),
                       args=dict(d.get("args") or {}))
            for d in doc]


def chrome_trace(spans: Iterable[SpanRecord], *, pid: int = 1) -> dict:
    """Chrome trace-event JSON (Perfetto-loadable).  Complete ``"X"`` events
    with µs timestamps; span/parent ids ride in ``args`` so nesting survives
    round-trips even when viewers re-sort by timestamp."""
    spans = list(spans)
    # compact per-process thread ids: viewers lay tracks out per tid, and raw
    # thread idents are unreadable 15-digit numbers
    tids: Dict[int, int] = {}
    for r in spans:
        tids.setdefault(r.thread, len(tids) + 1)
    events = []
    for r in spans:
        args = {"span_id": r.span_id, "parent_id": r.parent_id}
        args.update(r.args)
        events.append({
            "name": r.name, "ph": "X", "cat": "kishu",
            "ts": round(r.t0_s * 1e6, 3),
            "dur": max(round(r.dur_s * 1e6, 3), 0.001),
            "pid": pid, "tid": tids[r.thread], "args": args,
        })
    events.sort(key=lambda e: e["ts"])
    return {"traceEvents": events, "displayTimeUnit": "ms"}
