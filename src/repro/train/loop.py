"""ManagedTrainingSession — the training loop with Kishu attached.

Every user-visible operation (train phase, eval, hparam change, data swap)
is a *command* — the notebook-cell analogue.  After each command Kishu
detects the co-variable delta and writes an incremental checkpoint; any past
phase boundary can be checked out (undo a bad LR, fork a branch per data
mixture, roll back a loss spike) at sub-second cost because only diverged
co-variables are reloaded.

Namespace layout (flat names):
  state/params/...       model parameters (one leaf per tensor)
  state/params/lm_head   ALIAS of state/params/embed for tied archs — a real
                         shared reference the checkpointer must preserve
  state/opt/...          AdamW moments
  state/step, state/rng
  hparams/lr             dynamic learning rate (a tiny, frequently-read leaf)
  data/seed, data/step   versioned data-iterator state (replay determinism)
  metrics/...            eval outputs
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import KishuSession
from repro.core.chunkstore import ChunkStore
from repro.data.pipeline import DataState, TokenPipeline
from repro.models.config import ArchConfig
from repro.optim.adamw import AdamWConfig
from repro.train import step as step_lib


def _tied_alias_names(cfg: ArchConfig):
    return ("state/params/embed", "state/params/lm_head")


class ManagedTrainingSession:
    """Public driver: attach -> train/eval/set_lr/swap_data -> checkout."""

    def __init__(self, cfg: ArchConfig, opt_cfg: AdamWConfig,
                 store: ChunkStore, *, global_batch: int = 8,
                 seq_len: int = 64, chunk_bytes: int = 1 << 16,
                 async_write: bool = False, jit_step: bool = True):
        self.cfg = cfg
        self.opt_cfg = opt_cfg
        self.kishu = KishuSession(store, chunk_bytes=chunk_bytes,
                                  async_write=async_write)
        self.pipeline = TokenPipeline(cfg.vocab_size, global_batch, seq_len)
        fn = step_lib.make_train_step(cfg, opt_cfg, remat=False)
        self._step = jax.jit(fn) if jit_step else fn
        self._loss = step_lib.make_loss_fn(cfg, remat=False)
        self._register_commands()

    # ------------------------------------------------------------------
    # namespace <-> train state
    # ------------------------------------------------------------------
    def _read_state(self, ns) -> Dict[str, Any]:
        state = ns.get_tree("state")
        if self.cfg.tie_embeddings:
            state["params"].pop("lm_head", None)   # alias, not a model input
        return state

    def _write_state(self, ns, state: Dict[str, Any]) -> None:
        state = dict(state)
        ns.set_tree("state", state)
        if self.cfg.tie_embeddings:
            # restore the shared reference: lm_head IS embed
            ns["state/params/lm_head"] = ns["state/params/embed"]

    # ------------------------------------------------------------------
    # commands (the "cells")
    # ------------------------------------------------------------------
    def _register_commands(self) -> None:
        cfg, opt_cfg = self.cfg, self.opt_cfg

        def init_model(ns, seed: int):
            state = step_lib.init_train_state(cfg, jax.random.key(seed),
                                              opt_cfg)
            self._write_state(ns, state)
            ns["hparams/lr"] = float(opt_cfg.lr)
            ns["data/seed"] = int(seed)
            ns["data/step"] = 0

        def train_phase(ns, steps: int):
            state = self._read_state(ns)
            lr = jnp.float32(ns["hparams/lr"])
            dstate = DataState(ns["data/seed"], ns["data/step"])
            metrics = None
            for _ in range(steps):
                batch, dstate = self.pipeline.next_batch(dstate)
                batch = {k: jnp.asarray(v) for k, v in batch.items()}
                state, metrics = self._step(state, batch, lr)
            self._write_state(ns, state)
            ns["data/step"] = int(dstate.step)
            if metrics is not None:
                ns["metrics/last_loss"] = float(metrics["loss"])

        def eval_phase(ns, batches: int = 1, seed: int = 777):
            state = self._read_state(ns)
            pipe = TokenPipeline(cfg.vocab_size,
                                 self.pipeline.global_batch,
                                 self.pipeline.seq)
            ds = DataState(seed, 0)
            losses = []
            for _ in range(batches):
                batch, ds = pipe.next_batch(ds)
                batch = {k: jnp.asarray(v) for k, v in batch.items()}
                loss, _ = self._loss(state["params"], batch)
                losses.append(float(loss))
            ns["metrics/eval_loss"] = float(np.mean(losses))

        def set_lr(ns, lr: float):
            ns["hparams/lr"] = float(lr)

        def swap_data(ns, seed: int):
            ns["data/seed"] = int(seed)
            ns["data/step"] = 0

        for name, fn in [("init_model", init_model),
                         ("train_phase", train_phase),
                         ("eval_phase", eval_phase),
                         ("set_lr", set_lr), ("swap_data", swap_data)]:
            self.kishu.register(name, fn)

    # ------------------------------------------------------------------
    # public API
    # ------------------------------------------------------------------
    def attach(self, seed: int = 0) -> str:
        return self.kishu.run("init_model", seed=seed,
                              _message="init model")

    def train(self, steps: int) -> str:
        return self.kishu.run("train_phase", steps=steps,
                              _message=f"train {steps} steps")

    def evaluate(self, batches: int = 1) -> str:
        return self.kishu.run("eval_phase", batches=batches,
                              _message="eval")

    def set_lr(self, lr: float) -> str:
        return self.kishu.run("set_lr", lr=lr, _message=f"lr={lr}")

    def swap_data(self, seed: int) -> str:
        return self.kishu.run("swap_data", seed=seed,
                              _message=f"data seed={seed}")

    def checkout(self, commit_id: str):
        return self.kishu.checkout(commit_id)

    @property
    def ns(self):
        return self.kishu.ns

    def eval_loss(self) -> float:
        return self.ns["metrics/eval_loss"]

    def log(self):
        return self.kishu.log()

    def close(self):
        self.kishu.close()


def resume(cfg: ArchConfig, opt_cfg: AdamWConfig, store: ChunkStore,
           **kw) -> ManagedTrainingSession:
    """Crash/elastic recovery: rebuild a session over an existing store and
    check out HEAD (loads the full state once; later checkouts are
    incremental again)."""
    sess = ManagedTrainingSession(cfg, opt_cfg, store, **kw)
    head = sess.kishu.graph.head
    if head and head != "c00000":
        sess.kishu.records, _ = sess.kishu.loader.materialize_state(
            sess.kishu.tracked, head)
        from repro.core.covariable import group_covariables
        sess.kishu.covs = group_covariables(sess.kishu.records)
    return sess
