from repro.train.step import (TrainState, cross_entropy, make_decode_step,
                              make_prefill_step, make_train_step)

__all__ = ["TrainState", "cross_entropy", "make_decode_step",
           "make_prefill_step", "make_train_step"]
