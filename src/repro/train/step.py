"""Step functions: train (value_and_grad + AdamW, remat, microbatching),
prefill, and one-token decode.  All are factories returning closures that
jit cleanly with explicit in/out shardings (launch/dryrun.py) or run eagerly
on CPU (tests/examples).

``train_step`` consumes/produces a TrainState pytree — exactly the pytree
the Kishu session flattens into its namespace, so the paper's technique sees
params/moments/rng/step as first-class variables.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models import lm
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update

TrainState = Dict[str, Any]     # {"params", "opt", "step", "rng"}


def init_train_state(cfg: ArchConfig, key, opt_cfg: AdamWConfig) -> TrainState:
    params = lm.init_params(cfg, key)
    return {
        "params": params,
        "opt": adamw_init(params, opt_cfg),
        "step": jnp.zeros((), jnp.int32),
        "rng": jax.random.key_data(jax.random.key(0)),
    }


def abstract_train_state(cfg: ArchConfig, opt_cfg: AdamWConfig):
    return jax.eval_shape(
        lambda k: init_train_state(cfg, k, opt_cfg), jax.random.key(0))


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  true_vocab: int) -> jax.Array:
    """Mean token cross-entropy; positions >= true_vocab are masked padding
    columns of the padded embedding table."""
    v = logits.shape[-1]
    if true_vocab < v:
        neg = jnp.full((v - true_vocab,), -1e30, logits.dtype)
        logits = logits.at[..., true_vocab:].set(neg)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def make_loss_fn(cfg: ArchConfig, *, remat: bool = True,
                 moe_aux_coef: float = 0.01, mtp_coef: float = 0.1,
                 unroll: bool = False, hidden_sharding=None):
    def loss_fn(params, batch):
        logits, aux = lm.forward(cfg, params, batch, training=True,
                                 remat=remat, return_aux=True, unroll=unroll,
                                 hidden_sharding=hidden_sharding)
        loss = cross_entropy(logits, batch["labels"], cfg.vocab_size)
        total = loss + moe_aux_coef * aux["moe_aux"]
        if "mtp_logits" in aux:
            # MTP predicts token t+2: shift labels by one more
            lbl = batch["labels"]
            lbl2 = jnp.concatenate([lbl[:, 1:], lbl[:, -1:]], axis=1)
            total = total + mtp_coef * cross_entropy(
                aux["mtp_logits"], lbl2, cfg.vocab_size)
        return total, {"loss": loss, "moe_aux": aux["moe_aux"]}
    return loss_fn


def make_train_step(cfg: ArchConfig, opt_cfg: AdamWConfig, *,
                    remat: bool = True, microbatches: int = 1,
                    moe_aux_coef: float = 0.01, unroll: bool = False,
                    hidden_sharding=None):
    """Returns train_step(state, batch) -> (state, metrics)."""
    loss_fn = make_loss_fn(cfg, remat=remat, moe_aux_coef=moe_aux_coef,
                           unroll=unroll, hidden_sharding=hidden_sharding)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def _grads(params, batch):
        if microbatches == 1:
            (tot, aux), grads = grad_fn(params, batch)
            return tot, aux, grads
        # gradient accumulation over the batch dim (f32 accumulators)
        def split(x):
            b = x.shape[0]
            assert b % microbatches == 0, (b, microbatches)
            return x.reshape(microbatches, b // microbatches, *x.shape[1:])
        mb = jax.tree.map(split, batch)
        acc0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def body(acc, one):
            tot_a, aux_a, g_a = acc
            (tot, aux), g = grad_fn(params, one)
            g_a = jax.tree.map(lambda a, b_: a + b_.astype(jnp.float32), g_a, g)
            return (tot_a + tot, jax.tree.map(jnp.add, aux_a, aux), g_a), None

        aux0 = {"loss": jnp.zeros(()), "moe_aux": jnp.zeros(())}
        (tot, aux, gacc), _ = jax.lax.scan(body, (jnp.zeros(()), aux0, acc0), mb)
        scale = 1.0 / microbatches
        grads = jax.tree.map(lambda g: (g * scale), gacc)
        aux = jax.tree.map(lambda a: a * scale, aux)
        return tot * scale, aux, grads

    def train_step(state: TrainState, batch: Dict[str, Any], lr=None
                   ) -> Tuple[TrainState, Dict[str, Any]]:
        total, aux, grads = _grads(state["params"], batch)
        new_params, new_opt, om = adamw_update(grads, state["opt"],
                                               state["params"], opt_cfg, lr)
        metrics = {"total_loss": total, **aux, **om,
                   "step": state["step"] + 1}
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1, "rng": state["rng"]}
        return new_state, metrics

    return train_step


def make_prefill_step(cfg: ArchConfig, *, unroll: bool = False,
                      hidden_sharding=None):
    """prefill_step(params, batch) -> logits [B,S,V] (sampling-ready)."""
    def prefill_step(params, batch):
        return lm.forward(cfg, params, batch, training=False, remat=False,
                          unroll=unroll, hidden_sharding=hidden_sharding)
    return prefill_step


def make_decode_step(cfg: ArchConfig, *, greedy: bool = True,
                     unroll: bool = False):
    """serve_step(params, caches, batch) -> (next_token [B,1], caches)."""
    def serve_step(params, caches, batch):
        logits, caches = lm.decode_step(cfg, params, caches, batch,
                                        unroll=unroll)
        logits = logits[..., :cfg.vocab_size]
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return nxt, caches
    return serve_step
