"""Baselines from the paper's evaluation (§7.1), reimplemented on the same
storage substrate for apples-to-apples benchmarks:

- :class:`DumpSession`     — application-level whole-state serialization
  (dill.dump_session / ForkIt analogue): one blob per commit, checkout loads
  the entire blob.
- :class:`PageIncremental` — CRIU-Incremental analogue: the state is
  serialized to one contiguous "memory image"; commits store only 4 KiB pages
  that differ *positionally* from the parent commit's image.  Fragmentation
  and offset shifts dirty many pages (the paper's §2.3 criticism), and
  checkout must piece the full image back together (no incremental restore).
- :class:`DetReplay`       — Kishu+Det-replay (§7.1): commands annotated
  deterministic skip checkpointing entirely; checkout replays them, which can
  be catastrophically slow for expensive cells (§7.5.2).
"""
from __future__ import annotations

import io
import pickle
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Set, Tuple

import numpy as np

from repro.core.chunkstore import ChunkStore, chunk_key
from repro.core.namespace import Namespace, TrackedNamespace
from repro.core.serialize import (SerializationError, leaf_from_bytes,
                                  leaf_to_bytes)
from repro.core.session import KishuSession


@dataclass
class BaselineStats:
    ckpt_s: float = 0.0
    bytes_written: int = 0
    checkout_s: float = 0.0
    bytes_loaded: int = 0
    failed: bool = False
    fail_reason: str = ""


def _state_blob(ns: Namespace) -> bytes:
    """Serialize the whole namespace into one deterministic byte image."""
    out = io.BytesIO()
    index = []
    for name in ns.names():
        data, meta = leaf_to_bytes(ns[name])       # raises for opaque leaves
        index.append((name, meta, len(data)))
        out.write(data)
    blob = out.getvalue()
    header = pickle.dumps(index)
    return len(header).to_bytes(8, "little") + header + blob


def _state_from_blob(blob: bytes) -> Dict[str, Any]:
    hlen = int.from_bytes(blob[:8], "little")
    index = pickle.loads(blob[8:8 + hlen])
    off = 8 + hlen
    out = {}
    for name, meta, n in index:
        out[name] = leaf_from_bytes(blob[off:off + n], meta)
        off += n
    return out


class DumpSession:
    """Whole-state dump per commit (dill.dump_session analogue)."""

    def __init__(self, store: ChunkStore):
        self.store = store
        self.commits: List[str] = []
        self.stats: List[BaselineStats] = []

    def checkpoint(self, ns: Namespace, tag: str) -> BaselineStats:
        st = BaselineStats()
        t0 = time.perf_counter()
        try:
            blob = _state_blob(ns)
        except SerializationError as e:
            st.failed, st.fail_reason = True, str(e)
            self.stats.append(st)
            return st
        key = f"dump/{tag}"
        self.store.put_chunk(chunk_key(key.encode()) , blob)
        self.store.put_meta(key, {"chunk": chunk_key(key.encode()),
                                  "nbytes": len(blob)})
        st.bytes_written = len(blob)
        st.ckpt_s = time.perf_counter() - t0
        self.commits.append(tag)
        self.stats.append(st)
        return st

    def checkout(self, ns: Namespace, tag: str) -> BaselineStats:
        st = BaselineStats()
        t0 = time.perf_counter()
        meta = self.store.get_meta(f"dump/{tag}")
        blob = self.store.get_chunk(meta["chunk"])
        st.bytes_loaded = len(blob)
        values = _state_from_blob(blob)
        for name in list(ns.names()):
            del ns[name]
        for name, v in values.items():
            ns[name] = v
        st.checkout_s = time.perf_counter() - t0
        return st


PAGE = 4096


class PageIncremental:
    """CRIU-Incremental analogue: positional 4 KiB dirty-page deltas."""

    def __init__(self, store: ChunkStore):
        self.store = store
        self._images: Dict[str, Tuple[str, List[Optional[str]]]] = {}
        # tag -> (parent_tag, per-page chunk key or None==inherit)
        self._sizes: Dict[str, int] = {}
        self.stats: List[BaselineStats] = []

    def _pages(self, blob: bytes) -> List[bytes]:
        return [blob[i:i + PAGE] for i in range(0, len(blob), PAGE)]

    def _resolve(self, tag: str) -> List[str]:
        """Full per-page chunk-key list for a commit (piecing together)."""
        chain = []
        t: Optional[str] = tag
        while t is not None:
            parent, pages = self._images[t]
            chain.append(pages)
            t = parent
        n = max(len(p) for p in chain)
        out: List[Optional[str]] = [None] * n
        for pages in chain:                       # newest first
            for i, k in enumerate(pages):
                if out[i] is None and k is not None:
                    out[i] = k
        return [k for k in out if k is not None]

    def checkpoint(self, ns: Namespace, tag: str,
                   parent: Optional[str]) -> BaselineStats:
        st = BaselineStats()
        t0 = time.perf_counter()
        try:
            blob = _state_blob(ns)
        except SerializationError as e:
            st.failed, st.fail_reason = True, str(e)
            self.stats.append(st)
            return st
        pages = self._pages(blob)
        prev_keys: List[Optional[str]] = []
        if parent is not None:
            full = self._resolve(parent)
            prev_keys = list(full)
        entry: List[Optional[str]] = []
        for i, page in enumerate(pages):
            k = chunk_key(page)
            if i < len(prev_keys) and prev_keys[i] == k:
                entry.append(None)                 # clean page: inherit
            else:
                if not self.store.has_chunk(k):
                    self.store.put_chunk(k, page)
                    st.bytes_written += len(page)
                entry.append(k)
        # store full keys for truncation correctness
        if parent is not None and len(pages) < len(prev_keys):
            pass                                   # shorter image: ignore tail
        self._images[tag] = (parent, entry)
        self._sizes[tag] = len(blob)
        st.ckpt_s = time.perf_counter() - t0
        self.stats.append(st)
        return st

    def checkout(self, ns: Namespace, tag: str) -> BaselineStats:
        """Non-incremental restore: reassemble the whole image."""
        st = BaselineStats()
        t0 = time.perf_counter()
        keys = self._resolve(tag)
        blob = b"".join(self.store.get_chunk(k) for k in keys)
        blob = blob[:self._sizes[tag]]
        st.bytes_loaded = len(blob)
        values = _state_from_blob(blob)
        for name in list(ns.names()):
            del ns[name]
        for name, v in values.items():
            ns[name] = v
        st.checkout_s = time.perf_counter() - t0
        return st


class DetReplaySession(KishuSession):
    """Kishu+Det-replay: commands registered with ``deterministic=True`` skip
    delta checkpointing; their co-variables restore via fallback replay."""

    def __init__(self, *args, **kw):
        super().__init__(*args, **kw)
        self.deterministic: Set[str] = set()

    def register(self, name: str, fn: Callable,
                 deterministic: bool = False) -> None:
        super().register(name, fn)
        if deterministic:
            self.deterministic.add(name)

    def run(self, command: str, _message: str = "", **args) -> str:
        name = command
        if name not in self.deterministic:
            return super().run(name, _message=_message, **args)
        # Execute + track + detect, but store NO chunk data: the commit
        # records the delta membership with unserializable-style manifests,
        # forcing checkout to replay this command.
        saved_writer_write = self.writer.write_delta

        def _skip_write(delta, ns, prev_of, packs=None):
            from repro.core.checkpoint import WriteStats
            from repro.core.graph import key_str as ks
            manifests = {}
            for key, records in delta.updated.items():
                members = [{"name": r.name, "kind": r.kind, "dtype": r.dtype,
                            "shape": list(r.shape), "view": r.view,
                            "nbytes": r.nbytes} for r in records]
                manifests[ks(key)] = {"members": members,
                                      "unserializable": True,
                                      "det_skipped": True}
            return manifests, WriteStats()

        self.writer.write_delta = _skip_write
        try:
            return super().run(name, _message=_message, **args)
        finally:
            self.writer.write_delta = saved_writer_write
