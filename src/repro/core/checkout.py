"""Incremental checkout — the State Loader (§5.2).

Given the current HEAD and a target commit, compute the diverged co-variables
via the Checkpoint Graph index (Def 6), load *only* those from their
manifests, reconstruct shared references (aliases/views), and swap them into
the live namespace without touching identical co-variables.  Missing or
corrupt data falls back to recomputation (restore.py).

Chunk I/O is planned up front and executed by the parallel engine
(parallel.py, DESIGN.md §9): all chunk keys of the diff plan are deduplicated
into cov-ordered slabs, fetched with bounded concurrency, and each
co-variable is deserialized/materialized on the calling thread the moment its
last chunk lands — restore latency tracks store bandwidth, not per-chunk
round-trips.
"""
from __future__ import annotations

import threading
import time
from contextlib import nullcontext
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import delta as delta_mod
from repro.core import parallel
from repro.core.chunkstore import ChunkCache, ChunkStore
from repro.core.covariable import CovKey, LeafRecord
from repro.core.graph import CheckpointGraph, CheckoutPlan, key_str
from repro.core.hashing import hashes_hex
from repro.core.serialize import (ChunkMissingError, SerializationError,
                                  leaf_from_bytes, leaf_meta, leaf_nbytes,
                                  view_from_base)


@dataclass
class CheckoutStats:
    covs_loaded: int = 0
    covs_patched: int = 0           # subset of covs_loaded done via patching
    covs_deleted: int = 0
    covs_identical: int = 0
    covs_recomputed: int = 0        # co-variables restored via replay
                                    # (counted once per cov by DataRestorer)
    covs_planned_fetch: int = 0     # planner lane sizes (0 when plan_mode
    covs_planned_replay: int = 0    #  is off — the fixed ladder ran)
    covs_planned_patch: int = 0
    plan_est_s: float = 0.0         # planner's cost estimate for the
                                    # checkout (compare against wall_s)
    bytes_loaded: int = 0           # *moved*: bytes fetched from the backend
    bytes_cached: int = 0           # served from the shared chunk cache
    bytes_logical: int = 0          # logical size of restored co-variables
    chunks_patched: int = 0         # dirty chunks fetched + patched in
    chunks_inplace: int = 0         # clean chunks reused from the live buffer
    bytes_host2dev: int = 0         # host→device bytes patch uploads moved
                                    # (mirror of WriteStats.bytes_dev2host)
    covs_scattered: int = 0         # device covs patched in one fused
                                    # scatter pass (kernels/patch_scatter)
    kernel_fallbacks: int = 0       # device-kernel → host degradations
    wall_s: float = 0.0
    diff_s: float = 0.0


# CheckoutStats fields a concurrent fetch lane accumulates into its own
# instance and merges back after joining (plain += on a shared dataclass
# would race with the replay lane)
_ADDITIVE_STATS = (
    "covs_loaded", "covs_patched", "covs_deleted", "covs_recomputed",
    "bytes_loaded", "bytes_cached", "bytes_logical", "chunks_patched",
    "chunks_inplace", "bytes_host2dev", "covs_scattered", "kernel_fallbacks")


def _merge_stats(dst: CheckoutStats, src: CheckoutStats) -> None:
    for name in _ADDITIVE_STATS:
        setattr(dst, name, getattr(dst, name) + getattr(src, name))


@dataclass
class ChunkPatch:
    """Chunk-level checkout plan for one diverged co-variable: fetch only
    ``dirty`` chunks of the target manifest and patch them into the live
    base buffer, reusing every clean chunk already in memory."""
    key: CovKey
    version: str
    manifest: dict
    base: Any                       # live base buffer (np.ndarray/jax.Array)
    dirty: List[int]                # chunk indices to fetch + patch
    offsets: List[int]              # byte offset of every chunk
    is_device: bool                 # jax base: rebuild via on-device update


def materialize_manifest(store: ChunkStore, manifest: dict,
                         stats: Optional[CheckoutStats] = None,
                         chunks: Optional[Dict[str, bytes]] = None
                         ) -> Dict[str, Any]:
    """Load a co-variable's values from its manifest.

    Reconstructs shared references: one base buffer, members as views/aliases.
    ``chunks`` is an optional prefetched cache; keys absent from it are
    re-tried against the store (covers async-writer races) before failing.
    Raises ChunkMissingError / SerializationError on failure (-> fallback).
    """
    if manifest.get("unserializable"):
        raise SerializationError("manifest flagged unserializable")
    base_info = manifest["base"]
    parts = []
    for c in base_info["chunks"]:
        data = chunks.get(c["key"]) if chunks is not None else None
        if data is None:
            data = store.get_chunk(c["key"])
            if stats:
                stats.bytes_loaded += len(data)
        if len(data) != c["n"]:
            raise ChunkMissingError(f"chunk {c['key']}: size mismatch")
        parts.append(data)
    blob = b"".join(parts)
    if len(blob) != base_info["nbytes"]:
        raise ChunkMissingError("assembled size mismatch")
    if stats:
        stats.bytes_logical += len(blob)
    base = leaf_from_bytes(blob, base_info["meta"])

    out: Dict[str, Any] = {}
    for m in manifest["members"]:
        if m.get("view"):
            out[m["name"]] = view_from_base(base, m["view"])
        else:
            out[m["name"]] = base
    return out


def records_from_manifest(manifest: dict, values: Dict[str, Any]
                          ) -> Dict[str, LeafRecord]:
    """Rebuild LeafRecords after checkout without rehashing (det hashes are
    stored in the manifest)."""
    det_hex = [] if manifest.get("unserializable") else \
        manifest["base"].get("det_hashes", [])
    det = np.array([int(h, 16) for h in det_hex], dtype=np.uint64)
    base_id = None
    out = {}
    for m in manifest["members"]:
        val = values[m["name"]]
        from repro.core.serialize import base_of
        b = base_of(val)
        if base_id is None:
            base_id = id(b)
        out[m["name"]] = LeafRecord(
            name=m["name"], kind=m["kind"], dtype=m["dtype"],
            shape=tuple(m["shape"]), nbytes=m["nbytes"], alias_id=id(b),
            view=m.get("view"), base_hashes=det if len(det) else None)
    return out


class StateLoader:
    def __init__(self, graph: CheckpointGraph, store: ChunkStore,
                 fallback=None, *, io_threads: Optional[int] = None,
                 cache: Optional[ChunkCache] = None):
        self.graph = graph
        self.store = store
        self.fallback = fallback      # callable (key, version, stats) -> values
        # shared chunk cache (writer-populated): just-committed chunks are
        # served from memory, never the backend
        self.chunk_cache = cache
        # chunk-level patch checkout (dirty-chunk fetch into live buffers);
        # False restores the cov-granular pre-delta path (benchmarks).
        self.patch_enabled = True
        # <=1 forces the serial pre-engine path (benchmark baseline).
        self.io_threads = parallel.resolve_io_threads(io_threads)
        # Adaptive engagement (see parallel.py): first-slab latency below
        # the gate stays serial outright; above it a measured trial decides.
        # probe_threshold_s = 0.0 forces the pipeline; inf forces serial.
        self.probe_threshold_s = parallel.PARALLEL_LATENCY_THRESHOLD_S
        # observability handle (set by the session owning this loader)
        self.obs = None
        # cost-based checkout planner (set by the session when plan_mode is
        # not off); None keeps the fixed patch->fetch->fallback ladder
        self.planner = None

    def _span(self, name: str, **args):
        return self.obs.span(name, **args) if self.obs is not None \
            else nullcontext()

    def _cache_probe(self, keys, stats: Optional[CheckoutStats]
                     ) -> Dict[str, bytes]:
        """Chunks served by the shared cache (accounted as cached bytes)."""
        if self.chunk_cache is None:
            return {}
        hits = self.chunk_cache.get_many(dict.fromkeys(keys))
        if stats and hits:
            stats.bytes_cached += sum(len(v) for v in hits.values())
        return hits

    @staticmethod
    def _fetch_parallel(slabs, fetch, consume, workers):
        """Stream ``slabs`` through the prefetch pipeline; returns [] (all
        consumed) so callers can fall through to the serial remainder."""
        for slab, got in parallel.prefetch_map(fetch, slabs, workers):
            consume(slab, got)
        return []

    def load_cov(self, key: CovKey, version: str,
                 stats: Optional[CheckoutStats] = None) -> Dict[str, Any]:
        manifest = self.graph.manifest_of(key, version)
        if manifest is not None and not manifest.get("unserializable"):
            hits = self._cache_probe(
                [c["key"] for c in manifest["base"]["chunks"]], stats)
            try:
                return materialize_manifest(self.store, manifest, stats,
                                            chunks=hits or None)
            except (ChunkMissingError, SerializationError):
                pass
        if self.fallback is None:
            raise ChunkMissingError(
                f"co-variable {key} @ {version} unavailable and no fallback")
        # covs_recomputed is owned by the DataRestorer (one count per
        # replayed co-variable) — incrementing here too double-counted
        # recursive fallbacks
        return self.fallback(key, version, stats)

    def load_covs(self, items: Sequence[Tuple[CovKey, str]],
                  stats: Optional[CheckoutStats] = None, *,
                  use_fallback: bool = True
                  ) -> Dict[CovKey, Dict[str, Any]]:
        """Load many versioned co-variables through the parallel engine.

        Plans every chunk key up front (deduplicated across co-variables —
        content addressing means branches share chunks), streams cov-ordered
        slabs through a bounded-concurrency prefetch pipeline, and
        materializes each co-variable on the calling thread as soon as its
        last chunk arrives, overlapping deserialization with in-flight I/O.

        Per-cov failures (missing/corrupt chunks, unserializable manifests)
        degrade to the serial ``load_cov`` path, which recomputes via
        ``fallback``.  With ``use_fallback=False`` failed co-variables are
        omitted from the result instead (the Data Restorer drives its own
        recursion bookkeeping).
        """
        out: Dict[CovKey, Dict[str, Any]] = {}
        retry: List[Tuple[CovKey, str]] = []    # -> serial/fallback path
        cache: Dict[str, bytes] = {}            # prefetched chunks
        ready: List[Tuple[CovKey, str, dict, List[str]]] = []
        for key, version in items:
            manifest = self.graph.manifest_of(key, version)
            if manifest is None or manifest.get("unserializable"):
                retry.append((key, version))
            else:
                ready.append((key, version, manifest,
                              [c["key"] for c in manifest["base"]["chunks"]]))

        # shared-cache pass: chunks written or fetched earlier this session
        # are served from memory and never enter the fetch plan
        cache.update(self._cache_probe(
            [ck for _, _, _, cks in ready for ck in cks], stats))

        workers = self.io_threads \
            if getattr(self.store, "supports_parallel_get", True) else 1
        if workers <= 1 or len(ready) == 0:
            for key, version, _, _ in ready:
                retry.append((key, version))
            retry.sort()
        else:
            # chunk key -> indices of covs waiting on it (cov order kept)
            owners: Dict[str, List[int]] = {}
            pending = []
            for i, (_, _, _, cks) in enumerate(ready):
                uniq = set(cks) - cache.keys()    # cache hits need no fetch
                pending.append(len(uniq))
                for ck in uniq:
                    owners.setdefault(ck, []).append(i)
            unique_keys = list(owners)
            # refs: covs not yet finished per chunk key — once a key's last
            # owner materializes its bytes are evicted from the cache, so
            # peak memory is bounded by in-flight covs, not the whole
            # restore.  Keys of *failed* covs stay pinned for the retry.
            refs = {ck: len(own) for ck, own in owners.items()}
            pinned: set = set()

            def fetch(slab):
                # serial_section: the engine owns concurrency (slabs across
                # pool threads); the backend must not nest its own pool.
                with parallel.serial_section():
                    return slab, self.store.get_chunks(slab, missing_ok=True)

            def finish(i):
                key, version, manifest, cks = ready[i]
                try:
                    out[key] = materialize_manifest(self.store, manifest,
                                                    stats, chunks=cache)
                except (ChunkMissingError, SerializationError):
                    retry.append((key, version))
                    pinned.update(cks)
                for ck in set(cks):
                    if ck not in refs:            # cache-served key
                        continue
                    refs[ck] -= 1
                    if refs[ck] == 0 and ck not in pinned:
                        cache.pop(ck, None)

            def consume(slab, got):
                cache.update(got)
                if stats:
                    stats.bytes_loaded += sum(len(v) for v in got.values())
                if self.chunk_cache is not None:
                    self.chunk_cache.put_many(got)
                for ck in slab:      # missing keys count as resolved: the
                    for i in owners[ck]:   # cov will fail -> fallback
                        pending[i] -= 1
                        if pending[i] == 0:
                            finish(i)

            for i, n in enumerate(pending):
                if n == 0:           # chunkless manifest (empty buffer)
                    finish(i)

            slabs = list(parallel.iter_slabs(
                unique_keys,
                max(getattr(self.store, "min_slab", 1),
                    parallel.slab_size_for(len(unique_keys), workers))))
            # Adaptive engagement: bandwidth-bound stores (warm cache,
            # RAM-speed media) stay serial — a pipeline only adds
            # contention; round-trip-bound stores engage it after a
            # measured trial.
            if slabs:
                # Slab 0 absorbs cold-start effects (cache revalidation,
                # first touch) so the probe compares steady-state rates.
                consume(*fetch(slabs[0]))
                rest = slabs[1:]
                if self.probe_threshold_s <= 0:     # forced pipeline
                    rest = self._fetch_parallel(rest, fetch, consume, workers)
                elif rest:
                    # Probe: one slab on the calling thread, timed.
                    t0 = time.perf_counter()
                    slab1, got1 = fetch(rest[0])
                    dt = max(time.perf_counter() - t0, 1e-9)
                    consume(slab1, got1)
                    per_chunk_serial = dt / max(1, len(slab1))
                    rest = rest[1:]
                    if per_chunk_serial >= self.probe_threshold_s and rest:
                        # Slow store: trial a few slabs concurrently and
                        # keep the pipeline only if its measured rate beats
                        # serial by a clear margin (high-latency transports
                        # that *serialize* concurrency lose the trial).
                        # Timed around the fetches only — the serial probe
                        # above excludes consume() too.
                        trial, rest = rest[:workers], rest[workers:]
                        t0 = time.perf_counter()
                        trial_got = parallel.map_parallel(
                            lambda s: fetch(s)[1], trial, workers)
                        dt2 = max(time.perf_counter() - t0, 1e-9)
                        for slab, got in zip(trial, trial_got):
                            consume(slab, got)
                        per_chunk_par = dt2 \
                            / max(1, sum(len(s) for s in trial))
                        if per_chunk_par <= per_chunk_serial \
                                * parallel.PARALLEL_TRIAL_MARGIN:
                            rest = self._fetch_parallel(rest, fetch, consume,
                                                        workers)
                for slab in rest:                   # serial remainder
                    consume(*fetch(slab))

        for key, version in retry:
            manifest = self.graph.manifest_of(key, version)
            if manifest is not None and not manifest.get("unserializable"):
                try:
                    # reuse prefetched chunks; absent keys retry the store
                    out[key] = materialize_manifest(
                        self.store, manifest, stats,
                        chunks=cache if cache else None)
                    continue
                except (ChunkMissingError, SerializationError):
                    pass
            if not use_fallback:
                continue
            if self.fallback is None:
                raise ChunkMissingError(
                    f"co-variable {key} @ {version} unavailable and no "
                    f"fallback")
            out[key] = self.fallback(key, version, stats)
        return out

    # ------------------------------------------------------------------
    # chunk-level patch checkout
    # ------------------------------------------------------------------
    def _patch_candidate(self, key: CovKey, version: str,
                         records: Dict[str, LeafRecord], ns,
                         alias_groups: Dict[int, set]
                         ) -> Optional[ChunkPatch]:
        """Chunk-level plan for one diverged co-variable, or None when only
        full materialization is safe (structure divergence, missing hashes,
        unaligned/non-contiguous buffers, or everything dirty)."""
        manifest = self.graph.manifest_of(key, version)
        if manifest is None or manifest.get("unserializable"):
            return None
        base_info = manifest.get("base") or {}
        meta = base_info.get("meta") or {}
        tgt_det = base_info.get("det_hashes") or []
        tgt_chunks = base_info.get("chunks") or []
        nbytes = base_info.get("nbytes", 0)
        if meta.get("kind") != "array" or not tgt_det or nbytes <= 0 \
                or len(tgt_det) != len(tgt_chunks):
            return None
        man_members = {m["name"]: m for m in manifest["members"]}
        if set(man_members) != set(key):
            return None
        # live side: every member present, same structure, one shared base
        recs = []
        for name in key:
            rec = records.get(name)
            if rec is None or name not in ns:
                return None
            recs.append(rec)
        if len({r.alias_id for r in recs}) != 1 \
                or alias_groups.get(recs[0].alias_id) != set(key):
            return None                 # live aliasing differs from target
        live_det = recs[0].base_hashes
        if live_det is None or len(live_det) != len(tgt_det):
            return None
        for rec, name in zip(recs, key):
            m = man_members[name]
            if (rec.kind, rec.dtype, list(rec.shape), rec.view) != \
                    (m["kind"], m["dtype"], m["shape"], m.get("view")):
                return None
        from repro.core.serialize import base_of
        base = base_of(ns[key[0]])
        if leaf_meta(base) != meta or leaf_nbytes(base) != nbytes:
            return None

        offsets = delta_mod.chunk_offsets(tgt_chunks)
        if offsets and offsets[-1] + int(tgt_chunks[-1]["n"]) != nbytes:
            return None
        dirty = delta_mod.dirty_indices(hashes_hex(live_det), tgt_det)
        if len(dirty) == len(tgt_det):
            return None                 # fully diverged: full load is cheaper

        if isinstance(base, np.ndarray):
            if not (base.flags["C_CONTIGUOUS"] and base.flags["WRITEABLE"]):
                return None
            try:
                memoryview(base).cast("B")
            except (TypeError, ValueError, BufferError):
                return None
            is_device = False
        else:
            # device array: dirty ranges must be element-aligned for the
            # on-device dynamic_update_slice patch
            item = np.dtype(meta["dtype"]).itemsize
            for i in dirty:
                end = offsets[i] + int(tgt_chunks[i]["n"])
                if offsets[i] % item or (end % item and end != nbytes):
                    return None
            if any(m.get("view") for m in man_members.values()):
                return None             # strided views are numpy-only
            is_device = True
        return ChunkPatch(key=key, version=version, manifest=manifest,
                          base=base, dirty=dirty, offsets=offsets,
                          is_device=is_device)

    def plan_patches(self, plan: CheckoutPlan, records: Dict[str, LeafRecord],
                     ns) -> Tuple[List[ChunkPatch], List[Tuple[CovKey, str]]]:
        """Split the cov-granular diff into chunk-level patches and full
        loads; patches are also recorded on ``plan.patches``."""
        full: List[Tuple[CovKey, str]] = []
        patches: List[ChunkPatch] = []
        if not self.patch_enabled:
            return [], sorted(plan.to_load.items())
        alias_groups: Dict[int, set] = {}
        for name, rec in records.items():
            alias_groups.setdefault(rec.alias_id, set()).add(name)
        for key, version in sorted(plan.to_load.items()):
            p = self._patch_candidate(key, version, records, ns, alias_groups)
            if p is None:
                full.append((key, version))
            else:
                patches.append(p)
        plan.patches = patches
        return patches, full

    def _fetch_patch_chunks(self, patches: List[ChunkPatch],
                            stats: Optional[CheckoutStats]
                            ) -> Tuple[Dict[str, bytes], List[ChunkPatch],
                                       List[Tuple[CovKey, str]]]:
        """Fetch the dirty chunks of all patch plans (cache first, then one
        pipelined bulk fetch).  Plans with missing/short chunks demote to
        full loads."""
        need: Dict[str, int] = {}       # key -> expected logical size
        for p in patches:
            chunks = p.manifest["base"]["chunks"]
            for i in p.dirty:
                need[chunks[i]["key"]] = int(chunks[i]["n"])
        got = self._cache_probe(need, stats)
        missing = [k for k in need if k not in got]
        if missing:
            fetched = parallel.fetch_chunks(self.store, missing,
                                            self.io_threads)
            if stats:
                stats.bytes_loaded += sum(len(v) for v in fetched.values())
            if self.chunk_cache is not None:
                self.chunk_cache.put_many(fetched)
            got.update(fetched)
        ok_patches: List[ChunkPatch] = []
        demoted: List[Tuple[CovKey, str]] = []
        for p in patches:
            chunks = p.manifest["base"]["chunks"]
            bad = [i for i in p.dirty
                   if chunks[i]["key"] not in got
                   or len(got[chunks[i]["key"]]) != int(chunks[i]["n"])]
            if bad:
                # demotion is a degradation like any other: log-once + bump
                # the per-session fallback counter instead of going silent
                delta_mod.note_kernel_fallback(
                    "fetch_patch_chunks",
                    ChunkMissingError(
                        f"{key_str(p.key)}@{p.version}: {len(bad)} patch "
                        f"chunk(s) missing/short (first: "
                        f"{chunks[bad[0]]['key']})"))
                demoted.append((p.key, p.version))
            else:
                ok_patches.append(p)
        return got, ok_patches, demoted

    def _apply_patch(self, p: ChunkPatch, got: Dict[str, bytes],
                     stats: Optional[CheckoutStats], ns) -> Dict[str, Any]:
        """Patch dirty chunks into the live base and return the member
        values of the target state (live view/alias objects are preserved
        for in-place numpy patches)."""
        base_info = p.manifest["base"]
        chunks = base_info["chunks"]
        segs = [(p.offsets[i], got[chunks[i]["key"]]) for i in p.dirty]
        if p.is_device:
            # fused scatter first: one compacted upload + one kernel pass
            # for ALL dirty chunks of this co-variable; falls back to the
            # per-chunk dynamic_update_slice loop (same bytes, K dispatches)
            chunk_bytes = int(chunks[0]["n"]) if len(chunks) > 1 else 0
            fused = delta_mod.patch_device_chunks(p.base, segs, chunk_bytes)
            if fused is not None:
                new_base, moved = fused
                if stats:
                    stats.covs_scattered += 1
                    stats.bytes_host2dev += moved
            else:
                new_base = delta_mod.patch_device_array(p.base, segs)
                if stats:
                    stats.bytes_host2dev += sum(len(d) for _, d in segs)
            values = {m["name"]: new_base for m in p.manifest["members"]}
        else:
            delta_mod.patch_numpy_base(p.base, segs)
            # live members already view the patched base: identity preserved
            values = {m["name"]: ns[m["name"]]
                      for m in p.manifest["members"]}
        if stats:
            stats.covs_patched += 1
            stats.chunks_patched += len(p.dirty)
            stats.chunks_inplace += len(chunks) - len(p.dirty)
            stats.bytes_logical += base_info["nbytes"]
        return values

    def _materialize_mixed(self, full_items: List[Tuple[CovKey, str]],
                           replay_items: List[Tuple[CovKey, str]],
                           stats: Optional[CheckoutStats]
                           ) -> Dict[CovKey, Dict[str, Any]]:
        """Execute the planner's lanes: fetch slabs stream on a helper
        thread while replays run on the calling thread (commands may touch
        thread-affine state, and the restorer's own dependency loads nest
        safely through the re-entrant parallel engine).  A replay the
        planner mispredicted demotes to the fetch path after the lanes
        join — planner-on never changes what a checkout can restore."""
        if not replay_items:
            return self.load_covs(full_items, stats)
        box: Dict[str, Any] = {}
        fstats = CheckoutStats()
        th = None
        if full_items:
            def _fetch_lane():
                try:
                    box["out"] = self.load_covs(full_items, fstats)
                except BaseException as e:  # noqa: BLE001 — raised on join
                    box["err"] = e
            th = threading.Thread(target=_fetch_lane,
                                  name="kishu-fetch-lane", daemon=True)
            th.start()
        loaded: Dict[CovKey, Dict[str, Any]] = {}
        demoted: List[Tuple[CovKey, str]] = []
        for key, version in replay_items:
            try:
                if self.fallback is None:
                    raise ChunkMissingError(
                        f"co-variable {key} @ {version}: replay planned "
                        f"but no fallback wired")
                loaded[key] = self.fallback(key, version, stats)
            except Exception as e:  # noqa: BLE001 — mispredicted replay
                delta_mod.note_kernel_fallback("plan_replay", e)
                demoted.append((key, version))
        if th is not None:
            th.join()
        if stats is not None:
            _merge_stats(stats, fstats)
        if "err" in box:
            raise box["err"]
        loaded.update(box.get("out", {}))
        if demoted:
            loaded.update(self.load_covs(demoted, stats))
        return loaded

    def checkout(self, tracked_ns, records: Dict[str, LeafRecord],
                 target: str) -> Tuple[Dict[str, LeafRecord], CheckoutStats]:
        """Execute an incremental checkout; mutates the namespace in place.

        Returns (updated record map, stats)."""
        stats = CheckoutStats()
        t0 = time.perf_counter()
        fb0 = delta_mod.kernel_fallbacks()
        cur = self.graph.head
        td = time.perf_counter()
        # 1. plan: graph diff + chunk-level refinement — diverged covs whose
        #    live buffer matches the target structurally only fetch their
        #    differing chunks
        replay_items: List[Tuple[CovKey, str]] = []
        with self._span("plan"):
            plan: CheckoutPlan = self.graph.diff(cur, target)
            stats.diff_s = time.perf_counter() - td
            stats.covs_identical = len(plan.identical)
            patches, full_items = self.plan_patches(plan, records,
                                                    tracked_ns.base)
            if self.planner is not None and self.planner.engaged:
                priced = self.planner.price(cur, target, plan, patches,
                                            full_items)
                patches, full_items, replay_items = self.planner.partition(
                    priced, patches, full_items)
                plan.patches = patches
                stats.covs_planned_patch = len(patches)
                stats.covs_planned_fetch = len(full_items)
                stats.covs_planned_replay = len(replay_items)
                stats.plan_est_s = priced.est_total_s
        with self._span("fetch"):
            patch_data, patches, demoted = self._fetch_patch_chunks(patches,
                                                                    stats)
        full_items = sorted(full_items + demoted)

        # 2. load fully-diverged co-variables (before mutating anything),
        #    chunk I/O planned up front and prefetched in parallel; with a
        #    planner mixed plan the fetch slabs stream on a helper thread
        #    while replays run here
        with self._span("materialize",
                        covs=len(full_items) + len(replay_items)):
            loaded = self._materialize_mixed(full_items, replay_items, stats)

        # 3. apply patches (all data is in hand); unexpected failures fall
        #    back to the full serial load of just that co-variable
        with self._span("patch", covs=len(patches)):
            for p in patches:
                try:
                    loaded[p.key] = self._apply_patch(p, patch_data, stats,
                                                      tracked_ns.base)
                except Exception as e:  # noqa: BLE001 — corrupt patch:
                    delta_mod.note_kernel_fallback("apply_patch", e)
                    loaded[p.key] = self.load_cov(p.key, p.version, stats)

        # 4. swap into the namespace (tracking paused: checkout is not access)
        new_records = dict(records)
        with self._span("swap"), tracked_ns.pause():
            for key in plan.to_delete:
                for name in key:
                    if name in tracked_ns.base:
                        del tracked_ns.base[name]
                    new_records.pop(name, None)
            for key, values in loaded.items():
                manifest = self.graph.manifest_of(key, plan.to_load[key])
                for name, val in values.items():
                    tracked_ns.base[name] = val
                if manifest is not None and not manifest.get("unserializable"):
                    new_records.update(records_from_manifest(manifest, values))
                else:
                    # recomputed: rebuild records by hashing
                    from repro.core.covariable import RecordBuilder
                    rb = RecordBuilder()
                    cache: Dict[int, Any] = {}
                    for name, val in values.items():
                        new_records[name] = rb.build(name, val, cache)

        stats.covs_loaded = len(loaded)
        stats.covs_deleted = len(plan.to_delete)
        self.graph.set_head(target)
        stats.kernel_fallbacks = delta_mod.kernel_fallbacks() - fb0
        stats.wall_s = time.perf_counter() - t0
        return new_records, stats

    def materialize_state(self, tracked_ns, target: str
                          ) -> Tuple[Dict[str, LeafRecord], CheckoutStats]:
        """Full (non-incremental) load of a state into an empty namespace —
        the crash-recovery / elastic-resume path."""
        stats = CheckoutStats()
        t0 = time.perf_counter()
        from repro.core.graph import parse_key
        index = self.graph.nodes[target].state_index
        items = [(parse_key(ks), version)
                 for ks, version in sorted(index.items())]
        loaded = self.load_covs(items, stats)
        versions = dict(items)
        new_records: Dict[str, LeafRecord] = {}
        with tracked_ns.pause():
            for key, values in loaded.items():
                manifest = self.graph.manifest_of(key, versions[key])
                for name, val in values.items():
                    tracked_ns.base[name] = val
                if manifest is not None and not manifest.get("unserializable"):
                    new_records.update(records_from_manifest(manifest, values))
                else:
                    from repro.core.covariable import RecordBuilder
                    rb = RecordBuilder()
                    cache: Dict[int, Any] = {}
                    for name, val in values.items():
                        new_records[name] = rb.build(name, val, cache)
        stats.covs_loaded = len(index)
        self.graph.set_head(target)
        stats.wall_s = time.perf_counter() - t0
        return new_records, stats
