"""Incremental checkout — the State Loader (§5.2).

Given the current HEAD and a target commit, compute the diverged co-variables
via the Checkpoint Graph index (Def 6), load *only* those from their
manifests, reconstruct shared references (aliases/views), and swap them into
the live namespace without touching identical co-variables.  Missing or
corrupt data falls back to recomputation (restore.py).

Chunk I/O is planned up front and executed by the parallel engine
(parallel.py, DESIGN.md §9): all chunk keys of the diff plan are deduplicated
into cov-ordered slabs, fetched with bounded concurrency, and each
co-variable is deserialized/materialized on the calling thread the moment its
last chunk lands — restore latency tracks store bandwidth, not per-chunk
round-trips.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import parallel
from repro.core.chunkstore import ChunkStore
from repro.core.covariable import CovKey, LeafRecord
from repro.core.graph import CheckpointGraph, CheckoutPlan, key_str
from repro.core.serialize import (ChunkMissingError, SerializationError,
                                  leaf_from_bytes, view_from_base)


@dataclass
class CheckoutStats:
    covs_loaded: int = 0
    covs_deleted: int = 0
    covs_identical: int = 0
    covs_recomputed: int = 0
    bytes_loaded: int = 0
    wall_s: float = 0.0
    diff_s: float = 0.0


def materialize_manifest(store: ChunkStore, manifest: dict,
                         stats: Optional[CheckoutStats] = None,
                         chunks: Optional[Dict[str, bytes]] = None
                         ) -> Dict[str, Any]:
    """Load a co-variable's values from its manifest.

    Reconstructs shared references: one base buffer, members as views/aliases.
    ``chunks`` is an optional prefetched cache; keys absent from it are
    re-tried against the store (covers async-writer races) before failing.
    Raises ChunkMissingError / SerializationError on failure (-> fallback).
    """
    if manifest.get("unserializable"):
        raise SerializationError("manifest flagged unserializable")
    base_info = manifest["base"]
    parts = []
    for c in base_info["chunks"]:
        data = chunks.get(c["key"]) if chunks is not None else None
        if data is None:
            data = store.get_chunk(c["key"])
        if len(data) != c["n"]:
            raise ChunkMissingError(f"chunk {c['key']}: size mismatch")
        parts.append(data)
    blob = b"".join(parts)
    if len(blob) != base_info["nbytes"]:
        raise ChunkMissingError("assembled size mismatch")
    if stats:
        stats.bytes_loaded += len(blob)
    base = leaf_from_bytes(blob, base_info["meta"])

    out: Dict[str, Any] = {}
    for m in manifest["members"]:
        if m.get("view"):
            out[m["name"]] = view_from_base(base, m["view"])
        else:
            out[m["name"]] = base
    return out


def records_from_manifest(manifest: dict, values: Dict[str, Any]
                          ) -> Dict[str, LeafRecord]:
    """Rebuild LeafRecords after checkout without rehashing (det hashes are
    stored in the manifest)."""
    det_hex = [] if manifest.get("unserializable") else \
        manifest["base"].get("det_hashes", [])
    det = np.array([int(h, 16) for h in det_hex], dtype=np.uint64)
    base_id = None
    out = {}
    for m in manifest["members"]:
        val = values[m["name"]]
        from repro.core.serialize import base_of
        b = base_of(val)
        if base_id is None:
            base_id = id(b)
        out[m["name"]] = LeafRecord(
            name=m["name"], kind=m["kind"], dtype=m["dtype"],
            shape=tuple(m["shape"]), nbytes=m["nbytes"], alias_id=id(b),
            view=m.get("view"), base_hashes=det if len(det) else None)
    return out


class StateLoader:
    def __init__(self, graph: CheckpointGraph, store: ChunkStore,
                 fallback=None, *, io_threads: Optional[int] = None):
        self.graph = graph
        self.store = store
        self.fallback = fallback      # callable (key, version, stats) -> values
        # <=1 forces the serial pre-engine path (benchmark baseline).
        self.io_threads = parallel.resolve_io_threads(io_threads)
        # Adaptive engagement (see parallel.py): first-slab latency below
        # the gate stays serial outright; above it a measured trial decides.
        # probe_threshold_s = 0.0 forces the pipeline; inf forces serial.
        self.probe_threshold_s = parallel.PARALLEL_LATENCY_THRESHOLD_S

    @staticmethod
    def _fetch_parallel(slabs, fetch, consume, workers):
        """Stream ``slabs`` through the prefetch pipeline; returns [] (all
        consumed) so callers can fall through to the serial remainder."""
        for slab, got in parallel.prefetch_map(fetch, slabs, workers):
            consume(slab, got)
        return []

    def load_cov(self, key: CovKey, version: str,
                 stats: Optional[CheckoutStats] = None) -> Dict[str, Any]:
        manifest = self.graph.manifest_of(key, version)
        if manifest is not None and not manifest.get("unserializable"):
            try:
                return materialize_manifest(self.store, manifest, stats)
            except (ChunkMissingError, SerializationError):
                pass
        if self.fallback is None:
            raise ChunkMissingError(
                f"co-variable {key} @ {version} unavailable and no fallback")
        if stats:
            stats.covs_recomputed += 1
        return self.fallback(key, version, stats)

    def load_covs(self, items: Sequence[Tuple[CovKey, str]],
                  stats: Optional[CheckoutStats] = None, *,
                  use_fallback: bool = True
                  ) -> Dict[CovKey, Dict[str, Any]]:
        """Load many versioned co-variables through the parallel engine.

        Plans every chunk key up front (deduplicated across co-variables —
        content addressing means branches share chunks), streams cov-ordered
        slabs through a bounded-concurrency prefetch pipeline, and
        materializes each co-variable on the calling thread as soon as its
        last chunk arrives, overlapping deserialization with in-flight I/O.

        Per-cov failures (missing/corrupt chunks, unserializable manifests)
        degrade to the serial ``load_cov`` path, which recomputes via
        ``fallback``.  With ``use_fallback=False`` failed co-variables are
        omitted from the result instead (the Data Restorer drives its own
        recursion bookkeeping).
        """
        out: Dict[CovKey, Dict[str, Any]] = {}
        retry: List[Tuple[CovKey, str]] = []    # -> serial/fallback path
        cache: Dict[str, bytes] = {}            # prefetched chunks
        ready: List[Tuple[CovKey, str, dict, List[str]]] = []
        for key, version in items:
            manifest = self.graph.manifest_of(key, version)
            if manifest is None or manifest.get("unserializable"):
                retry.append((key, version))
            else:
                ready.append((key, version, manifest,
                              [c["key"] for c in manifest["base"]["chunks"]]))

        workers = self.io_threads \
            if getattr(self.store, "supports_parallel_get", True) else 1
        if workers <= 1 or len(ready) == 0:
            for key, version, _, _ in ready:
                retry.append((key, version))
            retry.sort()
        else:
            # chunk key -> indices of covs waiting on it (cov order kept)
            owners: Dict[str, List[int]] = {}
            pending = []
            for i, (_, _, _, cks) in enumerate(ready):
                uniq = set(cks)
                pending.append(len(uniq))
                for ck in uniq:
                    owners.setdefault(ck, []).append(i)
            unique_keys = list(owners)
            # refs: covs not yet finished per chunk key — once a key's last
            # owner materializes its bytes are evicted from the cache, so
            # peak memory is bounded by in-flight covs, not the whole
            # restore.  Keys of *failed* covs stay pinned for the retry.
            refs = {ck: len(own) for ck, own in owners.items()}
            pinned: set = set()

            def fetch(slab):
                # serial_section: the engine owns concurrency (slabs across
                # pool threads); the backend must not nest its own pool.
                with parallel.serial_section():
                    return slab, self.store.get_chunks(slab, missing_ok=True)

            def finish(i):
                key, version, manifest, cks = ready[i]
                try:
                    out[key] = materialize_manifest(self.store, manifest,
                                                    stats, chunks=cache)
                except (ChunkMissingError, SerializationError):
                    retry.append((key, version))
                    pinned.update(cks)
                for ck in set(cks):
                    refs[ck] -= 1
                    if refs[ck] == 0 and ck not in pinned:
                        cache.pop(ck, None)

            def consume(slab, got):
                cache.update(got)
                for ck in slab:      # missing keys count as resolved: the
                    for i in owners[ck]:   # cov will fail -> fallback
                        pending[i] -= 1
                        if pending[i] == 0:
                            finish(i)

            for i, n in enumerate(pending):
                if n == 0:           # chunkless manifest (empty buffer)
                    finish(i)

            slabs = list(parallel.iter_slabs(
                unique_keys,
                max(getattr(self.store, "min_slab", 1),
                    parallel.slab_size_for(len(unique_keys), workers))))
            # Adaptive engagement: bandwidth-bound stores (warm cache,
            # RAM-speed media) stay serial — a pipeline only adds
            # contention; round-trip-bound stores engage it after a
            # measured trial.
            if slabs:
                # Slab 0 absorbs cold-start effects (cache revalidation,
                # first touch) so the probe compares steady-state rates.
                consume(*fetch(slabs[0]))
                rest = slabs[1:]
                if self.probe_threshold_s <= 0:     # forced pipeline
                    rest = self._fetch_parallel(rest, fetch, consume, workers)
                elif rest:
                    # Probe: one slab on the calling thread, timed.
                    t0 = time.perf_counter()
                    slab1, got1 = fetch(rest[0])
                    dt = max(time.perf_counter() - t0, 1e-9)
                    consume(slab1, got1)
                    per_chunk_serial = dt / max(1, len(slab1))
                    rest = rest[1:]
                    if per_chunk_serial >= self.probe_threshold_s and rest:
                        # Slow store: trial a few slabs concurrently and
                        # keep the pipeline only if its measured rate beats
                        # serial by a clear margin (high-latency transports
                        # that *serialize* concurrency lose the trial).
                        # Timed around the fetches only — the serial probe
                        # above excludes consume() too.
                        trial, rest = rest[:workers], rest[workers:]
                        t0 = time.perf_counter()
                        trial_got = parallel.map_parallel(
                            lambda s: fetch(s)[1], trial, workers)
                        dt2 = max(time.perf_counter() - t0, 1e-9)
                        for slab, got in zip(trial, trial_got):
                            consume(slab, got)
                        per_chunk_par = dt2 \
                            / max(1, sum(len(s) for s in trial))
                        if per_chunk_par <= per_chunk_serial \
                                * parallel.PARALLEL_TRIAL_MARGIN:
                            rest = self._fetch_parallel(rest, fetch, consume,
                                                        workers)
                for slab in rest:                   # serial remainder
                    consume(*fetch(slab))

        for key, version in retry:
            manifest = self.graph.manifest_of(key, version)
            if manifest is not None and not manifest.get("unserializable"):
                try:
                    # reuse prefetched chunks; absent keys retry the store
                    out[key] = materialize_manifest(
                        self.store, manifest, stats,
                        chunks=cache if cache else None)
                    continue
                except (ChunkMissingError, SerializationError):
                    pass
            if not use_fallback:
                continue
            if self.fallback is None:
                raise ChunkMissingError(
                    f"co-variable {key} @ {version} unavailable and no "
                    f"fallback")
            if stats:
                stats.covs_recomputed += 1
            out[key] = self.fallback(key, version, stats)
        return out

    def checkout(self, tracked_ns, records: Dict[str, LeafRecord],
                 target: str) -> Tuple[Dict[str, LeafRecord], CheckoutStats]:
        """Execute an incremental checkout; mutates the namespace in place.

        Returns (updated record map, stats)."""
        stats = CheckoutStats()
        t0 = time.perf_counter()
        cur = self.graph.head
        td = time.perf_counter()
        plan: CheckoutPlan = self.graph.diff(cur, target)
        stats.diff_s = time.perf_counter() - td
        stats.covs_identical = len(plan.identical)

        # 1. load diverged co-variables (before mutating anything),
        #    chunk I/O planned up front and prefetched in parallel
        loaded = self.load_covs(sorted(plan.to_load.items()), stats)

        # 2. swap into the namespace (tracking paused: checkout is not access)
        new_records = dict(records)
        with tracked_ns.pause():
            for key in plan.to_delete:
                for name in key:
                    if name in tracked_ns.base:
                        del tracked_ns.base[name]
                    new_records.pop(name, None)
            for key, values in loaded.items():
                manifest = self.graph.manifest_of(key, plan.to_load[key])
                for name, val in values.items():
                    tracked_ns.base[name] = val
                if manifest is not None and not manifest.get("unserializable"):
                    new_records.update(records_from_manifest(manifest, values))
                else:
                    # recomputed: rebuild records by hashing
                    from repro.core.covariable import RecordBuilder
                    rb = RecordBuilder()
                    cache: Dict[int, Any] = {}
                    for name, val in values.items():
                        new_records[name] = rb.build(name, val, cache)

        stats.covs_loaded = len(loaded)
        stats.covs_deleted = len(plan.to_delete)
        self.graph.set_head(target)
        stats.wall_s = time.perf_counter() - t0
        return new_records, stats

    def materialize_state(self, tracked_ns, target: str
                          ) -> Tuple[Dict[str, LeafRecord], CheckoutStats]:
        """Full (non-incremental) load of a state into an empty namespace —
        the crash-recovery / elastic-resume path."""
        stats = CheckoutStats()
        t0 = time.perf_counter()
        from repro.core.graph import parse_key
        index = self.graph.nodes[target].state_index
        items = [(parse_key(ks), version)
                 for ks, version in sorted(index.items())]
        loaded = self.load_covs(items, stats)
        versions = dict(items)
        new_records: Dict[str, LeafRecord] = {}
        with tracked_ns.pause():
            for key, values in loaded.items():
                manifest = self.graph.manifest_of(key, versions[key])
                for name, val in values.items():
                    tracked_ns.base[name] = val
                if manifest is not None and not manifest.get("unserializable"):
                    new_records.update(records_from_manifest(manifest, values))
                else:
                    from repro.core.covariable import RecordBuilder
                    rb = RecordBuilder()
                    cache: Dict[int, Any] = {}
                    for name, val in values.items():
                        new_records[name] = rb.build(name, val, cache)
        stats.covs_loaded = len(index)
        self.graph.set_head(target)
        stats.wall_s = time.perf_counter() - t0
        return new_records, stats
