"""Incremental checkout — the State Loader (§5.2).

Given the current HEAD and a target commit, compute the diverged co-variables
via the Checkpoint Graph index (Def 6), load *only* those from their
manifests, reconstruct shared references (aliases/views), and swap them into
the live namespace without touching identical co-variables.  Missing or
corrupt data falls back to recomputation (restore.py).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.chunkstore import ChunkStore
from repro.core.covariable import CovKey, LeafRecord
from repro.core.graph import CheckpointGraph, CheckoutPlan, key_str
from repro.core.serialize import (ChunkMissingError, SerializationError,
                                  leaf_from_bytes, view_from_base)


@dataclass
class CheckoutStats:
    covs_loaded: int = 0
    covs_deleted: int = 0
    covs_identical: int = 0
    covs_recomputed: int = 0
    bytes_loaded: int = 0
    wall_s: float = 0.0
    diff_s: float = 0.0


def materialize_manifest(store: ChunkStore, manifest: dict,
                         stats: Optional[CheckoutStats] = None
                         ) -> Dict[str, Any]:
    """Load a co-variable's values from its manifest.

    Reconstructs shared references: one base buffer, members as views/aliases.
    Raises ChunkMissingError / SerializationError on failure (-> fallback).
    """
    if manifest.get("unserializable"):
        raise SerializationError("manifest flagged unserializable")
    base_info = manifest["base"]
    parts = []
    for c in base_info["chunks"]:
        data = store.get_chunk(c["key"])
        if len(data) != c["n"]:
            raise ChunkMissingError(f"chunk {c['key']}: size mismatch")
        parts.append(data)
    blob = b"".join(parts)
    if len(blob) != base_info["nbytes"]:
        raise ChunkMissingError("assembled size mismatch")
    if stats:
        stats.bytes_loaded += len(blob)
    base = leaf_from_bytes(blob, base_info["meta"])

    out: Dict[str, Any] = {}
    for m in manifest["members"]:
        if m.get("view"):
            out[m["name"]] = view_from_base(base, m["view"])
        else:
            out[m["name"]] = base
    return out


def records_from_manifest(manifest: dict, values: Dict[str, Any]
                          ) -> Dict[str, LeafRecord]:
    """Rebuild LeafRecords after checkout without rehashing (det hashes are
    stored in the manifest)."""
    det_hex = [] if manifest.get("unserializable") else \
        manifest["base"].get("det_hashes", [])
    det = np.array([int(h, 16) for h in det_hex], dtype=np.uint64)
    base_id = None
    out = {}
    for m in manifest["members"]:
        val = values[m["name"]]
        from repro.core.serialize import base_of
        b = base_of(val)
        if base_id is None:
            base_id = id(b)
        out[m["name"]] = LeafRecord(
            name=m["name"], kind=m["kind"], dtype=m["dtype"],
            shape=tuple(m["shape"]), nbytes=m["nbytes"], alias_id=id(b),
            view=m.get("view"), base_hashes=det if len(det) else None)
    return out


class StateLoader:
    def __init__(self, graph: CheckpointGraph, store: ChunkStore,
                 fallback=None):
        self.graph = graph
        self.store = store
        self.fallback = fallback      # callable (key, version, stats) -> values

    def load_cov(self, key: CovKey, version: str,
                 stats: Optional[CheckoutStats] = None) -> Dict[str, Any]:
        manifest = self.graph.manifest_of(key, version)
        if manifest is not None and not manifest.get("unserializable"):
            try:
                return materialize_manifest(self.store, manifest, stats)
            except (ChunkMissingError, SerializationError):
                pass
        if self.fallback is None:
            raise ChunkMissingError(
                f"co-variable {key} @ {version} unavailable and no fallback")
        if stats:
            stats.covs_recomputed += 1
        return self.fallback(key, version, stats)

    def checkout(self, tracked_ns, records: Dict[str, LeafRecord],
                 target: str) -> Tuple[Dict[str, LeafRecord], CheckoutStats]:
        """Execute an incremental checkout; mutates the namespace in place.

        Returns (updated record map, stats)."""
        stats = CheckoutStats()
        t0 = time.perf_counter()
        cur = self.graph.head
        td = time.perf_counter()
        plan: CheckoutPlan = self.graph.diff(cur, target)
        stats.diff_s = time.perf_counter() - td
        stats.covs_identical = len(plan.identical)

        # 1. load diverged co-variables (before mutating anything)
        loaded: Dict[CovKey, Dict[str, Any]] = {}
        for key, version in sorted(plan.to_load.items()):
            loaded[key] = self.load_cov(key, version, stats)

        # 2. swap into the namespace (tracking paused: checkout is not access)
        new_records = dict(records)
        with tracked_ns.pause():
            for key in plan.to_delete:
                for name in key:
                    if name in tracked_ns.base:
                        del tracked_ns.base[name]
                    new_records.pop(name, None)
            for key, values in loaded.items():
                manifest = self.graph.manifest_of(key, plan.to_load[key])
                for name, val in values.items():
                    tracked_ns.base[name] = val
                if manifest is not None and not manifest.get("unserializable"):
                    new_records.update(records_from_manifest(manifest, values))
                else:
                    # recomputed: rebuild records by hashing
                    from repro.core.covariable import RecordBuilder
                    rb = RecordBuilder()
                    cache: Dict[int, Any] = {}
                    for name, val in values.items():
                        new_records[name] = rb.build(name, val, cache)

        stats.covs_loaded = len(loaded)
        stats.covs_deleted = len(plan.to_delete)
        self.graph.set_head(target)
        stats.wall_s = time.perf_counter() - t0
        return new_records, stats

    def materialize_state(self, tracked_ns, target: str
                          ) -> Tuple[Dict[str, LeafRecord], CheckoutStats]:
        """Full (non-incremental) load of a state into an empty namespace —
        the crash-recovery / elastic-resume path."""
        stats = CheckoutStats()
        t0 = time.perf_counter()
        from repro.core.graph import parse_key
        index = self.graph.nodes[target].state_index
        new_records: Dict[str, LeafRecord] = {}
        with tracked_ns.pause():
            for ks, version in sorted(index.items()):
                key = parse_key(ks)
                values = self.load_cov(key, version, stats)
                manifest = self.graph.manifest_of(key, version)
                for name, val in values.items():
                    tracked_ns.base[name] = val
                if manifest is not None and not manifest.get("unserializable"):
                    new_records.update(records_from_manifest(manifest, values))
                else:
                    from repro.core.covariable import RecordBuilder
                    rb = RecordBuilder()
                    cache: Dict[int, Any] = {}
                    for name, val in values.items():
                        new_records[name] = rb.build(name, val, cache)
        stats.covs_loaded = len(index)
        self.graph.set_head(target)
        stats.wall_s = time.perf_counter() - t0
        return new_records, stats
