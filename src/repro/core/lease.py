"""Session leases — single-writer enforcement per checkpoint namespace
(DESIGN.md §14).

Two ``KishuSession``s opened on one store can tear a branch: both load the
same HEAD seq, both publish ``c{seq}``, and the second publish silently
orphans the first writer's commit.  A *lease* is the writer-side fix: one
meta document (``lease/<name>``) naming the current writer, acquired before
a session opens its graph (so crash recovery runs under the lease too) and
checked before every metadata publish.

**Clock discipline.**  Stores are shared across hosts, so the lease doc
never carries a wall-clock deadline that another host would have to trust
(an NTP step would instantly expire — or immortalize — the lease).
Expiry is *observed*, not declared: a contender may steal only after the
same ``(owner, token, ts)`` document has been continuously visible for the
doc's full ``ttl_s`` on the contender's **own monotonic clock**.  The
holder symmetrically trusts only its own monotonic clock: a successful
acquire/renew buys ``ttl_s`` of local validity, and once that horizon
passes the holder refuses to publish (``LeaseLost``) — which is always
*before* any contender can have finished observing a full quiet TTL,
because observation can only start at (or after) the holder's last write.

**Fencing.**  Every acquisition (first grant or steal) increments the
doc's ``token``.  A deposed writer discovers the steal at its next renew
(owner/token mismatch) or local expiry, and its transaction engine
poisons itself instead of publishing over the thief's commits; the
Checkpoint Graph's HEAD-seq compare-and-fail (txn.check_publish_guard)
backstops even the races a last-writer-wins meta store cannot exclude.

The store needs nothing beyond ``put_meta``/``get_meta``/``delete_meta``
— acquisition is write-then-read-back (the reader that sees its own doc
won the write race).  That is weaker than a CAS, so the lease is a
*practical* mutual exclusion (window: two writers racing the same
read-back), with the seq guard as the defense in depth the ISSUE keeps.
"""
from __future__ import annotations

import os
import socket
import threading
import time
import uuid
from typing import Dict, List, Optional

from repro.core.chunkstore import ChunkStore

LEASE_PREFIX = "lease/"
DEFAULT_TTL_S = 30.0


class LeaseError(RuntimeError):
    """Base class for lease failures."""


class LeaseHeld(LeaseError):
    """Acquisition failed: another writer holds an unexpired lease."""


class LeaseLost(LeaseError):
    """The local writer can no longer prove it holds the lease (stolen by
    another writer, or its local validity horizon passed without a renew);
    publishing now could tear the branch, so the caller must stop."""


def default_owner_id() -> str:
    """Host + pid + nonce: unique across hosts, processes, and multiple
    sessions inside one process (the kishud case)."""
    return (f"{socket.gethostname()}-{os.getpid()}-"
            f"{uuid.uuid4().hex[:8]}")


class Lease:
    """A writer lease over one checkpoint namespace.

        lease = Lease(store, ttl_s=10.0).acquire()
        ...                      # publish freely; call ensure() before each
        lease.ensure()           # cheap: I/O only when a renew is due
        lease.release()

    Thread-safe: the async publish worker calls ``ensure`` from its own
    thread while the session thread may be releasing.
    """

    #: fraction of the TTL after which ``ensure`` proactively renews —
    #: leaves at least half the TTL of slack for the renew round-trip
    RENEW_FRAC = 0.5

    def __init__(self, store: ChunkStore, name: str = "writer", *,
                 owner: Optional[str] = None, ttl_s: float = DEFAULT_TTL_S,
                 obs=None):
        self.store = store
        self.name = name
        self.doc_name = LEASE_PREFIX + name
        self.owner = owner or default_owner_id()
        self.ttl_s = float(ttl_s)
        self.token = 0
        self.obs = obs                # optional SessionObs for event counts
        self._held = False
        self._horizon = 0.0           # local-monotonic validity deadline
        self._observed = None         # (doc fingerprint, first-seen mono)
        self._lock = threading.RLock()

    def _event(self, event: str) -> None:
        if self.obs is not None:
            self.obs.registry.counter("kishu_lease_events_total",
                                      event=event).inc()

    # ------------------------------------------------------------------
    # acquisition
    # ------------------------------------------------------------------
    @property
    def held(self) -> bool:
        with self._lock:
            return self._held and time.monotonic() < self._horizon

    def _fingerprint(self, doc: dict):
        return (doc.get("owner"), doc.get("token"), doc.get("ts"))

    def _expired(self, doc: dict) -> bool:
        """True once the same doc has been continuously observed for its
        full TTL on *our* monotonic clock.  The first sighting only starts
        the observation window — never trust the doc's wall-clock ``ts``."""
        fp = self._fingerprint(doc)
        now = time.monotonic()
        if self._observed is None or self._observed[0] != fp:
            self._observed = (fp, now)
            return False
        return now - self._observed[1] >= float(doc.get("ttl_s", self.ttl_s))

    def _try_acquire(self, steal: bool) -> bool:
        cur = self.store.get_meta(self.doc_name)
        takeover = None
        if cur is not None and cur.get("owner") != self.owner:
            if steal:
                takeover = "steal"
            elif self._expired(cur):
                takeover = "expired_takeover"
            else:
                return False
        token = int((cur or {}).get("token", 0)) + 1
        t0 = time.monotonic()
        self.store.put_meta(self.doc_name, self._doc(token))
        back = self.store.get_meta(self.doc_name)
        if back is None or back.get("owner") != self.owner \
                or back.get("token") != token:
            return False              # lost the write race to another writer
        with self._lock:
            self.token = token
            self._held = True
            self._horizon = t0 + self.ttl_s
        self._event(takeover or "acquire")
        return True

    def _doc(self, token: int) -> dict:
        return {"owner": self.owner, "token": token, "ttl_s": self.ttl_s,
                "ts": time.time(), "pid": os.getpid(),
                "host": socket.gethostname()}

    def acquire(self, *, wait_s: float = 0.0, steal: bool = False,
                poll_s: float = 0.05) -> "Lease":
        """Take the lease.  Free (or our own) docs grant immediately; a
        foreign doc grants only after *observed* expiry — so with
        ``wait_s`` covering the TTL, a contender blocks until the holder
        dies, and with ``wait_s=0`` a held lease raises :class:`LeaseHeld`
        at once.  ``steal=True`` skips the observation (operator override:
        the caller asserts the holder is dead)."""
        deadline = time.monotonic() + max(0.0, wait_s)
        while True:
            if self._try_acquire(steal):
                return self
            if time.monotonic() >= deadline:
                cur = self.store.get_meta(self.doc_name) or {}
                self._event("held")
                raise LeaseHeld(
                    f"lease {self.doc_name!r} held by "
                    f"{cur.get('owner', '?')} (token {cur.get('token')}); "
                    f"not observed idle for ttl={cur.get('ttl_s')}s")
            time.sleep(min(poll_s, max(1e-3,
                                       deadline - time.monotonic())))

    # ------------------------------------------------------------------
    # holder-side maintenance
    # ------------------------------------------------------------------
    def renew(self) -> None:
        """Refresh the doc and extend the local validity horizon.  Raises
        :class:`LeaseLost` if another writer has taken over (owner or
        token mismatch) — the fencing check a deposed writer cannot miss."""
        with self._lock:
            if not self._held:
                raise LeaseLost(f"lease {self.doc_name!r} not held")
            cur = self.store.get_meta(self.doc_name)
            if cur is None or cur.get("owner") != self.owner \
                    or cur.get("token") != self.token:
                self._held = False
                self._event("lost")
                raise LeaseLost(
                    f"lease {self.doc_name!r} taken over by "
                    f"{(cur or {}).get('owner', '?')} "
                    f"(token {(cur or {}).get('token')})")
            t0 = time.monotonic()
            self.store.put_meta(self.doc_name, self._doc(self.token))
            self._horizon = t0 + self.ttl_s

    def ensure(self) -> None:
        """Pre-publish check: free while well inside the TTL, renews
        (2 meta round-trips) once past ``RENEW_FRAC`` of it, and raises
        :class:`LeaseLost` past the local horizon — at which point a
        contender may legitimately have stolen the lease, so publishing
        would risk tearing the branch."""
        with self._lock:
            if not self._held:
                raise LeaseLost(f"lease {self.doc_name!r} not held")
            now = time.monotonic()
            if now >= self._horizon:
                self._held = False
                self._event("lost")
                raise LeaseLost(
                    f"lease {self.doc_name!r} expired locally "
                    f"(no renew within ttl={self.ttl_s}s)")
            if now >= self._horizon - self.ttl_s * self.RENEW_FRAC:
                self.renew()

    def release(self) -> None:
        """Drop the lease doc iff still ours — releasing a stolen lease
        must not delete the thief's grant.  Idempotent."""
        with self._lock:
            if not self._held:
                return
            self._held = False
            try:
                cur = self.store.get_meta(self.doc_name)
                if cur is not None and cur.get("owner") == self.owner \
                        and cur.get("token") == self.token:
                    self.store.delete_meta(self.doc_name)
            except Exception:  # noqa: BLE001 — backend down: TTL reclaims
                pass


# ---------------------------------------------------------------------------
# introspection (CLI `lease` / `tenants`, kishud status)
# ---------------------------------------------------------------------------

def lease_status(store: ChunkStore) -> List[Dict]:
    """All lease docs visible in the store's namespace, with the doc's own
    wall-clock age as a *hint* (expiry itself is observation-based)."""
    out = []
    for name in store.list_meta(LEASE_PREFIX):
        doc = store.get_meta(name) or {}
        age = max(0.0, time.time() - float(doc.get("ts", 0.0)))
        out.append({"name": name[len(LEASE_PREFIX):],
                    "owner": doc.get("owner"), "token": doc.get("token"),
                    "ttl_s": doc.get("ttl_s"), "age_hint_s": round(age, 3),
                    "pid": doc.get("pid"), "host": doc.get("host")})
    return out
