"""Co-variables and LeafRecords — Definitions 1–2 adapted to array states.

A *co-variable* is a maximal set of names whose leaves share an underlying
buffer (weight tying, numpy views, duplicated references).  It is the minimum
unit that can be stored/loaded without silently breaking shared references —
restoring a tied ``embed``/``lm_head`` pair as two independent arrays unties
the model (DESIGN.md §2).

A :class:`LeafRecord` is the VarGraph analogue for one name:
  - structure: dtype/shape (+ view spec relative to the alias base)
  - identity:  alias key (which base buffer the leaf points into)
  - content:   per-chunk detection hashes of the *base* buffer

Update detection (Def 2) compares records before/after a command:
  node change  = base content hash diff
  edge change  = alias key / view-spec diff (split & merge)
  structure    = dtype/shape diff
"""
from __future__ import annotations

import pickle
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core import hashing
from repro.core.serialize import (OpaqueLeaf, base_of, is_array_leaf,
                                  is_prng_key, leaf_meta, view_spec)

CovKey = Tuple[str, ...]


def cov_key(names: Sequence[str]) -> CovKey:
    return tuple(sorted(names))


@dataclass
class LeafRecord:
    name: str
    kind: str                        # "array" | "prng" | "object" | "opaque"
    dtype: str = ""
    shape: Tuple[int, ...] = ()
    nbytes: int = 0
    alias_id: int = 0                # id() of the base buffer (session-local)
    view: Optional[dict] = None      # strided-view spec relative to base
    base_hashes: Optional[np.ndarray] = None  # uint64 [n_chunks] of base
    obj_digest: Optional[bytes] = None        # for small "object" leaves

    def content_equal(self, other: "LeafRecord") -> bool:
        """Value-level equality (ignores alias identity)."""
        if self.kind != other.kind:
            return False
        if self.kind == "opaque":
            return False                      # conservative: updated on access
        if (self.dtype, self.shape, self.view) != \
                (other.dtype, other.shape, other.view):
            return False
        if self.kind == "object":
            return self.obj_digest == other.obj_digest
        if self.base_hashes is None or other.base_hashes is None:
            return False
        return (self.base_hashes.shape == other.base_hashes.shape
                and bool(np.array_equal(self.base_hashes, other.base_hashes)))


class RecordBuilder:
    """Builds LeafRecords with a per-call base-hash cache so aliased members
    hash their shared base exactly once."""

    def __init__(self, chunk_bytes: int = hashing.DEFAULT_CHUNK_BYTES,
                 hasher=None):
        self.chunk_bytes = chunk_bytes
        self.hasher = hasher or hashing.chunk_hashes_np
        self.hash_calls = 0
        self.hashed_bytes = 0
        # fused-path handoff (DESIGN.md §15): id(base) -> DeltaPack built
        # during detection; the checkpoint writer reads the dirty chunks
        # from the pack's compacted device buffer instead of re-slicing the
        # array.  Cleared at the start of every detect_delta — ids are only
        # stable while the bases live in the namespace.
        self.packs: Dict[int, Any] = {}

    def _hash_base(self, base: Any, cache: Dict[int, np.ndarray],
                   prev_hashes: Optional[np.ndarray] = None) -> np.ndarray:
        key = id(base)
        if key in cache:
            return cache[key]
        if self.hasher is hashing.chunk_hashes_np and not is_prng_key(base):
            import jax
            if isinstance(base, jax.Array):
                from repro.core import delta as delta_mod
                # fused path: one pass yields hashes AND the compacted
                # dirty chunks (the writer consumes the pack; detection
                # transfers 12 bytes/chunk instead of the buffer)
                pack = delta_mod.device_delta_pack(base, prev_hashes,
                                                   self.chunk_bytes)
                if pack is not None:
                    self.packs[key] = pack
                    self.hash_calls += 1
                    self.hashed_bytes += pack.nbytes
                    cache[key] = pack.hashes
                    return pack.hashes
                # device arrays: hash on device (Pallas chunk_hash kernel,
                # jnp fallback) so delta *detection* doesn't transfer the
                # whole buffer host-side; None -> host path below
                h = hashing.chunk_hashes_device(base, self.chunk_bytes)
                if h is not None:
                    self.hash_calls += 1
                    self.hashed_bytes += int(
                        base.size * np.dtype(base.dtype).itemsize)
                    cache[key] = h
                    return h
        if is_prng_key(base):
            import jax
            arr = np.asarray(jax.random.key_data(base))
        else:
            arr = np.asarray(base)
            if not arr.flags["C_CONTIGUOUS"]:
                arr = np.ascontiguousarray(arr)
        h = self.hasher(arr.reshape(-1).view(np.uint8) if arr.ndim else
                        arr.tobytes(), self.chunk_bytes)
        self.hash_calls += 1
        self.hashed_bytes += arr.nbytes
        cache[key] = h
        return h

    def build(self, name: str, leaf: Any,
              cache: Optional[Dict[int, np.ndarray]] = None,
              prev: Optional[LeafRecord] = None) -> LeafRecord:
        cache = cache if cache is not None else {}
        if isinstance(leaf, OpaqueLeaf):
            return LeafRecord(name=name, kind="opaque", alias_id=id(leaf))
        if is_prng_key(leaf):
            import jax
            data = jax.random.key_data(leaf)
            return LeafRecord(
                name=name, kind="prng", dtype=str(np.asarray(data).dtype),
                shape=tuple(data.shape), nbytes=int(np.asarray(data).nbytes),
                alias_id=id(leaf), base_hashes=self._hash_base(leaf, cache))
        if is_array_leaf(leaf):
            base = base_of(leaf)
            # previous commit's hashes of this name (device arrays rebind
            # every run, so identity can't key this — the name does) seed
            # the fused hash+diff+compact pass
            prev_hashes = prev.base_hashes \
                if prev is not None and prev.kind == "array" else None
            return LeafRecord(
                name=name, kind="array", dtype=str(np.dtype(leaf.dtype)),
                shape=tuple(leaf.shape),
                nbytes=int(np.dtype(leaf.dtype).itemsize * int(np.prod(leaf.shape, dtype=np.int64))),
                alias_id=id(base), view=view_spec(leaf, base),
                base_hashes=self._hash_base(base, cache, prev_hashes))
        # small python object
        try:
            blob = pickle.dumps(leaf)
            import hashlib
            dig = hashlib.blake2b(blob, digest_size=16).digest()
            return LeafRecord(name=name, kind="object",
                              dtype=type(leaf).__name__, nbytes=len(blob),
                              alias_id=id(leaf), obj_digest=dig)
        except Exception:  # noqa: BLE001 — unpicklable object == opaque
            return LeafRecord(name=name, kind="opaque", alias_id=id(leaf))


def group_covariables(records: Dict[str, LeafRecord]) -> Dict[CovKey, List[str]]:
    """Connected components under shared base buffers (Def 1)."""
    by_alias: Dict[int, List[str]] = {}
    for name, rec in records.items():
        by_alias.setdefault(rec.alias_id, []).append(name)
    return {cov_key(names): sorted(names) for names in by_alias.values()}


@dataclass
class StateDelta:
    """Result of delta detection for one command execution (Def 2)."""
    updated: Dict[CovKey, List[LeafRecord]] = field(default_factory=dict)
    deleted: List[CovKey] = field(default_factory=list)
    unchanged_accessed: List[CovKey] = field(default_factory=list)
    candidates: List[CovKey] = field(default_factory=list)  # pre-state covs accessed
    checked: int = 0                 # co-variables actually inspected
    skipped: int = 0                 # pruned by Lemma 1


def detect_delta(prev_records: Dict[str, LeafRecord],
                 prev_covs: Dict[CovKey, List[str]],
                 ns, accessed: Set[str],
                 builder: RecordBuilder) -> Tuple[StateDelta, Dict[str, LeafRecord]]:
    """Compute the state delta at co-variable granularity.

    Only co-variables intersecting ``accessed`` (plus created names) are
    inspected — Lemma 1.  Returns (delta, new full record map).
    """
    cur_names = set(ns.names())
    prev_names = set(prev_records)
    created = cur_names - prev_names
    removed = prev_names - cur_names

    # candidate co-variables: any member accessed / removed
    touched = set(accessed) | created | removed
    candidates: List[CovKey] = []
    candidate_names: Set[str] = set(created)
    for key, members in prev_covs.items():
        if any(m in touched for m in members):
            candidates.append(key)
            candidate_names.update(members)
    delta = StateDelta(skipped=len(prev_covs) - len(candidates),
                       candidates=list(candidates))

    # rebuild records for candidate names only
    new_records: Dict[str, LeafRecord] = {}
    hash_cache: Dict[int, np.ndarray] = {}
    builder.packs.clear()           # packs are one-commit artifacts
    for name in sorted(candidate_names):
        if name in cur_names:
            new_records[name] = builder.build(name, ns[name], hash_cache,
                                              prev=prev_records.get(name))

    new_groups = group_covariables(new_records)
    delta.checked = len(new_groups)

    # full record map: unchanged names keep their old record
    full = {n: r for n, r in prev_records.items()
            if n not in candidate_names and n in cur_names}
    full.update(new_records)

    old_candidate_keys = set(candidates)
    for key, members in new_groups.items():
        if key in old_candidate_keys:
            same = all(
                m in prev_records
                and new_records[m].content_equal(prev_records[m])
                for m in members)
            if same:
                delta.unchanged_accessed.append(key)
                continue
        delta.updated[key] = [new_records[m] for m in members]

    # deletions: candidate covs whose exact membership no longer exists
    for key in candidates:
        if key not in new_groups:
            delta.deleted.append(key)
    return delta, full
