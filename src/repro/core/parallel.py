"""Bounded-concurrency I/O executor — the parallel chunk engine (DESIGN.md §9).

Checkpoint restore latency is bound by per-chunk round-trips when chunks are
fetched one-at-a-time on the calling thread; checkpoint write latency likewise
pays one store round-trip per chunk.  This module provides the shared
primitives that turn both paths into pipelined, bounded-concurrency batch I/O:

  - ``resolve_io_threads``  — one knob (ctor arg > $KISHU_IO_THREADS > default)
  - ``map_parallel``        — ordered parallel map over blocking calls
  - ``prefetch_map``        — streaming unordered map with a bounded
                              submission window: results are yielded on the
                              *calling* thread as they complete, so the
                              consumer (deserialization / materialization)
                              overlaps with in-flight I/O
  - ``iter_slabs``          — contiguous batching that preserves the caller's
                              key order, keeping early co-variables' chunks
                              early in the pipeline

All work runs on one shared, lazily-created, long-lived pool: spawning
threads (and, for SQLite, their thread-local connections) per checkout costs
more than a small restore itself.  Worker threads are tagged so
backend-native batched ops never nest a second level of parallelism inside a
pipeline worker (thread-explosion guard), and per-call concurrency is
enforced by a submission window rather than pool size.

The thread-count default is a small constant, not a large oversubscription:
I/O threads exist to hide per-chunk round-trip latency (network FS, cold
disk, database round trips), which takes a handful of in-flight requests —
while warm-local-cache reads are GIL/memcpy-bound, where a large pool only
thrashes.  ``io_threads=1`` (or $KISHU_IO_THREADS=1) restores the serial
path exactly.
"""
from __future__ import annotations

import os
import threading
from concurrent.futures import FIRST_COMPLETED, ThreadPoolExecutor, wait
from typing import Any, Callable, Iterable, Iterator, List, Optional, Sequence

DEFAULT_IO_THREADS = min(8, max(4, os.cpu_count() or 1))

# Adaptive-engagement latency gate (see StateLoader.load_covs).  A first
# slab fetched below this per-chunk latency means the store is serving at
# memory/cache-bandwidth class, where a thread pipeline only adds GIL and
# FS-client contention — the restore stays serial without further probing.
# Slower stores get an *empirical* trial: a few slabs fetched concurrently,
# and the measured serial vs parallel per-chunk rates pick the strategy for
# the remainder (some transports, e.g. 9p mounts, are high-latency yet
# serialize concurrent requests — only a measurement can tell).
PARALLEL_LATENCY_THRESHOLD_S = 1e-3

# The concurrent trial must beat the serial probe's per-chunk rate by this
# factor to keep the pipeline.  A transport that merely *serializes*
# concurrent requests measures ~1.0 here (and would later lose to
# consumer-side GIL contention); genuine round-trip hiding measures
# ~1/workers.  Between the two, serial is the safe choice.
PARALLEL_TRIAL_MARGIN = 0.75

_POOL_SIZE = 16          # shared-pool capacity; per-call windows bound usage
_pool: Optional[ThreadPoolExecutor] = None
_pool_lock = threading.Lock()

# Dedicated scatter-gather pool for the storage fabric (fabric.py).  Fabric
# concurrency is *topology-shaped* — one in-flight request per shard/replica,
# possibly issued from inside a checkout pipeline worker — so it must not
# share capacity with (or wait on) the chunk I/O pool: a fabric task queued
# behind the very pipeline worker awaiting it would deadlock.
_FABRIC_POOL_SIZE = 16
_fabric_pool: Optional[ThreadPoolExecutor] = None
_fabric_lock = threading.Lock()

_worker_state = threading.local()
_fabric_state = threading.local()


def _shared_pool() -> ThreadPoolExecutor:
    global _pool
    if _pool is None:
        with _pool_lock:
            if _pool is None:
                _pool = ThreadPoolExecutor(
                    max_workers=_POOL_SIZE,
                    thread_name_prefix="kishu-io")
    return _pool


def _fabric_shared_pool() -> ThreadPoolExecutor:
    global _fabric_pool
    if _fabric_pool is None:
        with _fabric_lock:
            if _fabric_pool is None:
                _fabric_pool = ThreadPoolExecutor(
                    max_workers=_FABRIC_POOL_SIZE,
                    thread_name_prefix="kishu-fabric")
    return _fabric_pool


def in_fabric_worker() -> bool:
    """True on a fabric scatter thread (nested fabrics degrade to serial)."""
    return getattr(_fabric_state, "is_worker", False)


def scatter_parallel(fn: Callable[[Any], Any], items: Sequence[Any]
                     ) -> List[Any]:
    """Ordered scatter-gather over fabric children (shards / replicas /
    tiers): one task per item on the dedicated fabric pool, all driven
    concurrently, results gathered in order.  The first child exception
    propagates.

    Scatter tasks are tagged both as fabric workers (a nested fabric — a
    replica set inside a shard ring — runs its own scatter serially instead
    of re-entering the pool) and as I/O workers (``serial_section``), so leaf
    backends' native batching degrades to plain loops: each child store
    behaves like one device that serializes its own requests, and all
    cross-device concurrency lives here, bounded by the topology's width.
    """
    items = list(items)
    if len(items) <= 1 or in_fabric_worker():
        return [fn(it) for it in items]

    def run(it):
        _fabric_state.is_worker = True
        with serial_section():
            return fn(it)

    futs = [_fabric_shared_pool().submit(run, it) for it in items]
    return [f.result() for f in futs]


def resolve_io_threads(n: Optional[int] = None) -> int:
    """Effective worker count: explicit arg > $KISHU_IO_THREADS > default.

    ``<= 1`` means serial (the pre-engine behavior, kept as the benchmark
    baseline and the fallback for tiny transfers)."""
    if n is None:
        env = os.environ.get("KISHU_IO_THREADS", "").strip()
        try:
            n = int(env) if env else DEFAULT_IO_THREADS
        except ValueError:      # unparseable knob: default, don't crash
            n = DEFAULT_IO_THREADS
    return max(1, int(n))


def in_io_worker() -> bool:
    """True when running on one of this module's pool threads (guards
    backend-native batching from nesting another pool)."""
    return getattr(_worker_state, "is_worker", False)


class serial_section:
    """Context manager marking the current thread as an I/O worker, so
    backend-native batched ops inside it degrade to serial loops.  The
    checkout engine owns its concurrency (slabs across pool threads) and
    uses this to keep its probes and serial remainders genuinely serial —
    without it, a main-thread ``get_chunks`` probe would measure the
    backend's own pool, not the store."""

    def __enter__(self):
        self._prev = getattr(_worker_state, "is_worker", False)
        _worker_state.is_worker = True
        return self

    def __exit__(self, *exc):
        _worker_state.is_worker = self._prev
        return False


def _tagged(fn: Callable, item: Any) -> Any:
    _worker_state.is_worker = True
    return fn(item)


def map_parallel(fn: Callable[[Any], Any], items: Sequence[Any],
                 max_workers: Optional[int] = None) -> List[Any]:
    """Ordered parallel map; serial for trivial inputs or nested calls.
    The first worker exception propagates to the caller."""
    items = list(items)
    workers = min(resolve_io_threads(max_workers), len(items))
    if workers <= 1 or len(items) <= 1 or in_io_worker():
        return [fn(it) for it in items]
    out: List[Any] = [None] * len(items)

    def run_at(i):
        return i, fn(items[i])
    for i, result in prefetch_map(run_at, range(len(items)), workers):
        out[i] = result
    return out


def iter_slabs(seq: Sequence[Any], slab_size: int) -> Iterator[List[Any]]:
    """Contiguous slabs preserving order (cov-ordered keys stay cov-ordered,
    so early co-variables complete — and materialize — early)."""
    slab_size = max(1, int(slab_size))
    for i in range(0, len(seq), slab_size):
        yield list(seq[i:i + slab_size])


def slab_size_for(n_items: int, workers: int, *, max_slab: int = 500) -> int:
    """Batch size giving each worker a few slabs to pipeline (granular enough
    that consumption overlaps I/O, coarse enough to amortize dispatch)."""
    if n_items <= 0:
        return 1
    return max(1, min(max_slab, -(-n_items // (max(1, workers) * 3))))


def fetch_chunks(store, keys: Sequence[str],
                 max_workers: Optional[int] = None, *,
                 missing_ok: bool = True) -> dict:
    """Deduplicated bulk chunk fetch through the prefetch pipeline —
    round-trip hiding for latency-bound stores; degrades to one
    backend-native batched call for small requests, non-parallel stores, or
    nested calls.  Shared by the patch-checkout planner and maintenance
    paths; the pipeline's worker tagging keeps backend-native batching from
    nesting a second pool."""
    uniq = list(dict.fromkeys(keys))
    workers = resolve_io_threads(max_workers)
    min_slab = getattr(store, "min_slab", 1)
    if getattr(store, "native_scatter", False) \
            or not getattr(store, "supports_parallel_get", True) \
            or workers <= 1 \
            or in_io_worker() or len(uniq) <= max(min_slab, workers):
        # native_scatter: the store fans the whole request out across its
        # devices itself — one call maximizes its load balance
        return store.get_chunks(uniq, missing_ok=missing_ok)
    slabs = iter_slabs(uniq, max(min_slab, slab_size_for(len(uniq), workers)))
    out: dict = {}
    for got in prefetch_map(
            lambda slab: store.get_chunks(slab, missing_ok=True),
            slabs, workers):
        out.update(got)
    if not missing_ok and len(out) != len(uniq):
        from repro.core.serialize import ChunkMissingError
        raise ChunkMissingError(next(k for k in uniq if k not in out))
    return out


def prefetch_map(fn: Callable[[Any], Any], items: Iterable[Any],
                 max_workers: Optional[int] = None,
                 window: Optional[int] = None) -> Iterator[Any]:
    """Yield ``fn(item)`` results as they complete, submission bounded to a
    sliding window (back-pressure and the effective concurrency limit: never
    more than ``window`` items in flight on the shared pool).  Results
    arrive unordered, on the calling thread — the consumer can materialize
    while the pool keeps fetching.  Worker exceptions propagate on yield;
    remaining futures are cancelled."""
    workers = resolve_io_threads(max_workers)
    if workers <= 1 or in_io_worker():
        for it in items:
            yield fn(it)
        return
    window = window or workers
    it = iter(items)
    ex = _shared_pool()
    inflight = set()
    def refill():
        nonlocal exhausted
        while not exhausted and len(inflight) < window:
            try:
                inflight.add(ex.submit(_tagged, fn, next(it)))
            except StopIteration:
                exhausted = True

    try:
        exhausted = False
        while True:
            refill()
            if not inflight:
                return
            done, inflight = wait(inflight, return_when=FIRST_COMPLETED)
            refill()      # keep workers busy while the consumer processes
            for f in done:
                yield f.result()
    finally:
        for f in inflight:
            f.cancel()
