"""Storage fabric — sharded, replicated, tiered chunk stores (DESIGN.md §12).

The chunk store interface (chunkstore.py) talks to *one* backend; serving a
fleet needs many backends behind that same interface.  This module composes
existing stores into a fabric:

  - ``ShardedStore``    — a consistent-hash ring over N child stores.  Chunk
                          keys are already uniform hashes, so the ring spreads
                          both capacity and *bandwidth*: scatter-gather
                          ``get_chunks``/``put_chunks`` group a plan by shard
                          and drive every shard concurrently
                          (``parallel.scatter_parallel``).  Reads that miss
                          the home shard sweep the others and heal placement
                          in passing — a ring change self-repairs on read.
  - ``ReplicatedStore`` — k-way replication: writes go to every replica,
                          reads are served by the first replica that has the
                          chunk and *read-repair* copies it back to the
                          replicas that missed, so a lost disk heals in place.
                          Only when every replica misses does the chunk count
                          as lost (-> DataRestorer fallback recomputation).
  - ``TieredStore``     — bounded in-memory hot tier over a cold backend:
                          writes go through to cold (durability) and prime
                          hot; reads promote; demotion is plain LRU eviction
                          (cold always holds the chunk).  This is the
                          per-*tier* generalization of the per-*session*
                          ChunkCache.

Topologies nest freely and are spelled as ``fabric://`` URIs understood by
``open_store`` (composable with ``?codec=``):

    fabric://shard(dir:///s0,dir:///s1,dir:///s2,dir:///s3)
    fabric://rep(dir:///a,dir:///b)
    fabric://tier(64M,sqlite:///cold.db)
    fabric://shard(rep(dir:///a0,dir:///a1),rep(dir:///b0,dir:///b1))?codec=auto

Fleet operations (CLI verbs ``topology`` / ``scrub`` / ``rebalance``) walk
the composition recursively: ``scrub`` finds (and with ``repair=True``
heals) replica-missing, misplaced, and content-corrupt chunks; ``rebalance``
moves chunks to their ring homes after a topology edit.
"""
from __future__ import annotations

import bisect
import hashlib
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core import parallel
from repro.core.chunkstore import (ChunkCache, ChunkStore, CompressedStore,
                                   FaultInjectedStore, NamespacedStore,
                                   chunk_key, open_store)
from repro.core.serialize import ChunkMissingError

DEFAULT_VNODES = 64


# ---------------------------------------------------------------------------
# consistent-hash ring
# ---------------------------------------------------------------------------

class HashRing:
    """Classic consistent hashing: every shard owns ``vnodes`` pseudo-random
    points on a 64-bit ring; a key belongs to the shard owning the first
    point at or after the key's hash.  Adding/removing one shard moves only
    ~1/N of the keys — the contract ``rebalance`` relies on."""

    def __init__(self, n_shards: int, vnodes: int = DEFAULT_VNODES):
        if n_shards < 1:
            raise ValueError("ring needs at least one shard")
        points: List[Tuple[int, int]] = []
        for s in range(n_shards):
            for v in range(vnodes):
                points.append((self._hash(f"{s}#{v}"), s))
        points.sort()
        self._hashes = [h for h, _ in points]
        self._shards = [s for _, s in points]
        self.n_shards = n_shards
        self.vnodes = vnodes

    @staticmethod
    def _hash(s: str) -> int:
        return int.from_bytes(
            hashlib.blake2b(s.encode(), digest_size=8).digest(), "big")

    def shard_for(self, key: str) -> int:
        i = bisect.bisect_right(self._hashes, self._hash(key))
        if i == len(self._hashes):
            i = 0
        return self._shards[i]


# ---------------------------------------------------------------------------
# sharded store
# ---------------------------------------------------------------------------

class ShardedStore(ChunkStore):
    """Consistent-hash ring over child stores with scatter-gather batched I/O.

    Chunks live on their ring home; metadata documents (commit graph, HEAD)
    are tiny and mirrored to *every* shard, so the graph stays readable with
    any single shard alive.  Reads that miss the home shard sweep the other
    shards — a chunk found astray (ring change, manual surgery) is served,
    copied home, and removed from the stray shard (incremental rebalance on
    read, counted in ``heals``)."""

    supports_parallel_get = True
    native_scatter = True       # get_chunks fans out across shards itself

    def __init__(self, shards: Sequence[ChunkStore], *,
                 vnodes: int = DEFAULT_VNODES):
        self.shards = list(shards)
        if not self.shards:
            raise ValueError("ShardedStore needs at least one shard")
        self.ring = HashRing(len(self.shards), vnodes)
        # slabs must be wide enough to give every shard work per scatter
        self.min_slab = len(self.shards) * max(
            getattr(s, "min_slab", 1) for s in self.shards)
        self.heals = 0

    def home(self, key: str) -> int:
        return self.ring.shard_for(key)

    def _group(self, keys: Iterable[str]) -> Dict[int, List[str]]:
        groups: Dict[int, List[str]] = {}
        for k in keys:
            groups.setdefault(self.home(k), []).append(k)
        return groups

    # ---- chunks ----
    def put_chunk(self, key, data):
        return self.shards[self.home(key)].put_chunk(key, data)

    def put_chunks(self, pairs):
        groups: Dict[int, List[Tuple[str, bytes]]] = {}
        for k, d in pairs:
            groups.setdefault(self.home(k), []).append((k, d))
        items = list(groups.items())
        written = parallel.scatter_parallel(
            lambda it: self.shards[it[0]].put_chunks(it[1]), items)
        return sum(written)

    def _heal(self, key: str, stray: int) -> None:
        """Move a stray chunk to its ring home — in its *stored* form, so a
        compressed chunk stays compressed across the move."""
        try:
            stored = self.shards[stray].get_chunk_stored(key)
        except ChunkMissingError:
            return
        self.shards[self.home(key)].put_chunk(key, stored)
        self.shards[stray].delete_chunk(key)
        self.heals += 1

    def get_chunk(self, key):
        home = self.home(key)
        try:
            return self.shards[home].get_chunk(key)
        except ChunkMissingError:
            pass
        for i, shard in enumerate(self.shards):
            if i == home:
                continue
            try:
                data = shard.get_chunk(key)
            except ChunkMissingError:
                continue
            self._heal(key, i)
            return data
        raise ChunkMissingError(key)

    def get_chunks(self, keys, *, missing_ok=False):
        uniq = list(dict.fromkeys(keys))
        groups = list(self._group(uniq).items())
        got: Dict[str, bytes] = {}
        for part in parallel.scatter_parallel(
                lambda it: self.shards[it[0]].get_chunks(it[1],
                                                         missing_ok=True),
                groups):
            got.update(part)
        missing = [k for k in uniq if k not in got]
        if missing:
            # stray sweep: ask every shard for the leftovers, heal hits home
            sweeps = parallel.scatter_parallel(
                lambda shard: shard.get_chunks(missing, missing_ok=True),
                self.shards)
            for i, part in enumerate(sweeps):
                for k, d in part.items():
                    if k not in got and i != self.home(k):
                        self._heal(k, i)
                    got.setdefault(k, d)
        if not missing_ok and len(got) != len(uniq):
            raise ChunkMissingError(next(k for k in uniq if k not in got))
        return got

    def get_chunk_stored(self, key):
        try:
            return self.shards[self.home(key)].get_chunk_stored(key)
        except ChunkMissingError:
            pass
        for i, shard in enumerate(self.shards):
            if i != self.home(key):
                try:
                    return shard.get_chunk_stored(key)
                except ChunkMissingError:
                    continue
        raise ChunkMissingError(key)

    def has_chunk(self, key):
        if self.shards[self.home(key)].has_chunk(key):
            return True
        return any(s.has_chunk(key) for s in self.shards)

    def list_chunk_keys(self):
        parts = parallel.scatter_parallel(
            lambda s: s.list_chunk_keys(), self.shards)
        return list(dict.fromkeys(k for part in parts for k in part))

    def chunk_sizes(self, keys):
        uniq = list(dict.fromkeys(keys))
        groups = list(self._group(uniq).items())
        out: Dict[str, int] = {}
        for part in parallel.scatter_parallel(
                lambda it: self.shards[it[0]].chunk_sizes(it[1]), groups):
            out.update(part)
        missing = [k for k in uniq if k not in out]
        if missing:
            for part in parallel.scatter_parallel(
                    lambda s: s.chunk_sizes(missing), self.shards):
                for k, n in part.items():
                    out.setdefault(k, n)
        return out

    def delete_chunk(self, key):
        # delete everywhere: strays (pre-rebalance copies) must die too
        for s in self.shards:
            s.delete_chunk(key)

    def delete_chunks(self, keys):
        keys = list(keys)
        removed = parallel.scatter_parallel(
            lambda s: s.delete_chunks(keys), self.shards)
        return sum(removed)

    # ---- meta: mirrored to every shard (small, and the graph must stay
    # readable no matter which single shard survives) ----
    def put_meta(self, name, doc):
        parallel.scatter_parallel(lambda s: s.put_meta(name, doc),
                                  self.shards)

    def put_meta_batch(self, docs):
        # one scatter, each shard applying its own atomic batch — the
        # commit engine's publish costs one round per shard, not one per
        # (doc x shard)
        parallel.scatter_parallel(lambda s: s.put_meta_batch(docs),
                                  self.shards)

    def get_meta(self, name):
        for s in self.shards:
            doc = s.get_meta(name)
            if doc is not None:
                return doc
        return None

    def list_meta(self, prefix):
        out = set()
        for s in self.shards:
            out.update(s.list_meta(prefix))
        return sorted(out)

    def delete_meta(self, name):
        # mirrored docs (journal seals, tombstone purges) die everywhere
        parallel.scatter_parallel(lambda s: s.delete_meta(name), self.shards)

    def delete_meta_batch(self, names):
        names = list(names)
        parallel.scatter_parallel(lambda s: s.delete_meta_batch(names),
                                  self.shards)

    # ---- stats ----
    def chunk_bytes_total(self):
        return sum(parallel.scatter_parallel(
            lambda s: s.chunk_bytes_total(), self.shards))

    def n_chunks(self):
        return sum(parallel.scatter_parallel(
            lambda s: s.n_chunks(), self.shards))


# ---------------------------------------------------------------------------
# replicated store
# ---------------------------------------------------------------------------

class ReplicatedStore(ChunkStore):
    """k-way replication with read-repair.

    Writes scatter to every replica; a write that lands on *any* replica is
    durable (per-replica write faults surface as read-repair work, not write
    errors).  Reads serve from the first replica holding the chunk and copy
    it back to the replicas before it that missed — losing a whole replica
    degrades one read per chunk, then heals.  A chunk absent from every
    replica raises ChunkMissingError, which upstream falls back to
    DataRestorer recomputation."""

    supports_parallel_get = True

    def __init__(self, replicas: Sequence[ChunkStore]):
        self.replicas = list(replicas)
        if not self.replicas:
            raise ValueError("ReplicatedStore needs at least one replica")
        self.min_slab = max(getattr(r, "min_slab", 1) for r in self.replicas)
        self.repairs = 0          # chunk copies healed onto a lagging replica
        self.replica_misses = 0   # reads not served by the primary
        self.write_errors = 0     # per-replica write faults absorbed

    def _scatter_writes(self, fn):
        """Run a write against every replica; a write that lands on *any*
        replica is durable, so per-replica faults (full/read-only disk) are
        absorbed — the lagging replica heals via read-repair/scrub — and
        only an all-replicas failure raises."""
        def safe(r):
            try:
                return fn(r)
            except Exception as e:  # noqa: BLE001 — dead replica
                return e
        results = parallel.scatter_parallel(safe, self.replicas)
        errors = [r for r in results if isinstance(r, Exception)]
        self.write_errors += len(errors)
        if len(errors) == len(results):
            raise errors[0]
        return [r for r in results if not isinstance(r, Exception)]

    # ---- chunks ----
    def put_chunk(self, key, data):
        return bool(self._scatter_writes(
            lambda r: r.put_chunk(key, data))[0])

    def put_chunks(self, pairs):
        pairs = list(pairs)
        return self._scatter_writes(lambda r: r.put_chunks(pairs))[0]

    def _repair(self, key: str, served_by: int) -> None:
        """Copy ``key`` onto replicas [0, served_by) that just missed it —
        in its *stored* form, so compression survives the repair."""
        try:
            stored = self.replicas[served_by].get_chunk_stored(key)
        except ChunkMissingError:
            return
        for r in self.replicas[:served_by]:
            try:
                if r.put_chunk(key, stored):
                    self.repairs += 1
            except Exception:  # noqa: BLE001 — dead replica: heal later
                pass

    def get_chunk(self, key):
        for i, r in enumerate(self.replicas):
            try:
                data = r.get_chunk(key)
            except ChunkMissingError:
                continue
            if i > 0:
                self.replica_misses += 1
                self._repair(key, i)
            return data
        raise ChunkMissingError(key)

    def get_chunk_stored(self, key):
        for r in self.replicas:
            try:
                return r.get_chunk_stored(key)
            except ChunkMissingError:
                continue
        raise ChunkMissingError(key)

    def get_chunks(self, keys, *, missing_ok=False):
        uniq = list(dict.fromkeys(keys))
        got: Dict[str, bytes] = {}
        missing = uniq
        for i, r in enumerate(self.replicas):
            if not missing:
                break
            try:
                part = r.get_chunks(missing, missing_ok=True)
            except ChunkMissingError:   # fault-wrapped replica: all lost
                part = {}
            if i > 0 and part:
                self.replica_misses += len(part)
                for k in part:
                    self._repair(k, i)
            got.update(part)
            missing = [k for k in missing if k not in got]
        if missing and not missing_ok:
            raise ChunkMissingError(missing[0])
        return got

    def has_chunk(self, key):
        return any(r.has_chunk(key) for r in self.replicas)

    def list_chunk_keys(self):
        parts = parallel.scatter_parallel(
            lambda r: r.list_chunk_keys(), self.replicas)
        return list(dict.fromkeys(k for part in parts for k in part))

    def chunk_sizes(self, keys):
        uniq = list(dict.fromkeys(keys))
        out: Dict[str, int] = {}
        missing = uniq
        for r in self.replicas:
            if not missing:
                break
            for k, n in r.chunk_sizes(missing).items():
                out.setdefault(k, n)
            missing = [k for k in missing if k not in out]
        return out

    def delete_chunk(self, key):
        for r in self.replicas:
            r.delete_chunk(key)

    def delete_chunks(self, keys):
        keys = list(keys)
        removed = parallel.scatter_parallel(
            lambda r: r.delete_chunks(keys), self.replicas)
        return max(removed) if removed else 0

    # ---- meta ----
    def put_meta(self, name, doc):
        parallel.scatter_parallel(lambda r: r.put_meta(name, doc),
                                  self.replicas)

    def put_meta_batch(self, docs):
        parallel.scatter_parallel(lambda r: r.put_meta_batch(docs),
                                  self.replicas)

    def get_meta(self, name):
        for r in self.replicas:
            doc = r.get_meta(name)
            if doc is not None:
                return doc
        return None

    def list_meta(self, prefix):
        out = set()
        for r in self.replicas:
            out.update(r.list_meta(prefix))
        return sorted(out)

    def delete_meta(self, name):
        parallel.scatter_parallel(lambda r: r.delete_meta(name),
                                  self.replicas)

    def delete_meta_batch(self, names):
        names = list(names)
        parallel.scatter_parallel(lambda r: r.delete_meta_batch(names),
                                  self.replicas)

    # ---- stats: logical (max across replicas), not physical sum ----
    def chunk_bytes_total(self):
        return max(parallel.scatter_parallel(
            lambda r: r.chunk_bytes_total(), self.replicas))

    def n_chunks(self):
        return max(parallel.scatter_parallel(
            lambda r: r.n_chunks(), self.replicas))


# ---------------------------------------------------------------------------
# tiered store
# ---------------------------------------------------------------------------

class TieredStore(ChunkStore):
    """Bounded in-memory hot tier over a cold backend.

    Write-through: every put lands on cold (durability) and primes hot.
    Reads promote on miss; demotion is LRU eviction out of the bounded hot
    tier — cold always holds the chunk, so demotion is a drop, never a
    write-back.  The hot tier holds *logical* (decoded) bytes, so a hit
    skips both the backend round-trip and the codec."""

    def __init__(self, cold: ChunkStore, *, hot_bytes: Optional[int] = None):
        from repro.core.chunkstore import decode_chunk
        self._decode = decode_chunk
        self.cold = cold
        self.hot = ChunkCache(hot_bytes)
        self.min_slab = getattr(cold, "min_slab", 1)
        self.supports_parallel_get = getattr(cold, "supports_parallel_get",
                                             True)
        self.native_scatter = getattr(cold, "native_scatter", False)

    # ---- chunks ----
    def put_chunk(self, key, data):
        wrote = self.cold.put_chunk(key, data)
        self.hot.put(key, self._decode(bytes(data)))
        return wrote

    def put_chunks(self, pairs):
        pairs = list(pairs)
        written = self.cold.put_chunks(pairs)
        for k, d in pairs:
            self.hot.put(k, self._decode(bytes(d)))
        return written

    def get_chunk(self, key):
        data = self.hot.get(key)
        if data is not None:
            return data
        data = self.cold.get_chunk(key)
        self.hot.put(key, data)                      # promotion
        return data

    def get_chunk_stored(self, key):
        return self.cold.get_chunk_stored(key)

    def get_chunks(self, keys, *, missing_ok=False):
        uniq = list(dict.fromkeys(keys))
        got = self.hot.get_many(uniq)
        missing = [k for k in uniq if k not in got]
        if missing:
            cold = self.cold.get_chunks(missing, missing_ok=missing_ok)
            self.hot.put_many(cold)
            got.update(cold)
        return got

    def has_chunk(self, key):
        return self.hot.get(key) is not None or self.cold.has_chunk(key)

    def list_chunk_keys(self):
        return self.cold.list_chunk_keys()

    def chunk_sizes(self, keys):
        return self.cold.chunk_sizes(keys)

    def delete_chunk(self, key):
        self.hot.discard(key)
        self.cold.delete_chunk(key)

    def delete_chunks(self, keys):
        keys = list(keys)
        for k in keys:
            self.hot.discard(k)
        return self.cold.delete_chunks(keys)

    # ---- meta / stats: cold is the source of truth ----
    def put_meta(self, name, doc):
        self.cold.put_meta(name, doc)

    def put_meta_batch(self, docs):
        self.cold.put_meta_batch(docs)

    def get_meta(self, name):
        return self.cold.get_meta(name)

    def list_meta(self, prefix):
        return self.cold.list_meta(prefix)

    def delete_meta(self, name):
        self.cold.delete_meta(name)

    def delete_meta_batch(self, names):
        self.cold.delete_meta_batch(names)

    def chunk_bytes_total(self):
        return self.cold.chunk_bytes_total()

    def n_chunks(self):
        return self.cold.n_chunks()


# ---------------------------------------------------------------------------
# fabric:// topology specs
# ---------------------------------------------------------------------------

_SIZE_SUFFIX = {"K": 1 << 10, "M": 1 << 20, "G": 1 << 30}


def parse_size(s: str) -> int:
    """``64M`` / ``1G`` / ``4096`` -> bytes."""
    s = s.strip()
    mult = _SIZE_SUFFIX.get(s[-1:].upper())
    if mult is not None:
        s = s[:-1]
    try:
        return int(s) * (mult or 1)
    except ValueError:
        raise ValueError(f"bad size spec {s!r} (want e.g. 64M, 1G, 4096)")


def _split_top(spec: str) -> List[str]:
    """Split on commas at paren depth 0."""
    parts, depth, cur = [], 0, []
    for ch in spec:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth < 0:
                raise ValueError(f"unbalanced parens in topology {spec!r}")
        if ch == "," and depth == 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if depth != 0:
        raise ValueError(f"unbalanced parens in topology {spec!r}")
    parts.append("".join(cur))
    return [p.strip() for p in parts if p.strip()]


def parse_topology(spec: str) -> ChunkStore:
    """Recursive ``fabric://`` topology grammar:

        expr  := 'shard(' expr {',' expr} ')'
               | 'rep(' expr {',' expr} ')'
               | 'tier(' SIZE ',' expr ')'
               | leaf store URI (memory:// | dir://path | sqlite://path | path)
    """
    spec = spec.strip()
    for comb in ("shard", "rep", "tier"):
        if spec.startswith(comb + "(") and spec.endswith(")"):
            parts = _split_top(spec[len(comb) + 1:-1])
            if comb == "tier":
                if len(parts) != 2:
                    raise ValueError(
                        f"tier(SIZE,COLD) takes exactly 2 args: {spec!r}")
                return TieredStore(parse_topology(parts[1]),
                                   hot_bytes=parse_size(parts[0]))
            if not parts:
                raise ValueError(f"{comb}() needs at least one child: "
                                 f"{spec!r}")
            children = [parse_topology(p) for p in parts]
            if comb == "shard":
                return ShardedStore(children)
            return ReplicatedStore(children)
    # leaf URI — a combinator typo must not silently become a directory path
    if any(ch in spec for ch in "(),"):
        raise ValueError(f"malformed topology spec {spec!r} "
                         "(want shard(...)/rep(...)/tier(...) or a store "
                         "URI)")
    return open_store(spec)


# ---------------------------------------------------------------------------
# fleet ops: topology / scrub / rebalance
# ---------------------------------------------------------------------------

def topology_lines(store: ChunkStore, indent: str = "") -> List[str]:
    """Human-readable tree of a store composition (CLI ``topology``)."""
    bump = indent + "  "
    if isinstance(store, ShardedStore):
        out = [f"{indent}shard(n={len(store.shards)}, "
               f"vnodes={store.ring.vnodes})"]
        for s in store.shards:
            out += topology_lines(s, bump)
        return out
    if isinstance(store, ReplicatedStore):
        out = [f"{indent}rep(k={len(store.replicas)})"]
        for r in store.replicas:
            out += topology_lines(r, bump)
        return out
    if isinstance(store, TieredStore):
        out = [f"{indent}tier(hot={store.hot.max_bytes})"]
        return out + topology_lines(store.cold, bump)
    if isinstance(store, CompressedStore):
        name = store.codec.name if store.codec else "raw"
        return [f"{indent}codec({name})"] + topology_lines(store.inner, bump)
    if isinstance(store, FaultInjectedStore):
        return [f"{indent}fault-injected"] + topology_lines(store.inner, bump)
    if isinstance(store, NamespacedStore):
        return ([f"{indent}tenant({store.tenant_id})"]
                + topology_lines(store.inner, bump))
    root = getattr(store, "root", None) or getattr(store, "path", None)
    kind = type(store).__name__
    return [f"{indent}{kind}({root})" if root else f"{indent}{kind}"]


@dataclass
class ScrubReport:
    chunks_checked: int = 0
    replica_missing: int = 0    # (chunk, replica) pairs absent
    misplaced: int = 0          # chunks off their ring home
    corrupt: int = 0            # content-address mismatches (deep only)
    repaired: int = 0
    details: List[str] = field(default_factory=list)

    @property
    def problems(self) -> int:
        return self.replica_missing + self.misplaced + self.corrupt

    @property
    def remaining(self) -> int:
        return max(0, self.problems - self.repaired)


def _scrub_replicated(store: ReplicatedStore, repair: bool,
                      report: ScrubReport) -> None:
    union = store.list_chunk_keys()
    per_replica = parallel.scatter_parallel(
        lambda r: set(r.list_chunk_keys()), store.replicas)
    for i, have in enumerate(per_replica):
        lost = [k for k in union if k not in have]
        report.replica_missing += len(lost)
        for k in lost:
            report.details.append(f"replica {i} missing {k}")
        if repair and lost:
            for k in lost:
                stored = None
                for j, src in enumerate(store.replicas):
                    if j == i:
                        continue
                    try:        # stored form: compression survives the copy
                        stored = src.get_chunk_stored(k)
                        break
                    except ChunkMissingError:
                        continue
                if stored is None:
                    continue                    # lost everywhere: not ours
                store.replicas[i].put_chunk(k, stored)
                if store.replicas[i].has_chunk(k):
                    report.repaired += 1


def _scrub_sharded(store: ShardedStore, repair: bool,
                   report: ScrubReport) -> None:
    per_shard = parallel.scatter_parallel(
        lambda s: s.list_chunk_keys(), store.shards)
    for i, keys in enumerate(per_shard):
        astray = [k for k in keys if store.home(k) != i]
        report.misplaced += len(astray)
        for k in astray:
            report.details.append(f"shard {i} holds stray {k} "
                                  f"(home {store.home(k)})")
        if repair:
            for k in astray:
                try:        # stored form: compression survives the move
                    stored = store.shards[i].get_chunk_stored(k)
                except ChunkMissingError:
                    continue
                store.shards[store.home(k)].put_chunk(k, stored)
                store.shards[i].delete_chunk(k)
                report.repaired += 1


def _scrub_leaf_deep(store: ChunkStore, report: ScrubReport) -> None:
    keys = store.list_chunk_keys()
    for got in parallel.prefetch_map(
            lambda slab: store.get_chunks(slab, missing_ok=True),
            parallel.iter_slabs(keys, max(getattr(store, "min_slab", 1),
                                          32))):
        for k, data in got.items():
            if chunk_key(data) != k:
                report.corrupt += 1
                report.details.append(f"corrupt {k}")


def _scrub_walk(store: ChunkStore, repair: bool, deep: bool,
                report: ScrubReport) -> None:
    if isinstance(store, ReplicatedStore):
        _scrub_replicated(store, repair, report)
        for r in store.replicas:
            _scrub_walk(r, repair, deep, report)
    elif isinstance(store, ShardedStore):
        _scrub_sharded(store, repair, report)
        for s in store.shards:
            _scrub_walk(s, repair, deep, report)
    elif isinstance(store, TieredStore):
        _scrub_walk(store.cold, repair, deep, report)
    elif isinstance(store, (CompressedStore, FaultInjectedStore,
                            NamespacedStore)):
        _scrub_walk(store.inner, repair, deep, report)
    elif deep:
        _scrub_leaf_deep(store, report)


def scrub(store: ChunkStore, *, repair: bool = False,
          deep: bool = False) -> ScrubReport:
    """Walk a store composition checking fabric invariants.

    Replica sets: every replica holds every chunk (``repair`` copies from a
    live replica, in stored form).  Shard rings: every chunk sits on its
    ring home (``repair`` moves strays home).  With ``deep``, leaf stores
    are also content-address-verified (corruption is reported, not
    repaired — the healthy copy, if any, lives in an enclosing replica
    set).  ``chunks_checked`` reports *logical* chunks (counted once at the
    top of the composition, however many physical copies exist below)."""
    report = ScrubReport()
    _scrub_walk(store, repair, deep, report)
    report.chunks_checked = len(store.list_chunk_keys())
    return report


def rebalance(store: ChunkStore) -> Dict[str, int]:
    """Move every chunk of every shard ring in the composition to its ring
    home — run after editing a ``fabric://shard(...)`` spec (the ring is
    derived from the shard list, so adding/removing/reordering shards
    reassigns ~1/N of the keys).  Reads already self-heal strays one at a
    time; rebalance does the whole fleet in one pass."""
    moved = checked = 0

    def walk(s: ChunkStore) -> None:
        nonlocal moved, checked
        if isinstance(s, ShardedStore):
            rep = ScrubReport()
            _scrub_sharded(s, True, rep)
            moved += rep.repaired
            checked += len(s.list_chunk_keys())
            for child in s.shards:
                walk(child)
        elif isinstance(s, ReplicatedStore):
            for child in s.replicas:
                walk(child)
        elif isinstance(s, TieredStore):
            walk(s.cold)
        elif isinstance(s, (CompressedStore, FaultInjectedStore,
                            NamespacedStore)):
            walk(s.inner)

    walk(store)
    return {"chunks_checked": checked, "chunks_moved": moved}
