"""KishuSession — the public time-traveling API (§3).

    session = KishuSession(store)
    session.register("train", train_command)
    session.init_state({...})                 # attach
    session.run("train", steps=10)            # cell execution + incr. ckpt
    session.log()                             # inspect the Checkpoint Graph
    session.checkout("c00003")                # incremental checkout (undo /
                                              #  branch switch)

Each ``run`` executes a registered command against the tracked namespace,
detects the co-variable-granularity state delta (Lemma-1-pruned), writes an
incremental checkpoint, and appends a commit to the Checkpoint Graph.
``checkout`` restores any past state by loading only diverged co-variables,
with recursive fallback recomputation for missing data.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

from repro.core import delta as delta_mod
from repro.core import hashing
from repro.core.checkpoint import CheckpointWriter, WriteStats
from repro.core.checkout import CheckoutStats, StateLoader
from repro.core.chunkstore import ChunkCache, ChunkStore, NamespacedStore
from repro.core.covariable import (CovKey, RecordBuilder, StateDelta,
                                   detect_delta, group_covariables)
from repro.core.graph import (CheckpointGraph, key_str,
                              manifest_chunk_entries)
from repro.core.lease import Lease
from repro.core.namespace import Namespace, TrackedNamespace
from repro.core.restore import DataRestorer
from repro.core.txn import TxnEngine, global_live_chunks
from repro.core.txn import purge_tombstones as txn_purge_tombstones
from repro.obs import TRACE_META_PREFIX, SessionObs


class QuotaExceededError(RuntimeError):
    """A commit would push the tenant's referenced bytes past its quota.
    The cell has already executed (the namespace is mutated) but nothing
    was committed; chunks staged for the rejected commit surface as
    dangling and are reclaimed by the next ``gc()``."""


@dataclass
class RunStats:
    commit_id: str = ""
    exec_s: float = 0.0
    detect_s: float = 0.0
    write_s: float = 0.0
    total_s: float = 0.0
    covs_updated: int = 0
    covs_deleted: int = 0
    covs_checked: int = 0
    covs_skipped: int = 0
    write: WriteStats = field(default_factory=WriteStats)


@dataclass
class _RunPlan:
    """Output of the *plan* stage of a run: the executed cell's detected
    delta plus everything the *execute* (commit) stage needs."""
    name: str
    args: dict
    delta: StateDelta
    deps: Dict[CovKey, str]
    stats: RunStats
    t_all: float
    fb0: int = 0                     # kernel-fallback counter at plan start


class KishuSession:
    def __init__(self, store: ChunkStore, *,
                 chunk_bytes: int = hashing.DEFAULT_CHUNK_BYTES,
                 async_write: bool = False,
                 write_deadline_s: float = 0.0,
                 check_all: bool = False,
                 hasher=None,
                 io_threads: Optional[int] = None,
                 cache_bytes: Optional[int] = None,
                 group_commit_n: int = 1,
                 async_publish: bool = False,
                 tenant: Optional[str] = None,
                 quota_bytes: Optional[int] = None,
                 lease_ttl_s: Optional[float] = None,
                 lease_wait_s: float = 0.0,
                 lease_steal: bool = False,
                 chunk_cache: Optional[ChunkCache] = None,
                 trace: Optional[bool] = None,
                 plan_mode: Optional[str] = None):
        # multi-session knobs (DESIGN.md §14):
        #   tenant       — scope this session to `tenant/<id>/` metadata on
        #                  the shared store (chunks stay shared/deduped)
        #   quota_bytes  — refuse commits once the tenant's referenced
        #                  bytes pass this (QuotaExceededError)
        #   lease_ttl_s  — acquire the namespace's writer lease before
        #                  opening the graph; None (default) runs
        #                  lease-less with only the HEAD-seq guard, which
        #                  keeps single-writer usage zero-cost
        #   chunk_cache  — share one cache across sessions (kishud)
        #   trace        — pipeline span tracing (DESIGN.md §16); None
        #                  defers to $KISHU_TRACE, default off
        #   plan_mode    — cost-based checkout planner (DESIGN.md §18):
        #                  off/auto/fetch/replay; None defers to
        #                  $KISHU_PLANNER, default off
        from repro.obs.instrument import InstrumentedStore

        if tenant is not None and not isinstance(store, NamespacedStore):
            store = NamespacedStore(store, tenant)
        self.tenant = getattr(store, "tenant_id", None)
        # observability plane (DESIGN.md §16): per-session tracer + metrics.
        # The InstrumentedStore sits INSIDE the namespace view — the txn
        # engine's isinstance(NamespacedStore) unwrapping and meta-prefix
        # handling must keep seeing the view as the outermost layer.
        self.obs = SessionObs(trace=trace, tenant=self.tenant)
        if isinstance(store, NamespacedStore):
            inner = store.root_store
            if not isinstance(inner, InstrumentedStore):
                store = NamespacedStore(
                    InstrumentedStore(inner, self.obs.registry),
                    store.tenant_id)
        elif not isinstance(store, InstrumentedStore):
            store = InstrumentedStore(store, self.obs.registry)
        self.store = store
        self.quota_bytes = quota_bytes
        # the lease is taken BEFORE recovery/graph construction: rolling
        # back a journal requires proving its writer is gone, and holding
        # the namespace's writer lease is exactly that proof
        self.lease: Optional[Lease] = None
        if lease_ttl_s is not None:
            self.lease = Lease(store, ttl_s=lease_ttl_s, obs=self.obs
                               ).acquire(wait_s=lease_wait_s,
                                         steal=lease_steal)
        self.ns = Namespace()
        self.tracked = TrackedNamespace(self.ns)
        self.builder = RecordBuilder(chunk_bytes, hasher=hasher)
        # one chunk cache shared by writer and loader: checking out a
        # just-committed state is served from memory, not the backend
        # (cache_bytes=0 disables; default $KISHU_CACHE_BYTES or 64 MiB)
        self.chunk_cache = chunk_cache or ChunkCache(cache_bytes)
        self.writer = CheckpointWriter(store, chunk_bytes=chunk_bytes,
                                       async_write=async_write,
                                       write_deadline_s=write_deadline_s,
                                       cache=self.chunk_cache)
        # transactional commit engine (DESIGN.md §13): every commit is a
        # journaled transaction — WAL, chunk puts, epoch fence, atomic
        # multi-meta publish, seal.  group_commit_n > 1 batches consecutive
        # cells' metadata into one publish (crash loses at most the last
        # n-1 cells, never tears state); async_publish hides the publish
        # behind the next cell's think time.
        # a write deadline bounds the publish fence too: the straggler
        # feature's contract is that a slow host delays durability, not
        # the cell loop — a commit published past the deadline references
        # still-pending chunks, and checkout of those falls back to
        # recomputation exactly as before the engine existed
        fence_timeout = write_deadline_s or None
        self.engine = TxnEngine(store, group_n=group_commit_n,
                                async_publish=async_publish,
                                fence=(lambda token: self.writer.wait_epoch(
                                    token, timeout=fence_timeout)),
                                fence_token=self.writer.epoch,
                                # sync writer journals a commit's chunks
                                # before commit() returns, so groups can
                                # detach at kick time; the async drain
                                # journals with a lag the fence bounds
                                early_snapshot=not async_write)
        self.engine.lease = self.lease    # checked/renewed on every publish
        self.writer.journal = self.engine.journal_chunks
        # worker threads (async drain, publish worker) don't inherit the
        # activation contextvar — they report through these handles instead
        self.writer.obs = self.obs
        self.engine.obs = self.obs
        # graph open runs txn.recover first: a crashed predecessor's
        # unsealed transactions are replayed or rolled back before loading
        # (activated so recovery counters attribute to this session)
        with self.obs.activate():
            self.graph = CheckpointGraph(store, engine=self.engine)
        self.registry: Dict[str, Callable] = {}
        self._replay_unsafe: set = set()   # register(replay_safe=False)
        self.records: Dict[str, Any] = {}
        self.covs: Dict[CovKey, List[str]] = {}
        self.check_all = check_all      # AblatedKishu(Check all) mode (§7.6)
        self.last_run: Optional[RunStats] = None
        self.last_checkout: Optional[CheckoutStats] = None

        self.loader = StateLoader(self.graph, store, io_threads=io_threads,
                                  cache=self.chunk_cache)
        self.loader.obs = self.obs
        self.restorer = DataRestorer(self.graph, self.loader, self.registry)
        self.loader.fallback = self.restorer.recompute
        # cost-based checkout planner (DESIGN.md §18): prices fetch vs
        # replay vs patch per co-variable from the obs registry's store
        # metrics + persisted exec_s; off keeps the fixed fallback ladder
        from repro.core.planner import CheckoutPlanner, resolve_plan_mode
        self.plan_mode = resolve_plan_mode(plan_mode)
        self.planner = CheckoutPlanner(
            self.graph, self.loader, commands=self.registry,
            unsafe=self._replay_unsafe, mode=self.plan_mode,
            cache=self.chunk_cache, obs=self.obs,
            max_depth=self.restorer.max_depth)
        if self.planner.engaged:
            self.loader.planner = self.planner
        # live cache gauges: this session's view of its (possibly shared)
        # chunk cache — kishud disambiguates by tenant const-label
        reg = self.obs.registry
        reg.gauge("kishu_cache_hits_total", fn=lambda: self.chunk_cache.hits)
        reg.gauge("kishu_cache_misses_total",
                  fn=lambda: self.chunk_cache.misses)
        reg.gauge("kishu_cache_bytes", fn=lambda: self.chunk_cache.bytes_used)

        if not self.graph.nodes:
            self.graph.init_root()

    # ------------------------------------------------------------------
    # attachment & commands
    # ------------------------------------------------------------------
    def register(self, name: str, fn: Callable, *,
                 replay_safe: bool = True) -> None:
        """Register a cell command.  ``replay_safe=False`` marks commands
        the planner must never choose to re-run (external side effects,
        non-deterministic inputs outside the namespace); the flag is
        persisted per commit so it survives into other sessions' plans."""
        self.registry[name] = fn
        if replay_safe:
            self._replay_unsafe.discard(name)
        else:
            self._replay_unsafe.add(name)

    def init_state(self, tree: Dict[str, Any], message: str = "attach") -> str:
        """Attach: populate the namespace and commit the initial state."""
        def _init(ns, **_):
            for prefix, sub in tree.items():
                if isinstance(sub, dict):
                    ns.set_tree(prefix, sub)
                else:
                    ns[prefix] = sub
        self.register("__attach__", _init)
        return self.run("__attach__", _message=message)

    @property
    def head(self) -> str:
        return self.graph.head

    # ------------------------------------------------------------------
    # cell execution + incremental checkpoint
    # ------------------------------------------------------------------
    def run(self, command: str, _message: str = "", **args) -> str:
        """Cell execution + incremental checkpoint, split into a *plan*
        stage (execute the cell, detect the state delta) and an *execute*
        stage (write chunks, commit through the transaction engine).  With
        ``async_publish`` the previous commit's metadata publish overlaps
        this cell's plan stage — the engine fences chunk durability on its
        own thread, so the cell loop never waits on the store's metadata
        round-trips."""
        with self.obs.activate(), self.obs.span("commit", command=command):
            plan = self._plan_run(command, args)
            return self._execute_commit(plan, _message)

    def _plan_run(self, name: str, args: dict) -> "_RunPlan":
        """Stage 1: run the cell against the tracked namespace and detect
        the co-variable-granularity delta (Lemma-1-pruned).  Touches no
        storage — everything durable happens in :meth:`_execute_commit`."""
        fn = self.registry[name]
        stats = RunStats()
        t_all = time.perf_counter()
        fb0 = delta_mod.kernel_fallbacks()

        self.tracked.reset()
        t0 = time.perf_counter()
        with self.obs.span("exec"):
            fn(self.tracked, **args)
        stats.exec_s = time.perf_counter() - t0

        accessed = (set(self.tracked.accessed) | set(self.tracked.written)
                    | set(self.tracked.deleted))
        if self.check_all:
            accessed = set(self.records) | set(self.ns.names())

        t0 = time.perf_counter()
        with self.obs.span("detect"):
            delta, self.records = detect_delta(self.records, self.covs,
                                               self.ns, accessed,
                                               self.builder)
            self.covs = group_covariables(self.records)
        stats.detect_s = time.perf_counter() - t0

        # dependencies: co-variables the cell *read* (or deleted — replay
        # must be able to `del` them), at their pre-execution versions.
        # Purely-overwritten co-variables are excluded: their pre-image is
        # dead weight a replay would otherwise have to restore first, which
        # is what makes recompute priceable against fetch (DESIGN.md §18).
        dep_names = set(self.tracked.read) | set(self.tracked.deleted)
        if self.check_all:
            dep_names |= accessed
        prev_index = self.graph.nodes[self.graph.head].state_index
        deps = {}
        for key in delta.candidates:
            ver = prev_index.get(key_str(key))
            if ver is not None and any(n in dep_names for n in key):
                deps[key] = ver
        return _RunPlan(name=name, args=args, delta=delta, deps=deps,
                        stats=stats, t_all=t_all, fb0=fb0)

    def _execute_commit(self, plan: "_RunPlan", message: str = "") -> str:
        """Stage 2: serialize the delta's dirty ranges into journaled chunk
        puts and append the commit to the Checkpoint Graph through the
        transaction engine (WAL ⟶ chunk puts ⟶ fence ⟶ atomic publish ⟶
        seal)."""
        delta, stats = plan.delta, plan.stats
        t0 = time.perf_counter()
        manifests, wstats = self.writer.write_delta(
            delta, self.ns, self._prev_manifest, packs=self.builder.packs)
        stats.write_s = time.perf_counter() - t0
        # degradations anywhere in this run — detection (plan) or write
        wstats.kernel_fallbacks = delta_mod.kernel_fallbacks() - plan.fb0
        stats.write = wstats

        if self.quota_bytes is not None:
            self._check_quota(manifests)
        node = self.graph.commit(
            command={"name": plan.name, "args": plan.args},
            manifests=manifests,
            deleted_keys=delta.deleted,
            accessed=plan.deps,
            updated_keys=list(delta.updated),
            message=message,
            stats={"bytes_written": wstats.bytes_written,
                   "bytes_serialized": wstats.bytes_serialized,
                   "bytes_logical": wstats.bytes_logical,
                   "chunks_written": wstats.chunks_written,
                   "chunks_reused": wstats.chunks_reused,
                   "chunks_encoded": wstats.chunks_encoded,
                   "chunks_codec_skipped": wstats.chunks_codec_skipped,
                   "bytes_dev2host": wstats.bytes_dev2host,
                   "exec_s": stats.exec_s,
                   "replay_safe": plan.name not in self._replay_unsafe})
        stats.commit_id = node.commit_id
        stats.covs_updated = len(delta.updated)
        stats.covs_deleted = len(delta.deleted)
        stats.covs_checked = delta.checked
        stats.covs_skipped = delta.skipped
        stats.total_s = time.perf_counter() - plan.t_all
        self.last_run = stats
        return node.commit_id

    def _check_quota(self, manifests: Dict[str, dict]) -> None:
        """Enforce the tenant byte quota *before* the commit publishes:
        current referenced bytes (from the refcount ledger) plus the bytes
        this commit would newly reference.  Chunks already counted by this
        namespace add nothing — quota follows references, like the ledger."""
        new_bytes = 0
        seen = set()
        for key, nbytes in manifest_chunk_entries(manifests):
            if key in seen or key in self.graph.refs.counts:
                continue
            seen.add(key)
            new_bytes += nbytes
        used = self.graph.refs.bytes_live()
        if used + new_bytes > self.quota_bytes:
            raise QuotaExceededError(
                f"tenant {self.tenant or '<root>'}: commit would reference "
                f"{used + new_bytes} bytes > quota {self.quota_bytes} "
                f"(currently {used}); delete branches and gc(), or raise "
                f"the quota")

    def _prev_manifest(self, key: CovKey) -> Optional[dict]:
        ver = self.graph.nodes[self.graph.head].state_index.get(key_str(key))
        if ver is None:
            return None
        return self.graph.manifest_of(key, ver)

    # ------------------------------------------------------------------
    # incremental checkout
    # ------------------------------------------------------------------
    def checkout(self, commit_id: str) -> CheckoutStats:
        with self.obs.activate(), self.obs.span("checkout",
                                                commit=commit_id):
            self.writer.flush()
            self.engine.flush()  # pending publishes land before time travel
            self.restorer.clear_memo()
            self.records, stats = self.loader.checkout(
                self.tracked, self.records, commit_id)
            self.covs = group_covariables(self.records)
        self.last_checkout = stats
        return stats

    def plan(self, commit_id: str):
        """Price a checkout of ``commit_id`` without executing it: the
        :class:`~repro.core.planner.PricedPlan` behind ``kishu plan``.
        Pending commits are flushed first so the plan sees the same graph
        a checkout would."""
        from repro.core.planner import PricedPlan  # noqa: F401 (re-export)
        with self.obs.activate(), self.obs.span("plan", commit=commit_id):
            self.writer.flush()
            self.engine.flush()
            return self.planner.price_checkout(
                self.graph.head, commit_id, records=self.records, ns=self.ns)

    # ------------------------------------------------------------------
    # introspection & maintenance
    # ------------------------------------------------------------------
    def log(self, limit: int = 0) -> List[dict]:
        return self.graph.log(limit)

    def diff(self, a: str, b: str) -> dict:
        """Human-oriented state diff between two commits: which co-variables
        diverged / exist only on one side (Def 6 over the graph index)."""
        plan = self.graph.diff(a, b)
        return {"diverged": sorted("+".join(k) for k in plan.to_load),
                "only_in_a": sorted("+".join(k) for k in plan.to_delete),
                "identical": len(plan.identical)}

    def delete_branch(self, tip: str) -> List[str]:
        """Delete the commits exclusive to ``tip``'s branch (up to but not
        including the first ancestor with another child or the HEAD path).
        Returns deleted commit ids. Run ``gc()`` afterwards to reclaim
        chunks."""
        assert tip != self.graph.head, "cannot delete the current branch"
        self.engine.flush()     # a queued publish must not resurrect a
                                # commit tombstoned below
        doomed = []
        node = self.graph.nodes[tip]
        while node.parent is not None:
            siblings = self.graph.children.get(node.parent, [])
            doomed.append(node.commit_id)
            if len(siblings) > 1 or node.parent == self.graph.head:
                break
            node = self.graph.nodes[node.parent]
        head_path = set(self.graph.path_from_root(self.graph.head))
        doomed = [c for c in doomed if c not in head_path]
        if not doomed:
            return doomed
        for cid in doomed:
            self.graph.forget(cid)      # updates in-memory refcounts too
        # tombstones + the decremented refcount ledger land in ONE batch:
        # a crash between them could otherwise leave counts claiming
        # chunks that no commit references (or vice versa)
        from repro.core.graph import REFS_DOC
        batch = {f"commit/{cid}": {"deleted": True} for cid in doomed}
        batch[REFS_DOC] = self.graph.refs.to_doc()
        self.store.put_meta_batch(batch)
        return doomed

    def gc(self) -> dict:
        """Content-addressed garbage collection: drop chunks referenced by
        no live manifest (after branch deletion / history truncation), and
        purge ``delete_branch`` tombstone metadata docs — without the purge
        every subsequent ``_load`` re-reads dead ``{"deleted": True}``
        markers forever.  Enumerates through ``list_chunk_keys()`` and
        deletes through the batched ``delete_chunks()`` — so every backend
        (single-file SQLite, sharded/replicated fabrics) reclaims space,
        and a fabric sweeps all its shards and replicas, strays included."""
        self.writer.flush()
        self.engine.flush()     # unpublished manifests must be visible to
                                # fsck/other readers before their chunks
                                # are judged live
        # the mark set is CROSS-SESSION: this graph's references plus every
        # other namespace's published refcounts plus any sibling's unsealed
        # journal — chunks are shared, so gc may only reap what NO session
        # can reach (ISSUE 6's refcounted-GC invariant)
        live = self.graph.live_chunk_keys() | global_live_chunks(self.store)
        dead = [k for k in self.store.list_chunk_keys() if k not in live]
        freed = sum(self.store.chunk_sizes(dead).values())
        self.store.delete_chunks(dead)
        purged = txn_purge_tombstones(self.store, self.graph.nodes)
        return {"chunks_dropped": len(dead), "bytes_freed": freed,
                "chunks_live": len(live), "tombstones_purged": purged}

    def storage_stats(self) -> dict:
        out = {"chunk_bytes": self.store.chunk_bytes_total(),
               "n_chunks": self.store.n_chunks(),
               "graph_meta_bytes": self.graph.total_meta_bytes(),
               "n_commits": len(self.graph.nodes),
               "txn_publishes": self.engine.stats.publishes,
               "txn_journal_puts": self.engine.stats.journal_puts,
               "tenant": self.tenant,
               "tenant_ref_bytes": self.graph.refs.bytes_live(),
               "quota_bytes": self.quota_bytes}
        if self.lease is not None:
            out["lease_owner"] = self.lease.owner
            out["lease_token"] = self.lease.token
        return out

    def metrics_text(self) -> str:
        """This session's metrics as Prometheus text exposition."""
        from repro.obs import render
        return render([self.obs.registry])

    def _persist_obs(self) -> None:
        """Best-effort span/metric snapshot under ``obs/trace/<sid>`` —
        only when tracing was opted into: the default path must add zero
        store writes (crash-injection op sweeps count every one)."""
        if not self.obs.tracer.enabled or not self.obs.tracer.spans:
            return
        try:
            self.store.put_meta(TRACE_META_PREFIX + self.obs.sid,
                                self.obs.to_doc())
        except Exception:  # noqa: BLE001 — a dying store must not block close
            pass

    def close(self) -> None:
        try:
            self.writer.flush()
            self.engine.flush()
            self._persist_obs()
        finally:
            # a flush error (poisoned engine, deferred publish failure)
            # must still join the worker threads; the unsealed journal is
            # the next open's recovery problem, not a thread leak
            self.engine.close()
            self.writer.close()
            if self.lease is not None:
                self.lease.release()
