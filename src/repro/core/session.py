"""KishuSession — the public time-traveling API (§3).

    session = KishuSession(store)
    session.register("train", train_command)
    session.init_state({...})                 # attach
    session.run("train", steps=10)            # cell execution + incr. ckpt
    session.log()                             # inspect the Checkpoint Graph
    session.checkout("c00003")                # incremental checkout (undo /
                                              #  branch switch)

Each ``run`` executes a registered command against the tracked namespace,
detects the co-variable-granularity state delta (Lemma-1-pruned), writes an
incremental checkpoint, and appends a commit to the Checkpoint Graph.
``checkout`` restores any past state by loading only diverged co-variables,
with recursive fallback recomputation for missing data.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

from repro.core import hashing
from repro.core.checkpoint import CheckpointWriter, WriteStats
from repro.core.checkout import CheckoutStats, StateLoader
from repro.core.chunkstore import ChunkCache, ChunkStore
from repro.core.covariable import (CovKey, RecordBuilder, StateDelta,
                                   detect_delta, group_covariables)
from repro.core.graph import CheckpointGraph, key_str
from repro.core.namespace import Namespace, TrackedNamespace
from repro.core.restore import DataRestorer


@dataclass
class RunStats:
    commit_id: str = ""
    exec_s: float = 0.0
    detect_s: float = 0.0
    write_s: float = 0.0
    total_s: float = 0.0
    covs_updated: int = 0
    covs_deleted: int = 0
    covs_checked: int = 0
    covs_skipped: int = 0
    write: WriteStats = field(default_factory=WriteStats)


class KishuSession:
    def __init__(self, store: ChunkStore, *,
                 chunk_bytes: int = hashing.DEFAULT_CHUNK_BYTES,
                 async_write: bool = False,
                 write_deadline_s: float = 0.0,
                 check_all: bool = False,
                 hasher=None,
                 io_threads: Optional[int] = None,
                 cache_bytes: Optional[int] = None):
        self.store = store
        self.ns = Namespace()
        self.tracked = TrackedNamespace(self.ns)
        self.graph = CheckpointGraph(store)
        self.builder = RecordBuilder(chunk_bytes, hasher=hasher)
        # one chunk cache shared by writer and loader: checking out a
        # just-committed state is served from memory, not the backend
        # (cache_bytes=0 disables; default $KISHU_CACHE_BYTES or 64 MiB)
        self.chunk_cache = ChunkCache(cache_bytes)
        self.writer = CheckpointWriter(store, chunk_bytes=chunk_bytes,
                                       async_write=async_write,
                                       write_deadline_s=write_deadline_s,
                                       cache=self.chunk_cache)
        self.registry: Dict[str, Callable] = {}
        self.records: Dict[str, Any] = {}
        self.covs: Dict[CovKey, List[str]] = {}
        self.check_all = check_all      # AblatedKishu(Check all) mode (§7.6)
        self.last_run: Optional[RunStats] = None
        self.last_checkout: Optional[CheckoutStats] = None

        self.loader = StateLoader(self.graph, store, io_threads=io_threads,
                                  cache=self.chunk_cache)
        self.restorer = DataRestorer(self.graph, self.loader, self.registry)
        self.loader.fallback = self.restorer.recompute

        if not self.graph.nodes:
            self.graph.init_root()

    # ------------------------------------------------------------------
    # attachment & commands
    # ------------------------------------------------------------------
    def register(self, name: str, fn: Callable) -> None:
        self.registry[name] = fn

    def init_state(self, tree: Dict[str, Any], message: str = "attach") -> str:
        """Attach: populate the namespace and commit the initial state."""
        def _init(ns, **_):
            for prefix, sub in tree.items():
                if isinstance(sub, dict):
                    ns.set_tree(prefix, sub)
                else:
                    ns[prefix] = sub
        self.register("__attach__", _init)
        return self.run("__attach__", _message=message)

    @property
    def head(self) -> str:
        return self.graph.head

    # ------------------------------------------------------------------
    # cell execution + incremental checkpoint
    # ------------------------------------------------------------------
    def run(self, command: str, _message: str = "", **args) -> str:
        name = command
        fn = self.registry[name]
        stats = RunStats()
        t_all = time.perf_counter()

        self.tracked.reset()
        t0 = time.perf_counter()
        fn(self.tracked, **args)
        stats.exec_s = time.perf_counter() - t0

        accessed = (set(self.tracked.accessed) | set(self.tracked.written)
                    | set(self.tracked.deleted))
        if self.check_all:
            accessed = set(self.records) | set(self.ns.names())

        t0 = time.perf_counter()
        delta, self.records = detect_delta(self.records, self.covs, self.ns,
                                           accessed, self.builder)
        self.covs = group_covariables(self.records)
        stats.detect_s = time.perf_counter() - t0

        # dependencies: accessed co-variables at their pre-execution versions
        prev_index = self.graph.nodes[self.graph.head].state_index
        deps = {}
        for key in delta.candidates:
            ver = prev_index.get(key_str(key))
            if ver is not None:
                deps[key] = ver

        t0 = time.perf_counter()
        manifests, wstats = self.writer.write_delta(
            delta, self.ns, self._prev_manifest)
        stats.write_s = time.perf_counter() - t0
        stats.write = wstats

        node = self.graph.commit(
            command={"name": name, "args": args},
            manifests=manifests,
            deleted_keys=delta.deleted,
            accessed=deps,
            updated_keys=list(delta.updated),
            message=_message,
            stats={"bytes_written": wstats.bytes_written,
                   "bytes_serialized": wstats.bytes_serialized,
                   "bytes_logical": wstats.bytes_logical,
                   "chunks_written": wstats.chunks_written,
                   "chunks_reused": wstats.chunks_reused,
                   "exec_s": stats.exec_s})
        stats.commit_id = node.commit_id
        stats.covs_updated = len(delta.updated)
        stats.covs_deleted = len(delta.deleted)
        stats.covs_checked = delta.checked
        stats.covs_skipped = delta.skipped
        stats.total_s = time.perf_counter() - t_all
        self.last_run = stats
        return node.commit_id

    def _prev_manifest(self, key: CovKey) -> Optional[dict]:
        ver = self.graph.nodes[self.graph.head].state_index.get(key_str(key))
        if ver is None:
            return None
        return self.graph.manifest_of(key, ver)

    # ------------------------------------------------------------------
    # incremental checkout
    # ------------------------------------------------------------------
    def checkout(self, commit_id: str) -> CheckoutStats:
        self.writer.flush()
        self.restorer.clear_memo()
        self.records, stats = self.loader.checkout(self.tracked, self.records,
                                                   commit_id)
        self.covs = group_covariables(self.records)
        self.last_checkout = stats
        return stats

    # ------------------------------------------------------------------
    # introspection & maintenance
    # ------------------------------------------------------------------
    def log(self, limit: int = 0) -> List[dict]:
        return self.graph.log(limit)

    def diff(self, a: str, b: str) -> dict:
        """Human-oriented state diff between two commits: which co-variables
        diverged / exist only on one side (Def 6 over the graph index)."""
        plan = self.graph.diff(a, b)
        return {"diverged": sorted("+".join(k) for k in plan.to_load),
                "only_in_a": sorted("+".join(k) for k in plan.to_delete),
                "identical": len(plan.identical)}

    def delete_branch(self, tip: str) -> List[str]:
        """Delete the commits exclusive to ``tip``'s branch (up to but not
        including the first ancestor with another child or the HEAD path).
        Returns deleted commit ids. Run ``gc()`` afterwards to reclaim
        chunks."""
        assert tip != self.graph.head, "cannot delete the current branch"
        doomed = []
        node = self.graph.nodes[tip]
        while node.parent is not None:
            siblings = self.graph.children.get(node.parent, [])
            doomed.append(node.commit_id)
            if len(siblings) > 1 or node.parent == self.graph.head:
                break
            node = self.graph.nodes[node.parent]
        head_path = set(self.graph.path_from_root(self.graph.head))
        doomed = [c for c in doomed if c not in head_path]
        for cid in doomed:
            parent = self.graph.nodes[cid].parent
            if parent in self.graph.children:
                self.graph.children[parent] = [
                    c for c in self.graph.children[parent] if c != cid]
            del self.graph.nodes[cid]
            self.store.put_meta(f"commit/{cid}", {"deleted": True})
        return doomed

    def gc(self) -> dict:
        """Content-addressed garbage collection: drop chunks referenced by
        no live manifest (after branch deletion / history truncation).
        Enumerates through ``list_chunk_keys()`` and deletes through the
        batched ``delete_chunks()`` — so every backend (single-file SQLite,
        sharded/replicated fabrics) reclaims space, and a fabric sweeps all
        its shards and replicas, strays included."""
        live = self.graph.live_chunk_keys()
        dead = [k for k in self.store.list_chunk_keys() if k not in live]
        freed = sum(self.store.chunk_sizes(dead).values())
        self.store.delete_chunks(dead)
        return {"chunks_dropped": len(dead), "bytes_freed": freed,
                "chunks_live": len(live)}

    def storage_stats(self) -> dict:
        return {"chunk_bytes": self.store.chunk_bytes_total(),
                "n_chunks": self.store.n_chunks(),
                "graph_meta_bytes": self.graph.total_meta_bytes(),
                "n_commits": len(self.graph.nodes)}

    def close(self) -> None:
        self.writer.flush()
        self.writer.close()
