"""Session namespace with access tracking — the Patched Namespace (§4.3).

The session state is a flat mapping ``name -> leaf`` where names are
"/"-joined paths (e.g. ``params/stages/stage_0/sub_0/attn/wq``).  Commands
execute against a :class:`TrackedNamespace`, whose get/set/delete hooks
record *accessed* names; by Lemma 1, only co-variables intersecting the
accessed set can have been updated, so delta detection is pruned to those.

Tree helpers convert nested pytrees (params, optimizer state) to and from
flat names, which is how the training substrate plugs into the paper's
variable model.
"""
from __future__ import annotations

from typing import Any, Dict, Iterable, Iterator, List, MutableMapping, Set

SEP = "/"


def flatten_tree(tree: Any, prefix: str = "") -> Dict[str, Any]:
    """Nested dicts -> flat {path: leaf}. Non-dict values are leaves."""
    out: Dict[str, Any] = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            sub = prefix + SEP + str(k) if prefix else str(k)
            out.update(flatten_tree(tree[k], sub))
    else:
        out[prefix] = tree
    return out


def unflatten_tree(flat: Dict[str, Any]) -> Any:
    root: Dict[str, Any] = {}
    for path, leaf in flat.items():
        parts = path.split(SEP)
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = leaf
    return root


class Namespace(MutableMapping):
    """Flat name -> leaf mapping with pytree conveniences."""

    def __init__(self, init: Dict[str, Any] | None = None):
        self._d: Dict[str, Any] = dict(init or {})

    # -- MutableMapping --
    def __getitem__(self, name: str) -> Any:
        return self._d[name]

    def __setitem__(self, name: str, value: Any) -> None:
        self._d[name] = value

    def __delitem__(self, name: str) -> None:
        del self._d[name]

    def __iter__(self) -> Iterator[str]:
        return iter(self._d)

    def __len__(self) -> int:
        return len(self._d)

    # -- trees --
    def get_tree(self, prefix: str) -> Any:
        pre = prefix + SEP
        sub = {k[len(pre):]: v for k, v in self._d.items() if k.startswith(pre)}
        if not sub:
            if prefix in self._d:
                return self._d[prefix]
            raise KeyError(prefix)
        return unflatten_tree(sub)

    def set_tree(self, prefix: str, tree: Any) -> List[str]:
        """Replace the subtree under ``prefix``; returns names written."""
        pre = prefix + SEP
        stale = [k for k in self._d if k.startswith(pre) or k == prefix]
        flat = flatten_tree(tree, prefix)
        for k in stale:
            if k not in flat:
                del self._d[k]
        self._d.update(flat)
        return list(flat)

    def names(self) -> List[str]:
        return sorted(self._d)


class TrackedNamespace(MutableMapping):
    """Records get/set/delete accesses on a Namespace (the §4.3 patch).

    ``accessed`` = any touch; ``written`` / ``deleted`` / ``created`` refine
    it for delta bookkeeping.  ``pause()`` suspends tracking (used by the
    checkout path, which replaces data *without* marking it accessed).
    """

    def __init__(self, base: Namespace):
        self.base = base
        self.accessed: Set[str] = set()
        self.read: Set[str] = set()     # data reads only — a pure overwrite
                                        # (ns["x"] = v) touches ``accessed``
                                        # but not ``read``, so replay deps
                                        # can skip pre-images the command
                                        # never looks at
        self.written: Set[str] = set()
        self.deleted: Set[str] = set()
        self._paused = False

    # -- tracking core --
    def _touch(self, name: str) -> None:
        if not self._paused:
            self.accessed.add(name)

    def __getitem__(self, name: str) -> Any:
        self._touch(name)
        if not self._paused:
            self.read.add(name)
        return self.base[name]

    def __setitem__(self, name: str, value: Any) -> None:
        self._touch(name)
        if not self._paused:
            self.written.add(name)
            self.deleted.discard(name)
        self.base[name] = value

    def __delitem__(self, name: str) -> None:
        self._touch(name)
        if not self._paused:
            self.deleted.add(name)
            self.written.discard(name)
        del self.base[name]

    def __iter__(self) -> Iterator[str]:
        # iteration (e.g. listing) does not count as data access
        return iter(self.base)

    def __len__(self) -> int:
        return len(self.base)

    # -- trees --
    def get_tree(self, prefix: str) -> Any:
        pre = prefix + SEP
        touched = [k for k in self.base if k.startswith(pre) or k == prefix]
        for k in touched:
            self._touch(k)
            if not self._paused:
                self.read.add(k)
        return self.base.get_tree(prefix)

    def set_tree(self, prefix: str, tree: Any) -> None:
        pre = prefix + SEP
        before = {k for k in self.base if k.startswith(pre) or k == prefix}
        names = self.base.set_tree(prefix, tree)
        if not self._paused:
            for k in names:
                self.accessed.add(k)
                self.written.add(k)
                self.deleted.discard(k)
            for k in before.difference(names):
                self.accessed.add(k)
                self.deleted.add(k)
                self.written.discard(k)

    def names(self) -> List[str]:
        return self.base.names()

    # -- control --
    def pause(self):
        class _P:
            def __enter__(_s):
                self._paused = True
            def __exit__(_s, *a):
                self._paused = False
        return _P()

    def reset(self) -> None:
        self.accessed.clear()
        self.read.clear()
        self.written.clear()
        self.deleted.clear()
