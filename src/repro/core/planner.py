"""Cost-based checkout planner — restore vs recompute vs hybrid (DESIGN.md §18).

Checkout assumed fetching chunks is always the cheapest path back to a
state; on a remote/slow fabric a co-variable is often cheaper to *replay*
from its recorded command (Fine-Grained Lineage) or to *patch* from a
nearer base (code+data space versioning).  The planner prices three paths
per diverged co-variable and hands ``StateLoader.checkout`` a mixed plan:

fetch   manifest bytes / an online per-backend bandwidth+latency model fed
        by the ``kishu_store_op_seconds`` / ``kishu_store_bytes_total``
        metrics the InstrumentedStore already records; chunks resident in
        the shared ChunkCache are priced at zero.
replay  measured cell cost (per-commit ``exec_s``) summed over the
        recursive dependency closure the DataRestorer would walk —
        memo-aware: a command shared by several co-variables (or already
        charged to another co-variable's replay in this plan) is priced
        once, mirroring the restorer's per-checkout replay memo.
patch   dirty-chunk bytes against the live base (``plan_patches``); chunks
        shared with *any* cache-resident commit are free through the CAS
        cache credit, which generalizes patching beyond HEAD without a
        separate execution path.

Unserializable manifests (det-replay skips, opaque leaves) price fetch at
infinity, so DetReplay commits always plan replay; commands that are
unregistered, marked replay-unsafe at commit time, or rooted at
``__init__`` price replay at infinity, so planner-on can never attempt a
replay planner-off would not survive.  Infinite-everywhere co-variables
stay on the fetch lane where the existing fallback ladder (and its error
reporting) is unchanged — the planner re-routes work, never re-defines
failure.
"""
from __future__ import annotations

import math
import os
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Set, Tuple

from repro.core.covariable import CovKey
from repro.core.graph import CheckpointGraph, CheckoutPlan, parse_key

INF = math.inf

PLAN_MODES = ("off", "auto", "fetch", "replay")
_MODE_ALIASES = {
    "": "off", "0": "off", "none": "off", "false": "off",
    "1": "auto", "on": "auto", "true": "auto",
    "forced-fetch": "fetch", "forced-replay": "replay",
}

# Conservative priors for a cold cost model (first checkout of a session,
# or `kishu plan` against a store never read from): local-disk-ish store,
# expensive-unless-measured cells.
DEFAULT_BANDWIDTH_BPS = 500e6
DEFAULT_LATENCY_S = 5e-4
DEFAULT_EXEC_S = 60.0           # commit docs predating exec_s persistence
REPLAY_EPS_S = 1e-4             # per-command overhead; ties break to fetch


def resolve_plan_mode(mode: Optional[str] = None) -> str:
    """Effective planner mode: explicit arg > $KISHU_PLANNER > off."""
    if mode is None:
        mode = os.environ.get("KISHU_PLANNER", "")
    mode = str(mode).strip().lower()
    mode = _MODE_ALIASES.get(mode, mode)
    if mode not in PLAN_MODES:
        raise ValueError(
            f"plan_mode {mode!r}: expected one of {'/'.join(PLAN_MODES)}")
    return mode


class StoreCostModel:
    """Online per-backend fetch estimator over the obs registry.

    Effective bandwidth = get bytes / get seconds across every backend
    label, so per-chunk stalls a slow transport serializes (latency-bound
    fabrics) are *inside* the rate — the model never needs to know whether
    a store is round-trip- or bandwidth-bound.  Latency is the mean
    observed get-op time, charged once per fetch (checkout issues one
    pipelined bulk fetch per lane)."""

    GET_OPS = ("get_chunk", "get_chunks")

    def __init__(self, registry=None, *,
                 default_bandwidth_Bps: float = DEFAULT_BANDWIDTH_BPS,
                 default_latency_s: float = DEFAULT_LATENCY_S):
        self.registry = registry
        self.default_bandwidth_Bps = default_bandwidth_Bps
        self.default_latency_s = default_latency_s

    def snapshot(self) -> Tuple[float, float, int]:
        """(latency_s, bandwidth_Bps, observed get ops)."""
        total_s = 0.0
        ops = 0
        nbytes = 0.0
        if self.registry is not None:
            for h in list(getattr(self.registry, "_histograms", {}).values()):
                if h.name == "kishu_store_op_seconds" \
                        and h.labels.get("op") in self.GET_OPS:
                    total_s += h.sum
                    ops += h.count
            for c in list(getattr(self.registry, "_counters", {}).values()):
                if c.name == "kishu_store_bytes_total" \
                        and c.labels.get("dir") == "get":
                    nbytes += c.value
        lat = total_s / ops if ops else self.default_latency_s
        bw = nbytes / total_s if nbytes > 0 and total_s > 0 \
            else self.default_bandwidth_Bps
        return lat, bw, ops

    def fetch_seconds(self, nbytes: int, nchunks: int) -> float:
        if nchunks <= 0:
            return 0.0
        lat, bw, _ = self.snapshot()
        return lat + nbytes / max(bw, 1.0)


@dataclass
class CovPlan:
    """One co-variable's priced paths and the chosen one."""
    key: CovKey
    version: str
    path: str                   # fetch | replay | patch
    est_s: float                # cost of the chosen path
    est_bytes: int              # bytes the chosen path moves from the store
    why: str
    fetch_s: float = INF
    replay_s: float = INF
    patch_s: float = INF

    @property
    def name(self) -> str:
        return "+".join(self.key)


@dataclass
class PricedPlan:
    cur: str
    target: str
    mode: str
    covs: List[CovPlan] = field(default_factory=list)
    identical: int = 0
    deleted: int = 0
    est_fetch_s: float = 0.0    # fetch+patch lane (store reads)
    est_replay_s: float = 0.0   # replay lane (compute)
    est_total_s: float = 0.0    # lanes overlap: max, not sum
    latency_s: float = 0.0      # cost-model snapshot the plan was priced at
    bandwidth_Bps: float = 0.0
    samples: int = 0

    def counts(self) -> Dict[str, int]:
        out = {"fetch": 0, "replay": 0, "patch": 0}
        for c in self.covs:
            out[c.path] += 1
        return out

    def path_of(self, key: CovKey) -> Optional[str]:
        for c in self.covs:
            if c.key == key:
                return c.path
        return None


def _fmt_bytes(n: float) -> str:
    for unit in ("B", "KB", "MB", "GB"):
        if abs(n) < 1024 or unit == "GB":
            return f"{n:.0f}{unit}" if unit == "B" else f"{n:.1f}{unit}"
        n /= 1024
    return f"{n:.1f}GB"


def _fmt_s(s: float) -> str:
    return "inf" if s == INF else f"{s:.3f}s"


def format_plan(p: PricedPlan) -> List[str]:
    """Human-oriented rendering shared by ``kishu plan`` and tests."""
    n = p.counts()
    lines = [
        f"plan {p.cur} -> {p.target}  mode={p.mode}  "
        f"est {_fmt_s(p.est_total_s)} "
        f"(fetch lane {_fmt_s(p.est_fetch_s)} | "
        f"replay lane {_fmt_s(p.est_replay_s)})",
        f"store model: latency {p.latency_s * 1e3:.2f}ms/op, "
        f"bandwidth {p.bandwidth_Bps / 1e6:.0f}MB/s "
        f"({p.samples} get op(s) observed)",
        f"{'PATH':<7} {'EST':>9} {'BYTES':>9}  CO-VARIABLE @ VERSION",
    ]
    for c in p.covs:
        lines.append(
            f"{c.path:<7} {_fmt_s(c.est_s):>9} {_fmt_bytes(c.est_bytes):>9}"
            f"  {c.name} @ {c.version}  -- {c.why}")
    lines.append(
        f"covs: {n['fetch']} fetch, {n['patch']} patch, {n['replay']} replay"
        f"; {p.identical} identical, {p.deleted} deleted")
    return lines


class CheckoutPlanner:
    """Prices fetch/replay/patch per diverged co-variable and partitions
    the checkout into the lanes ``StateLoader`` executes concurrently."""

    def __init__(self, graph: CheckpointGraph, loader, *,
                 commands: Optional[Dict[str, Callable]] = None,
                 unsafe: Optional[Set[str]] = None,
                 mode: Optional[str] = None,
                 cache=None,
                 obs=None,
                 max_depth: int = 64,
                 default_exec_s: float = DEFAULT_EXEC_S,
                 cost: Optional[StoreCostModel] = None):
        self.graph = graph
        self.loader = loader
        self.commands = commands        # None: assume registered (CLI plan)
        self.unsafe = unsafe if unsafe is not None else set()
        self.mode = resolve_plan_mode(mode)
        self.cache = cache              # shared ChunkCache (may be None)
        self.obs = obs
        self.max_depth = max_depth
        self.default_exec_s = default_exec_s
        self.cost = cost or StoreCostModel(
            obs.registry if obs is not None else None)

    @property
    def engaged(self) -> bool:
        return self.mode != "off"

    # ------------------------------------------------------------------
    # per-path pricing
    # ------------------------------------------------------------------
    def _cached(self, chunk_key: str) -> bool:
        return self.cache is not None and self.cache.contains(chunk_key)

    def _fetch_price(self, key: CovKey, version: str
                     ) -> Tuple[float, int, str]:
        """(seconds, cold bytes, why) for a full manifest fetch."""
        man = self.graph.manifest_of(key, version)
        if man is None:
            return INF, 0, "no manifest"
        if man.get("unserializable"):
            why = "det-skipped" if man.get("det_skipped") else "unserializable"
            return INF, 0, why
        chunks = man["base"]["chunks"]
        cold_b = cold_n = 0
        for c in chunks:
            if not self._cached(c["key"]):
                cold_b += int(c["n"])
                cold_n += 1
        why = f"{cold_n}/{len(chunks)} chunks cold" if cold_n \
            else "all chunks cache-resident"
        return self.cost.fetch_seconds(cold_b, cold_n), cold_b, why

    def _patch_price(self, patch) -> Tuple[float, int, str]:
        """(seconds, cold dirty bytes, why) for a live-base chunk patch."""
        chunks = patch.manifest["base"]["chunks"]
        cold_b = cold_n = 0
        for i in patch.dirty:
            c = chunks[i]
            if not self._cached(c["key"]):
                cold_b += int(c["n"])
                cold_n += 1
        why = f"{len(patch.dirty)}/{len(chunks)} chunks dirty ({cold_n} cold)"
        return self.cost.fetch_seconds(cold_b, cold_n), cold_b, why

    def _exec_cost(self, node) -> float:
        s = node.stats.get("exec_s")
        return REPLAY_EPS_S + (float(s) if s is not None
                               else self.default_exec_s)

    def _replayable(self, node) -> bool:
        name = node.command.get("name")
        if name == "__init__":
            return False                # root state: nothing to re-run
        if node.stats.get("replay_safe") is False or name in self.unsafe:
            return False
        if self.commands is not None and name not in self.commands:
            return False
        return True

    def _replay_price(self, version: str, charged: Set[str]
                      ) -> Tuple[float, Set[str], int]:
        """(seconds, commands that would newly run, commands total) to
        replay ``version``'s command with its dependency closure restored.

        Mirrors the DataRestorer exactly: dependencies load from the store
        when they can (priced as fetches, cache credit included) and only
        recurse into replay when fetch is impossible.  ``charged`` holds
        versions already committed to this plan's replay lane — the
        restorer's per-checkout memo replays each at most once, so a
        shared ancestor prices (and counts) once across co-variables."""
        local: Dict[str, Tuple[float, Set[str]]] = {}
        shared: Set[str] = set()

        def walk(ver: str, depth: int) -> Tuple[float, Set[str]]:
            if ver in charged:
                shared.add(ver)         # memo hit at execution time
                return 0.0, set()
            hit = local.get(ver)
            if hit is not None:
                return hit
            if depth > self.max_depth:
                return INF, set()
            node = self.graph.nodes.get(ver)
            if node is None or not self._replayable(node):
                return INF, set()
            local[ver] = (0.0, set())   # cycle guard (graph is a DAG)
            cost = self._exec_cost(node)
            used = {ver}
            for ks, dep_ver in sorted(node.accessed.items()):
                dep_fetch, _, _ = self._fetch_price(parse_key(ks), dep_ver)
                if dep_fetch < INF:
                    cost += dep_fetch   # restorer prefetches loadable deps
                else:
                    dep_cost, dep_used = walk(dep_ver, depth + 1)
                    cost += dep_cost
                    used |= dep_used
            local[ver] = (cost, used)
            return cost, used

        cost, used = walk(version, 0)
        return cost, used, len(used) + len(shared)

    # ------------------------------------------------------------------
    # plan assembly
    # ------------------------------------------------------------------
    def price_checkout(self, cur: str, target: str, *,
                       records=None, ns=None) -> PricedPlan:
        """Diff + patch-candidate scan + pricing, without executing.

        ``records``/``ns`` enable live-base patch candidates (a session
        passes its own; the CLI prices fetch-vs-replay only)."""
        plan = self.graph.diff(cur, target)
        if records is not None and ns is not None:
            patches, full_items = self.loader.plan_patches(plan, records, ns)
        else:
            patches, full_items = [], sorted(plan.to_load.items())
        return self.price(cur, target, plan, patches, full_items)

    def price(self, cur: str, target: str, plan: CheckoutPlan,
              patches: Sequence[Any],
              full_items: Sequence[Tuple[CovKey, str]]) -> PricedPlan:
        t0 = time.perf_counter()
        lat, bw, samples = self.cost.snapshot()
        out = PricedPlan(cur=cur, target=target, mode=self.mode,
                         identical=len(plan.identical),
                         deleted=len(plan.to_delete),
                         latency_s=lat, bandwidth_Bps=bw, samples=samples)
        charged: Set[str] = set()       # versions on the replay lane so far
        rows: List[Tuple[CovKey, str, Optional[Any]]] = \
            [(p.key, p.version, p) for p in patches] + \
            [(k, v, None) for k, v in full_items]
        for key, version, patch in sorted(rows, key=lambda r: r[0]):
            fetch_s, fetch_b, fetch_why = self._fetch_price(key, version)
            patch_s, patch_b, patch_why = (INF, 0, "")
            if patch is not None:
                patch_s, patch_b, patch_why = self._patch_price(patch)
            replay_s, closure, n_cmds = self._replay_price(version, charged)
            replay_why = (f"{len(closure)} cmd(s) to run"
                          + (f", {n_cmds - len(closure)} memo-shared"
                             if n_cmds > len(closure) else ""))
            path, est_s, est_b, why = self._choose(
                patch, fetch_s, fetch_b, fetch_why,
                patch_s, patch_b, patch_why, replay_s, replay_why)
            if path == "replay":
                charged |= closure      # shared ancestors price once
            out.covs.append(CovPlan(
                key=key, version=version, path=path, est_s=est_s,
                est_bytes=est_b, why=why, fetch_s=fetch_s,
                replay_s=replay_s, patch_s=patch_s))
        for c in out.covs:
            if c.path == "replay":
                out.est_replay_s += c.est_s
            elif c.est_s < INF:
                out.est_fetch_s += c.est_s
        out.est_total_s = max(out.est_fetch_s, out.est_replay_s)
        if self.obs is not None:
            reg = self.obs.registry
            for path, n in out.counts().items():
                if n:
                    reg.counter("kishu_plan_covs_total", path=path).inc(n)
            reg.histogram("kishu_plan_price_seconds").observe(
                time.perf_counter() - t0)
        return out

    def _choose(self, patch, fetch_s, fetch_b, fetch_why,
                patch_s, patch_b, patch_why, replay_s, replay_why):
        """Pick the path for one co-variable under the planner mode."""
        data_path = ("patch", patch_s, patch_b, patch_why) if patch is not None \
            else ("fetch", fetch_s, fetch_b, fetch_why)
        if self.mode == "fetch":
            return data_path
        if self.mode == "replay":
            if replay_s < INF:
                return "replay", replay_s, 0, replay_why + " (forced)"
            return data_path
        # auto: strictly cheaper replay wins; ties and infinities keep the
        # data path so planner-on never changes the failure ladder
        if replay_s < data_path[1]:
            return "replay", replay_s, 0, \
                replay_why + f" vs {data_path[0]} {_fmt_s(data_path[1])}"
        return data_path

    def partition(self, priced: PricedPlan, patches: Sequence[Any],
                  full_items: Sequence[Tuple[CovKey, str]]
                  ) -> Tuple[List[Any], List[Tuple[CovKey, str]],
                             List[Tuple[CovKey, str]]]:
        """Split the priced plan into execution lanes:
        (patches to apply, covs to fetch, covs to replay)."""
        path = {c.key: c.path for c in priced.covs}
        keep_patches = [p for p in patches
                        if path.get(p.key, "patch") != "replay"]
        demoted = [(p.key, p.version) for p in patches
                   if path.get(p.key) == "replay"]
        fetch_items = [(k, v) for k, v in full_items
                       if path.get(k, "fetch") != "replay"]
        replay_items = sorted(demoted + [
            (k, v) for k, v in full_items if path.get(k) == "replay"])
        return keep_patches, fetch_items, replay_items
