"""Leaf serialization for session states.

Exact, dtype-preserving byte views — no pickle for arrays, so roundtrips are
bit-exact by construction (the paper's "silent pickling errors" class cannot
occur for arrays; it is *simulated* via :class:`OpaqueLeaf` to exercise
fallback recomputation, mirroring generators/locks/remote handles in §5.1).

A leaf is one of:
  - ``jax.Array`` / ``np.ndarray``  -> raw bytes + (dtype, shape[, strides]) meta
  - jax typed PRNG key              -> key-data uint32 bytes + impl tag
  - small python objects            -> pickled (scalars, tuples, strs)
  - ``OpaqueLeaf``                  -> SerializationError (unserializable)
"""
from __future__ import annotations

import pickle
from dataclasses import dataclass, field
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class SerializationError(Exception):
    """Raised when a leaf cannot be serialized (paper §5.1: skip storage,
    fall back to recomputation at checkout)."""


class ChunkMissingError(Exception):
    """A chunk referenced by a manifest is absent/corrupt in the store."""


@dataclass
class OpaqueLeaf:
    """Simulates an unserializable object (generator, lock, GPU ipc handle).

    Carries a payload so fallback recomputation can be *verified* to rebuild
    the correct value; serialization of the leaf itself always fails.
    """
    payload: Any = None
    note: str = "unserializable"

    def __reduce__(self):
        raise SerializationError(f"OpaqueLeaf({self.note}) cannot be pickled")

    def __eq__(self, other):
        return isinstance(other, OpaqueLeaf) and other.payload == self.payload \
            and other.note == self.note


def is_array_leaf(x: Any) -> bool:
    return isinstance(x, (np.ndarray, jax.Array))


def is_prng_key(x: Any) -> bool:
    return isinstance(x, jax.Array) and jnp.issubdtype(x.dtype, jax.dtypes.prng_key)


def base_of(x: Any) -> Any:
    """Ultimate base buffer of a (possibly viewed) array leaf."""
    if isinstance(x, np.ndarray):
        while isinstance(x.base, np.ndarray):
            x = x.base
        return x
    return x


def view_spec(x: Any, base: Any) -> Optional[dict]:
    """(offset, shape, strides, dtype) of x relative to base, or None if
    x *is* the base."""
    if x is base:
        return None
    assert isinstance(x, np.ndarray) and isinstance(base, np.ndarray)
    off = x.__array_interface__["data"][0] - base.__array_interface__["data"][0]
    return {"offset": int(off), "shape": list(x.shape),
            "strides": list(x.strides), "dtype": str(x.dtype)}


def leaf_meta(x: Any) -> dict:
    if is_prng_key(x):
        data = jax.random.key_data(x)
        return {"kind": "prng", "impl": str(jax.random.key_impl(x)),
                "dtype": str(data.dtype), "shape": list(data.shape)}
    if is_array_leaf(x):
        dt = np.dtype(x.dtype)
        meta = {"kind": "array", "dtype": str(dt),
                "shape": list(x.shape), "jax": isinstance(x, jax.Array)}
        if dt.fields:                       # structured dtype: store descr
            meta["dtype_descr"] = [list(d) for d in dt.descr]
        return meta
    return {"kind": "object", "type": type(x).__name__}


def leaf_to_bytes(x: Any) -> Tuple[bytes, dict]:
    """Serialize a *base* leaf. Raises SerializationError for opaque leaves."""
    meta = leaf_meta(x)
    if meta["kind"] == "prng":
        return np.asarray(jax.random.key_data(x)).tobytes(), meta
    if meta["kind"] == "array":
        arr = np.asarray(x)
        if not arr.flags["C_CONTIGUOUS"]:
            arr = np.ascontiguousarray(arr)
        return arr.tobytes(), meta
    if isinstance(x, OpaqueLeaf):
        raise SerializationError(f"OpaqueLeaf({x.note})")
    try:
        return pickle.dumps(x), meta
    except Exception as e:  # noqa: BLE001 — any pickling failure is EAFP
        raise SerializationError(str(e)) from e


def leaf_from_bytes(data: bytes, meta: dict, *, device_put: bool = True) -> Any:
    if meta["kind"] == "prng":
        raw = np.frombuffer(data, dtype=np.dtype(meta["dtype"])) \
            .reshape(meta["shape"]).copy()
        return jax.random.wrap_key_data(jnp.asarray(raw))
    if meta["kind"] == "array":
        if meta.get("dtype_descr"):
            dt = np.dtype([tuple(d) for d in meta["dtype_descr"]])
        else:
            dt = np.dtype(meta["dtype"])
        arr = np.frombuffer(data, dtype=dt).reshape(meta["shape"]).copy()
        if meta.get("jax") and device_put:
            return jnp.asarray(arr)
        return arr
    return pickle.loads(data)


def view_from_base(base: np.ndarray, spec: dict) -> np.ndarray:
    """Reconstruct a strided view into ``base`` (shared-reference restore)."""
    flat = base.reshape(-1).view(np.uint8)
    dt = np.dtype(spec["dtype"])
    return np.lib.stride_tricks.as_strided(
        flat[spec["offset"]:].view(dt),
        shape=tuple(spec["shape"]), strides=tuple(spec["strides"]))


def leaf_nbytes(x: Any) -> int:
    if is_prng_key(x):
        return int(np.asarray(jax.random.key_data(x)).nbytes)
    if is_array_leaf(x):
        return int(np.dtype(x.dtype).itemsize
                   * int(np.prod(x.shape, dtype=np.int64)))
    try:
        return len(pickle.dumps(x))
    except Exception:  # noqa: BLE001
        return 0
