"""Fallback recomputation — the Data Restorer (§5.3).

A versioned co-variable (X, t) that was never stored (unserializable) or
fails to load (missing/corrupt chunks) is reconstructed by
  1. loading the versioned co-variables the commit *accessed* (its recorded
     dependencies) into a temporary namespace — recursively restoring any of
     *those* that are themselves missing (dynamic & recursive fallback), and
  2. re-running the recorded command on that namespace.

Determinism comes from the substrate: commands draw randomness from RNG-key
leaves *inside* the namespace and data from versioned iterator state, so a
replay sees bit-identical inputs (the paper's caveat about non-deterministic
cells — §5.3 Remark — is discharged by construction here; cf. DESIGN.md §2).

Replayed namespaces are memoized per checkout so a commit shared by several
co-variables (or a chain of det-replay commits) runs once.  The memo is
byte-bounded ($KISHU_RESTORE_MEMO_BYTES, default 256 MiB): deep checkouts
evict the least-recently-used replayed namespace instead of holding every
intermediate state alive.  A memoized version missing some requested names
(co-variable regrouping between commits) is topped up from the commit's own
state index instead of re-restoring every dependency and re-running the
command — a deterministic replay cannot produce names it didn't produce the
first time.
"""
from __future__ import annotations

import os
from collections import OrderedDict
from typing import Any, Callable, Dict, List, Optional, Set, Tuple

import numpy as np

from repro.core.covariable import CovKey
from repro.core.graph import CheckpointGraph, parse_key
from repro.core.namespace import Namespace, TrackedNamespace

DEFAULT_MEMO_BYTES = 256 << 20


def resolve_memo_bytes(n: Optional[int] = None) -> int:
    """Effective replay-memo capacity: explicit arg >
    $KISHU_RESTORE_MEMO_BYTES > 256 MiB.  ``0`` keeps only the most
    recently replayed namespace (the minimum needed for correctness of
    multi-cov extraction from one commit)."""
    if n is None:
        env = os.environ.get("KISHU_RESTORE_MEMO_BYTES", "").strip()
        try:
            n = int(env) if env else DEFAULT_MEMO_BYTES
        except ValueError:
            n = DEFAULT_MEMO_BYTES
    return max(0, int(n))


class RestoreError(Exception):
    pass


def _value_nbytes(val: Any) -> int:
    """Rough per-value footprint for the memo bound (arrays dominate)."""
    n = getattr(val, "nbytes", None)
    if isinstance(n, (int, np.integer)):
        return int(n)
    return 64


def _ns_nbytes(ns: Namespace) -> int:
    return sum(_value_nbytes(ns[name]) for name in ns.names())


def _replay_copy(val: Any) -> Any:
    """Defensive copy when a memoized replay value feeds another replay's
    namespace: the consuming command may mutate it in place, and the memo
    must keep serving the recorded version's bytes.  numpy copies; jax
    arrays are immutable; opaque objects pass through (the substrate's
    determinism contract covers them)."""
    if isinstance(val, np.ndarray):
        return val.copy()
    return val


class DataRestorer:
    def __init__(self, graph: CheckpointGraph, loader,
                 registry: Dict[str, Callable], *, max_depth: int = 64,
                 memo_bytes: Optional[int] = None):
        self.graph = graph
        self.loader = loader            # StateLoader (for dependency loads)
        self.registry = registry
        self.max_depth = max_depth
        self.replays = 0
        self.memo_bytes = resolve_memo_bytes(memo_bytes)
        # per-checkout replay memo: version -> replayed namespace (LRU over
        # approximate bytes). Restoring several co-variables of the same
        # commit (or a chain of det-replay commits) re-runs each command
        # once, not once per co-variable — the ARIES-style redo-caching the
        # paper defers to future work (§7.5.2).
        self._memo: "OrderedDict[str, Namespace]" = OrderedDict()
        self._memo_sizes: Dict[str, int] = {}
        # co-variables already counted into stats.covs_recomputed this
        # checkout: the counter means "co-variables restored via replay",
        # exactly once per (version, cov) regardless of recursion shape
        self._counted: Set[Tuple[str, CovKey]] = set()

    def clear_memo(self) -> None:
        self._memo.clear()
        self._memo_sizes.clear()
        self._counted.clear()

    # ------------------------------------------------------------------
    # memo bookkeeping
    # ------------------------------------------------------------------
    def _memo_put(self, version: str, temp: Namespace) -> None:
        self._memo.pop(version, None)
        self._memo[version] = temp
        self._memo_sizes[version] = _ns_nbytes(temp)
        total = sum(self._memo_sizes.values())
        while total > self.memo_bytes and len(self._memo) > 1:
            old, _ = self._memo.popitem(last=False)
            total -= self._memo_sizes.pop(old, 0)

    def _count(self, key: CovKey, version: str, stats) -> None:
        if stats is None:
            return
        mark = (version, key)
        if mark not in self._counted:
            self._counted.add(mark)
            stats.covs_recomputed += 1

    # ------------------------------------------------------------------
    # recomputation
    # ------------------------------------------------------------------
    def recompute(self, key: CovKey, version: str, stats=None,
                  _depth: int = 0) -> Dict[str, Any]:
        if _depth > self.max_depth:
            raise RestoreError(f"recursion limit restoring {key} @ {version}")
        node = self.graph.nodes[version]
        cmd = node.command
        if cmd["name"] == "__init__":
            raise RestoreError(f"cannot recompute {key}: created at root")
        fn = self.registry.get(cmd["name"])
        if fn is None:
            raise RestoreError(f"command {cmd['name']!r} not registered")

        temp = self._memo.get(version)
        if temp is not None:
            self._memo.move_to_end(version)
            missing = [n for n in key if n not in temp]
            if missing:
                # partial hit: the replay ran but this request names values
                # it didn't produce (co-variable regrouping). Re-running is
                # futile — deterministic replay yields the same namespace —
                # so top up only the missing names from the commit's state
                # index.  RestoreError below if the index lacks them too.
                self._top_up(node, temp, missing, stats, _depth)
                missing = [n for n in key if n not in temp]
            if not missing:
                self._count(key, version, stats)
                return {n: temp[n] for n in key}
            raise RestoreError(
                f"replay of {cmd['name']} did not produce {missing}")

        # 1. restore dependencies (recursively if needed).  Dependencies
        #    that are loadable arrive through the parallel chunk engine in
        #    one prefetched pass (use_fallback=False: recursion depth is
        #    bookkept here, not inside the loader); only the unavailable
        #    remainder recurses into replay.
        temp = Namespace()
        dep_items = [(parse_key(s), v) for s, v in node.accessed.items()]
        prefetched = self.loader.load_covs(dep_items, stats,
                                           use_fallback=False)
        for dep_key, dep_version in dep_items:
            values = prefetched.get(dep_key)
            if values is None:
                values = self.recompute(dep_key, dep_version, stats,
                                        _depth + 1)
                # replay-produced values alias the child memo's namespace;
                # copy before this command can mutate them in place
                values = {n: _replay_copy(v) for n, v in values.items()}
            for name, val in values.items():
                temp[name] = val

        # 2. re-run the recorded command
        tns = TrackedNamespace(temp)
        fn(tns, **cmd.get("args", {}))
        self.replays += 1
        node.stats["replays"] = int(node.stats.get("replays", 0) or 0) + 1
        self._memo_put(version, temp)

        # 3. extract the requested co-variable (membership may be verified
        #    against the recomputed aliasing)
        missing = [n for n in key if n not in temp]
        if missing:
            raise RestoreError(
                f"replay of {cmd['name']} did not produce {missing}")
        self._count(key, version, stats)
        return {n: temp[n] for n in key}

    def _top_up(self, node, temp: Namespace, missing: List[str], stats,
                _depth: int) -> None:
        """Load the co-variables owning ``missing`` names (at the commit's
        own state index) into a memoized namespace."""
        wanted: Dict[Tuple[CovKey, str], None] = {}
        for ks, ver in node.state_index.items():
            cov = parse_key(ks)
            if any(n in missing for n in cov):
                wanted[(cov, ver)] = None
        items = list(wanted)
        got = self.loader.load_covs(items, stats, use_fallback=False)
        for cov, ver in items:
            values = got.get(cov)
            if values is None:
                try:
                    values = self.recompute(cov, ver, stats, _depth + 1)
                except RestoreError:
                    continue            # caller reports what's still missing
                values = {n: _replay_copy(v) for n, v in values.items()}
            for name, val in values.items():
                if name not in temp:
                    temp[name] = val
