"""Fallback recomputation — the Data Restorer (§5.3).

A versioned co-variable (X, t) that was never stored (unserializable) or
fails to load (missing/corrupt chunks) is reconstructed by
  1. loading the versioned co-variables the commit *accessed* (its recorded
     dependencies) into a temporary namespace — recursively restoring any of
     *those* that are themselves missing (dynamic & recursive fallback), and
  2. re-running the recorded command on that namespace.

Determinism comes from the substrate: commands draw randomness from RNG-key
leaves *inside* the namespace and data from versioned iterator state, so a
replay sees bit-identical inputs (the paper's caveat about non-deterministic
cells — §5.3 Remark — is discharged by construction here; cf. DESIGN.md §2).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

from repro.core.covariable import CovKey, group_covariables, RecordBuilder
from repro.core.graph import CheckpointGraph, parse_key
from repro.core.namespace import Namespace, TrackedNamespace


class RestoreError(Exception):
    pass


class DataRestorer:
    def __init__(self, graph: CheckpointGraph, loader,
                 registry: Dict[str, Callable], *, max_depth: int = 64):
        self.graph = graph
        self.loader = loader            # StateLoader (for dependency loads)
        self.registry = registry
        self.max_depth = max_depth
        self.replays = 0
        # per-checkout replay memo: version -> replayed namespace. Restoring
        # several co-variables of the same commit (or a chain of
        # det-replay commits) re-runs each command once, not once per
        # co-variable — the ARIES-style redo-caching the paper defers to
        # future work (§7.5.2).
        self._memo: Dict[str, Namespace] = {}

    def clear_memo(self) -> None:
        self._memo.clear()

    def recompute(self, key: CovKey, version: str, stats=None,
                  _depth: int = 0) -> Dict[str, Any]:
        if _depth > self.max_depth:
            raise RestoreError(f"recursion limit restoring {key} @ {version}")
        node = self.graph.nodes[version]
        cmd = node.command
        if cmd["name"] == "__init__":
            raise RestoreError(f"cannot recompute {key}: created at root")
        fn = self.registry.get(cmd["name"])
        if fn is None:
            raise RestoreError(f"command {cmd['name']!r} not registered")

        if version in self._memo:
            temp = self._memo[version]
            missing = [n for n in key if n not in temp]
            if not missing:
                return {n: temp[n] for n in key}

        # 1. restore dependencies (recursively if needed).  Dependencies
        #    that are loadable arrive through the parallel chunk engine in
        #    one prefetched pass (use_fallback=False: recursion depth is
        #    bookkept here, not inside the loader); only the unavailable
        #    remainder recurses into replay.
        temp = Namespace()
        dep_items = [(parse_key(s), v) for s, v in node.accessed.items()]
        prefetched = self.loader.load_covs(dep_items, stats,
                                           use_fallback=False)
        for dep_key, dep_version in dep_items:
            values = prefetched.get(dep_key)
            if values is None:
                if stats:
                    stats.covs_recomputed += 1
                values = self.recompute(dep_key, dep_version, stats,
                                        _depth + 1)
            for name, val in values.items():
                temp[name] = val

        # 2. re-run the recorded command
        tns = TrackedNamespace(temp)
        fn(tns, **cmd.get("args", {}))
        self.replays += 1
        self._memo[version] = temp

        # 3. extract the requested co-variable (membership may be verified
        #    against the recomputed aliasing)
        missing = [n for n in key if n not in temp]
        if missing:
            raise RestoreError(
                f"replay of {cmd['name']} did not produce {missing}")
        return {n: temp[n] for n in key}
