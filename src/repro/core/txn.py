"""Transactional commit engine — journaled, group-committed, crash-recoverable
checkpoint publication (DESIGN.md §13).

The paper's checkpoint mechanism must be *fault-tolerant*, not just
efficient: before this engine, a commit was two independent ``put_meta``
calls (commit doc, then HEAD), and with the async chunk writer HEAD could
advance to a commit whose chunks were still queued — a crash left dangling
manifests or a torn graph.  Every commit now runs as a journaled
transaction:

    WAL record  ⟶  chunk puts  ⟶  fence  ⟶  atomic multi-meta publish  ⟶  seal

  * **WAL record** — the journal lives under ``txn/`` metadata (atomic
    per-doc replace on every backend, mirrored across a fabric).  The
    chunk writer journals each batch's keys *before* the backend put, as
    a per-batch *part* document (``txn/<id>.pNNNN``) so journal traffic
    stays O(chunks) and rollback knows exactly which chunks a dead
    transaction had landed.  The open state exists on disk purely as
    parts — no parts and no base record means nothing happened — and the
    base record itself rides the publish batch, keeping the default sync
    path at one journal write per chunk batch plus the publish.
  * **Fence** — an epoch counter on the ``CheckpointWriter``
    (enqueued vs completed chunks) proves every chunk the group references
    is durable before any metadata names it; with ``async_publish`` the
    wait leaves the cell loop entirely, and a ``write_deadline_s`` bounds
    it (the straggler feature: a publish past the deadline references
    still-pending chunks, and checkout of those falls back to
    recomputation).  A *failed* fence (a chunk that never landed) aborts
    the group — its journal and chunks are rolled back and the engine
    poisons itself so no later commit can publish on top of the missing
    state; a failed *publish* poisons likewise, leaving its journal for
    recovery.
  * **Atomic publish** — the journal base record (status ``publish``,
    carrying the full docs), the commit docs, and HEAD go through one
    ``ChunkStore.put_meta_batch`` (one SQLite transaction, staged renames
    on a directory store, one scatter per fabric child), ordered base →
    docs → HEAD: even a torn non-atomic publish cannot leave HEAD naming
    an absent commit, and the base lands before anything it publishes so
    recovery can always finish the job.
  * **Seal** — deleting the journal docs marks the transaction complete.

**Group commit** batches the metadata of up to ``group_n`` consecutive
cells into one WAL + one publish + one seal — amortizing per-publish
round-trips/fsyncs (large on fabrics, where metadata mirrors to every
shard) at the cost of classic group-commit semantics: a crash can lose up
to ``group_n - 1`` of the most recent cells, never tear state.  With
``async_publish`` the publish pipeline runs on a background thread, hiding
publish latency behind the next cell's think time.

**Recovery** (:func:`recover`) runs on every session/graph open and behind
the CLI verb ``kishu recover``: a journal still in ``open`` state rolls
*back* (its journaled chunks are deleted; the graph never referenced them),
one in ``publish`` state rolls *forward* (the fence already proved its
chunks durable, and the WAL carries the full docs — the publish is simply
re-applied, idempotently).  Either way the store lands in a state
:func:`fsck` certifies: no torn HEAD, no missing parents or chunks, no
unsealed journals, no dangling chunks.  One scoping note: with
``async_write`` on, a kill can strand chunks whose journal sealed with an
earlier group (keys the drain thread journaled between that group's fence
and its post-fence snapshot); they can only ever surface as fsck-visible
*dangling* chunks that ``gc`` reclaims — never as referenced-but-missing
state, because rollback filters its deletes against every published
reference.  Sync-writer groups detach at kick time instead, which closes
the window entirely.
"""
from __future__ import annotations

import os
import threading
import time
import uuid
from contextlib import nullcontext
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Set

from repro.core.chunkstore import ChunkStore, namespace_views
from repro.core.graph import REFS_DOC, manifest_chunk_keys
from repro.core.lease import Lease, LeaseError

TXN_PREFIX = "txn/"
PART_SEP = ".p"               # txn/<id>.pNNNN — per-batch chunk-key parts
STATUS_OPEN = "open"          # chunks may have landed; nothing references them
STATUS_PUBLISH = "publish"    # fence passed, docs in WAL: roll forward


class TxnError(RuntimeError):
    """A publish failed (or the engine is poisoned by a failed chunk
    fence); surfaced on the commit/flush that observes it."""


class StaleHeadError(TxnError):
    """A publish would move HEAD *backwards*: the durable HEAD's ``seq`` is
    already at or past the one being published, meaning another writer (or
    an older resurrected session) has advanced the branch since this
    session loaded it.  Publishing anyway would orphan the newer commits —
    the `graph.py` read-modify-write race this guard turns into a hard
    fail.  Leases make the race unreachable in normal operation; the guard
    stays as defense in depth for lease-less sessions."""


def check_publish_guard(store: ChunkStore, docs: Dict[str, dict], *,
                        lease: Optional[Lease] = None) -> None:
    """The two pre-publish safety checks, shared by the engine and by
    direct metadata publishes (``graph.set_head``): the writer still holds
    its lease (:class:`~repro.core.lease.LeaseLost` if not), and the HEAD
    being published strictly advances the durable ``seq``
    (:class:`StaleHeadError` if not).  Reads only — never counted by
    crash-injection op sweeps."""
    if lease is not None:
        lease.ensure()
    head = docs.get("HEAD")
    if head is not None:
        cur = store.get_meta("HEAD")
        if cur is not None \
                and int(cur.get("seq", -1)) >= int(head.get("seq", -1)):
            raise StaleHeadError(
                f"durable HEAD seq {cur.get('seq')} >= publishing seq "
                f"{head.get('seq')}: another writer advanced this branch "
                f"(durable head={cur.get('head')!r}); reopen the session "
                f"to continue from the new state")


@dataclass
class TxnStats:
    txns: int = 0               # journal groups opened
    commits: int = 0            # commit docs routed through the engine
    publishes: int = 0          # multi-meta publish batches issued
    journal_puts: int = 0       # WAL writes (open / parts / amend)
    chunks_journaled: int = 0
    fence_wait_s: float = 0.0   # time publish spent proving chunk durability
    publish_s: float = 0.0      # amend + put_meta_batch + seal wall time


class TxnEngine:
    """Journaled, group-committed publisher for Checkpoint Graph metadata.

    ``fence(token)`` / ``fence_token()`` hook the chunk writer's epoch
    counter (``CheckpointWriter.wait_epoch`` / ``.epoch``): the token is
    captured when a publish starts and the fence blocks until every chunk
    enqueued at or before it is durable.  ``journal_chunks`` is installed
    as the writer's WAL hook, called immediately before each backend put
    batch.  Thread-safe: the async chunk writer journals from its drain
    thread while the async publisher publishes from its own.
    """

    def __init__(self, store: ChunkStore, *, group_n: int = 1,
                 async_publish: bool = False,
                 fence: Optional[Callable[[Optional[int]], None]] = None,
                 fence_token: Optional[Callable[[], int]] = None,
                 early_snapshot: bool = True):
        self.store = store
        self.group_n = max(1, int(group_n))
        self.async_publish = async_publish
        self.fence = fence
        self.fence_token = fence_token
        # early_snapshot: the group can be detached from new journal
        # joins at kick time, because every journaled chunk of a commit
        # is attributed before that commit() returns — true for the sync
        # chunk writer.  The async writer journals from its drain thread
        # with a lag, so there the snapshot must wait until after the
        # fence (see _publish_group).
        self.early_snapshot = early_snapshot
        #: optional writer lease checked (and kept renewed) on every
        #: publish; set by the owning session after acquisition
        self.lease: Optional[Lease] = None
        #: observability handle (set by the session) — used instead of the
        #: activation contextvar because async publishes run on a worker
        #: thread that never sees the session's activation
        self.obs = None
        #: per-engine nonce for journal IDs — two engines in one process
        #: share a pid and both start their counters at zero, so pid +
        #: counter alone collide when they open within the same ms
        self._nonce = uuid.uuid4().hex[:6]
        self.stats = TxnStats()
        self._lock = threading.RLock()     # open-group state
        self._pub_lock = threading.Lock()  # publishes are serialized
        self._open: Optional[dict] = None
        self._open_name: Optional[str] = None
        self._parts = 0                    # part docs written for open group
        self._n = 0
        self._errors: List[Exception] = []
        self._poisoned: Optional[Exception] = None
        self._worker: Optional[threading.Thread] = None
        self._wake = threading.Condition()
        self._pending: List[Optional[tuple]] = []   # queued group snapshots
        self._busy = False                 # worker holds a popped group
        self._closing = False
        if async_publish:
            self._worker = threading.Thread(target=self._publish_loop,
                                            daemon=True)
            self._worker.start()

    # ------------------------------------------------------------------
    # observability (no-ops until the session attaches a handle)
    # ------------------------------------------------------------------
    def _span(self, name: str, **args):
        return self.obs.span(name, **args) if self.obs is not None \
            else nullcontext()

    def _count(self, name: str, **labels) -> None:
        if self.obs is not None:
            self.obs.registry.counter(name, **labels).inc()

    def _observe(self, name: str, v: float) -> None:
        if self.obs is not None:
            self.obs.registry.histogram(name).observe(v)

    # ------------------------------------------------------------------
    # journal (WAL)
    # ------------------------------------------------------------------
    def _ensure_open(self) -> None:
        if self._open is None:
            # unique across sessions sharing a store: time + pid + a random
            # per-engine nonce + counter — pid alone is not enough (kishud
            # runs many engines in one process) and the ms timestamp alone
            # is not either (two sessions commit in the same millisecond)
            tid = (f"{int(time.time() * 1000):013d}"
                   f"-{os.getpid()}-{self._nonce}-{self._n:04d}")
            self._n += 1
            self._open_name = TXN_PREFIX + tid
            # nothing is written to the store yet: the open state exists
            # on disk purely as part docs (absence of any journal == clean
            # rollback by doing nothing), and the base record rides the
            # publish batch — keeping the happy path at one journal write
            # per chunk batch plus one per publish
            self._open = {"txn_id": tid, "status": STATUS_OPEN,
                          "chunks": [], "docs": {}, "n_commits": 0,
                          "ts": time.time()}
            self._parts = 0
            self.stats.txns += 1

    def journal_chunks(self, keys: List[str]) -> None:
        """WAL the chunk keys the writer is about to land (called before
        every backend put batch) — rollback's exact delete set.  Each batch
        is one *part* doc, so journal traffic is O(chunks), not O(chunks²).
        """
        keys = list(keys)
        if not keys:
            return
        with self._lock:
            self._ensure_open()
            part = f"{self._open_name}{PART_SEP}{self._parts:04d}"
            self._parts += 1
            self._open["chunks"].extend(keys)      # in-memory, abort path
            self.stats.chunks_journaled += len(keys)
            self.store.put_meta(part, {"txn_id": self._open["txn_id"],
                                       "chunks": keys})
            self.stats.journal_puts += 1

    # ------------------------------------------------------------------
    # commit / publish
    # ------------------------------------------------------------------
    def commit(self, docs: Dict[str, dict]) -> None:
        """Queue metadata documents for publication.  Iteration order is
        preserved as publish order, except ``HEAD`` which is always moved
        (and, across a group, re-moved) to the end.  Docs are queued
        *before* any deferred background error is raised, so a surfaced
        error never silently drops the commit that observed it."""
        if self._poisoned is not None:
            raise TxnError("commit engine poisoned by a failed chunk "
                           "fence; restart the session (recovery will "
                           "restore the last sealed state)") \
                from self._poisoned
        with self._lock:
            self._ensure_open()
            group = self._open["docs"]
            for name, doc in docs.items():
                if name in group:          # reposition: latest write wins,
                    del group[name]        # and HEAD must stay last
                group[name] = doc
            if "HEAD" in group:
                group["HEAD"] = group.pop("HEAD")
            self._open["n_commits"] += 1
            self.stats.commits += 1
            full = self._open["n_commits"] >= self.group_n
        if full:
            self._kick()
        self._raise_deferred()

    def _kick(self) -> None:
        # With early_snapshot the group detaches HERE, on the commit
        # thread: later journal_chunks calls open a fresh group, so a
        # concurrently publishing group can never seal away another
        # cell's journal parts.
        snap = self._pop_open() if self.early_snapshot else None
        if self.async_publish:
            with self._wake:
                self._pending.append(snap)
                self._wake.notify()
        else:
            self._publish_group(snap)

    def _publish_loop(self) -> None:
        while True:
            with self._wake:
                self._wake.wait_for(lambda: self._pending or self._closing)
                if not self._pending:
                    return            # closing, queue drained
                item = self._pending.pop(0)
                self._busy = True     # flush() must see pop+publish as one
            try:
                self._publish_group(item)
            except Exception as e:  # noqa: BLE001 — surfaced on flush
                self._errors.append(e)     # before _busy clears below, so
            finally:                       # a concurrent flush cannot miss
                with self._wake:           # the error
                    self._busy = False
                    self._wake.notify_all()

    def _pop_open(self):
        with self._lock:
            rec, name, parts = self._open, self._open_name, self._parts
            self._open = None
            self._open_name = None
            self._parts = 0
        return rec, name, parts

    def _seal(self, name: str, parts: int) -> None:
        # one batched round-trip; order is parts before base, so a crash
        # mid-seal (on a decomposing backend) leaves the base record and
        # recovery still sees — and finishes — the transaction
        self.store.delete_meta_batch(
            [f"{name}{PART_SEP}{i:04d}" for i in range(parts)] + [name])

    def _abort(self, snap, cause: Exception) -> None:
        """Fence or guard failure: the group must not publish.  Roll it
        back in-store (journal + journaled chunks) and poison the engine —
        the in-memory graph is ahead of durable state now, and publishing
        any descendant would tear the store.  The chunk delete is filtered
        against every published reference in every namespace: under
        content addressing a journaled key may coincide with a chunk some
        other commit (ours or another tenant's) already owns."""
        self._poisoned = cause
        rec, name, parts = snap
        if rec is None:
            return
        try:
            if rec["chunks"]:
                protected = published_chunks(self.store, use_refs=False)
                self.store.delete_chunks(
                    [k for k in rec["chunks"] if k not in protected])
            self._seal(name, parts)
        except Exception:  # noqa: BLE001 — backend down: recovery on next
            pass           # open rolls the journal back instead

    def _publish_group(self, snap: Optional[tuple]) -> None:
        """Fence, then publish one group.  ``snap`` is the group snapshot
        when it was detached at kick time (early_snapshot); ``None`` means
        detach here, *after* the fence — required for the async chunk
        writer, whose drain thread journals a commit's keys with a lag the
        fence bounds, so only a post-fence snapshot is guaranteed to hold
        them all.  (In that mode, keys for a *later* cell can be journaled
        between fence and snapshot; they seal away with this group and can
        only ever surface as fsck-visible dangling chunks — see the module
        docstring's scoping note.)"""
        with self._pub_lock:
            t0 = time.perf_counter()
            if self.fence is not None:
                with self._span("epoch_fence"):
                    try:
                        token = self.fence_token() if self.fence_token \
                            else None
                        self.fence(token)
                    except Exception as e:
                        self._abort(snap if snap is not None
                                    else self._pop_open(), e)
                        self._count("kishu_txn_aborts_total", kind="fence")
                        raise TxnError("chunk write failed; transaction "
                                       "rolled back") from e
            dt = time.perf_counter() - t0
            self.stats.fence_wait_s += dt
            self._observe("kishu_txn_fence_seconds", dt)
            rec, name, parts = snap if snap is not None else self._pop_open()
            if rec is None:
                return
            if not rec["docs"]:
                # chunks journaled but no commit ever referenced them
                # (flush mid-delta): roll the group back ourselves —
                # filtered like every rollback, since a journaled key may
                # coincide with published content
                if rec["chunks"]:
                    protected = published_chunks(self.store, use_refs=False)
                    self.store.delete_chunks(
                        [k for k in rec["chunks"] if k not in protected])
                self._seal(name, parts)
                return
            try:
                # writer still leased + HEAD strictly advances: both are
                # store reads, checked as late as possible before the batch
                check_publish_guard(self.store, rec["docs"],
                                    lease=self.lease)
            except (LeaseError, StaleHeadError) as e:
                self._abort((rec, name, parts), e)
                self._count("kishu_txn_aborts_total", kind="guard")
                raise TxnError("publish refused: another writer owns this "
                               "branch; transaction rolled back") from e
            t0 = time.perf_counter()
            with self._span("publish", commits=rec.get("n_commits", 0)):
                rec["status"] = STATUS_PUBLISH
                # the point of no return rides the atomic publish itself:
                # the base record (first) flips the journal to roll-forward,
                # then commit docs, then HEAD — one batch, one backend
                # round-trip; a kill inside a decomposed batch still
                # recovers, because the base lands before anything it
                # publishes
                batch = {name: {**rec, "chunks": []}}
                batch.update(rec["docs"])
                try:
                    self.store.put_meta_batch(batch)
                except Exception as e:
                    # the group's docs are gone from memory and may be
                    # partly on disk; recovery finishes (or reverts) the
                    # job from the journal — but a LATER commit must never
                    # publish a child of a commit this failure lost, so
                    # the engine poisons
                    self._poisoned = e
                    raise TxnError("publish failed; journal left for "
                                   "recovery") from e
                self.stats.journal_puts += 1
                self._seal(name, parts)
            self.stats.publishes += 1
            dt = time.perf_counter() - t0
            self.stats.publish_s += dt
            self._observe("kishu_txn_publish_seconds", dt)
            self._count("kishu_txn_publishes_total")

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------
    def _raise_deferred(self) -> None:
        if self._errors:
            errs, self._errors = self._errors, []
            raise TxnError("background publish failed") from errs[0]
        if self._poisoned is not None:
            raise TxnError("commit engine poisoned by a failed chunk "
                           "fence") from self._poisoned

    def pending_commits(self) -> int:
        with self._lock:
            return self._open["n_commits"] if self._open else 0

    def flush(self) -> None:
        """Publish everything queued and surface any background error."""
        if self.async_publish:
            with self._wake:
                self._wake.wait_for(
                    lambda: not self._pending and not self._busy)
        self._publish_group(self._pop_open() if self.early_snapshot
                            else None)
        self._raise_deferred()

    def close(self) -> None:
        if self._worker is not None:
            with self._wake:
                self._closing = True
                self._wake.notify_all()
            self._worker.join(timeout=5)
            self._worker = None


# ---------------------------------------------------------------------------
# recovery
# ---------------------------------------------------------------------------

def _referenced_chunks(store: ChunkStore) -> set:
    """Chunk keys referenced by any non-tombstone commit doc on the store
    (raw-meta version of ``CheckpointGraph.live_chunk_keys`` — same
    ``manifest_chunk_keys`` walker, so they cannot disagree)."""
    refs = set()
    for name in store.list_meta("commit/"):
        doc = store.get_meta(name) or {}
        if doc.get("deleted") is True:
            continue
        refs.update(manifest_chunk_keys(doc.get("manifests", {})))
    return refs


# ---------------------------------------------------------------------------
# cross-namespace reference accounting
# ---------------------------------------------------------------------------
#
# Chunks are content-addressed and SHARED across tenant namespaces (that is
# the dedup win), so no delete may consult a single namespace's references:
# rollback, abort, gc, and fsck's dangling check all build their live set
# from every namespace reachable through the store.

def published_chunks(store: ChunkStore, *, use_refs: bool = True) -> Set[str]:
    """Chunks referenced by published (non-tombstone) commits in *every*
    namespace of ``store`` — the root graph plus each ``tenant/<id>/``.

    With ``use_refs`` a namespace that maintains the transactional refcount
    doc (graph.REFS_DOC, kept consistent by riding the atomic publish
    batch) is read in one meta get; namespaces without one fall back to
    walking their commit docs.  Safety-critical delete filters pass
    ``use_refs=False`` to always walk — the authoritative source."""
    refs: Set[str] = set()
    for _, view in namespace_views(store):
        doc = view.get_meta(REFS_DOC) if use_refs else None
        counts = (doc or {}).get("counts")
        if counts is not None:
            refs.update(k for k, cn in counts.items() if cn[0] > 0)
        else:
            refs.update(_referenced_chunks(view))
    return refs


def journaled_chunks(store: ChunkStore, *,
                     skip_own: bool = False) -> Set[str]:
    """Chunks named by unsealed txn journals (base records + part docs)
    across every namespace.  These landed in the store but are not yet
    referenced by any commit — a *sibling session mid-transaction* — so
    cross-session GC must treat them as live.  ``skip_own`` excludes the
    namespace ``store`` itself is scoped to (rollback of our own dead
    journals must still protect every *other* namespace's in-flight
    chunks, but not its own)."""
    own_prefix = getattr(store, "meta_prefix", "")
    out: Set[str] = set()
    for tid, view in namespace_views(store):
        if skip_own and getattr(view, "meta_prefix", "") == own_prefix:
            continue
        for name in view.list_meta(TXN_PREFIX):
            doc = view.get_meta(name) or {}
            out.update(doc.get("chunks", []) or [])
    return out


def global_live_chunks(store: ChunkStore, *,
                       use_refs: bool = True) -> Set[str]:
    """The full cross-session live set: published references in every
    namespace plus every unsealed journal's chunks.  ``gc()`` may reap
    exactly the stored chunks NOT in this set."""
    return published_chunks(store, use_refs=use_refs) | \
        journaled_chunks(store)


def recover(store: ChunkStore) -> Dict[str, int]:
    """Replay or roll back every unsealed transaction.  Idempotent; runs on
    every graph/session open (a store with no ``txn/`` docs pays one
    ``list_meta`` call) and behind CLI ``kishu recover``.

    Two passes.  First, ``publish`` journals roll forward: their fence
    already proved chunk durability and the WAL carries the full docs, so
    the publish is simply re-applied (HEAD last) and sealed — except that
    a stale journal's HEAD never overwrites a *newer* durable HEAD (seq
    comparison), so a transient publish failure followed by successful
    later publishes cannot time-travel the store backwards on the next
    open.  Then ``open`` journals roll back: their journaled chunks
    (gathered from the per-batch part docs) are deleted and the journal
    dropped — HEAD still names the last sealed state.  The rollback delete
    is filtered against every chunk any (sealed or just-replayed) commit
    references, so it can never reach into published state — journaled
    chunk lists are CAS-new by construction, but the filter makes rollback
    unconditionally safe."""
    out = {"replayed": 0, "rolled_back": 0, "commits_published": 0,
           "chunks_dropped": 0}
    names = store.list_meta(TXN_PREFIX)
    if not names:
        return out
    bases: Dict[str, Optional[dict]] = {}
    parts: Dict[str, List[str]] = {}
    for name in names:
        if PART_SEP in name:
            parts.setdefault(name.split(PART_SEP, 1)[0], []).append(name)
        else:
            bases[name] = store.get_meta(name)
    for base in parts:              # orphan parts: treat as open journals
        bases.setdefault(base, None)

    def part_chunks(base: str) -> List[str]:
        keys: List[str] = []
        for pname in sorted(parts.get(base, [])):
            doc = store.get_meta(pname) or {}
            keys.extend(doc.get("chunks", []))
        return keys

    def seal(base: str) -> None:
        store.delete_meta_batch(sorted(parts.get(base, [])) + [base])

    for base, rec in bases.items():             # pass 1: roll forward
        if not rec or rec.get("status") != STATUS_PUBLISH:
            continue
        docs = dict(rec.get("docs", {}))
        head = docs.get("HEAD")
        cur = store.get_meta("HEAD")
        if head is not None and cur is not None \
                and cur.get("seq", -1) > head.get("seq", -1):
            docs.pop("HEAD")        # stale journal: keep the newer HEAD
        store.put_meta_batch(docs)
        out["replayed"] += 1
        out["commits_published"] += sum(1 for n in docs if n != "HEAD")
        seal(base)
    protected = None
    for base, rec in bases.items():             # pass 2: roll back
        if rec and rec.get("status") == STATUS_PUBLISH:
            continue
        chunks = ((rec or {}).get("chunks", []) or []) + part_chunks(base)
        if chunks:
            if protected is None:
                # global: chunks are shared across namespaces, so the
                # delete must spare content published by ANY tenant and
                # content journaled by a sibling still mid-transaction
                protected = published_chunks(store, use_refs=False) \
                    | journaled_chunks(store, skip_own=True)
            doomed = [k for k in chunks if k not in protected]
            out["chunks_dropped"] += store.delete_chunks(doomed)
        out["rolled_back"] += 1
        seal(base)
    _note_recovery(out)
    return out


def _note_recovery(out: Dict[str, int]) -> None:
    """Attribute a recovery's work to the opening session's metrics (the
    session activates its obs handle around graph construction)."""
    if not (out["replayed"] or out["rolled_back"]):
        return
    try:
        from repro import obs as obs_mod
        o = obs_mod.active()
        if o is None:
            return
        for kind in ("replayed", "rolled_back", "commits_published",
                     "chunks_dropped"):
            if out[kind]:
                o.registry.counter("kishu_txn_recover_total",
                                   kind=kind).inc(out[kind])
    except Exception:  # noqa: BLE001 — observability must not fail recovery
        pass


# ---------------------------------------------------------------------------
# maintenance: tombstone purge (shared by session.gc and CLI gc)
# ---------------------------------------------------------------------------

def purge_tombstones(store: ChunkStore, live_ids, *,
                     dry_run: bool = False) -> int:
    """Delete ``delete_branch`` tombstone docs (``{"deleted": True}``) for
    commits not in ``live_ids`` — without the purge every subsequent graph
    load re-reads the dead markers forever.  Returns the purge count."""
    purged = 0
    for name in store.list_meta("commit/"):
        if name[len("commit/"):] in live_ids:
            continue
        doc = store.get_meta(name)
        if doc is not None and doc.get("deleted") is True:
            if not dry_run:
                store.delete_meta(name)
            purged += 1
    return purged


# ---------------------------------------------------------------------------
# fsck
# ---------------------------------------------------------------------------

FSCK_MAX_DETAILS = 200      # counters stay exact; detail lines are capped
                            # so fsck of a store with 10^5 unreferenced
                            # chunks doesn't build 10^5 strings to print 20


@dataclass
class FsckReport:
    commits: int = 0
    head: Optional[str] = None
    unsealed_txns: int = 0
    torn_head: int = 0          # HEAD names a missing/tombstoned commit
    missing_parents: int = 0
    missing_chunks: int = 0     # referenced by a manifest, absent in store
    dangling_chunks: int = 0    # stored, referenced by nothing
    refs_drift: int = 0         # refcount doc disagrees with commit walk
    tombstones: int = 0         # purgeable delete_branch markers (warning)
    details: List[str] = field(default_factory=list)

    def note(self, line: str) -> None:
        if len(self.details) < FSCK_MAX_DETAILS:
            self.details.append(line)

    @property
    def problems(self) -> int:
        return (self.unsealed_txns + self.torn_head + self.missing_parents
                + self.missing_chunks + self.dangling_chunks
                + self.refs_drift)

    @property
    def clean(self) -> bool:
        return self.problems == 0


def fsck(store: ChunkStore) -> FsckReport:
    """Check every commit-engine invariant over the raw store (no graph
    construction, so the un-recovered state is inspectable): journals all
    sealed, HEAD resolvable, parents present, every referenced chunk
    stored, no unreferenced chunks, refcount doc in agreement with the
    commit walk.  Tombstones are reported but are not problems — ``gc``
    purges them.

    Graph invariants (HEAD, parents, journals, refcounts) are checked for
    the namespace ``store`` is scoped to; the *dangling* check is
    necessarily global — chunks are shared, so "referenced by nothing"
    means by no namespace's commits and no namespace's open journal.
    Use :func:`fsck_all` to audit every namespace of a shared store."""
    rep = FsckReport()
    seen = set()
    for name in store.list_meta(TXN_PREFIX):
        base = name.split(PART_SEP, 1)[0]
        if base in seen:
            continue
        seen.add(base)
        rec = store.get_meta(base) or {}
        rep.unsealed_txns += 1
        rep.note(f"unsealed txn {base} ({rec.get('status', '?')}, "
                 f"{rec.get('n_commits', 0)} commits)")
    nodes: Dict[str, dict] = {}
    for name in store.list_meta("commit/"):
        doc = store.get_meta(name)
        if not doc:
            continue
        if doc.get("deleted") is True:
            rep.tombstones += 1
            continue
        nodes[doc["commit_id"]] = doc
    rep.commits = len(nodes)
    head_doc = store.get_meta("HEAD")
    if head_doc:
        rep.head = head_doc.get("head")
        if rep.head is not None and rep.head not in nodes:
            rep.torn_head = 1
            rep.note(f"HEAD names missing commit {rep.head}")
    referenced = set()
    for cid, doc in nodes.items():
        parent = doc.get("parent")
        if parent is not None and parent not in nodes:
            rep.missing_parents += 1
            rep.note(f"{cid}: parent {parent} missing")
        referenced.update(manifest_chunk_keys(doc.get("manifests", {})))
    refs_doc = store.get_meta(REFS_DOC)
    if refs_doc is not None:
        counted = {k for k, cn in refs_doc.get("counts", {}).items()
                   if cn[0] > 0}
        for k in sorted(counted ^ referenced):
            rep.refs_drift += 1
            rep.note(f"refcount drift: {k} "
                     f"({'counted but unreferenced' if k in counted else 'referenced but uncounted'})")
    present = set(store.chunk_sizes(list(referenced)))
    for k in sorted(referenced - present):
        rep.missing_chunks += 1
        rep.note(f"missing chunk {k}")
    live = global_live_chunks(store, use_refs=False)
    for k in sorted(set(store.list_chunk_keys()) - live):
        rep.dangling_chunks += 1
        rep.note(f"dangling chunk {k}")
    return rep


def fsck_all(store: ChunkStore) -> Dict[str, FsckReport]:
    """Audit every namespace of a shared store — the root graph plus each
    ``tenant/<id>/`` — keyed by tenant id ('' for root).  A store is fully
    healthy iff every report is clean."""
    return {tid: fsck(view) for tid, view in namespace_views(store)}
