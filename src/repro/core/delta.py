"""Chunk-granular delta planning — shared by the writer and the loader.

Both hot paths move *only the state difference* (the paper's headline):

  - the checkpoint writer serializes just the dirty byte ranges of an
    updated base buffer (checkpoint.build_manifest), and
  - the checkout loader fetches and patches just the chunks that differ
    between the live buffer and the target manifest (checkout.StateLoader).

This module holds the pieces both need: dirty-index computation from
detection hashes, run coalescing, zero-copy/device-sliced range readers,
device-side patching, and the exact (hash-free) chunk compare built on the
``block_diff`` Pallas kernel with a NumPy fallback.

Range extraction never materializes the full buffer: NumPy bases are read
through a zero-copy ``memoryview``; JAX bases are sliced on device so only
the dirty ranges cross the device→host boundary.
"""
from __future__ import annotations

import contextlib
import logging
import os
from typing import Any, Callable, List, Optional, Sequence, Tuple

import numpy as np

_log = logging.getLogger(__name__)

# Device-kernel fallback observability: every silent degradation to a host
# path bumps a counter (snapshotted into WriteStats/CheckoutStats per
# operation) and the *first* one per session logs a warning — a silently
# slow path must be visible without turning every commit into log spam.
#
# The counter lives in the *active session's* metrics registry
# (repro.obs.active()) when a session is executing: under kishud many
# sessions share this process, so a module global would cross-attribute
# tenants and the fb0 delta snapshots would race.  The module globals below
# remain as a deprecated process-wide shim for callers running outside any
# session (tests, ad-hoc kernel use).
_kernel_fallbacks = 0
_fallback_logged = False


def _active_obs():
    try:
        from repro import obs as _obs
        return _obs.active()
    except Exception:  # noqa: BLE001 — obs must never break the hot path
        return None


def note_kernel_fallback(where: str, err: Exception) -> None:
    """Record one device-kernel → host-path degradation."""
    global _kernel_fallbacks, _fallback_logged
    _kernel_fallbacks += 1          # process-wide shim stays monotonic
    o = _active_obs()
    if o is not None:
        first = o.note_kernel_fallback(where)
    else:
        first = not _fallback_logged
        _fallback_logged = True
    if first:
        _log.warning(
            "device kernel unavailable in %s (%s: %s); using the host path. "
            "Logged once per session — see the kernel_fallbacks counter in "
            "WriteStats/CheckoutStats for the running total.",
            where, type(err).__name__, err)


def kernel_fallbacks() -> int:
    """Total device-kernel fallbacks — scoped to the active session's
    metrics registry when one is executing; otherwise the (deprecated)
    process-wide total."""
    o = _active_obs()
    if o is not None:
        return o.kernel_fallbacks()
    return _kernel_fallbacks


def dirty_indices(prev_hex: Sequence[str], cur_hex: Sequence[str]) -> List[int]:
    """Chunk indices whose detection hash differs (index-aligned compare).
    Indices present on only one side count as dirty."""
    n = max(len(prev_hex), len(cur_hex))
    return [i for i in range(n)
            if i >= len(prev_hex) or i >= len(cur_hex)
            or prev_hex[i] != cur_hex[i]]


def coalesce(indices: Sequence[int]) -> List[Tuple[int, int]]:
    """Sorted chunk indices -> [start, stop) runs, merging adjacency (one
    device slice / one store range per run instead of one per chunk)."""
    runs: List[Tuple[int, int]] = []
    for i in sorted(indices):
        if runs and runs[-1][1] == i:
            runs[-1] = (runs[-1][0], i + 1)
        else:
            runs.append((i, i + 1))
    return runs


def chunk_offsets(chunks: Sequence[dict]) -> List[int]:
    """Byte offset of each chunk in the assembled base blob."""
    offs, pos = [], 0
    for c in chunks:
        offs.append(pos)
        pos += int(c["n"])
    return offs


# ---------------------------------------------------------------------------
# dirty-range readers (writer side)
# ---------------------------------------------------------------------------

def range_reader(base: Any, chunk_bytes: int) -> Optional[Callable[[int, int], bytes]]:
    """Callable ``(lo, hi) -> bytes`` over the logical byte image of an
    array base, moving only the requested range; ``None`` when the leaf
    cannot be range-read (non-array, non-contiguous, unaligned chunking) —
    callers then fall back to full serialization.

    Ranges must start on a ``chunk_bytes`` boundary; the final range may end
    at the buffer length.  The byte image matches ``leaf_to_bytes`` (C-order
    raw bytes), so range-read chunks are bit-identical to full-path chunks.
    """
    import jax

    from repro.core.serialize import is_prng_key

    if isinstance(base, np.ndarray):
        if not base.flags["C_CONTIGUOUS"]:
            return None
        try:
            mv = memoryview(base).cast("B")
        except (TypeError, ValueError, BufferError):
            return None
        return lambda lo, hi: bytes(mv[lo:hi])

    if isinstance(base, jax.Array) and not is_prng_key(base):
        dt = np.dtype(base.dtype)
        item = dt.itemsize
        if item <= 0 or chunk_bytes % item:
            return None
        flat = base.reshape(-1)
        total = flat.shape[0] * item

        def read(lo: int, hi: int) -> bytes:
            hi = min(hi, total)
            # element-aligned by construction: lo is a chunk boundary and
            # hi is a chunk boundary or the buffer end
            seg = flat[lo // item: -(-hi // item)]
            return np.asarray(seg).tobytes()[: hi - lo]

        return read
    return None


# ---------------------------------------------------------------------------
# fused on-device delta pack (writer side, DESIGN.md §15)
# ---------------------------------------------------------------------------

def device_delta_pack(base: Any, prev_hashes, chunk_bytes: int):
    """One fused Pallas pass over a device array: detection hashes, dirty
    indices, and a *compacted* dirty-chunk buffer still on device — only
    dirty bytes ever cross device→host (``DeltaPack.read_chunks``).

    Returns ``None`` whenever the fused path doesn't apply — host array,
    PRNG key, non-power-of-two chunking, no/mismatched previous hashes, or
    no working kernel backend — and the caller degrades one rung down the
    ladder (``chunk_hashes_device`` → ``chunk_hashes_np`` + range_reader).
    Only engaged off-CPU by default — on CPU interpret-mode dispatch loses
    to NumPy — override with ``KISHU_DEVICE_DELTA=1/0``.
    """
    if prev_hashes is None or chunk_bytes % 4 \
            or chunk_bytes & (chunk_bytes - 1):
        return None
    env = os.environ.get("KISHU_DEVICE_DELTA", "").strip()
    if env == "0":
        return None
    import jax

    from repro.core.serialize import is_prng_key

    if env != "1" and jax.default_backend() == "cpu":
        return None
    if not isinstance(base, jax.Array) or is_prng_key(base):
        return None
    nbytes = int(base.size) * np.dtype(base.dtype).itemsize
    if nbytes <= 0:
        return None
    n_chunks = -(-nbytes // chunk_bytes)
    prev = np.asarray(prev_hashes, dtype=np.uint64).reshape(-1)
    if prev.shape[0] != n_chunks:
        return None                      # structure changed: no valid diff
    o = _active_obs()
    span = o.span("delta_pack", nbytes=nbytes) if o is not None \
        else contextlib.nullcontext()
    with span:
        try:
            from repro.kernels.delta_pack.ops import delta_pack_auto
            return delta_pack_auto(base, prev, chunk_bytes)
        except Exception as e:  # noqa: BLE001 — no kernel backend: host path
            note_kernel_fallback("device_delta_pack", e)
            return None


# ---------------------------------------------------------------------------
# chunk patching (loader side)
# ---------------------------------------------------------------------------

def patch_numpy_base(base: np.ndarray, segs: Sequence[Tuple[int, bytes]]
                     ) -> np.ndarray:
    """Write byte segments into a live base buffer in place (views and
    aliases into it stay valid).  Returns the same object."""
    mv = memoryview(base).cast("B")
    for off, data in segs:
        mv[off:off + len(data)] = data
    return base


def patch_device_chunks(base: Any, segs: Sequence[Tuple[int, bytes]],
                        chunk_bytes: int) -> Optional[Tuple[Any, int]]:
    """Fused checkout scatter: upload all dirty chunks of a device array as
    one compacted buffer and land them in a single Pallas pass
    (kernels/patch_scatter) — the mirror image of ``device_delta_pack``.

    Returns ``(patched array, bytes moved host→device)``, or ``None``
    whenever the fused path doesn't apply — host array, PRNG key,
    non-chunk-aligned segments, unsupported dtype, codec/env veto, or no
    working backend — and the caller degrades to the per-chunk
    ``patch_device_array`` loop below.  Only engaged off-CPU by default
    (interpret-mode dispatch loses to the jnp loop on CPU); override with
    ``KISHU_DEVICE_SCATTER=1/0``.
    """
    if not segs or chunk_bytes <= 0 or chunk_bytes % 4:
        return None
    env = os.environ.get("KISHU_DEVICE_SCATTER", "").strip()
    if env == "0":
        return None
    import jax

    from repro.core.serialize import is_prng_key

    if env != "1" and jax.default_backend() == "cpu":
        return None
    if not isinstance(base, jax.Array) or is_prng_key(base):
        return None
    nbytes = int(base.size) * np.dtype(base.dtype).itemsize
    if nbytes <= 0:
        return None
    n_chunks = -(-nbytes // chunk_bytes)
    idx: List[int] = []
    blobs: List[bytes] = []
    for off, data in sorted(segs):
        if off % chunk_bytes:
            return None                  # not chunk-aligned: DUS path
        i = off // chunk_bytes
        want = min((i + 1) * chunk_bytes, nbytes) - off
        if i >= n_chunks or len(data) != want:
            return None                  # partial chunk: DUS path
        idx.append(i)
        blobs.append(data)
    o = _active_obs()
    span = o.span("scatter_dev", chunks=len(idx)) if o is not None \
        else contextlib.nullcontext()
    with span:
        try:
            from repro.kernels.patch_scatter.ops import scatter_chunks_auto
            out, moved = scatter_chunks_auto(base, idx, blobs, chunk_bytes)
        except Exception as e:  # noqa: BLE001 — no backend: DUS path
            note_kernel_fallback("patch_device_chunks", e)
            return None
    if o is not None:
        try:
            o.registry.counter("kishu_h2d_bytes_total").inc(moved)
        except Exception:  # noqa: BLE001 — metrics are best-effort
            pass
    return out, moved


def patch_device_array(base: Any, segs: Sequence[Tuple[int, bytes]]) -> Any:
    """Patch a device array by updating only the dirty element ranges on
    device: the only host→device traffic is the dirty bytes themselves.
    Segments must be element-aligned (checked by the planner).  Returns a
    new array (device buffers are immutable)."""
    import jax
    import jax.numpy as jnp

    dt = np.dtype(base.dtype)
    item = dt.itemsize
    flat = base.reshape(-1)
    # merge adjacent segments: one dynamic_update_slice per contiguous run
    # (accumulate parts and join once — a long dirty run must not devolve
    # into quadratic bytes concatenation)
    merged: List[Tuple[int, List[bytes]]] = []
    end = -1
    for off, data in sorted(segs):
        if merged and end == off:
            merged[-1][1].append(data)
        else:
            merged.append((off, [data]))
        end = off + len(data)
    for off, parts in merged:
        seg = np.frombuffer(b"".join(parts), dtype=dt)
        flat = jax.lax.dynamic_update_slice(
            flat, jnp.asarray(seg), (off // item,))
    return flat.reshape(base.shape)


# ---------------------------------------------------------------------------
# exact chunk compare (hash-free cross-check)
# ---------------------------------------------------------------------------

def exact_dirty_indices(a: Any, b: Any, chunk_bytes: int) -> List[int]:
    """Chunk indices where ``a`` and ``b`` differ bitwise — the exact
    (collision-free) answer the detection hashes approximate.  Uses the
    ``block_diff`` Pallas kernel for device arrays (jnp ref, then NumPy
    byte-compare as fallbacks); used by tests and paranoid verification to
    cross-check hash-planned deltas."""
    import jax

    if isinstance(a, jax.Array) and isinstance(b, jax.Array) \
            and chunk_bytes % 4 == 0 and chunk_bytes & (chunk_bytes - 1) == 0:
        try:
            from repro.kernels.block_diff.ops import dirty_chunks
            return [int(i) for i in dirty_chunks(a, b, chunk_bytes)]
        except Exception as e:  # noqa: BLE001 — kernel unavailable:
            note_kernel_fallback("exact_dirty_indices", e)  # host compare
    ba = np.ascontiguousarray(np.asarray(a)).reshape(-1).view(np.uint8)
    bb = np.ascontiguousarray(np.asarray(b)).reshape(-1).view(np.uint8)
    if ba.size != bb.size:
        raise ValueError("exact_dirty_indices: size mismatch")
    n_chunks = max(-(-ba.size // chunk_bytes), 1) if ba.size else 0
    out = []
    for i in range(n_chunks):
        lo, hi = i * chunk_bytes, min((i + 1) * chunk_bytes, ba.size)
        if not np.array_equal(ba[lo:hi], bb[lo:hi]):
            out.append(i)
    return out
