"""Kishu core — time-traveling for JAX training/serving sessions.

The paper's contribution (incremental checkpoint & checkout over a
Checkpoint Graph at co-variable granularity) as a composable library:

    from repro.core import KishuSession, open_store
    s = KishuSession(open_store("dir:///tmp/ckpt"))
    s.register("train", train_command)
    s.init_state({"params": params, "opt": opt_state, "rng": key})
    c1 = s.run("train", steps=100)
    c2 = s.run("train", steps=100)
    s.checkout(c1)          # sub-second undo: loads only diverged co-variables
"""
from repro.core.chunkstore import (ChunkCache, ChunkStore, CompressedStore,
                                   DirectoryStore, FaultInjectedStore,
                                   FaultInjectingStore, InjectedCrash,
                                   MemoryStore, SQLiteStore,
                                   available_codecs, open_store)
from repro.core.txn import FsckReport, TxnEngine, TxnError, fsck, recover
from repro.core.fabric import (HashRing, ReplicatedStore, ScrubReport,
                               ShardedStore, TieredStore, parse_topology,
                               rebalance, scrub)
from repro.core.covariable import (CovKey, LeafRecord, RecordBuilder,
                                   StateDelta, cov_key, detect_delta,
                                   group_covariables)
from repro.core.graph import CheckpointGraph, CheckoutPlan, CommitNode
from repro.core.planner import (CheckoutPlanner, CovPlan, PricedPlan,
                                StoreCostModel, format_plan,
                                resolve_plan_mode)
from repro.core.namespace import (Namespace, TrackedNamespace, flatten_tree,
                                  unflatten_tree)
from repro.core.serialize import (ChunkMissingError, OpaqueLeaf,
                                  SerializationError)
from repro.core.session import KishuSession, RunStats
from repro.core.baselines import (DetReplaySession, DumpSession,
                                  PageIncremental)

__all__ = [
    "ChunkCache", "ChunkStore", "CompressedStore", "DirectoryStore",
    "FaultInjectedStore", "MemoryStore", "SQLiteStore", "available_codecs",
    "open_store", "CovKey", "LeafRecord", "RecordBuilder",
    "StateDelta", "cov_key", "detect_delta", "group_covariables",
    "CheckpointGraph", "CheckoutPlan", "CommitNode", "Namespace",
    "TrackedNamespace", "flatten_tree", "unflatten_tree",
    "ChunkMissingError", "OpaqueLeaf", "SerializationError", "KishuSession",
    "RunStats", "DetReplaySession", "DumpSession", "PageIncremental",
    "HashRing", "ReplicatedStore", "ScrubReport", "ShardedStore",
    "TieredStore", "parse_topology", "rebalance", "scrub",
    "FaultInjectingStore", "InjectedCrash", "FsckReport", "TxnEngine",
    "TxnError", "fsck", "recover",
    "CheckoutPlanner", "CovPlan", "PricedPlan", "StoreCostModel",
    "format_plan", "resolve_plan_mode",
]
