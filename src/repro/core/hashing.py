"""Chunk-hash specification — the VarGraph node-compare, TPU-adapted.

One hash definition, three interchangeable implementations that MUST agree
bit-for-bit (tested):

  - :func:`chunk_hashes_np`   — vectorized NumPy (host path; used by the
                                 session on CPU arrays)
  - :func:`chunk_hashes_jnp`  — pure jnp (oracle for the Pallas kernel)
  - ``repro.kernels.chunk_hash`` — Pallas TPU kernel (HBM-bandwidth path)

Design: an order-sensitive, embarrassingly-parallel 2x32-bit hash.  Each
uint32 word is avalanche-mixed with its position, lanes are XOR-reduced, and
the chunk byte-length is folded in (so zero-padding cannot collide with real
zeros of a different length).  XOR-reduction makes the hash a pure map-reduce:
ideal for the VPU (no sequential dependency, unlike FNV).

Detection-grade hashing: equality of the 64-bit pair is treated as
"unchanged" (false-equal probability ~2^-64 per chunk — the same accuracy
class as the paper's pickling assumption, DESIGN.md §2).  *Storage* keys use
blake2b (exact) in the chunk store; this hash only decides what to inspect.
"""
from __future__ import annotations

import os
from typing import Optional

import numpy as np

GOLDEN = np.uint32(0x9E3779B9)
C1 = np.uint32(0x85EBCA6B)
C2 = np.uint32(0xC2B2AE35)
SEEDS = (np.uint32(0), np.uint32(0x517CC1B7))
DEFAULT_CHUNK_BYTES = 1 << 20


def _mix_np(w: np.ndarray, idx: np.ndarray, seed: np.uint32,
            n_valid: np.ndarray) -> np.ndarray:
    """Avalanche-mix words with their position; words past ``n_valid`` (zero
    padding) contribute 0, so the hash is independent of padding length."""
    with np.errstate(over="ignore"):
        m = (w ^ (idx * GOLDEN + seed)) * C1
        m ^= m >> np.uint32(16)
        m = m * C2
        m ^= m >> np.uint32(13)
    return np.where(idx < n_valid, m, np.uint32(0))


def _finalize_np(h: np.ndarray, nbytes: np.ndarray) -> np.ndarray:
    with np.errstate(over="ignore"):
        h = (h ^ nbytes.astype(np.uint32)) * C1
        h ^= h >> np.uint32(16)
    return h


def _effective_chunk_bytes(n: int, chunk_bytes: int) -> int:
    """Clamp the chunk size to the buffer length (word-aligned) so a huge
    configured chunk size (whole-co-variable mode) never allocates a huge
    zero pad.  Hash equality only ever compares same-length buffers, so the
    clamp is consistent across versions."""
    if chunk_bytes >= n:
        return max(((n + 3) // 4) * 4, 4)
    return chunk_bytes


def chunk_hashes_np(buf: bytes | np.ndarray,
                    chunk_bytes: int = DEFAULT_CHUNK_BYTES) -> np.ndarray:
    """Per-chunk 64-bit hashes of a byte buffer. Returns uint64 [n_chunks]."""
    raw = np.frombuffer(buf, dtype=np.uint8) if isinstance(buf, (bytes, bytearray, memoryview)) \
        else np.ascontiguousarray(buf).reshape(-1).view(np.uint8)
    n = raw.size
    if n == 0:
        return np.zeros((0,), np.uint64)
    assert chunk_bytes % 4 == 0
    chunk_bytes = _effective_chunk_bytes(n, chunk_bytes)
    n_chunks = -(-n // chunk_bytes)
    padded = np.zeros(n_chunks * chunk_bytes, np.uint8)
    padded[:n] = raw
    words = padded.view(np.uint32).reshape(n_chunks, chunk_bytes // 4)
    idx = np.arange(chunk_bytes // 4, dtype=np.uint32)[None, :]
    nbytes = np.minimum(
        np.full(n_chunks, chunk_bytes, np.int64),
        n - np.arange(n_chunks, dtype=np.int64) * chunk_bytes)
    n_valid = ((nbytes + 3) // 4).astype(np.uint32)[:, None]
    lanes = []
    for seed in SEEDS:
        m = _mix_np(words, idx, seed, n_valid)
        h = np.bitwise_xor.reduce(m, axis=1)
        lanes.append(_finalize_np(h, nbytes))
    return (lanes[0].astype(np.uint64) << np.uint64(32)) | lanes[1].astype(np.uint64)


def chunk_hashes_jnp(words, nbytes):
    """jnp oracle over pre-chunked words.

    words: uint32 [n_chunks, words_per_chunk]; nbytes: int32 [n_chunks]
    (true byte count per chunk).  Returns uint32 [n_chunks, 2].
    """
    import jax
    import jax.numpy as jnp
    idx = jnp.arange(words.shape[1], dtype=jnp.uint32)[None, :]
    n_valid = ((nbytes.astype(jnp.uint32) + 3) // 4)[:, None]
    outs = []
    for seed in SEEDS:
        m = (words ^ (idx * jnp.uint32(GOLDEN) + jnp.uint32(seed))) * jnp.uint32(C1)
        m = m ^ (m >> 16)
        m = m * jnp.uint32(C2)
        m = m ^ (m >> 13)
        m = jnp.where(idx < n_valid, m, jnp.uint32(0))
        h = jax.lax.reduce(m, jnp.uint32(0), jax.lax.bitwise_xor, (1,))
        h = (h ^ nbytes.astype(jnp.uint32)) * jnp.uint32(C1)
        h = h ^ (h >> 16)
        outs.append(h)
    return jnp.stack(outs, axis=-1)


def hashes_hex(h) -> list:
    """uint64 [n] -> 16-char hex strings (manifest / record interchange)."""
    if h is None:
        return []
    return [format(int(x), "016x") for x in np.asarray(h, dtype=np.uint64)]


def chunk_hashes_device(x, chunk_bytes: int = DEFAULT_CHUNK_BYTES
                        ) -> Optional[np.ndarray]:
    """Detection hashes of a *device* array without a host round-trip.

    Dispatches to the Pallas ``chunk_hash`` kernel (HBM-bandwidth path),
    degrading to the jnp oracle and finally to ``None`` (caller hashes on
    host via :func:`chunk_hashes_np`).  Only engaged off-CPU by default —
    on CPU the NumPy path is faster than jit dispatch — override with
    ``KISHU_DEVICE_HASH=1/0``.  Bit-identical to ``chunk_hashes_np`` by the
    kernel contract (tested).
    """
    if chunk_bytes % 4 or chunk_bytes & (chunk_bytes - 1):
        return None                 # kernel wants a power-of-two chunk
    env = os.environ.get("KISHU_DEVICE_HASH", "").strip()
    if env == "0":
        return None
    if env != "1":
        import jax
        if jax.default_backend() == "cpu":
            return None
    try:
        from repro.kernels.chunk_hash.ops import chunk_hash_u64_auto
        return chunk_hash_u64_auto(x, chunk_bytes)
    except Exception:  # noqa: BLE001 — no device backend: host path
        return None


def combine_u64(lanes) -> np.ndarray:
    """uint32 [n,2] -> uint64 [n] (matches chunk_hashes_np packing)."""
    lanes = np.asarray(lanes)
    return (lanes[:, 0].astype(np.uint64) << np.uint64(32)) \
        | lanes[:, 1].astype(np.uint64)


def split_u64(h) -> np.ndarray:
    """uint64 [n] -> uint32 [n,2] lanes (inverse of :func:`combine_u64`) —
    the previous-hash operand of the fused ``delta_pack`` kernel."""
    h = np.asarray(h, dtype=np.uint64)
    return np.stack([(h >> np.uint64(32)).astype(np.uint32),
                     (h & np.uint64(0xFFFFFFFF)).astype(np.uint32)], axis=1)


def words_view(buf: bytes | np.ndarray, chunk_bytes: int):
    """Pre-chunk a buffer for the jnp/pallas paths.

    Returns (words uint32 [n_chunks, W], nbytes int32 [n_chunks]).
    """
    raw = np.frombuffer(buf, dtype=np.uint8) if isinstance(buf, (bytes, bytearray, memoryview)) \
        else np.ascontiguousarray(buf).reshape(-1).view(np.uint8)
    n = raw.size
    chunk_bytes = _effective_chunk_bytes(max(n, 1), chunk_bytes)
    n_chunks = max(-(-n // chunk_bytes), 1)
    padded = np.zeros(n_chunks * chunk_bytes, np.uint8)
    padded[:n] = raw
    words = padded.view(np.uint32).reshape(n_chunks, chunk_bytes // 4)
    nbytes = np.minimum(
        np.full(n_chunks, chunk_bytes, np.int64),
        np.maximum(n - np.arange(n_chunks, dtype=np.int64) * chunk_bytes, 0))
    return words, nbytes.astype(np.int32)
