"""Checkpoint Graph — branch-based state versioning (§5.1–5.2, Defs 4–6).

A directed tree of commits.  Each node stores:
  - the *state delta*: manifests for co-variables updated by the command
  - the command spec (name/args/seed) — the "cell code" for fallback replay
  - the versioned co-variables the command *accessed* (its dependencies)
  - a snapshot of the full session-state index {co-variable -> version}
    (footnote 5 of the paper), making Def-5 resolution O(1) and checkout
    divergence (Def 6) a single index comparison.

The explicit LCA method (`identical_via_lca`) implements Def 6 literally and
is cross-checked against the index diff in property tests.
"""
from __future__ import annotations

import json
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from repro.core.chunkstore import ChunkStore
from repro.core.covariable import CovKey

KEY_SEP = "\x1f"


def key_str(key: CovKey) -> str:
    return KEY_SEP.join(key)


def parse_key(s: str) -> CovKey:
    return tuple(s.split(KEY_SEP))


def manifest_chunk_keys(manifests: Dict[str, dict]):
    """Chunk keys referenced by a commit doc's manifest map — THE single
    definition of a chunk reference, shared by gc marking
    (``live_chunk_keys``), recovery's rollback filter, and fsck, so the
    three can never disagree about what is referenced."""
    for man in manifests.values():
        if man.get("unserializable"):
            continue
        for c in man.get("base", {}).get("chunks", []):
            yield c["key"]


def manifest_chunk_entries(manifests: Dict[str, dict]):
    """Like :func:`manifest_chunk_keys` but yields ``(key, nbytes)`` pairs
    (the manifest's per-chunk logical length), for refcount accounting."""
    for man in manifests.values():
        if man.get("unserializable"):
            continue
        for c in man.get("base", {}).get("chunks", []):
            yield c["key"], int(c.get("n", 0))


#: per-namespace chunk refcount document.  Rides the same atomic publish
#: batch as the commit docs and HEAD, so it can never disagree with the
#: published graph — crash recovery's roll-forward replays it with them.
REFS_DOC = "refs"


class ChunkRefCounts:
    """Chunk refcounts for one namespace: ``{key: [n_commits, nbytes]}``.

    Counts are per *commit* (a commit referencing one key from several
    co-variables counts once), so ``add``/``remove`` of the same commit's
    manifests are exactly symmetric.  The count answers cross-session GC's
    question — "does any commit in this namespace still need this chunk?"
    — in one meta read instead of a full commit walk, and the per-key
    ``nbytes`` gives the byte total quotas are enforced against
    (:meth:`bytes_live` counts shared chunks toward every tenant that
    references them: dedup is a storage win, not a billing loophole)."""

    def __init__(self, counts: Optional[Dict[str, list]] = None):
        self.counts: Dict[str, list] = counts or {}

    @classmethod
    def from_doc(cls, doc: Optional[dict]) -> "ChunkRefCounts":
        return cls({k: list(v) for k, v in
                    (doc or {}).get("counts", {}).items()})

    @classmethod
    def from_nodes(cls, nodes: Dict[str, "CommitNode"]) -> "ChunkRefCounts":
        """Rebuild from a loaded graph — the upgrade path for stores
        written before refcounts existed."""
        refs = cls()
        for node in nodes.values():
            refs.add(node.manifests)
        return refs

    def to_doc(self) -> dict:
        return {"counts": {k: v for k, v in self.counts.items() if v[0] > 0}}

    def add(self, manifests: Dict[str, dict]) -> None:
        seen = set()
        for key, nbytes in manifest_chunk_entries(manifests):
            if key in seen:
                continue
            seen.add(key)
            cn = self.counts.setdefault(key, [0, nbytes])
            cn[0] += 1
            cn[1] = max(cn[1], nbytes)

    def remove(self, manifests: Dict[str, dict]) -> None:
        seen = set()
        for key, _ in manifest_chunk_entries(manifests):
            if key in seen:
                continue
            seen.add(key)
            cn = self.counts.get(key)
            if cn is not None:
                cn[0] -= 1
                if cn[0] <= 0:
                    del self.counts[key]

    def live_keys(self) -> set:
        return {k for k, cn in self.counts.items() if cn[0] > 0}

    def bytes_live(self) -> int:
        return sum(cn[1] for cn in self.counts.values() if cn[0] > 0)


@dataclass
class CommitNode:
    commit_id: str
    parent: Optional[str]
    depth: int
    timestamp: float
    command: dict                      # {"name", "args"} — the "cell code"
    manifests: Dict[str, dict]         # key_str -> manifest (the delta)
    deleted: List[str]                 # key_strs removed by this command
    accessed: Dict[str, str]           # key_str -> version (dependencies)
    state_index: Dict[str, str]        # key_str -> version (Def 5 snapshot)
    message: str = ""
    stats: dict = field(default_factory=dict)

    def to_doc(self) -> dict:
        return {
            "commit_id": self.commit_id, "parent": self.parent,
            "depth": self.depth, "timestamp": self.timestamp,
            "command": self.command, "manifests": self.manifests,
            "deleted": self.deleted, "accessed": self.accessed,
            "state_index": self.state_index, "message": self.message,
            "stats": self.stats,
        }

    @classmethod
    def from_doc(cls, doc: dict) -> "CommitNode":
        return cls(**doc)


@dataclass
class CheckoutPlan:
    to_load: Dict[CovKey, str]         # cov -> version to load
    to_delete: List[CovKey]
    identical: List[CovKey]
    # chunk-level refinement, filled in by StateLoader.plan_patches: diverged
    # co-variables whose live buffer matches the target structurally are
    # *patched* (fetch only differing chunks) instead of fully materialized
    patches: List[Any] = field(default_factory=list)

    @property
    def n_diverged(self) -> int:
        return len(self.to_load)

    @property
    def n_patched(self) -> int:
        return len(self.patches)


class CheckpointGraph:
    def __init__(self, store: ChunkStore, *, engine=None,
                 recover: bool = True):
        self.store = store
        # commit publication routes through the transactional engine when
        # one is attached (txn.TxnEngine): journaled, group-committed,
        # fenced against async chunk writes.  Engine-less graphs still
        # publish through the atomic put_meta_batch (doc before HEAD).
        self.engine = engine
        self.nodes: Dict[str, CommitNode] = {}
        self.children: Dict[str, List[str]] = {}
        self.head: Optional[str] = None
        self._seq = 0
        self._meta_bytes = 0    # cached sum of serialized node docs —
                                # storage_stats() must not re-dump the graph
        if recover:
            from repro.core import txn as txn_mod
            txn_mod.recover(store)     # replay/roll back unsealed txns
        self._load()

    # ------------------------------------------------------------------
    # persistence
    # ------------------------------------------------------------------
    def _load(self) -> None:
        for name in self.store.list_meta("commit/"):
            doc = self.store.get_meta(name)
            if not doc or doc.get("deleted") is True:
                continue    # delete_branch tombstone ({"deleted": True});
                            # a commit's own "deleted" field is a list
            node = CommitNode.from_doc(doc)
            self.nodes[node.commit_id] = node
            self._meta_bytes += len(json.dumps(node.to_doc()))
        for node in self.nodes.values():
            if node.parent is not None:
                self.children.setdefault(node.parent, []).append(node.commit_id)
        head_doc = self.store.get_meta("HEAD")
        if head_doc:
            self.head = head_doc["head"]
            self._seq = head_doc["seq"]
        refs_doc = self.store.get_meta(REFS_DOC)
        if refs_doc is not None:
            self.refs = ChunkRefCounts.from_doc(refs_doc)
        else:
            # pre-refcount store: rebuild from the loaded commits; the doc
            # itself first lands with the next publish that carries it
            self.refs = ChunkRefCounts.from_nodes(self.nodes)

    def _persist(self, node: CommitNode) -> None:
        doc = node.to_doc()
        self._meta_bytes += len(json.dumps(doc))
        self.refs.add(node.manifests)
        # the refcount doc travels in the same atomic batch as the commit
        # and HEAD: a torn publish (or its crash-recovery replay) can never
        # leave counts disagreeing with the published graph.  Order is
        # refs -> commit doc -> HEAD: on a decomposing backend the commit
        # doc still lands immediately before HEAD, preserving the
        # invariant that a torn publish never leaves HEAD naming an absent
        # commit (recovery squares the refs ledger either way)
        docs = {REFS_DOC: self.refs.to_doc(),
                f"commit/{node.commit_id}": doc,
                "HEAD": {"head": self.head, "seq": self._seq}}
        if self.engine is not None:
            self.engine.commit(docs)
        else:
            self.store.put_meta_batch(docs)    # atomic where the backend
                                               # allows; always doc-then-HEAD

    # ------------------------------------------------------------------
    # commits
    # ------------------------------------------------------------------
    def init_root(self) -> CommitNode:
        assert not self.nodes, "graph already initialized"
        root = CommitNode(
            commit_id="c00000", parent=None, depth=0, timestamp=time.time(),
            command={"name": "__init__", "args": {}}, manifests={},
            deleted=[], accessed={}, state_index={}, message="session start")
        self.nodes[root.commit_id] = root
        self.head = root.commit_id
        self._seq = 1
        self._persist(root)
        return root

    def commit(self, *, command: dict, manifests: Dict[str, dict],
               deleted_keys: List[CovKey], accessed: Dict[CovKey, str],
               updated_keys: List[CovKey], message: str = "",
               stats: Optional[dict] = None) -> CommitNode:
        assert self.head is not None
        parent = self.nodes[self.head]
        cid = f"c{self._seq:05d}"
        self._seq += 1

        index = dict(parent.state_index)
        for k in deleted_keys:
            index.pop(key_str(k), None)
        for k in updated_keys:
            index[key_str(k)] = cid

        node = CommitNode(
            commit_id=cid, parent=parent.commit_id, depth=parent.depth + 1,
            timestamp=time.time(), command=command, manifests=manifests,
            deleted=[key_str(k) for k in deleted_keys],
            accessed={key_str(k): v for k, v in accessed.items()},
            state_index=index, message=message, stats=stats or {})
        self.nodes[cid] = node
        self.children.setdefault(parent.commit_id, []).append(cid)
        self.head = cid
        self._persist(node)
        return node

    def set_head(self, commit_id: str) -> None:
        assert commit_id in self.nodes, commit_id
        self.head = commit_id
        if self.engine is not None:
            # publish any queued commits first: durable HEAD must never
            # name a commit whose doc is still in an open group
            self.engine.flush()
        # every HEAD movement — checkout included — advances seq, so a
        # concurrent (or resurrected) writer holding a stale seq fails the
        # publish guard instead of silently rewinding the branch.  Commit
        # ids derive from seq, so ids skip a number after a checkout;
        # nothing orders by density, only by monotonicity.
        self._seq += 1
        docs = {"HEAD": {"head": self.head, "seq": self._seq}}
        from repro.core import txn as txn_mod
        txn_mod.check_publish_guard(self.store, docs,
                                    lease=getattr(self.engine, "lease",
                                                  None))
        self.store.put_meta_batch(docs)

    def forget(self, commit_id: str) -> None:
        """Drop a commit from the in-memory graph (branch deletion),
        keeping children, refcounts, and the cached meta-bytes accounting
        in step.  The caller owns the on-store tombstone (and persists the
        decremented refcount doc in the same batch)."""
        node = self.nodes.pop(commit_id, None)
        if node is None:
            return
        self._meta_bytes -= len(json.dumps(node.to_doc()))
        self.refs.remove(node.manifests)
        self.children.pop(commit_id, None)
        if node.parent in self.children:
            self.children[node.parent] = [
                c for c in self.children[node.parent] if c != commit_id]

    # ------------------------------------------------------------------
    # queries
    # ------------------------------------------------------------------
    def lca(self, a: str, b: str) -> str:
        na, nb = self.nodes[a], self.nodes[b]
        while na.depth > nb.depth:
            na = self.nodes[na.parent]
        while nb.depth > na.depth:
            nb = self.nodes[nb.parent]
        while na.commit_id != nb.commit_id:
            na, nb = self.nodes[na.parent], self.nodes[nb.parent]
        return na.commit_id

    def state_index(self, t: str) -> Dict[str, str]:
        return self.nodes[t].state_index

    def identical_via_lca(self, key: CovKey, ta: str, tb: str) -> bool:
        """Def 6, literally: X identical between states ta and tb iff a single
        versioned co-variable (X, tc) is in the states of ta, tb and their LCA."""
        ks = key_str(key)
        tc = self.lca(ta, tb)
        va = self.nodes[ta].state_index.get(ks)
        vb = self.nodes[tb].state_index.get(ks)
        vc = self.nodes[tc].state_index.get(ks)
        return va is not None and va == vb == vc

    def diff(self, cur: str, tgt: str) -> CheckoutPlan:
        """Divergence between two states via index comparison (== Def 6)."""
        ci = self.nodes[cur].state_index
        ti = self.nodes[tgt].state_index
        to_load = {parse_key(k): v for k, v in ti.items() if ci.get(k) != v}
        to_delete = [parse_key(k) for k in ci if k not in ti]
        identical = [parse_key(k) for k, v in ci.items() if ti.get(k) == v]
        return CheckoutPlan(to_load=to_load, to_delete=to_delete,
                            identical=identical)

    def manifest_of(self, key: CovKey, version: str) -> Optional[dict]:
        return self.nodes[version].manifests.get(key_str(key))

    def live_chunk_keys(self) -> set:
        """Chunk keys referenced by any live commit's manifests — the GC
        mark set (shared by session gc and the CLI so they cannot disagree
        on what is garbage)."""
        live = set()
        for node in self.nodes.values():
            live.update(manifest_chunk_keys(node.manifests))
        return live

    def log(self, limit: int = 0) -> List[dict]:
        out = []
        for cid in sorted(self.nodes):
            n = self.nodes[cid]
            out.append({"commit": cid, "parent": n.parent,
                        "command": n.command.get("name"),
                        "message": n.message,
                        "updated": len(n.manifests),
                        "deleted": len(n.deleted),
                        # measured cell cost (None on pre-planner docs —
                        # the planner substitutes a conservative default)
                        "exec_s": n.stats.get("exec_s"),
                        "replays": int(n.stats.get("replays", 0) or 0),
                        "head": cid == self.head})
        return out[-limit:] if limit else out

    def path_from_root(self, t: str) -> List[str]:
        out = []
        node = self.nodes[t]
        while node is not None:
            out.append(node.commit_id)
            node = self.nodes[node.parent] if node.parent else None
        return out[::-1]

    def total_meta_bytes(self) -> int:
        """Serialized size of all commit docs — maintained incrementally
        (commit/load/forget), so ``storage_stats()`` is O(1) instead of
        re-dumping every node's JSON on each call."""
        return self._meta_bytes
