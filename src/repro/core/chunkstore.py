"""Content-addressed chunk store with pluggable backends.

The Checkpoint Graph stores versioned co-variables as *manifests* referencing
immutable chunks keyed by blake2b-128 of their content (exact, unlike the
detection hash).  Content addressing gives cross-version and cross-branch
dedup for free — the storage-level core of Kishu's "small incremental
checkpoints" result, plus our beyond-paper chunk-level dedup (DESIGN.md §2).

Backends:
  - MemoryStore     — dicts (benchmark baseline for pure algorithm cost)
  - DirectoryStore  — one file per chunk, sharded dirs; shard-local writers
                      on a multi-host cluster never contend (DESIGN.md §8)
  - SQLiteStore     — single-file deployment, as the paper ships (§6.1)

Fault-injection wrappers simulate chunk loss (-> fallback recomputation) and
slow hosts (-> straggler deadline / async writer tests).
"""
from __future__ import annotations

import hashlib
import json
import os
import sqlite3
import threading
import time
import zlib
from collections import OrderedDict
from dataclasses import dataclass
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core import parallel
from repro.core.serialize import ChunkMissingError


def chunk_key(data: bytes) -> str:
    return hashlib.blake2b(data, digest_size=16).hexdigest()


# ---------------------------------------------------------------------------
# per-chunk codec layer
# ---------------------------------------------------------------------------
#
# Chunks are keyed by the blake2b of their *logical* (uncompressed) content,
# so dedup and manifests are codec-agnostic; a compressed chunk is stored as
# a tagged frame:  MAGIC(4) | codec_id(1) | raw_len(8 LE) | payload.
# Reads are transparently decoded by every backend (frame sniffing), so a
# store written with compression stays readable by uncompressed readers and
# vice versa — old stores contain only unframed chunks, which pass through
# untouched.  Incompressible chunks are stored raw (the frame must *save*
# bytes to be used), so pathological data costs nothing.

CHUNK_MAGIC = b"KZC1"
_FRAME_HDR = len(CHUNK_MAGIC) + 1 + 8


@dataclass(frozen=True)
class ChunkCodec:
    codec_id: int
    name: str
    compress: Callable[[bytes], bytes]
    decompress: Callable[[bytes], bytes]
    # optional sampled pre-check: False -> data judged incompressible, the
    # encode is skipped entirely and the chunk stored raw (the skip is what
    # WriteStats.chunks_codec_skipped counts)
    probe: Optional[Callable[[bytes], bool]] = None


def _build_codecs() -> Dict[int, ChunkCodec]:
    out = {1: ChunkCodec(1, "zlib",
                         lambda b: zlib.compress(b, 1), zlib.decompress)}
    try:                                   # optional, not a hard dependency
        import zstandard as _zstd
        _zc, _zd = _zstd.ZstdCompressor(level=3), _zstd.ZstdDecompressor()
        out[2] = ChunkCodec(2, "zstd", _zc.compress, _zd.decompress)
    except Exception:  # noqa: BLE001 — absent/broken module: codec skipped
        pass
    try:
        import lz4.frame as _lz4
        out[3] = ChunkCodec(3, "lz4", _lz4.compress, _lz4.decompress)
    except Exception:  # noqa: BLE001
        pass
    try:
        # bit-plane codec (kernels/delta_codec): the host half is pure
        # numpy, so registering it here keeps every backend/CLI able to
        # decode device-encoded frames without an accelerator stack
        from repro.kernels.delta_codec import host as _bshuf
        out[_bshuf.CODEC_ID] = ChunkCodec(
            _bshuf.CODEC_ID, _bshuf.CODEC_NAME,
            _bshuf.bitplane_compress, _bshuf.bitplane_decompress,
            probe=_bshuf.bitplane_probe)
    except Exception:  # noqa: BLE001
        pass
    return out


_CODECS_BY_ID = _build_codecs()
_CODECS_BY_NAME = {c.name: c for c in _CODECS_BY_ID.values()}


def available_codecs() -> List[str]:
    return sorted(_CODECS_BY_NAME)


def resolve_codec(codec) -> Optional[ChunkCodec]:
    """None/"raw"/"none" -> no compression; "auto" -> best available
    (zstd > lz4 > zlib); a name -> that codec or ValueError."""
    if codec is None or isinstance(codec, ChunkCodec):
        return codec
    name = str(codec).lower()
    if name in ("raw", "none", ""):
        return None
    if name == "auto":
        for pick in ("zstd", "lz4", "zlib"):
            if pick in _CODECS_BY_NAME:
                return _CODECS_BY_NAME[pick]
        return None
    if name not in _CODECS_BY_NAME:
        raise ValueError(f"unknown chunk codec {codec!r}; "
                         f"available: {available_codecs()}")
    return _CODECS_BY_NAME[name]


_CODEC_STORED = 0                 # escape frame: payload is the raw bytes


def encode_chunk(data: bytes, codec: Optional[ChunkCodec]) -> bytes:
    """Frame ``data`` with ``codec`` iff that actually saves bytes.

    Raw data that happens to *begin with the magic* is escaped into a
    "stored" frame (codec id 0) so decoding stays unambiguous — without
    this, such a chunk would be misparsed as a frame on read."""
    if codec is not None and (codec.probe is None or codec.probe(data)):
        comp = codec.compress(data)
        if len(comp) + _FRAME_HDR < len(data):
            return (CHUNK_MAGIC + bytes([codec.codec_id])
                    + len(data).to_bytes(8, "little") + comp)
    if data.startswith(CHUNK_MAGIC):
        return (CHUNK_MAGIC + bytes([_CODEC_STORED])
                + len(data).to_bytes(8, "little") + data)
    return data


def decode_chunk(data: bytes) -> bytes:
    """Transparent inverse of :func:`encode_chunk`: unframed chunks pass
    through; framed chunks decompress (or unwrap the "stored" escape).
    Anything that merely *looks* like a frame but fails to parse — an
    unregistered codec id, a failed decompression, a length mismatch — is
    returned verbatim: it is far more likely a raw legacy chunk whose bytes
    coincide with the magic than a valid frame, and genuinely corrupt or
    codec-unavailable chunks are still caught downstream by the manifest's
    per-chunk size and content-address checks (-> fallback recomputation).
    """
    if len(data) < _FRAME_HDR or not data.startswith(CHUNK_MAGIC):
        return data
    codec_id = data[len(CHUNK_MAGIC)]
    raw_len = int.from_bytes(data[len(CHUNK_MAGIC) + 1:_FRAME_HDR], "little")
    if codec_id == _CODEC_STORED:
        if raw_len == len(data) - _FRAME_HDR:
            return data[_FRAME_HDR:]
        return data
    codec = _CODECS_BY_ID.get(codec_id)
    if codec is None:
        return data
    try:
        raw = codec.decompress(data[_FRAME_HDR:])
    except Exception:  # noqa: BLE001 — not a real frame (or corrupt)
        return data
    if len(raw) != raw_len:
        return data
    return raw


# ---------------------------------------------------------------------------
# shared chunk cache
# ---------------------------------------------------------------------------

DEFAULT_CACHE_BYTES = 64 << 20


def resolve_cache_bytes(n: Optional[int] = None) -> int:
    """Effective cache capacity: explicit arg > $KISHU_CACHE_BYTES > 64 MiB.
    ``0`` disables the cache."""
    if n is None:
        env = os.environ.get("KISHU_CACHE_BYTES", "").strip()
        try:
            n = int(env) if env else DEFAULT_CACHE_BYTES
        except ValueError:
            n = DEFAULT_CACHE_BYTES
    return max(0, int(n))


class ChunkCache:
    """Bounded LRU over *logical* chunk bytes, shared between the
    CheckpointWriter and the StateLoader: chunks written this session are
    served back to checkout without touching the backend at all, and chunks
    fetched once stay warm for the next time-travel hop.  Thread-safe (the
    async writer populates it from its drain thread)."""

    def __init__(self, max_bytes: Optional[int] = None):
        self.max_bytes = resolve_cache_bytes(max_bytes)
        self._d: "OrderedDict[str, bytes]" = OrderedDict()
        self._bytes = 0
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._d)

    @property
    def bytes_used(self) -> int:
        return self._bytes

    def put(self, key: str, data: bytes) -> None:
        if self.max_bytes <= 0 or len(data) > self.max_bytes:
            return
        with self._lock:
            old = self._d.pop(key, None)
            if old is not None:
                self._bytes -= len(old)
            self._d[key] = data
            self._bytes += len(data)
            while self._bytes > self.max_bytes:
                _, evicted = self._d.popitem(last=False)
                self._bytes -= len(evicted)

    def put_many(self, mapping: Dict[str, bytes]) -> None:
        for k, v in mapping.items():
            self.put(k, v)

    def get(self, key: str) -> Optional[bytes]:
        if self.max_bytes <= 0:
            return None
        with self._lock:
            data = self._d.get(key)
            if data is None:
                self.misses += 1
                return None
            self._d.move_to_end(key)
            self.hits += 1
            return data

    def contains(self, key: str) -> bool:
        """Non-mutating membership probe: no LRU promotion, no hit/miss
        accounting — the checkout planner prices cache-resident chunks at
        zero without perturbing the cache's behavior."""
        if self.max_bytes <= 0:
            return False
        with self._lock:
            return key in self._d

    def get_many(self, keys: Iterable[str]) -> Dict[str, bytes]:
        out: Dict[str, bytes] = {}
        for k in keys:
            data = self.get(k)
            if data is not None:
                out[k] = data
        return out

    def discard(self, key: str) -> None:
        with self._lock:
            old = self._d.pop(key, None)
            if old is not None:
                self._bytes -= len(old)

    def clear(self) -> None:
        with self._lock:
            self._d.clear()
            self._bytes = 0


class ChunkStore:
    """Interface: immutable chunks + small JSON metadata documents.

    Besides the per-chunk primitives, backends implement *batched* operations
    (``get_chunks`` / ``put_chunks`` / ``list_chunk_keys``) natively — one
    transaction for SQLite, a thread pool for the directory store — which the
    parallel I/O engine (parallel.py, DESIGN.md §9) and GC build on.  The
    base-class defaults degrade to per-chunk loops, so wrappers that inject
    per-chunk behavior (faults, delays) inherit correct pass-through
    semantics for free.

    Engine hints (class attributes):
      - ``supports_parallel_get``: False when concurrent fetches cannot beat
        a direct loop (pure in-memory stores have no round-trip to hide);
        the checkout pipeline then takes the serial path.
      - ``min_slab``: minimum keys per batched fetch — backends with
        per-statement overhead (SQL) want large slabs to amortize it.
      - ``native_scatter``: True when ``get_chunks`` drives its own
        cross-device concurrency (the sharded fabric's scatter-gather);
        bulk fetches then hand the store the whole key set in one call —
        slicing it into slabs would only add synchronization barriers on
        top of the store's internal parallelism.
    """

    supports_parallel_get = True
    min_slab = 1
    native_scatter = False

    def put_chunk(self, key: str, data: bytes) -> bool:
        raise NotImplementedError

    def get_chunk(self, key: str) -> bytes:
        raise NotImplementedError

    def get_chunk_stored(self, key: str) -> bytes:
        """The chunk's *stored* representation (codec frame included), for
        replication/placement machinery that moves chunks between backends:
        healing with decoded bytes would silently drop compression.  The
        default degrades to the decoded form — correct, since frames decode
        transparently on read, just not byte-preserving."""
        return self.get_chunk(key)

    def has_chunk(self, key: str) -> bool:
        raise NotImplementedError

    # ---- batched ops (parallel engine + GC entry points) ----
    def get_chunks(self, keys: Sequence[str], *,
                   missing_ok: bool = False) -> Dict[str, bytes]:
        """Fetch many chunks; returns {key: data}.  With ``missing_ok``
        absent chunks are simply omitted, else ChunkMissingError."""
        out: Dict[str, bytes] = {}
        for k in keys:
            if k in out:
                continue
            try:
                out[k] = self.get_chunk(k)
            except ChunkMissingError:
                if not missing_ok:
                    raise
        return out

    def put_chunks(self, pairs: Sequence[Tuple[str, bytes]]) -> int:
        """Store many chunks; returns the number newly written."""
        written = 0
        for k, d in pairs:
            if self.put_chunk(k, d):
                written += 1
        return written

    # ---- stored-form puts (device-encoded frames) ----
    #
    # ``data`` is already a KZC1 codec frame whose key was computed over the
    # *logical* bytes (the on-device codec emits frames directly, so the raw
    # bytes never exist on the host).  The base default just stores the
    # bytes verbatim — correct for every raw backend, since reads decode
    # frames transparently — while codec wrappers override these to bypass
    # re-encoding (double-framing would corrupt the chunk: one decode would
    # yield the inner frame, not the logical bytes).

    def put_chunk_stored(self, key: str, data: bytes) -> bool:
        return self.put_chunk(key, data)

    def put_chunks_stored(self, pairs: Sequence[Tuple[str, bytes]]) -> int:
        # delegating to put_chunks keeps the backend's native batching
        # (sqlite transactions, fabric scatter); only wrappers that
        # *transform* data on put (CompressedStore) must override
        return self.put_chunks(pairs)

    def list_chunk_keys(self) -> List[str]:
        """All chunk keys currently stored (GC / fsck enumeration)."""
        raise NotImplementedError

    def chunk_sizes(self, keys: Sequence[str]) -> Dict[str, int]:
        """Byte size per existing chunk (missing keys omitted) — metadata
        only where the backend allows, for GC accounting."""
        out: Dict[str, int] = {}
        for k in keys:
            try:
                out[k] = len(self.get_chunk(k))
            except ChunkMissingError:
                pass
        return out

    def put_meta(self, name: str, doc: dict) -> None:
        raise NotImplementedError

    def put_meta_batch(self, docs: "Dict[str, dict]") -> None:
        """Publish several metadata documents as one unit, as atomically as
        the backend allows (SQLite: one transaction; directory: staged tmp
        files then a tight rename loop; memory: a single dict update).
        Iteration order is the publish order — the transaction engine puts
        HEAD last so even a torn non-atomic publish can never leave HEAD
        naming a commit whose doc is absent.  The base default degrades to
        ordered per-doc puts, which fault-injection wrappers rely on to
        land a crash *between* documents."""
        for name, doc in docs.items():
            self.put_meta(name, doc)

    def get_meta(self, name: str) -> Optional[dict]:
        raise NotImplementedError

    def list_meta(self, prefix: str) -> List[str]:
        raise NotImplementedError

    def delete_meta(self, name: str) -> None:
        """Remove a metadata document (journal seals, tombstone purges);
        idempotent — deleting an absent doc is a no-op."""
        raise NotImplementedError

    def delete_meta_batch(self, names: Sequence[str]) -> None:
        """Remove several metadata documents, backend-batched where
        possible (one SQLite transaction) — the commit engine seals a
        transaction's journal docs in one round-trip.  Iteration order is
        the delete order; the default degrades to per-doc deletes, which
        fault-injection wrappers rely on to land a crash mid-seal."""
        for name in names:
            self.delete_meta(name)

    def delete_chunk(self, key: str) -> None:
        raise NotImplementedError

    def delete_chunks(self, keys: Sequence[str]) -> int:
        """Delete many chunks with backend-native batching (one SQL
        ``executemany``, pooled unlinks); returns the number of chunks
        actually removed.  The GC paths (``KishuSession.gc`` / CLI ``gc``)
        call this instead of looping ``delete_chunk``."""
        removed = 0
        for k in keys:
            if self.has_chunk(k):
                self.delete_chunk(k)
                removed += 1
        return removed

    # ---- stats ----
    def chunk_bytes_total(self) -> int:
        raise NotImplementedError

    def n_chunks(self) -> int:
        raise NotImplementedError


class MemoryStore(ChunkStore):
    supports_parallel_get = False     # dict access: no latency to overlap

    def __init__(self):
        self.chunks: Dict[str, bytes] = {}
        self.meta: Dict[str, dict] = {}
        self.put_count = 0
        self.put_bytes = 0

    def put_chunk(self, key, data):
        self.put_count += 1
        if key in self.chunks:
            return False
        self.chunks[key] = bytes(data)
        self.put_bytes += len(data)
        return True

    def get_chunk(self, key):
        try:
            return decode_chunk(self.chunks[key])
        except KeyError:
            raise ChunkMissingError(key) from None

    def get_chunk_stored(self, key):
        try:
            return self.chunks[key]
        except KeyError:
            raise ChunkMissingError(key) from None

    def get_chunks(self, keys, *, missing_ok=False):
        chunks = self.chunks
        if missing_ok:
            return {k: decode_chunk(chunks[k]) for k in keys if k in chunks}
        try:
            return {k: decode_chunk(chunks[k]) for k in keys}
        except KeyError as e:
            raise ChunkMissingError(e.args[0]) from None

    def list_chunk_keys(self):
        return list(self.chunks)

    def chunk_sizes(self, keys):
        chunks = self.chunks
        return {k: len(chunks[k]) for k in keys if k in chunks}

    def has_chunk(self, key):
        return key in self.chunks

    def delete_chunk(self, key):
        self.chunks.pop(key, None)

    def delete_chunks(self, keys):
        return sum(self.chunks.pop(k, None) is not None for k in keys)

    def put_meta(self, name, doc):
        self.meta[name] = json.loads(json.dumps(doc))

    def put_meta_batch(self, docs):
        # serialize everything first, install in one update: a failure while
        # preparing leaves the published metadata untouched
        prepared = {n: json.loads(json.dumps(d)) for n, d in docs.items()}
        self.meta.update(prepared)

    def get_meta(self, name):
        return self.meta.get(name)

    def list_meta(self, prefix):
        return sorted(k for k in self.meta if k.startswith(prefix))

    def delete_meta(self, name):
        self.meta.pop(name, None)

    def chunk_bytes_total(self):
        return sum(len(v) for v in self.chunks.values())

    def n_chunks(self):
        return len(self.chunks)


class DirectoryStore(ChunkStore):
    def __init__(self, root: str):
        self.root = root
        os.makedirs(os.path.join(root, "chunks"), exist_ok=True)
        os.makedirs(os.path.join(root, "meta"), exist_ok=True)

    def _chunk_path(self, key: str) -> str:
        return os.path.join(self.root, "chunks", key[:2], key)

    def put_chunk(self, key, data):
        path = self._chunk_path(key)
        if os.path.exists(path):
            return False
        os.makedirs(os.path.dirname(path), exist_ok=True)
        tmp = path + f".tmp.{os.getpid()}.{threading.get_ident()}"
        with open(tmp, "wb") as f:
            f.write(data)
        os.replace(tmp, path)  # atomic; idempotent across concurrent writers
        return True

    def get_chunk(self, key):
        try:
            with open(self._chunk_path(key), "rb") as f:
                return decode_chunk(f.read())
        except FileNotFoundError:
            raise ChunkMissingError(key) from None

    def get_chunk_stored(self, key):
        try:
            with open(self._chunk_path(key), "rb") as f:
                return f.read()
        except FileNotFoundError:
            raise ChunkMissingError(key) from None

    def has_chunk(self, key):
        return os.path.exists(self._chunk_path(key))

    def get_chunks(self, keys, *, missing_ok=False):
        # Thread-pooled reads: each open/read releases the GIL in the
        # syscall, so concurrent chunk files stream in parallel.
        def read_one(key):
            try:
                return key, self.get_chunk(key)
            except ChunkMissingError:
                if not missing_ok:
                    raise
                return key, None
        uniq = list(dict.fromkeys(keys))
        got = parallel.map_parallel(read_one, uniq)
        return {k: v for k, v in got if v is not None}

    def put_chunks(self, pairs):
        def write_one(pair):
            return self.put_chunk(pair[0], pair[1])
        return sum(bool(w) for w in parallel.map_parallel(write_one,
                                                          list(pairs)))

    def list_chunk_keys(self):
        out = []
        for _, _, files in os.walk(os.path.join(self.root, "chunks")):
            out.extend(f for f in files if not f.endswith(".tmp")
                       and ".tmp." not in f)
        return out

    def chunk_sizes(self, keys):
        out = {}
        for k in keys:
            try:
                out[k] = os.path.getsize(self._chunk_path(k))
            except FileNotFoundError:
                pass
        return out

    def delete_chunk(self, key):
        try:
            os.remove(self._chunk_path(key))
        except FileNotFoundError:
            pass

    def delete_chunks(self, keys):
        # pooled unlinks: each remove releases the GIL in the syscall
        def rm_one(key):
            try:
                os.remove(self._chunk_path(key))
                return True
            except FileNotFoundError:
                return False
        return sum(parallel.map_parallel(rm_one, list(keys)))

    def _meta_path(self, name: str) -> str:
        return os.path.join(self.root, "meta", name.replace("/", "__") + ".json")

    def put_meta(self, name, doc):
        path = self._meta_path(name)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(doc, f)
        os.replace(tmp, path)

    def put_meta_batch(self, docs):
        # stage every doc as a tmp file first, then a tight rename loop:
        # each rename is individually atomic, and the torn window between
        # renames is syscall-narrow (the commit journal covers even that)
        staged = []
        for name, doc in docs.items():
            path = self._meta_path(name)
            tmp = path + f".tmp.{os.getpid()}.{threading.get_ident()}"
            with open(tmp, "w") as f:
                json.dump(doc, f)
            staged.append((tmp, path))
        for tmp, path in staged:
            os.replace(tmp, path)

    def get_meta(self, name):
        try:
            with open(self._meta_path(name)) as f:
                return json.load(f)
        except FileNotFoundError:
            return None

    def delete_meta(self, name):
        try:
            os.remove(self._meta_path(name))
        except FileNotFoundError:
            pass

    def list_meta(self, prefix):
        mdir = os.path.join(self.root, "meta")
        pre = prefix.replace("/", "__")
        return sorted(f[:-5].replace("__", "/") for f in os.listdir(mdir)
                      if f.startswith(pre) and f.endswith(".json"))

    def chunk_bytes_total(self):
        total = 0
        cdir = os.path.join(self.root, "chunks")
        for d, _, files in os.walk(cdir):
            for f in files:
                total += os.path.getsize(os.path.join(d, f))
        return total

    def n_chunks(self):
        return sum(len(files) for _, _, files in
                   os.walk(os.path.join(self.root, "chunks")))


class SQLiteStore(ChunkStore):
    min_slab = 32                     # amortize per-SELECT overhead

    def __init__(self, path: str):
        self.path = path
        self._local = threading.local()
        con = self._con()
        con.execute("CREATE TABLE IF NOT EXISTS chunks"
                    " (key TEXT PRIMARY KEY, data BLOB)")
        con.execute("CREATE TABLE IF NOT EXISTS meta"
                    " (name TEXT PRIMARY KEY, doc TEXT)")
        con.commit()

    def _con(self) -> sqlite3.Connection:
        if not hasattr(self._local, "con"):
            self._local.con = sqlite3.connect(self.path)
        return self._local.con

    def put_chunk(self, key, data):
        con = self._con()
        cur = con.execute("INSERT OR IGNORE INTO chunks VALUES (?, ?)",
                          (key, sqlite3.Binary(data)))
        con.commit()
        return cur.rowcount > 0

    def get_chunk(self, key):
        row = self._con().execute(
            "SELECT data FROM chunks WHERE key=?", (key,)).fetchone()
        if row is None:
            raise ChunkMissingError(key)
        return decode_chunk(bytes(row[0]))

    def get_chunk_stored(self, key):
        row = self._con().execute(
            "SELECT data FROM chunks WHERE key=?", (key,)).fetchone()
        if row is None:
            raise ChunkMissingError(key)
        return bytes(row[0])

    def has_chunk(self, key):
        return self._con().execute(
            "SELECT 1 FROM chunks WHERE key=?", (key,)).fetchone() is not None

    # IN-clause batch bound: SQLite's default variable limit is 999.
    _SQL_BATCH = 500

    def get_chunks(self, keys, *, missing_ok=False):
        uniq = list(dict.fromkeys(keys))
        con = self._con()
        out: Dict[str, bytes] = {}
        for i in range(0, len(uniq), self._SQL_BATCH):
            part = uniq[i:i + self._SQL_BATCH]
            marks = ",".join("?" * len(part))
            rows = con.execute(
                f"SELECT key, data FROM chunks WHERE key IN ({marks})", part)
            for k, d in rows:
                out[k] = decode_chunk(bytes(d))
        if not missing_ok and len(out) != len(uniq):
            missing = next(k for k in uniq if k not in out)
            raise ChunkMissingError(missing)
        return out

    def put_chunks(self, pairs):
        # One transaction for the whole batch: a single fsync instead of one
        # per chunk — the dominant cost of the serial write path.
        con = self._con()
        before = con.total_changes
        con.executemany(
            "INSERT OR IGNORE INTO chunks VALUES (?, ?)",
            [(k, sqlite3.Binary(d)) for k, d in pairs])
        con.commit()
        return con.total_changes - before

    def list_chunk_keys(self):
        return [r[0] for r in self._con().execute("SELECT key FROM chunks")]

    def chunk_sizes(self, keys):
        uniq = list(dict.fromkeys(keys))
        con = self._con()
        out: Dict[str, int] = {}
        for i in range(0, len(uniq), self._SQL_BATCH):
            part = uniq[i:i + self._SQL_BATCH]
            marks = ",".join("?" * len(part))
            rows = con.execute(
                f"SELECT key, LENGTH(data) FROM chunks"
                f" WHERE key IN ({marks})", part)
            for k, n in rows:
                out[k] = int(n)
        return out

    def delete_chunk(self, key):
        con = self._con()
        con.execute("DELETE FROM chunks WHERE key=?", (key,))
        con.commit()

    def delete_chunks(self, keys):
        # one transaction for the whole sweep: a single fsync, like put_chunks
        con = self._con()
        before = con.total_changes
        con.executemany("DELETE FROM chunks WHERE key=?",
                        [(k,) for k in keys])
        con.commit()
        return con.total_changes - before

    def put_meta(self, name, doc):
        con = self._con()
        con.execute("INSERT OR REPLACE INTO meta VALUES (?, ?)",
                    (name, json.dumps(doc)))
        con.commit()

    def put_meta_batch(self, docs):
        # one transaction: the whole publish (commit docs + HEAD) is atomic
        con = self._con()
        con.executemany("INSERT OR REPLACE INTO meta VALUES (?, ?)",
                        [(n, json.dumps(d)) for n, d in docs.items()])
        con.commit()

    def get_meta(self, name):
        row = self._con().execute(
            "SELECT doc FROM meta WHERE name=?", (name,)).fetchone()
        return json.loads(row[0]) if row else None

    def delete_meta(self, name):
        con = self._con()
        con.execute("DELETE FROM meta WHERE name=?", (name,))
        con.commit()

    def delete_meta_batch(self, names):
        con = self._con()
        con.executemany("DELETE FROM meta WHERE name=?",
                        [(n,) for n in names])
        con.commit()

    def list_meta(self, prefix):
        rows = self._con().execute(
            "SELECT name FROM meta WHERE name LIKE ?", (prefix + "%",))
        return sorted(r[0] for r in rows)

    def chunk_bytes_total(self):
        row = self._con().execute(
            "SELECT COALESCE(SUM(LENGTH(data)),0) FROM chunks").fetchone()
        return int(row[0])

    def n_chunks(self):
        return int(self._con().execute(
            "SELECT COUNT(*) FROM chunks").fetchone()[0])


class CompressedStore(ChunkStore):
    """Write-side codec wrapper: chunks are framed with ``codec`` on every
    put path; reads pass through (all backends decode frames natively), so
    compressed and uncompressed chunks mix freely in one store and either
    reader works against either writer.  Tracks logical vs stored bytes so
    benchmarks and the CLI can report the compression win."""

    def __init__(self, inner: ChunkStore, codec="auto"):
        self.inner = inner
        self.codec = resolve_codec(codec)
        self.min_slab = getattr(inner, "min_slab", 1)
        self.supports_parallel_get = getattr(inner, "supports_parallel_get",
                                             True)
        self.native_scatter = getattr(inner, "native_scatter", False)
        self.logical_put_bytes = 0
        self.stored_put_bytes = 0
        self.chunks_codec_skipped = 0     # probe said "incompressible"

    def _encode(self, data: bytes) -> bytes:
        codec = self.codec
        if codec is not None and codec.probe is not None \
                and not codec.probe(data):
            self.chunks_codec_skipped += 1
            codec = None                  # probe veto: store raw
        enc = encode_chunk(data, codec)
        self.logical_put_bytes += len(data)
        self.stored_put_bytes += len(enc)
        return enc

    def put_chunk(self, key, data):
        return self.inner.put_chunk(key, self._encode(data))

    def put_chunks(self, pairs):
        return self.inner.put_chunks([(k, self._encode(d)) for k, d in pairs])

    # device-encoded frames are already in stored form: re-encoding would
    # double-frame them (a decode would then yield the inner frame, not the
    # logical bytes) — bypass the codec, keep the byte accounting honest
    def put_chunk_stored(self, key, data):
        self.stored_put_bytes += len(data)
        return self.inner.put_chunk_stored(key, data)

    def put_chunks_stored(self, pairs):
        self.stored_put_bytes += sum(len(d) for _, d in pairs)
        return self.inner.put_chunks_stored(pairs)

    def get_chunk(self, key):
        return self.inner.get_chunk(key)

    def get_chunk_stored(self, key):
        return self.inner.get_chunk_stored(key)

    def get_chunks(self, keys, *, missing_ok=False):
        return self.inner.get_chunks(keys, missing_ok=missing_ok)

    def has_chunk(self, key):
        return self.inner.has_chunk(key)

    def list_chunk_keys(self):
        return self.inner.list_chunk_keys()

    def chunk_sizes(self, keys):
        return self.inner.chunk_sizes(keys)

    def delete_chunk(self, key):
        self.inner.delete_chunk(key)

    def delete_chunks(self, keys):
        return self.inner.delete_chunks(keys)

    def put_meta(self, name, doc):
        self.inner.put_meta(name, doc)

    def put_meta_batch(self, docs):
        self.inner.put_meta_batch(docs)

    def get_meta(self, name):
        return self.inner.get_meta(name)

    def list_meta(self, prefix):
        return self.inner.list_meta(prefix)

    def delete_meta(self, name):
        self.inner.delete_meta(name)

    def delete_meta_batch(self, names):
        self.inner.delete_meta_batch(names)

    def chunk_bytes_total(self):
        return self.inner.chunk_bytes_total()

    def n_chunks(self):
        return self.inner.n_chunks()


# ---------------------------------------------------------------------------
# per-tenant namespaces
# ---------------------------------------------------------------------------

TENANT_PREFIX = "tenant/"


def validate_tenant_id(tenant: str) -> str:
    """Tenant ids become meta-name path components, so they must survive
    every backend's name encoding — in particular DirectoryStore maps
    ``/`` to ``__``, which makes both characters ambiguous inside an id."""
    if not tenant or not all(c.isalnum() or c in ".-" for c in tenant):
        raise ValueError(
            f"invalid tenant id {tenant!r}: need [A-Za-z0-9.-]+")
    return tenant


def tenant_ids(store: "ChunkStore") -> List[str]:
    """Tenant namespaces present in a *root* store, from its meta listing."""
    seen = []
    for name in store.list_meta(TENANT_PREFIX):
        tid = name[len(TENANT_PREFIX):].split("/", 1)[0]
        if tid and tid not in seen:
            seen.append(tid)
    return seen


class NamespacedStore(ChunkStore):
    """Per-tenant view of a shared store: every metadata name is prefixed
    ``tenant/<id>/`` while **chunks pass through unprefixed** — tenants get
    isolated checkpoint graphs, branches, and txn journals, but share one
    content-addressed chunk space, so identical data across sessions is
    stored once (the cross-session dedup the fabric exists for).

    The flip side of shared chunks is that no single tenant may delete a
    chunk just because *its* graph dropped the last reference — GC and
    recovery rollback must consult every namespace (txn.global_live_chunks).
    """

    def __init__(self, inner: ChunkStore, tenant: str):
        self.inner = inner
        self.tenant_id = validate_tenant_id(tenant)
        self.meta_prefix = TENANT_PREFIX + self.tenant_id + "/"
        self.min_slab = getattr(inner, "min_slab", 1)
        self.supports_parallel_get = getattr(inner, "supports_parallel_get",
                                             True)
        self.native_scatter = getattr(inner, "native_scatter", False)

    @property
    def root_store(self) -> ChunkStore:
        """The shared (un-namespaced) store, for cross-tenant operations."""
        return self.inner

    def _n(self, name: str) -> str:
        return self.meta_prefix + name

    # ---- chunks: shared, pass-through ----
    def put_chunk(self, key, data):
        return self.inner.put_chunk(key, data)

    def put_chunks(self, pairs):
        return self.inner.put_chunks(pairs)

    def put_chunk_stored(self, key, data):
        return self.inner.put_chunk_stored(key, data)

    def put_chunks_stored(self, pairs):
        return self.inner.put_chunks_stored(pairs)

    def get_chunk(self, key):
        return self.inner.get_chunk(key)

    def get_chunk_stored(self, key):
        return self.inner.get_chunk_stored(key)

    def get_chunks(self, keys, *, missing_ok=False):
        return self.inner.get_chunks(keys, missing_ok=missing_ok)

    def has_chunk(self, key):
        return self.inner.has_chunk(key)

    def list_chunk_keys(self):
        return self.inner.list_chunk_keys()

    def chunk_sizes(self, keys):
        return self.inner.chunk_sizes(keys)

    def delete_chunk(self, key):
        self.inner.delete_chunk(key)

    def delete_chunks(self, keys):
        return self.inner.delete_chunks(keys)

    # ---- meta: prefixed ----
    def put_meta(self, name, doc):
        self.inner.put_meta(self._n(name), doc)

    def put_meta_batch(self, docs):
        self.inner.put_meta_batch({self._n(n): d for n, d in docs.items()})

    def get_meta(self, name):
        return self.inner.get_meta(self._n(name))

    def list_meta(self, prefix):
        cut = len(self.meta_prefix)
        return [n[cut:] for n in self.inner.list_meta(self._n(prefix))]

    def delete_meta(self, name):
        self.inner.delete_meta(self._n(name))

    def delete_meta_batch(self, names):
        self.inner.delete_meta_batch([self._n(n) for n in names])

    def chunk_bytes_total(self):
        return self.inner.chunk_bytes_total()

    def n_chunks(self):
        return self.inner.n_chunks()


def namespace_views(store: "ChunkStore") -> List[Tuple[str, "ChunkStore"]]:
    """Every checkpoint namespace reachable through ``store``: the root
    namespace itself plus one :class:`NamespacedStore` view per tenant.
    If ``store`` is already a tenant view, enumeration happens on its root
    (so cross-namespace invariants hold no matter which view asks)."""
    root = store.root_store if isinstance(store, NamespacedStore) else store
    views: List[Tuple[str, ChunkStore]] = [("", root)]
    views.extend((tid, NamespacedStore(root, tid))
                 for tid in tenant_ids(root))
    return views


# ---------------------------------------------------------------------------
# fault injection
# ---------------------------------------------------------------------------

class FaultInjectedStore(ChunkStore):
    """Wrapper that drops/corrupts selected chunks and can delay I/O.

    ``fail_get``: predicate(key) -> bool — raise ChunkMissingError on read.
    ``write_delay``: seconds added per put (straggler simulation).
    ``read_delay``: seconds added per get (slow-host restore simulation).

    Batched ops are deliberately *not* overridden: the ChunkStore defaults
    loop through ``get_chunk``/``put_chunk`` here, so every chunk of a batch
    individually passes through the fault predicates and delays — the
    parallel engine is exercised against per-chunk failures, not
    batch-granularity ones.
    """

    def __init__(self, inner: ChunkStore, *, fail_get=None, fail_put=None,
                 write_delay: float = 0.0, read_delay: float = 0.0):
        self.inner = inner
        self.fail_get = fail_get or (lambda k: False)
        self.fail_put = fail_put or (lambda k: False)
        self.write_delay = write_delay
        self.read_delay = read_delay
        self.dropped_puts: List[str] = []
        # engine hints follow the wrapped backend; an injected read delay
        # adds a per-chunk round trip, which parallel fetch can hide even
        # over a store that opts out (e.g. a delayed MemoryStore models a
        # remote RAM-speed host)
        self.min_slab = getattr(inner, "min_slab", 1)
        self.supports_parallel_get = (
            getattr(inner, "supports_parallel_get", True) or read_delay > 0)

    def put_chunk(self, key, data):
        if self.write_delay:
            time.sleep(self.write_delay)
        if self.fail_put(key):
            self.dropped_puts.append(key)
            return False
        return self.inner.put_chunk(key, data)

    def get_chunk(self, key):
        if self.read_delay:
            time.sleep(self.read_delay)
        if self.fail_get(key):
            raise ChunkMissingError(f"injected failure: {key}")
        return self.inner.get_chunk(key)

    def get_chunk_stored(self, key):
        if self.read_delay:
            time.sleep(self.read_delay)
        if self.fail_get(key):
            raise ChunkMissingError(f"injected failure: {key}")
        return self.inner.get_chunk_stored(key)

    def list_chunk_keys(self):
        return self.inner.list_chunk_keys()

    def chunk_sizes(self, keys):
        return self.inner.chunk_sizes(keys)

    def has_chunk(self, key):
        return self.inner.has_chunk(key)

    def delete_chunk(self, key):
        self.inner.delete_chunk(key)

    def put_meta(self, name, doc):
        self.inner.put_meta(name, doc)

    def get_meta(self, name):
        return self.inner.get_meta(name)

    def list_meta(self, prefix):
        return self.inner.list_meta(prefix)

    def delete_meta(self, name):
        self.inner.delete_meta(name)

    def chunk_bytes_total(self):
        return self.inner.chunk_bytes_total()

    def n_chunks(self):
        return self.inner.n_chunks()


class InjectedCrash(RuntimeError):
    """Simulated process kill: raised *instead of* performing a write, so the
    wrapped store keeps exactly the state that had landed before the kill."""


class FaultInjectingStore(ChunkStore):
    """Crash-injection wrapper: kill the process after N write operations.

    Unlike :class:`FaultInjectedStore` (per-key fault predicates and delays),
    this wrapper models a *process death* at a precise point in the commit
    pipeline: every write-side operation (chunk put/delete, meta put/delete)
    advances a counter, and once ``crash_after`` operations have landed the
    next write raises :class:`InjectedCrash` without touching the backend.
    Crash-recovery tests sweep ``crash_after`` over every index, proving the
    transaction engine recovers from a kill between *any* two device writes.

    Batched operations decompose to per-op calls so the kill can land inside
    a batch — modeling a non-atomic backend / a kill mid-scatter — and so op
    indices are deterministic across identical runs.  Reads pass through
    uncounted (a crashed process performs no further reads that matter) and
    engine hints force the serial path, keeping the op order reproducible.
    """

    supports_parallel_get = False
    min_slab = 1
    native_scatter = False

    def __init__(self, inner: ChunkStore, *,
                 crash_after: Optional[int] = None):
        self.inner = inner
        self.crash_after = crash_after
        self.ops = 0                  # write ops that actually landed
        self.op_log: List[str] = []   # labels of landed ops, for tests that
                                      # target a specific pipeline stage

    def _tick(self, label: str) -> None:
        if self.crash_after is not None and self.ops >= self.crash_after:
            raise InjectedCrash(f"injected kill at write op {self.ops} "
                                f"(next: {label})")
        self.ops += 1
        self.op_log.append(label)

    # ---- writes: counted, crashing before the op reaches the backend ----
    def put_chunk(self, key, data):
        self._tick(f"put_chunk:{key}")
        return self.inner.put_chunk(key, data)

    def put_chunks(self, pairs):
        return sum(bool(self.put_chunk(k, d)) for k, d in pairs)

    def delete_chunk(self, key):
        self._tick(f"delete_chunk:{key}")
        self.inner.delete_chunk(key)

    def delete_chunks(self, keys):
        removed = 0
        for k in keys:
            had = self.inner.has_chunk(k)
            self.delete_chunk(k)
            removed += bool(had)
        return removed

    def put_meta(self, name, doc):
        self._tick(f"put_meta:{name}")
        self.inner.put_meta(name, doc)

    # put_meta_batch deliberately NOT overridden: the base default loops
    # per-doc through put_meta above, so a kill lands *between* documents —
    # the torn-publish case the journal must recover from.

    def delete_meta(self, name):
        self._tick(f"delete_meta:{name}")
        self.inner.delete_meta(name)

    # ---- reads: uncounted pass-through ----
    def get_chunk(self, key):
        return self.inner.get_chunk(key)

    def get_chunk_stored(self, key):
        return self.inner.get_chunk_stored(key)

    def get_chunks(self, keys, *, missing_ok=False):
        return self.inner.get_chunks(keys, missing_ok=missing_ok)

    def has_chunk(self, key):
        return self.inner.has_chunk(key)

    def list_chunk_keys(self):
        return self.inner.list_chunk_keys()

    def chunk_sizes(self, keys):
        return self.inner.chunk_sizes(keys)

    def get_meta(self, name):
        return self.inner.get_meta(name)

    def list_meta(self, prefix):
        return self.inner.list_meta(prefix)

    def chunk_bytes_total(self):
        return self.inner.chunk_bytes_total()

    def n_chunks(self):
        return self.inner.n_chunks()


def open_store(uri: str, codec=None, tenant: Optional[str] = None) -> ChunkStore:
    """"memory://", "dir:///path", "sqlite:///path.db", a bare path, or a
    "fabric://TOPOLOGY" composition (fabric.py) — e.g.
    ``fabric://shard(dir:///s0,dir:///s1)`` or ``fabric://rep(a,b)``.

    A ``?codec=NAME`` suffix (or the ``codec`` argument) wraps the store in
    :class:`CompressedStore` — e.g. ``sqlite:///ckpt.db?codec=auto`` or
    ``fabric://shard(...)?codec=zlib``.  Reading never needs the suffix:
    frames are decoded transparently.

    A ``?tenant=ID`` suffix (or the ``tenant`` argument) scopes the opened
    store to that tenant's namespace (:class:`NamespacedStore`); combine
    with ``&``: ``dir:///ckpt?codec=auto&tenant=alice``."""
    if "?" in uri:
        uri, _, query = uri.partition("?")
        for part in query.split("&"):
            key, _, val = part.partition("=")
            if key == "codec":
                codec = val
            elif key == "tenant":
                tenant = val
            elif part:
                raise ValueError(f"unknown store URI option {part!r}")
    if uri.startswith("fabric://"):
        from repro.core.fabric import parse_topology
        store: ChunkStore = parse_topology(uri[len("fabric://"):])
    elif uri == "memory://" or uri == ":memory:":
        store = MemoryStore()
    elif uri.startswith("sqlite://"):
        store = SQLiteStore(uri[len("sqlite://"):])
    elif uri.startswith("dir://"):
        store = DirectoryStore(uri[len("dir://"):])
    else:
        store = DirectoryStore(uri)
    if resolve_codec(codec) is not None:
        store = CompressedStore(store, codec)
    if tenant:
        store = NamespacedStore(store, tenant)
    return store
