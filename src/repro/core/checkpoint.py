"""Incremental checkpoint writing (§5.1).

For each updated co-variable, serialize its *base* buffer, cut it into
fixed-size chunks, and store only chunks not already present (content
addressing).  When the same co-variable existed in the parent version with
identical structure, chunks whose detection hash is unchanged are *referenced*
from the previous manifest without re-serializing — the beyond-paper
chunk-dedup (DESIGN.md §2).  Unserializable co-variables are skipped (EAFP,
§5.1) and flagged for fallback recomputation.

The async writer overlaps chunk I/O with subsequent compute ("think time",
§2.2): ``commit`` snapshots device arrays to host and enqueues; ``flush``
drains.  A write deadline marks commits non-durable until the writer catches
up (straggler mitigation — checkout of a pending chunk simply falls back to
recomputation).
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core import hashing
from repro.core.chunkstore import ChunkStore, chunk_key
from repro.core.covariable import CovKey, LeafRecord
from repro.core.graph import key_str
from repro.core.serialize import (SerializationError, base_of, leaf_to_bytes,
                                  view_spec)


@dataclass
class WriteStats:
    bytes_serialized: int = 0       # bytes of updated co-variables
    bytes_written: int = 0          # new chunk bytes actually stored
    chunks_written: int = 0
    chunks_reused: int = 0          # skipped via detection-hash delta
    chunks_dedup: int = 0           # skipped via CAS hit
    unserializable: int = 0
    wall_s: float = 0.0


def _hashes_hex(h: Optional[np.ndarray]) -> List[str]:
    if h is None:
        return []
    return [format(int(x), "016x") for x in np.asarray(h, dtype=np.uint64)]


def build_manifest(store: ChunkStore, key: CovKey,
                   records: List[LeafRecord], ns,
                   chunk_bytes: int,
                   prev_manifest: Optional[dict],
                   stats: WriteStats,
                   put: Callable[[str, bytes], None],
                   has: Optional[Callable[[str], bool]] = None) -> dict:
    """Serialize one co-variable into a manifest + chunk puts.

    ``has`` is the CAS-dedup membership test; the writer passes a variant
    that also sees chunks batched/enqueued but not yet landed in the store,
    so deferred (batched or async) puts never double-write within a delta."""
    if has is None:
        has = store.has_chunk
    members = []
    for r in records:
        members.append({"name": r.name, "kind": r.kind, "dtype": r.dtype,
                        "shape": list(r.shape), "view": r.view,
                        "nbytes": r.nbytes})
    if any(r.kind == "opaque" for r in records):
        stats.unserializable += 1
        return {"members": members, "unserializable": True}

    base = base_of(ns[records[0].name])
    try:
        blob, meta = leaf_to_bytes(base)
    except SerializationError:
        stats.unserializable += 1
        return {"members": members, "unserializable": True}

    det = records[0].base_hashes
    det_hex = _hashes_hex(det)
    prev_chunks: Dict[int, dict] = {}
    if prev_manifest and not prev_manifest.get("unserializable") \
            and prev_manifest.get("base", {}).get("meta") == meta:
        prev_det = prev_manifest["base"].get("det_hashes", [])
        for i, c in enumerate(prev_manifest["base"].get("chunks", [])):
            if i < len(prev_det):
                prev_chunks[i] = {"det": prev_det[i], **c}

    chunks = []
    n = len(blob)
    n_chunks = max(-(-n // chunk_bytes), 1) if n else 0
    stats.bytes_serialized += n
    for i in range(n_chunks):
        lo, hi = i * chunk_bytes, min((i + 1) * chunk_bytes, n)
        prev = prev_chunks.get(i)
        if prev is not None and i < len(det_hex) and prev["det"] == det_hex[i]:
            # unchanged chunk: reference previous storage, no hashing/copy
            chunks.append({"key": prev["key"], "n": prev["n"]})
            stats.chunks_reused += 1
            continue
        data = blob[lo:hi]
        ck = chunk_key(data)
        if has(ck):
            stats.chunks_dedup += 1
        else:
            put(ck, data)
            stats.chunks_written += 1
            stats.bytes_written += len(data)
        chunks.append({"key": ck, "n": hi - lo})

    return {"members": members, "unserializable": False,
            "base": {"meta": meta, "nbytes": n, "chunks": chunks,
                     "det_hashes": det_hex}}


class CheckpointWriter:
    """Sync or async (background-thread) chunk writer.

    Both modes route through the batched ``put_chunks`` backend op: the sync
    path accumulates a delta's new chunks and lands them in one batch (one
    SQLite transaction / one thread-pooled file sweep) before the commit
    returns; the async worker drains its queue in batches of up to
    ``drain_batch`` for the same amortization without changing the
    deadline/straggler semantics."""

    def __init__(self, store: ChunkStore, *, chunk_bytes: int = 1 << 20,
                 async_write: bool = False, write_deadline_s: float = 0.0,
                 drain_batch: int = 64):
        self.store = store
        self.chunk_bytes = chunk_bytes
        self.async_write = async_write
        self.write_deadline_s = write_deadline_s
        self.drain_batch = drain_batch
        self._q: "queue.Queue" = queue.Queue()
        self._batch: List[Tuple[str, bytes]] = []     # sync-mode delta batch
        self._batch_keys: set = set()
        self._worker: Optional[threading.Thread] = None
        self._errors: List[Exception] = []
        self.pending_keys: set = set()
        if async_write:
            self._worker = threading.Thread(target=self._drain, daemon=True)
            self._worker.start()

    def _drain(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                return
            batch = [item]
            saw_sentinel = False
            while len(batch) < self.drain_batch:
                try:
                    nxt = self._q.get_nowait()
                except queue.Empty:
                    break
                if nxt is None:
                    saw_sentinel = True
                    break
                batch.append(nxt)
            try:
                try:
                    self.store.put_chunks(batch)
                except Exception:  # noqa: BLE001
                    # batch op failed somewhere: degrade to per-chunk puts
                    # so one bad chunk doesn't drop its whole batch
                    for ck, data in batch:
                        try:
                            self.store.put_chunk(ck, data)
                        except Exception as e:  # noqa: BLE001
                            self._errors.append(e)
            finally:
                for ck, _ in batch:
                    self.pending_keys.discard(ck)
                for _ in batch:
                    self._q.task_done()
            if saw_sentinel:
                return

    def _put(self, ck: str, data: bytes) -> None:
        if self.async_write:
            self.pending_keys.add(ck)
            self._q.put((ck, bytes(data)))
        else:
            self._batch.append((ck, bytes(data)))
            self._batch_keys.add(ck)
            if len(self._batch) >= self.drain_batch:
                self._flush_batch()      # bound buffered delta memory

    def _flush_batch(self) -> None:
        if not self._batch:
            return
        batch, self._batch = self._batch, []
        self._batch_keys = set()
        self.store.put_chunks(batch)

    def _has(self, ck: str) -> bool:
        """CAS membership including chunks deferred in this delta."""
        return (ck in self.pending_keys or ck in self._batch_keys
                or self.store.has_chunk(ck))

    def write_delta(self, delta, ns,
                    prev_manifest_of: Callable[[CovKey], Optional[dict]]
                    ) -> Tuple[Dict[str, dict], WriteStats]:
        t0 = time.perf_counter()
        stats = WriteStats()
        manifests: Dict[str, dict] = {}
        for key, records in delta.updated.items():
            man = build_manifest(self.store, key, records, ns,
                                 self.chunk_bytes, prev_manifest_of(key),
                                 stats, self._put, self._has)
            manifests[key_str(key)] = man
        self._flush_batch()                  # sync mode: durable on return
        if self.async_write and self.write_deadline_s:
            deadline = time.time() + self.write_deadline_s
            while self.pending_keys and time.time() < deadline:
                time.sleep(0.001)
            # anything still pending is left to the background writer;
            # checkout before completion falls back to recomputation.
        stats.wall_s = time.perf_counter() - t0
        return manifests, stats

    def flush(self) -> None:
        if self.async_write:
            self._q.join()
        if self._errors:
            errs, self._errors = self._errors, []
            raise errs[0]

    def close(self) -> None:
        if self.async_write and self._worker is not None:
            self._q.put(None)
            self._worker.join(timeout=5)
            self._worker = None
