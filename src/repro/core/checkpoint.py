"""Incremental checkpoint writing (§5.1).

For each updated co-variable, serialize its *base* buffer, cut it into
fixed-size chunks, and store only chunks not already present (content
addressing).  When the same co-variable existed in the parent version with
identical structure, chunks whose detection hash is unchanged are *referenced*
from the previous manifest without re-serializing — the beyond-paper
chunk-dedup (DESIGN.md §2).  Unserializable co-variables are skipped (EAFP,
§5.1) and flagged for fallback recomputation.

The async writer overlaps chunk I/O with subsequent compute ("think time",
§2.2): ``commit`` snapshots device arrays to host and enqueues; ``flush``
drains.  A write deadline marks commits non-durable until the writer catches
up (straggler mitigation — checkout of a pending chunk simply falls back to
recomputation).
"""
from __future__ import annotations

import queue
import threading
import time
from contextlib import nullcontext
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core import delta as delta_mod
from repro.core import hashing
from repro.core.chunkstore import ChunkCache, ChunkStore, chunk_key
from repro.core.covariable import CovKey, LeafRecord
from repro.core.graph import key_str
from repro.core.serialize import (SerializationError, base_of, leaf_meta,
                                  leaf_nbytes, leaf_to_bytes, view_spec)


@dataclass
class WriteStats:
    bytes_serialized: int = 0       # *moved*: bytes actually serialized /
                                    # transferred (dirty ranges only on the
                                    # delta path)
    bytes_logical: int = 0          # logical size of updated co-variables
    bytes_written: int = 0          # new chunk bytes actually stored
    chunks_written: int = 0
    chunks_reused: int = 0          # skipped via detection-hash delta
    chunks_dedup: int = 0           # skipped via CAS hit
    covs_delta: int = 0             # covs written via the dirty-range path
    covs_packed: int = 0            # subset served by the fused device pack
    bytes_dev2host: int = 0         # device→host bytes the pack(s) moved
    chunks_encoded: int = 0         # chunks compressed on device (bit-plane
                                    # frames crossed PCIe, not raw rows)
    chunks_codec_skipped: int = 0   # probe said incompressible → raw
    kernel_fallbacks: int = 0       # device-kernel → host degradations
    unserializable: int = 0
    wall_s: float = 0.0


_hashes_hex = hashing.hashes_hex


def _pack_usable(pack, det_hex: List[str], dirty_set, n: int,
                 chunk_bytes: int, n_chunks: int) -> bool:
    """The fused device pack may serve this delta only when it describes
    exactly this base at exactly this chunking AND its dirty set covers
    every chunk the manifest compare wants rewritten.  The pack's dirty set
    is computed against the previous *record* hashes; the manifest compare
    runs against the previous *manifest* — normally identical, but a
    mismatch (recovered graph, size drift forcing extra rewrites) must fall
    back to the device-sliced reader rather than write stale rows."""
    if pack is None or pack.chunk_bytes != chunk_bytes \
            or pack.nbytes != n or pack.n_chunks != n_chunks:
        return False
    if hashing.hashes_hex(pack.hashes) != det_hex:
        return False
    return dirty_set <= pack.dirty_set


def _try_delta_manifest(base, det_hex: List[str], prev_manifest,
                        chunk_bytes: int, stats: WriteStats,
                        put, has, members, pack=None,
                        put_stored=None) -> Optional[dict]:
    """Dirty-range fast path: when the previous manifest matches this base
    structurally, compare detection hashes *first* and serialize only the
    dirty byte ranges — the full blob is never built and device→host
    traffic scales with dirty bytes, not total bytes.  Returns None when
    the fast path doesn't apply (first version, structure change, non-array
    leaf, everything dirty) — the caller falls back to full serialization,
    which produces bit-identical chunks."""
    if not det_hex or not prev_manifest or prev_manifest.get("unserializable"):
        return None
    prev_base = prev_manifest.get("base") or {}
    meta = leaf_meta(base)
    if meta.get("kind") != "array" or prev_base.get("meta") != meta:
        return None
    n = leaf_nbytes(base)
    if n <= 0 or prev_base.get("nbytes") != n:
        return None
    n_chunks = -(-n // chunk_bytes)
    prev_chunks = prev_base.get("chunks", [])
    prev_det = prev_base.get("det_hashes", [])
    if not (len(det_hex) == len(prev_chunks) == len(prev_det) == n_chunks):
        return None
    dirty_set = set(delta_mod.dirty_indices(prev_det, det_hex))
    dirty_set.update(                # stored size drift also forces rewrite
        i for i in range(n_chunks)
        if prev_chunks[i]["n"] != min((i + 1) * chunk_bytes, n)
        - i * chunk_bytes)
    dirty = sorted(dirty_set)
    if len(dirty) == n_chunks:
        return None                  # fully diverged: full path, same cost
    use_pack = _pack_usable(pack, det_hex, dirty_set, n, chunk_bytes,
                            n_chunks)
    reader = None
    if not use_pack:
        reader = delta_mod.range_reader(base, chunk_bytes)
        if reader is None:
            return None

    stats.bytes_logical += n
    stats.covs_delta += 1
    chunks: List[Optional[dict]] = [None] * n_chunks
    for i in range(n_chunks):
        if i not in dirty_set:
            chunks[i] = {"key": prev_chunks[i]["key"],
                         "n": prev_chunks[i]["n"]}
            stats.chunks_reused += 1

    def _store(i: int, cdata, frame=None) -> None:
        # the key is ALWAYS over the logical bytes — codec frames are a
        # storage representation, invisible to dedup and manifests
        ck = chunk_key(cdata)
        if has(ck):
            stats.chunks_dedup += 1
        elif frame is not None and put_stored is not None:
            put_stored(ck, cdata, frame)
            stats.chunks_written += 1
            stats.bytes_written += len(frame)
        else:
            put(ck, cdata)
            stats.chunks_written += 1
            stats.bytes_written += len(cdata)
        chunks[i] = {"key": ck, "n": len(cdata)}

    if use_pack:
        # fused device path: dirty chunks come out of the kernel's
        # compacted buffer — the puts above enqueue into the (possibly
        # async) writer while the reader keeps the *next* segment's
        # device→host DMA in flight (DESIGN.md §15).  With the on-device
        # codec engaged the rows cross PCIe as bit-plane frames and are
        # stored as-is (put_stored); keys stay logical-byte either way.
        stats.covs_packed += 1
        enc0, skip0 = pack.codec_chunks_encoded, pack.codec_chunks_skipped
        if put_stored is not None:
            for i, cdata, frame in pack.read_chunks_encoded(dirty):
                stats.bytes_serialized += len(cdata)
                _store(i, cdata, frame)
        else:
            for i, cdata in pack.read_chunks(dirty):
                stats.bytes_serialized += len(cdata)
                _store(i, cdata)
        stats.chunks_encoded += pack.codec_chunks_encoded - enc0
        stats.chunks_codec_skipped += pack.codec_chunks_skipped - skip0
        stats.bytes_dev2host += pack.bytes_transferred
    else:
        for start, stop in delta_mod.coalesce(dirty):
            lo, hi = start * chunk_bytes, min(stop * chunk_bytes, n)
            data = reader(lo, hi)
            stats.bytes_serialized += len(data)
            for i in range(start, stop):
                clo = i * chunk_bytes - lo
                chi = min((i + 1) * chunk_bytes, n) - lo
                _store(i, data[clo:chi])
    return {"members": members, "unserializable": False,
            "base": {"meta": meta, "nbytes": n, "chunks": chunks,
                     "det_hashes": det_hex}}


def build_manifest(store: ChunkStore, key: CovKey,
                   records: List[LeafRecord], ns,
                   chunk_bytes: int,
                   prev_manifest: Optional[dict],
                   stats: WriteStats,
                   put: Callable[[str, bytes], None],
                   has: Optional[Callable[[str], bool]] = None,
                   delta_ranges: bool = True,
                   packs: Optional[Dict[int, Any]] = None,
                   put_stored: Optional[Callable[[str, bytes, bytes],
                                                 None]] = None) -> dict:
    """Serialize one co-variable into a manifest + chunk puts.

    ``has`` is the CAS-dedup membership test; the writer passes a variant
    that also sees chunks batched/enqueued but not yet landed in the store,
    so deferred (batched or async) puts never double-write within a delta.
    ``delta_ranges=False`` disables the dirty-range fast path (benchmark
    baseline — the pre-delta cov-granular writer)."""
    if has is None:
        has = store.has_chunk
    members = []
    for r in records:
        members.append({"name": r.name, "kind": r.kind, "dtype": r.dtype,
                        "shape": list(r.shape), "view": r.view,
                        "nbytes": r.nbytes})
    if any(r.kind == "opaque" for r in records):
        stats.unserializable += 1
        return {"members": members, "unserializable": True}

    base = base_of(ns[records[0].name])
    det = records[0].base_hashes
    det_hex = _hashes_hex(det)

    # chunk-granular fast path: det-hash compare first, then serialize /
    # transfer only the dirty ranges (bytes_serialized ~ dirty bytes)
    if delta_ranges:
        man = _try_delta_manifest(base, det_hex, prev_manifest, chunk_bytes,
                                  stats, put, has, members,
                                  pack=(packs or {}).get(id(base)),
                                  put_stored=put_stored)
        if man is not None:
            return man

    try:
        blob, meta = leaf_to_bytes(base)
    except SerializationError:
        stats.unserializable += 1
        return {"members": members, "unserializable": True}

    prev_chunks: Dict[int, dict] = {}
    if prev_manifest and not prev_manifest.get("unserializable") \
            and prev_manifest.get("base", {}).get("meta") == meta:
        prev_det = prev_manifest["base"].get("det_hashes", [])
        for i, c in enumerate(prev_manifest["base"].get("chunks", [])):
            if i < len(prev_det):
                prev_chunks[i] = {"det": prev_det[i], **c}

    chunks = []
    n = len(blob)
    n_chunks = max(-(-n // chunk_bytes), 1) if n else 0
    stats.bytes_serialized += n
    stats.bytes_logical += n
    for i in range(n_chunks):
        lo, hi = i * chunk_bytes, min((i + 1) * chunk_bytes, n)
        prev = prev_chunks.get(i)
        if prev is not None and i < len(det_hex) and prev["det"] == det_hex[i]:
            # unchanged chunk: reference previous storage, no hashing/copy
            chunks.append({"key": prev["key"], "n": prev["n"]})
            stats.chunks_reused += 1
            continue
        data = blob[lo:hi]
        ck = chunk_key(data)
        if has(ck):
            stats.chunks_dedup += 1
        else:
            put(ck, data)
            stats.chunks_written += 1
            stats.bytes_written += len(data)
        chunks.append({"key": ck, "n": hi - lo})

    return {"members": members, "unserializable": False,
            "base": {"meta": meta, "nbytes": n, "chunks": chunks,
                     "det_hashes": det_hex}}


class CheckpointWriter:
    """Sync or async (background-thread) chunk writer.

    Both modes route through the batched ``put_chunks`` backend op: the sync
    path accumulates a delta's new chunks and lands them in one batch (one
    SQLite transaction / one thread-pooled file sweep) before the commit
    returns; the async worker drains its queue in batches of up to
    ``drain_batch`` for the same amortization without changing the
    deadline/straggler semantics."""

    def __init__(self, store: ChunkStore, *, chunk_bytes: int = 1 << 20,
                 async_write: bool = False, write_deadline_s: float = 0.0,
                 drain_batch: int = 64,
                 cache: Optional[ChunkCache] = None):
        self.store = store
        self.chunk_bytes = chunk_bytes
        self.cache = cache          # shared with the StateLoader: a chunk
                                    # written here is served back to checkout
                                    # without touching the backend
        self.async_write = async_write
        self.write_deadline_s = write_deadline_s
        self.drain_batch = drain_batch
        # dirty-range serialization; False = pre-delta full-blob writer
        # (benchmark baseline)
        self.delta_ranges = True
        # WAL hook (txn.TxnEngine.journal_chunks): called with a batch's
        # keys immediately before the backend put, so a crashed commit's
        # chunks are journaled and recovery can roll them back exactly
        self.journal: Optional[Callable[[List[str]], None]] = None
        # observability handle (set by the session): spans opened here from
        # the async drain thread become roots — contextvars don't cross
        # threads, and off-thread work genuinely is off the commit path
        self.obs = None
        self._q: "queue.Queue" = queue.Queue()
        # sync-mode delta batch: (key, bytes, stored-form flag)
        self._batch: List[Tuple[str, bytes, bool]] = []
        self._batch_keys: set = set()
        self._worker: Optional[threading.Thread] = None
        self._errors: List[Exception] = []
        self.pending_keys: set = set()
        # epoch fence: chunks enqueued vs chunks that have left the writer
        # (landed or failed) — the txn engine's durability proof for async
        # writes.  wait_epoch(epoch()) == "everything enqueued so far is
        # out of the pipeline".
        self._cv = threading.Condition()
        self._enqueued = 0
        self._completed = 0
        if async_write:
            self._worker = threading.Thread(target=self._drain, daemon=True)
            self._worker.start()

    def _drain(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                return
            batch = [item]
            saw_sentinel = False
            while len(batch) < self.drain_batch:
                try:
                    nxt = self._q.get_nowait()
                except queue.Empty:
                    break
                if nxt is None:
                    saw_sentinel = True
                    break
                batch.append(nxt)
            try:
                journaled = True
                if self.journal is not None:
                    try:        # WAL the keys BEFORE the backend put
                        self.journal([ck for ck, _, _ in batch])
                    except Exception as e:  # noqa: BLE001
                        journaled = False   # unjournaled chunks must not
                        self._errors.append(e)  # land: rollback couldn't
                                                # find them
                if journaled:
                    try:
                        with self._span("put_chunks", n=len(batch)):
                            self._put_batch(batch)
                    except Exception:  # noqa: BLE001
                        # batch op failed somewhere: degrade to per-chunk
                        # puts so one bad chunk doesn't drop its whole batch
                        for ck, data, stored in batch:
                            try:
                                if stored:
                                    self.store.put_chunk_stored(ck, data)
                                else:
                                    self.store.put_chunk(ck, data)
                            except Exception as e:  # noqa: BLE001
                                self._errors.append(e)
            finally:
                for ck, _, _ in batch:
                    self.pending_keys.discard(ck)
                for _ in batch:
                    self._q.task_done()
                with self._cv:
                    self._completed += len(batch)
                    self._cv.notify_all()
            if saw_sentinel:
                return

    def _put_batch(self, batch: List[Tuple[str, bytes, bool]]) -> None:
        """Land one mixed batch: raw chunks through ``put_chunks`` (codec
        wrappers encode them), device-encoded frames through
        ``put_chunks_stored`` (already frames — re-encoding would
        double-frame)."""
        raw = [(ck, d) for ck, d, stored in batch if not stored]
        pre = [(ck, d) for ck, d, stored in batch if stored]
        if raw:
            self.store.put_chunks(raw)
        if pre:
            self.store.put_chunks_stored(pre)

    def _enqueue(self, ck: str, data: bytes, stored: bool) -> None:
        with self._cv:
            self._enqueued += 1
        if self.async_write:
            self.pending_keys.add(ck)
            self._q.put((ck, bytes(data), stored))
        else:
            self._batch.append((ck, bytes(data), stored))
            self._batch_keys.add(ck)
            if len(self._batch) >= self.drain_batch:
                self._flush_batch()      # bound buffered delta memory

    def _put(self, ck: str, data: bytes) -> None:
        if self.cache is not None:
            self.cache.put(ck, bytes(data))
        self._enqueue(ck, data, stored=False)

    def _put_stored(self, ck: str, logical: bytes, frame: bytes) -> None:
        """Store a device-encoded chunk: the *frame* goes to the backend,
        the *logical* bytes feed the shared cache (checkout must see
        logical bytes, same as a backend read after transparent decode)."""
        if self.cache is not None:
            self.cache.put(ck, bytes(logical))
        self._enqueue(ck, frame, stored=True)

    def _span(self, name: str, **args):
        return self.obs.span(name, **args) if self.obs is not None \
            else nullcontext()

    def _flush_batch(self) -> None:
        if not self._batch:
            return
        batch, self._batch = self._batch, []
        self._batch_keys = set()
        try:
            if self.journal is not None:
                # WAL before the puts; a journal failure aborts the batch
                # (the exception propagates to run()) so no chunk ever
                # lands unjournaled
                self.journal([ck for ck, _, _ in batch])
            with self._span("put_chunks", n=len(batch)):
                self._put_batch(batch)
        finally:
            # the batch leaves the pipeline on ANY outcome — journal
            # failures included — or a later epoch fence would wait forever
            with self._cv:
                self._completed += len(batch)
                self._cv.notify_all()

    def epoch(self) -> int:
        """Fence token: number of chunks enqueued so far."""
        with self._cv:
            return self._enqueued

    def wait_epoch(self, token: Optional[int] = None,
                   timeout: Optional[float] = None) -> None:
        """Block until every chunk enqueued at or before ``token`` (default:
        all enqueued so far) has left the writer — landed or failed — then
        surface the first async write error, if any.  The txn engine's
        durability fence: once this returns cleanly, publishing metadata
        that references those chunks is safe."""
        with self._cv:
            tgt = self._enqueued if token is None else token
            self._cv.wait_for(lambda: self._completed >= tgt, timeout)
        if self._errors:
            errs, self._errors = self._errors, []
            raise errs[0]

    def _has(self, ck: str) -> bool:
        """CAS membership including chunks deferred in this delta."""
        return (ck in self.pending_keys or ck in self._batch_keys
                or self.store.has_chunk(ck))

    def write_delta(self, delta, ns,
                    prev_manifest_of: Callable[[CovKey], Optional[dict]],
                    packs: Optional[Dict[int, Any]] = None
                    ) -> Tuple[Dict[str, dict], WriteStats]:
        t0 = time.perf_counter()
        stats = WriteStats()
        manifests: Dict[str, dict] = {}
        with self._span("serialize", covs=len(delta.updated)):
            for key, records in delta.updated.items():
                man = build_manifest(self.store, key, records, ns,
                                     self.chunk_bytes, prev_manifest_of(key),
                                     stats, self._put, self._has,
                                     delta_ranges=self.delta_ranges,
                                     packs=packs,
                                     put_stored=self._put_stored)
                manifests[key_str(key)] = man
        self._flush_batch()                  # sync mode: durable on return
        if self.async_write and self.write_deadline_s:
            # monotonic, never wall-clock: an NTP step would expire this
            # deadline instantly (spurious drain timeout -> the commit
            # references still-pending chunks) or push it out indefinitely
            deadline = time.monotonic() + self.write_deadline_s
            while self.pending_keys and time.monotonic() < deadline:
                time.sleep(0.001)
            # anything still pending is left to the background writer;
            # checkout before completion falls back to recomputation.
        stats.wall_s = time.perf_counter() - t0
        return manifests, stats

    def flush(self) -> None:
        if self.async_write:
            self._q.join()
        if self._errors:
            errs, self._errors = self._errors, []
            raise errs[0]

    def close(self) -> None:
        if self.async_write and self._worker is not None:
            self._q.put(None)
            self._worker.join(timeout=5)
            self._worker = None
