"""Divisibility-aware sharding rules for all architectures and meshes.

Scheme (MaxText-style 2-D + optional pod axis):
  - FSDP: parameter d_model-like dims sharded over ("pod","data") / ("data",)
  - TP:   heads / ff / vocab dims sharded over "model"
  - EP:   expert dim sharded over "data" (experts per group), ff over "model"
  - activations: batch over ("pod","data"); decode caches shard the *sequence*
    dim over "model" (uniform across archs — works for kv_heads < mesh model
    size, e.g. whisper's 20 heads or smollm's 15)

Every choice is guarded by a divisibility check with a deterministic
fallback (head-TP -> head_dim-TP -> replicate), so smollm (15 heads) and
whisper (20 heads, vocab 51866) lower cleanly on a 16-way model axis.
Specs are derived from parameter *path names*, so they apply equally to
optimizer moments (same tree structure).
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ArchConfig


def _divides(n: int, by: int) -> bool:
    return by > 0 and n % by == 0


class ShardingRules:
    """Sharding policy. Tunables (hillclimb levers, EXPERIMENTS.md §Perf):

    - ``fsdp_pods``: fold the pod axis into the FSDP group.
    - ``expert_pod_shard``: shard the MoE expert dim over ("pod","data")
      instead of "data" alone (halves expert params/moments per device on
      the multi-pod mesh when n_experts divides pod*data).
    - ``attn_fallback``: when n_heads doesn't divide the model axis —
      "head_dim" shards head_dim over model (TP with per-layer reductions);
      "replicate" keeps attention weights replicated and data-parallel only
      (kills the per-layer attention collectives; costs memory).
    - ``seq_shard_activations``: constrain the residual stream to
      P(batch, "model", None) between stages (Megatron-SP style RS/AG
      instead of all-reduce).
    """

    def __init__(self, cfg: ArchConfig, mesh: Mesh, *,
                 fsdp_pods: bool = True,
                 expert_pod_shard: bool = False,
                 attn_fallback: str = "head_dim",
                 seq_shard_activations: bool = False,
                 expert_fsdp_pod: bool = False,
                 moe_dispatch_shard: bool = False,
                 dp_only: bool = False):
        self.cfg = cfg
        self.mesh = mesh
        self.expert_pod_shard = expert_pod_shard
        self.expert_fsdp_pod = expert_fsdp_pod
        self.moe_dispatch_shard = moe_dispatch_shard
        self.attn_fallback = attn_fallback
        self.seq_shard_activations = seq_shard_activations
        self.dp_only = dp_only
        names = mesh.axis_names
        self.model_axis = "model" if "model" in names else None
        self.data_axis = "data" if "data" in names else None
        self.pod_axis = "pod" if "pod" in names else None
        self.model_size = mesh.shape.get("model", 1)
        self.data_size = mesh.shape.get("data", 1)
        self.pod_size = mesh.shape.get("pod", 1)
        # FSDP group: pod axis folds into FSDP for huge models
        if dp_only:
            # ZeRO-3 regime: every axis is data-parallel; params/moments
            # fully sharded over the flat device space; no tensor parallel.
            axes = [a for a in (self.pod_axis, self.data_axis,
                                self.model_axis) if a]
            self.fsdp = tuple(axes)
            self.fsdp_size = self.pod_size * self.data_size * self.model_size
            self.batch_axes = tuple(axes)
            self.batch_size_div = self.fsdp_size
            self.model_axis = None
            self.model_size = 1
            return
        if self.pod_axis and fsdp_pods:
            self.fsdp: Any = (self.pod_axis, self.data_axis)
            self.fsdp_size = self.pod_size * self.data_size
        else:
            self.fsdp = self.data_axis
            self.fsdp_size = self.data_size
        self.batch_axes: Any = ((self.pod_axis, self.data_axis)
                                if self.pod_axis else self.data_axis)
        self.batch_size_div = self.pod_size * self.data_size

    # ------------------------------------------------------------------
    # helpers
    # ------------------------------------------------------------------
    def _fsdp_if(self, dim: int):
        return self.fsdp if _divides(dim, self.fsdp_size) else None

    def _model_if(self, dim: int):
        return self.model_axis if _divides(dim, self.model_size) else None

    def _batch_if(self, dim: int):
        if _divides(dim, self.batch_size_div):
            return self.batch_axes
        if _divides(dim, self.data_size):
            return self.data_axis
        return None

    # ------------------------------------------------------------------
    # parameters (and optimizer moments — same paths)
    # ------------------------------------------------------------------
    def param_spec(self, path: str, shape: Tuple[int, ...]) -> P:
        cfg = self.cfg
        leaf = path.split("/")[-1]
        parent = path.split("/")[-2] if "/" in path else ""

        if leaf in ("scale", "conv_b", "dt_bias", "A_log", "D"):
            return P()
        if leaf == "conv_w":
            lead = (None,) * (len(shape) - 2)
            return P(*lead, None, self._model_if(shape[-1]))
        if leaf == "embed":
            return P(self._model_if(shape[0]), self._fsdp_if(shape[1]))
        if leaf == "lm_head":
            return P(self._fsdp_if(shape[0]), self._model_if(shape[1]))
        if leaf == "router":
            lead = (None,) * (len(shape) - 2)
            return P(*lead, self._fsdp_if(shape[-2]), None)

        # MoE expert-stacked weights [*, E, d, f] / [*, E, f, d]
        if leaf in ("w_gate", "w_up", "w_down") and parent == "moe" or \
                (leaf in ("w_gate", "w_up", "w_down") and len(shape) >= 3
                 and "moe" in path):
            lead = (None,) * (len(shape) - 3)      # stacked n_units dims
            e, a, b = shape[-3], shape[-2], shape[-1]
            if self.expert_pod_shard and \
                    _divides(e, self.pod_size * self.data_size) and \
                    self.pod_axis:
                espec: Any = (self.pod_axis, self.data_axis)
            elif _divides(e, self.data_size):
                espec = self.data_axis
            else:
                espec = None
            # optional ZeRO-style pod-sharding of the expert d_model dim:
            # keeps the 16-way dispatch pattern, halves expert memory on the
            # multi-pod mesh at the cost of a small per-layer weight gather
            dpod = (self.pod_axis if self.expert_fsdp_pod and self.pod_axis
                    else None)
            if leaf == "w_down":                   # [E, f, d]
                d_ok = dpod if dpod and _divides(b, self.pod_size) else None
                return P(*lead, espec, self._model_if(a), d_ok)
            d_ok = dpod if dpod and _divides(a, self.pod_size) else None
            return P(*lead, espec, d_ok, self._model_if(b))

        # dense MLP [*, d, f] / [*, f, d]
        if leaf in ("w_gate", "w_up"):
            lead = (None,) * (len(shape) - 2)
            return P(*lead, self._fsdp_if(shape[-2]), self._model_if(shape[-1]))
        if leaf == "w_down":
            lead = (None,) * (len(shape) - 2)
            return P(*lead, self._model_if(shape[-2]), self._fsdp_if(shape[-1]))

        # attention projections [*, d, H, hd] / wo [*, H, hd, d]
        if leaf in ("wq", "wk", "wv"):
            lead = (None,) * (len(shape) - 3)
            d, h, hd = shape[-3], shape[-2], shape[-1]
            if _divides(h, self.model_size):
                return P(*lead, self._fsdp_if(d), self.model_axis, None)
            if self.attn_fallback == "head_dim" and \
                    _divides(hd, self.model_size):
                return P(*lead, self._fsdp_if(d), None, self.model_axis)
            return P(*lead, self._fsdp_if(d), None, None)
        if leaf == "wo":
            lead = (None,) * (len(shape) - 3)
            h, hd, d = shape[-3], shape[-2], shape[-1]
            if _divides(h, self.model_size):
                return P(*lead, self.model_axis, None, self._fsdp_if(d))
            if self.attn_fallback == "head_dim" and \
                    _divides(hd, self.model_size):
                return P(*lead, None, self.model_axis, self._fsdp_if(d))
            return P(*lead, None, None, self._fsdp_if(d))

        # MLA
        if leaf in ("wq_a", "wkv_a"):
            lead = (None,) * (len(shape) - 2)
            return P(*lead, self._fsdp_if(shape[-2]), None)
        if leaf in ("wq_b", "wkv_b"):
            lead = (None,) * (len(shape) - 3)
            return P(*lead, None, self._model_if(shape[-2]), None)

        # SSM projections [*, d, K] / out_proj [*, d_in, d]
        if leaf == "in_proj":
            lead = (None,) * (len(shape) - 2)
            return P(*lead, self._fsdp_if(shape[-2]), None)
        if leaf == "out_proj":
            lead = (None,) * (len(shape) - 2)
            return P(*lead, self._model_if(shape[-2]), self._fsdp_if(shape[-1]))
        if leaf == "proj":                          # mtp [2d, d]
            lead = (None,) * (len(shape) - 2)
            return P(*lead, self._fsdp_if(shape[-2]), self._model_if(shape[-1]))

        # default: replicate
        return P()

    def param_shardings(self, abstract_params) -> Any:
        from repro.core.namespace import flatten_tree
        flat = flatten_tree(abstract_params)
        specs = {k: NamedSharding(self.mesh, self.param_spec(k, tuple(v.shape)))
                 for k, v in flat.items()}
        from repro.core.namespace import unflatten_tree
        return unflatten_tree(specs)

    # ------------------------------------------------------------------
    # activations / batches / caches
    # ------------------------------------------------------------------
    def batch_spec(self, batch_tree) -> Any:
        def spec(x):
            if not hasattr(x, "shape") or x.ndim == 0:
                return NamedSharding(self.mesh, P())
            b = self._batch_if(x.shape[0])
            return NamedSharding(self.mesh, P(b, *([None] * (x.ndim - 1))))
        return jax.tree.map(spec, batch_tree)

    def cache_spec(self, caches_tree, batch: int) -> Any:
        """Decode caches: batch over data axes, *sequence* dim over model.

        Cache leaves are stacked [n_units, ...]; leaf kinds are identified by
        rank/shape (k/v: [U,B,S,H,hd]; c_kv: [U,B,S,r]; k_rope: [U,B,S,1,hd];
        ssm state: [U,B,H,P,N]; conv: [U,B,W,C]; index: [U])."""
        bspec = self._batch_if(batch)

        def spec(x):
            if not hasattr(x, "shape") or x.ndim <= 1:
                return NamedSharding(self.mesh, P())
            s = list(x.shape)
            if x.ndim == 5 and s[1] == batch:       # k/v cache [U,B,S,H,hd]
                seq_ax = self._model_if(s[2])
                if s[3] == 1:                        # k_rope single head
                    return NamedSharding(self.mesh, P(None, bspec, seq_ax, None, None))
                return NamedSharding(self.mesh, P(None, bspec, seq_ax, None, None))
            if x.ndim == 4 and s[1] == batch:
                # c_kv [U,B,S,r] or ssm state [U,B,H,P] won't occur (state is 5D
                # with U); treat dim2 as seq/heads: shard over model if divisible
                return NamedSharding(self.mesh, P(None, bspec, self._model_if(s[2]), None))
            if x.ndim == 3 and s[1] == batch:        # conv [U,B? ...]
                return NamedSharding(self.mesh, P(None, bspec, None))
            if x.ndim >= 2 and s[0] == batch:        # enc_out [B,S,d]
                return NamedSharding(self.mesh, P(bspec, *([None] * (x.ndim - 1))))
            return NamedSharding(self.mesh, P())
        return jax.tree.map(spec, caches_tree)

    def logits_spec(self, batch: int) -> NamedSharding:
        return NamedSharding(
            self.mesh, P(self._batch_if(batch), None,
                         self._model_if(self.cfg.padded_vocab)))

    def replicated(self) -> NamedSharding:
        return NamedSharding(self.mesh, P())

    # activation constraint used at stage boundaries inside the model
    def hidden_spec(self, batch: int, seq: int = 0) -> NamedSharding:
        if self.seq_shard_activations and seq and \
                _divides(seq, self.model_size):
            return NamedSharding(self.mesh,
                                 P(self._batch_if(batch), self.model_axis,
                                   None))
        return NamedSharding(self.mesh,
                             P(self._batch_if(batch), None, None))
