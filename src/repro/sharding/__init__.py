from repro.sharding.rules import ShardingRules

__all__ = ["ShardingRules"]
