"""Elastic restore: checkpoints are mesh-independent.

Chunk manifests describe *global* arrays (co-variable base buffers), so a
state written on a 16x16 mesh restores onto any other mesh — or onto a
different host count — by (a) selecting only the chunks overlapping the byte
ranges a host is responsible for and (b) ``device_put`` with the new
sharding.  This is the node-failure / elastic-scaling path: lose a pod,
rebuild the mesh, reload shard-locally, continue.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core.chunkstore import ChunkStore
from repro.core.serialize import leaf_from_bytes


def chunks_for_range(manifest: dict, lo: int, hi: int) -> List[int]:
    """Indices of chunks overlapping global byte range [lo, hi)."""
    out = []
    off = 0
    for i, c in enumerate(manifest["base"]["chunks"]):
        if off < hi and off + c["n"] > lo:
            out.append(i)
        off += c["n"]
    return out


def load_byte_range(store: ChunkStore, manifest: dict, lo: int, hi: int
                    ) -> bytes:
    """Assemble exactly [lo, hi) of the base buffer, reading only the
    overlapping chunks (shard-local restore).  The overlapping chunk set is
    planned first and fetched with the backend's batched op, so a host's
    shard streams in at store bandwidth instead of per-chunk round-trips."""
    base = manifest["base"]
    wanted = []                      # (key, slice lo, slice hi) per chunk
    off = 0
    for c in base["chunks"]:
        if off < hi and off + c["n"] > lo:
            wanted.append((c["key"], max(lo - off, 0), min(hi - off, c["n"])))
        off += c["n"]
        if off >= hi:
            break
    got = store.get_chunks([k for k, _, _ in wanted])
    return b"".join(got[k][a:b] for k, a, b in wanted)


def host_shard_ranges(shape: Tuple[int, ...], dtype, sharding
                      ) -> Dict[int, List[Tuple[int, int]]]:
    """Per-device contiguous byte ranges of a C-order array under a sharding.

    Only exact for shardings that partition the leading dimension (the FSDP
    layout used for parameters); other layouts fall back to the full range.
    """
    item = np.dtype(dtype).itemsize
    total = int(np.prod(shape, dtype=np.int64)) * item
    try:
        idx_map = sharding.devices_indices_map(tuple(shape))
    except Exception:  # noqa: BLE001
        return {0: [(0, total)]}
    row_bytes = total // shape[0] if shape else total
    out: Dict[int, List[Tuple[int, int]]] = {}
    for dev, idx in idx_map.items():
        first = idx[0] if idx else slice(None)
        if isinstance(first, slice) and all(
                (s == slice(None) for s in idx[1:])):
            lo = (first.start or 0) * row_bytes
            hi = (first.stop if first.stop is not None else shape[0]) * row_bytes
            out[getattr(dev, "id", 0)] = [(lo, hi)]
        else:
            out[getattr(dev, "id", 0)] = [(0, total)]
    return out


def elastic_restore_leaf(store: ChunkStore, manifest: dict,
                         sharding=None) -> Any:
    """Restore a manifest's base leaf, optionally placing it with a new
    sharding (single-process path: full load + device_put)."""
    base = manifest["base"]
    blob = load_byte_range(store, manifest, 0, base["nbytes"])
    leaf = leaf_from_bytes(blob, base["meta"])
    if sharding is not None and isinstance(leaf, jax.Array):
        leaf = jax.device_put(leaf, sharding)
    return leaf
