"""Trace-time sharding hints for layers that GSPMD mis-resolves.

The MoE expert matmul with pod-sharded weights has two legal SPMD
resolutions: all-reduce the [E, capacity, d_ff] output (~86 GB/layer for
DeepSeek-V3 — catastrophic, and what GSPMD picks) or all-gather the weights
(~44 MB/device/layer — ZeRO-style, what we want).  ``moe_weight_gather``
installs per-weight resharding constraints that moe_forward applies at use
time, forcing the gather resolution while the *persistent* weights stay
pod-sharded (the memory win).  Measured in EXPERIMENTS.md §Perf iteration C3.
"""
from __future__ import annotations

import contextlib
from typing import Optional, Tuple

_MOE_WEIGHT_SHARDINGS: Optional[Tuple] = None


def get_moe_weight_shardings():
    return _MOE_WEIGHT_SHARDINGS


@contextlib.contextmanager
def moe_weight_gather(rules):
    """Within this context, traced moe_forward calls re-shard expert weights
    to the dispatch layout (expert dim over data, ff over model, d_model
    replicated) before the expert einsums; with ``moe_dispatch_shard`` the
    scatter/gather dispatch buffers are additionally constrained to
    expert-sharded layouts (all-to-all token shuffle instead of replicated
    buffers)."""
    global _MOE_WEIGHT_SHARDINGS
    gather = getattr(rules, "expert_fsdp_pod", False)
    dispatch = getattr(rules, "moe_dispatch_shard", False)
    if not gather and not dispatch:
        yield
        return
    from jax.sharding import NamedSharding, PartitionSpec as P
    e = rules.data_axis
    m = rules.model_axis
    # moe_forward sees the per-unit slice [E, d, f] (the stacked n_units dim
    # is consumed by the scan/unroll over units)
    gate_up = NamedSharding(rules.mesh, P(e, None, m)) if gather else None
    down = NamedSharding(rules.mesh, P(e, m, None)) if gather else None
    buf_sh = NamedSharding(rules.mesh, P(e, None, None)) if dispatch else None
    h_sh = NamedSharding(rules.mesh, P(e, None, m)) if dispatch else None
    prev = _MOE_WEIGHT_SHARDINGS
    _MOE_WEIGHT_SHARDINGS = (gate_up, gate_up, down, buf_sh, h_sh)
    try:
        yield
    finally:
        _MOE_WEIGHT_SHARDINGS = prev
