from repro.data.pipeline import DataState, TokenPipeline

__all__ = ["DataState", "TokenPipeline"]
