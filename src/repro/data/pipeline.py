"""Deterministic, shardable, checkpointable synthetic data pipeline.

Batches are a pure function of (seed, step, shard) via counter-based Philox
streams, so:
  - replay is bit-exact (Kishu's fallback recomputation relies on the data
    state being a versioned leaf in the namespace — §5.3),
  - each data-parallel host generates only its shard (no host-0 broadcast),
  - resuming from a checkpointed ``DataState`` continues the exact stream,
    on *any* mesh shape (elastic restart: the stream is keyed by global
    example index, not by host).

The token distribution is a Zipf-like mixture with injected n-gram structure
so losses actually decrease during example runs (pure-uniform tokens give a
flat loss and make end-to-end tests meaningless).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class DataState:
    seed: int
    step: int

    def as_tree(self) -> Dict[str, int]:
        return {"seed": int(self.seed), "step": int(self.step)}

    @classmethod
    def from_tree(cls, t) -> "DataState":
        return cls(seed=int(t["seed"]), step=int(t["step"]))


class TokenPipeline:
    def __init__(self, vocab_size: int, global_batch: int, seq_len: int, *,
                 n_hosts: int = 1, host_id: int = 0):
        assert global_batch % n_hosts == 0
        self.vocab = vocab_size
        self.global_batch = global_batch
        self.local_batch = global_batch // n_hosts
        self.seq = seq_len
        self.n_hosts = n_hosts
        self.host_id = host_id

    def _example(self, seed: int, index: int) -> np.ndarray:
        """One (seq+1,) token stream keyed by global example index."""
        rng = np.random.Generator(np.random.Philox(key=seed, counter=index))
        # Zipf-ish marginal
        z = rng.zipf(1.3, size=self.seq + 1)
        toks = (z - 1) % self.vocab
        # inject deterministic bigram structure: with p=0.5, next = f(prev)
        follow = rng.random(self.seq + 1) < 0.5
        prev = np.roll(toks, 1)
        toks = np.where(follow, (prev * 31 + 7) % self.vocab, toks)
        return toks.astype(np.int32)

    def batch_at(self, state: DataState) -> Dict[str, np.ndarray]:
        """Deterministic local batch for ``state`` (host's shard only)."""
        base = state.step * self.global_batch + self.host_id * self.local_batch
        toks = np.stack([self._example(state.seed, base + i)
                         for i in range(self.local_batch)])
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def next_batch(self, state: DataState
                   ) -> Tuple[Dict[str, np.ndarray], DataState]:
        return self.batch_at(state), DataState(state.seed, state.step + 1)
