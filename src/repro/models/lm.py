"""Unified language model covering all 10 assigned architectures.

One config-driven decoder (+ optional encoder for enc-dec) built from:
  - per-layer specs (attention kind x FFN kind) derived from ArchConfig
  - scan-over-layers with stacked parameters, grouped into *stages* of
    repeating units so heterogeneous stacks (hybrid interleave, dense-prefix
    MoE) still lower to compact HLO
  - remat (jax.checkpoint) around the unit body for training
  - full-sequence forward (train/prefill) and one-token decode with caches

Parameters are nested dicts of arrays; caches are nested dicts stacked along
a leading n_units dim per stage so decode also scans.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ArchConfig
from repro.models import layers, mamba, moe as moe_lib

Array = jax.Array


# ---------------------------------------------------------------------------
# layer specs and stages
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class LayerSpec:
    kind: str          # "attn" | "ssm"
    ffn: str           # "dense" | "moe" | "none"
    cross: bool = False  # decoder cross-attention (enc-dec)


@dataclass(frozen=True)
class StageSpec:
    unit: Tuple[LayerSpec, ...]
    n_units: int


def layer_specs(cfg: ArchConfig, *, decoder: bool = True) -> List[LayerSpec]:
    kinds = cfg.layer_kinds
    specs = []
    for i, kind in enumerate(kinds):
        if cfg.family == "ssm":
            ffn = "none"
        elif cfg.moe is not None and i >= cfg.moe.n_dense_layers and \
                (i % cfg.moe.every_k_layers == cfg.moe.every_k_layers - 1):
            ffn = "moe"
        else:
            ffn = "dense"
        specs.append(LayerSpec(kind, ffn, cross=cfg.enc_dec and decoder))
    return specs


def _min_period(specs: List[LayerSpec]) -> int:
    n = len(specs)
    for u in range(1, n + 1):
        if n % u == 0 and all(specs[i] == specs[i % u] for i in range(n)):
            return u
    return n


def build_stages(cfg: ArchConfig, *, decoder: bool = True) -> List[StageSpec]:
    """Split the layer stack into (prefix) + (periodic) stages."""
    specs = layer_specs(cfg, decoder=decoder)
    prefix = cfg.moe.n_dense_layers if cfg.moe else 0
    stages: List[StageSpec] = []
    if prefix:
        head = specs[:prefix]
        u = _min_period(head)
        stages.append(StageSpec(tuple(head[:u]), len(head) // u))
        specs = specs[prefix:]
    if specs:
        u = _min_period(specs)
        stages.append(StageSpec(tuple(specs[:u]), len(specs) // u))
    return stages


def encoder_stages(cfg: ArchConfig) -> List[StageSpec]:
    n_enc = cfg.n_encoder_layers or cfg.n_layers
    spec = LayerSpec("attn", "dense", cross=False)
    return [StageSpec((spec,), n_enc)]


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------

def _init_layer(key, cfg: ArchConfig, spec: LayerSpec, dtype) -> dict:
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    p: Dict[str, Any] = {"norm1": layers.rmsnorm_init(d, dtype)}
    if spec.kind == "attn":
        if cfg.mla is not None:
            p["attn"] = layers.mla_init(ks[0], cfg, dtype)
        else:
            p["attn"] = layers.gqa_init(ks[0], cfg, dtype)
    else:
        p["ssm"] = mamba.ssm_init(ks[0], cfg, dtype)
    if spec.cross:
        p["cross_norm"] = layers.rmsnorm_init(d, dtype)
        p["cross"] = layers.cross_attn_init(ks[1], cfg, dtype)
    if spec.ffn == "dense":
        p["norm2"] = layers.rmsnorm_init(d, dtype)
        p["mlp"] = layers.mlp_init(ks[2], d, cfg.d_ff, dtype)
    elif spec.ffn == "moe":
        p["norm2"] = layers.rmsnorm_init(d, dtype)
        p["moe"] = moe_lib.moe_init(ks[2], cfg, dtype)
    return p


def _init_stage(key, cfg: ArchConfig, stage: StageSpec, dtype) -> dict:
    def unit_init(k):
        uks = jax.random.split(k, len(stage.unit))
        return {f"sub_{j}": _init_layer(uks[j], cfg, spec, dtype)
                for j, spec in enumerate(stage.unit)}
    keys = jax.random.split(key, stage.n_units)
    return jax.vmap(unit_init)(keys)


def init_params(cfg: ArchConfig, key: Array, dtype=None) -> dict:
    dtype = dtype or jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    embed = (jax.random.normal(ks[0], (cfg.padded_vocab, d), jnp.float32)
             * 0.02).astype(dtype)
    params: Dict[str, Any] = {
        "embed": embed,
        "final_norm": layers.rmsnorm_init(d, dtype),
        "stages": {},
    }
    for i, stage in enumerate(build_stages(cfg)):
        params["stages"][f"stage_{i}"] = _init_stage(ks[1 + i % 4], cfg, stage, dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = layers.dense_param(ks[5], d, cfg.padded_vocab, dtype)
    if cfg.enc_dec:
        enc: Dict[str, Any] = {"final_norm": layers.rmsnorm_init(d, dtype),
                               "stages": {}}
        for i, stage in enumerate(encoder_stages(cfg)):
            enc["stages"][f"stage_{i}"] = _init_stage(ks[6], cfg, stage, dtype)
        params["encoder"] = enc
    if cfg.mtp:
        params["mtp"] = {
            "proj": layers.dense_param(ks[7], 2 * d, d, dtype),
            "norm": layers.rmsnorm_init(d, dtype),
            "block": _init_layer(ks[3], cfg, LayerSpec("attn", "dense"), dtype),
        }
    # tied-embedding aliasing is realised at the state level (the training
    # state exposes `lm_head` as the same buffer as `embed`); inside the
    # model we read cfg.tie_embeddings.
    return params


def abstract_params(cfg: ArchConfig, dtype=None):
    """ShapeDtypeStruct pytree of the parameters (no allocation beyond a key)."""
    key = jax.random.key(0)
    return jax.eval_shape(lambda k: init_params(cfg, k, dtype), key)


# ---------------------------------------------------------------------------
# layer application (shared by forward & decode)
# ---------------------------------------------------------------------------

def _positions_of(batch: dict, cfg: ArchConfig, seq: int, bsz: int,
                  offset=0):
    if cfg.rope_type == "mrope":
        if "positions_thw" in batch:
            return batch["positions_thw"]
        pos = jnp.arange(seq, dtype=jnp.int32)[None, :] + offset
        pos = jnp.broadcast_to(pos, (bsz, seq))
        return jnp.stack([pos, pos, pos], axis=-1)
    pos = jnp.arange(seq, dtype=jnp.int32)[None, :] + offset
    return jnp.broadcast_to(pos, (bsz, seq))


def _sinusoidal_embed(positions: Array, d: int) -> Array:
    """In-graph sinusoidal positional embedding. positions [B,S] -> [B,S,d]."""
    half = d // 2
    inv = jnp.exp(-jnp.arange(half, dtype=jnp.float32)
                  * (np.log(10_000.0) / max(half - 1, 1)))
    ang = positions.astype(jnp.float32)[..., None] * inv
    out = jnp.zeros((*positions.shape, d), jnp.float32)
    out = out.at[..., 0::2].set(jnp.sin(ang))
    out = out.at[..., 1::2].set(jnp.cos(ang))
    return out


def _apply_layer(p: dict, cfg: ArchConfig, spec: LayerSpec, x: Array,
                 positions, enc_out: Optional[Array]) -> Tuple[Array, Array]:
    """Full-sequence layer. Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = layers.rmsnorm(p["norm1"], x, cfg.norm_eps)
    if spec.kind == "attn":
        if cfg.mla is not None:
            y = layers.mla_forward(p["attn"], cfg, h, positions)
        else:
            y = layers.gqa_forward(p["attn"], cfg, h, positions)
    else:
        y = mamba.ssm_forward(p["ssm"], cfg, h)
    x = x + y
    if spec.cross:
        h = layers.rmsnorm(p["cross_norm"], x, cfg.norm_eps)
        x = x + layers.cross_attn_forward(p["cross"], cfg, h, enc_out)
    if spec.ffn == "dense":
        h = layers.rmsnorm(p["norm2"], x, cfg.norm_eps)
        x = x + layers.mlp_forward(p["mlp"], h)
    elif spec.ffn == "moe":
        h = layers.rmsnorm(p["norm2"], x, cfg.norm_eps)
        y = moe_lib.moe_forward(p["moe"], cfg, h)
        aux = moe_lib.aux_load_balance_loss(
            p["moe"]["router"], h.reshape(-1, h.shape[-1]), cfg.moe)
        x = x + y
    return x, aux


def _run_stages(stages_params: dict, stage_specs: List[StageSpec],
                cfg: ArchConfig, x: Array, positions,
                enc_out: Optional[Array], *, remat: bool,
                unroll: bool = False,
                hidden_sharding=None) -> Tuple[Array, Array]:
    """Apply all stages.  ``unroll=True`` replaces the lax.scan over units
    with a python loop (no while op in HLO) — used by the dry-run's cost
    calibration (XLA cost analysis counts a while body once, not x trip
    count) and available as a perf lever (scan-vs-unroll trade-off)."""
    aux_total = jnp.zeros((), jnp.float32)
    if hidden_sharding is not None:
        x = jax.lax.with_sharding_constraint(x, hidden_sharding)
    for i, stage in enumerate(stage_specs):
        sp = stages_params[f"stage_{i}"]

        def unit_body(carry, unit_params, _stage=stage):
            h, aux = carry
            for j, spec in enumerate(_stage.unit):
                h, a = _apply_layer(unit_params[f"sub_{j}"], cfg, spec, h,
                                    positions, enc_out)
                aux = aux + a
            return (h, aux)

        body = unit_body
        if remat:
            body = jax.checkpoint(unit_body)

        if unroll:
            carry = (x, aux_total)
            for u in range(stage.n_units):
                unit_params = jax.tree.map(lambda a, _u=u: a[_u], sp)
                carry = body(carry, unit_params)
            x, aux_total = carry
        else:
            def scan_step(carry, unit_params, _body=body):
                return _body(carry, unit_params), None

            (x, aux_total), _ = jax.lax.scan(scan_step, (x, aux_total), sp)
        if hidden_sharding is not None:
            x = jax.lax.with_sharding_constraint(x, hidden_sharding)
    return x, aux_total


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def embed_inputs(cfg: ArchConfig, params: dict, batch: dict) -> Array:
    if "embeds" in batch:
        x = batch["embeds"].astype(jnp.dtype(cfg.dtype))
    else:
        x = params["embed"][batch["tokens"]]
    return x


def forward(cfg: ArchConfig, params: dict, batch: dict, *,
            training: bool = False, remat: Optional[bool] = None,
            return_aux: bool = False, unroll: bool = False,
            hidden_sharding=None):
    """Full-sequence forward. Returns logits [B,S,V] (and aux dict)."""
    remat = training if remat is None else remat
    x = embed_inputs(cfg, params, batch)
    bsz, seq, d = x.shape
    positions = _positions_of(batch, cfg, seq, bsz)
    if cfg.rope_type == "none":
        pos2d = positions if positions.ndim == 2 else positions[..., 0]
        x = (x.astype(jnp.float32) + _sinusoidal_embed(pos2d, d)).astype(x.dtype)

    enc_out = None
    if cfg.enc_dec:
        enc_out = encode(cfg, params, batch, remat=remat, unroll=unroll)

    x, aux = _run_stages(params["stages"], build_stages(cfg), cfg, x,
                         positions, enc_out, remat=remat, unroll=unroll,
                         hidden_sharding=hidden_sharding)
    x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(cfg, params, x)

    aux_d = {"moe_aux": aux}
    if cfg.mtp and training:
        aux_d["mtp_logits"] = _mtp_logits(cfg, params, x, batch, positions)
    if return_aux:
        return logits, aux_d
    return logits


def encode(cfg: ArchConfig, params: dict, batch: dict, *, remat: bool,
           unroll: bool = False) -> Array:
    enc_x = batch["enc_embeds"].astype(jnp.dtype(cfg.dtype))
    bsz, s_enc, d = enc_x.shape
    pos = jnp.broadcast_to(jnp.arange(s_enc, dtype=jnp.int32)[None, :],
                           (bsz, s_enc))
    enc_x = (enc_x.astype(jnp.float32)
             + _sinusoidal_embed(pos, d)).astype(enc_x.dtype)
    enc = params["encoder"]
    enc_x, _ = _run_stages(enc["stages"], encoder_stages(cfg), cfg, enc_x,
                           pos, None, remat=remat, unroll=unroll)
    return layers.rmsnorm(enc["final_norm"], enc_x, cfg.norm_eps)


def unembed(cfg: ArchConfig, params: dict, x: Array) -> Array:
    if cfg.tie_embeddings or "lm_head" not in params:
        w = params["embed"].T
    else:
        w = params["lm_head"]
    return jnp.einsum("bsd,dv->bsv", x, w,
                      preferred_element_type=jnp.float32)


def _mtp_logits(cfg, params, h_final, batch, positions):
    """DeepSeek-V3-style multi-token prediction: one extra block predicting
    token t+2 from [h_t ; embed(token_{t+1})]."""
    mtp = params["mtp"]
    tok = batch["tokens"]
    nxt = jnp.concatenate([tok[:, 1:], tok[:, -1:]], axis=1)
    e_next = params["embed"][nxt]
    h = jnp.concatenate([layers.rmsnorm(mtp["norm"], h_final, cfg.norm_eps),
                         e_next], axis=-1)
    h = jnp.einsum("bsk,kd->bsd", h, mtp["proj"],
                   preferred_element_type=jnp.float32).astype(h_final.dtype)
    h, _ = _apply_layer(mtp["block"], cfg, LayerSpec("attn", "dense"), h,
                        positions, None)
    return unembed(cfg, params, h)


# ---------------------------------------------------------------------------
# decode (one token against caches)
# ---------------------------------------------------------------------------

def _init_layer_cache(cfg: ArchConfig, spec: LayerSpec, batch: int, seq: int,
                      dtype) -> dict:
    c: Dict[str, Any] = {}
    if spec.kind == "attn":
        if cfg.mla is not None:
            c["attn"] = layers.mla_cache_init(cfg, batch, seq, dtype)
        else:
            c["attn"] = layers.gqa_cache_init(cfg, batch, seq, dtype)
    else:
        c["ssm"] = mamba.ssm_cache_init(cfg, batch, dtype)
    return c


def init_caches(cfg: ArchConfig, batch: int, seq: int, dtype=None,
                enc_seq: int = 0) -> dict:
    """Cache pytree: per stage, leaves stacked along n_units."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    caches: Dict[str, Any] = {"stages": {}}
    for i, stage in enumerate(build_stages(cfg)):
        def unit_cache(_, _stage=stage):
            return {f"sub_{j}": _init_layer_cache(cfg, spec, batch, seq, dtype)
                    for j, spec in enumerate(_stage.unit)}
        caches["stages"][f"stage_{i}"] = jax.vmap(unit_cache)(
            jnp.arange(stage.n_units))
    if cfg.enc_dec:
        caches["enc_out"] = jnp.zeros((batch, enc_seq or seq, cfg.d_model),
                                      dtype=dtype)
    return caches


def _decode_layer(p: dict, c: dict, cfg: ArchConfig, spec: LayerSpec,
                  x: Array, positions, enc_out) -> Tuple[Array, dict]:
    new_c: Dict[str, Any] = {}
    h = layers.rmsnorm(p["norm1"], x, cfg.norm_eps)
    if spec.kind == "attn":
        if cfg.mla is not None:
            y, new_c["attn"] = layers.mla_decode(p["attn"], cfg, h, c["attn"],
                                                 positions)
        else:
            y, new_c["attn"] = layers.gqa_decode(p["attn"], cfg, h, c["attn"],
                                                 positions)
    else:
        y, new_c["ssm"] = mamba.ssm_decode(p["ssm"], cfg, h, c["ssm"])
    x = x + y
    if spec.cross:
        h = layers.rmsnorm(p["cross_norm"], x, cfg.norm_eps)
        x = x + layers.cross_attn_forward(p["cross"], cfg, h, enc_out)
    if spec.ffn == "dense":
        h = layers.rmsnorm(p["norm2"], x, cfg.norm_eps)
        x = x + layers.mlp_forward(p["mlp"], h)
    elif spec.ffn == "moe":
        h = layers.rmsnorm(p["norm2"], x, cfg.norm_eps)
        y = moe_lib.moe_forward(p["moe"], cfg, h)
        x = x + y
    return x, new_c


def decode_step(cfg: ArchConfig, params: dict, caches: dict, batch: dict,
                *, unroll: bool = False) -> Tuple[Array, dict]:
    """One-token decode. batch: {"tokens": [B,1]} (vlm may pass embeds).
    Returns (logits [B,1,V], new caches)."""
    x = embed_inputs(cfg, params, batch)
    bsz, _, d = x.shape
    index = batch["index"]  # scalar int32: current cache fill
    if cfg.rope_type == "mrope":
        pos = jnp.broadcast_to(index[None, None], (bsz, 1)).astype(jnp.int32)
        positions = jnp.stack([pos, pos, pos], axis=-1)
    else:
        positions = jnp.broadcast_to(index[None, None], (bsz, 1)).astype(jnp.int32)
    if cfg.rope_type == "none":
        x = (x.astype(jnp.float32)
             + _sinusoidal_embed(positions, d)).astype(x.dtype)

    enc_out = caches.get("enc_out")
    new_caches: Dict[str, Any] = {"stages": {}}
    if enc_out is not None:
        new_caches["enc_out"] = enc_out

    for i, stage in enumerate(build_stages(cfg)):
        sp = params["stages"][f"stage_{i}"]
        sc = caches["stages"][f"stage_{i}"]

        def scan_step(carry, xs, _stage=stage):
            h = carry
            unit_p, unit_c = xs
            new_unit_c = {}
            for j, spec in enumerate(_stage.unit):
                h, nc = _decode_layer(unit_p[f"sub_{j}"], unit_c[f"sub_{j}"],
                                      cfg, spec, h, positions, enc_out)
                new_unit_c[f"sub_{j}"] = nc
            return h, new_unit_c

        if unroll:
            outs = []
            for u in range(stage.n_units):
                unit_p = jax.tree.map(lambda a, _u=u: a[_u], sp)
                unit_c = jax.tree.map(lambda a, _u=u: a[_u], sc)
                x, nc = scan_step(x, (unit_p, unit_c))
                outs.append(nc)
            new_sc = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        else:
            x, new_sc = jax.lax.scan(scan_step, x, (sp, sc))
        new_caches["stages"][f"stage_{i}"] = new_sc

    x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(cfg, params, x)
    return logits, new_caches
