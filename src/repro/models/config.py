"""Architecture configuration system.

One frozen dataclass describes every assigned architecture; the unified LM in
``lm.py`` interprets it. Configs are pure data — safe to import without touching
jax device state.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2/V3)."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 16
    top_k: int = 2
    d_ff_expert: int = 0          # expert hidden size (0 -> use cfg.d_ff)
    n_shared_experts: int = 0     # always-on shared experts (DeepSeek)
    capacity_factor: float = 1.25
    every_k_layers: int = 1       # MoE on layers where (idx % every_k == k-1)
    n_dense_layers: int = 0       # first N layers stay dense (DeepSeek: 3)
    router_dtype: str = "float32"


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 SSD parameters."""
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    chunk_size: int = 256
    n_groups: int = 1
    conv_width: int = 4


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | ssm | hybrid | moe | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    qk_norm: bool = False
    rope_type: str = "standard"   # standard | mrope | none
    rope_theta: float = 10_000.0
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)  # t/h/w split of head_dim/2
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # Hybrid interleave: repeating unit of layer kinds, e.g. ("attn",) + ("ssm",)*7.
    hybrid_pattern: Optional[Tuple[str, ...]] = None
    enc_dec: bool = False         # whisper: encoder + decoder w/ cross-attention
    n_encoder_layers: int = 0     # enc-dec only (0 -> n_layers)
    frontend: Optional[str] = None  # "audio" | "vision" | None (stub modality)
    mtp: bool = False             # multi-token-prediction extra block (DeepSeek-V3)
    dtype: str = "bfloat16"
    # Embedding tables are padded up to a multiple of this so the vocab dim is
    # always TP-shardable; the loss/sampler mask positions >= vocab_size.
    vocab_pad_multiple: int = 256
    # Source provenance, for the config files' docstrings.
    source: str = ""

    # ---- derived ----
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k shape (SSM/hybrid families)."""
        return self.family in ("ssm", "hybrid")

    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer kind sequence, length n_layers (decoder side for enc-dec)."""
        if self.hybrid_pattern:
            unit = self.hybrid_pattern
            reps = self.n_layers // len(unit)
            assert reps * len(unit) == self.n_layers, (
                f"{self.name}: n_layers={self.n_layers} not a multiple of "
                f"hybrid unit {len(unit)}")
            return unit * reps
        if self.family == "ssm":
            return ("ssm",) * self.n_layers
        return ("attn",) * self.n_layers

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    # ---- parameter counting (for 6ND roofline terms) ----
    def param_counts(self) -> dict:
        """Analytic parameter counts: {'total': N, 'active': N_active}.

        ``active`` counts MoE experts at top_k (+shared) instead of n_experts,
        which is what 6*N_active*D model-FLOPs uses.
        """
        d, hd = self.d_model, self.resolved_head_dim
        nq, nkv = self.n_heads, self.n_kv_heads
        total = active = 0

        def attn_params() -> int:
            if self.mla is not None:
                m = self.mla
                qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
                p = d * m.q_lora_rank + m.q_lora_rank * nq * qk_hd        # q down/up
                p += d * (m.kv_lora_rank + m.qk_rope_head_dim)             # kv down
                p += m.kv_lora_rank * nq * (m.qk_nope_head_dim + m.v_head_dim)
                p += nq * m.v_head_dim * d                                 # o proj
                return p
            return d * nq * hd + 2 * d * nkv * hd + nq * hd * d

        def mlp_params(ff: int) -> int:
            return 3 * d * ff                                              # gate,up,down

        def ssm_params() -> int:
            s = self.ssm or SSMConfig()
            d_in = s.expand * d
            nh = d_in // s.head_dim
            p = d * (2 * d_in + 2 * s.n_groups * s.d_state + nh)           # in_proj
            p += s.conv_width * (d_in + 2 * s.n_groups * s.d_state)        # conv
            p += 2 * nh                                                    # A_log, D
            p += d_in * d                                                  # out_proj
            return p

        kinds = self.layer_kinds
        moe = self.moe
        for i, kind in enumerate(kinds):
            if kind == "attn":
                total += attn_params(); active += attn_params()
            else:
                total += ssm_params(); active += ssm_params()
            # per-layer FFN (attn layers in hybrids also carry FFN; ssm layers in
            # pure-ssm archs do not).
            if self.family == "ssm":
                continue
            if moe is not None and i >= moe.n_dense_layers and \
                    (i % moe.every_k_layers == moe.every_k_layers - 1):
                ff = moe.d_ff_expert or self.d_ff
                total += moe.n_experts * mlp_params(ff)
                active += moe.top_k * mlp_params(ff)
                total += moe.n_shared_experts * mlp_params(ff)
                active += moe.n_shared_experts * mlp_params(ff)
                total += d * moe.n_experts                                  # router
                active += d * moe.n_experts
            else:
                total += mlp_params(self.d_ff); active += mlp_params(self.d_ff)
        # norms (2/layer + final)
        total += (2 * len(kinds) + 1) * d; active += (2 * len(kinds) + 1) * d
        # embeddings (+ untied head)
        emb = self.vocab_size * d
        total += emb; active += emb
        if not self.tie_embeddings:
            total += emb; active += emb
        if self.enc_dec:
            n_enc = self.n_encoder_layers or self.n_layers
            enc = n_enc * (attn_params() + mlp_params(self.d_ff) + 2 * d)
            # decoder cross-attention blocks
            dec_x = len(kinds) * (attn_params() + d)
            total += enc + dec_x; active += enc + dec_x
        return {"total": total, "active": active}


_REGISTRY: dict = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    # configs package registers on import
    from repro import configs as _  # noqa: F401
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list:
    from repro import configs as _  # noqa: F401
    return sorted(_REGISTRY)
