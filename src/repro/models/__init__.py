from repro.models.config import (ArchConfig, MLAConfig, MoEConfig, SSMConfig,
                                 get_config, list_configs, register)

__all__ = ["ArchConfig", "MLAConfig", "MoEConfig", "SSMConfig", "get_config",
           "list_configs", "register"]
