"""Core neural layers: norms, RoPE (standard + M-RoPE), GQA and MLA attention,
gated MLP. Pure-functional JAX; parameters are plain nested dicts of arrays.

Conventions
-----------
- activations: [batch, seq, d_model] unless noted
- attention tensors: [batch, seq, heads, head_dim]
- all matmuls accumulate in float32 (``preferred_element_type``), outputs cast
  back to the activation dtype.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ArchConfig, MLAConfig

Array = jax.Array

# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def _dense_init(key, shape, in_axis_size, dtype):
    scale = 1.0 / np.sqrt(max(in_axis_size, 1))
    return (jax.random.uniform(key, shape, jnp.float32, -scale, scale)
            .astype(dtype))


def dense_param(key, d_in: int, d_out, dtype) -> Array:
    shape = (d_in, d_out) if isinstance(d_out, int) else (d_in, *d_out)
    return _dense_init(key, shape, d_in, dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rmsnorm(params: dict, x: Array, eps: float = 1e-5) -> Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float64)
                            / head_dim))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """Standard rotary embedding. x: [B,S,H,hd]; positions: [B,S] (int32)."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(hd, theta), dtype=jnp.float32)
    ang = positions[..., None].astype(jnp.float32) * freqs        # [B,S,hd/2]
    cos = jnp.cos(ang)[:, :, None, :]                             # [B,S,1,hd/2]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: Array, positions_thw: Array, theta: float,
                sections: Tuple[int, int, int]) -> Array:
    """Multimodal RoPE (Qwen2-VL): the head_dim/2 frequency bands are split
    into (t,h,w) sections, each rotated by its own position id.

    x: [B,S,H,hd]; positions_thw: [B,S,3] int32; sections sum to hd//2.
    """
    hd = x.shape[-1]
    assert sum(sections) == hd // 2, (sections, hd)
    freqs = jnp.asarray(rope_frequencies(hd, theta), dtype=jnp.float32)
    # pick the position id per frequency band
    sect_id = jnp.asarray(
        np.repeat(np.arange(3), np.asarray(sections)), dtype=jnp.int32)  # [hd/2]
    pos = jnp.take_along_axis(
        positions_thw.astype(jnp.float32),
        jnp.broadcast_to(sect_id[None, None, :],
                         (*positions_thw.shape[:2], hd // 2)),
        axis=-1)                                                  # [B,S,hd/2]
    ang = pos * freqs
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, d: int) -> np.ndarray:
    """Whisper-style fixed sinusoidal embeddings [seq, d]."""
    pos = np.arange(seq)[:, None]
    inv = 1.0 / (10_000 ** (np.arange(0, d, 2) / d))
    ang = pos * inv[None, :]
    out = np.zeros((seq, d), dtype=np.float32)
    out[:, 0::2] = np.sin(ang)
    out[:, 1::2] = np.cos(ang)
    return out


# ---------------------------------------------------------------------------
# attention core
# ---------------------------------------------------------------------------

def _repeat_kv(k: Array, n_rep: int) -> Array:
    """[B,S,Hkv,hd] -> [B,S,Hkv*n_rep,hd] by head-group broadcast."""
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d)) \
              .reshape(b, s, h * n_rep, d)


def attention_core(q: Array, k: Array, v: Array, *, causal: bool,
                   q_offset: Array | int = 0,
                   softmax_scale: Optional[float] = None) -> Array:
    """Scaled dot-product attention with GQA broadcast.

    q: [B,Sq,Hq,hd]  k,v: [B,Skv,Hkv,hd(v)]  -> [B,Sq,Hq,hd_v]
    ``q_offset``: absolute position of q[0] (for decode: Skv_filled).
    """
    bq, sq, hq, hd = q.shape
    hkv = k.shape[2]
    n_rep = hq // hkv
    k = _repeat_kv(k, n_rep)
    v = _repeat_kv(v, n_rep)
    scale = softmax_scale if softmax_scale is not None else 1.0 / np.sqrt(hd)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    if causal:
        qpos = jnp.arange(sq)[:, None] + q_offset
        kpos = jnp.arange(k.shape[1])[None, :]
        mask = qpos >= kpos
        logits = jnp.where(mask[None, None], logits, jnp.float32(-1e30))
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention block
# ---------------------------------------------------------------------------

def gqa_init(key, cfg: ArchConfig, dtype) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_param(ks[0], d, (cfg.n_heads, hd), dtype),
        "wk": dense_param(ks[1], d, (cfg.n_kv_heads, hd), dtype),
        "wv": dense_param(ks[2], d, (cfg.n_kv_heads, hd), dtype),
        "wo": _dense_init(ks[3], (cfg.n_heads, hd, d), cfg.n_heads * hd, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(hd, dtype)
        p["k_norm"] = rmsnorm_init(hd, dtype)
    return p


def _project_qkv(p: dict, cfg: ArchConfig, x: Array, positions) -> Tuple[Array, Array, Array]:
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    if cfg.rope_type == "standard":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    elif cfg.rope_type == "mrope":
        q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    return q, k, v


def gqa_forward(p: dict, cfg: ArchConfig, x: Array, positions,
                *, causal: bool = True) -> Array:
    """Full self-attention (train / prefill). Returns [B,S,d]."""
    q, k, v = _project_qkv(p, cfg, x, positions)
    out = attention_core(q, k, v, causal=causal)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"],
                      preferred_element_type=jnp.float32).astype(x.dtype)


def gqa_decode(p: dict, cfg: ArchConfig, x: Array, cache: dict,
               positions) -> Tuple[Array, dict]:
    """One-token decode against a KV cache.

    cache: {"k": [B,S,Hkv,hd], "v": [B,S,Hkv,hd], "index": scalar int32}
    x: [B,1,d].
    """
    q, k_new, v_new = _project_qkv(p, cfg, x, positions)
    idx = cache["index"]
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new.astype(cache["k"].dtype), idx, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new.astype(cache["v"].dtype), idx, axis=1)
    # mask out unfilled cache slots via causal mask with q_offset=idx
    out = attention_core(q, k, v, causal=True, q_offset=idx)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    return y, {"k": k, "v": v, "index": idx + 1}


def gqa_cache_init(cfg: ArchConfig, batch: int, seq: int, dtype) -> dict:
    hd = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, seq, cfg.n_kv_heads, hd), dtype=dtype),
        "v": jnp.zeros((batch, seq, cfg.n_kv_heads, hd), dtype=dtype),
        "index": jnp.zeros((), dtype=jnp.int32),
    }


# ---------------------------------------------------------------------------
# cross attention (whisper decoder)
# ---------------------------------------------------------------------------

def cross_attn_init(key, cfg: ArchConfig, dtype) -> dict:
    return gqa_init(key, cfg.replace(qk_norm=False), dtype)


def cross_attn_forward(p: dict, cfg: ArchConfig, x: Array, enc_out: Array) -> Array:
    """Decoder cross-attention over encoder output (no rope, no mask)."""
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    out = attention_core(q, k, v, causal=False)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"],
                      preferred_element_type=jnp.float32).astype(x.dtype)


# ---------------------------------------------------------------------------
# MLA attention (DeepSeek-V3)
# ---------------------------------------------------------------------------

def mla_init(key, cfg: ArchConfig, dtype) -> dict:
    m: MLAConfig = cfg.mla
    d, nq = cfg.d_model, cfg.n_heads
    qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq_a": dense_param(ks[0], d, m.q_lora_rank, dtype),
        "q_a_norm": rmsnorm_init(m.q_lora_rank, dtype),
        "wq_b": dense_param(ks[1], m.q_lora_rank, (nq, qk_hd), dtype),
        # kv down-projection -> compressed latent + decoupled rope key
        "wkv_a": dense_param(ks[2], d, m.kv_lora_rank + m.qk_rope_head_dim, dtype),
        "kv_a_norm": rmsnorm_init(m.kv_lora_rank, dtype),
        "wkv_b": dense_param(ks[3], m.kv_lora_rank,
                             (nq, m.qk_nope_head_dim + m.v_head_dim), dtype),
        "wo": _dense_init(ks[4], (nq, m.v_head_dim, d), nq * m.v_head_dim, dtype),
    }


def _mla_qkv(p: dict, cfg: ArchConfig, x: Array, positions):
    m = cfg.mla
    nq = cfg.n_heads
    q_lat = jnp.einsum("bsd,dr->bsr", x, p["wq_a"],
                       preferred_element_type=jnp.float32).astype(x.dtype)
    q_lat = rmsnorm(p["q_a_norm"], q_lat, cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", q_lat, p["wq_b"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"],
                    preferred_element_type=jnp.float32).astype(x.dtype)
    c_kv, k_rope = jnp.split(kv, [m.kv_lora_rank], axis=-1)
    c_kv = rmsnorm(p["kv_a_norm"], c_kv, cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)  # 1 head
    return q_nope, q_rope, c_kv, k_rope


def _mla_attend(p: dict, cfg: ArchConfig, q_nope, q_rope, c_kv, k_rope,
                q_offset=0) -> Array:
    """Attention in the latent space: expand c_kv to per-head k_nope/v."""
    m = cfg.mla
    nq = cfg.n_heads
    kv = jnp.einsum("bsr,rhk->bshk", c_kv, p["wkv_b"],
                    preferred_element_type=jnp.float32).astype(c_kv.dtype)
    k_nope, v = jnp.split(kv, [m.qk_nope_head_dim], axis=-1)
    k_rope_b = jnp.broadcast_to(k_rope, (*k_rope.shape[:2], nq, m.qk_rope_head_dim))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    scale = 1.0 / np.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    return attention_core(q, k, v, causal=True, q_offset=q_offset,
                          softmax_scale=scale)


def mla_forward(p: dict, cfg: ArchConfig, x: Array, positions) -> Array:
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(p, cfg, x, positions)
    out = _mla_attend(p, cfg, q_nope, q_rope, c_kv, k_rope)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"],
                      preferred_element_type=jnp.float32).astype(x.dtype)


def mla_decode(p: dict, cfg: ArchConfig, x: Array, cache: dict,
               positions) -> Tuple[Array, dict]:
    """Decode with the *compressed* MLA cache: {"c_kv":[B,S,r], "k_rope":[B,S,1,hd_r]}."""
    q_nope, q_rope, c_new, kr_new = _mla_qkv(p, cfg, x, positions)
    idx = cache["index"]
    c_kv = jax.lax.dynamic_update_slice_in_dim(
        cache["c_kv"], c_new.astype(cache["c_kv"].dtype), idx, axis=1)
    k_rope = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], kr_new.astype(cache["k_rope"].dtype), idx, axis=1)
    out = _mla_attend(p, cfg, q_nope, q_rope, c_kv, k_rope, q_offset=idx)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    return y, {"c_kv": c_kv, "k_rope": k_rope, "index": idx + 1}


def mla_cache_init(cfg: ArchConfig, batch: int, seq: int, dtype) -> dict:
    m = cfg.mla
    return {
        "c_kv": jnp.zeros((batch, seq, m.kv_lora_rank), dtype=dtype),
        "k_rope": jnp.zeros((batch, seq, 1, m.qk_rope_head_dim), dtype=dtype),
        "index": jnp.zeros((), dtype=jnp.int32),
    }


# ---------------------------------------------------------------------------
# gated MLP
# ---------------------------------------------------------------------------

def mlp_init(key, d: int, d_ff: int, dtype) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_param(ks[0], d, d_ff, dtype),
        "w_up": dense_param(ks[1], d, d_ff, dtype),
        "w_down": dense_param(ks[2], d_ff, d, dtype),
    }


def mlp_forward(p: dict, x: Array) -> Array:
    g = jnp.einsum("bsd,df->bsf", x, p["w_gate"],
                   preferred_element_type=jnp.float32)
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"],
                   preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) * u).astype(x.dtype)
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"],
                      preferred_element_type=jnp.float32).astype(x.dtype)
