"""Mamba-2 layer via SSD (state-space duality), chunked algorithm.

Reference: "Transformers are SSMs" (arXiv:2405.21060). The sequence is cut
into chunks of length L; within a chunk the output is an attention-like
masked-decay matmul (MXU-friendly), and a single ``lax.scan`` over chunks
carries the [B,H,P,N] recurrent state — O(S) work, O(1) decode state.

Shapes: x_head [B,S,H,P], dt [B,S,H], A [H] (negative), B/C broadcast from
[B,S,G,N] groups to heads. State: [B,H,P,N].
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig, SSMConfig
from repro.models import layers

Array = jax.Array


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------

def _dims(cfg: ArchConfig):
    s: SSMConfig = cfg.ssm
    d_in = s.expand * cfg.d_model
    n_heads = d_in // s.head_dim
    conv_ch = d_in + 2 * s.n_groups * s.d_state
    return s, d_in, n_heads, conv_ch


def ssm_init(key, cfg: ArchConfig, dtype) -> dict:
    s, d_in, n_heads, conv_ch = _dims(cfg)
    d = cfg.d_model
    proj_out = 2 * d_in + 2 * s.n_groups * s.d_state + n_heads
    ks = jax.random.split(key, 5)
    return {
        "in_proj": layers.dense_param(ks[0], d, proj_out, dtype),
        "conv_w": (jax.random.normal(ks[1], (s.conv_width, conv_ch), jnp.float32)
                   * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "A_log": jnp.zeros((n_heads,), jnp.float32),
        "D": jnp.ones((n_heads,), jnp.float32),
        "norm": layers.rmsnorm_init(d_in, dtype),
        "out_proj": layers.dense_param(ks[4], d_in, d, dtype),
    }


# ---------------------------------------------------------------------------
# projections + causal depthwise conv
# ---------------------------------------------------------------------------

def _split_proj(p, cfg: ArchConfig, x: Array):
    s, d_in, n_heads, _ = _dims(cfg)
    gn = s.n_groups * s.d_state
    proj = jnp.einsum("bsd,dk->bsk", x, p["in_proj"],
                      preferred_element_type=jnp.float32).astype(x.dtype)
    z, xin, b_ssm, c_ssm, dt = jnp.split(
        proj, [d_in, 2 * d_in, 2 * d_in + gn, 2 * d_in + 2 * gn], axis=-1)
    return z, xin, b_ssm, c_ssm, dt


def causal_conv(conv_w: Array, conv_b: Array, u: Array) -> Array:
    """Depthwise causal conv1d. u: [B,S,C]; conv_w: [W,C]."""
    w = conv_w.shape[0]
    pad = jnp.pad(u, ((0, 0), (w - 1, 0), (0, 0)))
    out = jnp.zeros_like(u, dtype=jnp.float32)
    for i in range(w):
        out = out + pad[:, i:i + u.shape[1], :].astype(jnp.float32) \
            * conv_w[i].astype(jnp.float32)
    return jax.nn.silu(out + conv_b.astype(jnp.float32)).astype(u.dtype)


def _groups_to_heads(t: Array, n_heads: int, n_groups: int) -> Array:
    """[B,S,G*N] -> [B,S,H,N]."""
    b, s_, gn = t.shape
    n = gn // n_groups
    t = t.reshape(b, s_, n_groups, n)
    rep = n_heads // n_groups
    return jnp.broadcast_to(t[:, :, :, None, :], (b, s_, n_groups, rep, n)) \
        .reshape(b, s_, n_heads, n)


# ---------------------------------------------------------------------------
# chunked SSD core
# ---------------------------------------------------------------------------

def ssd_chunked(x: Array, dt: Array, a: Array, b_ssm: Array, c_ssm: Array,
                d_skip: Array, chunk: int,
                initial_state: Array | None = None) -> Tuple[Array, Array]:
    """SSD over a full sequence.

    x: [B,S,H,P]; dt: [B,S,H] (post-softplus, >0); a: [H] (negative);
    b_ssm/c_ssm: [B,S,H,N]; d_skip: [H]. Returns (y [B,S,H,P], state [B,H,P,N]).
    """
    bsz, seq, nh, hp = x.shape
    nstate = b_ssm.shape[-1]
    assert seq % chunk == 0, (seq, chunk)
    nc = seq // chunk

    # per-step log decay, f32 throughout the decay path
    la = dt.astype(jnp.float32) * a.astype(jnp.float32)          # [B,S,H] (<0)
    xc = x.reshape(bsz, nc, chunk, nh, hp)
    dtc = dt.reshape(bsz, nc, chunk, nh).astype(jnp.float32)
    lac = la.reshape(bsz, nc, chunk, nh)
    bc = b_ssm.reshape(bsz, nc, chunk, nh, nstate)
    cc = c_ssm.reshape(bsz, nc, chunk, nh, nstate)

    cum = jnp.cumsum(lac, axis=2)                                # inclusive [B,C,L,H]
    total = cum[:, :, -1, :]                                     # [B,C,H]

    # ---- intra-chunk (attention-like) ----
    # M[i,j] = exp(cum_i - cum_j) for j <= i
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]         # [B,C,L,L,H]
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    m = jnp.where(mask[None, None, :, :, None], jnp.exp(diff), 0.0)
    cb = jnp.einsum("bcihn,bcjhn->bchij", cc, bc,
                    preferred_element_type=jnp.float32)
    # scores[b,c,h,i,j] = (C_i . B_j) * M[i,j] * dt_j
    m_h = jnp.moveaxis(m, -1, 2)                                 # [B,C,H,L,L]
    dt_j = dtc.transpose(0, 1, 3, 2)[:, :, :, None, :]           # [B,C,H,1,L]
    scores = cb * m_h * dt_j
    y_intra = jnp.einsum("bchij,bcjhp->bcihp", scores.astype(x.dtype), xc,
                         preferred_element_type=jnp.float32)

    # ---- per-chunk local end-state ----
    # S_local[c] = sum_j exp(total_c - cum_j) * dt_j * B_j (x) x_j
    w_end = jnp.exp(total[:, :, None, :] - cum) * dtc            # [B,C,L,H]
    s_local = jnp.einsum("bclh,bclhn,bclhp->bchpn",
                         w_end.astype(x.dtype), bc, xc,
                         preferred_element_type=jnp.float32)     # [B,C,H,P,N]

    # ---- inter-chunk scan ----
    if initial_state is None:
        init = jnp.zeros((bsz, nh, hp, nstate), jnp.float32)
    else:
        init = initial_state.astype(jnp.float32)

    def step(s_prev, inp):
        s_loc, tot = inp                                         # [B,H,P,N], [B,H]
        s_new = s_prev * jnp.exp(tot)[:, :, None, None] + s_loc
        return s_new, s_prev

    xs = (jnp.moveaxis(s_local, 1, 0), jnp.moveaxis(total, 1, 0))
    s_final, s_prevs = jax.lax.scan(step, init, xs)
    s_prevs = jnp.moveaxis(s_prevs, 0, 1)                        # [B,C,H,P,N]

    # Y_inter[i] = exp(cum_i) * C_i . S_prev
    y_inter = jnp.einsum("bclh,bclhn,bchpn->bclhp",
                         jnp.exp(cum).astype(x.dtype), cc,
                         s_prevs.astype(x.dtype),
                         preferred_element_type=jnp.float32)

    y = (y_intra + y_inter).reshape(bsz, seq, nh, hp)
    y = y + x.astype(jnp.float32) * d_skip.astype(jnp.float32)[None, None, :, None]
    return y.astype(x.dtype), s_final


def ssd_decode_step(state: Array, x: Array, dt: Array, a: Array,
                    b_ssm: Array, c_ssm: Array, d_skip: Array
                    ) -> Tuple[Array, Array]:
    """One recurrent step. state [B,H,P,N]; x [B,H,P]; dt [B,H];
    b/c [B,H,N]. Returns (y [B,H,P], new state)."""
    dt32 = dt.astype(jnp.float32)
    decay = jnp.exp(dt32 * a.astype(jnp.float32))                 # [B,H]
    inp = (dt32[:, :, None, None]
           * x.astype(jnp.float32)[:, :, :, None]
           * b_ssm.astype(jnp.float32)[:, :, None, :])
    new_state = state * decay[:, :, None, None] + inp
    y = jnp.einsum("bhpn,bhn->bhp", new_state,
                   c_ssm.astype(jnp.float32))
    y = y + x.astype(jnp.float32) * d_skip.astype(jnp.float32)[None, :, None]
    return y.astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# full layer forward / decode
# ---------------------------------------------------------------------------

def ssm_forward(p: dict, cfg: ArchConfig, x: Array) -> Array:
    """Full-sequence Mamba-2 block. x: [B,S,d] -> [B,S,d]."""
    s, d_in, n_heads, _ = _dims(cfg)
    bsz, seq, _ = x.shape
    z, xin, b_raw, c_raw, dt_raw = _split_proj(p, cfg, x)
    conv_in = jnp.concatenate([xin, b_raw, c_raw], axis=-1)
    conv_out = causal_conv(p["conv_w"], p["conv_b"], conv_in)
    xin, b_raw, c_raw = jnp.split(
        conv_out, [d_in, d_in + s.n_groups * s.d_state], axis=-1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xin.reshape(bsz, seq, n_heads, s.head_dim)
    bh = _groups_to_heads(b_raw, n_heads, s.n_groups)
    ch = _groups_to_heads(c_raw, n_heads, s.n_groups)

    y, _ = ssd_chunked(xh, dt, a, bh, ch, p["D"], s.chunk_size)
    y = y.reshape(bsz, seq, d_in)
    y = layers.rmsnorm(p["norm"], y * jax.nn.silu(z.astype(jnp.float32))
                       .astype(y.dtype), cfg.norm_eps)
    return jnp.einsum("bsk,kd->bsd", y, p["out_proj"],
                      preferred_element_type=jnp.float32).astype(x.dtype)


def ssm_cache_init(cfg: ArchConfig, batch: int, dtype) -> dict:
    s, d_in, n_heads, conv_ch = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, s.conv_width - 1, conv_ch), dtype=dtype),
        "state": jnp.zeros((batch, n_heads, s.head_dim, s.d_state), jnp.float32),
    }


def ssm_decode(p: dict, cfg: ArchConfig, x: Array, cache: dict
               ) -> Tuple[Array, dict]:
    """One-token decode. x: [B,1,d]. Cache: {"conv": [B,W-1,C], "state": [B,H,P,N]}."""
    s, d_in, n_heads, _ = _dims(cfg)
    bsz = x.shape[0]
    z, xin, b_raw, c_raw, dt_raw = _split_proj(p, cfg, x)
    conv_in = jnp.concatenate([xin, b_raw, c_raw], axis=-1)      # [B,1,C]
    window = jnp.concatenate([cache["conv"], conv_in], axis=1)   # [B,W,C]
    conv_out = (jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                           p["conv_w"].astype(jnp.float32))
                + p["conv_b"].astype(jnp.float32))
    conv_out = jax.nn.silu(conv_out).astype(x.dtype)[:, None, :]  # [B,1,C]
    xin, b_raw, c_raw = jnp.split(
        conv_out, [d_in, d_in + s.n_groups * s.d_state], axis=-1)

    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))     # [B,H]
    a = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xin[:, 0].reshape(bsz, n_heads, s.head_dim)
    bh = _groups_to_heads(b_raw, n_heads, s.n_groups)[:, 0]
    ch = _groups_to_heads(c_raw, n_heads, s.n_groups)[:, 0]

    y, new_state = ssd_decode_step(cache["state"], xh, dt, a, bh, ch, p["D"])
    y = y.reshape(bsz, 1, d_in)
    y = layers.rmsnorm(p["norm"], y * jax.nn.silu(z.astype(jnp.float32))
                       .astype(y.dtype), cfg.norm_eps)
    out = jnp.einsum("bsk,kd->bsd", y, p["out_proj"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    new_cache = {"conv": window[:, 1:], "state": new_state}
    return out, new_cache


def ssd_reference(x, dt, a, b_ssm, c_ssm, d_skip):
    """Naive O(S) sequential oracle for tests. Same signature as ssd_chunked
    minus chunking. Returns (y, final_state)."""
    bsz, seq, nh, hp = x.shape
    n = b_ssm.shape[-1]
    state = jnp.zeros((bsz, nh, hp, n), jnp.float32)
    ys = []
    for t in range(seq):
        y, state = ssd_decode_step(state, x[:, t], dt[:, t], a,
                                   b_ssm[:, t], c_ssm[:, t], d_skip)
        ys.append(y)
    return jnp.stack(ys, axis=1), state
