"""Mixture-of-Experts layer with capacity-based gather/scatter dispatch.

Design notes
------------
We deliberately avoid the dense one-hot dispatch einsum (``[T,E] x [T,d]``):
at 256 experts it multiplies HLO_FLOPs by ~E/top_k and destroys the
MODEL_FLOPS/HLO_FLOPs roofline ratio. Instead tokens are ranked within their
expert via a stable sort + segment offsets and scattered into an
``[E, capacity, d]`` buffer; expert matmuls are batched einsums over the
expert dim; results are gathered back and combined with router probabilities.
Overflowed tokens (rank >= capacity) are dropped, standard for
capacity-factor MoE. Under a sharded mesh the scatter/gather lowers to
all-to-all style collectives between the token (data) and expert shardings.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig, MoEConfig
from repro.models import layers

Array = jax.Array


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def capacity(n_tokens: int, mcfg: MoEConfig) -> int:
    c = math.ceil(n_tokens * mcfg.top_k / mcfg.n_experts * mcfg.capacity_factor)
    return max(_round_up(c, 8), 8)


def moe_init(key, cfg: ArchConfig, dtype) -> dict:
    m = cfg.moe
    d_ff = m.d_ff_expert or cfg.d_ff
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    p = {
        "router": layers.dense_param(ks[0], d, m.n_experts, jnp.float32),
        "w_gate": layers._dense_init(ks[1], (m.n_experts, d, d_ff), d, dtype),
        "w_up": layers._dense_init(ks[2], (m.n_experts, d, d_ff), d, dtype),
        "w_down": layers._dense_init(ks[3], (m.n_experts, d_ff, d), d_ff, dtype),
    }
    if m.n_shared_experts:
        p["shared"] = layers.mlp_init(ks[4], d, d_ff * m.n_shared_experts, dtype)
    return p


def route(router_w: Array, x_flat: Array, mcfg: MoEConfig) -> Tuple[Array, Array]:
    """Router: returns (probs [T,K] float32, expert ids [T,K] int32)."""
    logits = jnp.einsum("td,de->te", x_flat.astype(jnp.float32), router_w,
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, mcfg.top_k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    return top_p, top_e.astype(jnp.int32)


def dispatch_indices(top_e: Array, n_experts: int, cap: int) -> Tuple[Array, Array]:
    """Compute destination slots for each (token, k) assignment.

    Returns (dest [T*K] int32 in [0, E*cap] — E*cap is the drop slot,
             valid [T*K] bool).
    """
    flat_e = top_e.reshape(-1)                                  # [T*K]
    tk = flat_e.shape[0]
    order = jnp.argsort(flat_e, stable=True)                    # tokens by expert
    sorted_e = flat_e[order]
    counts = jnp.zeros((n_experts,), jnp.int32).at[flat_e].add(1)
    seg_start = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1]])
    rank_sorted = jnp.arange(tk, dtype=jnp.int32) - seg_start[sorted_e]
    rank = jnp.zeros((tk,), jnp.int32).at[order].set(rank_sorted)
    valid = rank < cap
    dest = jnp.where(valid, flat_e * cap + rank, n_experts * cap)
    return dest, valid


def moe_forward(p: dict, cfg: ArchConfig, x: Array) -> Array:
    """x: [B,S,d] -> [B,S,d]."""
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    cap = capacity(t, m)

    top_p, top_e = route(p["router"], xt, m)
    dest, valid = dispatch_indices(top_e, m.n_experts, cap)

    # scatter tokens into expert buffers (extra row = drop slot)
    x_rep = jnp.repeat(xt, m.top_k, axis=0)                     # [T*K, d]
    buf = jnp.zeros((m.n_experts * cap + 1, d), x.dtype).at[dest].set(x_rep)
    buf = buf[:-1].reshape(m.n_experts, cap, d)

    # optional ZeRO-style weight gather (see sharding/context.py): forces
    # GSPMD to all-gather pod-sharded expert weights instead of
    # all-reducing the dispatch-sized einsum outputs
    from repro.sharding import context as _shctx
    w_gate, w_up, w_down = p["w_gate"], p["w_up"], p["w_down"]
    shs = _shctx.get_moe_weight_shardings()
    if shs is not None:
        if shs[0] is not None:
            w_gate = jax.lax.with_sharding_constraint(w_gate, shs[0])
            w_up = jax.lax.with_sharding_constraint(w_up, shs[1])
            w_down = jax.lax.with_sharding_constraint(w_down, shs[2])
        if len(shs) > 3 and shs[3] is not None:
            buf = jax.lax.with_sharding_constraint(buf, shs[3])

    g = jnp.einsum("ecd,edf->ecf", buf, w_gate,
                   preferred_element_type=jnp.float32)
    u = jnp.einsum("ecd,edf->ecf", buf, w_up,
                   preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) * u).astype(x.dtype)
    if shs is not None and len(shs) > 4 and shs[4] is not None:
        h = jax.lax.with_sharding_constraint(h, shs[4])
    y = jnp.einsum("ecf,efd->ecd", h, w_down,
                   preferred_element_type=jnp.float32).astype(x.dtype)

    y = jnp.concatenate(
        [y.reshape(m.n_experts * cap, d), jnp.zeros((1, d), y.dtype)], axis=0)
    y_tok = y[dest]                                             # [T*K, d]
    w = (top_p.reshape(-1) * valid.astype(jnp.float32)).astype(jnp.float32)
    out = (y_tok.astype(jnp.float32) * w[:, None]).reshape(t, m.top_k, d) \
        .sum(axis=1).astype(x.dtype)

    if m.n_shared_experts:
        out = out + layers.mlp_forward(p["shared"], x).reshape(t, d)
    return out.reshape(b, s, d)


def aux_load_balance_loss(router_w: Array, x_flat: Array, mcfg: MoEConfig) -> Array:
    """Switch-style load-balancing auxiliary loss (float32 scalar)."""
    logits = jnp.einsum("td,de->te", x_flat.astype(jnp.float32), router_w)
    probs = jax.nn.softmax(logits, axis=-1)
    top1 = jnp.argmax(probs, axis=-1)
    frac_tokens = jnp.zeros((mcfg.n_experts,), jnp.float32) \
        .at[top1].add(1.0) / x_flat.shape[0]
    frac_probs = probs.mean(axis=0)
    return mcfg.n_experts * jnp.sum(frac_tokens * frac_probs)
