"""Reduced-config helpers shared by smoke tests, examples and benchmarks."""
from __future__ import annotations

import dataclasses

from repro.models.config import ArchConfig, MLAConfig, MoEConfig, SSMConfig


def reduced(cfg: ArchConfig, *, n_layers: int | None = None) -> ArchConfig:
    """Shrink a config to CPU-smoke size while keeping its *family structure*
    (hybrid pattern unit, MoE routing, MLA, qk-norm, enc-dec, frontend)."""
    kw: dict = {
        "d_model": 64,
        "d_ff": 128 if cfg.d_ff else 0,
        "vocab_size": 503,          # deliberately not a multiple of the pad
        "vocab_pad_multiple": 32,
        "head_dim": 16,
        "dtype": "float32",
    }
    if cfg.hybrid_pattern:
        unit = len(cfg.hybrid_pattern)
        kw["n_layers"] = n_layers or 2 * unit
        kw["n_heads"], kw["n_kv_heads"] = 4, 2
    elif cfg.family == "ssm":
        kw["n_layers"] = n_layers or 4
        kw["n_heads"] = kw["n_kv_heads"] = 8   # d_inner/head_dim = 128/16
    else:
        kw["n_layers"] = n_layers or 4
        kw["n_heads"], kw["n_kv_heads"] = 4, 2
    if cfg.enc_dec:
        kw["n_encoder_layers"] = 2
    if cfg.mla is not None:
        kw["mla"] = MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                              qk_nope_head_dim=16, qk_rope_head_dim=8,
                              v_head_dim=16)
        kw["head_dim"] = 0
    if cfg.moe is not None:
        m = cfg.moe
        nd = min(m.n_dense_layers, 1)
        # capacity_factor 8 => effectively no token dropping, so reduced-config
        # prefill and decode agree exactly (dropping depends on T=B*S and is
        # exercised separately in test_moe.py).
        kw["moe"] = MoEConfig(n_experts=4, top_k=min(m.top_k, 2),
                              d_ff_expert=64,
                              n_shared_experts=min(m.n_shared_experts, 1),
                              every_k_layers=m.every_k_layers,
                              n_dense_layers=nd,
                              capacity_factor=8.0)
        if cfg.hybrid_pattern:
            kw["moe"] = dataclasses.replace(kw["moe"], n_dense_layers=0)
    if cfg.ssm is not None:
        kw["ssm"] = SSMConfig(d_state=16, head_dim=16, expand=2,
                              chunk_size=8, n_groups=cfg.ssm.n_groups
                              if cfg.ssm.n_groups <= 2 else 2,
                              conv_width=4)
    if cfg.rope_type == "mrope":
        kw["mrope_sections"] = (4, 2, 2)   # head_dim/2 = 8
    return cfg.replace(**kw)
