"""Sharded AdamW, written directly over pytrees.

Moments inherit the parameter sharding (same tree paths -> same
PartitionSpecs via ShardingRules).  ``moment_dtype="bfloat16"`` halves
optimizer memory for the >=398B archs (DESIGN.md §6); the update math is
always float32.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"


def adamw_init(params: Any, cfg: AdamWConfig) -> dict:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dtype=dt)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(grads: Any, opt_state: dict, params: Any,
                 cfg: AdamWConfig, lr=None) -> Tuple[Any, dict, dict]:
    """Returns (new_params, new_opt_state, metrics).

    ``lr`` may be a traced scalar (dynamic schedules / Kishu hparam leaves);
    defaults to the static cfg.lr."""
    lr = cfg.lr if lr is None else lr
    count = opt_state["count"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) \
        if cfg.grad_clip > 0 else jnp.float32(1.0)
    dt = jnp.dtype(cfg.moment_dtype)

    def upd(g, mu, nu, p):
        g32 = g.astype(jnp.float32) * clip
        mu32 = mu.astype(jnp.float32) * cfg.b1 + g32 * (1 - cfg.b1)
        nu32 = nu.astype(jnp.float32) * cfg.b2 + jnp.square(g32) * (1 - cfg.b2)
        mu_hat = mu32 / (1 - cfg.b1 ** count.astype(jnp.float32))
        nu_hat = nu32 / (1 - cfg.b2 ** count.astype(jnp.float32))
        step = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
        p32 = p.astype(jnp.float32)
        if p.ndim >= 2:   # decay matrices only (norms/scalars exempt)
            p32 = p32 * (1 - lr * cfg.weight_decay)
        new_p = p32 - lr * step
        return new_p.astype(p.dtype), mu32.astype(dt), nu32.astype(dt)

    out = jax.tree.map(upd, grads, opt_state["mu"], opt_state["nu"], params)
    # unzip the 3-tuples
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    metrics = {"grad_norm": gnorm}
    return new_params, {"mu": new_mu, "nu": new_nu, "count": count}, metrics
