"""Int8 error-feedback gradient compression over the data axes.

Beyond-paper distributed-optimization feature (DESIGN.md §2): gradients are
quantized to int8 against a globally-agreed scale (one pmax round of a few
bytes), summed with ``psum`` in int32 (exact — no quantization noise is added
by the reduction itself), and dequantized; the per-device quantization
residual is carried in the optimizer state and added to the next step's
gradient (error feedback), so the scheme is unbiased over time.

Implemented with ``shard_map`` so the all-reduce payload really is int8 on
the wire: 4x less collective traffic than f32, 2x less than bf16 — a direct
lever on the collective roofline term.  Off by default; enabled per-config.
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def _quantize(g: jax.Array, scale: jax.Array) -> jax.Array:
    q = jnp.clip(jnp.round(g / scale), -127, 127)
    return q.astype(jnp.int8)


def compressed_psum(grads: Any, residual: Any, mesh: Mesh, axis: str
                    ) -> Tuple[Any, Any]:
    """All-reduce-mean ``grads`` (replicated-per-``axis`` pytree shards) with
    int8 payload + error feedback.

    grads/residual: pytrees of *local* gradient shards, laid out identically
    on every member of ``axis``.  Returns (mean gradients, new residual).
    """
    n = mesh.shape[axis]

    def one(g, r):
        def body(g_local, r_local):
            g_local = g_local.astype(jnp.float32) + r_local
            amax = jax.lax.pmax(jnp.max(jnp.abs(g_local)), axis)
            scale = jnp.maximum(amax, 1e-12) / 127.0
            q = _quantize(g_local, scale)
            deq = q.astype(jnp.float32) * scale
            new_r = g_local - deq                      # error feedback
            s = jax.lax.psum(q.astype(jnp.int32), axis)
            return (s.astype(jnp.float32) * scale / n), new_r

        sm = shard_map(body, mesh=mesh,
                       in_specs=(P(), P()), out_specs=(P(), P()),
                       check_rep=False)
        return sm(g, r)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    mean_g = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_r = jax.tree.unflatten(treedef, [o[1] for o in outs])
    return mean_g, new_r


def residual_init(grads_like: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)
