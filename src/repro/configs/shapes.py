"""Assigned input shapes and abstract input specs for the dry-run.

Every (arch x shape) cell resolves to a *step kind* plus a pytree of
``jax.ShapeDtypeStruct`` stand-ins (weak-type-correct, shardable, zero
allocation):

  train_4k    -> train_step   tokens/labels [256, 4096]
  prefill_32k -> prefill_step tokens [32, 32768]
  decode_32k  -> serve_step   1 new token, KV/SSM cache filled to 32768, B=128
  long_500k   -> serve_step   1 new token, cache 524288, B=1 (sub-quadratic only)

Modality frontends are stubs: audio provides encoder frame embeddings,
vlm provides patch/text embeddings + M-RoPE position ids.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models import lm


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ArchConfig, shape: str) -> Tuple[bool, str]:
    """(applicable, reason-if-not)."""
    if shape == "long_500k" and not cfg.sub_quadratic:
        return False, ("long_500k needs sub-quadratic attention; "
                       f"{cfg.name} is pure full-attention (see DESIGN.md §5)")
    return True, ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def token_batch(cfg: ArchConfig, batch: int, seq: int, *,
                labels: bool) -> Dict[str, Any]:
    """Abstract input batch for full-sequence steps."""
    d = cfg.d_model
    b: Dict[str, Any] = {}
    if cfg.frontend == "vision":
        b["embeds"] = _sds((batch, seq, d), cfg.dtype)
        b["positions_thw"] = _sds((batch, seq, 3), jnp.int32)
    else:
        b["tokens"] = _sds((batch, seq), jnp.int32)
    if cfg.enc_dec:
        b["enc_embeds"] = _sds((batch, seq, d), cfg.dtype)
    if labels:
        b["labels"] = _sds((batch, seq), jnp.int32)
    return b


def decode_batch(cfg: ArchConfig, batch: int) -> Dict[str, Any]:
    b: Dict[str, Any] = {"index": jax.ShapeDtypeStruct((), jnp.int32)}
    if cfg.frontend == "vision":
        b["embeds"] = _sds((batch, 1, cfg.d_model), cfg.dtype)
    else:
        b["tokens"] = _sds((batch, 1), jnp.int32)
    return b


def abstract_caches(cfg: ArchConfig, batch: int, seq: int):
    """ShapeDtypeStruct pytree of decode caches (no allocation)."""
    return jax.eval_shape(
        lambda: lm.init_caches(cfg, batch, seq,
                               enc_seq=min(seq, 4096) if cfg.enc_dec else 0))


def input_specs(cfg: ArchConfig, shape: str) -> Dict[str, Any]:
    """Returns {"kind", "batch", and for decode "caches"} — all abstract."""
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        raise ValueError(f"{cfg.name} x {shape}: {why}")
    s = SHAPES[shape]
    if s.kind == "train":
        return {"kind": "train",
                "batch": token_batch(cfg, s.global_batch, s.seq_len,
                                     labels=True)}
    if s.kind == "prefill":
        return {"kind": "prefill",
                "batch": token_batch(cfg, s.global_batch, s.seq_len,
                                     labels=False)}
    return {"kind": "decode",
            "batch": decode_batch(cfg, s.global_batch),
            "caches": abstract_caches(cfg, s.global_batch, s.seq_len)}


def cells(arch_ids: Optional[List[str]] = None) -> List[Tuple[str, str, bool, str]]:
    """All (arch, shape, applicable, reason) cells — 40 total."""
    from repro.models.config import get_config
    from repro import configs as cfgs
    out = []
    for a in (arch_ids or cfgs.ARCH_IDS):
        cfg = get_config(a)
        for sh in SHAPES:
            ok, why = shape_applicable(cfg, sh)
            out.append((a, sh, ok, why))
    return out
