"""qwen3-1.7b — dense decoder LM with qk-norm.

[hf:Qwen/Qwen3-8B family; hf] 28L d_model=2048 16H (GQA kv=8) d_ff=6144
vocab=151936. head_dim=128, per-head RMSNorm on q and k, tied embeddings.
"""
from repro.models.config import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen3-1.7b",
    family="dense",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=6144,
    vocab_size=151936,
    qk_norm=True,
    tie_embeddings=True,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen3-1.7B",
))
