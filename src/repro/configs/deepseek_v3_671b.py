"""deepseek-v3-671b — MLA + 256-expert top-8 MoE + MTP.

[arXiv:2412.19437; hf] 61L d_model=7168 128H d_ff=2048 (routed expert
hidden), vocab=129280, MoE 1 shared + 256 routed top-8, first 3 layers
dense (d_ff 18432), MLA (q_lora 1536, kv_lora 512, nope 128, rope 64,
v 128), multi-token-prediction head. Decode caches the *compressed*
latent (c_kv 512 + k_rope 64 per token per layer).
"""
from repro.models.config import ArchConfig, MLAConfig, MoEConfig, register

CONFIG = register(ArchConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=18432,            # dense-prefix layers
    vocab_size=129280,
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=256, top_k=8, d_ff_expert=2048,
                  n_shared_experts=1, n_dense_layers=3,
                  capacity_factor=1.25),
    mtp=True,
    source="arXiv:2412.19437",
))
