"""XLA tuning flags, applied the safe way.

Replaces the ad-hoc ``os.environ["XLA_FLAGS"] = ...`` writes scattered
through launch/benchmark scripts with two invariants:

  - **merge, never clobber** — flags the user already set in ``XLA_FLAGS``
    win; we only append flags whose name isn't present yet
    (:func:`merge_xla_flags`), and
  - **opt-in, no-op on CPU** — :func:`apply_xla_tuning` does nothing unless
    ``KISHU_XLA_TUNING=1`` *and* the target platform is an accelerator.
    The latency-hiding/async-stream flags below only exist on the GPU
    backend; exporting them on CPU makes XLA warn-or-die at init.

Must run **before** jax initializes its backends (XLA reads the env var at
backend init, once).  This module therefore imports nothing from jax; the
platform is resolved from the standard ``JAX_PLATFORMS``/
``JAX_PLATFORM_NAME`` env hints or an explicit argument.
"""
from __future__ import annotations

import os
from typing import Optional, Sequence

# Latency-hiding / async-stream flags (the bayespec recipe; see
# https://jax.readthedocs.io/en/latest/gpu_performance_tips.html).  The
# scheduler + async-collective pair is what lets the checkpoint pipeline's
# device→host DMA overlap compute and backend puts.
GPU_TUNING_FLAGS = (
    "--xla_gpu_enable_triton_softmax_fusion=true",
    "--xla_gpu_triton_gemm_any=True",
    "--xla_gpu_enable_async_collectives=true",
    "--xla_gpu_enable_latency_hiding_scheduler=true",
    "--xla_gpu_enable_highest_priority_async_stream=true",
)


def _flag_name(flag: str) -> str:
    return flag.split("=", 1)[0]


def merge_xla_flags(flags: Sequence[str], env=None) -> str:
    """Append ``flags`` to ``XLA_FLAGS`` without overriding any flag the
    user (or an earlier caller) already set.  Returns the resulting value."""
    env = os.environ if env is None else env
    current = env.get("XLA_FLAGS", "").split()
    have = {_flag_name(f) for f in current}
    added = [f for f in flags if _flag_name(f) not in have]
    merged = " ".join(current + added)
    if merged:
        env["XLA_FLAGS"] = merged
    return merged


def resolve_platform(platform: Optional[str] = None, env=None) -> str:
    """Best-effort platform without touching jax (which would lock the
    backend before the flags land): explicit argument, then the standard
    jax env hints, else "cpu" (the conservative no-op default)."""
    env = os.environ if env is None else env
    if platform:
        return platform.lower()
    for var in ("JAX_PLATFORMS", "JAX_PLATFORM_NAME"):
        val = env.get(var, "").strip().lower()
        if val:
            return val.split(",")[0]
    return "cpu"


def apply_xla_tuning(platform: Optional[str] = None, env=None) -> str:
    """Opt-in XLA tuning: merge the accelerator flag block into
    ``XLA_FLAGS`` when ``KISHU_XLA_TUNING=1`` and the platform is a GPU.

    No-op (returns "") on CPU/TPU or without the opt-in, so importing a
    benchmark never changes a user's XLA configuration behind their back.
    Call before anything initializes jax.
    """
    env = os.environ if env is None else env
    if env.get("KISHU_XLA_TUNING", "").strip() != "1":
        return ""
    if resolve_platform(platform, env) != "gpu":
        return ""
    return merge_xla_flags(GPU_TUNING_FLAGS, env)


def force_host_device_count(n: int, env=None) -> str:
    """Merge ``--xla_force_host_platform_device_count=n`` (dry-run drivers
    simulating multi-pod meshes on one host).  A user-provided count in
    ``XLA_FLAGS`` wins; call before jax initializes."""
    return merge_xla_flags(
        [f"--xla_force_host_platform_device_count={n}"], env)
