"""mamba2-780m — SSD (state-space duality), attention-free.

[arXiv:2405.21060; unverified] 48L d_model=1536 d_ff=0 vocab=50280,
ssm_state=128. Mamba-2 defaults: expand=2 (d_inner=3072), head_dim=64
(48 SSD heads), 1 group, conv width 4, tied embeddings (GPT-NeoX tokenizer).
"""
from repro.models.config import ArchConfig, SSMConfig, register

CONFIG = register(ArchConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=48,            # SSD heads (d_inner / head_dim)
    n_kv_heads=48,
    d_ff=0,
    vocab_size=50280,
    rope_type="none",
    tie_embeddings=True,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, chunk_size=256,
                  n_groups=1, conv_width=4),
    source="arXiv:2405.21060",
))
