"""phi3.5-moe-42b-a6.6b — 16-expert top-2 MoE.

[hf:microsoft/Phi-3.5-MoE-instruct; hf] 32L d_model=4096 32H (GQA kv=8)
d_ff=6400 (per expert), MoE 16e top-2, vocab=32064. head_dim=128.
"""
from repro.models.config import ArchConfig, MoEConfig, register

CONFIG = register(ArchConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=6400,
    vocab_size=32064,
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=6400),
    source="hf:microsoft/Phi-3.5-MoE-instruct",
))
