"""qwen2-vl-72b — VLM backbone with M-RoPE.

[arXiv:2409.12191; hf] 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064. Vision frontend is a STUB: input_specs() provides
precomputed patch/text embeddings [B, S, d_model] plus positions_thw
[B, S, 3] (temporal/height/width M-RoPE ids). head_dim=128;
mrope_sections (16,24,24) over head_dim/2=64.
"""
from repro.models.config import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152064,
    rope_type="mrope",
    mrope_sections=(16, 24, 24),
    rope_theta=1_000_000.0,
    frontend="vision",
    source="arXiv:2409.12191",
))
