"""Assigned architecture configs. Importing this package registers all archs."""
from repro.configs import (  # noqa: F401
    mamba2_780m,
    stablelm_12b,
    smollm_360m,
    mistral_nemo_12b,
    qwen3_1p7b,
    jamba_1p5_large_398b,
    whisper_large_v3,
    phi35_moe_42b,
    deepseek_v3_671b,
    qwen2_vl_72b,
)
from repro.configs.shapes import SHAPES, input_specs, cells  # noqa: F401

ARCH_IDS = [
    "mamba2-780m", "stablelm-12b", "smollm-360m", "mistral-nemo-12b",
    "qwen3-1.7b", "jamba-1.5-large-398b", "whisper-large-v3",
    "phi3.5-moe-42b-a6.6b", "deepseek-v3-671b", "qwen2-vl-72b",
]
