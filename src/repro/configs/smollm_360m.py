"""smollm-360m — llama-arch small dense LM.

[hf:HuggingFaceTB/SmolLM-135M; hf] 32L d_model=960 15H (GQA kv=5)
d_ff=2560 vocab=49152. head_dim = 64. Tied embeddings.
15 heads is not divisible by the 16-way model axis — exercises the
sequence-parallel sharding fallback.
"""
from repro.models.config import ArchConfig, register

CONFIG = register(ArchConfig(
    name="smollm-360m",
    family="dense",
    n_layers=32,
    d_model=960,
    n_heads=15,
    n_kv_heads=5,
    d_ff=2560,
    vocab_size=49152,
    tie_embeddings=True,
    rope_theta=10_000.0,
    source="hf:HuggingFaceTB/SmolLM-360M",
))
