"""jamba-1.5-large-398b — hybrid Mamba+attention MoE.

[arXiv:2403.19887; hf] 72L d_model=8192 64H (GQA kv=8) d_ff=24576
vocab=65536, MoE 16e top-2, attn:mamba 1:7 interleave.

Repeating unit of 8 layers: [attn, ssm x7]; MoE FFN on every 2nd layer
(others dense). Mamba layers use our Mamba-2 SSD formulation (see
DESIGN.md §8 — Jamba ships Mamba-1; same state-space family). Chunk size
128 keeps the intra-chunk SSD working set VMEM-friendly at d_inner=16384.
"""
from repro.models.config import ArchConfig, MoEConfig, SSMConfig, register

CONFIG = register(ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    hybrid_pattern=("attn",) + ("ssm",) * 7,
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=24576, every_k_layers=2),
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, chunk_size=128,
                  n_groups=8, conv_width=4),
    source="arXiv:2403.19887",
))
