"""whisper-large-v3 — encoder-decoder audio backbone.

[arXiv:2212.04356; unverified] 32L d_model=1280 20H (kv=20) d_ff=5120
vocab=51866. Conv frontend is a STUB: input_specs() provides precomputed
frame embeddings [B, S, d_model] for the encoder. Sinusoidal positions
(rope_type="none"); decoder has cross-attention over encoder output.
20 heads not divisible by 16 — exercises the seq-parallel fallback.
"""
from repro.models.config import ArchConfig, register

CONFIG = register(ArchConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,
    n_encoder_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    rope_type="none",
    enc_dec=True,
    frontend="audio",
    source="arXiv:2212.04356",
))
