"""End-to-end training driver with Kishu time-traveling attached.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --reduced \
        --steps 200 --phase-steps 20 --store dir:///tmp/kishu_run

Full-size archs are launched the same way on a real TPU mesh (the dry-run
proves the shardings compile); on this CPU container use ``--reduced`` for a
runnable model.  The driver demonstrates the production loop: phases as
commands, incremental checkpoints every phase, automatic rollback if a phase
diverges (loss spike), and resume-from-store on restart.
"""
from __future__ import annotations

import argparse
import math
import os
import time

import jax

from repro.core.chunkstore import open_store
from repro.models.config import get_config
from repro.models.testing import reduced as reduce_cfg
from repro.optim.adamw import AdamWConfig
from repro.train.loop import ManagedTrainingSession, resume


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized config of the same family")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--phase-steps", type=int, default=10)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--store", default="memory://")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--spike-rollback", type=float, default=3.0,
                    help="rollback a phase if loss spikes by this factor")
    ap.add_argument("--async-write", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    opt_cfg = AdamWConfig(lr=args.lr)
    store = open_store(args.store)

    if args.resume:
        sess = resume(cfg, opt_cfg, store, global_batch=args.global_batch,
                      seq_len=args.seq_len, async_write=args.async_write)
        print(f"resumed at {sess.kishu.head}")
    else:
        sess = ManagedTrainingSession(
            cfg, opt_cfg, store, global_batch=args.global_batch,
            seq_len=args.seq_len, async_write=args.async_write)
        sess.attach(seed=0)

    n_phases = math.ceil(args.steps / args.phase_steps)
    prev_loss = float("inf")
    good_commit = sess.kishu.head
    for phase in range(n_phases):
        t0 = time.monotonic()
        cid = sess.train(args.phase_steps)
        loss = sess.ns.get("metrics/last_loss", float("nan"))
        rs = sess.kishu.last_run
        print(f"phase {phase:3d} [{cid}] loss={loss:.4f} "
              f"({args.phase_steps} steps, {time.monotonic()-t0:.1f}s; "
              f"ckpt {rs.write.bytes_written/1e6:.2f}MB in {rs.write_s*1e3:.0f}ms, "
              f"detect {rs.detect_s*1e3:.0f}ms)", flush=True)
        if loss > prev_loss * args.spike_rollback:
            print(f"  loss spike ({loss:.3f} > {args.spike_rollback}x"
                  f" {prev_loss:.3f}) -> rollback to {good_commit}")
            st = sess.checkout(good_commit)
            print(f"  rolled back in {st.wall_s*1e3:.0f}ms "
                  f"(loaded {st.covs_loaded} covs, kept {st.covs_identical})")
            sess.set_lr(sess.ns["hparams/lr"] * 0.5)
        else:
            prev_loss = min(prev_loss, loss)
            good_commit = cid
    sess.evaluate(batches=2)
    print(f"final eval loss: {sess.eval_loss():.4f}")
    print("storage:", sess.kishu.storage_stats())
    sess.close()


if __name__ == "__main__":
    main()
