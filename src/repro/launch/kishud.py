"""kishud — a multi-tenant checkpoint daemon over one shared fabric
(DESIGN.md §14; ROADMAP open item 1).

One long-running process multiplexes N notebook sessions over a single
content-addressed store:

  * each tenant gets its own ``tenant/<id>/`` metadata namespace (graph,
    branches, txn journal) and its own writer lease, while chunks are
    shared and deduped store-wide;
  * one :class:`~repro.core.chunkstore.ChunkCache` is shared across every
    session — a tenant checking out data another tenant just wrote is
    served from memory;
  * every storage operation passes through an **admission queue** with two
    classes: *interactive* work (cell commits, checkouts — a human is
    waiting) always runs before *background* work (gc, scrub, rebalance),
    so fleet maintenance can never queue ahead of a notebook user.

Run it embedded::

    d = Kishud("dir:///ckpt", workers=4)
    alice = d.session("alice")
    alice.register("train", train)
    alice.run("train", steps=10)

or as a daemon with a unix-socket control plane::

    python -m repro.launch.kishud --store dir:///ckpt --socket /tmp/kishud.sock
    python -m repro.launch.kishu_cli --store ... kishud status --socket ...

The control protocol is JSON-lines over a unix socket: one request object
per line (``{"cmd": "ping" | "status" | "tenants" | "metrics" |
"stop"}``), one
response object per line.
"""
from __future__ import annotations

import argparse
import heapq
import json
import os
import socket
import threading
import time
from typing import Any, Callable, Dict, List, Optional

from repro.core import fabric
from repro.core.chunkstore import (ChunkCache, ChunkStore, namespace_views,
                                   open_store)
from repro.core.lease import lease_status
from repro.core.session import KishuSession

INTERACTIVE = 0          # a human is waiting: cell run, checkout
BACKGROUND = 1           # fleet hygiene: gc, scrub, rebalance


class _Job:
    __slots__ = ("fn", "priority", "enq_mono", "done", "result", "error")

    def __init__(self, fn: Callable[[], Any], priority: int):
        self.fn = fn
        self.priority = priority
        self.enq_mono = time.monotonic()
        self.done = threading.Event()
        self.result: Any = None
        self.error: Optional[BaseException] = None


class AdmissionQueue:
    """Two-class priority admission: a pool of workers drains a heap
    ordered by ``(priority, arrival)``, so *every* queued interactive job
    is admitted before *any* queued background job, and jobs within a
    class run in arrival order.  A long-running background job already on
    a worker is never preempted — admission control, not scheduling — but
    with ``workers > 1`` an interactive job still finds a free worker
    unless every one is busy."""

    def __init__(self, workers: int = 2):
        self._heap: List[tuple] = []     # (priority, seqno, job)
        self._seq = 0
        self._cv = threading.Condition()
        self._closing = False
        self.served = [0, 0]             # per class
        self.wait_s = [0.0, 0.0]         # queue time per class
        self._workers = [threading.Thread(target=self._drain, daemon=True)
                         for _ in range(max(1, workers))]
        for w in self._workers:
            w.start()

    def _drain(self) -> None:
        while True:
            with self._cv:
                self._cv.wait_for(lambda: self._heap or self._closing)
                if not self._heap:
                    return               # closing, drained
                _, _, job = heapq.heappop(self._heap)
                self.wait_s[job.priority] += time.monotonic() - job.enq_mono
                self.served[job.priority] += 1
            try:
                job.result = job.fn()
            except BaseException as e:  # noqa: BLE001 — delivered to caller
                job.error = e
            finally:
                job.done.set()

    def submit(self, fn: Callable[[], Any],
               priority: int = INTERACTIVE) -> _Job:
        job = _Job(fn, priority)
        with self._cv:
            if self._closing:
                raise RuntimeError("admission queue closed")
            heapq.heappush(self._heap, (priority, self._seq, job))
            self._seq += 1
            self._cv.notify()
        return job

    def run(self, fn: Callable[[], Any],
            priority: int = INTERACTIVE) -> Any:
        """Submit and wait; re-raises the job's exception in the caller."""
        job = self.submit(fn, priority)
        job.done.wait()
        if job.error is not None:
            raise job.error
        return job.result

    def stats(self) -> dict:
        with self._cv:
            depth = [0, 0]
            for prio, _, _ in self._heap:
                depth[prio] += 1
        return {"queued_interactive": depth[INTERACTIVE],
                "queued_background": depth[BACKGROUND],
                "served_interactive": self.served[INTERACTIVE],
                "served_background": self.served[BACKGROUND],
                "wait_s_interactive": round(self.wait_s[INTERACTIVE], 6),
                "wait_s_background": round(self.wait_s[BACKGROUND], 6)}

    def close(self) -> None:
        with self._cv:
            self._closing = True
            self._cv.notify_all()
        for w in self._workers:
            w.join(timeout=5)


class TenantSession:
    """A tenant's handle on the daemon: the same surface as ``KishuSession``
    (register / init_state / run / checkout / gc / ...), with every storage
    operation admitted through the daemon's queue — run and checkout as
    *interactive*, gc as *background* — and serialized per tenant (one
    session object is not thread-safe; two tenants still run in parallel
    on different workers)."""

    def __init__(self, daemon: "Kishud", session: KishuSession):
        self._daemon = daemon
        self.session = session
        self._lock = threading.Lock()

    def _admit(self, priority: int, fn: Callable[[], Any]) -> Any:
        def locked():
            with self._lock:
                return fn()
        return self._daemon.queue.run(locked, priority)

    # ---- interactive: a human is waiting ----
    def run(self, command: str, _message: str = "", **args) -> str:
        return self._admit(INTERACTIVE,
                           lambda: self.session.run(command, _message,
                                                    **args))

    def checkout(self, commit_id: str):
        return self._admit(INTERACTIVE,
                           lambda: self.session.checkout(commit_id))

    def init_state(self, tree, message: str = "attach") -> str:
        return self._admit(INTERACTIVE,
                           lambda: self.session.init_state(tree, message))

    # ---- background: fleet hygiene ----
    def gc(self) -> dict:
        return self._admit(BACKGROUND, self.session.gc)

    def delete_branch(self, tip: str):
        return self._admit(BACKGROUND,
                           lambda: self.session.delete_branch(tip))

    # ---- local (no storage round-trips worth queueing) ----
    def register(self, name: str, fn: Callable) -> None:
        self.session.register(name, fn)

    def log(self, limit: int = 0):
        return self.session.log(limit)

    def storage_stats(self) -> dict:
        return self.session.storage_stats()

    @property
    def ns(self):
        return self.session.ns

    @property
    def head(self) -> str:
        return self.session.head

    @property
    def tenant(self) -> Optional[str]:
        return self.session.tenant

    def close(self) -> None:
        self._daemon._forget(self)
        with self._lock:
            self.session.close()


class Kishud:
    """The daemon: one shared store + cache + admission queue, N tenant
    sessions.  Sessions opened through :meth:`session` hold their
    namespace's writer lease (default ttl 10 s) — a kishud crash leaves
    leases to expire, so a restarted daemon (or a direct session) can take
    over after observing a quiet TTL."""

    def __init__(self, store, *, workers: int = 4,
                 cache_bytes: Optional[int] = None,
                 lease_ttl_s: Optional[float] = 10.0,
                 **session_kw):
        self.store: ChunkStore = (open_store(store) if isinstance(store, str)
                                  else store)
        self.cache = ChunkCache(cache_bytes)
        self.queue = AdmissionQueue(workers)
        self.lease_ttl_s = lease_ttl_s
        self.session_kw = session_kw
        self.started_mono = time.monotonic()
        self._sessions: Dict[int, TenantSession] = {}
        self._lock = threading.Lock()

    # ------------------------------------------------------------------
    # sessions
    # ------------------------------------------------------------------
    def session(self, tenant: str, *, lease_wait_s: float = 0.0,
                **kw) -> TenantSession:
        """Open (and lease) a tenant session multiplexed over the shared
        store.  ``lease_wait_s`` bounds how long to wait for a previous
        holder's lease to be observed expired (pass ≥ the TTL to take over
        from a crashed predecessor)."""
        merged = {**self.session_kw, **kw}
        sess = KishuSession(self.store, tenant=tenant,
                            lease_ttl_s=self.lease_ttl_s,
                            lease_wait_s=lease_wait_s,
                            chunk_cache=self.cache, **merged)
        ts = TenantSession(self, sess)
        with self._lock:
            self._sessions[id(ts)] = ts
        return ts

    def _forget(self, ts: TenantSession) -> None:
        with self._lock:
            self._sessions.pop(id(ts), None)

    # ------------------------------------------------------------------
    # fleet hygiene (background class)
    # ------------------------------------------------------------------
    def scrub(self, *, repair: bool = False) -> Any:
        return self.queue.run(
            lambda: fabric.scrub(self.store, repair=repair), BACKGROUND)

    def rebalance(self) -> dict:
        return self.queue.run(
            lambda: fabric.rebalance(self.store), BACKGROUND)

    # ------------------------------------------------------------------
    # introspection
    # ------------------------------------------------------------------
    def status(self) -> dict:
        with self._lock:
            live = list(self._sessions.values())
        return {"pid": os.getpid(),
                "uptime_s": round(time.monotonic() - self.started_mono, 3),
                "n_sessions": len(live),
                "tenants": sorted({ts.tenant for ts in live
                                   if ts.tenant is not None}),
                "cache_bytes": self.cache.bytes_used,
                "cache_hits": self.cache.hits,
                "cache_misses": self.cache.misses,
                "queue": self.queue.stats(),
                "store_chunks": self.store.n_chunks(),
                "store_bytes": self.store.chunk_bytes_total()}

    def metrics_text(self) -> str:
        """One Prometheus exposition covering the daemon (uptime, shared
        cache, admission queue, store totals) and every live tenant
        session's registry (store-op histograms, pipeline counters) —
        sessions carry a ``tenant`` const-label, so one scrape
        disambiguates the whole fleet."""
        from repro.obs import MetricsRegistry, render

        reg = MetricsRegistry()
        st = self.status()
        reg.gauge("kishud_uptime_seconds").set(st["uptime_s"])
        reg.gauge("kishud_sessions").set(st["n_sessions"])
        reg.gauge("kishud_cache_bytes").set(st["cache_bytes"])
        reg.gauge("kishud_cache_hits_total").set(st["cache_hits"])
        reg.gauge("kishud_cache_misses_total").set(st["cache_misses"])
        reg.gauge("kishud_store_chunks").set(st["store_chunks"])
        reg.gauge("kishud_store_bytes").set(st["store_bytes"])
        for k, v in st["queue"].items():
            reg.gauge(f"kishud_queue_{k}").set(float(v))
        with self._lock:
            live = list(self._sessions.values())
        return render([reg] + [ts.session.obs.registry for ts in live])

    def tenants(self) -> List[dict]:
        """Per-tenant usage as seen by the live sessions, plus every lease
        visible on the store (sessions opened elsewhere included)."""
        with self._lock:
            live = list(self._sessions.values())
        out = []
        for ts in live:
            st = ts.storage_stats()
            out.append({"tenant": st["tenant"], "head": ts.head,
                        "n_commits": st["n_commits"],
                        "ref_bytes": st["tenant_ref_bytes"],
                        "quota_bytes": st["quota_bytes"],
                        "lease_owner": st.get("lease_owner")})
        return out

    def close(self) -> None:
        with self._lock:
            live = list(self._sessions.values())
            self._sessions.clear()
        for ts in live:
            with ts._lock:
                ts.session.close()
        self.queue.close()


# ---------------------------------------------------------------------------
# unix-socket control plane
# ---------------------------------------------------------------------------

class KishudServer:
    """JSON-lines control server for a :class:`Kishud` on a unix socket.
    One request per line; ``stop`` answers then shuts the daemon down."""

    def __init__(self, daemon: Kishud, socket_path: str):
        self.daemon = daemon
        self.socket_path = socket_path
        self.stopped = threading.Event()
        try:
            os.unlink(socket_path)
        except FileNotFoundError:
            pass
        self._sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        self._sock.bind(socket_path)
        self._sock.listen(8)
        self._thread = threading.Thread(target=self._serve, daemon=True)
        self._thread.start()

    def _handle(self, req: dict) -> dict:
        cmd = req.get("cmd")
        if cmd == "ping":
            return {"ok": True, "pong": True, "pid": os.getpid()}
        if cmd == "status":
            return {"ok": True, **self.daemon.status()}
        if cmd == "tenants":
            leases = [dict(doc, tenant=tid)
                      for tid, view in namespace_views(self.daemon.store)
                      for doc in lease_status(view)]
            return {"ok": True, "tenants": self.daemon.tenants(),
                    "leases": leases}
        if cmd == "metrics":
            return {"ok": True, "metrics": self.daemon.metrics_text()}
        if cmd == "stop":
            self.stopped.set()
            return {"ok": True, "stopping": True}
        return {"ok": False, "error": f"unknown cmd {cmd!r}"}

    def _serve(self) -> None:
        while not self.stopped.is_set():
            try:
                conn, _ = self._sock.accept()
            except OSError:
                return                   # socket closed by close()
            with conn:
                buf = b""
                while not buf.endswith(b"\n"):
                    part = conn.recv(4096)
                    if not part:
                        break
                    buf += part
                if not buf.strip():
                    continue
                try:
                    resp = self._handle(json.loads(buf))
                except Exception as e:  # noqa: BLE001 — malformed request
                    resp = {"ok": False, "error": str(e)}
                try:
                    conn.sendall(json.dumps(resp).encode() + b"\n")
                except OSError:
                    pass

    def wait(self, timeout: Optional[float] = None) -> bool:
        return self.stopped.wait(timeout)

    def close(self) -> None:
        self.stopped.set()
        try:
            self._sock.close()
        finally:
            try:
                os.unlink(self.socket_path)
            except FileNotFoundError:
                pass
        self._thread.join(timeout=5)


def control(socket_path: str, cmd: str, *,
            timeout: float = 5.0) -> dict:
    """Send one control command to a running kishud; returns its response.
    Raises ``ConnectionError``/``FileNotFoundError`` if no daemon answers."""
    with socket.socket(socket.AF_UNIX, socket.SOCK_STREAM) as s:
        s.settimeout(timeout)
        s.connect(socket_path)
        s.sendall(json.dumps({"cmd": cmd}).encode() + b"\n")
        buf = b""
        while not buf.endswith(b"\n"):
            part = s.recv(4096)
            if not part:
                break
            buf += part
    return json.loads(buf) if buf.strip() else {"ok": False,
                                                "error": "empty response"}


def main(argv: Optional[list] = None) -> int:
    ap = argparse.ArgumentParser(prog="kishud")
    ap.add_argument("--store", required=True,
                    help="shared store URI (any open_store form)")
    ap.add_argument("--socket", required=True,
                    help="unix socket path for the control plane")
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--cache-bytes", type=int, default=None)
    ap.add_argument("--lease-ttl", type=float, default=10.0)
    args = ap.parse_args(argv)

    daemon = Kishud(args.store, workers=args.workers,
                    cache_bytes=args.cache_bytes,
                    lease_ttl_s=args.lease_ttl)
    server = KishudServer(daemon, args.socket)
    print(f"kishud: serving {args.store} on {args.socket} "
          f"(pid {os.getpid()})", flush=True)
    try:
        server.wait()
    except KeyboardInterrupt:
        pass
    finally:
        server.close()
        daemon.close()
    print("kishud: stopped", flush=True)
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
