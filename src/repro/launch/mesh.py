"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run driver sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and only then calls ``make_production_mesh``.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; multi_pod adds a leading 2-pod axis (512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model: int = 1, data: int = 0):
    """Mesh over whatever devices exist (tests / CPU examples)."""
    n = len(jax.devices())
    data = data or (n // model)
    return jax.make_mesh((data, model), ("data", "model"))


# Hardware constants for the roofline model (TPU v5e per chip).
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW_PER_LINK = 50e9          # bytes/s/link
