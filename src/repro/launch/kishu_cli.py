"""kishu CLI — inspect and maintain a checkpoint store from the shell.

    python -m repro.launch.kishu_cli --store dir:///ckpt log
    python -m repro.launch.kishu_cli --store ... show c00042
    python -m repro.launch.kishu_cli --store ... diff c00012 c00042
    python -m repro.launch.kishu_cli --store ... plan c00042 [--from c00012]
    python -m repro.launch.kishu_cli --store ... stats
    python -m repro.launch.kishu_cli --store ... verify [--commit cXXXXX]
    python -m repro.launch.kishu_cli --store ... gc
    python -m repro.launch.kishu_cli --store ... fsck
    python -m repro.launch.kishu_cli --store ... recover
    python -m repro.launch.kishu_cli --store ... lease [--release NAME]
    python -m repro.launch.kishu_cli --store ... tenants
    python -m repro.launch.kishu_cli --store ... kishud start|stop|status \
        --socket /tmp/kishud.sock [--detach]
    python -m repro.launch.kishu_cli --store fabric://... topology
    python -m repro.launch.kishu_cli --store fabric://... scrub [--repair]
    python -m repro.launch.kishu_cli --store fabric://... rebalance

Every subcommand shares ``open_store``, so any store URI works anywhere —
including ``?codec=`` suffixes and ``fabric://`` compositions.

``verify`` checks that every chunk referenced by a state's manifests is
present (``--deep``: fetched in bulk through the parallel engine and
content-address-checked) — the operator's answer to "can I still restore
this run?" after storage incidents (missing chunks are reported per
co-variable; they will restore via fallback recomputation as long as the
command registry is available).  The fleet verbs ``topology`` / ``scrub`` /
``rebalance`` operate on the storage fabric itself: print the composition
tree, find-and-heal replica-missing / misplaced / corrupt chunks, and move
chunks to their ring homes after a topology edit.

``fsck`` / ``recover`` are the transaction-engine verbs (DESIGN.md §13):
``fsck`` audits the *raw, un-recovered* store — unsealed commit journals,
torn HEAD, missing parents/chunks, dangling chunks — and ``recover``
replays or rolls back unsealed transactions exactly as a session open
does implicitly.  The other subcommands never touch the journal: a CLI
process doesn't own the store the way a session does, and recovering
under a live session would roll back its in-flight transaction.
"""
from __future__ import annotations

import argparse
import sys
from typing import Optional

from repro.core import fabric, parallel, txn
from repro.core.chunkstore import (NamespacedStore, chunk_key, open_store,
                                   tenant_ids)
from repro.core.graph import REFS_DOC, CheckpointGraph, parse_key
from repro.core.lease import LEASE_PREFIX, lease_status


def cmd_log(graph: CheckpointGraph, args) -> int:
    for e in graph.log(limit=args.limit):
        mark = "*" if e["head"] else " "
        exec_s = f"{e['exec_s']:7.3f}s" if e.get("exec_s") is not None \
            else "      -"
        print(f"{mark} {e['commit']}  <- {e['parent'] or '-':8s} "
              f"{e['command'] or '':14s} upd={e['updated']:3d} "
              f"del={e['deleted']:2d} exec={exec_s}  {e['message']}")
    return 0


def cmd_plan(store, graph: CheckpointGraph, args) -> int:
    """``kishu plan <commit>``: price a checkout (fetch vs replay per
    co-variable) without executing it.  The CLI has no live namespace, so
    chunk-patch candidates don't apply, and no command registry, so
    replayability relies on the per-commit ``replay_safe`` flag."""
    from repro.core.checkout import StateLoader
    from repro.core.planner import CheckoutPlanner, format_plan
    if args.commit not in graph.nodes:
        print(f"no such commit: {args.commit}", file=sys.stderr)
        return 1
    cur = args.from_ or graph.head
    if cur not in graph.nodes:
        print(f"no such commit: {cur}", file=sys.stderr)
        return 1
    loader = StateLoader(graph, store)
    planner = CheckoutPlanner(graph, loader, mode=args.mode)
    priced = planner.price_checkout(cur, args.commit)
    for line in format_plan(priced):
        print(line)
    return 0


def cmd_show(graph: CheckpointGraph, args) -> int:
    node = graph.nodes.get(args.commit)
    if node is None:
        print(f"no such commit: {args.commit}", file=sys.stderr)
        return 1
    print(f"commit  {node.commit_id} (parent {node.parent}, "
          f"depth {node.depth})")
    print(f"command {node.command}")
    print(f"message {node.message!r}")
    print(f"state   {len(node.state_index)} co-variables")
    moved = node.stats.get("bytes_serialized")
    logical = node.stats.get("bytes_logical")
    if moved is not None and logical:
        print(f"delta   {moved:,d} B moved of {logical:,d} B logical "
              f"({moved / logical:.1%})")
    for ks, man in sorted(node.manifests.items()):
        names = "+".join(parse_key(ks))
        if man.get("unserializable"):
            print(f"  upd {names:42s} UNSERIALIZABLE (fallback recompute)")
        else:
            b = man["base"]
            print(f"  upd {names:42s} {b['nbytes']:>12,d} B "
                  f"{len(b['chunks'])} chunks")
    for ks in node.deleted:
        print(f"  del {'+'.join(parse_key(ks))}")
    return 0


def cmd_diff(graph: CheckpointGraph, args) -> int:
    for c in (args.a, args.b):
        if c not in graph.nodes:
            print(f"no such commit: {c}", file=sys.stderr)
            return 1
    plan = graph.diff(args.a, args.b)
    print(f"{args.a} -> {args.b}: {plan.n_diverged} diverged, "
          f"{len(plan.to_delete)} only-in-{args.a}, "
          f"{len(plan.identical)} identical")
    for key, ver in sorted(plan.to_load.items()):
        print(f"  ~ {'+'.join(key):42s} @ {ver}")
    for key in plan.to_delete:
        print(f"  - {'+'.join(key)}")
    return 0


def cmd_stats_metrics(store, args) -> int:
    """``stats --metrics``: Prometheus text exposition — live store gauges
    (re-read through an InstrumentedStore, so the graph load itself is
    timed) merged with every persisted session snapshot (``obs/trace/*``,
    written by traced sessions on close)."""
    from repro.obs import (TRACE_META_PREFIX, InstrumentedStore,
                           MetricsRegistry, render)
    reg = MetricsRegistry()
    store = InstrumentedStore(store, reg)
    graph = CheckpointGraph(store, recover=False)
    reg.gauge("kishu_graph_commits").set(len(graph.nodes))
    reg.gauge("kishu_graph_meta_bytes").set(graph.total_meta_bytes())
    reg.gauge("kishu_store_chunks").set(store.n_chunks())
    reg.gauge("kishu_store_chunk_bytes").set(store.chunk_bytes_total())
    moved = sum(n.stats.get("bytes_serialized", 0)
                for n in graph.nodes.values())
    logical = sum(n.stats.get("bytes_logical", 0)
                  for n in graph.nodes.values())
    reg.gauge("kishu_ckpt_bytes_moved").set(moved)
    reg.gauge("kishu_ckpt_bytes_logical").set(logical)
    regs = [reg]
    for name in sorted(store.list_meta(TRACE_META_PREFIX)):
        doc = store.get_meta(name) or {}
        snap = doc.get("metrics")
        if snap:
            sreg = MetricsRegistry.from_doc(snap)
            sreg.const_labels.setdefault(
                "sid", str(doc.get("sid", name.rsplit("/", 1)[-1])))
            regs.append(sreg)
    sys.stdout.write(render(regs))
    return 0


def cmd_trace(store, args) -> int:
    """``kishu trace``: merge persisted span dumps into one Chrome
    trace-event JSON (Perfetto / chrome://tracing loadable); one pid per
    recorded session."""
    import json

    from repro.obs import TRACE_META_PREFIX, chrome_trace, spans_from_doc
    names = sorted(store.list_meta(TRACE_META_PREFIX))
    events, n_sessions = [], 0
    for name in names:
        doc = store.get_meta(name) or {}
        spans = spans_from_doc(doc.get("spans", []))
        if not spans:
            continue
        n_sessions += 1
        events.extend(chrome_trace(spans, pid=n_sessions)["traceEvents"])
    if not events:
        print("trace: no persisted spans — run a session with "
              "KISHU_TRACE=1 (or trace=True) and close it first",
              file=sys.stderr)
        return 1
    text = json.dumps({"traceEvents": events, "displayTimeUnit": "ms"},
                      indent=1)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text)
        print(f"trace: {len(events)} events from {n_sessions} session(s) "
              f"-> {args.out}", file=sys.stderr)
    else:
        print(text)
    return 0


def cmd_stats(store, graph: CheckpointGraph, args) -> int:
    print(f"commits      {len(graph.nodes)}")
    print(f"head         {graph.head}")
    print(f"chunks       {store.n_chunks()}")
    print(f"chunk bytes  {store.chunk_bytes_total():,d}")
    print(f"graph bytes  {graph.total_meta_bytes():,d}")
    # delta-pipeline accounting: bytes actually moved at checkpoint time
    # vs the logical size of everything those checkpoints covered
    moved = sum(n.stats.get("bytes_serialized", 0)
                for n in graph.nodes.values())
    logical = sum(n.stats.get("bytes_logical", 0)
                  for n in graph.nodes.values())
    print(f"ckpt moved   {moved:,d}")
    print(f"ckpt logical {logical:,d}")
    if logical:
        print(f"delta ratio  {moved / logical:.1%}")
    # device-codec accounting: PCIe traffic on the write path (device→host
    # after on-device compression) and how often the codec engaged
    d2h = sum(n.stats.get("bytes_dev2host", 0) for n in graph.nodes.values())
    enc = sum(n.stats.get("chunks_encoded", 0) for n in graph.nodes.values())
    skip = sum(n.stats.get("chunks_codec_skipped", 0)
               for n in graph.nodes.values())
    if d2h or enc or skip:
        print(f"dev->host    {d2h:,d}")
        print(f"dev encoded  {enc}")
        print(f"codec skips  {skip}")
    return 0


def cmd_verify(store, graph: CheckpointGraph, args) -> int:
    commits = [args.commit] if args.commit else sorted(graph.nodes)
    # plan every referenced chunk up front, then resolve presence (and, with
    # --deep, content) in bulk: batched metadata / scatter-gather fetches
    # through the parallel engine instead of one store round-trip per chunk
    refs = []                     # (cid, names, chunk_key, logical_n)
    for cid in commits:
        node = graph.nodes.get(cid)
        if node is None:
            print(f"no such commit: {cid}", file=sys.stderr)
            return 1
        for ks, man in node.manifests.items():
            if man.get("unserializable"):
                continue
            names = "+".join(parse_key(ks))
            for c in man["base"]["chunks"]:
                refs.append((cid, names, c["key"], int(c["n"])))
    uniq = list(dict.fromkeys(r[2] for r in refs))
    if args.deep:
        # streamed in slabs: bulk scatter-gather fetches without ever
        # holding more than a window of chunks in memory (a deep verify
        # of a multi-GB CAS must not materialize the whole store)
        want_n = {r[2]: r[3] for r in refs}
        present, corrupt = set(), set()
        for got in parallel.prefetch_map(
                lambda slab: store.get_chunks(slab, missing_ok=True),
                parallel.iter_slabs(
                    uniq, max(getattr(store, "min_slab", 1), 32))):
            for k, d in got.items():
                present.add(k)
                if chunk_key(d) != k or len(d) != want_n[k]:
                    corrupt.add(k)
    else:
        # chunk_sizes is metadata-only and backend-batched (one SQL pass,
        # pooled stats, sharded scatter) — presence without moving data
        present = set(store.chunk_sizes(uniq))
        corrupt = set()
    bad = 0
    for cid, names, key, _ in refs:
        if key not in present:
            print(f"MISSING {cid} {names} chunk {key}")
            bad += 1
        elif key in corrupt:
            print(f"CORRUPT {cid} {names} chunk {key}")
            bad += 1
    print(f"verify: {'OK' if bad == 0 else f'{bad} problems'} "
          f"({len(commits)} commits)")
    return 0 if bad == 0 else 2


def cmd_gc(store, graph: CheckpointGraph, args) -> int:
    # session-less GC: the mark set is shared with KishuSession.gc(); chunk
    # enumeration and the delete sweep are backend-native batched ops
    # (works on sqlite:// stores and whole fabrics alike).  Chunks are
    # shared across tenant namespaces, so the mark set unions every
    # namespace's references and any unsealed journal's chunks.
    live = graph.live_chunk_keys() | txn.global_live_chunks(store)
    dead = [k for k in store.list_chunk_keys() if k not in live]
    if not args.dry_run:
        store.delete_chunks(dead)
    # delete_branch tombstones are dead weight once the graph has loaded
    # without them — purge, or every future _load re-reads them forever
    # (same helper as KishuSession.gc, so the two sweeps cannot disagree)
    purged = txn.purge_tombstones(store, graph.nodes, dry_run=args.dry_run)
    verb = "would drop" if args.dry_run else "dropped"
    print(f"gc: {verb} {len(dead)} chunks ({len(live)} live), "
          f"{purged} tombstones")
    return 0


def cmd_fsck(store, args) -> int:
    rep = txn.fsck(store)
    for line in rep.details[:args.limit]:
        print(f"  {line}")
    if len(rep.details) > args.limit:
        print(f"  ... {len(rep.details) - args.limit} more")
    print(f"fsck: {'OK' if rep.clean else f'{rep.problems} problems'} "
          f"({rep.commits} commits, {rep.unsealed_txns} unsealed txns, "
          f"{rep.torn_head} torn HEAD, {rep.missing_parents} missing "
          f"parents, {rep.missing_chunks} missing chunks, "
          f"{rep.dangling_chunks} dangling chunks, {rep.tombstones} "
          f"tombstones)")
    if rep.unsealed_txns:
        print("hint: `recover` replays or rolls back unsealed txns")
    if rep.dangling_chunks and not rep.unsealed_txns:
        # expected between delete_branch and gc; gc is the reclaimer
        print("hint: dangling chunks are unreferenced data — `gc` "
              "reclaims them")
    return 0 if rep.clean else 2


def cmd_recover(store, args) -> int:
    out = txn.recover(store)
    print(f"recover: {out['replayed']} txns replayed "
          f"({out['commits_published']} commits published), "
          f"{out['rolled_back']} rolled back, "
          f"{out['chunks_dropped']} orphan chunks dropped")
    return 0


def cmd_lease(store, args) -> int:
    """Show writer leases (this namespace); ``--release NAME`` drops one —
    an operator override for a provably dead holder.  Session code never
    needs it: contenders steal automatically after an observed TTL."""
    if args.release:
        name = LEASE_PREFIX + args.release
        if store.get_meta(name) is None:
            print(f"no such lease: {args.release}", file=sys.stderr)
            return 1
        store.delete_meta(name)
        print(f"lease {args.release} released")
        return 0
    leases = lease_status(store)
    if not leases:
        print("no leases held")
        return 0
    for rec in leases:
        print(f"{rec['name']:8s} owner={rec['owner']} "
              f"token={rec['token']} ttl={rec['ttl_s']}s "
              f"age~{rec['age_hint_s']}s pid={rec['pid']} "
              f"host={rec['host']}")
    return 0


def cmd_tenants(store, args) -> int:
    """Per-tenant usage on a shared store: commits, referenced bytes (from
    each namespace's refcount ledger), and the namespace's writer lease."""
    rows = [("", store)] + [(tid, NamespacedStore(store, tid))
                            for tid in tenant_ids(store)]
    print(f"{'tenant':16s} {'commits':>7s} {'ref_bytes':>12s} "
          f"{'head':8s} lease")
    for tid, view in rows:
        n_commits = sum(1 for name in view.list_meta("commit/")
                        if not (view.get_meta(name) or {}).get("deleted"))
        if tid == "" and n_commits == 0:
            continue                     # bare root namespace: skip noise
        refs = (view.get_meta(REFS_DOC) or {}).get("counts", {})
        ref_bytes = sum(cn[1] for cn in refs.values() if cn[0] > 0)
        head = (view.get_meta("HEAD") or {}).get("head") or "-"
        leases = lease_status(view)
        owner = leases[0]["owner"] if leases else "-"
        print(f"{tid or '<root>':16s} {n_commits:7d} {ref_bytes:12,d} "
              f"{head:8s} {owner}")
    return 0


def cmd_kishud(store_uri: str, args) -> int:
    from repro.launch import kishud as kishud_mod
    if args.action == "start":
        if args.detach:
            import subprocess
            proc = subprocess.Popen(
                [sys.executable, "-m", "repro.launch.kishud",
                 "--store", store_uri, "--socket", args.socket,
                 "--workers", str(args.workers),
                 "--lease-ttl", str(args.lease_ttl)],
                stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL,
                start_new_session=True)
            # wait for the control socket to answer before declaring success
            import time as _time
            for _ in range(100):
                try:
                    if kishud_mod.control(args.socket, "ping").get("ok"):
                        print(f"kishud: started (pid {proc.pid}, "
                              f"socket {args.socket})")
                        return 0
                except OSError:
                    _time.sleep(0.05)
            print("kishud: did not come up", file=sys.stderr)
            return 1
        return kishud_mod.main(["--store", store_uri,
                                "--socket", args.socket,
                                "--workers", str(args.workers),
                                "--lease-ttl", str(args.lease_ttl)])
    try:
        resp = kishud_mod.control(args.socket, args.action)
    except OSError as e:
        print(f"kishud: no daemon on {args.socket} ({e})", file=sys.stderr)
        return 1
    if args.action == "metrics" and resp.get("ok"):
        sys.stdout.write(resp.get("metrics", ""))
        return 0
    print(resp if args.action != "status"
          else "\n".join(f"{k:18s} {v}" for k, v in resp.items()))
    return 0 if resp.get("ok") else 1


def cmd_topology(store, args) -> int:
    print("\n".join(fabric.topology_lines(store)))
    return 0


def cmd_scrub(store, args) -> int:
    rep = fabric.scrub(store, repair=args.repair, deep=args.deep)
    for line in rep.details[:args.limit]:
        print(f"  {line}")
    if len(rep.details) > args.limit:
        print(f"  ... {len(rep.details) - args.limit} more")
    print(f"scrub: {rep.problems} problems "
          f"({rep.replica_missing} replica-missing, {rep.misplaced} "
          f"misplaced, {rep.corrupt} corrupt) across {rep.chunks_checked} "
          f"chunks; {rep.repaired} repaired, {rep.remaining} remaining")
    return 0 if rep.remaining == 0 else 2


def cmd_rebalance(store, args) -> int:
    out = fabric.rebalance(store)
    print(f"rebalance: moved {out['chunks_moved']} of "
          f"{out['chunks_checked']} chunks to their ring homes")
    return 0


def main(argv: Optional[list] = None) -> int:
    ap = argparse.ArgumentParser(prog="kishu")
    ap.add_argument("--store", required=True,
                    help="memory:// | dir:///path | sqlite:///db")
    sub = ap.add_subparsers(dest="cmd", required=True)
    p = sub.add_parser("log")
    p.add_argument("--limit", type=int, default=0)
    p = sub.add_parser("show")
    p.add_argument("commit")
    p = sub.add_parser("diff")
    p.add_argument("a")
    p.add_argument("b")
    p = sub.add_parser("plan")
    p.add_argument("commit")
    p.add_argument("--from", dest="from_", metavar="COMMIT",
                   help="plan from this commit instead of HEAD")
    p.add_argument("--mode", default="auto",
                   choices=["auto", "fetch", "replay"])
    p = sub.add_parser("stats")
    p.add_argument("--metrics", action="store_true",
                   help="Prometheus text exposition instead of the "
                        "human-readable summary")
    p = sub.add_parser("trace")
    p.add_argument("--out", help="write Chrome trace JSON here instead of "
                                 "stdout (load in Perfetto)")
    p = sub.add_parser("verify")
    p.add_argument("--commit")
    p.add_argument("--deep", action="store_true")
    p = sub.add_parser("gc")
    p.add_argument("--dry-run", action="store_true")
    p = sub.add_parser("fsck")
    p.add_argument("--limit", type=int, default=20,
                   help="max per-problem detail lines to print")
    sub.add_parser("recover")
    p = sub.add_parser("lease")
    p.add_argument("--release", metavar="NAME",
                   help="force-drop a lease (operator override)")
    sub.add_parser("tenants")
    p = sub.add_parser("kishud")
    p.add_argument("action", choices=["start", "stop", "status", "ping",
                                      "metrics"])
    p.add_argument("--socket", default="/tmp/kishud.sock")
    p.add_argument("--detach", action="store_true",
                   help="start: run the daemon in its own process")
    p.add_argument("--workers", type=int, default=4)
    p.add_argument("--lease-ttl", type=float, default=10.0)
    sub.add_parser("topology")
    p = sub.add_parser("scrub")
    p.add_argument("--repair", action="store_true")
    p.add_argument("--deep", action="store_true")
    p.add_argument("--limit", type=int, default=20,
                   help="max per-chunk problem lines to print")
    sub.add_parser("rebalance")
    args = ap.parse_args(argv)

    # kishud verbs talk to the daemon (or spawn it) — the daemon owns the
    # store; opening it here too would be a second uncoordinated opener
    if args.cmd == "kishud":
        return cmd_kishud(args.store, args)
    store = open_store(args.store)
    # store-level verbs run BEFORE any graph construction: fsck must see
    # the raw, un-recovered state, and recover applies it explicitly
    if args.cmd == "fsck":
        return cmd_fsck(store, args)
    if args.cmd == "recover":
        return cmd_recover(store, args)
    if args.cmd == "lease":
        return cmd_lease(store, args)
    if args.cmd == "tenants":
        return cmd_tenants(store, args)
    # observability verbs: trace reads persisted span dumps (no graph);
    # stats --metrics builds its own instrumented graph view
    if args.cmd == "trace":
        return cmd_trace(store, args)
    if args.cmd == "stats" and args.metrics:
        return cmd_stats_metrics(store, args)
    # fleet verbs operate on the store itself — no graph required
    if args.cmd == "topology":
        return cmd_topology(store, args)
    if args.cmd == "scrub":
        return cmd_scrub(store, args)
    if args.cmd == "rebalance":
        return cmd_rebalance(store, args)
    # CLI graph verbs are read-only on the commit journal: recovery here
    # could roll back a LIVE session's in-flight transaction (this process
    # doesn't own the store the way a session does).  Recovery stays
    # explicit (`recover`) or implicit on session open.
    graph = CheckpointGraph(store, recover=False)
    if args.cmd == "log":
        return cmd_log(graph, args)
    if args.cmd == "show":
        return cmd_show(graph, args)
    if args.cmd == "diff":
        return cmd_diff(graph, args)
    if args.cmd == "plan":
        return cmd_plan(store, graph, args)
    if args.cmd == "stats":
        return cmd_stats(store, graph, args)
    if args.cmd == "verify":
        return cmd_verify(store, graph, args)
    if args.cmd == "gc":
        return cmd_gc(store, graph, args)
    return 1


if __name__ == "__main__":
    sys.exit(main())
