"""kishu CLI — inspect and maintain a checkpoint store from the shell.

    python -m repro.launch.kishu_cli --store dir:///ckpt log
    python -m repro.launch.kishu_cli --store ... show c00042
    python -m repro.launch.kishu_cli --store ... diff c00012 c00042
    python -m repro.launch.kishu_cli --store ... stats
    python -m repro.launch.kishu_cli --store ... verify [--commit cXXXXX]
    python -m repro.launch.kishu_cli --store ... gc

``verify`` checks that every chunk referenced by a state's manifests is
present and content-addressed correctly — the operator's answer to "can I
still restore this run?" after storage incidents (missing chunks are
reported per co-variable; they will restore via fallback recomputation as
long as the command registry is available).
"""
from __future__ import annotations

import argparse
import sys
from typing import Optional

from repro.core.chunkstore import chunk_key, open_store
from repro.core.graph import CheckpointGraph, parse_key


def cmd_log(graph: CheckpointGraph, args) -> int:
    for e in graph.log(limit=args.limit):
        mark = "*" if e["head"] else " "
        print(f"{mark} {e['commit']}  <- {e['parent'] or '-':8s} "
              f"{e['command'] or '':14s} upd={e['updated']:3d} "
              f"del={e['deleted']:2d}  {e['message']}")
    return 0


def cmd_show(graph: CheckpointGraph, args) -> int:
    node = graph.nodes.get(args.commit)
    if node is None:
        print(f"no such commit: {args.commit}", file=sys.stderr)
        return 1
    print(f"commit  {node.commit_id} (parent {node.parent}, "
          f"depth {node.depth})")
    print(f"command {node.command}")
    print(f"message {node.message!r}")
    print(f"state   {len(node.state_index)} co-variables")
    moved = node.stats.get("bytes_serialized")
    logical = node.stats.get("bytes_logical")
    if moved is not None and logical:
        print(f"delta   {moved:,d} B moved of {logical:,d} B logical "
              f"({moved / logical:.1%})")
    for ks, man in sorted(node.manifests.items()):
        names = "+".join(parse_key(ks))
        if man.get("unserializable"):
            print(f"  upd {names:42s} UNSERIALIZABLE (fallback recompute)")
        else:
            b = man["base"]
            print(f"  upd {names:42s} {b['nbytes']:>12,d} B "
                  f"{len(b['chunks'])} chunks")
    for ks in node.deleted:
        print(f"  del {'+'.join(parse_key(ks))}")
    return 0


def cmd_diff(graph: CheckpointGraph, args) -> int:
    for c in (args.a, args.b):
        if c not in graph.nodes:
            print(f"no such commit: {c}", file=sys.stderr)
            return 1
    plan = graph.diff(args.a, args.b)
    print(f"{args.a} -> {args.b}: {plan.n_diverged} diverged, "
          f"{len(plan.to_delete)} only-in-{args.a}, "
          f"{len(plan.identical)} identical")
    for key, ver in sorted(plan.to_load.items()):
        print(f"  ~ {'+'.join(key):42s} @ {ver}")
    for key in plan.to_delete:
        print(f"  - {'+'.join(key)}")
    return 0


def cmd_stats(store, graph: CheckpointGraph, args) -> int:
    print(f"commits      {len(graph.nodes)}")
    print(f"head         {graph.head}")
    print(f"chunks       {store.n_chunks()}")
    print(f"chunk bytes  {store.chunk_bytes_total():,d}")
    print(f"graph bytes  {graph.total_meta_bytes():,d}")
    # delta-pipeline accounting: bytes actually moved at checkpoint time
    # vs the logical size of everything those checkpoints covered
    moved = sum(n.stats.get("bytes_serialized", 0)
                for n in graph.nodes.values())
    logical = sum(n.stats.get("bytes_logical", 0)
                  for n in graph.nodes.values())
    print(f"ckpt moved   {moved:,d}")
    print(f"ckpt logical {logical:,d}")
    if logical:
        print(f"delta ratio  {moved / logical:.1%}")
    return 0


def cmd_verify(store, graph: CheckpointGraph, args) -> int:
    commits = [args.commit] if args.commit else sorted(graph.nodes)
    bad = 0
    for cid in commits:
        node = graph.nodes.get(cid)
        if node is None:
            print(f"no such commit: {cid}", file=sys.stderr)
            return 1
        for ks, man in node.manifests.items():
            if man.get("unserializable"):
                continue
            names = "+".join(parse_key(ks))
            for c in man["base"]["chunks"]:
                if not store.has_chunk(c["key"]):
                    print(f"MISSING {cid} {names} chunk {c['key']}")
                    bad += 1
                elif args.deep:
                    data = store.get_chunk(c["key"])
                    if chunk_key(data) != c["key"] or len(data) != c["n"]:
                        print(f"CORRUPT {cid} {names} chunk {c['key']}")
                        bad += 1
    print(f"verify: {'OK' if bad == 0 else f'{bad} problems'} "
          f"({len(commits)} commits)")
    return 0 if bad == 0 else 2


def cmd_gc(store, graph: CheckpointGraph, args) -> int:
    # session-less GC: the mark set is shared with KishuSession.gc(); chunk
    # enumeration is backend-native (works on sqlite:// stores too)
    live = graph.live_chunk_keys()
    dead = [k for k in store.list_chunk_keys() if k not in live]
    if not args.dry_run:
        for k in dead:
            store.delete_chunk(k)
    print(f"gc: {'would drop' if args.dry_run else 'dropped'} {len(dead)} "
          f"chunks ({len(live)} live)")
    return 0


def main(argv: Optional[list] = None) -> int:
    ap = argparse.ArgumentParser(prog="kishu")
    ap.add_argument("--store", required=True,
                    help="memory:// | dir:///path | sqlite:///db")
    sub = ap.add_subparsers(dest="cmd", required=True)
    p = sub.add_parser("log")
    p.add_argument("--limit", type=int, default=0)
    p = sub.add_parser("show")
    p.add_argument("commit")
    p = sub.add_parser("diff")
    p.add_argument("a")
    p.add_argument("b")
    sub.add_parser("stats")
    p = sub.add_parser("verify")
    p.add_argument("--commit")
    p.add_argument("--deep", action="store_true")
    p = sub.add_parser("gc")
    p.add_argument("--dry-run", action="store_true")
    args = ap.parse_args(argv)

    store = open_store(args.store)
    graph = CheckpointGraph(store)
    if args.cmd == "log":
        return cmd_log(graph, args)
    if args.cmd == "show":
        return cmd_show(graph, args)
    if args.cmd == "diff":
        return cmd_diff(graph, args)
    if args.cmd == "stats":
        return cmd_stats(store, graph, args)
    if args.cmd == "verify":
        return cmd_verify(store, graph, args)
    if args.cmd == "gc":
        return cmd_gc(store, graph, args)
    return 1


if __name__ == "__main__":
    sys.exit(main())
