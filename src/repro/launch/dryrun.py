import os

from repro.configs.xla_flags import apply_xla_tuning, force_host_device_count
force_host_device_count(512)    # merged, not clobbered: user XLA_FLAGS win
apply_xla_tuning()              # opt-in ($KISHU_XLA_TUNING=1), no-op on CPU
# ^ MUST run before jax's first init: the backend locks XLA_FLAGS then.
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver:
  1. builds abstract inputs (ShapeDtypeStruct — zero allocation),
  2. derives in/out shardings from ShardingRules on the production mesh,
  3. ``jit(step).lower(...).compile()`` — proving the distribution config is
     coherent (sharding divisibility, collective legality, memory layout),
  4. records ``memory_analysis()`` / ``cost_analysis()`` and the collective
     byte volume parsed from the optimized HLO into a JSON artifact that
     benchmarks/roofline.py consumes.

Usage:
  python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--force]
"""
import argparse
import json
import re
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.launch.mesh import make_production_mesh
from repro.models.config import get_config
from repro.configs.shapes import SHAPES, input_specs, shape_applicable, cells
from repro.sharding.rules import ShardingRules
from repro.optim.adamw import AdamWConfig
from repro.train import step as step_lib

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "benchmarks", "artifacts", "dryrun")

DTYPE_BYTES = {
    "f64": 8, "u64": 8, "s64": 8, "c64": 8, "f32": 4, "u32": 4, "s32": 4,
    "bf16": 2, "f16": 2, "u16": 2, "s16": 2, "pred": 1, "u8": 1, "s8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "c128": 16, "token": 0, "opaque": 0,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    """Sum byte sizes of every typed shape literal in an HLO result type."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, Any]:
    """Parse optimized HLO; sum result bytes per collective op kind."""
    out = {k: 0 for k in COLLECTIVES}
    counts = {k: 0 for k in COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        eq = s.find(" = ")
        if eq < 0:
            continue
        rhs = s[eq + 3:]
        m = re.match(r"((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\][^ ]*)) "
                     r"([a-z0-9-]+)", rhs)
        if not m:
            continue
        result_type, op = m.group(1), m.group(2)
        # match e.g. all-reduce, all-reduce-start, all-gather-done
        for kind in COLLECTIVES:
            if op == kind or op.startswith(kind + "-"):
                if op.endswith("-done"):
                    break                      # counted at -start
                out[kind] += _shape_bytes(result_type)
                counts[kind] += 1
                break
    out_counts = {f"n_{k}": v for k, v in counts.items()}
    return {**out, **out_counts,
            "total": sum(out[k] for k in COLLECTIVES)}


def _tree_device_bytes(tree, shardings, n_devices: int) -> int:
    """Analytic per-device bytes of a sharded abstract pytree."""
    leaves = jax.tree.leaves(tree)
    shard_leaves = jax.tree.leaves(shardings,
                                   is_leaf=lambda x: hasattr(x, "spec"))
    total = 0
    for leaf, sh in zip(leaves, shard_leaves):
        nbytes = np.prod(leaf.shape, dtype=np.int64) * np.dtype(leaf.dtype).itemsize
        try:
            ways = int(np.prod([1] + [
                0 or _axis_size(sh, ax) for ax in _spec_axes(sh)]))
        except Exception:  # noqa: BLE001
            ways = 1
        total += int(nbytes) // max(ways, 1)
    return total


def _spec_axes(sh):
    axes = []
    for entry in sh.spec:
        if entry is None:
            continue
        if isinstance(entry, tuple):
            axes.extend(entry)
        else:
            axes.append(entry)
    return axes


def _axis_size(sh, ax):
    return dict(zip(sh.mesh.axis_names, sh.mesh.devices.shape))[ax]


def stage_unit_counts(cfg) -> list:
    """Current number of units per stage (decoder stages [+ encoder])."""
    from repro.models import lm as lm_lib
    counts = [s.n_units for s in lm_lib.build_stages(cfg)]
    if cfg.enc_dec:
        counts.append(lm_lib.encoder_stages(cfg)[0].n_units)
    return counts


def with_stage_counts(cfg, counts: list):
    """Config surgery: rebuild cfg so each stage has the given unit count."""
    from repro.models import lm as lm_lib
    stages = lm_lib.build_stages(cfg)
    kw = {}
    if cfg.moe is not None and cfg.moe.n_dense_layers:
        assert len(stages) == 2
        import dataclasses
        kw["moe"] = dataclasses.replace(cfg.moe, n_dense_layers=counts[0])
        kw["n_layers"] = counts[0] + counts[1] * len(stages[1].unit)
    else:
        assert len(stages) == 1
        kw["n_layers"] = counts[0] * len(stages[0].unit)
    if cfg.enc_dec:
        kw["n_encoder_layers"] = counts[-1]
    return cfg.replace(**kw)


def calibration_points(cfg) -> list:
    """(variant_cfg, counts) points for solving cost = outer + sum N_i*body_i:
    a base with 1 unit per stage plus one +1 point per stage."""
    n_stages = len(stage_unit_counts(cfg))
    base = [1] * n_stages
    pts = [list(base)]
    for i in range(n_stages):
        v = list(base)
        v[i] = 2
        pts.append(v)
    return [(with_stage_counts(cfg, c), c) for c in pts]


def build_cell(arch: str, shape: str, mesh, *, unroll: bool = False,
               cfg_override=None,
               rules_opts: Optional[dict] = None) -> Dict[str, Any]:
    """Build (fn, args, in/out shardings) for one cell."""
    cfg = cfg_override if cfg_override is not None else get_config(arch)
    spec = input_specs(cfg, shape)
    rules = ShardingRules(cfg, mesh, **(rules_opts or {}))
    hidden_sharding = (rules.hidden_spec(SHAPES[shape].global_batch,
                                         SHAPES[shape].seq_len)
                       if rules.seq_shard_activations else None)
    opt_cfg = AdamWConfig(
        moment_dtype="bfloat16" if cfg.param_counts()["total"] > 5e10
        else "float32")

    if spec["kind"] == "train":
        state = step_lib.abstract_train_state(cfg, opt_cfg)
        pshard = rules.param_shardings(state["params"])
        state_shard = {
            "params": pshard,
            "opt": {"mu": pshard, "nu": pshard,
                    "count": rules.replicated()},
            "step": rules.replicated(),
            "rng": rules.replicated(),
        }
        batch_shard = rules.batch_spec(spec["batch"])
        fn = step_lib.make_train_step(cfg, opt_cfg, remat=True,
                                      unroll=unroll,
                                      hidden_sharding=hidden_sharding)
        jfn = jax.jit(fn, in_shardings=(state_shard, batch_shard),
                      out_shardings=(state_shard, rules.replicated()),
                      donate_argnums=(0,))
        return {"jfn": jfn, "args": (state, spec["batch"]),
                "cfg": cfg, "rules": rules,
                "arg_shards": (state_shard, batch_shard)}

    from repro.models import lm
    params = lm.abstract_params(cfg)
    pshard = rules.param_shardings(params)
    if spec["kind"] == "prefill":
        batch_shard = rules.batch_spec(spec["batch"])
        fn = step_lib.make_prefill_step(cfg, unroll=unroll,
                                        hidden_sharding=hidden_sharding)
        jfn = jax.jit(fn, in_shardings=(pshard, batch_shard),
                      out_shardings=rules.logits_spec(
                          SHAPES[shape].global_batch))
        return {"jfn": jfn, "args": (params, spec["batch"]),
                "cfg": cfg, "rules": rules,
                "arg_shards": (pshard, batch_shard)}

    # decode
    bsz = SHAPES[shape].global_batch
    caches = spec["caches"]
    cshard = rules.cache_spec(caches, bsz)
    batch_shard = rules.batch_spec(spec["batch"])
    fn = step_lib.make_decode_step(cfg, unroll=unroll)
    jfn = jax.jit(fn, in_shardings=(pshard, cshard, batch_shard),
                  out_shardings=(rules.batch_spec(
                      jax.ShapeDtypeStruct((bsz, 1), np.int32)), cshard),
                  donate_argnums=(1,))
    return {"jfn": jfn, "args": (params, caches, spec["batch"]),
            "cfg": cfg, "rules": rules,
            "arg_shards": (pshard, cshard, batch_shard)}


def run_cell(arch: str, shape: str, mesh_kind: str, *,
             out_dir: str = ART_DIR, force: bool = False,
             save: bool = True, variant: str = "",
             rules_opts: Optional[dict] = None) -> Dict[str, Any]:
    os.makedirs(out_dir, exist_ok=True)
    suffix = f"__{variant}" if variant else ""
    path = os.path.join(out_dir, f"{arch}__{shape}__{mesh_kind}{suffix}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    cfg = get_config(arch)
    ok, why = shape_applicable(cfg, shape)
    rec: Dict[str, Any] = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                           "variant": variant, "rules_opts": rules_opts or {}}
    if not ok:
        rec.update({"status": "skip", "reason": why})
        if save:
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = int(np.prod(mesh.devices.shape))
    t0 = time.monotonic()
    try:
        from repro.sharding import context as shctx
        with mesh:
            cell = build_cell(arch, shape, mesh, rules_opts=rules_opts)
            with shctx.moe_weight_gather(cell["rules"]):
                lowered = cell["jfn"].lower(*cell["args"])
            t_lower = time.monotonic() - t0
            compiled = lowered.compile()
            t_compile = time.monotonic() - t0 - t_lower

            cost = {}
            try:
                ca = compiled.cost_analysis()
                if isinstance(ca, (list, tuple)):
                    ca = ca[0]
                cost = {k: float(v) for k, v in (ca or {}).items()
                        if isinstance(v, (int, float)) and (
                            k in ("flops", "transcendentals")
                            or k.startswith("bytes accessed"))}
            except Exception as e:  # noqa: BLE001
                cost = {"error": str(e)}

            memory = {}
            try:
                ma = compiled.memory_analysis()
                for f in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "alias_size_in_bytes",
                          "generated_code_size_in_bytes"):
                    if hasattr(ma, f):
                        memory[f] = int(getattr(ma, f))
            except Exception as e:  # noqa: BLE001
                memory = {"error": str(e)}

            hlo = compiled.as_text()
            coll = collective_bytes(hlo)

            arg_dev_bytes = sum(
                _tree_device_bytes(a, s, n_dev)
                for a, s in zip(cell["args"], cell["arg_shards"]))

            pc = cfg.param_counts()
            rec.update({
                "status": "ok",
                "n_devices": n_dev,
                "lower_s": round(t_lower, 2),
                "compile_s": round(t_compile, 2),
                "cost_analysis": cost,
                "memory_analysis": memory,
                "collectives": coll,
                "arg_bytes_per_device": int(arg_dev_bytes),
                "params_total": pc["total"],
                "params_active": pc["active"],
                "hlo_lines": hlo.count("\n"),
            })
    except Exception as e:  # noqa: BLE001
        rec.update({"status": "error", "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-4000:]})
    if save:
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def _cost_vector(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    vec = {"flops": float((ca or {}).get("flops", 0.0)),
           "bytes": float((ca or {}).get("bytes accessed", 0.0))}
    for k in COLLECTIVES:
        vec[f"coll_{k}"] = float(coll[k])
    vec["coll_total"] = float(coll["total"])
    return vec


def calibrate_cell(arch: str, shape: str, mesh_kind: str, *,
                   out_dir: str = ART_DIR, force: bool = False,
                   variant: str = "",
                   rules_opts: Optional[dict] = None) -> Optional[dict]:
    """Scan-aware cost calibration (XLA cost analysis counts a while body
    once).  Compiles small *unrolled* variants — 1 unit per stage plus one
    (+1 unit) point per stage — and solves

        cost = outer + sum_i N_i * body_i

    exactly for the linear per-stage costs, then evaluates at the real unit
    counts.  Stored under "calibrated" in the cell artifact."""
    suffix = f"__{variant}" if variant else ""
    path = os.path.join(out_dir, f"{arch}__{shape}__{mesh_kind}{suffix}.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        rec = json.load(f)
    if rec.get("status") != "ok":
        return None
    if "calibrated" in rec and not force:
        return rec["calibrated"]
    rules_opts = rules_opts or rec.get("rules_opts") or {}

    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    points = calibration_points(cfg)
    vecs = []
    try:
        from repro.sharding import context as shctx
        with mesh:
            for vcfg, counts in points:
                cell = build_cell(arch, shape, mesh, unroll=True,
                                  cfg_override=vcfg, rules_opts=rules_opts)
                with shctx.moe_weight_gather(cell["rules"]):
                    compiled = cell["jfn"].lower(*cell["args"]).compile()
                vecs.append((counts, _cost_vector(compiled)))
    except Exception as e:  # noqa: BLE001
        rec["calibrated"] = {"error": f"{type(e).__name__}: {e}"}
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        return rec["calibrated"]

    base_counts, base = vecs[0]
    n_true = stage_unit_counts(cfg)
    calibrated = {"points": [{"counts": c, **v} for c, v in vecs],
                  "n_units": n_true}
    for metric in base:
        bodies = [vecs[1 + i][1][metric] - base[metric]
                  for i in range(len(n_true))]
        outer = base[metric] - sum(bodies)
        calibrated[metric] = outer + sum(
            n * b for n, b in zip(n_true, bodies))
        calibrated[f"{metric}_outer"] = outer
        calibrated[f"{metric}_bodies"] = bodies
    rec["calibrated"] = calibrated
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return calibrated


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--calibrate", action="store_true",
                    help="add scan-aware calibrated costs to artifacts")
    ap.add_argument("--out", default=ART_DIR)
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    todo = []
    if args.all:
        for arch, shape, _ok, _why in cells():
            for mk in meshes:
                todo.append((arch, shape, mk))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        todo = [(args.arch, args.shape, mk) for mk in meshes]

    failures = 0
    for arch, shape, mk in todo:
        t0 = time.monotonic()
        if args.calibrate:
            cal = calibrate_cell(arch, shape, mk, out_dir=args.out,
                                 force=args.force)
            dt = time.monotonic() - t0
            if cal is None:
                print(f"[n/a  ] {arch:24s} {shape:12s} {mk:6s}", flush=True)
            elif "error" in cal:
                failures += 1
                print(f"[error] {arch:24s} {shape:12s} {mk:6s} ({dt:5.1f}s) "
                      f"{cal['error'][:120]}", flush=True)
            else:
                print(f"[ok   ] {arch:24s} {shape:12s} {mk:6s} ({dt:5.1f}s) "
                      f"cal_flops={cal['flops']:.3e} "
                      f"cal_coll={cal['coll_total']:.3e}B", flush=True)
            continue
        rec = run_cell(arch, shape, mk, out_dir=args.out, force=args.force)
        dt = time.monotonic() - t0
        status = rec["status"]
        extra = ""
        if status == "ok":
            fl = rec["cost_analysis"].get("flops", 0)
            extra = (f" flops={fl:.3e} coll={rec['collectives']['total']:.3e}B"
                     f" arg/dev={rec['arg_bytes_per_device']/2**30:.2f}GiB"
                     f" compile={rec['compile_s']:.0f}s")
        elif status == "error":
            failures += 1
            extra = " " + rec["error"][:160]
        print(f"[{status:5s}] {arch:24s} {shape:12s} {mk:6s}"
              f" ({dt:5.1f}s){extra}", flush=True)
    if failures:
        print(f"{failures} FAILURES", flush=True)
        sys.exit(1)
    print("dry-run complete", flush=True)


if __name__ == "__main__":
    main()
