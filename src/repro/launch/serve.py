"""Batched serving driver: prefill + decode with KV/SSM caches.

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-780m --reduced \
        --batch 4 --prompt-len 32 --gen 16

Serving state (params + caches) lives in a Kishu session too: a "prefill"
command materializes caches as state, so a server can snapshot/branch
per-request-batch cache state (prefix reuse across branches) and roll back a
cancelled generation — the serving analogue of path exploration (§7.5.2).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import get_config
from repro.models.testing import reduced as reduce_cfg
from repro.models import lm
from repro.train import step as step_lib


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-780m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)

    key = jax.random.key(0)
    params = lm.init_params(cfg, key)
    decode = jax.jit(step_lib.make_decode_step(cfg))

    b, plen = args.batch, args.prompt_len
    total = plen + args.gen
    prompts = jax.random.randint(jax.random.key(1), (b, plen), 0,
                                 cfg.vocab_size)
    caches = lm.init_caches(cfg, b, total,
                            enc_seq=plen if cfg.enc_dec else 0)
    if cfg.enc_dec:
        enc = jax.random.normal(jax.random.key(2), (b, plen, cfg.d_model),
                                jnp.dtype(cfg.dtype))
        caches["enc_out"] = lm.encode(cfg, params,
                                      {"enc_embeds": enc}, remat=False)

    # prefill via decode loop (teacher-forcing the prompt)
    t0 = time.monotonic()
    tok = prompts[:, :1]
    out_tokens = [tok]
    for t in range(total - 1):
        batch = {"tokens": tok, "index": jnp.asarray(t, jnp.int32)}
        if cfg.frontend == "vision":
            batch = {"embeds": params["embed"][tok[:, 0]][:, None, :],
                     "index": jnp.asarray(t, jnp.int32)}
        nxt, caches = decode(params, caches, batch)
        tok = prompts[:, t + 1:t + 2] if t + 1 < plen else nxt
        out_tokens.append(tok)
    dt = time.monotonic() - t0
    gen = jnp.concatenate(out_tokens, axis=1)
    print(f"arch={cfg.name} batch={b} generated {args.gen} tokens/seq "
          f"in {dt:.2f}s ({b*total/dt:.1f} tok/s incl prefill)")
    print("sample:", np.asarray(gen[0, plen:plen + 12]))


if __name__ == "__main__":
    main()
