"""Host (numpy) half of the bitshuffle+RLE block codec — the format oracle.

The codec transposes each group of ``gw`` uint32 words into 32 bit-planes
of ``gw`` bits and run-length-encodes at *plane* granularity: planes that
are all-zero or all-one collapse into two 32-bit masks per group; only the
remaining ("stored") planes are kept verbatim.  Typical numeric notebook
state — small-range ints, slowly-varying floats, masks — has most high
bit-planes constant, so dirty chunks shrink 2-20x with a branch-free
transform simple enough to run inside the delta_pack Pallas pipeline
(kernel.py / ref.py produce the identical plane stream on device).

Payload layout (all little-endian), wrapped by the standard ``KZC1`` chunk
frame (``core/chunkstore.py``) under ``CODEC_ID``:

    header (16 B): u8 version=1 | u8 log2_gw | u16 0 | u32 n_groups
                   | u64 raw_len
    group headers: n_groups x (u32 stored_mask | u32 ones_mask)
    planes:        stored planes in (group, plane-ascending) order,
                   gw/8 bytes each

A plane absent from ``stored_mask`` is all-one if its ``ones_mask`` bit is
set, else all-zero.  ``raw_len`` truncates the reconstruction (groups are
zero-padded on encode), so odd-sized chunks round-trip exactly.  The
decoder validates the header and the exact payload length and raises on
any mismatch — ``decode_chunk`` then returns the bytes verbatim, exactly
like a corrupt zlib frame.

This module is pure numpy (no jax import): ``core/chunkstore.py`` registers
it as a first-class :class:`ChunkCodec`, and chunk stores must stay
importable on hosts without an accelerator stack.
"""
from __future__ import annotations

import struct
from typing import List, Optional, Sequence

import numpy as np

CODEC_ID = 4                 # KZC1 frame codec id (core/chunkstore.py)
CODEC_NAME = "bshuf"
FRAME_MAGIC = b"KZC1"        # must match chunkstore.CHUNK_MAGIC
_FRAME_HDR = len(FRAME_MAGIC) + 1 + 8

_VERSION = 1
_HDR = struct.Struct("<BBHIQ")          # ver, log2_gw, 0, n_groups, raw_len
HEADER_BYTES = _HDR.size                # 16

GROUP_WORDS = 1024           # default group size (4 KiB of words)
MIN_GROUP_WORDS = 32         # one bitmap word per plane
PROBE_THRESHOLD = 0.75       # est. stored-plane fraction above which we skip
PROBE_MIN_BYTES = 256        # below this, framing overhead always loses
_ALL_ONES = np.uint32(0xFFFFFFFF)


def _log2(n: int) -> int:
    return int(n).bit_length() - 1


def pow2ceil(n: int) -> int:
    """Smallest power of two >= n (n >= 1)."""
    return 1 << max(0, int(n - 1).bit_length())


def popcount_u32(a: np.ndarray) -> np.ndarray:
    """Per-element popcount of a uint32 array."""
    b = np.ascontiguousarray(a, dtype="<u4").view(np.uint8)
    return np.unpackbits(b).reshape(-1, 32).sum(axis=1).astype(np.int64)


def pick_group_words(n_words: int) -> int:
    """Group size for ``n_words`` of data: the smallest power of two
    covering it, clamped to [MIN_GROUP_WORDS, GROUP_WORDS] — small chunks
    avoid padding a 4 KiB group, large chunks amortize the 8-byte/group
    header."""
    gw = GROUP_WORDS
    while gw > MIN_GROUP_WORDS and gw // 2 >= n_words:
        gw //= 2
    return gw


def _words_of(data: bytes, gw: int) -> np.ndarray:
    """Zero-padded little-endian uint32 words, grouped: [n_groups, gw]."""
    n_words = -(-len(data) // 4)
    n_groups = -(-n_words // gw) if n_words else 0
    buf = np.zeros(max(n_groups, 1) * gw * 4, np.uint8)
    buf[:len(data)] = np.frombuffer(data, np.uint8)
    return buf.view("<u4").reshape(-1, gw)[:n_groups]


def plane_split(groups: np.ndarray) -> np.ndarray:
    """Bitshuffle: uint32 [n_groups, gw] -> planes [n_groups, 32, gw//32].

    Bit ``k`` of plane word ``j`` in plane ``p`` is bit ``p`` of source word
    ``j*32 + k`` — identical packing to the device kernels."""
    ng, gw = groups.shape
    w = groups.reshape(ng, gw // 32, 32).astype("<u4")
    shifts = np.arange(32, dtype=np.uint32)
    planes = np.empty((ng, 32, gw // 32), dtype="<u4")
    for p in range(32):
        bits = (w >> np.uint32(p)) & np.uint32(1)
        planes[:, p, :] = np.bitwise_or.reduce(bits << shifts, axis=2)
    return planes


def plane_join(planes: np.ndarray) -> np.ndarray:
    """Inverse of :func:`plane_split`: planes [ng, 32, gw//32] -> words
    [ng, gw]."""
    ng, _, pw = planes.shape
    shifts = np.arange(32, dtype=np.uint32)
    words = np.zeros((ng, pw, 32), dtype="<u4")
    for p in range(32):
        bits = (planes[:, p, :, None] >> shifts) & np.uint32(1)
        words |= bits << np.uint32(p)
    return words.reshape(ng, pw * 32)


def classify_planes(planes: np.ndarray):
    """(stored_mask u32 [ng], ones_mask u32 [ng], store_flags bool [ng,32])."""
    zero = np.all(planes == 0, axis=2)
    ones = np.all(planes == _ALL_ONES, axis=2)
    store = ~zero & ~ones
    weights = (np.uint32(1) << np.arange(32, dtype=np.uint32))
    smask = np.bitwise_or.reduce(
        np.where(store, weights, np.uint32(0)), axis=1)
    omask = np.bitwise_or.reduce(
        np.where(ones, weights, np.uint32(0)), axis=1)
    return smask.astype("<u4"), omask.astype("<u4"), store


def payload_from_planes(smask: np.ndarray, omask: np.ndarray,
                        stored_planes: np.ndarray, gw: int,
                        raw_len: int) -> bytes:
    """Assemble one codec payload from classified planes (host or device
    produced — both emit the same (group, plane) stream)."""
    n_groups = int(smask.shape[0])
    hdr = _HDR.pack(_VERSION, _log2(gw), 0, n_groups, raw_len)
    masks = np.column_stack([smask, omask]).astype("<u4").tobytes()
    return hdr + masks + np.ascontiguousarray(
        stored_planes, dtype="<u4").tobytes()


def bitplane_compress(data: bytes, group_words: Optional[int] = None) -> bytes:
    """Pure-numpy encoder (the host rung of the ladder, and the reference
    the device kernels are tested against)."""
    data = bytes(data)
    gw = group_words or pick_group_words(-(-len(data) // 4))
    if gw < MIN_GROUP_WORDS or gw & (gw - 1):
        raise ValueError(f"group_words {gw}: need a power of two >= "
                         f"{MIN_GROUP_WORDS}")
    groups = _words_of(data, gw)
    planes = plane_split(groups)
    smask, omask, store = classify_planes(planes)
    return payload_from_planes(smask, omask, planes[store], gw, len(data))


def bitplane_decompress(payload: bytes) -> bytes:
    """Strict inverse of :func:`bitplane_compress` / the device encoder.
    Raises ValueError on any malformed payload (decode_chunk treats that as
    "not a frame" and returns the stored bytes verbatim)."""
    payload = bytes(payload)
    if len(payload) < HEADER_BYTES:
        raise ValueError("bitplane payload shorter than header")
    ver, log2_gw, pad, n_groups, raw_len = _HDR.unpack_from(payload)
    gw = 1 << log2_gw
    if ver != _VERSION or pad != 0 or gw < MIN_GROUP_WORDS \
            or gw > (GROUP_WORDS << 8):
        raise ValueError("bitplane payload: bad header")
    if raw_len > n_groups * gw * 4 or (n_groups == 0) != (raw_len == 0):
        raise ValueError("bitplane payload: raw_len out of range")
    masks_end = HEADER_BYTES + n_groups * 8
    if len(payload) < masks_end:
        raise ValueError("bitplane payload: truncated group headers")
    masks = np.frombuffer(payload, "<u4", count=n_groups * 2,
                          offset=HEADER_BYTES).reshape(n_groups, 2)
    counts = popcount_u32(masks[:, 0])
    total = int(counts.sum())
    pw = gw // 32
    if len(payload) != masks_end + total * pw * 4:
        raise ValueError("bitplane payload: plane stream length mismatch")
    flat = np.frombuffer(payload, "<u4", offset=masks_end).reshape(total, pw)

    planes = np.zeros((n_groups, 32, pw), dtype="<u4")
    shifts = np.arange(32, dtype=np.uint32)
    ones = ((masks[:, 1:2] >> shifts) & np.uint32(1)).astype(bool)
    planes[ones] = _ALL_ONES
    store = ((masks[:, 0:1] >> shifts) & np.uint32(1)).astype(bool)
    if np.any(store & ones):
        raise ValueError("bitplane payload: stored+ones plane conflict")
    planes[store] = flat
    words = plane_join(planes)
    return words.astype("<u4").tobytes()[:raw_len]


# ---------------------------------------------------------------------------
# sampled-incompressibility probe (host and device paths share the estimate)
# ---------------------------------------------------------------------------

def estimate_stored_fraction(words: np.ndarray) -> float:
    """Estimated fraction of bit-planes the codec would have to store, from
    a word sample: a plane whose bit differs anywhere in the sample cannot
    be all-zero or all-one.  Biased low (a plane constant in the sample may
    still vary per group) — cheap and good enough to skip the encode for
    already-compressed/random chunks."""
    w = np.ascontiguousarray(words, dtype="<u4").reshape(-1)
    if w.size == 0:
        return 0.0
    varying = np.bitwise_and.reduce(w) ^ np.bitwise_or.reduce(w)
    return float(popcount_u32(np.array([varying], "<u4"))[0]) / 32.0


def bitplane_probe(data: bytes, sample_words: int = 256,
                   threshold: float = PROBE_THRESHOLD) -> bool:
    """True when ``data`` looks worth bit-plane encoding.  Samples ~256
    words spread across the chunk; random/already-compressed data has every
    plane varying and is skipped without touching the full buffer."""
    if len(data) < PROBE_MIN_BYTES:
        return False
    n_words = len(data) // 4
    step = max(1, n_words // sample_words)
    sample = np.frombuffer(data, "<u4",
                           count=n_words)[::step][:sample_words]
    return estimate_stored_fraction(sample) < threshold


# ---------------------------------------------------------------------------
# frame assembly for device-encoded segments (kernels/delta_pack pipeline)
# ---------------------------------------------------------------------------

def make_frame(payload: bytes, raw_len: int) -> bytes:
    """Wrap a codec payload in the standard chunk frame (KZC1 | id |
    raw_len | payload) — byte-identical to ``chunkstore.encode_chunk`` with
    this codec, so any backend decodes it transparently on read."""
    return (FRAME_MAGIC + bytes([CODEC_ID])
            + int(raw_len).to_bytes(8, "little") + payload)


def frames_from_encoded(masks: np.ndarray, planes: np.ndarray,
                        groups_per_row: int, gw: int,
                        row_lens: Sequence[int]) -> List[bytes]:
    """Split a device-encoded segment (per-group masks + compacted plane
    stream, in row order) into one codec payload frame per row (= one
    chunk).  ``row_lens[r]`` is row r's logical byte length (raw_len)."""
    counts = popcount_u32(masks[:, 0])
    bounds = np.concatenate([[0], np.cumsum(counts)])
    out: List[bytes] = []
    for r, raw_len in enumerate(row_lens):
        g0, g1 = r * groups_per_row, (r + 1) * groups_per_row
        payload = payload_from_planes(
            masks[g0:g1, 0], masks[g0:g1, 1],
            planes[int(bounds[g0]):int(bounds[g1])], gw, int(raw_len))
        out.append(make_frame(payload, int(raw_len)))
    return out
