"""numpy-in / device-out wrappers for the on-device bit-plane encoder.

``encode_rows`` runs the device encode on a compacted dirty-chunk buffer
(one ``delta_pack`` segment) and returns the masks on host plus the plane
stream still *on device* — the caller overlaps its transfer with the next
segment's encode, mirroring ``DeltaPack.read_chunks``'s double buffering.

Row counts vary per commit, so rows are padded to the next power of two
before the jit'd encode — padded zero rows classify as all-zero planes and
contribute nothing to masks or the plane stream, and the compile cache
stays O(log max_rows) per (W, gw).
"""
from __future__ import annotations

import os
from typing import List, Tuple

import numpy as np

from repro.kernels.delta_codec import host

_AUTO_BACKEND: List[str] = []          # memoized first working backend
_MIN_ROW_PAD = 8


def device_codec_enabled() -> bool:
    """KISHU_DEVICE_CODEC: "0" disables, anything else (or unset) leaves
    the codec on whenever the device pack pipeline is engaged."""
    return os.environ.get("KISHU_DEVICE_CODEC", "1") != "0"


def group_words_for(width: int) -> int:
    """Device group size for a W-word chunk row: one group per row when the
    row fits a group, else the largest group that tiles the row."""
    return min(host.GROUP_WORDS, width)


def encode_rows(rows, *, backend: str = "pallas", interpret: bool = False):
    """Encode uint32 device ``rows`` [R, W] (R >= 1, W a power of two >=
    MIN_GROUP_WORDS).

    Returns (masks np.uint32 [R*gpr, 2], planes_dev [n_stored, gw//32]
    still on device, gw).  Only the masks (8 bytes/group) are materialized
    here; the caller transfers ``planes_dev`` when it is ready for it."""
    import jax.numpy as jnp

    r, w = int(rows.shape[0]), int(rows.shape[1])
    gw = group_words_for(w)
    if gw < host.MIN_GROUP_WORDS or w % gw:
        raise ValueError(f"row width {w} not codec-eligible")
    gpr = w // gw
    rp = max(_MIN_ROW_PAD, host.pow2ceil(r))
    if rp > r:                          # pad: bounded jit shape universe
        rows = jnp.zeros((rp, w), jnp.uint32).at[:r].set(rows)
    if backend == "pallas":
        from repro.kernels.delta_codec.kernel import codec_encode_pallas
        masks_d, _count, planes_d = codec_encode_pallas(
            rows, gw=gw, interpret=interpret)
    elif backend == "ref":
        from repro.kernels.delta_codec.ref import codec_encode_ref
        masks_d, _count, planes_d = codec_encode_ref(rows, gw=gw)
    else:
        raise ValueError(f"unknown codec backend {backend!r}")
    masks = np.asarray(masks_d)[: r * gpr].astype("<u4")
    n_stored = int(host.popcount_u32(masks[:, 0]).sum())
    return masks, planes_d[:n_stored], gw


def encode_rows_auto(rows):
    """encode_rows with the memoized pallas -> jnp-ref fallback ladder
    (same probe pattern as delta_pack / chunk_hash)."""
    if _AUTO_BACKEND:
        return encode_rows(rows, backend=_AUTO_BACKEND[0])
    last: Exception = RuntimeError("no codec backend")
    for backend in ("pallas", "ref"):
        try:
            out = encode_rows(rows, backend=backend)
            _AUTO_BACKEND.append(backend)
            return out
        except Exception as e:  # noqa: BLE001 — probe failures expected
            last = e
    raise last


def probe_device_rows(rows, max_rows: int = 4,
                      sample_words: int = 256) -> bool:
    """Device-side analogue of ``host.bitplane_probe``: pull a small word
    sample from the compacted buffer (a few hundred bytes over PCIe) and
    estimate whether the encode is worth launching at all."""
    r, w = int(rows.shape[0]), int(rows.shape[1])
    if r == 0:
        return False
    take = min(r, max_rows)
    step = max(1, (take * w) // sample_words)
    sample = np.asarray(rows[:take]).reshape(-1)[::step][:sample_words]
    return host.estimate_stored_fraction(sample) < host.PROBE_THRESHOLD
