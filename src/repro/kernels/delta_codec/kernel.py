"""Pallas TPU kernel: on-device bit-plane encode of the compacted buffer.

Runs immediately after ``delta_pack`` on the same device, turning the
compacted dirty-chunk buffer into the codec's plane stream *before* it
crosses PCIe — the host then assembles KZC1 frames (``host.py``) without
ever seeing the raw bytes.

Grid: one program per group (``gw`` words), sequential per core, so the
SMEM running counter is a legal cross-step accumulator — the same
compaction pattern as ``delta_pack``.  Each step streams one (1, gw) block
in, classifies its 32 bit-planes (all-zero / all-one / stored) with
unrolled OR/AND halving trees (no axis reductions — Mosaic-friendly), packs
stored planes into gw-bit bitmaps via a shift + OR-tree, and appends them
at the running position.

Outputs (group-major, plane-ascending — byte-identical stream to
``host.plane_split`` + compaction):
  masks   uint32 [n_groups, 2]        — (stored_mask, ones_mask)
  count   int32  [1, 1]               — total stored planes
  planes  uint32 [n_groups*32, gw/32] — stored planes compacted to the
                                        front; rows past ``count`` garbage

VMEM: one (1, gw) input block plus the whole planes buffer
(n_groups * 32 * gw/8 bytes = input_bytes) — callers reuse delta_pack's
segment bound, so a call never exceeds the segment budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _or_tree_rows(v: jax.Array) -> jax.Array:
    """OR-reduce v [rows, 1] -> scalar via an unrolled halving tree."""
    rows = v.shape[0]
    while rows > 1:
        half = rows // 2
        v = v[:half, :] | v[half:rows, :]
        rows = half
    return v[0, 0]


def _codec_encode_kernel(words_ref, masks_ref, count_ref, planes_ref,
                         cnt_ref):
    g = pl.program_id(0)

    @pl.when(g == 0)
    def _():
        cnt_ref[0] = 0                 # running stored-plane counter

    w = words_ref[...]                                   # (1, gw) uint32
    gw = w.shape[1]
    pw = gw // 32
    grouped = w.reshape(pw, 32)        # element [j, k] = word j*32 + k
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (pw, 32), 1)
    base = cnt_ref[0]
    off = jnp.int32(0)
    smask = jnp.uint32(0)
    omask = jnp.uint32(0)
    for p in range(32):                # unrolled: 32 static plane slots
        bits = (grouped >> jnp.uint32(p)) & jnp.uint32(1)
        v = bits << shifts             # (pw, 32): lane k carries bit k
        length = 32
        while length > 1:              # OR-tree pack -> bitmap word per row
            half = length // 2
            v = v[:, :half] | v[:, half:length]
            length = half
        packed = v                     # (pw, 1): plane p's gw-bit bitmap
        zero = _or_tree_rows(packed) == jnp.uint32(0)
        ones = _or_tree_rows(~packed) == jnp.uint32(0)
        store = jnp.logical_not(zero) & jnp.logical_not(ones)
        smask = smask | (store.astype(jnp.uint32) << jnp.uint32(p))
        omask = omask | (ones.astype(jnp.uint32) << jnp.uint32(p))

        @pl.when(store)
        def _(packed=packed, off=off):
            planes_ref[pl.ds(base + off, 1), :] = packed.reshape(1, pw)

        off = off + store.astype(jnp.int32)

    masks_ref[0, 0] = smask
    masks_ref[0, 1] = omask
    cnt_ref[0] = base + off
    count_ref[0, 0] = base + off       # last program leaves the total


@functools.partial(jax.jit, static_argnames=("gw", "interpret"))
def codec_encode_pallas(rows: jax.Array, *, gw: int,
                        interpret: bool = False):
    """rows: uint32 [R, W] with W % gw == 0, gw a power of two >= 32.

    Returns (masks [R*W//gw, 2] u32, count [1,1] i32,
    planes [R*W//gw*32, gw//32] u32) — same contract as
    :func:`ref.codec_encode_ref`."""
    r, w = rows.shape
    assert gw >= 32 and gw & (gw - 1) == 0, f"gw={gw}"
    assert w % gw == 0, (w, gw)
    gpr = w // gw
    ng = r * gpr
    pw = gw // 32
    return pl.pallas_call(
        _codec_encode_kernel,
        grid=(ng,),
        in_specs=[
            pl.BlockSpec((1, gw), lambda g: (g // gpr, g % gpr)),
        ],
        out_specs=[
            pl.BlockSpec((1, 2), lambda g: (g, 0)),
            pl.BlockSpec((1, 1), lambda g: (0, 0)),
            pl.BlockSpec((ng * 32, pw), lambda g: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((ng, 2), jnp.uint32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
            jax.ShapeDtypeStruct((ng * 32, pw), jnp.uint32),
        ],
        scratch_shapes=[pltpu.SMEM((1,), jnp.int32)],
        interpret=interpret,
    )(rows)
