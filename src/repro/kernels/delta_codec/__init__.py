"""On-device bitshuffle+RLE block codec for the delta_pack pipeline.

- ``host``   — pure-numpy encoder/decoder + frame assembly (no jax import);
               the format oracle, registered as chunkstore codec id 4.
- ``ref``    — jit-compiled jnp encoder, bit-identical plane stream.
- ``kernel`` — Pallas TPU encoder (interpret=True on CPU CI).
- ``ops``    — numpy-in/segment-out wrappers with auto backend probing.
"""
