"""jnp reference encoder for the bit-plane codec.

Same contract as :func:`kernel.codec_encode_pallas` and the same plane
stream as ``host.bitplane_compress`` — the compaction order (stable sort on
the negated store flags) matches the kernel's running-counter append order,
so device payloads are byte-identical to host payloads.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("gw",))
def codec_encode_ref(rows: jax.Array, *, gw: int):
    """Encode uint32 ``rows`` [R, W] (W % gw == 0) into bit-planes.

    Returns:
      masks  uint32 [R * W//gw, 2]  — (stored_mask, ones_mask) per group
      count  int32  [1, 1]          — number of stored planes
      planes uint32 [R * W//gw * 32, gw//32] — stored planes compacted to
                                      the front in (group, plane) order
    """
    r, w = rows.shape
    gpr = w // gw
    ng = r * gpr
    pw = gw // 32
    grouped = rows.reshape(ng, pw, 32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    per_plane = []
    for p in range(32):
        bits = (grouped >> jnp.uint32(p)) & jnp.uint32(1)
        per_plane.append(jnp.sum(bits << shifts, axis=2, dtype=jnp.uint32))
    planes = jnp.stack(per_plane, axis=1)                    # [ng, 32, pw]
    zero = jnp.all(planes == 0, axis=2)
    ones = jnp.all(planes == jnp.uint32(0xFFFFFFFF), axis=2)
    store = (~zero) & (~ones)                                # [ng, 32]
    smask = jnp.sum(jnp.where(store, jnp.uint32(1) << shifts, 0),
                    axis=1, dtype=jnp.uint32)
    omask = jnp.sum(jnp.where(ones, jnp.uint32(1) << shifts, 0),
                    axis=1, dtype=jnp.uint32)
    masks = jnp.stack([smask, omask], axis=1)
    flags = store.reshape(ng * 32)
    order = jnp.argsort(~flags, stable=True)                 # stored first
    buf = planes.reshape(ng * 32, pw)[order]
    count = jnp.sum(flags.astype(jnp.int32)).reshape(1, 1)
    return masks, count, buf
