"""Pallas TPU kernel: exact per-chunk dirty detection between two resident
arrays (the undo-path fast check — both versions in device memory, so a
bitwise compare is cheaper and exact vs hashing one side).

Grid: one program per chunk; streams (1, W) uint32 blocks of both inputs
HBM->VMEM, reduces `any(a != b)` on the VPU, writes one int32 flag.
Bandwidth-bound by design: 2 streams in, 4 bytes out per chunk.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _block_diff_kernel(a_ref, b_ref, out_ref):
    a = a_ref[...]                                    # (1, W) uint32
    b = b_ref[...]
    neq = (a != b).astype(jnp.int32)
    out_ref[0, 0] = jnp.max(neq)


@functools.partial(jax.jit, static_argnames=("interpret",))
def block_diff_pallas(a_words: jax.Array, b_words: jax.Array, *,
                      interpret: bool = False) -> jax.Array:
    """a/b: uint32 [n_chunks, W]. Returns int32 [n_chunks]."""
    assert a_words.shape == b_words.shape, (a_words.shape, b_words.shape)
    n_chunks, wsize = a_words.shape
    out = pl.pallas_call(
        _block_diff_kernel,
        grid=(n_chunks,),
        in_specs=[
            pl.BlockSpec((1, wsize), lambda i: (i, 0)),
            pl.BlockSpec((1, wsize), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_chunks, 1), jnp.int32),
        interpret=interpret,
    )(a_words, b_words)
    return out[:, 0]
