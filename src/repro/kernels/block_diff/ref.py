"""Pure-jnp oracle for the block-diff kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def block_diff_ref(a_words: jax.Array, b_words: jax.Array) -> jax.Array:
    """a/b: uint32 [n_chunks, W]. Returns int32 [n_chunks]: 1 iff any word
    differs in that chunk (exact bitwise compare)."""
    neq = (a_words != b_words).astype(jnp.int32)
    return jnp.max(neq, axis=1)
