from repro.kernels.block_diff.ops import block_diff
from repro.kernels.block_diff.ref import block_diff_ref

__all__ = ["block_diff", "block_diff_ref"]
