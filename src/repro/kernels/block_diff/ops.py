"""jit'd public wrapper for exact per-chunk diffing of two same-shape arrays."""
from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.block_diff.kernel import block_diff_pallas
from repro.kernels.block_diff.ref import block_diff_ref
from repro.kernels.chunk_hash.ops import _to_words


@functools.partial(jax.jit,
                   static_argnames=("chunk_bytes", "backend", "interpret"))
def block_diff(a: jax.Array, b: jax.Array, chunk_bytes: int = 1 << 18, *,
               backend: Literal["pallas", "ref"] = "pallas",
               interpret: bool = False) -> jax.Array:
    """int32 [n_chunks]: 1 iff chunk i of a and b differ bitwise.

    a and b must have identical shape/dtype (structure changes are detected
    before content compare — covariable.py).
    """
    assert a.shape == b.shape and a.dtype == b.dtype, "structure mismatch"
    assert chunk_bytes % 4 == 0 and chunk_bytes & (chunk_bytes - 1) == 0
    nbytes_total = a.size * np.dtype(a.dtype).itemsize
    wa, wb = _to_words(a), _to_words(b)
    wpc = chunk_bytes // 4
    n_chunks = max(-(-int(nbytes_total) // chunk_bytes), 1)
    pad = n_chunks * wpc - wa.shape[0]
    if pad:
        zeros = jnp.zeros((pad,), jnp.uint32)
        wa = jnp.concatenate([wa, zeros])
        wb = jnp.concatenate([wb, zeros])
    wa = wa.reshape(n_chunks, wpc)
    wb = wb.reshape(n_chunks, wpc)
    if backend == "pallas":
        return block_diff_pallas(wa, wb, interpret=interpret)
    return block_diff_ref(wa, wb)


_AUTO_BACKEND: list = []        # memoized working backend ([] = unprobed)


def dirty_chunks(a: jax.Array, b: jax.Array,
                 chunk_bytes: int = 1 << 18) -> np.ndarray:
    """Indices of chunks where ``a`` and ``b`` differ bitwise, as a host
    int array — the exact-compare entry point the delta pipeline wires in
    (Pallas kernel where it runs, jnp ref otherwise; memoized probe).
    Raises when neither backend works (callers compare on host)."""
    last_err: Exception = RuntimeError("no block_diff backend")
    for backend in _AUTO_BACKEND or ("pallas", "ref"):
        try:
            mask = block_diff(a, b, chunk_bytes, backend=backend)
        except Exception as e:  # noqa: BLE001 — backend unsupported here
            last_err = e
            continue
        _AUTO_BACKEND[:] = [backend]
        return np.nonzero(np.asarray(mask))[0]
    raise last_err
