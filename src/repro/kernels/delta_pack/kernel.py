"""Pallas TPU kernel: the fused on-device delta pipeline.

One pass over HBM per chunk does everything the checkpoint writer's
detection+extraction hot path needs:

  hash      — avalanche-mix + XOR-tree-reduce each (1, W) uint32 block into
              the 2x32-bit detection hash pair (same math as ``chunk_hash``;
              the spec lives in repro.core.hashing)
  diff      — compare the pair against the *previous* commit's hash pair for
              that chunk (prefetched alongside the data block)
  compact   — dirty chunks are appended, in chunk order, to a compacted
              output buffer at a running-counter position, so the caller
              transfers ``count`` rows device→host instead of the whole array

Grid: one program per chunk, executed sequentially per core (the TPU grid
contract), which makes the SMEM running counter a legal cross-step
accumulator — the standard Pallas compaction pattern.  Streams one (1, W)
block in, writes the (1, 2) hash pair, a dirty flag, the chunk's compacted
position (-1 when clean), and conditionally one (1, W) row of the compacted
buffer: bandwidth-bound at ~1 read stream + dirty-fraction write stream.

Outputs (in order):
  hashes  uint32 [n_chunks, 2]   — detection hash pairs (lane 0 = high word)
  dirty   int32  [n_chunks, 1]   — 1 iff the pair differs from ``prev``
  pos     int32  [n_chunks, 1]   — row of the chunk in the compacted buffer,
                                   -1 when clean
  count   int32  [1, 1]          — total dirty chunks (valid rows of ``buf``)
  buf     uint32 [n_chunks, W]   — compacted dirty chunks; rows past
                                   ``count`` are unwritten garbage

VMEM budget: the input block plus the *whole* compacted buffer are resident
(4*W + 4*n_chunks*W bytes) — ops.py bounds n_chunks per call by segmenting,
so a call never exceeds its VMEM budget regardless of array size.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.hashing import C1, C2, GOLDEN, SEEDS


def _xor_tree(v: jax.Array) -> jax.Array:
    """XOR-reduce v [1, W] -> scalar via an unrolled halving tree."""
    length = v.shape[1]
    while length > 1:
        half = length // 2
        v = v[:, :half] ^ v[:, half:length]
        length = half
    return v[0, 0]


def _delta_pack_kernel(words_ref, prev_ref, nbytes_ref,
                       hash_ref, dirty_ref, pos_ref, count_ref, buf_ref,
                       cnt_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _():
        cnt_ref[0] = 0                 # running compaction counter (SMEM
                                       # scratch persists across grid steps)

    w = words_ref[...]                                   # (1, W) uint32
    wsize = w.shape[1]
    idx = jax.lax.broadcasted_iota(jnp.uint32, (1, wsize), 1)
    nbytes = nbytes_ref[0, 0].astype(jnp.uint32)
    n_valid = (nbytes + 3) // 4          # padding words contribute zero
    lanes = []
    for lane, seed in enumerate(SEEDS):
        m = (w ^ (idx * jnp.uint32(GOLDEN) + jnp.uint32(seed))) * jnp.uint32(C1)
        m = m ^ (m >> 16)
        m = m * jnp.uint32(C2)
        m = m ^ (m >> 13)
        m = jnp.where(idx < n_valid, m, jnp.uint32(0))
        h = _xor_tree(m)
        h = (h ^ nbytes) * jnp.uint32(C1)
        h = h ^ (h >> 16)
        hash_ref[0, lane] = h
        lanes.append(h)

    dirty = (lanes[0] != prev_ref[0, 0]) | (lanes[1] != prev_ref[0, 1])
    d32 = dirty.astype(jnp.int32)
    dirty_ref[0, 0] = d32
    pos = cnt_ref[0]
    pos_ref[0, 0] = jnp.where(dirty, pos, -1)

    @pl.when(dirty)
    def _():
        # append this chunk's words at the next free compacted row; the
        # block is already in VMEM from the hash read — no second HBM fetch
        buf_ref[pl.ds(pos, 1), :] = w

    cnt_ref[0] = pos + d32
    count_ref[0, 0] = pos + d32        # last program leaves the total


@functools.partial(jax.jit, static_argnames=("interpret",))
def delta_pack_pallas(words: jax.Array, prev: jax.Array, nbytes: jax.Array,
                      *, interpret: bool = False):
    """words: uint32 [n_chunks, W] (W power of two); prev: uint32
    [n_chunks, 2] previous hash pairs; nbytes: int32 [n_chunks].

    Returns (hashes [n,2] u32, dirty [n,1] i32, pos [n,1] i32,
    count [1,1] i32, buf [n,W] u32)."""
    n_chunks, wsize = words.shape
    assert wsize & (wsize - 1) == 0, f"W={wsize} must be a power of two"
    assert prev.shape == (n_chunks, 2), (prev.shape, n_chunks)
    return pl.pallas_call(
        _delta_pack_kernel,
        grid=(n_chunks,),
        in_specs=[
            pl.BlockSpec((1, wsize), lambda i: (i, 0)),
            pl.BlockSpec((1, 2), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 2), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((n_chunks, wsize), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_chunks, 2), jnp.uint32),
            jax.ShapeDtypeStruct((n_chunks, 1), jnp.int32),
            jax.ShapeDtypeStruct((n_chunks, 1), jnp.int32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
            jax.ShapeDtypeStruct((n_chunks, wsize), jnp.uint32),
        ],
        scratch_shapes=[pltpu.SMEM((1,), jnp.int32)],
        interpret=interpret,
    )(words, prev, nbytes.reshape(-1, 1))
