"""jnp reference for the fused delta-pack kernel — the bit-exact oracle.

Same contract and output shapes as ``delta_pack_pallas`` but built from
plain jnp ops: hashes via :func:`repro.core.hashing.chunk_hashes_jnp`,
compaction via a stable argsort that moves dirty rows to the front in chunk
order.  Runs anywhere jax runs (the "jnp" rung of the fallback ladder) and
is what the Pallas kernel is tested against.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.hashing import chunk_hashes_jnp


@jax.jit
def delta_pack_ref(words: jax.Array, prev: jax.Array, nbytes: jax.Array):
    """words uint32 [n, W]; prev uint32 [n, 2]; nbytes int32 [n].

    Returns the kernel's 5-tuple: (hashes [n,2] u32, dirty [n,1] i32,
    pos [n,1] i32, count [1,1] i32, buf [n,W] u32).  Rows of ``buf`` past
    ``count`` hold clean chunks (the kernel leaves garbage there) — callers
    must only read the first ``count`` rows either way.
    """
    hashes = chunk_hashes_jnp(words, nbytes)
    dirty = jnp.any(hashes != prev, axis=1)
    d32 = dirty.astype(jnp.int32)
    cum = jnp.cumsum(d32)
    pos = jnp.where(dirty, cum - 1, -1).astype(jnp.int32)
    count = cum[-1:].astype(jnp.int32) if words.shape[0] else \
        jnp.zeros((1,), jnp.int32)
    # stable sort on ~dirty: dirty rows first, original chunk order kept
    order = jnp.argsort(~dirty, stable=True)
    buf = words[order]
    return (hashes, d32[:, None], pos[:, None], count[:, None], buf)
