"""Public wrapper for the fused on-device delta pipeline.

``delta_pack(x, prev_hashes, chunk_bytes)`` runs one fused pass (hash +
diff + compaction) over a device array and returns a :class:`DeltaPack`:
the new detection hashes, the dirty-chunk index vector, and handles to the
*compacted* dirty-chunk buffers still resident on device.  The checkpoint
writer then streams only the dirty rows host-side via
:meth:`DeltaPack.read_chunks`, double-buffered (``copy_to_host_async`` of
segment *i+1* is issued before segment *i*'s rows are consumed) so the
device→host DMA overlaps the backend ``put_chunks`` upload.

VMEM bounding: the kernel keeps its whole compacted output in VMEM, so the
wrapper segments the array into super-blocks of at most ``seg_bytes``
(default 4 MiB) chunks and launches one ``pallas_call`` per segment — at
most two jit shapes (full segments + the tail) regardless of array size.

Traffic accounting: ``bytes_transferred`` counts every byte this pack moved
device→host — 12 bytes/chunk of metadata (8 hash + 4 dirty flag) plus the
compacted rows actually materialized — the numerator of the detection
roofline in benchmarks/bench_device_delta.py.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Iterable, Iterator, List, Optional, Tuple

import numpy as np

from repro.core import hashing

DEFAULT_SEG_BYTES = 4 << 20      # compacted VMEM buffer bound per launch


def _obs_span(name: str, **args):
    """Span on the active SessionObs, or a no-op outside a session."""
    import contextlib
    try:
        from repro import obs as _obs
        o = _obs.active()
        if o is not None:
            return o.span(name, **args)
    except Exception:  # noqa: BLE001 — observability is best-effort
        pass
    return contextlib.nullcontext()


@dataclass
class _Seg:
    start: int                   # first chunk index covered by this segment
    stop: int
    dirty: np.ndarray            # global indices of dirty chunks, ascending
    buf: Any                     # device uint32 [len(dirty), W] compacted rows


@dataclass
class DeltaPack:
    """Result of one fused delta pass: detection hashes + dirty indices on
    host, compacted dirty-chunk buffers still on device."""
    nbytes: int
    chunk_bytes: int
    n_chunks: int
    hashes: np.ndarray           # uint64 [n_chunks] detection hashes
    dirty: np.ndarray            # ascending global dirty-chunk indices
    bytes_transferred: int = 0   # device→host bytes moved so far
    codec_chunks_encoded: int = 0    # chunks that crossed PCIe as frames
    codec_chunks_skipped: int = 0    # probe veto / frame larger than raw
    _segments: List[_Seg] = field(default_factory=list)

    @property
    def count(self) -> int:
        return int(self.dirty.size)

    @property
    def dirty_set(self) -> set:
        return set(int(i) for i in self.dirty)

    def _chunk_len(self, i: int) -> int:
        return min((i + 1) * self.chunk_bytes, self.nbytes) \
            - i * self.chunk_bytes

    def _plan(self, indices: Optional[Iterable[int]]
              ) -> List[Tuple[_Seg, List[int]]]:
        """Per-segment read plan for the requested dirty chunks."""
        want = sorted(set(int(i) for i in indices)) if indices is not None \
            else [int(i) for i in self.dirty]
        if not want:
            return []
        bad = [i for i in want if not (0 <= i < self.n_chunks)]
        assert not bad, f"chunk indices out of range: {bad[:4]}"
        plan: List[Tuple[_Seg, List[int]]] = []
        for seg in self._segments:
            sel = [i for i in want if seg.start <= i < seg.stop]
            if not sel:
                continue
            rowmap = {int(ci): r for r, ci in enumerate(seg.dirty)}
            missing = [i for i in sel if i not in rowmap]
            if missing:
                raise KeyError(f"chunks {missing[:4]} are not dirty in "
                               f"this pack")
            plan.append((seg, sel))
        return plan

    def read_chunks(self, indices: Optional[Iterable[int]] = None
                    ) -> Iterator[Tuple[int, bytes]]:
        """Yield ``(chunk_index, chunk_bytes)`` for the requested dirty
        chunks in ascending index order, moving only compacted rows.

        Double-buffered: before segment *i*'s rows are materialized (a
        blocking ``np.asarray``), segment *i+1*'s ``copy_to_host_async`` is
        already in flight — so while the caller hashes/uploads segment *i*'s
        chunks, the next segment's DMA proceeds in parallel.
        """
        plan = self._plan(indices)
        if plan:
            try:                    # prime the pipeline
                plan[0][0].buf.copy_to_host_async()
            except AttributeError:
                pass
        for k, (seg, sel) in enumerate(plan):
            if k + 1 < len(plan):
                try:                # overlap: next DMA behind this upload
                    plan[k + 1][0].buf.copy_to_host_async()
                except AttributeError:
                    pass
            host = np.asarray(seg.buf)          # blocks on this segment only
            self.bytes_transferred += host.nbytes
            rowmap = {int(ci): r for r, ci in enumerate(seg.dirty)}
            raw = host.view(np.uint8)
            for ci in sel:
                row = raw[rowmap[ci]]
                yield ci, row[: self._chunk_len(ci)].tobytes()

    def read_chunks_encoded(self, indices: Optional[Iterable[int]] = None
                            ) -> Iterator[Tuple[int, bytes,
                                                Optional[bytes]]]:
        """Like :meth:`read_chunks`, but compress each segment *on device*
        with the bit-plane codec (kernels/delta_codec) before it crosses
        PCIe: yields ``(chunk_index, logical_bytes, stored_frame)`` where
        ``stored_frame`` is a ready-to-store KZC1 frame (None when the
        chunk went raw — codec off, probe veto, or the frame would not
        save bytes).  Chunk keys stay logical-byte: the logical bytes are
        reconstructed host-side from the frame itself.

        Device→host traffic per segment is 8 bytes/group of masks plus only
        the *stored* planes — the compacted rows themselves never cross.
        A tiny word sample (a few hundred bytes) is pulled first to skip
        the encode entirely for incompressible data.
        """
        from repro.kernels.delta_codec import host as codec_host
        from repro.kernels.delta_codec import ops as codec_ops

        plan = self._plan(indices)
        if not plan:
            return
        width = self.chunk_bytes // 4
        engage = (codec_ops.device_codec_enabled()
                  and width >= codec_host.MIN_GROUP_WORDS)
        if engage:                      # sampled-incompressibility probe
            try:
                engage = codec_ops.probe_device_rows(plan[0][0].buf)
            except Exception:  # noqa: BLE001 — probe trouble: go raw
                engage = False
        if not engage:
            self.codec_chunks_skipped += sum(len(sel) for _, sel in plan)
            for ci, data in self.read_chunks(indices):
                yield ci, data, None
            return

        # phase 1: launch every segment's encode, overlap plane DMA
        enc: List[Optional[tuple]] = []
        for seg, _sel in plan:
            try:
                with _obs_span("encode_dev", rows=int(seg.dirty.size)):
                    masks, planes_dev, gw = codec_ops.encode_rows_auto(
                        seg.buf)
                try:
                    planes_dev.copy_to_host_async()
                except AttributeError:
                    pass
                enc.append((masks, planes_dev, gw))
            except Exception as e:  # noqa: BLE001 — encode degrades to raw
                from repro.core.delta import note_kernel_fallback
                note_kernel_fallback("codec_encode", e)
                enc.append(None)

        # phase 2: materialize plane streams, assemble per-chunk frames
        for k, (seg, sel) in enumerate(plan):
            if enc[k] is None:          # this segment degraded to raw
                host = np.asarray(seg.buf)
                self.bytes_transferred += host.nbytes
                self.codec_chunks_skipped += len(sel)
                rowmap = {int(ci): r for r, ci in enumerate(seg.dirty)}
                raw = host.view(np.uint8)
                for ci in sel:
                    row = raw[rowmap[ci]]
                    yield ci, row[: self._chunk_len(ci)].tobytes(), None
                continue
            masks, planes_dev, gw = enc[k]
            planes = np.asarray(planes_dev)     # blocks on this DMA only
            self.bytes_transferred += masks.nbytes + planes.nbytes
            gpr = width // gw
            frames = codec_host.frames_from_encoded(
                masks, planes, gpr, gw,
                [self._chunk_len(int(ci)) for ci in seg.dirty])
            rowmap = {int(ci): r for r, ci in enumerate(seg.dirty)}
            for ci in sel:
                frame = frames[rowmap[ci]]
                logical = codec_host.bitplane_decompress(
                    frame[codec_host._FRAME_HDR:])
                if len(frame) < len(logical):
                    self.codec_chunks_encoded += 1
                    yield ci, logical, frame
                else:                   # frame saves nothing: store raw
                    self.codec_chunks_skipped += 1
                    yield ci, logical, None


def delta_pack(x, prev_hashes, chunk_bytes: int = 1 << 18, *,
               backend: str = "pallas", interpret: bool = False,
               seg_bytes: int = DEFAULT_SEG_BYTES) -> DeltaPack:
    """Fused hash + diff + compaction of a device array against the previous
    commit's detection hashes.

    ``prev_hashes`` is uint64 [n_chunks] (the previous LeafRecord's
    ``base_hashes``); ``chunk_bytes`` must be a power-of-two multiple of 4.
    The returned hashes are bit-identical to ``hashing.chunk_hashes_np``.
    """
    import jax.numpy as jnp

    from repro.kernels.chunk_hash.ops import _to_words
    from repro.kernels.delta_pack.kernel import delta_pack_pallas
    from repro.kernels.delta_pack.ref import delta_pack_ref

    assert chunk_bytes % 4 == 0 and chunk_bytes & (chunk_bytes - 1) == 0
    nbytes_total = int(x.size) * np.dtype(x.dtype).itemsize
    if nbytes_total == 0:
        return DeltaPack(nbytes=0, chunk_bytes=chunk_bytes, n_chunks=0,
                         hashes=np.zeros((0,), np.uint64),
                         dirty=np.zeros((0,), np.int64))
    wpc = chunk_bytes // 4
    n_chunks = -(-nbytes_total // chunk_bytes)
    prev = np.asarray(prev_hashes, dtype=np.uint64).reshape(-1)
    assert prev.shape == (n_chunks,), (prev.shape, n_chunks)
    words = _to_words(x)
    pad = n_chunks * wpc - words.shape[0]
    if pad:
        words = jnp.concatenate([words, jnp.zeros((pad,), jnp.uint32)])
    words = words.reshape(n_chunks, wpc)
    prev32 = jnp.asarray(hashing.split_u64(prev))
    nb_np = np.minimum(
        np.full(n_chunks, chunk_bytes, np.int64),
        np.maximum(nbytes_total
                   - np.arange(n_chunks, dtype=np.int64) * chunk_bytes, 0)
    ).astype(np.int32)

    seg_chunks = max(1, seg_bytes // chunk_bytes)
    segs: List[_Seg] = []
    hash_parts: List[np.ndarray] = []
    dirty_parts: List[np.ndarray] = []
    moved = 0
    for s0 in range(0, n_chunks, seg_chunks):
        s1 = min(s0 + seg_chunks, n_chunks)
        fn = delta_pack_pallas if backend == "pallas" else delta_pack_ref
        kw = {"interpret": interpret} if backend == "pallas" else {}
        h, d, _pos, cnt, buf = fn(words[s0:s1], prev32[s0:s1],
                                  jnp.asarray(nb_np[s0:s1]), **kw)
        count = int(np.asarray(cnt)[0, 0])
        dflags = np.asarray(d).reshape(-1)
        hash_parts.append(np.asarray(h))
        moved += (s1 - s0) * 12 + 4          # hash pair + flag (+ count)
        gdirty = s0 + np.flatnonzero(dflags).astype(np.int64)
        assert gdirty.size == count, (gdirty.size, count)
        # trim to the valid compacted rows on device — only these rows ever
        # cross device→host (read_chunks)
        segs.append(_Seg(start=s0, stop=s1, dirty=gdirty, buf=buf[:count]))
        dirty_parts.append(gdirty)
    hashes = hashing.combine_u64(np.concatenate(hash_parts, axis=0))
    dirty = np.concatenate(dirty_parts) if dirty_parts else \
        np.zeros((0,), np.int64)
    return DeltaPack(nbytes=nbytes_total, chunk_bytes=chunk_bytes,
                     n_chunks=n_chunks, hashes=hashes, dirty=dirty,
                     bytes_transferred=moved, _segments=segs)


_AUTO_BACKEND: list = []        # memoized working backend ([] = unprobed)


def delta_pack_auto(x, prev_hashes, chunk_bytes: int = 1 << 18,
                    **kw) -> DeltaPack:
    """DeltaPack with backend auto-selection: the Pallas kernel where it
    runs (TPU), the jnp reference otherwise; raises only when neither works
    (callers then take the host path).  Probed once and memoized, like
    ``chunk_hash_u64_auto`` — this runs per leaf per commit."""
    last_err: Exception = RuntimeError("no delta_pack backend")
    for backend in _AUTO_BACKEND or ("pallas", "ref"):
        try:
            pack = delta_pack(x, prev_hashes, chunk_bytes,
                              backend=backend, **kw)
        except Exception as e:  # noqa: BLE001 — backend unsupported here
            last_err = e
            continue
        _AUTO_BACKEND[:] = [backend]
        return pack
    raise last_err
