"""Fused on-device delta pipeline: hash + diff + dirty-chunk compaction in
one Pallas pass over HBM (DESIGN.md §15)."""
from repro.kernels.delta_pack.kernel import delta_pack_pallas  # noqa: F401
from repro.kernels.delta_pack.ops import (DeltaPack, delta_pack,  # noqa: F401
                                          delta_pack_auto)
from repro.kernels.delta_pack.ref import delta_pack_ref  # noqa: F401
