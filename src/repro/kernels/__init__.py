"""Pallas TPU kernels.

Paper hot-spots (delta detection — §6.2's hash-based detection made the
primary mechanism in the TPU adaptation):

- ``chunk_hash``: per-chunk detection hashing at HBM bandwidth.
- ``block_diff``: exact per-chunk dirty-compare when both versions are
  device-resident (undo fast path).

Beyond-paper (perf hillclimb, EXPERIMENTS.md §Perf cell A):

- ``flash_attention``: tiled online-softmax attention (forward/prefill) —
  removes the S²-logit HBM traffic that dominates the roofline memory term
  for long-sequence cells.

Each kernel ships kernel.py (pl.pallas_call + explicit BlockSpec VMEM
tiling), ops.py (jit'd public wrapper) and ref.py (pure-jnp oracle); tests
sweep shapes/dtypes and assert agreement in interpret mode.
"""
from repro.kernels.chunk_hash import chunk_hash, chunk_hash_u64
from repro.kernels.block_diff import block_diff
from repro.kernels.flash_attention import flash_attention

__all__ = ["chunk_hash", "chunk_hash_u64", "block_diff", "flash_attention"]
