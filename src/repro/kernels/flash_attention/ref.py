"""Pure-jnp oracle for the flash-attention kernel (naive softmax attention)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True) -> jax.Array:
    """q,k,v: [B,S,H,hd] (same H — GQA broadcast happens in ops.py).
    Returns [B,S,H,hd], float32 accumulation, output in q.dtype."""
    scale = 1.0 / np.sqrt(q.shape[-1])
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    if causal:
        s = q.shape[1]
        mask = jnp.tril(jnp.ones((s, s), bool))
        logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(jnp.float32),
                     v.astype(jnp.float32))
    return out.astype(q.dtype)
