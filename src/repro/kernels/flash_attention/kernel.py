"""Pallas TPU flash-attention (forward): tiled online-softmax attention.

Addresses the dominant roofline term found in §Perf cell A: naive attention
materializes S² logits to HBM; this kernel keeps the [BQ, BK] score tile and
the [BQ, hd] accumulator in VMEM, streaming K/V blocks — HBM traffic drops
from O(S²·H) to O(S·hd·H·S/BK) (the K/V re-reads), a ~BK/3 reduction.

Grid: (B·Hq, S/BQ, S/BK) with the K dimension innermost; running max /
normalizer / accumulator live in VMEM scratch across K iterations
(initialized at ik==0, output written at the last K block).  Causal blocks
strictly above the diagonal are skipped via pl.when; partial blocks mask in
f32 with -1e30 (finite: avoids -inf NaN propagation through the online
update).  GQA is handled in the K/V index maps (query-head -> kv-head), so
KV blocks are never materially repeated.

VMEM budget at BQ=BK=512, hd<=256: scores 1 MB f32 + q/k/v tiles ~0.8 MB
+ acc 0.5 MB — comfortably inside 16 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, block_q: int, block_k: int,
                  n_k: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _update():
        q = q_ref[0].astype(jnp.float32)              # [BQ, hd]
        k = k_ref[0].astype(jnp.float32)              # [BK, hd]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = iq * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            kpos = ik * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            valid = qpos >= kpos
            s = jnp.where(valid, s, NEG_INF)
        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        if causal:
            p = jnp.where(valid, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = corr * l_prev + jnp.sum(p, axis=1)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    if causal:
        # skip K blocks strictly above the causal diagonal
        pl.when(ik * block_k <= iq * block_q + block_q - 1)(_update)
    else:
        _update()

    @pl.when(ik == n_k - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "block_q", "block_k", "n_rep", "interpret"))
def flash_attention_bhsd(q, k, v, *, causal: bool = True,
                         block_q: int = 512, block_k: int = 512,
                         n_rep: int = 1, interpret: bool = False):
    """q: [BHq, S, hd]; k,v: [BHkv, S, hd] with BHq = BHkv * n_rep.
    Returns [BHq, S, hd]."""
    bh, s, hd = q.shape
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    n_q = s // block_q
    n_k = s // block_k
    scale = 1.0 / np.sqrt(hd)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal,
        block_q=block_q, block_k=block_k, n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid=(bh, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, iq, ik: (b, iq, 0)),
            pl.BlockSpec((1, block_k, hd),
                         lambda b, iq, ik, _r=n_rep: (b // _r, ik, 0)),
            pl.BlockSpec((1, block_k, hd),
                         lambda b, iq, ik, _r=n_rep: (b // _r, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, iq, ik: (b, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, hd), q.dtype),
        scratch_shapes=[
            _vmem((block_q,), jnp.float32),       # running max
            _vmem((block_q,), jnp.float32),       # running normalizer
            _vmem((block_q, hd), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)
