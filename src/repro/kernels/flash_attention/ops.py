"""jit'd public wrapper: [B,S,H,hd] GQA flash attention (forward/prefill).

Handles layout ([B,S,H,hd] <-> [B*H,S,hd]), GQA head-group mapping via the
kernel's K/V index maps (no materialized repeat), and the ref dispatch.
Forward-only: the training path keeps XLA attention (a Pallas backward is
future work; see EXPERIMENTS.md §Perf kernel note).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_bhsd
from repro.kernels.flash_attention.ref import flash_attention_ref


@functools.partial(jax.jit, static_argnames=(
    "causal", "block_q", "block_k", "backend", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, block_q: int = 512,
                    block_k: int = 512, backend: str = "pallas",
                    interpret: bool = False) -> jax.Array:
    """q: [B,S,Hq,hd]; k,v: [B,S,Hkv,hd] (Hq % Hkv == 0) -> [B,S,Hq,hd]."""
    b, s, hq, hd = q.shape
    hkv = k.shape[2]
    assert hq % hkv == 0, (hq, hkv)
    n_rep = hq // hkv
    if backend == "ref":
        if n_rep > 1:
            k = jnp.repeat(k, n_rep, axis=2)
            v = jnp.repeat(v, n_rep, axis=2)
        return flash_attention_ref(q, k, v, causal=causal)

    qf = q.transpose(0, 2, 1, 3).reshape(b * hq, s, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * hkv, s, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * hkv, s, hd)
    of = flash_attention_bhsd(qf, kf, vf, causal=causal, block_q=block_q,
                              block_k=block_k, n_rep=n_rep,
                              interpret=interpret)
    return of.reshape(b, hq, s, hd).transpose(0, 2, 1, 3)
