"""Fused device-scatter checkout: patch every dirty chunk of a co-variable
into the live device array in one Pallas pass.

- ``kernel`` — scalar-prefetch scatter with input/output aliasing.
- ``ref``    — jit-compiled ``words.at[idx].set(rows)`` reference.
- ``ops``    — bytes-in wrappers (word bitcasts, padding, auto probe).
"""
