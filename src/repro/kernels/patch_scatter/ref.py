"""jnp reference for the fused chunk scatter: one XLA scatter call, same
contract as :func:`kernel.patch_scatter_pallas`.  Duplicate indices (row
padding repeats row 0 / idx 0) write identical data, so the order XLA picks
is immaterial."""
from __future__ import annotations

import jax
import jax.numpy as jnp


@jax.jit
def patch_scatter_ref(words: jax.Array, idx: jax.Array,
                      rows: jax.Array) -> jax.Array:
    """words u32 [C, W]; idx i32 [K]; rows u32 [K, W] ->
    words with words[idx[k]] = rows[k]."""
    return words.at[idx, :].set(rows)
