"""bytes-in wrappers for the fused device scatter.

``scatter_chunks`` takes the live device array, the dirty chunk indices and
their raw chunk bytes (as fetched from the store, already decoded to
logical bytes), uploads ONE compacted [K, W] uint32 buffer + index vector,
and lands every chunk in a single kernel pass.  The inverse bitcasts
(``_from_words``) mirror ``chunk_hash.ops._to_words`` exactly, so the
round-trip is bit-identical for every supported dtype.

K varies per checkout, so rows/idx are padded to the next power of two by
repeating row 0 (idempotent duplicate writes) — the jit cache stays
O(log max_rows) per (C, W).
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np

from repro.kernels.delta_codec.host import pow2ceil

_AUTO_BACKEND: List[str] = []          # memoized first working backend


def _from_words(words, dtype, shape):
    """Inverse of ``chunk_hash.ops._to_words``: uint32 device words back to
    an array of ``dtype``/``shape`` (little-endian lane order)."""
    import jax
    import jax.numpy as jnp

    dt = np.dtype(dtype)
    n = 1
    for s in shape:
        n *= int(s)
    item = dt.itemsize
    nw = -(-n * item // 4)
    w = words[:nw]
    if dt.kind == "c":
        if item != 8:
            raise TypeError(f"unsupported complex itemsize {item}")
        f = jax.lax.bitcast_convert_type(w, jnp.float32)
        out = jax.lax.complex(f[0::2], f[1::2])
    elif item == 4:
        out = jax.lax.bitcast_convert_type(w, dt)
    elif item == 8:
        out = jax.lax.bitcast_convert_type(w.reshape(-1, 2), dt)
    elif item == 2:
        u = jax.lax.bitcast_convert_type(w, jnp.uint16).reshape(-1)[:n]
        out = jax.lax.bitcast_convert_type(u, dt) \
            if dt != np.dtype(np.uint16) else u
    elif item == 1:
        u = jax.lax.bitcast_convert_type(w, jnp.uint8).reshape(-1)[:n]
        if dt == np.dtype(bool):
            out = u.astype(jnp.bool_)
        elif dt == np.dtype(np.uint8):
            out = u
        else:
            out = jax.lax.bitcast_convert_type(u, dt)
    else:
        raise TypeError(f"unsupported itemsize {item} for dtype {dt}")
    return out.reshape(shape)


def _rows_from_blobs(blobs: Sequence[bytes], width: int) -> np.ndarray:
    """Pack per-chunk logical bytes into a [K, width] uint32 row buffer
    (zero-padded tail — pad bits land past raw_len and are dropped by
    ``_from_words``'s element slice)."""
    rows = np.zeros((len(blobs), width * 4), np.uint8)
    for r, blob in enumerate(blobs):
        b = np.frombuffer(blob, np.uint8)
        rows[r, :b.size] = b
    return rows.view("<u4").reshape(len(blobs), width)


def scatter_chunks(x, idx: Sequence[int], blobs: Sequence[bytes],
                   chunk_bytes: int, *, backend: str = "pallas",
                   interpret: bool = False) -> Tuple[object, int]:
    """Patch chunks ``idx`` of device array ``x`` with ``blobs`` in one
    fused pass.

    Returns (patched array, bytes moved host->device).  Raises on any
    contract violation — callers fall back to the per-chunk
    ``dynamic_update_slice`` ladder."""
    import jax.numpy as jnp

    from repro.kernels.chunk_hash.ops import _to_words

    if chunk_bytes <= 0 or chunk_bytes % 4:
        raise ValueError(f"chunk_bytes {chunk_bytes} not word-aligned")
    if not blobs:
        return x, 0
    width = chunk_bytes // 4
    nbytes = x.size * np.dtype(x.dtype).itemsize
    n_chunks = max(-(-int(nbytes) // chunk_bytes), 1)
    words = _to_words(x)
    pad = n_chunks * width - words.shape[0]
    if pad:
        words = jnp.concatenate([words, jnp.zeros((pad,), jnp.uint32)])
    words = words.reshape(n_chunks, width)

    k = len(blobs)
    idx_np = np.asarray(idx, np.int32)
    if idx_np.shape != (k,) or idx_np.min() < 0 or idx_np.max() >= n_chunks:
        raise ValueError("chunk indices out of range")
    rows = _rows_from_blobs(blobs, width)
    # pow2 padding alone bounds the jit cache to O(log max_rows) per
    # (C, W); a higher floor would inflate the PCIe upload at small K
    kp = pow2ceil(k)
    if kp > k:                          # idempotent duplicates of row 0
        idx_np = np.concatenate([idx_np, np.full(kp - k, idx_np[0],
                                                 np.int32)])
        rows = np.concatenate([rows, np.repeat(rows[:1], kp - k, axis=0)])
    moved = rows.nbytes + idx_np.nbytes
    idx_d = jnp.asarray(idx_np)
    rows_d = jnp.asarray(rows)
    if backend == "pallas":
        from repro.kernels.patch_scatter.kernel import patch_scatter_pallas
        out = patch_scatter_pallas(words, idx_d, rows_d,
                                   interpret=interpret)
    elif backend == "ref":
        from repro.kernels.patch_scatter.ref import patch_scatter_ref
        out = patch_scatter_ref(words, idx_d, rows_d)
    else:
        raise ValueError(f"unknown scatter backend {backend!r}")
    return _from_words(out.reshape(-1), x.dtype, x.shape), moved


def scatter_chunks_auto(x, idx, blobs, chunk_bytes: int):
    """scatter_chunks with the memoized pallas -> jnp-ref fallback ladder."""
    if _AUTO_BACKEND:
        return scatter_chunks(x, idx, blobs, chunk_bytes,
                              backend=_AUTO_BACKEND[0])
    last: Exception = RuntimeError("no scatter backend")
    for backend in ("pallas", "ref"):
        try:
            out = scatter_chunks(x, idx, blobs, chunk_bytes,
                                 backend=backend)
            _AUTO_BACKEND.append(backend)
            return out
        except Exception as e:  # noqa: BLE001 — probe failures expected
            last = e
    raise last
