"""Pallas TPU kernel: scatter compacted dirty rows into a chunked array.

The checkout mirror of ``delta_pack``'s compaction: the host uploads the
K dirty chunks of a co-variable as one compacted [K, W] buffer (plus a K
int32 row->chunk index vector) and a single pass lands every row at its
chunk slot — replacing the per-chunk ``dynamic_update_slice`` loop, whose
K separate dispatches each copy the whole array.

Grid: one program per dirty row.  The chunk index vector rides in as a
scalar-prefetch operand (``PrefetchScalarGridSpec``), so the *output*
BlockSpec can be data-dependent: program k maps its (1, W) output block to
chunk ``idx[k]``.  The output aliases the input array
(``input_output_aliases``), so blocks no program touches keep their
original contents — only ``K * W * 4`` bytes move, not ``C * W * 4``.

Duplicate indices are allowed only when they carry identical rows (the ops
layer pads K to a power of two by repeating row 0) — the grid is
sequential per core, so the last write wins deterministically anyway.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scatter_kernel(idx_ref, words_ref, rows_ref, out_ref):
    del idx_ref, words_ref                 # routing happens in the BlockSpecs
    out_ref[...] = rows_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",), donate_argnums=(0,))
def patch_scatter_pallas(words: jax.Array, idx: jax.Array, rows: jax.Array,
                         *, interpret: bool = False) -> jax.Array:
    """words u32 [C, W]; idx i32 [K] (values in [0, C)); rows u32 [K, W].

    Returns words with words[idx[k]] = rows[k]; untouched chunks preserved
    via output aliasing."""
    c, w = words.shape
    k, wr = rows.shape
    assert wr == w, (wr, w)
    assert idx.shape == (k,), (idx.shape, k)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(k,),
        in_specs=[
            pl.BlockSpec((1, w), lambda i, idx_ref: (idx_ref[i], 0)),
            pl.BlockSpec((1, w), lambda i, idx_ref: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, w), lambda i, idx_ref: (idx_ref[i], 0)),
    )
    return pl.pallas_call(
        _scatter_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((c, w), jnp.uint32),
        input_output_aliases={1: 0},       # words (first non-scalar) -> out
        interpret=interpret,
    )(idx, words, rows)
