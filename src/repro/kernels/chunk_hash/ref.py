"""Pure-jnp oracle for the chunk-hash kernel.

Delegates to the canonical spec in ``repro.core.hashing`` so the Pallas
kernel, this oracle, and the host NumPy path are provably the same function
(tested bit-for-bit in tests/test_kernels_chunk_hash.py).
"""
from __future__ import annotations

import jax

from repro.core.hashing import chunk_hashes_jnp


def chunk_hash_ref(words: jax.Array, nbytes: jax.Array) -> jax.Array:
    """words: uint32 [n_chunks, W]; nbytes: int32 [n_chunks]
    -> uint32 [n_chunks, 2]."""
    return chunk_hashes_jnp(words, nbytes)
