"""Pallas TPU kernel: per-chunk detection hash at HBM bandwidth.

Grid: one program per chunk.  Each program streams one chunk of uint32 words
HBM->VMEM (BlockSpec (1, W)), avalanche-mixes every word with its position
(pure VPU ops: xor/mul/shift), XOR-tree-reduces, folds in the true byte
length, and writes a (1, 2) uint32 hash pair.

The XOR reduction is an unrolled log2(W) halving tree — no sequential
dependency, unlike FNV — which is exactly why this hash was chosen for the
TPU adaptation (DESIGN.md §4).  W must be a power of two; ops.py pads.

VMEM budget: one (1, W) uint32 block = 4*W bytes; the default W=65536
(256 KiB chunks) uses 256 KiB of VMEM plus negligible intermediates.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.hashing import C1, C2, GOLDEN, SEEDS


def _xor_tree(v: jax.Array) -> jax.Array:
    """XOR-reduce v [1, W] -> scalar via an unrolled halving tree."""
    length = v.shape[1]
    while length > 1:
        half = length // 2
        v = v[:, :half] ^ v[:, half:length]
        length = half
    return v[0, 0]


def _chunk_hash_kernel(words_ref, nbytes_ref, out_ref):
    w = words_ref[...]                                   # (1, W) uint32
    wsize = w.shape[1]
    idx = jax.lax.broadcasted_iota(jnp.uint32, (1, wsize), 1)
    nbytes = nbytes_ref[0, 0].astype(jnp.uint32)
    n_valid = (nbytes + 3) // 4          # padding words contribute zero
    for lane, seed in enumerate(SEEDS):
        m = (w ^ (idx * jnp.uint32(GOLDEN) + jnp.uint32(seed))) * jnp.uint32(C1)
        m = m ^ (m >> 16)
        m = m * jnp.uint32(C2)
        m = m ^ (m >> 13)
        m = jnp.where(idx < n_valid, m, jnp.uint32(0))
        h = _xor_tree(m)
        h = (h ^ nbytes) * jnp.uint32(C1)
        h = h ^ (h >> 16)
        out_ref[0, lane] = h


@functools.partial(jax.jit, static_argnames=("interpret",))
def chunk_hash_pallas(words: jax.Array, nbytes: jax.Array, *,
                      interpret: bool = False) -> jax.Array:
    """words: uint32 [n_chunks, W] (W power of two); nbytes: int32 [n_chunks].
    Returns uint32 [n_chunks, 2]."""
    n_chunks, wsize = words.shape
    assert wsize & (wsize - 1) == 0, f"W={wsize} must be a power of two"
    return pl.pallas_call(
        _chunk_hash_kernel,
        grid=(n_chunks,),
        in_specs=[
            pl.BlockSpec((1, wsize), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_chunks, 2), jnp.uint32),
        interpret=interpret,
    )(words, nbytes.reshape(-1, 1))
