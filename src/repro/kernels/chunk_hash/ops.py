"""jit'd public wrapper for on-device chunk hashing.

Handles arbitrary array dtypes/shapes: bitcasts to uint32 words (with
zero-padding), reshapes into [n_chunks, W], dispatches to the Pallas kernel
(TPU; interpret-mode on CPU) or the jnp oracle, and packs the two 32-bit
lanes into uint64 detection hashes identical to
``repro.core.hashing.chunk_hashes_np``.
"""
from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashing
from repro.kernels.chunk_hash.kernel import chunk_hash_pallas
from repro.kernels.chunk_hash.ref import chunk_hash_ref


def _to_words(x: jax.Array) -> jax.Array:
    """Flatten + bitcast any-dtype array to uint32 words (little-endian)."""
    flat = x.reshape(-1)
    item = np.dtype(x.dtype).itemsize
    if item == 4:
        return jax.lax.bitcast_convert_type(flat, jnp.uint32)
    if item == 8:
        w = jax.lax.bitcast_convert_type(flat, jnp.uint32)   # [..., 2]
        return w.reshape(-1)
    if item == 2:
        u = jax.lax.bitcast_convert_type(flat, jnp.uint16).astype(jnp.uint32)
        if u.shape[0] % 2:
            u = jnp.concatenate([u, jnp.zeros((1,), jnp.uint32)])
        u = u.reshape(-1, 2)
        return u[:, 0] | (u[:, 1] << 16)
    if item == 1:
        u = jax.lax.bitcast_convert_type(flat, jnp.uint8).astype(jnp.uint32)
        pad = (-u.shape[0]) % 4
        if pad:
            u = jnp.concatenate([u, jnp.zeros((pad,), jnp.uint32)])
        u = u.reshape(-1, 4)
        return u[:, 0] | (u[:, 1] << 8) | (u[:, 2] << 16) | (u[:, 3] << 24)
    raise TypeError(f"unsupported itemsize {item} for dtype {x.dtype}")


@functools.partial(jax.jit,
                   static_argnames=("chunk_bytes", "backend", "interpret"))
def chunk_hash(x: jax.Array, chunk_bytes: int = 1 << 18, *,
               backend: Literal["pallas", "ref"] = "pallas",
               interpret: bool = False) -> jax.Array:
    """Per-chunk detection hashes of an on-device array.

    Returns uint32 [n_chunks, 2].  ``chunk_bytes`` must be a power of two
    multiple of 4.
    """
    assert chunk_bytes % 4 == 0 and chunk_bytes & (chunk_bytes - 1) == 0
    nbytes_total = x.size * np.dtype(x.dtype).itemsize
    words = _to_words(x)
    wpc = chunk_bytes // 4
    n_chunks = max(-(-int(nbytes_total) // chunk_bytes), 1)
    pad = n_chunks * wpc - words.shape[0]
    if pad:
        words = jnp.concatenate([words, jnp.zeros((pad,), jnp.uint32)])
    words = words.reshape(n_chunks, wpc)
    # per-chunk true byte counts (host math in int64: sizes can exceed int32)
    nbytes = jnp.asarray(np.minimum(
        np.full(n_chunks, chunk_bytes, np.int64),
        np.maximum(int(nbytes_total)
                   - np.arange(n_chunks, dtype=np.int64) * chunk_bytes, 0)
    ).astype(np.int32))
    if backend == "pallas":
        return chunk_hash_pallas(words, nbytes, interpret=interpret)
    return chunk_hash_ref(words, nbytes)


def chunk_hash_u64(x, chunk_bytes: int = 1 << 18, *,
                   backend: str = "pallas", interpret: bool = False
                   ) -> np.ndarray:
    """Host-side convenience: uint64 [n_chunks], matching chunk_hashes_np."""
    lanes = np.asarray(chunk_hash(x, chunk_bytes, backend=backend,
                                  interpret=interpret))
    return hashing.combine_u64(lanes)


_AUTO_BACKEND: list = []        # memoized working backend ([] = unprobed)


def chunk_hash_u64_auto(x, chunk_bytes: int = 1 << 18) -> np.ndarray:
    """uint64 detection hashes with backend auto-selection: the Pallas
    kernel where it runs (TPU), the jnp oracle otherwise; raises only when
    neither works (callers then hash on host).  The working backend is
    probed once and memoized — the delta pipeline calls this per leaf per
    commit, so repeated exception-driven probing would dominate."""
    last_err: Exception = RuntimeError("no chunk_hash backend")
    for backend in _AUTO_BACKEND or ("pallas", "ref"):
        try:
            h = chunk_hash_u64(x, chunk_bytes, backend=backend)
        except Exception as e:  # noqa: BLE001 — backend unsupported here
            last_err = e
            continue
        _AUTO_BACKEND[:] = [backend]
        return h
    raise last_err


def device_hasher(chunk_bytes: int = 1 << 18, *, backend: str = "pallas",
                  interpret: bool = False):
    """Adapter for RecordBuilder(hasher=...): on-device detection hashing.

    Accepts the bytes/uint8-view the builder passes and returns uint64
    [n_chunks] — the TPU path for delta detection.
    """
    def _hash(buf, cb=None):
        arr = np.frombuffer(buf, dtype=np.uint8) if isinstance(
            buf, (bytes, bytearray, memoryview)) else np.asarray(buf)
        return chunk_hash_u64(jnp.asarray(arr), cb or chunk_bytes,
                              backend=backend, interpret=interpret)
    return _hash
