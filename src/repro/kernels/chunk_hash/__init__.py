from repro.kernels.chunk_hash.ops import chunk_hash, chunk_hash_u64
from repro.kernels.chunk_hash.ref import chunk_hash_ref

__all__ = ["chunk_hash", "chunk_hash_u64", "chunk_hash_ref"]
