"""Pallas block_diff kernel vs oracle: exact dirty-chunk detection."""
import numpy as np
import jax.numpy as jnp
import pytest

from _hypothesis_compat import given, settings, st

from repro.kernels.block_diff import block_diff

pytestmark = pytest.mark.slow    # JAX jit-heavy; fast lane: -m "not slow"

CB = 1 << 12


@pytest.mark.parametrize("dtype", [np.float32, np.float16, np.int8])
@pytest.mark.parametrize("n", [16, 1024, 4096, 10000])
def test_identical_arrays_clean(dtype, n):
    x = np.random.default_rng(0).standard_normal(n).astype(dtype)
    d = block_diff(jnp.asarray(x), jnp.asarray(x.copy()), CB,
                   backend="pallas", interpret=True)
    assert int(np.max(np.asarray(d))) == 0


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=1, max_value=20000),
       st.integers(min_value=0, max_value=19999))
def test_single_element_flip_detected_in_right_chunk(n, pos):
    pos = pos % n
    a = np.zeros(n, np.float32)
    b = a.copy()
    b[pos] = 1.0
    got = np.asarray(block_diff(jnp.asarray(a), jnp.asarray(b), CB,
                                backend="pallas", interpret=True))
    want = np.asarray(block_diff(jnp.asarray(a), jnp.asarray(b), CB,
                                 backend="ref"))
    assert np.array_equal(got, want)
    chunk = (pos * 4) // CB
    assert got[chunk] == 1 and got.sum() == 1


def test_multi_chunk_dirty():
    a = np.zeros(CB, np.float32)        # 4 chunks of CB bytes
    b = a.copy()
    b[0] = 1; b[-1] = 1
    got = np.asarray(block_diff(jnp.asarray(a), jnp.asarray(b), CB,
                                backend="pallas", interpret=True))
    assert got.tolist() == [1, 0, 0, 1]


def test_structure_mismatch_rejected():
    with pytest.raises(AssertionError):
        block_diff(jnp.zeros(4), jnp.zeros(5), CB)
