"""Fused delta_pack kernel + pipeline wiring tests (fast lane).

Covers the kernel contract (hashes / dirty vector / compacted buffer) on
both backends in interpret mode, VMEM segmenting, the env gate, the
fallback-counter observability satellite, and the end-to-end guarantee:
a jax session on the fused path produces bit-identical checkpoints (same
states, same content-addressed chunk keys) as the host path.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import delta as delta_mod
from repro.core import hashing as H
from repro.kernels.delta_pack.ops import DeltaPack, delta_pack

BACKENDS = [("ref", {}), ("pallas", {"interpret": True})]


def _mk(nbytes, cb, dirty_chunks, seed=0):
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 256, nbytes, dtype=np.uint8)
    prev = H.chunk_hashes_np(a.tobytes(), cb)
    b = a.copy()
    for i in dirty_chunks:
        b[i * cb] ^= 0xFF
    return a, b, prev


@pytest.mark.parametrize("backend,kw", BACKENDS)
@pytest.mark.parametrize("nbytes,cb,dirty", [
    (4096 * 4, 1024, [0, 3, 7]),
    (4096 * 3 + 7, 1024, [0, 12]),       # odd tail, dirty last chunk region
    (17, 8, [1]),                        # sub-word tail
    (600, 1024, [0]),                    # single chunk, chunk_bytes > nbytes
])
def test_pack_contract(backend, kw, nbytes, cb, dirty):
    a, b, prev = _mk(nbytes, cb, dirty)
    pack = delta_pack(jnp.asarray(b), prev, cb, backend=backend, **kw)
    n_chunks = -(-nbytes // cb)
    assert pack.n_chunks == n_chunks and pack.nbytes == nbytes
    assert np.array_equal(pack.hashes,
                          H.chunk_hashes_np(b.tobytes(), cb))
    want_dirty = sorted(set(min(i, n_chunks - 1) for i in dirty))
    assert list(pack.dirty) == want_dirty
    got = dict(pack.read_chunks())
    assert sorted(got) == want_dirty
    for i, data in got.items():
        lo, hi = i * cb, min((i + 1) * cb, nbytes)
        assert data == b[lo:hi].tobytes()


@pytest.mark.parametrize("backend,kw", BACKENDS)
def test_pack_segmenting(backend, kw):
    """A tiny seg_bytes forces many pallas_call segments; compaction and
    chunk indexing must stay global across segment boundaries."""
    nbytes, cb = 64 * 256, 256           # 64 chunks
    dirty = [0, 1, 31, 32, 63]           # straddle every segment edge
    _, b, prev = _mk(nbytes, cb, dirty, seed=3)
    pack = delta_pack(jnp.asarray(b), prev, cb, backend=backend,
                      seg_bytes=4 * 256, **kw)     # 4 chunks per segment
    assert len(pack._segments) == 16
    assert list(pack.dirty) == dirty
    for i, data in pack.read_chunks():
        assert data == b[i * cb:(i + 1) * cb].tobytes()
    # partial reads hit only the owning segments
    sub = dict(pack.read_chunks([31, 63]))
    assert sorted(sub) == [31, 63]
    with pytest.raises(KeyError):
        list(pack.read_chunks([2]))      # clean chunk: not in the pack


def test_pack_transfer_accounting():
    nbytes, cb = 8 * 512, 512
    _, b, prev = _mk(nbytes, cb, [2], seed=5)
    pack = delta_pack(jnp.asarray(b), prev, cb, backend="ref")
    base = pack.bytes_transferred
    assert base == 8 * 12 + 4            # hash pairs + dirty flags + count
    list(pack.read_chunks())
    assert pack.bytes_transferred == base + cb   # one compacted row moved
    assert pack.bytes_transferred < nbytes       # never the whole array


def test_device_delta_pack_gating(monkeypatch):
    x = jnp.arange(1024, dtype=jnp.float32)
    prev = H.chunk_hashes_np(np.asarray(x).tobytes(), 1 << 10)
    monkeypatch.setenv("KISHU_DEVICE_DELTA", "0")
    assert delta_mod.device_delta_pack(x, prev, 1 << 10) is None
    monkeypatch.setenv("KISHU_DEVICE_DELTA", "1")
    pack = delta_mod.device_delta_pack(x, prev, 1 << 10)
    assert isinstance(pack, DeltaPack) and pack.count == 0
    # ladder guards: no prev hashes / wrong length / non-pow2 chunks / host
    assert delta_mod.device_delta_pack(x, None, 1 << 10) is None
    assert delta_mod.device_delta_pack(x, prev[:-1], 1 << 10) is None
    assert delta_mod.device_delta_pack(x, prev, 3000) is None
    assert delta_mod.device_delta_pack(np.arange(4), prev, 1 << 10) is None


def test_fallback_counter_and_log_once(monkeypatch, caplog):
    """exact_dirty_indices degrading to the host compare must bump the
    session fallback counter and warn exactly once (the observability
    satellite — a silently slow path is now visible)."""
    import importlib
    import logging

    # repro.kernels re-exports the block_diff *function* over the submodule
    # name, so plain attribute-style import resolves to the function
    bd = importlib.import_module("repro.kernels.block_diff.ops")

    def boom(*a, **k):
        raise RuntimeError("no backend")
    monkeypatch.setattr(bd, "dirty_chunks", boom)
    monkeypatch.setattr(delta_mod, "_fallback_logged", False)
    a = jnp.arange(2048, dtype=jnp.float32)
    b = a.at[0].set(9.0)
    before = delta_mod.kernel_fallbacks()
    with caplog.at_level(logging.WARNING, logger="repro.core.delta"):
        assert delta_mod.exact_dirty_indices(a, b, 1 << 10) == [0]
        assert delta_mod.exact_dirty_indices(a, b, 1 << 10) == [0]
    assert delta_mod.kernel_fallbacks() == before + 2
    warns = [r for r in caplog.records if "device kernel" in r.message]
    assert len(warns) == 1               # log-once-per-session


def _session_states(store, force: str, chunk_bytes=1 << 12):
    from repro.core import KishuSession
    sess = KishuSession(store, chunk_bytes=chunk_bytes, cache_bytes=0)

    def init(ns):
        ns["x"] = jnp.arange(8192, dtype=jnp.float32)
        ns["y"] = jnp.zeros((2048,), jnp.int32)

    def mutate(ns, seed):
        ns["x"] = ns["x"].at[:1024].set(float(seed))
        ns["y"] = ns["y"] + seed

    sess.register("init", init)
    sess.register("mutate", mutate)
    sess.init_state({})
    cids = [sess.run("init")]
    cids += [sess.run("mutate", seed=s) for s in (3, 5)]
    wstats = sess.last_run.write
    states = []
    for cid in cids:
        sess.checkout(cid)
        states.append({n: np.asarray(sess.ns[n]).tobytes()
                       for n in sess.ns.names()})
    keys = sorted(store.list_chunk_keys())
    sess.close()
    return states, keys, wstats


def test_session_fused_vs_host_bit_identical(monkeypatch):
    """End to end: the fused device path commits the same chunk keys and
    restores the same bytes as the host path, and WriteStats records the
    pack usage + device→host savings."""
    from repro.core import MemoryStore
    monkeypatch.setenv("KISHU_DEVICE_DELTA", "1")
    monkeypatch.setenv("KISHU_DEVICE_HASH", "1")
    dev_states, dev_keys, dev_w = _session_states(MemoryStore(), "1")
    monkeypatch.setenv("KISHU_DEVICE_DELTA", "0")
    monkeypatch.setenv("KISHU_DEVICE_HASH", "0")
    host_states, host_keys, host_w = _session_states(MemoryStore(), "0")
    assert dev_states == host_states
    assert dev_keys == host_keys
    assert dev_w.covs_packed >= 1
    assert 0 < dev_w.bytes_dev2host < dev_w.bytes_logical
    assert host_w.covs_packed == 0 and host_w.bytes_dev2host == 0


def test_checkout_stats_have_fallback_counter():
    from repro.core.checkout import CheckoutStats
    assert CheckoutStats().kernel_fallbacks == 0
