"""Three-way hash-implementation parity on edge shapes (fast lane).

chunk_hashes_np vs chunk_hashes_jnp vs the Pallas chunk_hash kernel
(interpret mode) must agree bit-for-bit on the shapes that historically
break chunked hashing: odd byte lengths, sub-word tails, chunk_bytes ≥
nbytes, empty arrays — and zero-padding must never collide with real
zeros of a different length (the hashing.py contract).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hashing as H

# (nbytes, chunk_bytes): odd lengths, sub-word tails, one-chunk clamps
EDGE_SHAPES = [
    (1, 4096),        # single byte, chunk far larger than the buffer
    (3, 4096),        # sub-word tail only
    (5, 4),           # chunk smaller than a word-pair, odd tail
    (7, 8),           # one partial chunk
    (4095, 4096),     # one byte short of a chunk
    (4096, 4096),     # exactly one chunk
    (4097, 4096),     # one byte over: 2nd chunk is a 1-byte tail
    (4097, 1 << 20),  # chunk_bytes >= nbytes (whole-co-variable mode)
    (12288 + 2, 4096),  # several chunks + 2-byte tail
]


def _np_ref(buf: bytes, cb: int) -> np.ndarray:
    return H.chunk_hashes_np(buf, cb)


def _jnp_hash(buf: bytes, cb: int) -> np.ndarray:
    words, nbytes = H.words_view(buf, cb)
    return H.combine_u64(np.asarray(
        H.chunk_hashes_jnp(jnp.asarray(words), jnp.asarray(nbytes))))


def _pallas_hash(buf: bytes, cb: int) -> np.ndarray:
    from repro.kernels.chunk_hash.ops import chunk_hash_u64
    arr = jnp.asarray(np.frombuffer(buf, np.uint8))
    return chunk_hash_u64(arr, cb, backend="pallas", interpret=True)


@pytest.mark.parametrize("nbytes,cb", EDGE_SHAPES)
def test_np_vs_jnp_edge_shapes(nbytes, cb):
    rng = np.random.default_rng(nbytes * 31 + cb)
    buf = rng.integers(0, 256, nbytes, dtype=np.uint8).tobytes()
    assert np.array_equal(_np_ref(buf, cb), _jnp_hash(buf, cb))


@pytest.mark.parametrize("nbytes,cb", EDGE_SHAPES)
def test_np_vs_pallas_edge_shapes(nbytes, cb):
    if cb & (cb - 1):
        pytest.skip("pallas kernel requires power-of-two chunks")
    rng = np.random.default_rng(nbytes * 37 + cb)
    raw = rng.integers(0, 256, nbytes, dtype=np.uint8)
    got = _pallas_hash(raw.tobytes(), cb)
    want = _np_ref(raw.tobytes(), cb)
    # chunk_bytes >= nbytes clamps host-side (no huge pad alloc) but the
    # chunk COUNT matches; values must agree because padding contributes 0
    assert np.array_equal(got, want)


def test_empty_array_all_impls():
    assert _np_ref(b"", 4096).size == 0
    x = jnp.zeros((0,), jnp.float32)
    from repro.kernels.delta_pack.ops import delta_pack
    pack = delta_pack(x, np.zeros((0,), np.uint64), 4096)
    assert pack.n_chunks == 0 and pack.hashes.size == 0 \
        and pack.count == 0 and list(pack.read_chunks()) == []


@pytest.mark.parametrize("impl", ["np", "jnp", "pallas"])
def test_padding_never_collides(impl):
    """A buffer of n zeros and one of n+1 zeros land in the same padded
    word block — only the folded byte length separates their hashes."""
    fn = {"np": _np_ref, "jnp": _jnp_hash, "pallas": _pallas_hash}[impl]
    for n in (1, 2, 3, 4, 5, 7, 4095):
        a = fn(b"\x00" * n, 4096)
        b = fn(b"\x00" * (n + 1), 4096)
        assert a[0] != b[0], f"{impl}: pad collision at n={n}"


@pytest.mark.parametrize("nbytes,cb", [(17, 8), (4097, 4096), (9000, 512)])
def test_delta_pack_hashes_match_np(nbytes, cb):
    """The fused kernel's hash lanes are the same spec — parity through the
    whole delta_pack wrapper, both backends."""
    from repro.kernels.delta_pack.ops import delta_pack
    rng = np.random.default_rng(nbytes)
    raw = rng.integers(0, 256, nbytes, dtype=np.uint8)
    prev = _np_ref(raw.tobytes(), cb)
    want = prev                       # unchanged buffer: same hashes
    for backend, kw in (("ref", {}), ("pallas", {"interpret": True})):
        pack = delta_pack(jnp.asarray(raw), prev, cb, backend=backend, **kw)
        assert np.array_equal(pack.hashes, want), backend
        assert pack.count == 0, backend   # nothing dirty vs itself


def test_split_combine_u64_roundtrip():
    h = np.array([0, 1, 0xdeadbeef_cafebabe, (1 << 64) - 1], np.uint64)
    assert np.array_equal(H.combine_u64(H.split_u64(h)), h)
