"""Chunk store backends: roundtrip, CAS dedup, metadata, fault injection."""
import os

import pytest

from repro.core.chunkstore import (DirectoryStore, FaultInjectedStore,
                                   MemoryStore, SQLiteStore, chunk_key,
                                   open_store)
from repro.core.serialize import ChunkMissingError


@pytest.fixture(params=["memory", "dir", "sqlite"])
def store(request, tmp_path):
    if request.param == "memory":
        return MemoryStore()
    if request.param == "dir":
        return DirectoryStore(str(tmp_path / "cas"))
    return SQLiteStore(str(tmp_path / "cas.db"))


def test_roundtrip(store):
    data = b"hello world" * 100
    k = chunk_key(data)
    assert store.put_chunk(k, data) is True
    assert store.get_chunk(k) == data
    assert store.has_chunk(k)
    assert store.n_chunks() == 1
    assert store.chunk_bytes_total() == len(data)


def test_cas_dedup(store):
    data = b"x" * 1000
    k = chunk_key(data)
    assert store.put_chunk(k, data) is True
    assert store.put_chunk(k, data) is False       # already present
    assert store.n_chunks() == 1


def test_missing_chunk_raises(store):
    with pytest.raises(ChunkMissingError):
        store.get_chunk("deadbeef" * 4)


def test_meta_roundtrip(store):
    store.put_meta("commit/c1", {"a": 1, "nested": {"b": [1, 2]}})
    store.put_meta("commit/c2", {"a": 2})
    store.put_meta("HEAD", {"head": "c2"})
    assert store.get_meta("commit/c1")["nested"]["b"] == [1, 2]
    assert store.list_meta("commit/") == ["commit/c1", "commit/c2"]
    assert store.get_meta("nope") is None


def test_delete_chunk(store):
    data = b"abc" * 10
    k = chunk_key(data)
    store.put_chunk(k, data)
    store.delete_chunk(k)
    assert not store.has_chunk(k)
    store.delete_chunk(k)                          # idempotent


def test_delete_chunks_batched(store):
    pairs = [(chunk_key(bytes([i]) * 50), bytes([i]) * 50)
             for i in range(20)]
    assert store.put_chunks(pairs) == 20
    doomed = [k for k, _ in pairs[:15]]
    # batched delete: backend-native (executemany / pooled unlink); counts
    # removals and is idempotent on re-delete and unknown keys
    assert store.delete_chunks(doomed + ["f" * 32]) == 15
    assert store.delete_chunks(doomed) == 0
    assert store.n_chunks() == 5
    for k, _ in pairs[15:]:
        assert store.has_chunk(k)


def test_fault_injection():
    inner = MemoryStore()
    bad = {"victim"}
    fs = FaultInjectedStore(inner, fail_get=lambda k: k in bad)
    fs.put_chunk("victim", b"data")
    fs.put_chunk("fine", b"data2")
    assert fs.get_chunk("fine") == b"data2"
    with pytest.raises(ChunkMissingError):
        fs.get_chunk("victim")


def test_open_store(tmp_path):
    assert isinstance(open_store("memory://"), MemoryStore)
    assert isinstance(open_store(f"dir://{tmp_path}/a"), DirectoryStore)
    assert isinstance(open_store(f"sqlite://{tmp_path}/b.db"), SQLiteStore)
    assert isinstance(open_store(str(tmp_path / "c")), DirectoryStore)


def test_chunk_key_is_content_addressed():
    assert chunk_key(b"a") == chunk_key(b"a")
    assert chunk_key(b"a") != chunk_key(b"b")
