"""Transactional commit engine: crash injection, recovery, fsck, group
commit (DESIGN.md §13).

The core suite sweeps a simulated process kill over EVERY write operation of
a small multi-commit workload — on every backend (memory / directory /
SQLite) and on fabric topologies (shard ring, replica set) — and proves
that after ``txn.recover`` (run implicitly by the session/graph open):

  * the store is fsck-clean: no unsealed journals, no torn HEAD, no
    missing parents or chunks, no dangling chunks;
  * the recovered state is *bit-identical* to some prefix of the committed
    workload (commit atomicity: a kill can lose the in-flight cell, never
    tear or corrupt state).
"""
import json

import numpy as np
import pytest

from repro.core import txn
from repro.core.chunkstore import (DirectoryStore, FaultInjectingStore,
                                   InjectedCrash, MemoryStore, SQLiteStore)
from repro.core.fabric import ReplicatedStore, ShardedStore
from repro.core.session import KishuSession
from repro.launch.kishu_cli import main as cli

BACKENDS = ["memory", "dir", "sqlite", "shard", "rep"]


def make_inner(kind, tmp_path, tag):
    if kind == "memory":
        return MemoryStore()
    if kind == "dir":
        return DirectoryStore(str(tmp_path / f"d{tag}"))
    if kind == "sqlite":
        return SQLiteStore(str(tmp_path / f"s{tag}.db"))
    if kind == "shard":
        return ShardedStore([MemoryStore(), MemoryStore()])
    if kind == "rep":
        return ReplicatedStore([MemoryStore(), MemoryStore()])
    raise AssertionError(kind)


def set_val(ns, name, val):
    ns[name] = np.full(400, float(val), np.float32)


def build_session(store, **kw):
    s = KishuSession(store, chunk_bytes=1 << 9, **kw)
    s.register("set_val", set_val)
    return s


def snapshot(ns):
    return {name: bytes(np.ascontiguousarray(ns[name]))
            for name in ns.names()}


def run_workload(s, states=None):
    """Three cells after attach; record the live state after each commit."""
    def record():
        if states is not None:
            states.append(snapshot(s.ns))
    s.init_state({"a": np.arange(64, dtype=np.float32)})
    record()
    s.run("set_val", name="x", val=1)
    record()
    s.run("set_val", name="y", val=2)
    record()
    s.run("set_val", name="x", val=3)
    record()


def crash_run(store, **session_kw):
    """Build a session and drive the workload, absorbing the injected kill
    wherever it lands (including session construction — init_root commits).
    A kill inside the publish surfaces wrapped in TxnError (the engine
    poisons itself on publish failure) — still the simulated process
    death.  Returns True if the workload survived to completion."""
    from repro.core.txn import TxnError
    try:
        s = build_session(store, **session_kw)
        run_workload(s)
        s.close()
        return True
    except InjectedCrash:
        return False
    except TxnError as e:
        if isinstance(e.__cause__, InjectedCrash):
            return False
        raise


def probe_ops(store_factory, **session_kw):
    """Run the workload uncrashed over a counting wrapper; returns the
    wrapper (total op count + per-op labels)."""
    probe = FaultInjectingStore(store_factory())
    assert crash_run(probe, **session_kw)
    return probe


@pytest.fixture(scope="module")
def reference_states():
    """Bit-exact session states after each commit of the workload, plus the
    empty pre-attach state — the only legal recovery targets."""
    s = build_session(MemoryStore())
    states = [{}]
    run_workload(s, states)
    s.close()
    return states


def reopen_state(inner):
    """Reboot: fresh session over the bare store (open runs txn.recover),
    then materialize HEAD exactly as elastic crash-recovery would."""
    s = KishuSession(inner, chunk_bytes=1 << 9)
    if s.graph.head is not None and s.graph.nodes[s.graph.head].state_index:
        s.loader.materialize_state(s.tracked, s.graph.head)
    state = snapshot(s.ns)
    s.close()
    return state


def assert_recovers_clean(inner, k, reference_states):
    # pre-recovery invariant (the _persist ordering bug): even before any
    # recovery runs, HEAD must never name a commit whose doc is missing
    head_doc = inner.get_meta("HEAD")
    if head_doc and head_doc.get("head") is not None:
        doc = inner.get_meta(f"commit/{head_doc['head']}")
        assert doc is not None and doc.get("deleted") is not True, \
            f"torn HEAD at kill point {k}"
    state = reopen_state(inner)       # session open replays/rolls back
    assert state in reference_states, \
        f"kill at op {k}: recovered state matches no committed prefix"
    rep = txn.fsck(inner)
    assert rep.problems == 0, (k, rep.details)


@pytest.mark.parametrize("kind", BACKENDS)
def test_crash_sweep_recovers_bit_identical(kind, tmp_path,
                                            reference_states):
    total = probe_ops(lambda: make_inner(kind, tmp_path, "probe")).ops
    assert total > 10, "sweep would not cover the pipeline"
    for k in range(total):
        inner = make_inner(kind, tmp_path, k)
        survived = crash_run(FaultInjectingStore(inner, crash_after=k))
        assert not survived      # every k < total is a real kill point
        assert_recovers_clean(inner, k, reference_states)


def test_kill_between_commit_doc_and_head(tmp_path, reference_states):
    """Satellite regression: on a backend whose multi-meta publish
    decomposes to per-doc puts, kill exactly between the commit doc and
    the HEAD put — HEAD must keep naming the previous durable commit and
    recovery must roll the journaled publish forward."""
    probe = probe_ops(MemoryStore)
    doc_puts = [i for i, op in enumerate(probe.op_log)
                if op.startswith("put_meta:commit/")
                and probe.op_log[i + 1].startswith("put_meta:HEAD")]
    assert doc_puts, "publish pattern not found in op trace"
    for k in (i + 1 for i in doc_puts):     # commit doc landed, HEAD next
        inner = MemoryStore()
        assert not crash_run(FaultInjectingStore(inner, crash_after=k))
        assert_recovers_clean(inner, k, reference_states)


def test_group_commit_batches_publishes():
    store = MemoryStore()
    s = build_session(store, group_commit_n=3)  # init_root queued (1 of 3)
    s.init_state({"a": np.arange(64, dtype=np.float32)})   # attach (2 of 3)
    # group not full: nothing published yet — the in-memory graph is
    # deliberately ahead of the durable one
    assert s.engine.pending_commits() == 2
    assert store.get_meta(f"commit/{s.head}") is None
    s.run("set_val", name="x", val=1)           # 3 of 3 -> published
    assert s.engine.pending_commits() == 0
    assert store.get_meta(f"commit/{s.head}") is not None
    assert store.get_meta("HEAD")["head"] == s.head
    s.run("set_val", name="y", val=2)           # queued again
    s.close()                                   # flush publishes the tail
    assert store.get_meta("HEAD")["head"] == s.head
    assert s.engine.stats.publishes == 2
    assert txn.fsck(store).problems == 0


def test_group_commit_crash_loses_at_most_group(tmp_path, reference_states):
    """A kill mid-group recovers to SOME committed prefix (possibly a few
    cells back — classic group-commit semantics), never torn state."""
    total = probe_ops(MemoryStore, group_commit_n=2).ops
    for k in range(total):
        inner = MemoryStore()
        crash_run(FaultInjectingStore(inner, crash_after=k),
                  group_commit_n=2)
        assert_recovers_clean(inner, k, reference_states)


def test_async_write_and_async_publish_roundtrip(tmp_path, reference_states):
    store = SQLiteStore(str(tmp_path / "async.db"))
    s = build_session(store, async_write=True, async_publish=True,
                      group_commit_n=2)
    states = []
    run_workload(s, states)
    s.close()
    assert txn.fsck(store).problems == 0
    assert reopen_state(store) == states[-1] == reference_states[-1]


def test_checkout_flushes_pending_publishes():
    store = MemoryStore()
    s = build_session(store, group_commit_n=8, async_publish=True)
    s.init_state({"a": np.arange(64, dtype=np.float32)})
    c1 = s.run("set_val", name="x", val=1)
    s.run("set_val", name="x", val=2)
    s.checkout(c1)                  # time travel forces the queue out
    assert np.all(s.ns["x"] == 1.0)
    assert store.get_meta(f"commit/{c1}") is not None
    s.close()
    assert txn.fsck(store).problems == 0


def test_recover_rolls_forward_and_is_idempotent(tmp_path):
    probe = probe_ops(lambda: SQLiteStore(str(tmp_path / "probe.db")))
    # kill right before a commit-doc put: the journal is in publish state,
    # so recovery must roll FORWARD (replay the publish)
    k = max(i for i, op in enumerate(probe.op_log)
            if op.startswith("put_meta:commit/"))
    inner = SQLiteStore(str(tmp_path / "idem.db"))
    assert not crash_run(FaultInjectingStore(inner, crash_after=k))
    first = txn.recover(inner)
    assert first["replayed"] == 1
    assert first["commits_published"] >= 1
    second = txn.recover(inner)
    assert second == {"replayed": 0, "rolled_back": 0,
                      "commits_published": 0, "chunks_dropped": 0}
    assert txn.fsck(inner).problems == 0


def test_recover_rolls_back_open_txn(tmp_path):
    probe = probe_ops(MemoryStore)
    # kill right before the first chunk put of the last cell: journal is
    # open with chunk keys; recovery must roll BACK and drop the orphans
    k = max(i for i, op in enumerate(probe.op_log)
            if op.startswith("put_chunk:"))
    inner = MemoryStore()
    assert not crash_run(FaultInjectingStore(inner, crash_after=k + 1))
    out = txn.recover(inner)
    assert out["rolled_back"] >= 1
    assert out["chunks_dropped"] >= 1
    assert txn.fsck(inner).problems == 0


class _FailingPutStore(MemoryStore):
    """Chunk puts raise (disk full / dead backend) while ``fail`` is on;
    everything else works — the async drain records the errors and the
    publish fence must surface them."""

    def __init__(self):
        super().__init__()
        self.fail = False

    def put_chunk(self, key, data):
        if self.fail:
            raise IOError("injected: chunk device full")
        return super().put_chunk(key, data)


def test_failed_async_chunk_write_never_publishes_torn_state():
    """A chunk that never lands (async writer fault) must abort its
    transaction: the fence failure rolls the group back, the engine
    poisons itself, and no later commit can publish metadata naming the
    missing chunks — the reopened store is fsck-clean at the last good
    prefix."""
    from repro.core.txn import TxnError

    store = _FailingPutStore()
    s = build_session(store, async_write=True)
    s.init_state({"a": np.arange(64, dtype=np.float32)})   # lands durably
    attach_state = snapshot(s.ns)
    store.fail = True
    with pytest.raises(TxnError):
        s.run("set_val", name="x", val=1)      # fence fails -> abort
    with pytest.raises(TxnError):
        s.run("set_val", name="y", val=2)      # engine is poisoned
    store.fail = False
    rep = txn.fsck(store)
    assert rep.problems == 0, rep.details      # nothing torn, no orphans
    assert reopen_state(store) == attach_state


def test_fsck_detects_problems():
    store = MemoryStore()
    s = build_session(store)
    run_workload(s)
    s.close()
    assert txn.fsck(store).clean
    # dangling chunk
    store.put_chunk("deadbeef" * 4, b"junk")
    rep = txn.fsck(store)
    assert rep.dangling_chunks == 1 and not rep.clean
    store.delete_chunk("deadbeef" * 4)
    # missing chunk
    victim = next(iter(s.graph.live_chunk_keys()))
    data = store.get_chunk(victim)
    store.delete_chunk(victim)
    assert txn.fsck(store).missing_chunks >= 1
    store.put_chunk(victim, data)
    # torn HEAD
    good_head = store.get_meta("HEAD")
    store.put_meta("HEAD", {"head": "c99999", "seq": 99})
    assert txn.fsck(store).torn_head == 1
    store.put_meta("HEAD", good_head)
    # unsealed journal
    store.put_meta("txn/zzz", {"status": "open", "chunks": []})
    assert txn.fsck(store).unsealed_txns == 1
    store.delete_meta("txn/zzz")
    assert txn.fsck(store).clean


def test_gc_purges_tombstones(tmp_path):
    store = SQLiteStore(str(tmp_path / "gc.db"))
    s = build_session(store)
    s.init_state({"a": np.arange(64, dtype=np.float32)})
    root = s.run("set_val", name="x", val=1)
    s.run("set_val", name="y", val=2)
    branch_tip = s.head
    s.checkout(root)
    s.run("set_val", name="y", val=9)
    doomed = s.delete_branch(branch_tip)
    assert doomed
    # tombstones present until gc purges them
    tombs = [n for n in store.list_meta("commit/")
             if (store.get_meta(n) or {}).get("deleted") is True]
    assert len(tombs) == len(doomed)
    out = s.gc()
    assert out["tombstones_purged"] == len(doomed)
    assert not [n for n in store.list_meta("commit/")
                if (store.get_meta(n) or {}).get("deleted") is True]
    # the graph reloads identically without the tombstones
    s2 = KishuSession(store, chunk_bytes=1 << 9)
    assert sorted(s2.graph.nodes) == sorted(s.graph.nodes)
    s2.close()
    s.close()
    assert txn.fsck(store).problems == 0


def test_total_meta_bytes_cached():
    store = MemoryStore()
    s = build_session(store)
    run_workload(s)

    def recompute(graph):
        return sum(len(json.dumps(n.to_doc()))
                   for n in graph.nodes.values())

    assert s.graph.total_meta_bytes() == recompute(s.graph)
    branch_root = s.head
    s.run("set_val", name="z", val=7)
    tip = s.head
    s.checkout(branch_root)
    s.run("set_val", name="z", val=8)
    s.delete_branch(tip)
    assert s.graph.total_meta_bytes() == recompute(s.graph)
    s.close()
    # a reloaded graph agrees
    s2 = KishuSession(store, chunk_bytes=1 << 9)
    assert s2.graph.total_meta_bytes() == recompute(s2.graph)
    s2.close()


def test_cli_fsck_and_recover(tmp_path, capsys):
    probe = probe_ops(lambda: SQLiteStore(str(tmp_path / "probe.db")))
    k = max(i for i, op in enumerate(probe.op_log)
            if op.startswith("put_meta:commit/"))
    uri = f"sqlite://{tmp_path}/cli.db"
    inner = SQLiteStore(str(tmp_path / "cli.db"))
    assert not crash_run(FaultInjectingStore(inner, crash_after=k))
    # fsck sees the raw crashed state (no implicit recovery)
    assert cli(["--store", uri, "fsck"]) == 2
    assert "unsealed" in capsys.readouterr().out
    assert cli(["--store", uri, "recover"]) == 0
    assert "replayed" in capsys.readouterr().out
    assert cli(["--store", uri, "fsck"]) == 0
    assert "OK" in capsys.readouterr().out


def test_cli_gc_reports_tombstones(tmp_path, capsys):
    uri = f"dir://{tmp_path}/cas"
    s = build_session(DirectoryStore(str(tmp_path / "cas")))
    s.init_state({"a": np.arange(64, dtype=np.float32)})
    root = s.run("set_val", name="x", val=1)
    s.run("set_val", name="y", val=2)
    tip = s.head
    s.checkout(root)
    s.run("set_val", name="y", val=3)
    doomed = s.delete_branch(tip)
    s.close()
    assert cli(["--store", uri, "gc"]) == 0
    out = capsys.readouterr().out
    assert f"{len(doomed)} tombstones" in out
    assert cli(["--store", uri, "fsck"]) == 0


# ---------------------------------------------------------------------------
# multi-session safety (DESIGN.md §14): two writers, one store
# ---------------------------------------------------------------------------

LEASE_TTL = 0.15
A_WORKLOAD = [("ax", 1), ("ay", 2), ("az", 9)]
B_WORKLOAD = [("bx", 5), ("by", 6)]


def test_txn_ids_never_collide_across_engines(monkeypatch):
    """Satellite regression: journal IDs were time(ms)+counter, so two
    engines opened in the same millisecond journaled to the SAME
    ``txn/<id>`` doc and corrupted each other's WAL.  Freeze the clock and
    prove the per-engine nonce keeps the names distinct anyway."""
    monkeypatch.setattr(txn.time, "time", lambda: 1_700_000_000.0)
    store = MemoryStore()
    engines = [txn.TxnEngine(store) for _ in range(4)]
    names = set()
    for e in engines:
        e._ensure_open()
        names.add(e._open_name)
    assert len(names) == len(engines), sorted(names)


def test_stale_writer_publish_refused_and_reopen_continues():
    """Satellite regression (the ``_seq`` race): a writer that loaded HEAD
    before another writer advanced it must not publish ``c{seq}`` over the
    newer commit.  The publish guard compares the durable seq, refuses,
    and the store keeps the newer writer's commit; reopening resumes from
    the durable state."""
    from repro.core.txn import TxnError

    store = MemoryStore()
    a = build_session(store)
    a.init_state({"a": np.arange(64, dtype=np.float32)})
    b = build_session(store)            # loads the same HEAD seq as a...
    cb = b.run("set_val", name="x", val=7)     # ...then advances it
    with pytest.raises(TxnError):
        a.run("set_val", name="x", val=9)      # stale seq: refused
    assert store.get_meta("HEAD")["head"] == cb
    b.close()
    assert txn.fsck(store).problems == 0, txn.fsck(store).details
    a2 = KishuSession(store, chunk_bytes=1 << 9)
    assert a2.graph.head == cb
    a2.close()


ATTACH = {"alice": "a", "bob": "b"}
WORKLOADS = {"alice": A_WORKLOAD, "bob": B_WORKLOAD}


@pytest.fixture(scope="module")
def two_writer_refs():
    """Bit-exact reference states for each writer's solo workload — tenant
    namespaces don't change values, so one clean run per writer suffices."""
    def solo(attach_name, workload):
        s = build_session(MemoryStore())
        states = [{}]
        s.init_state({attach_name: np.arange(32, dtype=np.float32)})
        states.append(snapshot(s.ns))
        for name, val in workload:
            s.run("set_val", name=name, val=val)
            states.append(snapshot(s.ns))
        s.close()
        return states
    return {t: solo(ATTACH[t], WORKLOADS[t]) for t in ("alice", "bob")}


def _run_two_writers(inner, fault_store, victim="bob"):
    """Two tenant writers interleave commits on one shared store.  The
    *victim* commits (leased) through ``fault_store`` — typically a fault
    injector — and its injected death is absorbed wherever it lands; the
    *survivor* commits on the bare store and always finishes.  Returns
    (victim survived, survivor's final live state)."""
    from repro.core.txn import TxnError

    survivor = "alice" if victim == "bob" else "bob"
    s_surv = build_session(inner, tenant=survivor)
    s_surv.init_state(
        {ATTACH[survivor]: np.arange(32, dtype=np.float32)})
    alive = [True]
    box = [None]

    def v(fn):
        if not alive[0]:
            return
        try:
            fn()
        except InjectedCrash:
            alive[0] = False
        except TxnError as e:
            if isinstance(e.__cause__, InjectedCrash):
                alive[0] = False
            else:
                raise

    def open_victim():
        box[0] = build_session(fault_store, tenant=victim,
                               lease_ttl_s=LEASE_TTL)

    v(open_victim)
    v(lambda: box[0].init_state(
        {ATTACH[victim]: np.arange(32, dtype=np.float32)}))
    w_surv, w_vic = WORKLOADS[survivor], WORKLOADS[victim]
    for i in range(max(len(w_surv), len(w_vic))):
        if i < len(w_surv):
            name, val = w_surv[i]
            s_surv.run("set_val", name=name, val=val)
        if i < len(w_vic):
            name, val = w_vic[i]
            v(lambda name=name, val=val:
              box[0].run("set_val", name=name, val=val))
    surv_final = snapshot(s_surv.ns)
    s_surv.close()
    if alive[0]:
        v(lambda: box[0].close())
    return alive[0], surv_final


def _assert_two_writer_recovers(inner, k, refs, victim="bob"):
    """After the victim's death at op ``k``: its lease is stolen only
    after a full observed TTL, it recovers to a committed prefix, the
    survivor's gc reaps nothing the victim references, and every
    namespace fscks clean."""
    import time as _t

    survivor = "alice" if victim == "bob" else "bob"
    had_lease = inner.get_meta(
        f"tenant/{victim}/lease/writer") is not None
    t0 = _t.monotonic()
    sv = KishuSession(inner, tenant=victim, chunk_bytes=1 << 9,
                      lease_ttl_s=LEASE_TTL, lease_wait_s=30.0)
    waited = _t.monotonic() - t0
    if had_lease:
        assert waited >= LEASE_TTL, \
            f"kill at op {k}: dead writer's lease stolen in {waited:.3f}s"
    if sv.graph.head is not None \
            and sv.graph.nodes[sv.graph.head].state_index:
        sv.loader.materialize_state(sv.tracked, sv.graph.head)
    vic_state = snapshot(sv.ns)
    assert vic_state in refs[victim], \
        f"kill at op {k}: {victim} recovered to no committed prefix"
    sv.close()

    ss = KishuSession(inner, tenant=survivor, chunk_bytes=1 << 9)
    ss.gc()                # must not reap anything the victim references
    ss.close()
    sv2 = KishuSession(inner, tenant=victim, chunk_bytes=1 << 9)
    if sv2.graph.head is not None \
            and sv2.graph.nodes[sv2.graph.head].state_index:
        sv2.loader.materialize_state(sv2.tracked, sv2.graph.head)
    assert snapshot(sv2.ns) == vic_state, \
        f"kill at op {k}: {survivor}'s gc corrupted {victim}'s state"
    sv2.close()
    for tid, rep in txn.fsck_all(inner).items():
        assert rep.problems == 0, (k, tid, rep.details)


@pytest.mark.parametrize("kind", ["memory", "dir", "sqlite", "shard"])
def test_two_writer_crash_sweep(kind, tmp_path, two_writer_refs):
    """Tentpole acceptance: two tenant sessions interleave commits on one
    shared store (memory / dir / sqlite / fabric shard ring); a simulated
    kill at EVERY one of the leased writer's store ops leaves the other
    writer bit-identical, the victim recoverable to a committed prefix
    behind a TTL-guarded lease steal, and cross-writer gc reaping
    nothing."""
    refs = two_writer_refs
    inner = make_inner(kind, tmp_path, "probe2w")
    probe = FaultInjectingStore(inner)
    survived, surv_final = _run_two_writers(inner, probe)
    assert survived and surv_final == refs["alice"][-1]
    total = probe.ops
    assert total > 10, "sweep would not cover the victim's pipeline"
    kills = 0
    for k in range(total):
        inner = make_inner(kind, tmp_path, f"2w{k}")
        survived, surv_final = _run_two_writers(
            inner, FaultInjectingStore(inner, crash_after=k))
        assert surv_final == refs["alice"][-1], \
            f"kill at bob op {k} disturbed writer alice"
        if survived:
            # lease renew writes are timing-dependent with a tiny TTL, so
            # the crash run can finish in fewer ops than the probe did —
            # a clean finish must still leave every namespace fsck-clean
            for tid, rep in txn.fsck_all(inner).items():
                assert rep.problems == 0, (k, tid, rep.details)
            continue
        kills += 1
        _assert_two_writer_recovers(inner, k, refs)
    assert kills >= total // 2, \
        f"only {kills}/{total} kill points actually fired"


@pytest.mark.parametrize("kind", ["dir", "shard"])
def test_kill_of_either_writer(kind, tmp_path, two_writer_refs):
    """The sweep above always kills the second writer; the acceptance bar
    says *either*.  Swap the roles — the FIRST writer (alice) dies at
    each mid-publish op and at its last chunk put — and assert the same
    recovery story with bob as the survivor."""
    refs = two_writer_refs
    inner = make_inner(kind, tmp_path, "probeA")
    probe = FaultInjectingStore(inner)
    survived, surv_final = _run_two_writers(inner, probe, victim="alice")
    assert survived and surv_final == refs["bob"][-1]
    kill_points = [i for i, op in enumerate(probe.op_log)
                   if op.startswith("put_meta:tenant/alice/commit/")]
    kill_points.append(max(i for i, op in enumerate(probe.op_log)
                           if op.startswith("put_chunk:")))
    assert kill_points, "no mid-publish ops found in alice's trace"
    kills = 0
    for k in kill_points:
        inner = make_inner(kind, tmp_path, f"2wA{k}")
        survived, surv_final = _run_two_writers(
            inner, FaultInjectingStore(inner, crash_after=k),
            victim="alice")
        assert surv_final == refs["bob"][-1], \
            f"kill at alice op {k} disturbed writer bob"
        if survived:
            continue             # renew-timing drift: op k fell past the end
        kills += 1
        _assert_two_writer_recovers(inner, k, refs, victim="alice")
    assert kills >= 1, "no kill point actually fired"
