# NOTE: do NOT set XLA_FLAGS / device-count overrides here — smoke tests and
# benchmarks must see the real single CPU device.  Distribution tests that
# need multiple devices spawn subprocesses with their own XLA_FLAGS.
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
