"""Per-arch smoke tests: reduced config, one forward + one train step on CPU,
asserting output shapes and absence of NaNs (assignment requirement f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest


from repro.models import get_config, list_configs, lm
from repro.models.testing import reduced
from repro.optim.adamw import AdamWConfig
from repro.train import step as step_lib

pytestmark = pytest.mark.slow    # JAX jit-heavy; fast lane: -m "not slow"

ARCHS = ["mamba2-780m", "stablelm-12b", "smollm-360m", "mistral-nemo-12b",
         "qwen3-1.7b", "jamba-1.5-large-398b", "whisper-large-v3",
         "phi3.5-moe-42b-a6.6b", "deepseek-v3-671b", "qwen2-vl-72b"]


def _batch(cfg, B, S, key, labels=True):
    b = {}
    if cfg.frontend == "vision":
        b["embeds"] = jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S)).astype(jnp.int32)
        b["positions_thw"] = jnp.stack([pos, pos, pos], -1)
    else:
        b["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    if cfg.enc_dec:
        b["enc_embeds"] = jax.random.normal(key, (B, S, cfg.d_model),
                                            jnp.float32)
    if labels:
        b["labels"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    return b


def test_all_archs_registered():
    assert set(ARCHS) <= set(list_configs())


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_smoke(arch):
    cfg = reduced(get_config(arch))
    params = lm.init_params(cfg, jax.random.key(0))
    B, S = 2, 16
    logits = lm.forward(cfg, params, _batch(cfg, B, S, jax.random.key(1),
                                            labels=False))
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = reduced(get_config(arch))
    oc = AdamWConfig(lr=1e-3)
    state = step_lib.init_train_state(cfg, jax.random.key(0), oc)
    step = step_lib.make_train_step(cfg, oc, remat=False)
    batch = _batch(cfg, 2, 16, jax.random.key(1))
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"])), arch
    assert np.isfinite(float(metrics["grad_norm"])), arch
    assert int(state["step"]) == 1
    # params actually moved
    l0 = jax.tree.leaves(state["params"])[0]
    assert bool(jnp.isfinite(l0).all())


@pytest.mark.parametrize("arch", ["mamba2-780m", "jamba-1.5-large-398b",
                                  "deepseek-v3-671b", "whisper-large-v3"])
def test_decode_smoke(arch):
    cfg = reduced(get_config(arch))
    params = lm.init_params(cfg, jax.random.key(0))
    B, CACHE = 2, 16
    caches = lm.init_caches(cfg, B, CACHE, enc_seq=8 if cfg.enc_dec else 0)
    if cfg.enc_dec:
        enc = jax.random.normal(jax.random.key(2), (B, 8, cfg.d_model),
                                jnp.float32)
        caches["enc_out"] = lm.encode(cfg, params, {"enc_embeds": enc},
                                      remat=False)
    serve = step_lib.make_decode_step(cfg)
    tok = jax.random.randint(jax.random.key(1), (B, 1), 0, cfg.vocab_size)
    for t in range(4):
        batch = {"tokens": tok, "index": jnp.asarray(t, jnp.int32)}
        if cfg.frontend == "vision":
            batch = {"embeds": params["embed"][tok[:, 0]][:, None, :],
                     "index": jnp.asarray(t, jnp.int32)}
        tok, caches = serve(params, caches, batch)
    assert tok.shape == (B, 1)
    assert int(tok.max()) < cfg.vocab_size


def test_param_counts_sane():
    """Analytic parameter counts should be within ~25% of the named sizes
    for the full configs (sanity of the 6ND roofline inputs)."""
    expect = {
        "mamba2-780m": 0.78e9, "smollm-360m": 0.36e9,
        "mistral-nemo-12b": 12e9, "qwen3-1.7b": 1.7e9,
        "deepseek-v3-671b": 671e9, "qwen2-vl-72b": 72e9,
        "phi3.5-moe-42b-a6.6b": 42e9, "jamba-1.5-large-398b": 398e9,
        "stablelm-12b": 12e9, "whisper-large-v3": 1.5e9,
    }
    for arch, n in expect.items():
        got = get_config(arch).param_counts()["total"]
        assert 0.6 * n < got < 1.5 * n, (arch, got, n)


def test_active_params_moe():
    ds = get_config("deepseek-v3-671b").param_counts()
    assert ds["active"] < ds["total"] / 10       # 37B active vs 671B total
    phi = get_config("phi3.5-moe-42b-a6.6b").param_counts()
    assert phi["active"] < phi["total"] / 3      # 6.6B vs 42B
