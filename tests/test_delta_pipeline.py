"""Chunk-granular delta pipeline: dirty-range serialization, patch
checkout, codec round-trips, and writer<->loader cache coherence."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (CompressedStore, FaultInjectedStore, KishuSession,
                        MemoryStore, Namespace, RecordBuilder)
from repro.core.chunkstore import (DirectoryStore, SQLiteStore, chunk_key,
                                   decode_chunk, encode_chunk, open_store,
                                   resolve_codec)
from repro.core import delta as delta_mod
from repro.core.checkpoint import WriteStats, build_manifest
from repro.core.checkout import materialize_manifest
from repro.core.covariable import cov_key
from repro.core.serialize import base_of


def make_store(kind, tmp_path):
    if kind == "memory":
        return MemoryStore()
    if kind == "dir":
        return DirectoryStore(str(tmp_path / "cas"))
    return SQLiteStore(str(tmp_path / "cas.db"))


@pytest.fixture(params=["memory", "dir", "sqlite"])
def store(request, tmp_path):
    return make_store(request.param, tmp_path)


CHUNK = 1 << 10                        # 1 KiB chunks
N = 2048                               # float32 -> 8 KiB -> 8 chunks


def _manifest_for(store, arr, prev=None, chunk=CHUNK):
    """Drive build_manifest directly for one single-member co-variable."""
    ns = Namespace({"x": arr})
    rb = RecordBuilder(chunk)
    rec = rb.build("x", arr, {})
    stats = WriteStats()
    man = build_manifest(store, ("x",), [rec], ns, chunk, prev, stats,
                         store.put_chunk)
    return man, stats


def _restored(store, man):
    return materialize_manifest(store, man)["x"]


# ---------------------------------------------------------------------------
# dirty-range serialization (det-hash reuse in build_manifest)
# ---------------------------------------------------------------------------

def test_build_manifest_unchanged_prev_serializes_nothing(store):
    arr = np.random.default_rng(0).standard_normal(N).astype(np.float32)
    man1, st1 = _manifest_for(store, arr)
    assert st1.bytes_serialized == st1.bytes_logical == arr.nbytes
    man2, st2 = _manifest_for(store, arr, prev=man1)
    assert st2.bytes_serialized == 0           # nothing moved
    assert st2.bytes_logical == arr.nbytes     # logical size still reported
    assert st2.chunks_reused == len(man1["base"]["chunks"])
    assert st2.covs_delta == 1
    assert man2["base"]["chunks"] == man1["base"]["chunks"]
    assert np.array_equal(_restored(store, man2), arr)


def test_build_manifest_partially_dirty_moves_only_dirty(store):
    rng = np.random.default_rng(1)
    arr = rng.standard_normal(N).astype(np.float32)
    man1, _ = _manifest_for(store, arr)
    arr2 = arr.copy()
    arr2[0] += 1.0                              # chunk 0
    arr2[-1] += 1.0                             # last chunk
    man2, st2 = _manifest_for(store, arr2, prev=man1)
    assert st2.bytes_serialized == 2 * CHUNK
    assert st2.bytes_logical == arr.nbytes
    assert st2.chunks_reused == 8 - 2
    # clean chunks reference the previous version's storage
    assert man2["base"]["chunks"][1:-1] == man1["base"]["chunks"][1:-1]
    assert np.array_equal(_restored(store, man2), arr2)


def test_build_manifest_meta_change_falls_back_to_full(store):
    arr = np.random.default_rng(2).standard_normal(N).astype(np.float32)
    man1, _ = _manifest_for(store, arr)
    arr2 = np.random.default_rng(3).standard_normal(N // 2).astype(np.float64)
    man2, st2 = _manifest_for(store, arr2, prev=man1)
    assert st2.covs_delta == 0                  # fast path not applicable
    assert st2.bytes_serialized == arr2.nbytes  # full serialization
    assert np.array_equal(_restored(store, man2), arr2)


def test_build_manifest_fully_dirty_takes_full_path(store):
    arr = np.random.default_rng(4).standard_normal(N).astype(np.float32)
    man1, _ = _manifest_for(store, arr)
    arr2 = arr + 1.0                            # every chunk dirty
    man2, st2 = _manifest_for(store, arr2, prev=man1)
    assert st2.covs_delta == 0
    assert st2.bytes_serialized == arr2.nbytes
    assert np.array_equal(_restored(store, man2), arr2)


def test_delta_chunks_bit_identical_to_full_path(store):
    """A chunk written through the dirty-range reader must hash and store
    exactly like one cut from the full blob."""
    rng = np.random.default_rng(5)
    arr = rng.standard_normal(N).astype(np.float32)
    man1, _ = _manifest_for(store, arr)
    arr2 = arr.copy()
    arr2[300] = 42.0
    man_delta, st = _manifest_for(store, arr2, prev=man1)
    assert st.covs_delta == 1
    man_full, _ = _manifest_for(MemoryStore(), arr2)   # no prev: full path
    assert [c["key"] for c in man_delta["base"]["chunks"]] \
        == [c["key"] for c in man_full["base"]["chunks"]]


def test_device_array_delta_write_and_patch(store):
    s = KishuSession(store, chunk_bytes=CHUNK, cache_bytes=0)

    def init(ns, seed):
        ns["w"] = jnp.arange(N, dtype=jnp.float32) * seed

    def bump(ns):
        ns["w"] = ns["w"].at[5].add(1.0)
    s.register("init", init)
    s.register("bump", bump)
    s.init_state({})
    c1 = s.run("init", seed=2)
    snap1 = np.asarray(s.ns["w"]).tobytes()
    c2 = s.run("bump")
    w = s.last_run.write
    assert w.covs_delta == 1
    assert w.bytes_serialized == CHUNK          # one dirty chunk transferred
    snap2 = np.asarray(s.ns["w"]).tobytes()
    st = s.checkout(c1)
    assert st.covs_patched == 1 and st.bytes_loaded == CHUNK
    assert isinstance(s.ns["w"], jax.Array)
    assert np.asarray(s.ns["w"]).tobytes() == snap1
    s.checkout(c2)
    assert np.asarray(s.ns["w"]).tobytes() == snap2
    s.close()


# ---------------------------------------------------------------------------
# in-place patch checkout
# ---------------------------------------------------------------------------

def _delta_session(store, cache_bytes=0):
    s = KishuSession(store, chunk_bytes=CHUNK, cache_bytes=cache_bytes)

    def init(ns, seed):
        rng = np.random.default_rng(seed)
        for i in range(3):
            ns[f"v{i}"] = rng.standard_normal(N).astype(np.float32)

    def mutate(ns, seed):
        rng = np.random.default_rng(seed)
        for i in range(3):
            ns[f"v{i}"][i] = rng.standard_normal()   # 1 dirty chunk per cov
    s.register("init", init)
    s.register("mutate", mutate)
    s.init_state({})
    return s


def _snap(s):
    return {n: np.asarray(s.ns[n]).tobytes() for n in s.ns.names()}


def test_patch_checkout_fetches_only_dirty_chunks(store):
    s = _delta_session(store)
    c1 = s.run("init", seed=1)
    snap1 = _snap(s)
    c2 = s.run("mutate", seed=9)
    snap2 = _snap(s)
    st = s.checkout(c1)
    assert st.covs_patched == 3
    assert st.chunks_patched == 3               # one dirty chunk per cov
    assert st.chunks_inplace == 3 * 8 - 3
    assert st.bytes_loaded == 3 * CHUNK         # moved ~ dirty, not logical
    assert st.bytes_logical == 3 * N * 4
    assert _snap(s) == snap1
    st = s.checkout(c2)                         # and forward again
    assert st.covs_patched == 3
    assert _snap(s) == snap2
    s.close()


def test_patch_preserves_live_object_identity(store):
    s = _delta_session(store)
    c1 = s.run("init", seed=1)
    c2 = s.run("mutate", seed=9)
    obj = s.ns["v0"]
    s.checkout(c1)
    assert s.ns["v0"] is obj                    # patched in place, not swapped
    s.close()


def test_patch_disabled_matches_patched_restore(store):
    s = _delta_session(store)
    c1 = s.run("init", seed=1)
    snap1 = _snap(s)
    s.run("mutate", seed=9)
    s.loader.patch_enabled = False
    st = s.checkout(c1)
    assert st.covs_patched == 0
    assert st.bytes_loaded == 3 * N * 4         # pre-delta full fetch
    assert _snap(s) == snap1
    s.close()


def test_patch_exactness_cross_checked_with_block_diff(store):
    """After a patch checkout the live buffer must be *exactly* the target
    — verified chunk-by-chunk with the exact (hash-free) compare."""
    s = _delta_session(store)
    c1 = s.run("init", seed=1)
    ref = {n: np.asarray(s.ns[n]).copy() for n in s.ns.names()}
    s.run("mutate", seed=9)
    s.checkout(c1)
    for n, want in ref.items():
        assert delta_mod.exact_dirty_indices(s.ns[n], want, CHUNK) == []
    s.close()


def test_structure_change_falls_back_to_full_load(store):
    s = KishuSession(store, chunk_bytes=CHUNK, cache_bytes=0)

    def a(ns):
        ns["x"] = np.ones(N, np.float32)

    def b(ns):
        ns["x"] = np.ones(N // 2, np.float64) * 2
    s.register("a", a)
    s.register("b", b)
    s.init_state({})
    ca = s.run("a")
    s.run("b")
    st = s.checkout(ca)
    assert st.covs_patched == 0                 # meta diverged: full load
    assert np.array_equal(s.ns["x"], np.ones(N, np.float32))
    s.close()


# ---------------------------------------------------------------------------
# codec round-trips
# ---------------------------------------------------------------------------

def test_codec_roundtrip_all_backends(store):
    cs = CompressedStore(store, "zlib")
    data = (b"compressible " * 1000)[:8192]
    k = chunk_key(data)
    assert cs.put_chunk(k, data)
    assert cs.get_chunk(k) == data
    # physically smaller on disk, logically intact through any reader
    assert store.get_chunk(k) == data           # backend decodes frames
    assert cs.stored_put_bytes < cs.logical_put_bytes


def test_codec_mixed_store_stays_readable(store):
    """Chunks written raw (old store) and compressed (new writer) coexist;
    either reader sees logical bytes."""
    raw_data = b"written before compression existed" * 100
    k_raw = chunk_key(raw_data)
    store.put_chunk(k_raw, raw_data)            # uncompressed writer
    cs = CompressedStore(store, "zlib")
    comp_data = b"written by the compressed writer" * 100
    k_comp = chunk_key(comp_data)
    cs.put_chunk(k_comp, comp_data)
    for reader in (store, cs):
        assert reader.get_chunk(k_raw) == raw_data
        assert reader.get_chunks([k_raw, k_comp]) \
            == {k_raw: raw_data, k_comp: comp_data}


def test_incompressible_chunks_stored_raw():
    inner = MemoryStore()
    cs = CompressedStore(inner, "zlib")
    noise = np.random.default_rng(0).bytes(4096)
    k = chunk_key(noise)
    cs.put_chunk(k, noise)
    assert inner.chunks[k] == noise             # no frame, zero overhead
    assert cs.get_chunk(k) == noise


def test_encode_decode_frame_contract():
    codec = resolve_codec("zlib")
    data = b"abc" * 5000
    enc = encode_chunk(data, codec)
    assert enc != data and decode_chunk(enc) == data
    assert decode_chunk(data) == data           # unframed passthrough
    assert encode_chunk(data, None) == data


def test_magic_prefixed_user_data_survives(store):
    """Logical chunk bytes that *begin with the frame magic* must round-trip
    through every backend and through the compressed writer — they are
    escaped (or decode-tolerated), never misparsed as a frame."""
    from repro.core.chunkstore import CHUNK_MAGIC
    for tail in (b"", b"\x00" * 40, b"not a frame at all" * 10,
                 b"\x01" + (8).to_bytes(8, "little") + b"xxxxxxxx"):
        data = CHUNK_MAGIC + tail
        k = chunk_key(data)
        store.put_chunk(k, data)                # raw writer
        assert store.get_chunk(k) == data
        store.delete_chunk(k)
        cs = CompressedStore(store, "zlib")     # compressed writer (escape)
        cs.put_chunk(k, data)
        assert cs.get_chunk(k) == data
        assert store.get_chunk(k) == data
        store.delete_chunk(k)


def test_session_end_to_end_compressed(store):
    cs = CompressedStore(store, "zlib")
    s = _delta_session(cs)
    c1 = s.run("init", seed=1)
    snap1 = _snap(s)
    c2 = s.run("mutate", seed=7)
    snap2 = _snap(s)
    assert s.checkout(c1).covs_patched == 3
    assert _snap(s) == snap1
    s.checkout(c2)
    assert _snap(s) == snap2
    s.close()


def test_open_store_codec_uri(tmp_path):
    cs = open_store(f"sqlite://{tmp_path}/c.db?codec=zlib")
    assert isinstance(cs, CompressedStore)
    with pytest.raises(ValueError):
        open_store("memory://?codec=nope")


# ---------------------------------------------------------------------------
# shared chunk cache (writer <-> loader coherence)
# ---------------------------------------------------------------------------

def test_checkout_of_just_committed_state_never_touches_backend():
    inner = MemoryStore()
    # every backend read fails: only the shared cache can serve checkout
    dark = FaultInjectedStore(inner, fail_get=lambda k: True)
    s = _delta_session(dark, cache_bytes=64 << 20)
    c1 = s.run("init", seed=1)
    snap1 = _snap(s)
    s.run("mutate", seed=3)
    st = s.checkout(c1)
    assert st.bytes_loaded == 0                 # zero backend bytes
    assert st.bytes_cached > 0
    assert st.covs_recomputed == 0
    assert _snap(s) == snap1
    s.close()


def test_cache_lru_eviction_bounds_memory():
    from repro.core import ChunkCache
    c = ChunkCache(max_bytes=3000)
    c.put("a", b"x" * 1000)
    c.put("b", b"y" * 1000)
    c.put("c", b"z" * 1000)
    assert c.get("a") is not None               # refresh a
    c.put("d", b"w" * 1000)                     # evicts b (LRU)
    assert c.get("b") is None
    assert c.get("a") is not None and c.get("d") is not None
    assert c.bytes_used <= 3000
    c.put("huge", b"h" * 5000)                  # larger than capacity: skip
    assert c.get("huge") is None


def test_cache_disabled_session_hits_backend(store):
    s = _delta_session(store, cache_bytes=0)
    c1 = s.run("init", seed=1)
    s.run("mutate", seed=2)
    st = s.checkout(c1)
    assert st.bytes_cached == 0 and st.bytes_loaded > 0
    s.close()
