"""Baseline correctness: DumpSession, PageIncremental, DetReplay."""
import numpy as np
import pytest

from repro.core import MemoryStore, Namespace, OpaqueLeaf
from repro.core.baselines import DetReplaySession, DumpSession, PageIncremental


def _ns(**kw):
    ns = Namespace()
    for k, v in kw.items():
        ns[k] = v
    return ns


def test_dumpsession_roundtrip():
    store = MemoryStore()
    d = DumpSession(store)
    ns = _ns(a=np.arange(10, dtype=np.float32), b=np.ones(5))
    st = d.checkpoint(ns, "t1")
    assert not st.failed and st.bytes_written > 0
    ns["a"] = ns["a"] * 3
    d.checkout(ns, "t1")
    assert np.array_equal(ns["a"], np.arange(10, dtype=np.float32))


def test_dumpsession_fails_on_opaque():
    d = DumpSession(MemoryStore())
    st = d.checkpoint(_ns(g=OpaqueLeaf()), "t1")
    assert st.failed                     # like dill on unserializable data


def test_page_incremental_stores_only_dirty_pages():
    store = MemoryStore()
    p = PageIncremental(store)
    big = np.zeros(1 << 16, np.uint8)    # 64 KB
    ns = _ns(big=big, small=np.zeros(16, np.uint8))
    st1 = p.checkpoint(ns, "t1", parent=None)
    ns["small"] = ns["small"] + 1        # dirty a few pages only
    st2 = p.checkpoint(ns, "t2", parent="t1")
    assert st2.bytes_written < st1.bytes_written / 4
    ns["small"] = ns["small"] * 0
    p.checkout(ns, "t2")
    assert ns["small"][0] == 1
    p.checkout(ns, "t1")
    assert ns["small"][0] == 0


def test_page_incremental_fragmentation_hurts():
    """A tiny logical change that shifts offsets dirties many pages —
    the paper's §2.3 criticism of page-granularity deltas."""
    store = MemoryStore()
    p = PageIncremental(store)
    rng = np.random.default_rng(0)
    arrs = {f"k{i:02d}": rng.integers(0, 256, 3000).astype(np.uint8)
            for i in range(20)}
    ns = _ns(**arrs)
    p.checkpoint(ns, "t1", parent=None)
    # in-place change of ONE array -> only its pages dirty
    ns["k10"] = ns["k10"] ^ 1
    st = p.checkpoint(ns, "t2", parent="t1")
    inplace_bytes = st.bytes_written
    # now *grow* an early array: every later offset shifts -> most pages dirty
    ns["k00"] = rng.integers(0, 256, 3001).astype(np.uint8)
    st = p.checkpoint(ns, "t3", parent="t2")
    assert st.bytes_written > 5 * inplace_bytes


def test_detreplay_skips_storage_and_replays():
    s = DetReplaySession(MemoryStore())

    def det_step(ns):
        ns["w"] = ns["w"] * 2.0
    s.register("det_step", det_step, deterministic=True)
    s.init_state({"w": np.ones(1000, np.float32)})
    base_bytes = s.store.chunk_bytes_total()
    c1 = s.run("det_step")
    assert s.store.chunk_bytes_total() == base_bytes   # nothing stored
    c2 = s.run("det_step")
    s.checkout(c1)                                     # restores via replay
    assert float(s.ns["w"][0]) == 2.0
    assert s.restorer.replays >= 1
