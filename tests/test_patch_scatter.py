"""Fused device-scatter checkout: kernel parity, dtype round-trips, the
patch_device_chunks contract + fallback ladder, and end-to-end checkout
bit-identity with the scatter forced on (fast lane).

The invariant under test everywhere: scattering the dirty chunks of a
co-variable in ONE pass (kernels/patch_scatter, Pallas via interpret on
CPU) restores exactly the bytes the per-chunk ``dynamic_update_slice``
loop would have — on every supported dtype, alignment and tail shape —
and every reason the fused path disengages routes through
``note_kernel_fallback`` instead of dying or silently corrupting.
"""
import numpy as np
import pytest

from repro.core import delta as delta_mod
from repro.kernels.patch_scatter.ops import scatter_chunks

BACKENDS = ["ref", "pallas"]


def _scatter(x, idx, blobs, cb, backend):
    kw = {"interpret": True} if backend == "pallas" else {}
    return scatter_chunks(x, idx, blobs, cb, backend=backend, **kw)


# ------------------------------------------------------------ kernel parity

@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("n,cb,dirty", [
    (4096, 256, [0]),
    (4096, 256, [0, 3, 15]),
    (4096, 256, list(range(16))),         # every chunk dirty
    (1000, 256, [1, 3]),                  # ragged tail chunk clean
    (1000, 256, [3]),                     # ragged tail chunk dirty
    (100, 256, [0]),                      # single short chunk
])
def test_scatter_matches_dus(backend, n, cb, dirty):
    import jax.numpy as jnp

    rng = np.random.default_rng(n + len(dirty))
    base_np = rng.integers(0, 2**31, n // 4, dtype=np.int64) \
        .astype(np.int32)
    base = jnp.asarray(base_np)
    blobs, segs = [], []
    for i in dirty:
        lo, hi = i * cb, min((i + 1) * cb, n)
        blob = rng.integers(0, 256, hi - lo, dtype=np.uint8).tobytes()
        blobs.append(blob)
        segs.append((lo, blob))
    got, moved = _scatter(base, dirty, blobs, cb, backend)
    want = delta_mod.patch_device_array(base, segs)
    assert np.array_equal(np.asarray(got), np.asarray(want))
    assert got.dtype == base.dtype and got.shape == base.shape
    assert moved >= sum(len(b) for b in blobs)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("dtype", ["uint8", "int8", "uint16", "int16",
                                   "float16", "uint32", "int32", "float32"])
def test_scatter_roundtrip_dtypes(backend, dtype):
    import jax.numpy as jnp

    rng = np.random.default_rng(7)
    n = 777
    item = np.dtype(dtype).itemsize
    base_np = rng.integers(0, 250, n * item, dtype=np.uint8) \
        .view(dtype)[:n].copy()
    target_np = base_np.copy()
    cb = 64
    blobs, idx = [], []
    for i in (0, 3, (n * item - 1) // cb):
        lo, hi = i * cb, min((i + 1) * cb, n * item)
        blob = rng.integers(0, 250, hi - lo, dtype=np.uint8).tobytes()
        view = target_np.view(np.uint8)
        view[lo:hi] = np.frombuffer(blob, np.uint8)
        blobs.append(blob)
        idx.append(i)
    got, _ = _scatter(jnp.asarray(base_np), idx, blobs, cb, backend)
    assert np.asarray(got).tobytes() == target_np.tobytes()


@pytest.mark.parametrize("dtype", ["uint64", "int64", "float64"])
def test_scatter_roundtrip_wide_dtypes(dtype):
    from jax.experimental import enable_x64
    import jax.numpy as jnp

    rng = np.random.default_rng(11)
    n = 130
    item = np.dtype(dtype).itemsize
    base_np = rng.integers(0, 250, n * item, dtype=np.uint8) \
        .view(dtype)[:n].copy()
    target_np = base_np.copy()
    cb = 128
    blob = rng.integers(0, 250, cb, dtype=np.uint8).tobytes()
    target_np.view(np.uint8)[cb:2 * cb] = np.frombuffer(blob, np.uint8)
    with enable_x64():
        got, _ = _scatter(jnp.asarray(base_np), [1], [blob], cb, "pallas")
        assert np.asarray(got).tobytes() == target_np.tobytes()
        assert got.dtype == base_np.dtype


@pytest.mark.parametrize("backend", BACKENDS)
def test_scatter_contract_violations(backend):
    import jax.numpy as jnp

    x = jnp.arange(1024, dtype=jnp.int32)
    blob = b"\0" * 256
    with pytest.raises(ValueError):
        _scatter(x, [99], [blob], 256, backend)      # index out of range
    with pytest.raises(ValueError):
        _scatter(x, [0], [blob], 255, backend)       # unaligned chunk size
    got, moved = _scatter(x, [], [], 256, backend)   # no-op
    assert moved == 0 and np.array_equal(np.asarray(got), np.asarray(x))


# --------------------------------------------- patch_device_chunks contract

def _covs(monkeypatch):
    monkeypatch.setenv("KISHU_DEVICE_SCATTER", "1")


def test_patch_device_chunks_applies(monkeypatch):
    import jax.numpy as jnp

    _covs(monkeypatch)
    base = jnp.asarray(np.arange(4096, dtype=np.int32))
    cb = 1024
    blob = (np.full(cb // 4, 9, np.int32)).tobytes()
    out = delta_mod.patch_device_chunks(base, [(cb, blob)], cb)
    assert out is not None
    patched, moved = out
    want = np.arange(4096, dtype=np.int32)
    want[cb // 4: 2 * cb // 4] = 9
    assert np.array_equal(np.asarray(patched), want)
    assert moved >= len(blob)


@pytest.mark.parametrize("case", ["env_off", "host_array", "unaligned_off",
                                  "short_seg", "bad_chunk_bytes", "bool",
                                  "complex"])
def test_patch_device_chunks_disengages(monkeypatch, case):
    import jax.numpy as jnp

    _covs(monkeypatch)
    cb = 1024
    base = jnp.asarray(np.arange(4096, dtype=np.int32))
    segs = [(cb, b"\x09" * cb)]
    if case == "env_off":
        monkeypatch.setenv("KISHU_DEVICE_SCATTER", "0")
    elif case == "host_array":
        base = np.arange(4096, dtype=np.int32)
    elif case == "unaligned_off":
        segs = [(cb + 4, b"\x09" * cb)]
    elif case == "short_seg":
        segs = [(cb, b"\x09" * (cb - 8))]
    elif case == "bad_chunk_bytes":
        cb = 1022
        segs = [(0, b"\x09" * cb)]
    elif case == "bool":
        base = jnp.asarray(np.ones(4096, bool))
        segs = [(cb, b"\x01" * cb)]
    elif case == "complex":
        # _to_words can't bitcast complex: the fused path must bow out
        base = jnp.asarray(np.zeros(1024, np.complex64))
        segs = [(cb, b"\x01" * cb)]
    assert delta_mod.patch_device_chunks(base, segs, cb) is None


def test_bool_and_complex128_fall_back_to_dus():
    """dtypes the word bitcast can't express still checkout correctly via
    the per-chunk DUS loop — the ladder degrades, never corrupts."""
    import jax.numpy as jnp

    base = jnp.asarray(np.zeros(4096, bool))
    blob = b"\x01" * 1024
    out = delta_mod.patch_device_array(base, [(1024, blob)])
    want = np.zeros(4096, bool)
    want[1024:2048] = True
    assert np.array_equal(np.asarray(out), want)


# ------------------------------------------------- end-to-end checkout path

def _mk_session(store, monkeypatch, scatter="1"):
    import jax.numpy as jnp

    from repro.core import KishuSession

    monkeypatch.setenv("KISHU_DEVICE_DELTA", "1")
    monkeypatch.setenv("KISHU_DEVICE_HASH", "1")
    monkeypatch.setenv("KISHU_DEVICE_CODEC", "1")
    monkeypatch.setenv("KISHU_DEVICE_SCATTER", scatter)
    sess = KishuSession(store, chunk_bytes=4096, cache_bytes=0)

    def init(ns):
        ns["v"] = jnp.arange(1 << 14, dtype=jnp.int32) % 89
        ns["w"] = jnp.arange(1 << 13, dtype=jnp.float32)

    def mutate(ns, seed):
        idx = jnp.arange(3) * 1024
        ns["v"] = ns["v"].at[idx].set(seed)
        ns["w"] = ns["w"].at[idx[:2]].set(float(seed))

    sess.register("init", init)
    sess.register("mutate", mutate)
    sess.init_state({})
    sess.run("init")
    return sess


def test_checkout_scatter_bit_identity(tmp_path, monkeypatch):
    """Same commits restored with the fused scatter forced on vs off must
    be byte-identical, and the scatter must cover every patched cov while
    accounting its host→device upload."""
    from repro.core import MemoryStore

    runs = {}
    for scatter in ("0", "1"):
        sess = _mk_session(MemoryStore(), monkeypatch, scatter=scatter)
        cids = [sess.run("mutate", seed=s) for s in (5, 6, 7)]
        states, scattered, h2d = [], 0, 0
        for cid in cids:
            st = sess.checkout(cid)
            scattered += st.covs_scattered
            h2d += st.bytes_host2dev
            assert st.covs_patched > 0
            states.append({n: np.asarray(sess.ns[n]).tobytes()
                           for n in sess.ns.names()})
        runs[scatter] = (states, scattered, h2d)
        if scatter == "1":
            assert scattered > 0 and h2d > 0
        else:
            assert scattered == 0
        sess.close()
    assert runs["0"][0] == runs["1"][0]


def test_fetch_patch_chunks_fallback_routes_through_counter(tmp_path,
                                                            monkeypatch):
    """A missing patch chunk must demote to a full-cov load *and* count as
    a kernel fallback (observable), not silently degrade."""
    from repro.core import MemoryStore

    store = MemoryStore()
    sess = _mk_session(store, monkeypatch)
    cid = sess.run("mutate", seed=3)
    sess.run("mutate", seed=4)

    # drop one chunk the patch planner will want for the checkout of `cid`
    man = sess.graph.nodes[cid].manifests
    victim = None
    for ks, m in man.items():
        for c in m["base"]["chunks"]:
            victim = c["key"]
            break
        break
    assert victim is not None
    del store.chunks[victim]

    fb0 = delta_mod._kernel_fallbacks
    st = sess.checkout(cid)                  # must still restore (recompute
    assert delta_mod._kernel_fallbacks > fb0  # or full load), and count
    want = np.arange(1 << 14, dtype=np.int32) % 89
    want[np.arange(3) * 1024] = 3
    assert np.array_equal(np.asarray(sess.ns["v"]), want)
    sess.close()
