"""Parallel chunk I/O engine: batched store ops, bit-identical parallel
checkout on every backend, and fault/latency injection under parallel fetch
(chunk loss -> fallback recomputation, slow hosts -> bandwidth not
round-trips; never crashes or deadlocks)."""
import time

import numpy as np
import pytest

from repro.core import (FaultInjectedStore, KishuSession, MemoryStore,
                        ChunkMissingError)
from repro.core.chunkstore import (DirectoryStore, SQLiteStore, chunk_key)
from repro.core import parallel


def make_store(kind, tmp_path):
    if kind == "memory":
        return MemoryStore()
    if kind == "dir":
        return DirectoryStore(str(tmp_path / "cas"))
    return SQLiteStore(str(tmp_path / "cas.db"))


@pytest.fixture(params=["memory", "dir", "sqlite"])
def store(request, tmp_path):
    return make_store(request.param, tmp_path)


# ---------------------------------------------------------------------------
# batched backend ops
# ---------------------------------------------------------------------------

def test_put_get_chunks_roundtrip(store):
    pairs = [(chunk_key(bytes([i]) * 100), bytes([i]) * 100)
             for i in range(20)]
    assert store.put_chunks(pairs) == 20
    assert store.put_chunks(pairs) == 0            # CAS dedup, batched
    got = store.get_chunks([k for k, _ in pairs])
    assert got == dict(pairs)
    assert sorted(store.list_chunk_keys()) == sorted(k for k, _ in pairs)


def test_get_chunks_missing(store):
    k = chunk_key(b"present")
    store.put_chunk(k, b"present")
    ghost = "deadbeef" * 4
    assert store.get_chunks([k, ghost], missing_ok=True) == {k: b"present"}
    with pytest.raises(ChunkMissingError):
        store.get_chunks([k, ghost])


def test_get_chunks_duplicate_keys(store):
    k = chunk_key(b"x" * 50)
    store.put_chunk(k, b"x" * 50)
    assert store.get_chunks([k, k, k]) == {k: b"x" * 50}


def test_list_chunk_keys_empty(store):
    assert store.list_chunk_keys() == []


def test_chunk_sizes(store):
    pairs = [(chunk_key(bytes([i]) * (10 + i)), bytes([i]) * (10 + i))
             for i in range(5)]
    store.put_chunks(pairs)
    sizes = store.chunk_sizes([k for k, _ in pairs] + ["feedbeef" * 4])
    assert sizes == {k: len(d) for k, d in pairs}


def test_fault_wrapper_forwards_engine_hints(tmp_path):
    sq = FaultInjectedStore(SQLiteStore(str(tmp_path / "h.db")))
    assert sq.min_slab == SQLiteStore.min_slab
    assert sq.supports_parallel_get
    mem = FaultInjectedStore(MemoryStore())
    assert not mem.supports_parallel_get       # RAM: nothing to overlap
    slow = FaultInjectedStore(MemoryStore(), read_delay=0.001)
    assert slow.supports_parallel_get          # injected round trip


def test_sqlite_batch_larger_than_in_clause_limit(tmp_path):
    store = SQLiteStore(str(tmp_path / "big.db"))
    pairs = [(chunk_key(str(i).encode()), str(i).encode())
             for i in range(1203)]                  # > 2 x _SQL_BATCH
    assert store.put_chunks(pairs) == len(pairs)
    got = store.get_chunks([k for k, _ in pairs])
    assert len(got) == len(pairs)


# ---------------------------------------------------------------------------
# parallel executor primitives
# ---------------------------------------------------------------------------

def test_prefetch_map_yields_all_results():
    out = sorted(parallel.prefetch_map(lambda x: x * 2, range(50), 8))
    assert out == [x * 2 for x in range(50)]


def test_prefetch_map_serial_fallback():
    assert list(parallel.prefetch_map(lambda x: x + 1, [1, 2, 3], 1)) \
        == [2, 3, 4]


def test_prefetch_map_propagates_exceptions():
    def boom(x):
        if x == 7:
            raise ValueError("x7")
        return x
    with pytest.raises(ValueError):
        list(parallel.prefetch_map(boom, range(20), 4))


def test_map_parallel_ordered():
    assert parallel.map_parallel(lambda x: -x, list(range(40)), 8) \
        == [-x for x in range(40)]


def test_no_nested_pools():
    def outer(_):
        assert parallel.in_io_worker()
        # nested call must degrade to serial, not spawn another pool
        return parallel.map_parallel(lambda y: y, [1, 2, 3], 8)
    assert parallel.map_parallel(outer, [0, 1], 2) == [[1, 2, 3]] * 2


def test_iter_slabs_preserve_order():
    slabs = list(parallel.iter_slabs(list(range(10)), 4))
    assert slabs == [[0, 1, 2, 3], [4, 5, 6, 7], [8, 9]]


# ---------------------------------------------------------------------------
# parallel checkout == serial checkout, bit for bit, on all backends
# ---------------------------------------------------------------------------

N_VARS = 6
N_ELEMS = 4000          # x float32 = 16 KB -> 16 chunks at 1 KB each


def build_session(store, io_threads):
    # cache_bytes=0: these tests measure the *backend* I/O engine; the
    # shared chunk cache would serve just-written chunks from memory
    s = KishuSession(store, chunk_bytes=1 << 10, io_threads=io_threads,
                     cache_bytes=0)
    s.loader.probe_threshold_s = 0.0     # always engage the pipeline

    def step(ns, seed):
        rng = np.random.default_rng(seed)
        for i in range(N_VARS):
            ns[f"v{i}"] = rng.standard_normal(N_ELEMS).astype(np.float32)
    s.register("step", step)
    s.init_state({})
    return s


def snapshot(sess):
    return {n: np.asarray(sess.ns[n]).tobytes() for n in sess.ns.names()}


def test_parallel_checkout_bit_identical_to_serial(store):
    s = build_session(store, io_threads=8)
    c1 = s.run("step", seed=1)
    c2 = s.run("step", seed=2)

    s.loader.io_threads = 1                  # serial reference restore
    s.checkout(c1)
    ref = snapshot(s)
    s.checkout(c2)

    s.loader.io_threads = 8                  # engine restore
    st = s.checkout(c1)
    assert snapshot(s) == ref
    assert st.covs_loaded == N_VARS and st.covs_recomputed == 0
    assert st.bytes_loaded == N_VARS * N_ELEMS * 4


def test_parallel_checkout_deterministic_across_runs(store):
    s = build_session(store, io_threads=8)
    c1 = s.run("step", seed=1)
    c2 = s.run("step", seed=2)
    snaps = []
    for _ in range(3):
        s.checkout(c1)
        snaps.append(snapshot(s))
        s.checkout(c2)
    assert snaps[0] == snaps[1] == snaps[2]


def test_materialize_state_parallel(store):
    s = build_session(store, io_threads=8)
    c1 = s.run("step", seed=3)
    s.run("step", seed=4)
    s.loader.io_threads = 1
    s.loader.materialize_state(s.tracked, c1)
    ref = snapshot(s)
    s.loader.io_threads = 8
    from repro.core.namespace import Namespace, TrackedNamespace
    fresh = TrackedNamespace(Namespace())
    records, st = s.loader.materialize_state(fresh, c1)
    assert {n: np.asarray(fresh.base[n]).tobytes()
            for n in fresh.base.names()} == ref
    assert set(records) == set(f"v{i}" for i in range(N_VARS))


# ---------------------------------------------------------------------------
# fault injection under parallel fetch
# ---------------------------------------------------------------------------

def chunk_keys_of(sess, commit):
    out = []
    for man in sess.graph.nodes[commit].manifests.values():
        if man.get("unserializable"):
            continue
        out.extend(c["key"] for c in man["base"]["chunks"])
    return out


def test_chunk_loss_falls_back_to_recompute():
    bad = set()
    # read_delay: a slow host, so the wrapper advertises parallel fetch and
    # the loss is hit inside the pipeline, not the serial path
    store = FaultInjectedStore(MemoryStore(), fail_get=lambda k: k in bad,
                               read_delay=0.0005)
    s = build_session(store, io_threads=8)
    c1 = s.run("step", seed=1)
    c2 = s.run("step", seed=2)

    s.checkout(c1)
    ref = snapshot(s)
    s.checkout(c2)

    lost = chunk_keys_of(s, c1)
    bad.update(lost[::3])                    # drop a third of c1's chunks
    st = s.checkout(c1)
    assert snapshot(s) == ref                # recomputed, still bit-exact
    assert st.covs_recomputed > 0


def test_total_chunk_loss_still_restores():
    bad = set()
    store = FaultInjectedStore(MemoryStore(), fail_get=lambda k: k in bad,
                               read_delay=0.0005)
    s = build_session(store, io_threads=8)
    c1 = s.run("step", seed=5)
    c2 = s.run("step", seed=6)
    s.checkout(c1)
    ref = snapshot(s)
    s.checkout(c2)
    bad.update(chunk_keys_of(s, c1))         # every chunk of the target
    st = s.checkout(c1)
    assert snapshot(s) == ref
    assert st.covs_recomputed == N_VARS


def test_slow_host_parallel_fetch_beats_serial():
    """Per-chunk read latency dominates: the engine overlaps it; must also
    stay bit-exact and finish (no deadlock under delay injection)."""
    delay = 0.004
    store = FaultInjectedStore(MemoryStore(), read_delay=delay)
    s = build_session(store, io_threads=8)
    c1 = s.run("step", seed=1)
    c2 = s.run("step", seed=2)

    s.loader.io_threads = 1
    t0 = time.perf_counter()
    s.checkout(c1)
    serial_s = time.perf_counter() - t0
    ref = snapshot(s)
    s.checkout(c2)

    s.loader.io_threads = 8
    t0 = time.perf_counter()
    s.checkout(c1)
    parallel_s = time.perf_counter() - t0
    assert snapshot(s) == ref
    # ~96 chunks x 4ms serial vs 8-way overlap: generous 0.6 margin
    assert parallel_s < serial_s * 0.6, (serial_s, parallel_s)


def test_slow_host_with_chunk_loss_no_deadlock():
    bad = set()
    store = FaultInjectedStore(MemoryStore(), read_delay=0.002,
                               fail_get=lambda k: k in bad)
    s = build_session(store, io_threads=8)
    c1 = s.run("step", seed=7)
    c2 = s.run("step", seed=8)
    s.checkout(c1)
    ref = snapshot(s)
    s.checkout(c2)
    bad.update(chunk_keys_of(s, c1)[::5])
    st = s.checkout(c1)                      # completes: no deadlock
    assert snapshot(s) == ref
    assert st.covs_recomputed > 0


# ---------------------------------------------------------------------------
# adaptive engagement probe
# ---------------------------------------------------------------------------

def pipeline_spy(monkeypatch):
    calls = []
    real = parallel.prefetch_map

    def spy(fn, items, max_workers=None, window=None):
        calls.append(True)
        return real(fn, items, max_workers, window)
    monkeypatch.setattr(parallel, "prefetch_map", spy)
    return calls


def test_probe_engages_pipeline_on_slow_store(monkeypatch):
    calls = pipeline_spy(monkeypatch)
    store = FaultInjectedStore(MemoryStore(), read_delay=0.005)
    s = build_session(store, io_threads=4)
    s.loader.probe_threshold_s = 1e-3    # default adaptive threshold
    c1 = s.run("step", seed=1)
    s.run("step", seed=2)
    s.checkout(c1)
    assert calls                         # 5ms/chunk >> threshold: parallel


def test_probe_stays_serial_on_fast_store(monkeypatch):
    calls = pipeline_spy(monkeypatch)
    # tiny delay keeps the wrapper parallel-capable; the threshold decides
    store = FaultInjectedStore(MemoryStore(), read_delay=1e-5)
    s = build_session(store, io_threads=4)
    s.loader.probe_threshold_s = float("inf")     # force bandwidth-bound
    c1 = s.run("step", seed=1)
    s.run("step", seed=2)
    st = s.checkout(c1)
    assert not calls                     # degraded to serial slab loop
    assert st.covs_loaded == N_VARS      # ...and still restored everything


# ---------------------------------------------------------------------------
# batched writer
# ---------------------------------------------------------------------------

def test_sync_write_durable_on_return(store):
    s = build_session(store, io_threads=8)
    c1 = s.run("step", seed=1)
    for k in chunk_keys_of(s, c1):           # batch landed before run returned
        assert store.has_chunk(k)


def test_async_write_batched_drain(store):
    s = KishuSession(store, chunk_bytes=1 << 10, async_write=True,
                     io_threads=8)

    def step(ns, seed):
        rng = np.random.default_rng(seed)
        for i in range(N_VARS):
            ns[f"v{i}"] = rng.standard_normal(N_ELEMS).astype(np.float32)
    s.register("step", step)
    s.init_state({})
    c1 = s.run("step", seed=1)
    c2 = s.run("step", seed=2)
    s.writer.flush()
    for k in chunk_keys_of(s, c1) + chunk_keys_of(s, c2):
        assert store.has_chunk(k)
    s.checkout(c1)
    assert float(np.asarray(s.ns["v0"])[0]) == pytest.approx(float(
        np.random.default_rng(1).standard_normal(N_ELEMS).astype(
            np.float32)[0]))
    s.close()


def test_writer_no_double_write_within_delta():
    """Identical content appearing twice in one delta is written once even
    though puts are deferred into the batch."""
    store = MemoryStore()
    s = KishuSession(store, chunk_bytes=1 << 10)

    def twins(ns):
        ns["a"] = np.ones(N_ELEMS, np.float32)
        ns["b"] = np.ones(N_ELEMS, np.float32)   # same bytes, distinct cov
    s.register("twins", twins)
    s.init_state({})
    s.run("twins")
    ws = s.last_run.write
    assert ws.chunks_dedup > 0
    assert ws.chunks_written * (1 << 10) <= N_ELEMS * 4
