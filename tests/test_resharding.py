"""Elastic restore: byte-range chunk selection, shard-local loads,
mesh-independent manifests."""
import numpy as np
import pytest

from repro.core import KishuSession, MemoryStore
from repro.sharding.resharding import (chunks_for_range, elastic_restore_leaf,
                                       load_byte_range)


@pytest.fixture
def committed():
    s = KishuSession(MemoryStore(), chunk_bytes=1 << 10)

    def put(ns):
        ns["w"] = np.arange(2000, dtype=np.float32)   # 8000 B -> 8 chunks
    s.register("put", put)
    s.init_state({})
    cid = s.run("put")
    man = s.graph.manifest_of(("w",), cid)
    return s, man


def test_chunks_for_range(committed):
    _, man = committed
    assert chunks_for_range(man, 0, 1024) == [0]
    assert chunks_for_range(man, 1023, 1025) == [0, 1]
    assert chunks_for_range(man, 4096, 8000) == [4, 5, 6, 7]


def test_load_byte_range_matches_full(committed):
    s, man = committed
    full = np.arange(2000, dtype=np.float32).tobytes()
    for lo, hi in [(0, 8000), (0, 1024), (512, 2048), (7000, 8000),
                   (1, 2), (4095, 4097)]:
        got = load_byte_range(s.store, man, lo, hi)
        assert got == full[lo:hi], (lo, hi)


def test_shard_local_reads_touch_only_needed_chunks(committed):
    s, man = committed
    # drop chunks outside the requested range; the read must still succeed
    keep = set(c["key"] for i, c in enumerate(man["base"]["chunks"])
               if i in (2, 3))
    for c in man["base"]["chunks"]:
        if c["key"] not in keep:
            s.store.delete_chunk(c["key"])
    got = load_byte_range(s.store, man, 2048, 4096)
    want = np.arange(2000, dtype=np.float32).tobytes()[2048:4096]
    assert got == want


def test_elastic_restore_leaf(committed):
    s, man = committed
    leaf = elastic_restore_leaf(s.store, man)
    assert np.array_equal(leaf, np.arange(2000, dtype=np.float32))
