"""Storage fabric: hash ring, sharded scatter-gather, replicated
read-repair, tiered promotion/demotion, fabric:// topologies, fleet ops
(topology / scrub / rebalance), and session-level fault tolerance."""
import os
import shutil

import numpy as np
import pytest

from repro.core import (FaultInjectedStore, KishuSession, MemoryStore,
                        ReplicatedStore, ShardedStore, TieredStore,
                        open_store, rebalance, scrub)
from repro.core.chunkstore import DirectoryStore, chunk_key
from repro.core.fabric import HashRing, parse_size, parse_topology
from repro.core.serialize import ChunkMissingError
from repro.launch.kishu_cli import main as cli


def _pairs(n, tag="chunk"):
    out = []
    for i in range(n):
        d = f"{tag}-{i}".encode() * 7
        out.append((chunk_key(d), d))
    return out


# ---------------------------------------------------------------------------
# hash ring
# ---------------------------------------------------------------------------

def test_ring_deterministic_and_covering():
    r1, r2 = HashRing(4), HashRing(4)
    keys = [chunk_key(bytes([i & 255, i >> 8])) for i in range(1000)]
    homes = [r1.shard_for(k) for k in keys]
    assert homes == [r2.shard_for(k) for k in keys]      # deterministic
    counts = [homes.count(s) for s in range(4)]
    assert all(c > 100 for c in counts), counts          # roughly uniform


def test_ring_consistency_on_growth():
    """Adding one shard must move only a minority of keys (the consistent-
    hashing contract rebalance relies on)."""
    keys = [chunk_key(bytes([i & 255, i >> 8])) for i in range(2000)]
    r4, r5 = HashRing(4), HashRing(5)
    moved = sum(r4.shard_for(k) != r5.shard_for(k) for k in keys)
    assert 0 < moved < len(keys) // 2, moved


def test_ring_rejects_empty():
    with pytest.raises(ValueError):
        HashRing(0)


# ---------------------------------------------------------------------------
# sharded store
# ---------------------------------------------------------------------------

def test_sharded_roundtrip_and_placement():
    shards = [MemoryStore() for _ in range(4)]
    ss = ShardedStore(shards)
    pairs = _pairs(100)
    assert ss.put_chunks(pairs) == 100
    assert ss.get_chunks([k for k, _ in pairs]) == dict(pairs)
    assert ss.n_chunks() == 100
    assert sum(s.n_chunks() for s in shards) == 100      # no duplication
    assert all(s.n_chunks() > 0 for s in shards)         # all shards used
    for k, _ in pairs:                                   # ring placement
        assert shards[ss.home(k)].has_chunk(k)


def test_sharded_single_ops_and_missing():
    ss = ShardedStore([MemoryStore(), MemoryStore()])
    k, d = _pairs(1)[0]
    assert ss.put_chunk(k, d) is True
    assert ss.put_chunk(k, d) is False                   # CAS dedup
    assert ss.get_chunk(k) == d
    assert ss.has_chunk(k)
    with pytest.raises(ChunkMissingError):
        ss.get_chunk("f" * 32)
    assert ss.get_chunks(["f" * 32], missing_ok=True) == {}
    with pytest.raises(ChunkMissingError):
        ss.get_chunks([k, "f" * 32])


def test_sharded_stray_read_heals_placement():
    """A chunk sitting on the wrong shard (ring change, manual surgery) is
    served, copied home, and removed from the stray shard."""
    shards = [MemoryStore() for _ in range(3)]
    ss = ShardedStore(shards)
    k, d = _pairs(1, "stray")[0]
    stray = (ss.home(k) + 1) % 3
    shards[stray].put_chunk(k, d)
    assert ss.get_chunk(k) == d
    assert ss.heals == 1
    assert shards[ss.home(k)].has_chunk(k)
    assert not shards[stray].has_chunk(k)
    # batched path heals too
    k2, d2 = _pairs(1, "stray2")[0]
    stray2 = (ss.home(k2) + 1) % 3
    shards[stray2].put_chunk(k2, d2)
    assert ss.get_chunks([k, k2]) == {k: d, k2: d2}
    assert shards[ss.home(k2)].has_chunk(k2)
    assert not shards[stray2].has_chunk(k2)


def test_sharded_meta_mirrored_survives_shard_loss():
    shards = [MemoryStore() for _ in range(3)]
    ss = ShardedStore(shards)
    ss.put_meta("commit/c1", {"a": 1})
    ss.put_meta("HEAD", {"head": "c1"})
    shards[0].meta.clear()                               # lose one shard
    assert ss.get_meta("commit/c1") == {"a": 1}
    assert ss.list_meta("commit/") == ["commit/c1"]


def test_sharded_delete_sweeps_strays():
    shards = [MemoryStore() for _ in range(2)]
    ss = ShardedStore(shards)
    k, d = _pairs(1)[0]
    shards[0].put_chunk(k, d)
    shards[1].put_chunk(k, d)                            # stray copy too
    ss.delete_chunk(k)
    assert not any(s.has_chunk(k) for s in shards)
    pairs = _pairs(20)
    ss.put_chunks(pairs)
    assert ss.delete_chunks([k for k, _ in pairs]) == 20
    assert ss.n_chunks() == 0


# ---------------------------------------------------------------------------
# replicated store
# ---------------------------------------------------------------------------

def test_replicated_writes_land_everywhere():
    reps = [MemoryStore() for _ in range(3)]
    rs = ReplicatedStore(reps)
    pairs = _pairs(25)
    assert rs.put_chunks(pairs) == 25
    assert all(r.n_chunks() == 25 for r in reps)
    assert rs.n_chunks() == 25                           # logical, not 75


def test_replicated_read_repair_on_lost_replica():
    reps = [MemoryStore() for _ in range(2)]
    rs = ReplicatedStore(reps)
    pairs = _pairs(30)
    rs.put_chunks(pairs)
    reps[0].chunks.clear()                               # replica 0 dies
    assert rs.get_chunks([k for k, _ in pairs]) == dict(pairs)
    assert rs.replica_misses == 30
    assert rs.repairs == 30
    assert reps[0].n_chunks() == 30                      # healed in place
    assert scrub(rs).problems == 0


def test_replicated_serves_through_injected_fault():
    """FaultInjectedStore killing one replica: every read still succeeds."""
    healthy = MemoryStore()
    dead = FaultInjectedStore(MemoryStore(), fail_get=lambda k: True)
    rs = ReplicatedStore([dead, healthy])
    pairs = _pairs(10)
    rs.put_chunks(pairs)
    assert rs.get_chunk(pairs[0][0]) == pairs[0][1]
    assert rs.get_chunks([k for k, _ in pairs]) == dict(pairs)


def test_replicated_write_survives_dead_replica():
    """A replica whose writes *raise* (full/read-only disk) must not take
    down checkpointing: the write lands on the live replicas and the dead
    one heals later via read-repair/scrub."""
    class BrokenWrites(MemoryStore):
        def put_chunk(self, key, data):
            raise OSError("disk full")

        def put_chunks(self, pairs):
            raise OSError("disk full")

    healthy = MemoryStore()
    rs = ReplicatedStore([BrokenWrites(), healthy])
    pairs = _pairs(8)
    assert rs.put_chunks(pairs) == 8
    k, d = _pairs(1, "single")[0]
    assert rs.put_chunk(k, d) is True
    assert healthy.n_chunks() == 9
    assert rs.write_errors == 2
    assert rs.get_chunks([k for k, _ in pairs]) == dict(pairs)
    # every replica broken -> the write error surfaces
    rs_dead = ReplicatedStore([BrokenWrites(), BrokenWrites()])
    with pytest.raises(OSError):
        rs_dead.put_chunk(k, d)


def test_repair_and_heal_preserve_stored_compression():
    """Read-repair and stray-healing move chunks in *stored* form: a
    compressed chunk must stay compressed on the healed replica/shard."""
    from repro.core import CompressedStore
    data = b"Z" * 8192                                   # very compressible
    k = chunk_key(data)
    # replicated under an outer codec (the fabric://...?codec= shape)
    reps = [MemoryStore() for _ in range(2)]
    cs = CompressedStore(ReplicatedStore(reps), "zlib")
    cs.put_chunk(k, data)
    stored = reps[1].chunks[k]
    assert len(stored) < len(data)
    reps[0].chunks.clear()
    assert cs.get_chunk(k) == data                       # read-repairs
    assert reps[0].chunks[k] == stored                   # byte-identical copy
    # sharded stray heal
    shards = [MemoryStore() for _ in range(2)]
    ss = ShardedStore(shards)
    stray = (ss.home(k) + 1) % 2
    shards[stray].chunks[k] = stored                     # misplaced, framed
    assert ss.get_chunk(k) == data
    assert shards[ss.home(k)].chunks[k] == stored        # moved, still framed


def test_scrub_counts_logical_chunks_once():
    """chunks_checked reports logical chunks, not per-replica/per-level
    physical copies."""
    nested = ShardedStore([
        ReplicatedStore([MemoryStore(), MemoryStore()]),
        ReplicatedStore([MemoryStore(), MemoryStore()])])
    pairs = _pairs(40)
    nested.put_chunks(pairs)
    assert scrub(nested, deep=True).chunks_checked == 40
    assert scrub(nested).chunks_checked == 40


def test_replicated_lost_everywhere_raises():
    rs = ReplicatedStore([MemoryStore(), MemoryStore()])
    with pytest.raises(ChunkMissingError):
        rs.get_chunk("f" * 32)
    with pytest.raises(ChunkMissingError):
        rs.get_chunks(["f" * 32])
    assert rs.get_chunks(["f" * 32], missing_ok=True) == {}


def test_replicated_scrub_repair_heals_partial_loss():
    reps = [MemoryStore() for _ in range(3)]
    rs = ReplicatedStore(reps)
    pairs = _pairs(12)
    rs.put_chunks(pairs)
    for k, _ in pairs[:5]:
        reps[1].delete_chunk(k)
    rep = scrub(rs)
    assert rep.problems == 5 and rep.remaining == 5
    rep = scrub(rs, repair=True)
    assert rep.repaired == 5 and rep.remaining == 0
    assert scrub(rs).problems == 0
    assert all(r.n_chunks() == 12 for r in reps)


# ---------------------------------------------------------------------------
# tiered store
# ---------------------------------------------------------------------------

def test_tiered_write_through_and_promotion():
    cold = MemoryStore()
    ts = TieredStore(cold, hot_bytes=1 << 20)
    pairs = _pairs(10)
    ts.put_chunks(pairs)
    assert cold.n_chunks() == 10                         # durable on cold
    # hot hit: serve without touching cold
    cold.chunks.clear()
    assert ts.get_chunk(pairs[0][0]) == pairs[0][1]
    assert ts.get_chunks([k for k, _ in pairs]) == dict(pairs)


def test_tiered_promotes_on_read_and_bounds_hot():
    cold = MemoryStore()
    pairs = _pairs(50)
    cold_bytes = sum(len(d) for _, d in pairs)
    hot_cap = cold_bytes // 4
    ts = TieredStore(cold, hot_bytes=hot_cap)
    for k, d in pairs:
        cold.put_chunk(k, d)
    for k, d in pairs:                                   # reads promote
        assert ts.get_chunk(k) == d
    assert 0 < ts.hot.bytes_used <= hot_cap              # bounded demotion
    assert cold.n_chunks() == 50                         # demotion = drop


def test_tiered_delete_clears_both_tiers():
    cold = MemoryStore()
    ts = TieredStore(cold, hot_bytes=1 << 20)
    pairs = _pairs(6)
    ts.put_chunks(pairs)
    assert ts.delete_chunks([k for k, _ in pairs[:4]]) == 4
    assert ts.n_chunks() == 2
    for k, _ in pairs[:4]:
        assert not ts.has_chunk(k)
        with pytest.raises(ChunkMissingError):
            ts.get_chunk(k)


def test_tiered_hot_serves_logical_bytes_under_codec():
    """Hot tier caches decoded bytes: a compressed put must read back
    logical content from the hot tier."""
    from repro.core import CompressedStore
    cold = MemoryStore()
    ts = TieredStore(cold, hot_bytes=1 << 20)
    cs = CompressedStore(ts, "zlib")
    data = b"A" * 4096                                   # very compressible
    k = chunk_key(data)
    cs.put_chunk(k, data)
    assert cold.chunk_bytes_total() < len(data)          # stored compressed
    cold.chunks.clear()                                  # force hot path
    assert cs.get_chunk(k) == data


# ---------------------------------------------------------------------------
# topology specs
# ---------------------------------------------------------------------------

def test_parse_size():
    assert parse_size("4096") == 4096
    assert parse_size("64K") == 64 << 10
    assert parse_size("64M") == 64 << 20
    assert parse_size("1g") == 1 << 30
    with pytest.raises(ValueError):
        parse_size("lots")


def test_parse_topology_shapes(tmp_path):
    ss = parse_topology(f"shard(dir://{tmp_path}/a,dir://{tmp_path}/b)")
    assert isinstance(ss, ShardedStore) and len(ss.shards) == 2
    rs = parse_topology("rep(memory://,memory://,memory://)")
    assert isinstance(rs, ReplicatedStore) and len(rs.replicas) == 3
    ts = parse_topology(f"tier(64K,sqlite://{tmp_path}/c.db)")
    assert isinstance(ts, TieredStore) and ts.hot.max_bytes == 64 << 10
    nested = parse_topology("shard(rep(memory://,memory://),"
                            "rep(memory://,memory://))")
    assert isinstance(nested, ShardedStore)
    assert all(isinstance(c, ReplicatedStore) for c in nested.shards)


def test_parse_topology_errors():
    for bad in ("shard()", "rep()", "tier(64M)",
                "tier(64M,memory://,memory://)", "shard(memory://"):
        with pytest.raises(ValueError):
            parse_topology(bad)


def test_open_store_fabric_with_codec(tmp_path):
    from repro.core import CompressedStore
    st = open_store(f"fabric://shard(dir://{tmp_path}/s0,"
                    f"dir://{tmp_path}/s1)?codec=zlib")
    assert isinstance(st, CompressedStore)
    assert isinstance(st.inner, ShardedStore)
    data = os.urandom(100) + b"\x00" * 4000
    k = chunk_key(data)
    st.put_chunk(k, data)
    assert st.get_chunk(k) == data
    # readable without the codec suffix (frames decode transparently)
    st2 = open_store(f"fabric://shard(dir://{tmp_path}/s0,"
                     f"dir://{tmp_path}/s1)")
    assert st2.get_chunk(k) == data


# ---------------------------------------------------------------------------
# rebalance
# ---------------------------------------------------------------------------

def test_rebalance_after_adding_a_shard(tmp_path):
    pairs = _pairs(120)
    old = ShardedStore([DirectoryStore(str(tmp_path / "s0")),
                        DirectoryStore(str(tmp_path / "s1"))])
    old.put_chunks(pairs)
    # ring change: same dirs plus a fresh shard
    new = ShardedStore([DirectoryStore(str(tmp_path / "s0")),
                        DirectoryStore(str(tmp_path / "s1")),
                        DirectoryStore(str(tmp_path / "s2"))])
    out = rebalance(new)
    assert 0 < out["chunks_moved"] < len(pairs) // 2     # ~1/3 of the keys
    assert scrub(new).misplaced == 0
    assert new.shards[2].n_chunks() == out["chunks_moved"]
    assert new.get_chunks([k for k, _ in pairs]) == dict(pairs)


# ---------------------------------------------------------------------------
# session + CLI end-to-end
# ---------------------------------------------------------------------------

@pytest.fixture
def fabric_session(tmp_path):
    uri = (f"fabric://shard(rep(dir://{tmp_path}/a0,dir://{tmp_path}/a1),"
           f"rep(dir://{tmp_path}/b0,dir://{tmp_path}/b1))")
    s = KishuSession(open_store(uri), chunk_bytes=1 << 10, cache_bytes=0)

    def set_val(ns, name, val):
        ns[name] = np.full(1500, float(val), np.float32)
    s.register("set_val", set_val)
    s.init_state({})
    c1 = s.run("set_val", name="x", val=1)
    s.run("set_val", name="x", val=2)
    s.close()
    return uri, s, c1, tmp_path


def _wipe_chunks(root):
    shutil.rmtree(os.path.join(root, "chunks"))
    os.makedirs(os.path.join(root, "chunks"))


def test_session_restores_with_one_replica_of_each_pair_down(fabric_session):
    uri, s, c1, tmp_path = fabric_session
    want = np.full(1500, 1.0, np.float32).tobytes()
    _wipe_chunks(str(tmp_path / "a0"))
    _wipe_chunks(str(tmp_path / "b1"))
    s2 = KishuSession(open_store(uri), chunk_bytes=1 << 10, cache_bytes=0)
    s2.checkout(c1)
    assert np.asarray(s2.ns["x"]).tobytes() == want      # bit-identical
    s2.close()
    # read-repair healed what checkout touched; scrub --repair the rest
    store = open_store(uri)
    scrub(store, repair=True)
    assert scrub(store).problems == 0


def test_session_falls_back_to_recompute_when_lost_everywhere(tmp_path):
    """Chunk lost on ALL replicas -> DataRestorer recomputation still
    restores the state."""
    uri = f"fabric://rep(dir://{tmp_path}/r0,dir://{tmp_path}/r1)"
    s = KishuSession(open_store(uri), chunk_bytes=1 << 10, cache_bytes=0)

    def fill(ns, seed):
        rng = np.random.default_rng(seed)
        ns["x"] = rng.standard_normal(1000).astype(np.float32)
    s.register("fill", fill)
    s.init_state({})
    c1 = s.run("fill", seed=7)
    want = np.asarray(s.ns["x"]).tobytes()
    s.run("fill", seed=8)
    for root in ("r0", "r1"):
        _wipe_chunks(str(tmp_path / root))
    st = s.checkout(c1)
    assert st.covs_recomputed > 0
    assert np.asarray(s.ns["x"]).tobytes() == want
    s.close()


def test_session_gc_sweeps_all_shards_and_replicas(fabric_session):
    uri, s, c1, tmp_path = fabric_session
    store = open_store(uri)
    junk = _pairs(5, "junk")
    store.put_chunks(junk)                               # orphans
    s3 = KishuSession(open_store(uri), chunk_bytes=1 << 10)
    s3.register("set_val", lambda ns, name, val: None)
    out = s3.gc()
    assert out["chunks_dropped"] == 5
    for k, _ in junk:
        assert not store.has_chunk(k)
    s3.close()


def test_cli_fleet_verbs(fabric_session, capsys):
    uri, s, c1, tmp_path = fabric_session
    assert cli(["--store", uri, "topology"]) == 0
    out = capsys.readouterr().out
    assert "shard(n=2" in out and "rep(k=2)" in out
    assert cli(["--store", uri, "scrub", "--deep"]) == 0
    assert "0 problems" in capsys.readouterr().out
    assert cli(["--store", uri, "rebalance"]) == 0
    assert "moved 0" in capsys.readouterr().out
    # break a replica -> scrub reports, exit 2; --repair heals, exit 0
    _wipe_chunks(str(tmp_path / "a1"))
    assert cli(["--store", uri, "scrub"]) == 2
    assert "replica-missing" in capsys.readouterr().out
    assert cli(["--store", uri, "scrub", "--repair"]) == 0
    assert cli(["--store", uri, "scrub"]) == 0
    assert "0 problems" in capsys.readouterr().out


def test_cli_verify_and_log_on_fabric(fabric_session, capsys):
    uri, s, c1, _ = fabric_session
    assert cli(["--store", uri, "log"]) == 0
    assert "set_val" in capsys.readouterr().out
    assert cli(["--store", uri, "verify", "--deep"]) == 0
    assert "OK" in capsys.readouterr().out
