"""ManagedTrainingSession integration: tied-embedding aliasing, undo, branch,
hparam deltas, async checkpointing, crash resume."""
import numpy as np
import pytest


import jax

from repro.core import MemoryStore
from repro.models import get_config
from repro.models.testing import reduced
from repro.optim.adamw import AdamWConfig
from repro.train.loop import ManagedTrainingSession, resume

pytestmark = pytest.mark.slow    # JAX jit-heavy; fast lane: -m "not slow"


@pytest.fixture(scope="module")
def tied_cfg():
    return reduced(get_config("qwen3-1.7b"), n_layers=2)


def make_sess(cfg, store=None, **kw):
    return ManagedTrainingSession(cfg, AdamWConfig(lr=1e-3),
                                  store or MemoryStore(),
                                  global_batch=2, seq_len=16, **kw)


def test_tied_embedding_covariable(tied_cfg):
    s = make_sess(tied_cfg)
    s.attach(seed=0)
    key = tuple(sorted(["state/params/embed", "state/params/lm_head"]))
    assert key in s.kishu.covs
    assert s.ns["state/params/embed"] is s.ns["state/params/lm_head"]


def test_undo_restores_exact_params_and_tie(tied_cfg):
    s = make_sess(tied_cfg)
    s.attach(seed=0)
    c1 = s.train(2)
    w1 = np.asarray(s.ns["state/params/embed"]).copy()
    s.train(2)
    st = s.checkout(c1)
    assert np.array_equal(np.asarray(s.ns["state/params/embed"]), w1)
    assert s.ns["state/params/embed"] is s.ns["state/params/lm_head"], \
        "checkout broke weight tying"
    assert st.wall_s < 5.0


def test_hparam_delta_is_tiny(tied_cfg):
    s = make_sess(tied_cfg)
    s.attach(seed=0)
    s.train(1)
    s.set_lr(5e-4)
    assert s.kishu.last_run.covs_updated == 1
    assert s.kishu.last_run.write.bytes_written < 200


def test_branching_data_mixture(tied_cfg):
    s = make_sess(tied_cfg)
    s.attach(seed=0)
    c1 = s.train(1)
    s.swap_data(seed=100)
    s.train(1)
    la = np.asarray(s.ns["state/params/embed"]).copy()
    s.checkout(c1)
    s.swap_data(seed=200)
    s.train(1)
    lb = np.asarray(s.ns["state/params/embed"])
    assert not np.array_equal(la, lb)     # different mixtures diverge


def test_train_replay_determinism(tied_cfg):
    """The same phase from the same state gives bit-identical results —
    the foundation of fallback recomputation for training states."""
    s = make_sess(tied_cfg)
    s.attach(seed=0)
    c1 = s.train(2)
    w_first = np.asarray(s.ns["state/params/embed"]).copy()
    s.checkout(s.kishu.graph.nodes[c1].parent)
    s.train(2)
    assert np.array_equal(np.asarray(s.ns["state/params/embed"]), w_first)


def test_chunk_loss_during_training_falls_back(tied_cfg):
    store = MemoryStore()
    s = make_sess(tied_cfg, store=store)
    s.attach(seed=0)
    c1 = s.train(1)
    w1 = np.asarray(s.ns["state/params/embed"]).copy()
    s.train(1)
    man = s.kishu.graph.manifest_of(
        tuple(sorted(["state/params/embed", "state/params/lm_head"])), c1)
    for ch in man["base"]["chunks"]:
        store.delete_chunk(ch["key"])
    # drop the shared chunk cache too: it would (correctly) mask the
    # storage incident; this test targets the replay fallback
    s.kishu.chunk_cache.clear()
    s.kishu.chunk_cache.max_bytes = 0
    s.checkout(c1)
    assert np.array_equal(np.asarray(s.ns["state/params/embed"]), w1)
    assert s.kishu.restorer.replays >= 1


def test_async_checkpointing(tied_cfg):
    s = make_sess(tied_cfg, async_write=True)
    s.attach(seed=0)
    c1 = s.train(1)
    s.train(1)
    s.checkout(c1)               # flushes the writer first
    assert s.ns is not None
    s.close()


def test_crash_resume(tied_cfg):
    store = MemoryStore()
    s = make_sess(tied_cfg, store=store)
    s.attach(seed=0)
    s.train(2)
    s.set_lr(7e-4)
    head = s.kishu.head
    w = np.asarray(s.ns["state/params/embed"]).copy()
    s.close()
    del s
    s2 = resume(reduced(get_config("qwen3-1.7b"), n_layers=2),
                AdamWConfig(lr=1e-3), store, global_batch=2, seq_len=16)
    assert s2.kishu.head == head
    assert np.array_equal(np.asarray(s2.ns["state/params/embed"]), w)
    assert s2.ns["hparams/lr"] == 7e-4
    assert s2.ns["state/params/embed"] is s2.ns["state/params/lm_head"]
    s2.train(1)                  # continues fine
