"""Optional-hypothesis shim for property tests.

Tier-1 must collect and pass on a clean environment (no ``hypothesis``
installed).  When hypothesis is available this module re-exports the real
API unchanged; when it is absent it provides minimal stand-ins:

  - ``given(...)`` marks the test skipped (reason: hypothesis not installed)
  - ``settings(...)`` / ``strategies`` / ``HealthCheck`` accept any usage at
    module import time without doing anything

so property-test modules import, collect, and report skips instead of
erroring the whole run, while their plain (non-property) tests still run.
"""
try:
    from hypothesis import HealthCheck, given, settings  # noqa: F401
    from hypothesis import strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed")(fn)
        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _Anything:
        """Callable, attribute-chainable sink for strategy expressions."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    strategies = _Anything()
    HealthCheck = _Anything()

st = strategies
