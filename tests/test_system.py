"""End-to-end system tests — the paper's headline claims as assertions.

On a session with the paper's workload traits (§2.2):
  1. incremental checkpoints are much smaller than whole-state dumps (Fig 13)
  2. incremental checkout loads far less than a full restore (Fig 15)
  3. access-pruned detection inspects only touched co-variables (Lemma 1)
  4. fallback recomputation restores exactly what storage lost (§5.3)
  5. the whole pipeline works against every storage backend
"""
import numpy as np
import pytest

from repro.core import (DumpSession, KishuSession, MemoryStore, Namespace,
                        TrackedNamespace, open_store)

MB = 1 << 20


def build_session(store):
    s = KishuSession(store, chunk_bytes=1 << 16)
    rng = np.random.default_rng(0)

    def load_corpus(ns):
        r = np.random.default_rng(ns["seed"])
        ns["corpus"] = r.standard_normal(4 * MB // 4).astype(np.float32)

    def clean(ns, i):
        ns[f"lists/l{i}"] = ns[f"lists/l{i}"] * 0.9 + 0.1

    def fit(ns, i):
        x = ns[f"lists/l{i}"]
        ns[f"models/m{i}"] = np.outer(x[:32], x[:32]).astype(np.float32)

    s.register("load_corpus", load_corpus)
    s.register("clean", clean)
    s.register("fit", fit)
    s.init_state({"seed": 3,
                  "lists": {f"l{i}": rng.standard_normal(2048)
                            .astype(np.float32) for i in range(6)}})
    s.run("load_corpus")
    return s


def test_incremental_vs_dump_size():
    store = MemoryStore()
    s = build_session(store)
    base = store.chunk_bytes_total()
    for i in range(6):
        s.run("clean", i=i)
        s.run("fit", i=i)
    incr = store.chunk_bytes_total() - base

    # dump baseline over the same script
    d = DumpSession(MemoryStore())
    s2 = build_session(MemoryStore())   # same commands on a raw namespace
    dump_total = 0
    tns = TrackedNamespace(s2.ns)
    for i in range(6):
        s2.registry["clean"](tns, i=i)
        st = d.checkpoint(s2.ns, f"a{i}")
        dump_total += st.bytes_written
        s2.registry["fit"](tns, i=i)
        st = d.checkpoint(s2.ns, f"b{i}")
        dump_total += st.bytes_written
    assert incr * 10 < dump_total, (incr, dump_total)


def test_incremental_checkout_loads_less():
    s = build_session(MemoryStore())
    c1 = s.run("clean", i=0)
    s.run("clean", i=1)
    st = s.checkout(c1)
    state_bytes = sum(r.nbytes for r in s.records.values())
    assert st.bytes_loaded * 50 < state_bytes      # only l1 reloaded
    assert st.covs_identical >= 7


def test_lemma1_pruning_in_system():
    s = build_session(MemoryStore())
    s.run("clean", i=2)
    assert s.last_run.covs_skipped >= 6            # corpus + 5 lists + seed
    assert s.last_run.covs_updated == 1


def test_fallback_after_storage_loss():
    store = MemoryStore()
    s = build_session(store)
    c1 = s.run("fit", i=0)
    expected = s.ns["models/m0"].copy()
    s.run("clean", i=0)                            # moves on; m0 unchanged
    c3 = s.run("fit", i=0)                         # new version of m0
    # destroy ALL chunks of m0@c1, then time-travel back (cache dropped
    # too — it would otherwise serve the lost chunks from memory)
    man = s.graph.manifest_of(("models/m0",), c1)
    for ch in man["base"]["chunks"]:
        store.delete_chunk(ch["key"])
    s.chunk_cache.clear()
    s.chunk_cache.max_bytes = 0
    s.checkout(c1)
    assert np.array_equal(s.ns["models/m0"], expected)
    assert s.restorer.replays >= 1


@pytest.mark.parametrize("uri", ["memory://", "dir://{tmp}/cas",
                                 "sqlite://{tmp}/cas.db"])
def test_all_backends_end_to_end(uri, tmp_path):
    store = open_store(uri.format(tmp=tmp_path))
    s = build_session(store)
    c1 = s.run("clean", i=0)
    v1 = s.ns["lists/l0"].copy()
    s.run("clean", i=0)
    s.checkout(c1)
    assert np.array_equal(s.ns["lists/l0"], v1)
    # session restart against the same store
    s.close()
    s2 = KishuSession(store, chunk_bytes=1 << 16)
    assert s2.graph.head == c1
