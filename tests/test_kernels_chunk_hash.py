"""Pallas chunk_hash kernel vs pure-jnp oracle vs NumPy spec.

Sweeps shapes x dtypes in interpret mode (CPU executes the kernel body);
agreement must be bit-exact — the kernel IS the hash definition on TPU.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest


from repro.core import hashing as H
from repro.kernels.chunk_hash import chunk_hash, chunk_hash_u64
from repro.kernels.chunk_hash.kernel import chunk_hash_pallas
from repro.kernels.chunk_hash.ref import chunk_hash_ref

pytestmark = pytest.mark.slow    # JAX jit-heavy; fast lane: -m "not slow"

CB = 1 << 12

DTYPES = [np.float32, np.float16, np.int8, np.int32, np.uint8, np.int16]
SHAPES = [(1,), (7,), (1024,), (4096,), (4097,), (128, 33), (3, 5, 17)]


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("shape", SHAPES)
def test_pallas_matches_ref_and_numpy(dtype, shape):
    rng = np.random.default_rng(hash((np.dtype(dtype).name, shape)) % 2**32)
    if np.issubdtype(dtype, np.floating):
        x = rng.standard_normal(shape).astype(dtype)
    else:
        x = rng.integers(0, 100, shape).astype(dtype)
    xj = jnp.asarray(x)
    got_pallas = chunk_hash_u64(xj, CB, backend="pallas", interpret=True)
    got_ref = chunk_hash_u64(xj, CB, backend="ref")
    want = H.chunk_hashes_np(np.ascontiguousarray(x).tobytes(), CB)
    assert np.array_equal(got_pallas, want)
    assert np.array_equal(got_ref, want)


def test_bfloat16():
    x = jax.random.normal(jax.random.key(0), (1000, 33), jnp.bfloat16)
    got = chunk_hash_u64(x, CB, backend="pallas", interpret=True)
    want = H.chunk_hashes_np(np.asarray(x).tobytes(), CB)
    assert np.array_equal(got, want)


def test_kernel_direct_prechunked():
    words = jnp.asarray(
        np.random.default_rng(0).integers(0, 2**32, (8, 1024), dtype=np.uint32))
    nbytes = jnp.full((8,), 4096, jnp.int32)
    k = chunk_hash_pallas(words, nbytes, interpret=True)
    r = chunk_hash_ref(words, nbytes)
    assert np.array_equal(np.asarray(k), np.asarray(r))


def test_chunk_sensitivity_on_device():
    x = jnp.zeros(CB * 4, jnp.uint8)                # 4 chunks
    h0 = chunk_hash_u64(x, CB, backend="pallas", interpret=True)
    x1 = x.at[CB + 5].set(1)                        # dirty chunk 1 only
    h1 = chunk_hash_u64(x1, CB, backend="pallas", interpret=True)
    assert h0[1] != h1[1]
    assert h0[0] == h1[0] and h0[2] == h1[2] and h0[3] == h1[3]


def test_vmem_block_is_power_of_two():
    with pytest.raises(AssertionError):
        chunk_hash(jnp.zeros(10, jnp.float32), 3 * 1024)
