"""Checkpoint Graph tests: commits, LCA, Def 5/6, persistence."""
import numpy as np
import pytest

from repro.core.chunkstore import MemoryStore
from repro.core.graph import CheckpointGraph, key_str, parse_key


def _commit(g, updated, deleted=(), accessed=None):
    return g.commit(command={"name": "cmd", "args": {}},
                    manifests={key_str(k): {"members": [],
                                            "unserializable": False}
                               for k in updated},
                    deleted_keys=list(deleted),
                    accessed=accessed or {},
                    updated_keys=list(updated))


def test_linear_chain_and_index():
    g = CheckpointGraph(MemoryStore())
    g.init_root()
    a = _commit(g, [("x",)]).commit_id
    b = _commit(g, [("y",)]).commit_id
    c = _commit(g, [("x",)]).commit_id
    idx = g.state_index(c)
    assert idx[key_str(("x",))] == c
    assert idx[key_str(("y",))] == b


def test_branching_and_lca():
    g = CheckpointGraph(MemoryStore())
    g.init_root()
    a = _commit(g, [("x",), ("d",)]).commit_id
    b = _commit(g, [("x",)]).commit_id           # branch 1
    g.set_head(a)
    c = _commit(g, [("x",)]).commit_id           # branch 2
    assert g.lca(b, c) == a
    assert g.lca(b, b) == b
    assert g.lca(a, c) == a
    # Def 6: d identical (version a in both + LCA); x diverged
    assert g.identical_via_lca(("d",), b, c)
    assert not g.identical_via_lca(("x",), b, c)


def test_diff_matches_lca_definition():
    g = CheckpointGraph(MemoryStore())
    g.init_root()
    _commit(g, [("a",), ("b",), ("c",)])
    r = g.head
    b1 = _commit(g, [("a",)]).commit_id
    b2 = _commit(g, [("b",)]).commit_id
    g.set_head(r)
    b3 = _commit(g, [("a",), ("d",)], deleted=[("c",)]).commit_id
    plan = g.diff(b2, b3)
    for k in plan.identical:
        assert g.identical_via_lca(k, b2, b3)
    for k in plan.to_load:
        assert not g.identical_via_lca(k, b2, b3)
    # c was deleted on branch 2: must be in to_delete going b2 -> b3
    assert ("c",) in plan.to_delete
    assert ("d",) in plan.to_load


def test_deleted_covariable_not_in_index():
    g = CheckpointGraph(MemoryStore())
    g.init_root()
    _commit(g, [("x",)])
    n = _commit(g, [], deleted=[("x",)])
    assert key_str(("x",)) not in g.state_index(n.commit_id)


def test_persistence_reload():
    store = MemoryStore()
    g = CheckpointGraph(store)
    g.init_root()
    a = _commit(g, [("x",)], accessed={("x",): "c00000"}).commit_id
    b = _commit(g, [("y",)]).commit_id
    g2 = CheckpointGraph(store)
    assert g2.head == b
    assert set(g2.nodes) == set(g.nodes)
    assert g2.nodes[a].accessed == {key_str(("x",)): "c00000"}
    # continue committing after reload: no id collisions
    c = _commit(g2, [("z",)]).commit_id
    assert c not in g.nodes


def test_key_str_roundtrip():
    for key in [("a",), ("a", "b/c"), ("x/y/z", "w")]:
        assert parse_key(key_str(key)) == key


def test_log_and_path():
    g = CheckpointGraph(MemoryStore())
    g.init_root()
    a = _commit(g, [("x",)]).commit_id
    b = _commit(g, [("y",)]).commit_id
    assert [e["commit"] for e in g.log()] == ["c00000", a, b]
    assert g.path_from_root(b) == ["c00000", a, b]
