"""Numerical consistency: SSD vs sequential oracle, MoE vs dense reference,
prefill vs decode for all archs, MLA cache equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest


from repro.models import get_config, layers, lm, mamba
from repro.models import moe as moe_lib
from repro.models.config import MoEConfig
from repro.models.testing import reduced

pytestmark = pytest.mark.slow    # JAX jit-heavy; fast lane: -m "not slow"

ARCHS = ["mamba2-780m", "stablelm-12b", "smollm-360m", "mistral-nemo-12b",
         "qwen3-1.7b", "jamba-1.5-large-398b", "whisper-large-v3",
         "phi3.5-moe-42b-a6.6b", "deepseek-v3-671b", "qwen2-vl-72b"]


@pytest.mark.parametrize("chunk", [8, 16, 32, 64])
def test_ssd_chunked_vs_sequential(chunk):
    ks = jax.random.split(jax.random.key(1), 5)
    B, S, H, P, N = 2, 64, 4, 8, 16
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    a = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    b = jax.random.normal(ks[3], (B, S, H, N)) * 0.5
    c = jax.random.normal(ks[4], (B, S, H, N)) * 0.5
    d = jnp.ones((H,))
    y_ref, s_ref = mamba.ssd_reference(x, dt, a, b, c, d)
    y, s = mamba.ssd_chunked(x, dt, a, b, c, d, chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=5e-5, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               atol=5e-5, rtol=1e-4)


def test_ssd_initial_state_threading():
    """Running two halves with carried state == running the whole sequence."""
    ks = jax.random.split(jax.random.key(3), 5)
    B, S, H, P, N = 1, 32, 2, 4, 8
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    a = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    b = jax.random.normal(ks[3], (B, S, H, N)) * 0.5
    c = jax.random.normal(ks[4], (B, S, H, N)) * 0.5
    d = jnp.zeros((H,))
    y_full, s_full = mamba.ssd_chunked(x, dt, a, b, c, d, 8)
    y1, s1 = mamba.ssd_chunked(x[:, :16], dt[:, :16], a, b[:, :16],
                               c[:, :16], d, 8)
    y2, s2 = mamba.ssd_chunked(x[:, 16:], dt[:, 16:], a, b[:, 16:],
                               c[:, 16:], d, 8, initial_state=s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=5e-5, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                               atol=5e-5, rtol=1e-4)


def _dense_moe_reference(p, cfg, x):
    """No-drop dense reference: out = sum_k p_k * expert_k(x)."""
    m = cfg.moe
    b, s, d = x.shape
    xt = x.reshape(-1, d)
    top_p, top_e = moe_lib.route(p["router"], xt, m)
    outs = []
    for e in range(m.n_experts):
        g = xt @ p["w_gate"][e]
        u = xt @ p["w_up"][e]
        outs.append((jax.nn.silu(g) * u) @ p["w_down"][e])
    ys = jnp.stack(outs, 1)                       # [T, E, d]
    w = jnp.zeros((xt.shape[0], m.n_experts))
    for k in range(m.top_k):
        w = w.at[jnp.arange(xt.shape[0]), top_e[:, k]].add(top_p[:, k])
    out = jnp.einsum("te,ted->td", w, ys)
    if m.n_shared_experts:
        from repro.models import layers as L
        out = out + L.mlp_forward(p["shared"], x).reshape(-1, d)
    return out.reshape(b, s, d)


def test_moe_dispatch_matches_dense_reference():
    cfg = reduced(get_config("phi3.5-moe-42b-a6.6b"))
    p = moe_lib.moe_init(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 8, cfg.d_model))
    got = moe_lib.moe_forward(p, cfg, x)           # cf=8 -> no drops
    want = _dense_moe_reference(p, cfg, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=1e-4)


def test_moe_capacity_dropping():
    cfg = reduced(get_config("phi3.5-moe-42b-a6.6b"))
    cfg = cfg.replace(moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=64,
                                    capacity_factor=0.25))
    p = moe_lib.moe_init(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (4, 16, cfg.d_model))
    y = moe_lib.moe_forward(p, cfg, x)
    assert bool(jnp.isfinite(y).all())
    # with brutal capacity, some tokens must be dropped (output smaller norm)
    t = x.reshape(-1, cfg.d_model).shape[0]
    cap = moe_lib.capacity(t, cfg.moe)
    _, top_e = moe_lib.route(p["router"], x.reshape(-1, cfg.d_model), cfg.moe)
    dest, valid = moe_lib.dispatch_indices(top_e, 4, cap)
    assert int(valid.sum()) < valid.shape[0]


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch):
    cfg = reduced(get_config(arch))
    params = lm.init_params(cfg, jax.random.key(0))
    B, S = 2, 8
    toks = jax.random.randint(jax.random.key(2), (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.frontend == "vision":
        x = jax.random.normal(jax.random.key(3), (B, S, cfg.d_model))
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S)).astype(jnp.int32)
        batch = {"embeds": x, "positions_thw": jnp.stack([pos] * 3, -1)}
    if cfg.enc_dec:
        batch["enc_embeds"] = jax.random.normal(jax.random.key(4),
                                                (B, S, cfg.d_model))
    full = lm.forward(cfg, params, batch)
    caches = lm.init_caches(cfg, B, S, enc_seq=S if cfg.enc_dec else 0)
    if cfg.enc_dec:
        caches["enc_out"] = lm.encode(cfg, params, batch, remat=False)
    outs = []
    for t in range(S):
        db = {"index": jnp.asarray(t, jnp.int32)}
        if cfg.frontend == "vision":
            db["embeds"] = batch["embeds"][:, t:t + 1]
        else:
            db["tokens"] = toks[:, t:t + 1]
        lg, caches = lm.decode_step(cfg, params, caches, db)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    err = float(jnp.max(jnp.abs(full - dec)))
    assert err < 2e-3, f"{arch}: prefill/decode diverge by {err}"


def test_mrope_differs_from_rope_when_positions_disagree():
    cfg = reduced(get_config("qwen2-vl-72b"))
    p = layers.gqa_init(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (1, 8, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(8)[None], (1, 8)).astype(jnp.int32)
    same = jnp.stack([pos, pos, pos], -1)
    diff = jnp.stack([pos, pos * 2, pos * 3], -1)
    y1 = layers.gqa_forward(p, cfg, x, same)
    y2 = layers.gqa_forward(p, cfg, x, diff)
    assert not np.allclose(np.asarray(y1), np.asarray(y2))


def test_remat_matches_no_remat():
    cfg = reduced(get_config("qwen3-1.7b"))
    params = lm.init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab_size)
    a = lm.forward(cfg, params, {"tokens": toks}, remat=False)
    b = lm.forward(cfg, params, {"tokens": toks}, remat=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
