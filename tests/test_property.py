"""Hypothesis property tests over the system's invariants.

Random command sequences (in-place update / rebind / create / delete /
alias / unalias / branch checkout) against a model of the state, asserting:

  P1  checkout reproduces the recorded state bit-exactly (Remark §5.3)
  P2  delta detection has no false negatives (Table 5: Fail == 0)
  P3  index-based divergence == Def-6 LCA divergence
  P4  storage is append-only content-addressed: re-writing identical data
      adds no chunks
"""
import numpy as np
import pytest
from _hypothesis_compat import HealthCheck, given, settings, st

from repro.core import KishuSession, MemoryStore, cov_key
from repro.core.graph import parse_key

NAMES = ["a", "b", "c", "d"]

op = st.one_of(
    st.tuples(st.just("bump"), st.sampled_from(NAMES)),
    st.tuples(st.just("rebind_same"), st.sampled_from(NAMES)),
    st.tuples(st.just("create"), st.sampled_from(["e", "f"])),
    st.tuples(st.just("delete"), st.sampled_from(NAMES + ["e", "f"])),
    st.tuples(st.just("alias"), st.sampled_from(NAMES),
              st.sampled_from(NAMES)),
    st.tuples(st.just("checkout"), st.integers(min_value=0, max_value=100)),
)


def _snapshot(ns):
    out = {}
    for name in ns.names():
        v = ns[name]
        out[name] = np.asarray(v).copy() if isinstance(v, np.ndarray) else v
    return out


def _apply(sess, o, rng):
    kind = o[0]
    if kind == "bump":
        name = o[1]
        if name in sess.ns:
            sess.run("bump", name=name)
            return True
    elif kind == "rebind_same":
        name = o[1]
        if name in sess.ns:
            sess.run("rebind_same", name=name)
            return True
    elif kind == "create":
        sess.run("create", name=o[1], value=float(rng.integers(0, 100)))
        return True
    elif kind == "delete":
        name = o[1]
        if name in sess.ns and len(sess.ns) > 1:
            sess.run("delete", name=name)
            return True
    elif kind == "alias":
        src, dst = o[1], o[2]
        if src in sess.ns and src != dst:
            sess.run("alias", src=src, dst=dst)
            return True
    return False


@settings(max_examples=20, deadline=None,
          suppress_health_check=[HealthCheck.too_slow,
                                 HealthCheck.data_too_large])
@given(st.lists(op, min_size=3, max_size=12), st.integers(0, 2**16))
def test_random_sessions_invariants(ops, seed):
    rng = np.random.default_rng(seed)
    sess = KishuSession(MemoryStore(), chunk_bytes=256)

    def bump(ns, name):
        ns[name] = ns[name] + 1.0

    def rebind_same(ns, name):
        ns[name] = ns[name].copy()

    def create(ns, name, value):
        ns[name] = np.full(37, value, np.float32)

    def delete(ns, name):
        del ns[name]

    def alias(ns, src, dst):
        ns[dst] = ns[src]

    for n, f in [("bump", bump), ("rebind_same", rebind_same),
                 ("create", create), ("delete", delete), ("alias", alias)]:
        sess.register(n, f)

    sess.init_state({n: np.arange(41, dtype=np.float32) + i
                     for i, n in enumerate(NAMES)})
    snapshots = {sess.head: _snapshot(sess.ns)}
    commits = [sess.head]

    for o in ops:
        if o[0] == "checkout":
            target = commits[o[1] % len(commits)]
            sess.checkout(target)
            # P1: bit-exact restoration
            want = snapshots[target]
            got = _snapshot(sess.ns)
            assert set(got) == set(want), (sorted(got), sorted(want))
            for k in want:
                assert np.array_equal(got[k], want[k]), k
        else:
            before = _snapshot(sess.ns)
            if not _apply(sess, o, rng):
                continue
            commits.append(sess.head)
            snapshots[sess.head] = _snapshot(sess.ns)
            # P2: no false negatives — every name whose value changed must be
            # covered by an updated co-variable in this commit
            node = sess.graph.nodes[sess.head]
            updated_names = set()
            for ks in node.manifests:
                updated_names.update(parse_key(ks))
            after = snapshots[sess.head]
            for name in after:
                if name not in before or \
                        not np.array_equal(np.asarray(after[name]),
                                           np.asarray(before[name])):
                    assert name in updated_names, \
                        f"false negative: {name} changed but not in delta"

    # P3: index diff == Def-6 LCA for all commit pairs (sampled)
    pairs = [(commits[i], commits[j])
             for i in range(0, len(commits), 3)
             for j in range(0, len(commits), 4)]
    for a, b in pairs[:12]:
        plan = sess.graph.diff(a, b)
        for k in plan.identical:
            assert sess.graph.identical_via_lca(k, a, b)
        for k in plan.to_load:
            assert not sess.graph.identical_via_lca(k, a, b)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 5), st.integers(0, 2**16))
def test_p4_idempotent_storage(n_repeats, seed):
    """Re-running a command that recreates identical data adds no chunks."""
    sess = KishuSession(MemoryStore(), chunk_bytes=512)

    def recreate(ns):
        ns["x"] = np.arange(300, dtype=np.float32)   # same every time
    sess.register("recreate", recreate)
    sess.init_state({})
    sess.run("recreate")
    chunks_after_first = sess.store.n_chunks()
    for _ in range(n_repeats):
        sess.run("recreate")
    assert sess.store.n_chunks() == chunks_after_first


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 6))
def test_chunk_dedup_across_branches(n_branches):
    """Branches sharing data store it once (content addressing)."""
    sess = KishuSession(MemoryStore(), chunk_bytes=1024)

    def seed_data(ns):
        ns["shared"] = np.ones(5000, np.float32)

    def tweak(ns, i):
        ns["small"] = np.full(10, float(i), np.float32)

    sess.register("seed_data", seed_data)
    sess.register("tweak", tweak)
    sess.init_state({})
    root = sess.run("seed_data")
    bytes_base = sess.store.chunk_bytes_total()
    for i in range(n_branches):
        sess.checkout(root)
        sess.run("tweak", i=i)
    extra = sess.store.chunk_bytes_total() - bytes_base
    assert extra < 2000 * n_branches      # only the small arrays, never shared
