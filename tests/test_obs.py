"""Observability plane (DESIGN.md §16): span tracer, metrics registry,
InstrumentedStore over every backend, session pipeline integration,
kernel-fallback scoping, and the export surfaces (Chrome trace JSON,
Prometheus text via CLI and the kishud socket)."""
import json
import re

import numpy as np
import pytest

from repro.core import KishuSession, MemoryStore, open_store
from repro.obs import (SessionObs, TRACE_META_PREFIX, Tracer, active,
                       chrome_trace, render, spans_from_doc)
from repro.obs.instrument import (InstrumentedStore, backend_label,
                                  instrument_tree)
from repro.obs.metrics import Histogram, MetricsRegistry

# every line of a Prometheus text exposition: comment or sample
_EXPO_LINE = re.compile(
    r"^(# (TYPE|HELP) .*|[a-zA-Z_:][a-zA-Z0-9_:]*(\{[^}]*\})? [-+0-9.e]+)$")


def _assert_exposition(text: str) -> None:
    lines = [ln for ln in text.splitlines() if ln]
    assert lines, "empty exposition"
    for ln in lines:
        assert _EXPO_LINE.match(ln), f"bad exposition line: {ln!r}"


def set_val(ns, name, val):
    ns[name] = np.full(256, float(val), np.float32)


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------

def test_tracer_nesting_and_ids():
    tr = Tracer(enabled=True)
    with tr.span("outer"):
        with tr.span("inner", k=1):
            pass
    by_name = {r.name: r for r in tr.spans}
    assert by_name["inner"].parent_id == by_name["outer"].span_id
    assert by_name["outer"].parent_id is None
    assert by_name["inner"].args == {"k": 1}
    # inner recorded first (exit order), intervals nest
    o, i = by_name["outer"], by_name["inner"]
    assert o.t0_s <= i.t0_s and i.t0_s + i.dur_s <= o.t0_s + o.dur_s + 1e-9


def test_tracer_disabled_is_noop_and_ring_bounds():
    tr = Tracer(enabled=False)
    with tr.span("x"):
        pass
    assert len(tr.spans) == 0
    tr = Tracer(enabled=True, max_spans=8)
    for i in range(50):
        with tr.span(f"s{i}"):
            pass
    assert len(tr.spans) == 8
    assert [r.name for r in tr.spans][-1] == "s49"


def test_tracer_stage_totals_and_doc_roundtrip():
    tr = Tracer(enabled=True)
    for _ in range(3):
        with tr.span("stage_a"):
            pass
    totals = tr.stage_totals()
    assert set(totals) == {"stage_a"} and totals["stage_a"] >= 0
    back = spans_from_doc(tr.to_doc())
    assert [r.name for r in back] == [r.name for r in tr.spans]
    assert back[0].span_id == list(tr.spans)[0].span_id


def test_chrome_trace_format():
    tr = Tracer(enabled=True)
    with tr.span("commit", command="c1"):
        with tr.span("detect"):
            pass
    doc = chrome_trace(list(tr.spans))
    evs = doc["traceEvents"]
    assert len(evs) == 2 and doc["displayTimeUnit"] == "ms"
    for e in evs:
        assert e["ph"] == "X" and e["dur"] > 0 and "ts" in e
        assert "span_id" in e["args"]
    # sorted by ts: parent (earlier start) first
    assert evs[0]["name"] == "commit" and evs[1]["name"] == "detect"
    assert evs[1]["args"]["parent_id"] == evs[0]["args"]["span_id"]
    assert evs[0]["args"]["command"] == "c1"
    json.dumps(doc)     # JSON-serializable end to end


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def test_histogram_buckets_and_exposition():
    reg = MetricsRegistry()
    h = reg.histogram("kishu_test_seconds", base=1e-6)
    for v in (1e-6, 3e-6, 1e-3, 0.5):
        h.observe(v)
    assert h.count == 4 and abs(h.sum - 0.501004) < 1e-6
    c = reg.counter("kishu_test_total", op="get")
    c.inc(3)
    text = render([reg])
    _assert_exposition(text)
    assert 'kishu_test_total{op="get"} 3' in text
    assert "kishu_test_seconds_count 4" in text
    # cumulative le= buckets are monotone non-decreasing
    counts = [float(m.group(1)) for m in re.finditer(
        r'kishu_test_seconds_bucket\{le="[^"]*"\} ([0-9.]+)', text)]
    assert counts == sorted(counts) and counts[-1] == 4


def test_registry_doc_roundtrip_and_const_labels():
    reg = MetricsRegistry(const_labels={"tenant": "t1"})
    reg.counter("kishu_x_total").inc()
    reg.histogram("kishu_y_seconds").observe(0.01)
    back = MetricsRegistry.from_doc(reg.to_doc())
    text = render([back])
    _assert_exposition(text)
    assert 'tenant="t1"' in text
    assert 'kishu_y_seconds_count{tenant="t1"} 1' in text
    assert back.counter_total("kishu_x_total") == 1


# ---------------------------------------------------------------------------
# InstrumentedStore — every base backend + a fabric composition
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("uri", ["memory://", "dir://{tmp}/cas",
                                 "sqlite://{tmp}/cas.db"])
def test_instrumented_store_backends(uri, tmp_path):
    store = open_store(uri.format(tmp=tmp_path))
    label = backend_label(store)
    reg = MetricsRegistry()
    inst = InstrumentedStore(store, reg)
    inst.put_chunks([("k1", b"x" * 64), ("k2", b"y" * 64)])
    got = inst.get_chunks(["k1", "k2"])
    assert got["k1"] == b"x" * 64
    inst.put_meta("m/doc", {"a": 1})
    assert inst.get_meta("m/doc") == {"a": 1}
    text = render([reg])
    _assert_exposition(text)
    for op in ("put_chunks", "get_chunks", "put_meta", "get_meta"):
        assert (f'kishu_store_op_seconds_count'
                f'{{backend="{label}",op="{op}"}} 1') in text
    assert (f'kishu_store_bytes_total'
            f'{{backend="{label}",dir="put"}} 128') in text
    assert (f'kishu_store_bytes_total'
            f'{{backend="{label}",dir="get"}} 128') in text


def test_instrument_tree_fabric_composition(tmp_path):
    uri = (f"fabric://shard(rep(dir://{tmp_path}/a0,dir://{tmp_path}/a1),"
           f"sqlite://{tmp_path}/b.db)")
    store = open_store(uri)
    reg = MetricsRegistry()
    inst = instrument_tree(store, reg)
    inst.put_chunks([(f"k{i}", bytes([i]) * 32) for i in range(16)])
    inst.get_chunks([f"k{i}" for i in range(16)])
    text = render([reg])
    _assert_exposition(text)
    # root labeled as the shard router, children per slot:backend
    assert 'backend="shard"' in text
    assert 'backend="shard0:rep"' in text
    assert 'backend="shard1:sqlite"' in text
    # both shards actually saw traffic
    for b in ("shard0:rep", "shard1:sqlite"):
        n = re.search(r'kishu_store_op_seconds_count'
                      r'\{backend="%s",op="put_chunks"\} (\d+)' % b, text)
        assert n and int(n.group(1)) >= 1


def test_instrumented_store_passthrough_semantics():
    inner = MemoryStore()
    reg = MetricsRegistry()
    inst = InstrumentedStore(inner, reg)
    docs = {"a/1": {"v": 1}, "a/2": {"v": 2}}
    inst.put_meta_batch(docs)            # dict-shaped batch API preserved
    assert inner.get_meta("a/2") == {"v": 2}
    assert sorted(inst.list_meta("a/")) == ["a/1", "a/2"]
    inst.put_chunks([("k", b"z")])
    assert inst.delete_chunks(["k"]) == 1   # int return forwarded


# ---------------------------------------------------------------------------
# session pipeline integration
# ---------------------------------------------------------------------------

def _traced_session(store, **kw):
    sess = KishuSession(store, chunk_bytes=1 << 10, trace=True, **kw)
    sess.register("set_val", set_val)
    sess.init_state({})
    return sess


def test_session_trace_covers_pipelines_and_nests(tmp_path):
    sess = _traced_session(open_store(f"sqlite://{tmp_path}/cas.db"))
    c1 = sess.run("set_val", name="x", val=1)
    sess.run("set_val", name="x", val=2)
    sess.checkout(c1)
    spans = list(sess.obs.tracer.spans)
    names = {r.name for r in spans}
    assert {"commit", "detect", "serialize", "put_chunks", "publish",
            "checkout", "plan"} <= names
    assert len(names) >= 6
    by_id = {r.span_id: r for r in spans}
    nested = 0
    for r in spans:
        if r.parent_id is None:
            continue
        p = by_id[r.parent_id]
        assert p.t0_s - 1e-6 <= r.t0_s
        assert r.t0_s + r.dur_s <= p.t0_s + p.dur_s + 1e-6
        nested += 1
    assert nested > 0
    # store-op histograms populated for the sqlite backend
    text = sess.metrics_text()
    _assert_exposition(text)
    assert 'backend="sqlite"' in text and "kishu_store_op_seconds" in text
    sid = sess.obs.sid
    sess.close()
    # trace persisted on close, loadable via the meta plane
    store = open_store(f"sqlite://{tmp_path}/cas.db")
    doc = store.get_meta(TRACE_META_PREFIX + sid)
    assert doc and [r.name for r in spans_from_doc(doc["spans"])]


def test_untraced_session_records_nothing_but_metrics(tmp_path):
    sess = KishuSession(open_store(f"dir://{tmp_path}/cas"),
                        chunk_bytes=1 << 10)
    sess.register("set_val", set_val)
    sess.init_state({})
    sess.run("set_val", name="x", val=1)
    assert len(sess.obs.tracer.spans) == 0
    assert "kishu_store_op_seconds" in sess.metrics_text()
    sid = sess.obs.sid
    sess.close()
    # no trace doc written when tracing was off
    assert open_store(f"dir://{tmp_path}/cas").get_meta(
        TRACE_META_PREFIX + sid) is None


def test_trace_env_var_opt_in(tmp_path, monkeypatch):
    monkeypatch.setenv("KISHU_TRACE", "1")
    sess = KishuSession(MemoryStore(), chunk_bytes=1 << 10)
    assert sess.obs.tracer.enabled
    sess.close()


# ---------------------------------------------------------------------------
# kernel-fallback scoping (satellite: per-session registry + module shim)
# ---------------------------------------------------------------------------

def test_kernel_fallback_scoped_per_session():
    from repro.core import delta as delta_mod
    a, b = SessionObs(), SessionObs()
    err = RuntimeError("no kernel")
    with a.activate():
        assert active() is a
        delta_mod.note_kernel_fallback("t1", err)
        delta_mod.note_kernel_fallback("t1", err)
        assert delta_mod.kernel_fallbacks() == 2
    with b.activate():
        assert delta_mod.kernel_fallbacks() == 0     # b's counter, not a's
        delta_mod.note_kernel_fallback("t1", err)
        assert delta_mod.kernel_fallbacks() == 1
    assert active() is None
    assert a.kernel_fallbacks() == 2 and b.kernel_fallbacks() == 1


def test_kernel_fallback_module_shim_still_monotonic():
    from repro.core import delta as delta_mod
    before = delta_mod._kernel_fallbacks
    with SessionObs().activate():
        delta_mod.note_kernel_fallback("shim", RuntimeError("no kernel"))
    # the deprecated module-global keeps counting even when scoped
    assert delta_mod._kernel_fallbacks == before + 1


# ---------------------------------------------------------------------------
# export surfaces: CLI + kishud socket
# ---------------------------------------------------------------------------

@pytest.fixture
def traced_store_uri(tmp_path):
    uri = f"dir://{tmp_path}/cas"
    sess = _traced_session(open_store(uri))
    c1 = sess.run("set_val", name="x", val=1)
    sess.run("set_val", name="y", val=2)
    sess.checkout(c1)
    sess.close()
    return uri


def test_cli_trace_exports_chrome_json(traced_store_uri, tmp_path, capsys):
    from repro.launch.kishu_cli import main as cli
    out_path = tmp_path / "trace.json"
    assert cli(["--store", traced_store_uri, "trace",
                "--out", str(out_path)]) == 0
    doc = json.loads(out_path.read_text())
    evs = doc["traceEvents"]
    assert len(evs) >= 6
    assert all(e["ph"] == "X" and "ts" in e and "dur" in e for e in evs)
    assert len({e["name"] for e in evs}) >= 6
    # stdout mode too
    assert cli(["--store", traced_store_uri, "trace"]) == 0
    doc2 = json.loads(capsys.readouterr().out)
    assert len(doc2["traceEvents"]) == len(evs)


def test_cli_stats_metrics_exposition(traced_store_uri, capsys):
    from repro.launch.kishu_cli import main as cli
    assert cli(["--store", traced_store_uri, "stats", "--metrics"]) == 0
    text = capsys.readouterr().out
    _assert_exposition(text)
    assert "kishu_graph_commits" in text
    assert "kishu_store_op_seconds" in text
    # persisted per-session snapshots merged in, tagged by sid
    assert 'sid="' in text
    # plain stats unaffected
    assert cli(["--store", traced_store_uri, "stats"]) == 0
    assert "chunks" in capsys.readouterr().out


def test_kishud_metrics_socket_roundtrip(tmp_path):
    from repro.launch.kishud import Kishud, KishudServer, control
    d = Kishud(MemoryStore(), workers=1, lease_ttl_s=30.0,
               chunk_bytes=1 << 9)
    sock = str(tmp_path / "kd.sock")
    srv = KishudServer(d, sock)
    try:
        s = d.session("alice")
        s.register("set_val", set_val)
        s.init_state({})
        s.run("set_val", name="x", val=1)
        resp = control(sock, "metrics")
        assert resp["ok"]
        text = resp["metrics"]
        _assert_exposition(text)
        assert "kishud_uptime_seconds" in text
        assert "kishud_sessions 1" in text
        assert 'tenant="alice"' in text
        assert "kishu_store_op_seconds" in text
    finally:
        srv.close()
        d.close()
