"""kishu CLI: log/show/diff/stats/verify/gc against a directory store."""
import numpy as np
import pytest

from repro.core import KishuSession, open_store
from repro.launch.kishu_cli import main as cli


@pytest.fixture
def store_uri(tmp_path):
    uri = f"dir://{tmp_path}/cas"
    s = KishuSession(open_store(uri), chunk_bytes=1 << 10)

    def set_val(ns, name, val):
        ns[name] = np.full(500, float(val), np.float32)
    s.register("set_val", set_val)
    s.init_state({})
    s.run("set_val", name="x", val=1)
    root = s.head
    s.run("set_val", name="y", val=2)
    s.checkout(root)
    s.run("set_val", name="y", val=3)
    s.close()
    return uri, s


def test_log_show_diff_stats(store_uri, capsys):
    uri, s = store_uri
    assert cli(["--store", uri, "log"]) == 0
    out = capsys.readouterr().out
    assert "set_val" in out and "*" in out

    head = s.graph.head
    assert cli(["--store", uri, "show", head]) == 0
    out = capsys.readouterr().out
    assert "upd y" in out

    nodes = sorted(s.graph.nodes)
    assert cli(["--store", uri, "diff", nodes[-2], nodes[-1]]) == 0
    out = capsys.readouterr().out
    assert "diverged" in out

    assert cli(["--store", uri, "stats"]) == 0
    assert "chunks" in capsys.readouterr().out


def test_verify_detects_missing_chunk(store_uri, capsys):
    uri, s = store_uri
    assert cli(["--store", uri, "verify", "--deep"]) == 0
    assert "OK" in capsys.readouterr().out
    # drop one chunk
    store = open_store(uri)
    man = next(m for n in s.graph.nodes.values()
               for m in n.manifests.values() if not m.get("unserializable"))
    store.delete_chunk(man["base"]["chunks"][0]["key"])
    assert cli(["--store", uri, "verify"]) == 2
    assert "MISSING" in capsys.readouterr().out


def test_gc_dry_run_and_real(store_uri, capsys):
    uri, s = store_uri
    # orphan a chunk by writing junk directly
    store = open_store(uri)
    store.put_chunk("deadbeef" * 4, b"junk")
    assert cli(["--store", uri, "gc", "--dry-run"]) == 0
    assert "would drop 1" in capsys.readouterr().out
    assert cli(["--store", uri, "gc"]) == 0
    assert "dropped 1" in capsys.readouterr().out
    assert not store.has_chunk("deadbeef" * 4)


def test_bad_commit_errors(store_uri):
    uri, _ = store_uri
    assert cli(["--store", uri, "show", "c99999"]) == 1
    assert cli(["--store", uri, "diff", "c99999", "c00000"]) == 1


# ---------------------------------------------------------------------------
# --store URI handling: ?codec= and fabric:// must work for EVERY subcommand
# (they all share open_store — this pins that contract)
# ---------------------------------------------------------------------------

def _build_history(uri):
    s = KishuSession(open_store(uri), chunk_bytes=1 << 10)

    def set_val(ns, name, val):
        ns[name] = np.full(500, float(val), np.float32)
    s.register("set_val", set_val)
    s.init_state({})
    s.run("set_val", name="x", val=1)
    s.run("set_val", name="y", val=2)
    s.close()
    return s


@pytest.fixture(params=["sqlite_codec", "fabric", "fabric_codec"])
def any_store_uri(request, tmp_path):
    uri = {
        "sqlite_codec": f"sqlite://{tmp_path}/cas.db?codec=zlib",
        "fabric": f"fabric://shard(dir://{tmp_path}/s0,dir://{tmp_path}/s1)",
        "fabric_codec": (f"fabric://rep(dir://{tmp_path}/r0,"
                         f"dir://{tmp_path}/r1)?codec=zlib"),
    }[request.param]
    return uri, _build_history(uri)


def test_every_subcommand_accepts_uri(any_store_uri, capsys):
    uri, s = any_store_uri
    nodes = sorted(s.graph.nodes)
    assert cli(["--store", uri, "log"]) == 0
    assert "set_val" in capsys.readouterr().out
    assert cli(["--store", uri, "show", s.graph.head]) == 0
    assert "upd y" in capsys.readouterr().out
    assert cli(["--store", uri, "diff", nodes[-2], nodes[-1]]) == 0
    assert "diverged" in capsys.readouterr().out
    assert cli(["--store", uri, "stats"]) == 0
    assert "chunks" in capsys.readouterr().out
    assert cli(["--store", uri, "verify", "--deep"]) == 0
    assert "OK" in capsys.readouterr().out
    assert cli(["--store", uri, "gc", "--dry-run"]) == 0
    assert "would drop 0" in capsys.readouterr().out
    assert cli(["--store", uri, "topology"]) == 0
    assert cli(["--store", uri, "scrub"]) == 0


def test_trace_without_spans_exits_nonzero(tmp_path, capsys):
    uri = f"dir://{tmp_path}/cas"
    _build_history(uri)         # untraced session: no obs/trace/* docs
    assert cli(["--store", uri, "trace"]) == 1
    assert "no persisted spans" in capsys.readouterr().err


def test_stats_metrics_on_every_uri(any_store_uri, capsys):
    import re
    uri, _ = any_store_uri
    assert cli(["--store", uri, "stats", "--metrics"]) == 0
    out = capsys.readouterr().out
    line = re.compile(r"^(# (TYPE|HELP) .*|"
                      r"[a-zA-Z_:][a-zA-Z0-9_:]*(\{[^}]*\})? [-+0-9.e]+)$")
    for ln in out.splitlines():
        if ln:
            assert line.match(ln), f"bad exposition line: {ln!r}"
    m = re.search(r"^kishu_graph_commits (\d+)$", out, re.M)
    assert m and int(m.group(1)) >= 2
