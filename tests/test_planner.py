"""Cost-based checkout planner (DESIGN.md §18): pricing, mode resolution,
parity of planner-on checkout with the fixed ladder on every backend, the
covs_recomputed single-count contract, and the bounded replay memo."""
import os
import time

import numpy as np
import pytest

from repro.core import (CheckoutPlanner, DetReplaySession, KishuSession,
                        MemoryStore, PricedPlan, StoreCostModel, format_plan,
                        open_store, resolve_plan_mode)
from repro.core.chunkstore import ChunkCache, DirectoryStore, SQLiteStore
from repro.core.planner import INF
from repro.core.restore import DataRestorer, resolve_memo_bytes
from repro.obs.metrics import MetricsRegistry


def make_store(kind, tmp_path):
    if kind == "memory":
        return MemoryStore()
    tmp_path.mkdir(parents=True, exist_ok=True)
    if kind == "dir":
        return DirectoryStore(str(tmp_path / "cas"))
    if kind == "sqlite":
        return SQLiteStore(str(tmp_path / "cas.db"))
    return open_store(f"fabric://shard(dir://{tmp_path}/s0,"
                      f"dir://{tmp_path}/s1)")


def build_session(store, **kw):
    kw.setdefault("chunk_bytes", 256)
    s = KishuSession(store, **kw)
    s.register("step", _step)
    s.register("derive", _derive)
    return s


def _step(ns, k=1.0):
    ns["w"] = ns["w"] + np.float32(k)


def _derive(ns, scale=1.0):
    ns["big"] = (np.arange(512, dtype=np.float32)
                 * ns["seed"].sum() * np.float32(scale))


def run_workload(s):
    cids = [s.init_state({"w": np.zeros(256, np.float32),
                          "seed": np.arange(4, dtype=np.float32)})]
    for k in range(1, 4):
        cids.append(s.run("step", k=float(k)))
        cids.append(s.run("derive", scale=float(k)))
    return cids


# ---------------------------------------------------------------------------
# mode resolution
# ---------------------------------------------------------------------------

def test_resolve_plan_mode_arg_env_default(monkeypatch):
    assert resolve_plan_mode(None) == "off"
    monkeypatch.setenv("KISHU_PLANNER", "auto")
    assert resolve_plan_mode(None) == "auto"
    assert resolve_plan_mode("off") == "off"       # arg wins over env
    monkeypatch.setenv("KISHU_PLANNER", "1")
    assert resolve_plan_mode(None) == "auto"
    assert resolve_plan_mode("forced-replay") == "replay"
    assert resolve_plan_mode("forced-fetch") == "fetch"
    with pytest.raises(ValueError):
        resolve_plan_mode("bogus")


# ---------------------------------------------------------------------------
# store cost model
# ---------------------------------------------------------------------------

def test_cost_model_cold_defaults():
    m = StoreCostModel(None)
    lat, bw, n = m.snapshot()
    assert n == 0 and lat > 0 and bw > 0
    assert m.fetch_seconds(0, 0) == 0.0
    assert m.fetch_seconds(1 << 20, 4) > 0


def test_cost_model_reads_store_metrics():
    reg = MetricsRegistry()
    h = reg.histogram("kishu_store_op_seconds", op="get_chunks",
                      backend="memory")
    for _ in range(10):
        h.observe(0.01)                  # 10 ops x 10ms
    reg.counter("kishu_store_bytes_total", dir="get",
                backend="memory").inc(1_000_000)
    m = StoreCostModel(reg)
    lat, bw, n = m.snapshot()
    assert n == 10
    assert lat == pytest.approx(0.01)
    assert bw == pytest.approx(1_000_000 / 0.1)
    # 1MB at 10MB/s ~ 0.1s plus one op latency
    assert m.fetch_seconds(1_000_000, 3) == pytest.approx(0.11, rel=0.05)


# ---------------------------------------------------------------------------
# pricing
# ---------------------------------------------------------------------------

def test_plan_prices_and_formats(tmp_path):
    s = build_session(MemoryStore(), plan_mode="auto", cache_bytes=0)
    cids = run_workload(s)
    p = s.plan(cids[2])
    assert isinstance(p, PricedPlan)
    assert p.target == cids[2] and p.mode == "auto"
    assert p.covs, "diverged covs must be priced"
    for c in p.covs:
        assert c.path in ("fetch", "replay", "patch")
        assert c.fetch_s < INF           # everything serializable here
    text = "\n".join(format_plan(p))
    assert cids[2] in text and "store model" in text
    s.close()


def test_cache_resident_bytes_price_zero():
    s = build_session(MemoryStore(), plan_mode="auto")   # default cache on
    cids = run_workload(s)
    p = s.plan(cids[-2])
    # every chunk was just written through the shared cache
    fetchable = [c for c in p.covs if c.path != "replay"]
    assert fetchable and all(c.est_bytes == 0 for c in fetchable)
    s.close()


def test_replay_shared_ancestor_priced_once():
    """Two co-variables produced by the same commit charge its exec once."""
    store = MemoryStore()
    s = KishuSession(store, plan_mode="auto", cache_bytes=0, chunk_bytes=256)

    def pair(ns, k=1.0):
        ns["a"] = np.full(64, np.float32(k))
        ns["b"] = np.full(64, np.float32(-k))
    s.register("pair", pair)
    s.init_state({"seed": np.arange(4, dtype=np.float32)})
    c1 = s.run("pair", k=1.0)
    s.run("pair", k=2.0)
    planner = s.planner
    charged = set()
    cost_a, closure_a, _ = planner._replay_price(c1, charged)
    assert cost_a < INF and closure_a
    charged |= closure_a
    cost_b, closure_b, _ = planner._replay_price(c1, charged)
    assert cost_b == 0.0 and not closure_b   # memo-shared: free second time
    s.close()


def test_unregistered_and_unsafe_commands_never_replay():
    s = build_session(MemoryStore(), plan_mode="replay", cache_bytes=0)
    s.register("sideeffect", lambda ns, v=1.0: ns.__setitem__(
        "x", np.full(8, np.float32(v))), replay_safe=False)
    run_workload(s)
    cx = s.run("sideeffect", v=1.0)
    s.run("sideeffect", v=2.0)           # x diverges between HEAD and cx
    p = s.plan(cx)
    x_plan = [c for c in p.covs if "x" in c.key]
    assert x_plan and x_plan[0].path != "replay"
    assert x_plan[0].replay_s == INF
    # and the flag is persisted in the commit doc
    assert s.graph.nodes[cx].stats["replay_safe"] is False
    s.close()


def test_forced_replay_routes_replayable_covs():
    s = build_session(MemoryStore(), plan_mode="replay", cache_bytes=0)
    cids = run_workload(s)
    st = s.checkout(cids[-3])
    assert st.covs_planned_replay > 0
    assert st.covs_recomputed == st.covs_planned_replay
    s.close()


# ---------------------------------------------------------------------------
# parity: planner on == planner off, bit for bit, on every backend
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["memory", "dir", "sqlite", "fabric"])
@pytest.mark.parametrize("mode", ["auto", "fetch", "replay"])
def test_planner_parity(kind, mode, tmp_path):
    base = build_session(make_store(kind, tmp_path / "off"), plan_mode="off",
                         cache_bytes=0)
    plnd = build_session(make_store(kind, tmp_path / mode), plan_mode=mode,
                         cache_bytes=0)
    cids_a = run_workload(base)
    cids_b = run_workload(plnd)
    assert cids_a == cids_b
    for target in (cids_a[2], cids_a[-1], cids_a[1]):
        base.checkout(target)
        plnd.checkout(target)
        assert sorted(base.ns.names()) == sorted(plnd.ns.names())
        for name in base.ns.names():
            a, b = np.asarray(base.ns[name]), np.asarray(plnd.ns[name])
            assert a.dtype == b.dtype and a.shape == b.shape
            assert np.array_equal(a, b), (name, target)
        # same chunk keys: the graphs must reference identical manifests
        na, nb = base.graph.nodes[target], plnd.graph.nodes[target]
        for ks in na.state_index:
            assert na.state_index[ks] == nb.state_index[ks]
    base.close()
    plnd.close()


def test_plan_matches_executed_paths():
    s = build_session(MemoryStore(), plan_mode="auto", cache_bytes=0)
    cids = run_workload(s)
    target = cids[-3]
    p = s.plan(target)
    st = s.checkout(target)
    n = p.counts()
    assert st.covs_planned_fetch == n["fetch"]
    assert st.covs_planned_patch == n["patch"]
    assert st.covs_planned_replay == n["replay"]
    assert st.plan_est_s == pytest.approx(p.est_total_s, rel=0.5, abs=1.0)
    s.close()


def test_det_replay_prices_fetch_at_infinity():
    """DetReplay's skipped commits are unserializable: the planner must
    price fetch at infinity and still checkout bit-identically."""
    store = MemoryStore()
    s = DetReplaySession(store, plan_mode="auto", cache_bytes=0,
                         chunk_bytes=256)
    s.register("det", lambda ns, k=1.0: ns.__setitem__(
        "w", ns["w"] * np.float32(k)), deterministic=True)
    c0 = s.init_state({"w": np.arange(128, dtype=np.float32)})
    c1 = s.run("det", k=2.0)
    c2 = s.run("det", k=3.0)
    p = s.plan(c1)
    w_plan = [c for c in p.covs if "w" in c.key]
    assert w_plan and w_plan[0].fetch_s == INF
    assert w_plan[0].path == "replay"
    st = s.checkout(c1)
    assert np.array_equal(s.ns["w"], np.arange(128, dtype=np.float32) * 2.0)
    assert st.covs_recomputed >= 1
    s.close()


# ---------------------------------------------------------------------------
# covs_recomputed: one count per replayed co-variable (satellite audit)
# ---------------------------------------------------------------------------

def test_covs_recomputed_three_deep_chain():
    """3-deep dependency chain with every chunk wiped from the store:
    checkout restores a/b/c via recursive replay (root replays too, as the
    chain's dependency).  covs_recomputed must count each distinct
    versioned co-variable exactly once — the old accounting incremented at
    both the loader call sites and inside the recursion, double-counting
    every intermediate link."""
    store = MemoryStore()
    s = KishuSession(store, chunk_bytes=256, cache_bytes=0)
    def mk(ns, name, dep):
        ns[name] = ns[dep] + np.float32(1)
    s.register("mk", mk)
    c0 = s.init_state({"root": np.zeros(64, np.float32)})
    s.run("mk", name="a", dep="root")
    s.run("mk", name="b", dep="a")
    c3 = s.run("mk", name="c", dep="b")
    # wipe the CAS: every load now falls back to replay
    store.delete_chunks(list(store.list_chunk_keys()))
    st = s.checkout(c0)
    assert st.covs_recomputed == 0       # deletes only, nothing restored
    st = s.checkout(c3)
    # distinct versioned covs restored via replay: a@c1, b@c2, c@c3, plus
    # root@c0 replayed as the chain's root dependency = 4.  (The old
    # double-counting reported 6 on this shape.)
    assert st.covs_recomputed == 4
    assert np.array_equal(s.ns["c"], np.full(64, np.float32(3)))
    s.close()


# ---------------------------------------------------------------------------
# replay memo: bound + partial-hit top-up (satellite)
# ---------------------------------------------------------------------------

def test_resolve_memo_bytes(monkeypatch):
    assert resolve_memo_bytes(123) == 123
    monkeypatch.setenv("KISHU_RESTORE_MEMO_BYTES", "4096")
    assert resolve_memo_bytes() == 4096
    monkeypatch.setenv("KISHU_RESTORE_MEMO_BYTES", "junk")
    assert resolve_memo_bytes() == 256 << 20
    monkeypatch.delenv("KISHU_RESTORE_MEMO_BYTES")
    assert resolve_memo_bytes() == 256 << 20


def test_memo_bounded_eviction(monkeypatch):
    monkeypatch.setenv("KISHU_RESTORE_MEMO_BYTES", "1024")
    s = KishuSession(MemoryStore(), chunk_bytes=256, cache_bytes=0)
    assert s.restorer.memo_bytes == 1024

    class Opaque:
        def __init__(self, v):
            self.v = v
    def grow(ns, k=0):
        ns[f"o{k}"] = Opaque(k)
        ns["carry"] = np.full(256, np.float32(k))   # 1 KiB per namespace
    s.register("grow", grow)
    s.init_state({"carry": np.zeros(256, np.float32)})
    last = None
    for k in range(6):
        last = s.run("grow", k=k)
    s.checkout(s.graph.path_from_root(last)[0])
    s.checkout(last)                     # replays the opaque chain
    # the memo held at most ~1 KiB worth of namespaces (plus the floor of
    # one entry), not all six replayed states
    assert len(s.restorer._memo) <= 2
    s.close()


def test_memo_partial_hit_tops_up_without_rerun():
    """A memoized replay missing a requested name is topped up from the
    commit's state index — the command must NOT run again."""
    s = KishuSession(MemoryStore(), chunk_bytes=256, cache_bytes=0)
    runs = {"n": 0}
    def two(ns, k=1.0):
        runs["n"] += 1
        ns["p"] = np.full(16, np.float32(k))
        ns["q"] = np.full(16, np.float32(-k))
    s.register("two", two)
    s.init_state({"seed": np.zeros(4, np.float32)})
    c1 = s.run("two", k=5.0)
    before = runs["n"]
    # replay once to seed the memo
    got = s.restorer.recompute(("p",), c1, None)
    assert runs["n"] == before + 1
    # simulate a partial namespace (regrouped request): drop q from the memo
    memo_ns = s.restorer._memo[c1]
    del memo_ns["q"]
    got = s.restorer.recompute(("q",), c1, None)
    assert np.array_equal(got["q"], np.full(16, np.float32(-5.0)))
    assert runs["n"] == before + 1       # topped up from the store, no rerun
    s.close()


def test_replay_count_in_log():
    s = build_session(MemoryStore(), plan_mode="replay", cache_bytes=0)
    cids = run_workload(s)
    s.checkout(cids[1])
    entries = {e["commit"]: e for e in s.log()}
    assert all("exec_s" in e and "replays" in e for e in entries.values())
    assert sum(e["replays"] for e in entries.values()) == s.restorer.replays
    assert any(e["replays"] > 0 for e in entries.values())
    s.close()


# ---------------------------------------------------------------------------
# ChunkCache.contains: non-mutating probe
# ---------------------------------------------------------------------------

def test_cache_contains_no_side_effects():
    c = ChunkCache(1 << 16)
    c.put("k1", b"x" * 100)
    h0, m0 = c.hits, c.misses
    assert c.contains("k1") and not c.contains("nope")
    assert (c.hits, c.misses) == (h0, m0)
    assert ChunkCache(0).contains("k1") is False
